#!/usr/bin/env bash
# Chaos/crash smoke matrix: the CI gate for the failure-domain story.
#
#   scripts/chaos_smoke.sh              # full matrix (CHAOS_SEEDS="0 1 2")
#   CHAOS_SEEDS="7" scripts/chaos_smoke.sh
#
# Five legs, each a different failure domain:
#
#   writer-kill   a real SIGKILL of a *leased* durable writer process
#                 mid-stream, once per seed; then a replica takes over
#                 the stale lease (epoch bump + WAL fence + tail drain),
#                 appends as the new epoch, probes that the dead epoch
#                 is refused with nothing written, and finally both
#                 recovery paths (latest snapshot + WAL tail vs
#                 generation-0 scratch replay) must agree bit-for-bit
#                 across the mixed-epoch log
#   chaos soak    seeded in-process fault plans (repro.launch.chaos):
#                 WAL write/fsync faults incl. torn records, replica
#                 kills, broker stalls -- gating zero acked-op loss,
#                 typed-errors-only, availability > 0 while any replica
#                 is healthy, and recovery-under-fire, per seed x
#                 {disk-fault, replica-kill, mixed}
#   failover      in-process writer-loss soak per seed: crash the
#                 leased writer mid-stream; gate promotion, fencing
#                 (split-brain resurrect probe), client reroute on
#                 NotLeader, zero acked-op loss across the handoff
#   tenant soak   disk-fault plans biting the per-tenant WAL dirs of
#                 the multi-tenant service: typed-errors-only and
#                 per-tenant zero acked-op loss
#   supervised    multi-process serving: parent writer + replica child
#                 processes, SIGKILL one child, require a supervisor
#                 restart and every slot to converge to the final gen
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

SEEDS="${CHAOS_SEEDS:-0 1 2}"

echo "== writer-kill smoke: SIGKILL a leased writer, promote, verify (seeds: $SEEDS) =="
for seed in $SEEDS; do
    CRASH_DIR=$(mktemp -d)
    python -m repro.launch.replica --writer-child --ha --dir "$CRASH_DIR" \
        --seed "$seed" --steps 100000 --snapshot-every 16 \
        > "$CRASH_DIR/writer.log" 2>&1 &
    WRITER_PID=$!
    commits=0
    for _ in $(seq 1 300); do
        commits=$(grep -c '^gen ' "$CRASH_DIR/writer.log" 2>/dev/null || true)
        [[ "${commits:-0}" -ge 24 ]] && break
        kill -0 "$WRITER_PID" 2>/dev/null || {
            cat "$CRASH_DIR/writer.log" >&2
            echo "crash-smoke writer (seed $seed) died before being killed" >&2
            exit 1
        }
        sleep 0.1
    done
    [[ "${commits:-0}" -ge 24 ]] || {
        echo "crash-smoke writer (seed $seed) made no progress" >&2; exit 1; }
    kill -9 "$WRITER_PID" 2>/dev/null
    wait "$WRITER_PID" 2>/dev/null || true
    python -m repro.launch.replica --promote-after-crash --dir "$CRASH_DIR" \
        --seed "$seed"
    python -m repro.launch.replica --verify-recovery --dir "$CRASH_DIR"
    rm -rf "$CRASH_DIR"
done

echo "== chaos soak: seeded fault plans x {disk-fault, replica-kill, mixed} =="
python -m repro.launch.chaos --smoke --seeds "${SEEDS// /,}" \
    --profiles disk-fault,replica-kill,mixed

echo "== writer failover soak: crash the leased writer, gate promotion + fencing =="
python -m repro.launch.chaos --failover --smoke --seeds "${SEEDS// /,}"

echo "== tenant soak: disk faults on per-tenant WAL dirs =="
python -m repro.launch.chaos --tenant-soak --smoke --seeds "${SEEDS// /,}"

echo "== supervised multi-process serving: SIGKILL a replica child =="
SUP_DIR=$(mktemp -d)
python -m repro.launch.replica --dir "$SUP_DIR" --supervised \
    --replicas 2 --steps 40 --chunk 24 --nv 192 --kill-child-after 3 \
    | tail -1
rm -rf "$SUP_DIR"

echo "chaos smoke OK"
