#!/usr/bin/env bash
# CI entry point: lint-light checks, tier-1 tests, stream-driver smoke.
#
#   scripts/ci.sh           # the whole gate
#   scripts/ci.sh --fast    # skip the bench smoke (tests only)
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS=cpu
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== compileall (syntax gate) =="
python -m compileall -q src tests benchmarks examples scripts

echo "== docs gate: every file the docs reference must exist =="
grep -ohE '`[a-zA-Z0-9_/.-]+\.(py|sh|md)`' docs/*.md \
    | tr -d '\`' | sort -u | while read -r f; do
    if [[ ! -f "$f" && ! -f "docs/$f" ]]; then
        echo "docs reference a missing file: $f" >&2
        exit 1
    fi
done

echo "== repair gate: dense repair must feed a matmul hook (Pallas) =="
# the dense tier's closure must run through the injected reach_blockmm
# product -- a bare scc_dense_region( call in core/ silently falls back to
# the jnp einsum everywhere, including real TPUs
python - <<'PYEOF'
import pathlib, re, sys

bad = []
for p in sorted(pathlib.Path("src/repro/core").rglob("*.py")):
    text = p.read_text()
    for m in re.finditer(r"scc_dense_region\(", text):
        head = text[:m.start()].rstrip()
        if head.endswith("def"):  # the definition itself
            continue
        i, depth = m.end(), 1  # span the whole (multi-line) call
        while i < len(text) and depth:
            depth += (text[i] == "(") - (text[i] == ")")
            i += 1
        if "matmul=" not in text[m.end():i]:
            bad.append(f"{p}:{text.count(chr(10), 0, m.start()) + 1}")
if bad:
    print("core/ scc_dense_region call site without a matmul= hook:",
          *bad, file=sys.stderr)
    sys.exit(1)
PYEOF

echo "== api gate: no raw engine call sites outside src/repro/core =="
# the typed repro.api.GraphClient is the only public surface: raw
# (kind, u, v) .apply( chunks and string-kind broker submit( calls must
# not reappear in drivers, examples, or benchmarks
if grep -rnE '\.apply\(' examples benchmarks src/repro/launch --include='*.py'; then
    echo "legacy raw .apply( call site found -- use repro.api.GraphClient" >&2
    exit 1
fi
if grep -rnE '\.submit\([[:space:]]*["'\'']' examples benchmarks src/repro/launch --include='*.py'; then
    echo "legacy string-kind submit( call site found -- use typed repro.api ops" >&2
    exit 1
fi

echo "== tier-1 tests (pytest.ini defaults to -m 'not slow') =="
python -m pytest -x -q tests/

if [[ "${1:-}" != "--fast" ]]; then
    echo "== stream service smoke (grow-and-replay + mixes + overlap + repair tiers) =="
    python -m benchmarks.bench_stream --smoke --json BENCH_stream.json
    echo "== perf-trajectory gates (BENCH_stream.json) =="
    python - <<'PYEOF'
import json

rep = json.load(open("BENCH_stream.json"))
buckets = rep["n_buckets"]
tiers = rep["repair_tier_count"]
# compile-count bound: tier dispatch is a runtime branch inside ONE
# compiled step program, so the per-config bound stays 2 x buckets (step
# paths) and is in particular <= buckets x repair-tiers per config
for row in rep["mixes"]:
    n_cfgs = 1 + row["grows"] + row["compactions"]
    bound = buckets * tiers * n_cfgs
    assert row["compiled_shapes"] <= bound, (
        f"{row['mix']}: {row['compiled_shapes']} compiled step shapes "
        f"exceed the {buckets} buckets x {tiers} tiers x {n_cfgs} "
        f"configs bound")
rt = rep["repair_tiers"]
assert rt["tier_counts"]["compact"] > 0, "compact tier never fired"
assert rt["compact_vs_full_speedup"] > 1.0, (
    "compact-sparse repair lost to full-sparse: "
    f"{rt['compact_vs_full_speedup']}x")
print("perf-trajectory gates OK:",
      f"repair speedup {rt['compact_vs_full_speedup']}x,",
      f"tier hits {rt['tier_counts']}")
PYEOF
    echo "== documented serving entry point (examples/dynamic_scc_serving.py --smoke) =="
    python examples/dynamic_scc_serving.py --smoke
fi

echo "CI OK"
