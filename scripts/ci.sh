#!/usr/bin/env bash
# CI entry point: lint-light checks, tier-1 tests, stream-driver smoke.
#
#   scripts/ci.sh           # the whole gate
#   scripts/ci.sh --fast    # skip the bench smoke (tests only)
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS=cpu
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== compileall (syntax gate) =="
python -m compileall -q src tests benchmarks examples scripts

echo "== tier-1 tests (pytest.ini defaults to -m 'not slow') =="
python -m pytest -x -q tests/

if [[ "${1:-}" != "--fast" ]]; then
    echo "== stream service smoke (grow-and-replay + both mix extremes) =="
    python -m benchmarks.bench_stream --smoke
fi

echo "CI OK"
