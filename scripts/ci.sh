#!/usr/bin/env bash
# CI entry point: lint-light checks, tier-1 tests, stream-driver smoke.
#
#   scripts/ci.sh           # the whole gate
#   scripts/ci.sh --fast    # skip the bench smoke (tests only)
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS=cpu
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== compileall (syntax gate) =="
python -m compileall -q src tests benchmarks examples scripts

echo "== docs gate: every file the docs reference must exist =="
grep -ohE '`[a-zA-Z0-9_/.-]+\.(py|sh|md)`' docs/*.md \
    | tr -d '\`' | sort -u | while read -r f; do
    if [[ ! -f "$f" && ! -f "docs/$f" ]]; then
        echo "docs reference a missing file: $f" >&2
        exit 1
    fi
done

echo "== api gate: no raw engine call sites outside src/repro/core =="
# the typed repro.api.GraphClient is the only public surface: raw
# (kind, u, v) .apply( chunks and string-kind broker submit( calls must
# not reappear in drivers, examples, or benchmarks
if grep -rnE '\.apply\(' examples benchmarks src/repro/launch --include='*.py'; then
    echo "legacy raw .apply( call site found -- use repro.api.GraphClient" >&2
    exit 1
fi
if grep -rnE '\.submit\([[:space:]]*["'\'']' examples benchmarks src/repro/launch --include='*.py'; then
    echo "legacy string-kind submit( call site found -- use typed repro.api ops" >&2
    exit 1
fi

echo "== tier-1 tests (pytest.ini defaults to -m 'not slow') =="
python -m pytest -x -q tests/

if [[ "${1:-}" != "--fast" ]]; then
    echo "== stream service smoke (grow-and-replay + mixes + reader overlap) =="
    python -m benchmarks.bench_stream --smoke
    echo "== documented serving entry point (examples/dynamic_scc_serving.py --smoke) =="
    python examples/dynamic_scc_serving.py --smoke
fi

echo "CI OK"
