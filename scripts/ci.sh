#!/usr/bin/env bash
# CI entry point: lint-light checks, tier-1 tests, stream-driver smoke.
#
#   scripts/ci.sh           # the whole gate
#   scripts/ci.sh --fast    # skip the bench smoke (tests only)
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS=cpu
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== compileall (syntax gate) =="
python -m compileall -q src tests benchmarks examples scripts

echo "== docs gate: every file the docs reference must exist =="
grep -ohE '`[a-zA-Z0-9_/.-]+\.(py|sh|md)`' docs/*.md \
    | tr -d '\`' | sort -u | while read -r f; do
    if [[ ! -f "$f" && ! -f "docs/$f" ]]; then
        echo "docs reference a missing file: $f" >&2
        exit 1
    fi
done

echo "== repair gate: dense repair must feed a matmul hook (Pallas) =="
# the dense tier's closure must run through the injected reach_blockmm
# product -- a bare scc_dense_region( call in core/ silently falls back to
# the jnp einsum everywhere, including real TPUs
python - <<'PYEOF'
import pathlib, re, sys

bad = []
for p in sorted(pathlib.Path("src/repro/core").rglob("*.py")):
    text = p.read_text()
    for m in re.finditer(r"scc_dense_region\(", text):
        head = text[:m.start()].rstrip()
        if head.endswith("def"):  # the definition itself
            continue
        i, depth = m.end(), 1  # span the whole (multi-line) call
        while i < len(text) and depth:
            depth += (text[i] == "(") - (text[i] == ")")
            i += 1
        if "matmul=" not in text[m.end():i]:
            bad.append(f"{p}:{text.count(chr(10), 0, m.start()) + 1}")
if bad:
    print("core/ scc_dense_region call site without a matmul= hook:",
          *bad, file=sys.stderr)
    sys.exit(1)
PYEOF

echo "== sparse gate: every core/ sweep call site must thread impl= =="
# the frontier_expand / hash_probe kernels only reach the dataflow when
# the call site forwards the configured impl -- a bare sweep call in
# core/ silently pins the XLA oracle everywhere, including real TPUs
python - <<'PYEOF'
import pathlib, re, sys

SWEEPS = ("forward_reach", "backward_reach", "fused_fw_bw_reach",
          "propagate_min_labels", "propagate_min_prio",
          "multi_forward_reach", "is_reachable",
          "scc_static", "scc_compact_region")
PAT = re.compile(
    r"(?<![\w.])(?:reach\.|scc\.)?(?:%s)\(" % "|".join(SWEEPS))
ET_PAT = re.compile(r"(?<![\w.])et\.(?:lookup|insert|remove|rehash|"
                    r"compact)\(")
bad = []
for p in sorted(pathlib.Path("src/repro/core").rglob("*.py")):
    text = p.read_text()
    for pat in (PAT, ET_PAT):
        for m in pat.finditer(text):
            head = text[:m.start()].rstrip()
            if head.endswith("def"):  # the definition itself
                continue
            i, depth = m.end(), 1  # span the whole (multi-line) call
            while i < len(text) and depth:
                depth += (text[i] == "(") - (text[i] == ")")
                i += 1
            if "impl=" not in text[m.end():i]:
                bad.append(f"{p}:{text.count(chr(10), 0, m.start()) + 1}")
if bad:
    print("core/ sparse-sweep call site without an impl= hook:",
          *bad, file=sys.stderr)
    sys.exit(1)
PYEOF

echo "== api gate: no raw engine call sites outside src/repro/core =="
# the typed repro.api.GraphClient is the only public surface: the old
# SCCService.apply shim is gone, and raw (kind, u, v) .apply( chunks or
# string-kind broker submit( calls must not reappear in drivers,
# examples, or benchmarks (internal layers/tests use _apply_chunk)
if grep -rnE '\.apply\(' examples benchmarks src/repro/launch --include='*.py'; then
    echo "legacy raw .apply( call site found -- use repro.api.GraphClient" >&2
    exit 1
fi
if grep -rnE '\.submit\([[:space:]]*["'\'']' examples benchmarks src/repro/launch --include='*.py'; then
    echo "legacy string-kind submit( call site found -- use typed repro.api ops" >&2
    exit 1
fi

echo "== tier-1 tests (pytest.ini defaults to -m 'not slow') =="
python -m pytest -x -q tests/

if [[ "${1:-}" != "--fast" ]]; then
    echo "== chaos gate: crash/fault/failover matrix (scripts/chaos_smoke.sh) =="
    # writer SIGKILL per seed, seeded in-process fault-plan soaks (zero
    # acked-op loss, typed errors only, availability floor, recovery
    # under fire), and the supervised multi-process replica restart
    scripts/chaos_smoke.sh
fi

if [[ "${1:-}" != "--fast" ]]; then
    echo "== stream service smoke (grow-and-replay + mixes + gate/scan + overlap + repair tiers) =="
    # appends one labelled run to the perf trajectory (BENCH_LABEL env
    # var names the point; defaults to this PR's label)
    python -m benchmarks.bench_stream --smoke --json BENCH_stream.json \
        --label "${BENCH_LABEL:-pr10-writer-failover}"
    echo "== perf-trajectory gates (BENCH_stream.json, newest run) =="
    python - <<'PYEOF'
import json

trajectory = json.load(open("BENCH_stream.json"))
assert isinstance(trajectory.get("runs"), list) and trajectory["runs"], (
    "BENCH_stream.json is not the append-friendly runs schema")
rep = trajectory["runs"][-1]  # gate the run this CI invocation appended
buckets = rep["n_buckets"]
scan_lengths = rep["n_scan_lengths"]
# compile-count bound: repair tiers and the repair gate are runtime
# branches inside ONE compiled step program; the per-config entries are
# one fused-scan program per scan length, the single-step pipelined
# program, and the serial grow-and-replay program per bucket
for row in rep["mixes"]:
    n_cfgs = 1 + row["grows"] + row["compactions"]
    bound = buckets * (scan_lengths + 1) * n_cfgs
    assert row["compiled_shapes"] <= bound, (
        f"{row['mix']}: {row['compiled_shapes']} compiled step shapes "
        f"exceed the {buckets} buckets x ({scan_lengths} scan lengths "
        f"+ serial) x {n_cfgs} configs bound")
# fused-update-engine gate: the update-heavy mix must beat the committed
# PR-4 baseline (154 combined ops/s on this smoke workload) by >= 3x,
# with the repair gate and the scan engine demonstrably in the dataflow
uh = next(r for r in rep["mixes"] if r["mix"] == "update_heavy")
assert uh["combined_per_s"] >= 3 * 154, (
    f"update-heavy mix too slow: {uh['combined_per_s']} combined ops/s "
    f"< 3 x the committed PR-4 baseline (154)")
assert uh["repair_skipped_steps"] > 0, "repair gate never skipped a step"
assert uh["scanned_chunks"] > 0, "scan engine never fused a super-chunk"
overhead = rep["client_overhead"]["overhead_frac"]
assert isinstance(overhead, float), "overhead_frac must be a scalar"
rt = rep["repair_tiers"]
assert rt["tier_counts"]["compact"] > 0, "compact tier never fired"
assert rt["compact_vs_full_speedup"] > 1.0, (
    "compact-sparse repair lost to full-sparse: "
    f"{rt['compact_vs_full_speedup']}x")
# overlap floor: concurrent readers must beat the serial baseline by a
# real margin, not a rounding error.  The floor is a RATIO because the
# absolute row is container-speed-dependent (the pr4 -> pr5 "regression"
# was exactly that: single-shot wall-clock noise across CI containers,
# the engines measure ~25% apart the OTHER way under controlled A/B --
# see run_overlap's docstring; the section is best-of-reps now).
serial_row = next(r for r in rep["overlap"] if r["mode"] == "serial_readers")
conc_row = next(r for r in rep["overlap"] if r["mode"].startswith("concurrent"))
overlap_ratio = conc_row["combined_per_s"] / serial_row["combined_per_s"]
assert overlap_ratio >= 1.25, (
    f"reader/updater overlap eroded: concurrent combined "
    f"{conc_row['combined_per_s']} ops/s is only {overlap_ratio:.2f}x "
    f"the serial baseline {serial_row['combined_per_s']} (floor 1.25x)")
# sparse-kernel-era gates (PR 7): the run must record which sparse impl
# it measured, the compact tier's median repair step must stay within an
# absolute ceiling (generous 3x over the committed pr6 6.58ms point, to
# ride out container speed variance), and the query-heavy mix must hold
# a floor relative to the committed pr6-durability trajectory point
# (0.6x in-gate: single-shot smoke throughput jitters across CI
# containers; the acceptance review compares the appended runs 1:1)
assert rep.get("kernel_impl", {}).get("frontier_expand") in (
    "pallas", "pallas_interpret", "xla"), (
    "run is missing kernel_impl provenance")
compact_med = rt["median_step_s"]["compact"]["tiered_s"]
assert compact_med <= 0.020, (
    f"compact-tier median repair step regressed: {compact_med:.4f}s "
    f"> 0.020s ceiling (pr6-durability committed 0.00658s)")
pr6 = next((r for r in trajectory["runs"]
            if r.get("label") == "pr6-durability"), None)
if pr6 is not None:
    qh = next(r for r in rep["mixes"] if r["mix"] == "query_heavy")
    qh6 = next(r for r in pr6["mixes"] if r["mix"] == "query_heavy")
    assert qh["combined_per_s"] >= 0.6 * qh6["combined_per_s"], (
        f"query-heavy mix fell below the pr6-durability floor: "
        f"{qh['combined_per_s']} < 0.6 x {qh6['combined_per_s']} ops/s")
# replica-scaling gate: 2 WAL-tailing read replicas must deliver >= 1.5x
# the combined throughput of 1 on the read-your-writes round workload
rs = rep["replicas"]
assert rs["scaling"] >= 1.5, (
    f"replica scaling regressed: {rs['counts'][-1]} replicas gave only "
    f"{rs['scaling']}x the combined ops/s of {rs['counts'][0]} (floor 1.5x)")
# multi-tenant gates (PR 8): N tenants through the shared vmapped engine
# must beat N sequential single-tenant services by >= 2x in the
# many-small-tenants regime, every tenant must stay inside the asserted
# compiled-entry registry bound, and the run must carry the admission
# telemetry (queue depth/rejects/flush causes + per-tenant lines) so
# trajectory points can be triaged without re-running
tn = rep["tenancy"]
assert tn["speedup"] >= 2.0, (
    f"multi-tenant coalescing regressed: {tn['tenants']} tenants gave "
    f"only {tn['speedup']}x the sequential baseline (floor 2.0x)")
assert tn["compile_count"] <= tn["compile_bound"], (
    f"tenant engine minted {tn['compile_count']} compiled entries, over "
    f"the {tn['compile_bound']} registry bound")
assert tn["queue"]["waves"] > 0 and "rejects" in tn["queue"] and \
    tn["queue"]["flush_causes"], "tenancy run is missing queue telemetry"
assert len(tn["per_tenant"]) == tn["tenants"] and all(
    "gen" in row and "fallback_chunks" in row for row in tn["per_tenant"]), (
    "tenancy run is missing per-tenant telemetry")
# availability gate (PR 9): killing one replica mid-window (with the
# supervisor restarting it) must keep closed-loop query throughput at
# >= 0.5x the steady window -- the caller is latency-bound, so failover
# should cost one resubmit, not half the window
av = rep["availability"]
assert av["ratio"] >= 0.5, (
    f"degraded-window availability collapsed: {av['ratio']}x of the "
    f"steady window (floor 0.5x)")
assert av["restarts"] >= 1, (
    "availability window killed a replica but the supervisor never "
    "restarted it")
# write-availability gate (PR 10): crashing the leased writer mid-window
# must cost one lease TTL + takeover, not the window -- a replica is
# promoted to the next WAL epoch and the client reroutes on NotLeader
assert av["write_availability"] >= 0.5, (
    f"write availability collapsed under writer loss: "
    f"{av['write_availability']}x of the steady window (floor 0.5x)")
assert av["promotions"] >= 1, (
    "availability window crashed the leased writer but no replica was "
    "ever promoted")
print("perf-trajectory gates OK:",
      f"update-heavy {uh['combined_per_s']} ops/s "
      f"({uh['combined_per_s'] / 154:.1f}x the PR-4 baseline),",
      f"{uh['repair_skipped_steps']} gated steps,",
      f"{uh['scanned_chunks']} scanned chunks,",
      f"client overhead {overhead:.1%},",
      f"repair speedup {rt['compact_vs_full_speedup']}x,",
      f"tier hits {rt['tier_counts']},",
      f"overlap {overlap_ratio:.2f}x,",
      f"replica scaling {rs['scaling']}x,",
      f"compact median {compact_med * 1e3:.2f}ms,",
      f"sparse impl {rep['kernel_impl']['frontier_expand']},",
      f"tenancy {tn['speedup']}x @ {tn['tenants']} tenants "
      f"({tn['compile_count']}/{tn['compile_bound']} compiled entries),",
      f"availability {av['ratio']}x under replica kill "
      f"({av['restarts']} restart(s)),",
      f"write availability {av['write_availability']}x under writer "
      f"loss ({av['promotions']} promotion(s))")
PYEOF
    echo "== documented serving entry point (examples/dynamic_scc_serving.py --smoke) =="
    python examples/dynamic_scc_serving.py --smoke
fi

echo "CI OK"
