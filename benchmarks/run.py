"""Benchmark entry point: one section per paper table/figure.

``python -m benchmarks.run [--full]`` -- default is the quick profile
(CPU-friendly); --full uses the paper-scale graph sizes.
"""
from __future__ import annotations

import argparse
import os

from benchmarks import (bench_community, bench_decremental,
                        bench_incremental, bench_kernels, bench_mix,
                        common)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    quick = not args.full
    header = ["workload", "algo", "ops", "ops_per_s", "ms"]

    print("# Fig 4a -- 50/50 add/rem mix")
    common.emit(bench_mix.run(mix=50, quick=quick), header)
    print("\n# Fig 4b -- 90/10 add/rem mix")
    common.emit(bench_mix.run(mix=90, quick=quick), header)
    print("\n# Fig 4c -- 10/90 add/rem mix")
    common.emit(bench_mix.run(mix=10, quick=quick), header)
    print("\n# Fig 4 (woDV variant) -- 50/50 edges only")
    common.emit(bench_mix.run(mix=50, include_vertex_ops=False,
                              quick=quick), header)
    print("\n# Fig 5a -- incremental only (100% add)")
    common.emit(bench_incremental.run(quick=quick), header)
    print("\n# Fig 5b -- decremental only (100% rem)")
    common.emit(bench_decremental.run(quick=quick), header)
    print("\n# Fig 5c -- community detection (80% check / 20% update)")
    common.emit(bench_community.run(quick=quick), header)
    print("\n# Locality of repair + round-collapse (paper core + beyond)")
    from benchmarks import bench_locality
    common.emit(bench_locality.run(quick=quick),
                ["graph", "measure", "n", "ms", "note"])
    print("\n# Kernel micro-benchmarks (CPU interpret -- correctness scale)")
    common.emit(bench_kernels.run(quick=quick), ["kernel", "size", "ms"])

    if os.path.exists("dryrun_results.jsonl"):
        from benchmarks import roofline
        recs = roofline.load("dryrun_results.jsonl")
        for mesh in ("16x16", "2x16x16"):
            rows = roofline.table(recs, mesh)
            if rows:
                print()
                print(roofline.render(rows, mesh))
    else:
        print("\n(no dryrun_results.jsonl -- run python -m "
              "repro.launch.dryrun --all --both-meshes for §Roofline)")


if __name__ == "__main__":
    main()
