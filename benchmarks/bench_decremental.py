"""Paper Fig 5b: decremental-only SCC maintenance (100% Rem V+E).

SMISCC in the paper's naming: dense starting graph, pure deletion
batches; repair = limited-Kosaraju-analogue split only.
"""
from __future__ import annotations

import argparse

from repro.core import baselines, dynamic
from repro.launch import workload
from benchmarks import common


def run(nv=2048, batches=(16, 64, 256, 1024), seq_ops=64, iters=3,
        quick=False):
    if quick:
        nv, batches, seq_ops, iters = 512, (16, 128), 32, 2
    cfg, state0 = common.make_engine(nv=nv, avg_degree=8)
    rows = []
    for name, fn in (("seq", baselines.sequential_apply),
                     ("coarse", baselines.coarse_apply)):
        ops = workload.op_stream(nv, seq_ops, step=0, add_frac=0.0)
        t, _ = common.time_fn(lambda o: fn(state0, o, cfg), ops,
                              iters=iters)
        rows.append(("decremental", name, seq_ops,
                     round(seq_ops / t, 1), round(t * 1e3, 2)))
    for b in batches:
        ops = workload.op_stream(nv, b, step=1, add_frac=0.0)
        t, _ = common.time_fn(
            lambda o: dynamic.apply_batch(state0, o, cfg), ops,
            iters=iters)
        rows.append(("decremental", f"smscc_b{b}", b, round(b / t, 1),
                     round(t * 1e3, 2)))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    rows = run(quick=ap.parse_args().quick)
    common.emit(rows, ["workload", "algo", "ops", "ops_per_s", "ms"])


if __name__ == "__main__":
    main()
