"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> re-analyse.

For each chosen cell, run the baseline plus a ladder of config overrides;
every rung is a full dry-run (lower + compile + roofline terms) so the
deltas are measured on the compiled artifact, not estimated.  Results are
appended to ``hillclimb_results.jsonl``; EXPERIMENTS.md §Perf narrates
the hypothesis/outcome per rung.

    PYTHONPATH=src python -m benchmarks.hillclimb --cell danube_train
    PYTHONPATH=src python -m benchmarks.hillclimb --cell moe_train
    PYTHONPATH=src python -m benchmarks.hillclimb --cell smscc_update

(Each rung compiles a 256-chip SPMD program; run cells one at a time.)
"""
from __future__ import annotations

import argparse
import json

from jax.sharding import PartitionSpec as P

# cell -> (arch, shape, [(tag, overrides, hypothesis)])
CELLS = {
    "gnn_minibatch": ("gatedgcn", "minibatch_lg", [
        ("baseline", {},
         "shipped default: nodes sharded across every mesh axis, edges "
         "on dp -- scatter-adds from dp-sharded edges into 256-way-"
         "sharded nodes dominate the collective term"),
        ("nodes_model", {"node_ax": "model"},
         "shard nodes over 'model' only: scatter targets 16 shards "
         "instead of 256 -- collective term should drop, memory term "
         "rises 16x on node arrays (still small for a 170k-node block)"),
        ("nodes_replicated", {"node_ax": None},
         "replicate nodes entirely: a sampled block holds ~170k nodes x "
         "70 features = 48 MB -- scatters become node-local partials + "
         "one all-reduce; expect the collective term to hit its floor"),
        ("nodes_repl_noremat", {"node_ax": None, "remat": False},
         "with nodes replicated the activation footprint is tiny: drop "
         "remat to cut the recompute flops/bytes"),
    ]),
    "danube_train": ("h2o-danube-3-4b", "train_4k", [
        ("baseline", {"attn_impl": "xla"},
         "paper-faithful baseline: full remat, materialized-scores "
         "attention, Megatron SP (attn_impl pinned to 'xla'; 'chunked' "
         "became the shipped default after this ladder confirmed it)"),
        ("chunked_attn", {"attn_impl": "chunked"},
         "online-softmax KV-chunked attention removes the [B,H,S,S] "
         "score tensor: memory term drops by ~2*S/d_head per layer"),
        ("remat_dots", {"remat": "dots"},
         "checkpoint-dots policy keeps matmul outputs, recomputing only "
         "cheap elementwise ops: compute term drops ~25% (8NDt -> 6NDt), "
         "memory term rises (saved activations)"),
        ("chunked+dots", {"attn_impl": "chunked", "remat": "dots"},
         "compose both: memory win of chunking + compute win of dots"),
        ("chunked+dots+nosp",
         {"attn_impl": "chunked", "remat": "dots", "act_spec": None},
         "ablation: drop sequence-parallel constraint -- expect collective "
         "term down (no per-layer seq all-gathers) but memory term up"),
    ]),
    "moe_train": ("qwen3-moe-235b-a22b", "train_4k", [
        ("baseline", {},
         "paper-faithful GShard einsum dispatch: [T,E,C] one-hot matmuls "
         "dominate the compute term (dispatch FLOPs ~ expert FLOPs)"),
        ("sort_dispatch", {"moe.dispatch": "sort"},
         "argsort-gather dispatch replaces the T*E*C*D dispatch einsums "
         "with O(T*k*D) data movement: compute term drops toward the "
         "expert-FLOP floor"),
        ("sort+dots", {"moe.dispatch": "sort", "remat": "dots"},
         "compose with checkpoint-dots: backward recompute no longer "
         "replays the expert matmuls"),
        ("sort+capacity1",
         {"moe.dispatch": "sort", "moe.capacity_factor": 1.0},
         "capacity 1.25->1.0 cuts expert buffer flops/bytes 20% at the "
         "cost of more dropped tokens (quality knob, perf measurement)"),
        ("einsum+dots", {"remat": "dots"},
         "keep the shard-friendly grouped einsum dispatch, add "
         "checkpoint-dots: backward keeps matmul outputs so the "
         "dispatch einsums are not replayed -- expect the compute term "
         "toward ~6/8 of baseline with no collective regression"),
    ]),
    "smscc_update": ("smscc", "update_1m", [
        ("baseline", {},
         "paper-faithful: labels/frontiers replicated; every fixpoint "
         "round merges shard contributions with an NV-sized all-reduce; "
         "FW and BW candidate sweeps run as two sequential fixpoints"),
        ("sharded_labels", {"label_spec": P("model")},
         "shard label/frontier arrays over 'model': per-round merge "
         "becomes reduce-scatter-sized; collective bytes drop ~16x"),
        ("sharded_labels_dp", {"label_spec": P("data")},
         "shard over 'data' instead: edge shards and label shards "
         "co-located -- tests which axis GSPMD exploits better"),
        ("fused_fwbw", {"fuse_fwbw": True},
         "run FW and BW sweeps in ONE fixpoint over a stacked [2,NV] "
         "frontier: rounds drop from d_fw+d_bw to max(d_fw,d_bw) and "
         "each round issues one 2x-wide merge instead of two -- halves "
         "collective LAUNCH count (latency-bound at ~1MB messages) and "
         "total rounds; static bytes unchanged, so the win shows in the "
         "CPU round/wall measurements"),
        ("fused+dense4k", {"fuse_fwbw": True, "dense_capacity": 4096},
         "small affected regions repair on the dense MXU closure path "
         "(reach_blockmm): per-round NV-array merges are replaced by one "
         "Rxx gather + log2(R) boolean matmuls + one scatter"),
        ("shortcut", {"shortcut": True},
         "Shiloach-Vishkin pointer doubling in the coloring sweep: "
         "label chains collapse in O(log d) rounds -- attacks the ROUND "
         "multiplier (the dominant cost is rounds x per-round terms); "
         "adds one gather per round (memory term up slightly)"),
        ("shortcut+fused", {"shortcut": True, "fuse_fwbw": True},
         "compose the round-count winners"),
    ]),
}


def cpu_wall_time(overrides, nv=2 ** 14, ec=2 ** 16, batch=2048, iters=3,
                  topology="random"):
    """Measured single-device wall time per apply_batch (captures the
    data-dependent round counts the static metering cannot).

    topology='random': degree-4 random digraph (shallow, diameter ~log n);
    topology='ring':   one nv-cycle + sparse chords (diameter ~nv/2) --
                       the adversarial case for round-synchronous sweeps.
    """
    import dataclasses
    import jax
    import numpy as np
    import time
    from repro.core import dynamic, graph_state as gs
    from repro.launch import workload

    deep = topology == "ring"
    cfg = gs.GraphConfig(n_vertices=nv, edge_capacity=ec, max_probes=128,
                         max_outer=64,
                         max_inner=2 * nv if deep else 128)
    cfg = dataclasses.replace(
        cfg, **{k: v for k, v in overrides.items()
                if k in ("fuse_fwbw", "dense_capacity", "shortcut")})
    rng = np.random.default_rng(0)
    if deep:
        ring_src = np.arange(nv)
        ring_dst = (ring_src + 1) % nv
        ch_src = rng.integers(0, nv, nv // 8)
        ch_dst = rng.integers(0, nv, nv // 8)
        state = gs.from_arrays(cfg, np.concatenate([ring_src, ch_src]),
                               np.concatenate([ring_dst, ch_dst]))
    else:
        state = gs.from_arrays(cfg, rng.integers(0, nv, nv * 4),
                               rng.integers(0, nv, nv * 4))
    state = dynamic.recompute(state, cfg)
    ops = workload.op_stream(nv, batch, step=1, add_frac=0.5)
    out = dynamic.apply_batch(state, ops, cfg)   # compile + warm
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = dynamic.apply_batch(state, ops, cfg)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def main():
    import repro.launch.dryrun as dryrun  # sets XLA_FLAGS before jax init

    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=sorted(CELLS))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="hillclimb_results.jsonl")
    ap.add_argument("--rung", default=None,
                    help="run a single named rung instead of the ladder")
    args = ap.parse_args()

    arch, shape, ladder = CELLS[args.cell]
    for tag, overrides, hypothesis in ladder:
        if args.rung and tag != args.rung:
            continue
        print(f"[hillclimb] {args.cell}:{tag} -- {hypothesis[:70]}...",
              flush=True)
        try:
            rec = dryrun.run_cell(arch, shape, args.multi_pod,
                                  overrides=overrides, tag=tag)
            rec["cell"] = args.cell
            rec["hypothesis"] = hypothesis
            if args.cell == "smscc_update":
                # rounds are data-dependent: complement the static terms
                # with measured single-device wall times on a shallow and
                # a deep (high-diameter) topology
                rec["cpu_wall_s"] = cpu_wall_time(overrides)
                rec["cpu_wall_ring_s"] = cpu_wall_time(
                    overrides, nv=2 ** 12, ec=2 ** 14, batch=512,
                    topology="ring")
        except Exception as e:  # noqa: BLE001
            import traceback
            rec = {"cell": args.cell, "tag": tag, "status": "error",
                   "error": str(e),
                   "trace": traceback.format_exc()[-1500:]}
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
        rf = rec.get("roofline", {})
        print(f"  -> {rec['status']}: compute={rf.get('compute_s', 0):.3g}s"
              f" memory={rf.get('memory_s', 0):.3g}s"
              f" collective={rf.get('collective_s', 0):.3g}s"
              f" bottleneck={rf.get('bottleneck', '-')}", flush=True)


if __name__ == "__main__":
    main()
