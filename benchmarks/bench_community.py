"""Paper Fig 5c: community detection -- 80% membership queries / 20%
updates, served through the typed public API.

Queries are wait-free in the paper; here the 80% side is typed
``SameSCC`` / ``CommunityOf`` (+ one ``CommunitySizes``) ops coalesced by
the QueryBroker into vectorized gathers against one committed snapshot
per flush (strictly stronger: thousands of membership checks cost one
memory sweep), while the 20% update side streams typed ops through the
same :class:`repro.api.GraphClient` session.  Reported per batch size:
the mixed 80/20 stream and the pure query throughput.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.api import CommunityOf, CommunitySizes, GraphClient, SameSCC, \
    updates_from_arrays
from repro.core.broker import QueryBroker
from repro.core.service import SCCService
from repro.launch import workload
from benchmarks import common


def run(nv=2048, batches=(64, 256, 1024, 4096), iters=3, quick=False):
    if quick:
        nv, batches, iters = 512, (64, 512), 2
    cfg, state0 = common.make_engine(nv=nv)
    rng = np.random.default_rng(0)
    rows = []
    for b in batches:
        q = b * 4 // 5           # 80% membership queries
        u = b - q                # 20% updates
        n_same = q * 3 // 4      # query mix: SameSCC pairs ...
        n_comm = q - n_same - 1  # ... CommunityOf points + one histogram
        qs = [SameSCC(int(a), int(c)) for a, c in
              zip(rng.integers(0, nv, n_same), rng.integers(0, nv, n_same))]
        qs += [CommunityOf(int(a)) for a in rng.integers(0, nv, n_comm)]
        qs += [CommunitySizes()]
        ops = workload.op_stream(nv, max(u, 1), step=2, add_frac=0.5)
        typed_u = updates_from_arrays(ops.kind, ops.u, ops.v)

        svc = SCCService(cfg, buckets=(max(u, 1),), state=state0)
        client = GraphClient(svc, broker=QueryBroker(
            svc, buckets=(n_same, max(n_comm, 1))))

        def mixed():
            res_q = client.submit_many(qs)
            res_u = client.submit_many(typed_u)
            return res_q[0].gen, res_u[0].gen

        t, _ = common.time_fn(mixed, iters=iters)
        rows.append(("community80/20", f"client_b{b}", b,
                     round(b / t, 1), round(t * 1e3, 2)))
        # pure query throughput (wait-free analogue): broker-coalesced
        # typed membership checks against the committed snapshot
        t, _ = common.time_fn(client.submit_many, qs, iters=iters)
        rows.append(("membership_only", f"q{q}", q, round(q / t, 1),
                     round(t * 1e3, 2)))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    rows = run(quick=ap.parse_args().quick)
    common.emit(rows, ["workload", "algo", "ops", "ops_per_s", "ms"])


if __name__ == "__main__":
    main()
