"""Paper Fig 5c: community detection -- 80% checkSCC queries / 20%
updates.  Queries are wait-free in the paper; here a query batch is one
vectorized gather (strictly stronger), so we report query and update
throughput both separately and for the mixed 80/20 stream.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import community, dynamic
from repro.data import pipeline
from benchmarks import common


def run(nv=2048, batches=(64, 256, 1024, 4096), iters=3, quick=False):
    if quick:
        nv, batches, iters = 512, (64, 512), 2
    cfg, state0 = common.make_engine(nv=nv)
    rng = np.random.default_rng(0)
    rows = []
    for b in batches:
        q = b * 4 // 5           # 80% checks
        u = b - q                # 20% updates
        qu = np.asarray(rng.integers(0, nv, q))
        qv = np.asarray(rng.integers(0, nv, q))
        ops = pipeline.op_stream(nv, max(u, 1), step=2, add_frac=0.5)

        def mixed(state):
            same = community.check_scc(state, qu, qv)
            st2, ok = dynamic.apply_batch(state, ops, cfg)
            return same, st2.ccid, ok

        t, _ = common.time_fn(mixed, state0, iters=iters)
        rows.append(("community80/20", f"smscc_b{b}", b,
                     round(b / t, 1), round(t * 1e3, 2)))
        # pure query throughput (wait-free analogue)
        t, _ = common.time_fn(
            lambda s: community.check_scc(s, qu, qv), state0, iters=iters)
        rows.append(("checkscc_only", f"q{q}", q, round(q / t, 1),
                     round(t * 1e3, 2)))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    rows = run(quick=ap.parse_args().quick)
    common.emit(rows, ["workload", "algo", "ops", "ops_per_s", "ms"])


if __name__ == "__main__":
    main()
