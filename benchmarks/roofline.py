"""Roofline reporter: dryrun_results.jsonl -> markdown table + summary.

Per (arch × shape × mesh): the three terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS useful ratio, and the roofline fraction

    fraction = compute_s / max(compute_s, memory_s, collective_s)

i.e. how close the cell is to being compute-bound at peak; 1.0 means the
compute term dominates (the best any schedule can do is the FLOP roofline).
"""
from __future__ import annotations

import argparse
import json


def load(path):
    recs = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fraction(r):
    rf = r.get("roofline")
    if not rf:
        return None
    mx = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
    return rf["compute_s"] / mx if mx else None


def table(recs, mesh="16x16"):
    rows = []
    for (arch, shape, m), r in sorted(recs.items()):
        if m != mesh:
            continue
        if r["status"] == "skipped":
            rows.append((arch, shape, "SKIP", "-", "-", "-", "-", "-",
                         r.get("reason", "")[:40]))
            continue
        if r["status"] != "ok":
            rows.append((arch, shape, "ERR", "-", "-", "-", "-", "-",
                         r.get("error", "")[:40]))
            continue
        rf = r["roofline"]
        rows.append((
            arch, shape, rf["bottleneck"].replace("_s", ""),
            f"{rf['compute_s']:.3g}", f"{rf['memory_s']:.3g}",
            f"{rf['collective_s']:.3g}",
            f"{fraction(r):.2f}" if fraction(r) is not None else "-",
            f"{rf['useful_ratio']:.2f}" if rf.get("useful_ratio") else "-",
            ""))
    return rows


def render(rows, mesh):
    hdr = ["arch", "shape", "bottleneck", "compute_s", "memory_s",
           "collective_s", "roofline_frac", "useful_ratio", "note"]
    out = [f"### Mesh {mesh}", "",
           "| " + " | ".join(hdr) + " |",
           "|" + "|".join(["---"] * len(hdr)) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(x) for x in r) + " |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="dryrun_results.jsonl")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    recs = load(args.results)
    print(render(table(recs, args.mesh), args.mesh))


if __name__ == "__main__":
    main()
