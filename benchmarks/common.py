"""Shared benchmark harness: deterministic graphs, timing, CSV output."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dynamic, graph_state as gs


def make_engine(nv=2048, ec=2 ** 14, seed=0, avg_degree=4,
                dense_capacity=0):
    """Pre-loaded dynamic engine: random digraph, labels computed."""
    cfg = gs.GraphConfig(n_vertices=nv, edge_capacity=ec,
                         max_probes=128, max_outer=64, max_inner=128,
                         dense_capacity=dense_capacity)
    rng = np.random.default_rng(seed)
    e = nv * avg_degree
    src = rng.integers(0, nv, e)
    dst = rng.integers(0, nv, e)
    state = gs.from_arrays(cfg, src, dst)
    state = dynamic.recompute(state, cfg)
    jax.block_until_ready(state.ccid)
    return cfg, state


def time_fn(fn, *args, iters=3, warmup=1):
    """Median wall time of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


def emit(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    return rows
