"""Streaming-service throughput: sustained ops/sec across workload mixes.

The paper (Fig 4/5) measures an *on-line* system: a fixed pool of update
threads applies an unbounded stream while readers run SameSCC queries
concurrently.  This bench drives the serving stack through the typed
public API (:class:`repro.api.GraphClient` over
:class:`repro.core.service.SCCService`) -- grow-and-replay, bucketed batch
scheduling, the pipelined in-flight update window, periodic compaction --
with the paper's mix axes:

  update-heavy   90% inserts, no queries        (Fig 4b analogue)
  balanced       50/50 add/remove + queries     (Fig 4a analogue)
  query-heavy    mostly reader batches          (Fig 5 analogue)

then demonstrates the paper's headline *overlap* claim: the same update
mix run once with serial query interleaving (`run_stream`) and once with
per-reader client sessions over a QueryBroker dispatcher
(`run_concurrent_stream --readers N`).  Combined (update+query)
throughput with concurrent readers must exceed the serial baseline --
queries execute against the committed snapshot while the next update step
is still in flight.

Finally the **client-overhead** section prices the facade itself: the
same deterministic stream driven once through typed ops +
``GraphClient.submit_many`` and once through the internal raw-array
entry points, asserting the typed path keeps >= 85% of the internal
path's combined ops/s (facade cost < 15%).

Reported per mix: update ops/s, query ops/s, combined ops/s, number of
compiled step shapes (bounded by 2 x bucket-count x capacity-growth count
no matter the stream length: pipelined + serial-replay jit entries), table
grows, compactions.

    PYTHONPATH=src python -m benchmarks.bench_stream [--smoke] [--full]
                                                     [--readers N]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro import configs
from repro.core import graph_state as gs
from repro.core.service import SCCService
from repro.launch import stream
from benchmarks import common


def booted_service(cfg, buckets):
    """Service over a graph with every vertex slot live (singleton SCCs):
    edge inserts then land immediately, so an undersized table must grow."""
    return SCCService(cfg, buckets=buckets, state=gs.all_singletons(cfg))

MIXES = {
    "update_heavy": dict(add_frac=0.9, query_frac=0.0),
    "balanced": dict(add_frac=0.5, query_frac=0.5),
    "query_heavy": dict(add_frac=0.5, query_frac=1.0),
}


def assert_compile_bound(rep, buckets):
    # grows AND capacity-escalating compactions each mint a new
    # GraphConfig (hence up to len(buckets) fresh step shapes); the
    # pipelined fast path and the serial grow-and-replay path are
    # separate jit entries, hence the factor 2
    n_cfgs = 1 + rep["grows"] + rep["compactions"]
    assert rep["compile_count"] <= 2 * len(buckets) * n_cfgs, (
        "per-chunk recompilation detected: "
        f"{rep['compile_count']} compiled shapes for "
        f"{len(buckets)} buckets x {n_cfgs} configs x 2 step paths")


def run(nv=4096, edge_capacity=4096, n_ops=16384, chunk=512,
        buckets=(128, 512), n_queries=2048, mixes=None, seed=0):
    """One service per mix (fresh table so growth cost is included)."""
    smscc = configs.get("smscc")
    rows = []
    for name in (mixes or MIXES):
        mix = MIXES[name]
        cfg = smscc.config(n_vertices=nv, edge_capacity=edge_capacity,
                           max_probes=64, max_outer=64, max_inner=128)
        svc = booted_service(cfg, buckets)
        rep = stream.run_stream(
            svc, n_ops=n_ops, chunk=chunk, n_queries=n_queries,
            seed=seed, **mix)
        rows.append((name, rep["ops"], rep["ops_per_s"], rep["queries"],
                     rep["queries_per_s"], rep["combined_per_s"],
                     rep["compile_count"], rep["grows"],
                     rep["compactions"], rep["edge_capacity"]))
        assert_compile_bound(rep, buckets)
    return rows


def _warm_caches(fresh, chunk, n_queries):
    """Warm the shared jit cache (step buckets + both query shapes at the
    boot cfg) on a throwaway service, through the same typed-client path
    the timed runs use, so neither timed run is charged compile time the
    other gets for free; growth-minted configs compile identically in
    both runs (same deterministic update stream)."""
    from repro.api import GraphClient, Reachable, SameSCC
    from repro.core.broker import QueryBroker

    warm = fresh()
    # same query-bucket registry as both timed drivers, so the compiled
    # query shapes are all paid for here
    client = GraphClient(warm, broker=QueryBroker(
        warm, buckets=tuple(sorted({n_queries, min(32, n_queries)}))))
    ops = stream.typed_op_stream(warm.cfg.n_vertices, chunk, step=0,
                                 add_frac=0.5, seed=999)
    client.submit_many(ops)
    client.submit_many([SameSCC(0, 0)] * n_queries)
    client.submit_many([Reachable(0, 0)] * min(32, n_queries))
    client.close()


def run_overlap(nv=4096, edge_capacity=4096, n_ops=16384, chunk=512,
                buckets=(128, 512), n_queries=2048, readers=2, seed=0):
    """Serial-reader baseline vs concurrent reader pool on the SAME update
    mix (balanced): the paper's Fig 4/5 overlap demonstration."""
    smscc = configs.get("smscc")

    def fresh():
        cfg = smscc.config(n_vertices=nv, edge_capacity=edge_capacity,
                           max_probes=64, max_outer=64, max_inner=128)
        return booted_service(cfg, buckets)

    _warm_caches(fresh, chunk, n_queries)

    # both modes are scored on full wall clock (workload generation and
    # thread startup included) so the comparison is symmetric
    t0 = time.perf_counter()
    serial = stream.run_stream(fresh(), n_ops=n_ops, add_frac=0.5,
                               query_frac=1.0, chunk=chunk,
                               n_queries=n_queries, seed=seed)
    serial_wall = time.perf_counter() - t0
    serial_combined = int((serial["ops"] + serial["queries"]) /
                          serial_wall)
    conc = stream.run_concurrent_stream(fresh(), n_ops=n_ops,
                                        readers=readers, add_frac=0.5,
                                        chunk=chunk, n_queries=n_queries,
                                        seed=seed)
    assert_compile_bound(conc, buckets)
    rows = [("serial_readers", serial["ops"], serial["ops_per_s"],
             serial["queries"], serial["queries_per_s"],
             serial_combined, 0),
            (f"concurrent_x{readers}", conc["ops"], conc["ops_per_s"],
             conc["queries"], conc["queries_per_s"],
             conc["combined_per_s"], readers)]
    assert conc["combined_per_s"] > serial_combined, (
        "no reader/updater overlap: concurrent combined throughput "
        f"{conc['combined_per_s']} ops/s did not beat the serial "
        f"baseline {serial_combined} ops/s")
    return rows


def run_client_overhead(nv=4096, edge_capacity=4096, n_ops=8192,
                        chunk=512, buckets=(128, 512), n_queries=1024,
                        seed=0, reps=3, max_overhead=0.15):
    """Price the typed facade: the same deterministic update+query stream
    through (a) typed ops + ``GraphClient.submit_many`` and (b) the
    internal raw-array entry points (``SCCService._apply_chunk`` +
    direct snapshot queries) -- identical device work, so the delta is
    pure client-layer overhead (op objects, encoding, broker futures).

    Asserts the typed path sustains >= ``1 - max_overhead`` of the
    internal path's combined ops/s (min-of-``reps`` wall times, plus a
    small absolute slack so tiny smoke runs don't flake on scheduler
    noise)."""
    from repro.api import GraphClient, SameSCC
    from repro.core.broker import QueryBroker
    from repro.data import pipeline

    smscc = configs.get("smscc")

    def fresh():
        cfg = smscc.config(n_vertices=nv, edge_capacity=edge_capacity,
                           max_probes=64, max_outer=64, max_inner=128)
        return booted_service(cfg, buckets)

    n_chunks = n_ops // chunk
    raw, typed, qpairs, typed_q = [], [], [], []
    for step in range(n_chunks):
        ops = pipeline.op_stream(nv, chunk, step=step, add_frac=0.5,
                                 seed=seed)
        arrs = (np.asarray(ops.kind), np.asarray(ops.u),
                np.asarray(ops.v))
        raw.append(arrs)
        typed.append(stream.typed_op_stream(nv, chunk, step=step,
                                            add_frac=0.5, seed=seed))
        rng = np.random.default_rng(seed + step)
        qu = rng.integers(0, nv, n_queries)
        qv = rng.integers(0, nv, n_queries)
        qpairs.append((qu, qv))
        typed_q.append([SameSCC(int(a), int(b)) for a, b in zip(qu, qv)])

    def time_direct():
        svc = fresh()
        t0 = time.perf_counter()
        for arrs, (qu, qv) in zip(raw, qpairs):
            svc._apply_chunk(*arrs)
            svc.same_scc(qu, qv)
        return time.perf_counter() - t0

    def time_typed():
        svc = fresh()
        # broker bucket == query batch size so both paths run identical
        # device shapes; only the facade differs
        client = GraphClient(svc, broker=QueryBroker(
            svc, buckets=(n_queries,)))
        t0 = time.perf_counter()
        for ops, qs in zip(typed, typed_q):
            client.submit_many(ops)
            client.submit_many(qs)
        dt = time.perf_counter() - t0
        client.close()
        return dt

    time_direct()  # shared-cache warmup for both paths' jit entries
    time_typed()
    t_direct = min(time_direct() for _ in range(reps))
    t_typed = min(time_typed() for _ in range(reps))
    total = n_chunks * (chunk + n_queries)
    direct_ps = int(total / t_direct)
    typed_ps = int(total / t_typed)
    rows = [("internal_raw", total, direct_ps, round(t_direct, 4)),
            ("typed_client", total, typed_ps, round(t_typed, 4)),
            ("overhead_frac", "", "",
             round(max(0.0, t_typed / t_direct - 1.0), 4))]
    assert t_typed <= t_direct * (1 + max_overhead) + 0.05, (
        f"GraphClient facade too expensive: {t_typed:.4f}s typed vs "
        f"{t_direct:.4f}s internal "
        f"({(t_typed / t_direct - 1) * 100:.1f}% > {max_overhead:.0%})")
    return rows


HEADER = ["mix", "ops", "ops_per_s", "queries", "queries_per_s",
          "combined_per_s", "compiled_shapes", "grows", "compactions",
          "final_capacity"]
OVERLAP_HEADER = ["mode", "ops", "ops_per_s", "queries", "queries_per_s",
                  "combined_per_s", "readers"]
OVERHEAD_HEADER = ["path", "ops", "combined_per_s", "wall_s"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CPU-friendly run (CI: exercises grow + "
                         "replay + both mix extremes + reader overlap + "
                         "the facade-overhead bound end-to-end)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale graph (slow; accelerator advised)")
    ap.add_argument("--readers", type=int, default=2,
                    help="reader threads for the overlap comparison")
    args = ap.parse_args()
    if args.smoke:
        # capacity starts undersized on purpose so the smoke run also
        # covers grow-and-replay
        rows = run(nv=256, edge_capacity=256, n_ops=1024, chunk=128,
                   buckets=(32, 128), n_queries=256,
                   mixes=("update_heavy", "query_heavy"))
        overlap = run_overlap(nv=256, edge_capacity=1024, n_ops=1024,
                              chunk=128, buckets=(32, 128), n_queries=256,
                              readers=args.readers)
        overhead = run_client_overhead(nv=256, edge_capacity=1024,
                                       n_ops=1024, chunk=128,
                                       buckets=(32, 128), n_queries=256)
    elif args.full:
        rows = run(nv=2 ** 17, edge_capacity=2 ** 18, n_ops=2 ** 17,
                   chunk=4096, buckets=(1024, 4096), n_queries=2 ** 15)
        overlap = run_overlap(nv=2 ** 17, edge_capacity=2 ** 18,
                              n_ops=2 ** 17, chunk=4096,
                              buckets=(1024, 4096), n_queries=2 ** 15,
                              readers=args.readers)
        overhead = run_client_overhead(nv=2 ** 17, edge_capacity=2 ** 18,
                                       n_ops=2 ** 16, chunk=4096,
                                       buckets=(1024, 4096),
                                       n_queries=2 ** 14)
    else:
        rows = run()
        overlap = run_overlap(readers=args.readers)
        overhead = run_client_overhead()
    common.emit(rows, HEADER)
    common.emit(overlap, OVERLAP_HEADER)
    common.emit(overhead, OVERHEAD_HEADER)


if __name__ == "__main__":
    main()
