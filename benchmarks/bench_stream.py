"""Streaming-service throughput: sustained ops/sec across workload mixes.

The paper (Fig 4/5) measures an *on-line* system: a fixed pool of update
threads applies an unbounded stream while readers run SameSCC queries
concurrently.  This bench drives the serving stack through the typed
public API (:class:`repro.api.GraphClient` over
:class:`repro.core.service.SCCService`) -- grow-and-replay, bucketed batch
scheduling, the pipelined in-flight update window, periodic compaction --
with the paper's mix axes:

  update-heavy   90% inserts, no queries        (Fig 4b analogue)
                 measured as build phase + steady-state phase: the
                 steady phase re-adds live edges / removes absent pairs
                 (structure-preserving), so the in-graph repair gate
                 skips phase 5 and the lax.scan super-chunk engine
                 amortizes dispatch -- the paper's claim that most ops
                 leave SCC structure alone is what the row prices
  balanced       50/50 add/remove + queries     (Fig 4a analogue)
  query-heavy    mostly reader batches          (Fig 5 analogue)

then demonstrates the paper's headline *overlap* claim: the same update
mix run once with serial query interleaving (`run_stream`) and once with
per-reader client sessions over a QueryBroker dispatcher
(`run_concurrent_stream --readers N`).  Combined (update+query)
throughput with concurrent readers must exceed the serial baseline --
queries execute against the committed snapshot while the next update step
is still in flight.

The **client-overhead** section prices the facade itself: the same
deterministic stream driven once through typed ops +
``GraphClient.submit_many`` and once through the internal raw-array
entry points, asserting the typed path keeps >= 85% of the internal
path's combined ops/s (facade cost < 15%).

The **replica** section (PR-6) measures the durability stack: a WAL-
backed durable writer plus N read replicas tailing the log serve
closed-loop read-your-writes reader rounds
(:func:`repro.launch.replica.run_replicated_stream`); combined
throughput must scale >= 1.5x from 1 to 2 replicas (staggered replica
poll grids hide replication lag -- a latency-bound regime, so the
scaling is honest on a single core).

The **availability** section (PR-9/PR-10) prices the failure domain:
the same closed-loop replica-served query workload in a steady window
vs a window opened by killing a replica (the supervisor restarts it
mid-window), then closed-loop writes in a steady window vs a window
opened by crashing the *leased* writer (a replica is lease-promoted to
the next WAL epoch mid-window and the client reroutes on ``NotLeader``);
both degraded-window throughput ratios are gated >= 0.5x by
``scripts/ci.sh``.

Finally the **repair-tier** section measures the tiered repair engine on
the paper's locality-of-repair shape (tiny affected regions inside a
large table): the identical small-region workload under the tiered and
untiered configs, per-tier hit counts and median step latency, asserting
the compact-sparse tier's median step beats the full-sparse sweep.

Reported per mix: update ops/s, query ops/s, combined ops/s, number of
compiled step shapes (bounded by bucket-count x (scan-lengths + 1) x
capacity-growth count no matter the stream length: fused-scan + pipelined
+ serial-replay jit entries), table grows, compactions, steady-phase op
count, and the fused-engine counters (``repair_skipped_steps``,
``scanned_chunks``).  ``--json PATH`` *appends* the report to the
perf-trajectory file (``{"runs": [...]}``, one labelled entry per run)
-- ``scripts/ci.sh`` records it as ``BENCH_stream.json`` and gates on
the newest run, so the trajectory accumulates across PRs.

    PYTHONPATH=src python -m benchmarks.bench_stream [--smoke] [--full]
                                                     [--readers N]
                                                     [--json PATH]
                                                     [--label NAME]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro import configs
from repro.configs.smscc import SCAN_LENGTHS
from repro.core import dynamic, graph_state as gs
from repro.core.service import SCCService
from repro.launch import stream
from benchmarks import common


def booted_service(cfg, buckets):
    """Service over a graph with every vertex slot live (singleton SCCs):
    edge inserts then land immediately, so an undersized table must grow.
    Runs the full fused update engine: scan-length super-chunks plus
    proactive growth (growth rehashes happen ahead of a chunk that cannot
    fit, instead of as doomed-dispatch + serial-replay + recompile waves
    on the critical path)."""
    return SCCService(cfg, buckets=buckets, state=gs.all_singletons(cfg),
                      scan_lengths=SCAN_LENGTHS, proactive_grow=True)

MIXES = {
    "update_heavy": dict(add_frac=0.9, query_frac=0.0),
    "balanced": dict(add_frac=0.5, query_frac=0.5),
    "query_heavy": dict(add_frac=0.5, query_frac=1.0),
}


def assert_compile_bound(rep, buckets):
    # grows AND capacity-escalating compactions each mint a new
    # GraphConfig (hence up to len(buckets) fresh step shapes); per
    # config the step entries are one fused-scan program per registered
    # scan length > 1, the single-step pipelined program, and the serial
    # grow-and-replay program -- len(scan_lengths) + 1 per bucket
    n_cfgs = 1 + rep["grows"] + rep["compactions"]
    bound = len(buckets) * (len(SCAN_LENGTHS) + 1) * n_cfgs
    assert rep["compile_count"] <= bound, (
        "per-chunk recompilation detected: "
        f"{rep['compile_count']} compiled shapes for {len(buckets)} "
        f"buckets x ({len(SCAN_LENGTHS)} scan lengths + serial) x "
        f"{n_cfgs} configs")


def run_steady_phase(svc, n_ops, chunk, seed):
    """Structure-preserving churn against the built graph -- the paper's
    steady-state regime where most ops change no SCC structure.

    90% of lanes re-add already-live edges, 10% remove absent pairs; the
    repair gate proves every step's region empty (``repair_skipped_steps``
    advances) and the scan engine amortizes the dispatches, which is
    exactly where the paper's 3-6x mixed-update headline lives."""
    from repro.api import AddEdge, GraphClient, RemoveEdge

    nv = svc.cfg.n_vertices
    live = sorted(svc.edge_set())
    assert live, "steady phase needs a non-empty graph"
    live_set = set(live)
    rng = np.random.default_rng(seed + 0x5EAD)
    client = GraphClient(svc)
    applied = 0
    t0 = time.perf_counter()
    while applied < n_ops:
        n = min(chunk, n_ops - applied)
        ops = []
        for _ in range(n):
            if rng.random() < 0.9:
                a, b = live[int(rng.integers(len(live)))]
                ops.append(AddEdge(int(a), int(b)))
            else:
                while True:
                    a = int(rng.integers(nv))
                    b = int(rng.integers(nv))
                    if (a, b) not in live_set:
                        break
                ops.append(RemoveEdge(a, b))
        client.submit_many(ops)
        applied += n
    wall = time.perf_counter() - t0
    client.close()
    return {"ops": applied, "wall_s": wall}


def run(nv=4096, edge_capacity=4096, n_ops=16384, chunk=512,
        buckets=(128, 512), n_queries=2048, mixes=None, seed=0):
    """One service per mix (fresh table so growth cost is included).

    The update-heavy mix is measured in two phases on one service: the
    build stream (random mixed updates from an undersized table, growth
    included) followed by an equally long steady-state phase
    (:func:`run_steady_phase`).  The row's throughput covers both; the
    ``steady_ops`` column records the split and the
    ``repair_skipped_steps`` / ``scanned_chunks`` columns show the fused
    engine doing its job."""
    smscc = configs.get("smscc")

    def mix_cfg():
        return smscc.config(n_vertices=nv, edge_capacity=edge_capacity,
                            max_probes=64, max_outer=64, max_inner=128)

    # Boot-config step and query shapes are warmed once on a throwaway
    # service (a NOP chunk: the repair gate skips it, so this is pure
    # compilation; the query registry matches run_stream's).  Growth-
    # minted configs still compile inside the timed runs -- growth cost
    # stays included, exactly the PR-4 accounting where later mixes
    # reused the first mix's boot-config jit entries.
    from repro.api import GraphClient, Reachable, SameSCC
    from repro.core.broker import QueryBroker

    warm = booted_service(mix_cfg(), buckets)
    zeros = np.zeros(chunk, np.int32)
    warm._apply_chunk(np.full(chunk, dynamic.NOP, np.int32), zeros, zeros)
    n_reach = min(32, n_queries)
    warm_client = GraphClient(warm, broker=QueryBroker(
        warm, buckets=tuple(sorted({n_queries, n_reach}))))
    warm_client.submit_many([SameSCC(0, 0)] * n_queries)
    warm_client.submit_many([Reachable(0, 0)] * n_reach)
    warm_client.close()

    rows = []
    for name in (mixes or MIXES):
        mix = MIXES[name]
        svc = booted_service(mix_cfg(), buckets)
        rep = stream.run_stream(
            svc, n_ops=n_ops, chunk=chunk, n_queries=n_queries,
            seed=seed, **mix)
        ops, t_update, n_steady = rep["ops"], rep["update_s"], 0
        if name == "update_heavy":
            n_steady = n_ops
            steady = run_steady_phase(svc, n_steady, chunk, seed)
            ops += steady["ops"]
            t_update += steady["wall_s"]
            rep.update(svc.stats())  # cumulative over both phases
        wall = t_update + rep["query_s"]
        rows.append((name, ops,
                     int(ops / t_update) if t_update else 0,
                     rep["queries"], rep["queries_per_s"],
                     int((ops + rep["queries"]) / wall) if wall else 0,
                     rep["compile_count"], rep["grows"],
                     rep["compactions"], rep["edge_capacity"], n_steady,
                     rep["repair_skipped_steps"], rep["scanned_chunks"]))
        assert_compile_bound(rep, buckets)
    return rows


def _warm_caches(fresh, chunk, n_queries):
    """Warm the shared jit cache (step buckets + both query shapes at the
    boot cfg) on a throwaway service, through the same typed-client path
    the timed runs use, so neither timed run is charged compile time the
    other gets for free; growth-minted configs compile identically in
    both runs (same deterministic update stream)."""
    from repro.api import GraphClient, Reachable, SameSCC
    from repro.core.broker import QueryBroker

    warm = fresh()
    # same query-bucket registry as both timed drivers, so the compiled
    # query shapes are all paid for here
    client = GraphClient(warm, broker=QueryBroker(
        warm, buckets=tuple(sorted({n_queries, min(32, n_queries)}))))
    ops = stream.typed_op_stream(warm.cfg.n_vertices, chunk, step=0,
                                 add_frac=0.5, seed=999)
    client.submit_many(ops)
    client.submit_many([SameSCC(0, 0)] * n_queries)
    client.submit_many([Reachable(0, 0)] * min(32, n_queries))
    client.close()


def run_overlap(nv=4096, edge_capacity=4096, n_ops=16384, chunk=512,
                buckets=(128, 512), n_queries=2048, readers=2, seed=0,
                reps=2):
    """Serial-reader baseline vs concurrent reader pool on the SAME update
    mix (balanced): the paper's Fig 4/5 overlap demonstration.

    Each mode is run ``reps`` times and scored on its best rep.  The
    section is wall-clock-sensitive (threads + single-shot streams), and
    single-shot scoring is what produced the phantom pr4 -> pr5
    "regression" in the trajectory: controlled A/B on one machine shows
    the pr5 engine is ~25% *faster* on this exact workload, while the
    committed single-shot numbers moved 137,925 -> 66,700 across two CI
    containers whose min-of-reps client-overhead sections agree within
    1.5%.  Best-of-reps makes the trajectory row mean what it says."""
    smscc = configs.get("smscc")

    def fresh():
        cfg = smscc.config(n_vertices=nv, edge_capacity=edge_capacity,
                           max_probes=64, max_outer=64, max_inner=128)
        return booted_service(cfg, buckets)

    _warm_caches(fresh, chunk, n_queries)

    # both modes are scored on full wall clock (workload generation and
    # thread startup included) so the comparison is symmetric
    serial, serial_combined = None, 0
    for _ in range(reps):
        t0 = time.perf_counter()
        rep = stream.run_stream(fresh(), n_ops=n_ops, add_frac=0.5,
                                query_frac=1.0, chunk=chunk,
                                n_queries=n_queries, seed=seed)
        wall = time.perf_counter() - t0
        combined = int((rep["ops"] + rep["queries"]) / wall)
        if combined >= serial_combined:
            serial, serial_combined = rep, combined
    conc = None
    for _ in range(reps):
        rep = stream.run_concurrent_stream(fresh(), n_ops=n_ops,
                                           readers=readers, add_frac=0.5,
                                           chunk=chunk,
                                           n_queries=n_queries, seed=seed)
        if conc is None or rep["combined_per_s"] > conc["combined_per_s"]:
            conc = rep
    assert_compile_bound(conc, buckets)
    rows = [("serial_readers", serial["ops"], serial["ops_per_s"],
             serial["queries"], serial["queries_per_s"],
             serial_combined, 0),
            (f"concurrent_x{readers}", conc["ops"], conc["ops_per_s"],
             conc["queries"], conc["queries_per_s"],
             conc["combined_per_s"], readers)]
    assert conc["combined_per_s"] > serial_combined, (
        "no reader/updater overlap: concurrent combined throughput "
        f"{conc['combined_per_s']} ops/s did not beat the serial "
        f"baseline {serial_combined} ops/s")
    return rows


def run_client_overhead(nv=4096, edge_capacity=4096, n_ops=8192,
                        chunk=512, buckets=(128, 512), n_queries=1024,
                        seed=0, reps=3, max_overhead=0.15):
    """Price the typed facade: the same deterministic update+query stream
    through (a) typed ops + ``GraphClient.submit_many`` and (b) the
    internal raw-array entry points (``SCCService._apply_chunk`` +
    direct snapshot queries) -- identical device work, so the delta is
    pure client-layer overhead (op objects, encoding, broker futures).

    Asserts the typed path sustains >= ``1 - max_overhead`` of the
    internal path's combined ops/s (min-of-``reps`` wall times, plus a
    small absolute slack so tiny smoke runs don't flake on scheduler
    noise)."""
    from repro.api import GraphClient, SameSCC
    from repro.core.broker import QueryBroker
    from repro.launch import workload

    smscc = configs.get("smscc")

    def fresh():
        cfg = smscc.config(n_vertices=nv, edge_capacity=edge_capacity,
                           max_probes=64, max_outer=64, max_inner=128)
        return booted_service(cfg, buckets)

    n_chunks = n_ops // chunk
    raw, typed, qpairs, typed_q = [], [], [], []
    for step in range(n_chunks):
        ops = workload.op_stream(nv, chunk, step=step, add_frac=0.5,
                                 seed=seed)
        arrs = (np.asarray(ops.kind), np.asarray(ops.u),
                np.asarray(ops.v))
        raw.append(arrs)
        typed.append(stream.typed_op_stream(nv, chunk, step=step,
                                            add_frac=0.5, seed=seed))
        rng = np.random.default_rng(seed + step)
        qu = rng.integers(0, nv, n_queries)
        qv = rng.integers(0, nv, n_queries)
        qpairs.append((qu, qv))
        typed_q.append([SameSCC(int(a), int(b)) for a, b in zip(qu, qv)])

    def time_direct():
        svc = fresh()
        t0 = time.perf_counter()
        for arrs, (qu, qv) in zip(raw, qpairs):
            svc._apply_chunk(*arrs)
            svc.same_scc(qu, qv)
        return time.perf_counter() - t0

    def time_typed():
        svc = fresh()
        # broker bucket == query batch size so both paths run identical
        # device shapes; only the facade differs
        client = GraphClient(svc, broker=QueryBroker(
            svc, buckets=(n_queries,)))
        t0 = time.perf_counter()
        for ops, qs in zip(typed, typed_q):
            client.submit_many(ops)
            client.submit_many(qs)
        dt = time.perf_counter() - t0
        client.close()
        return dt

    time_direct()  # shared-cache warmup for both paths' jit entries
    time_typed()
    t_direct = min(time_direct() for _ in range(reps))
    t_typed = min(time_typed() for _ in range(reps))
    total = n_chunks * (chunk + n_queries)
    direct_ps = int(total / t_direct)
    typed_ps = int(total / t_typed)
    rows = [("internal_raw", total, direct_ps, round(t_direct, 4)),
            ("typed_client", total, typed_ps, round(t_typed, 4))]
    overhead_frac = round(max(0.0, t_typed / t_direct - 1.0), 4)
    assert t_typed <= t_direct * (1 + max_overhead) + 0.05, (
        f"GraphClient facade too expensive: {t_typed:.4f}s typed vs "
        f"{t_direct:.4f}s internal "
        f"({(t_typed / t_direct - 1) * 100:.1f}% > {max_overhead:.0%})")
    return rows, overhead_frac


def run_repair_tiers(nv=8192, edge_capacity=2 ** 15, cycle=8, steps=48,
                     touched_cycles=2, seed=0, assert_speedup=True):
    """The repair-tier section: small-region repair on a large graph.

    The base graph is ``nv / cycle`` disjoint directed cycles (one SCC
    each).  Every step removes the edges of a few random cycles and
    re-adds them in the same batch, so the affected region is just those
    cycles' members -- the paper's locality-of-repair shape: the region
    stays tiny while the table stays huge.  A handful of steps are forced
    tiny (dense tier) or huge (full tier) so every tier reports a hit.

    The identical deterministic op sequence runs once under the tiered
    config and once under the untiered full-sparse baseline; per-step wall
    times are grouped by the tier the tiered run reported.  Asserts the
    compact-sparse tier's median step beats the full-sparse baseline's
    median over the very same steps.
    """
    smscc = configs.get("smscc")
    n_cycles = nv // cycle
    vcap = max(64, touched_cycles * cycle * 4)

    def build(tiered: bool):
        kw = dict(n_vertices=nv, edge_capacity=edge_capacity,
                  max_probes=64, max_outer=64, max_inner=128)
        if tiered:
            # dense tier sized to one cycle, compact to a few, full beyond
            kw.update(dense_capacity=cycle, region_vertex_capacity=vcap,
                      region_edge_buckets=(256, 4096))
        else:
            kw.update(dense_capacity=0, region_vertex_capacity=0)
        cfg = smscc.config(**kw)
        base = np.arange(nv, dtype=np.int32)
        src = base
        dst = (base // cycle) * cycle + (base + 1) % cycle
        state = gs.from_arrays(cfg, src, dst)
        assert int(state.overflow) == 0
        state = dynamic.recompute(state, cfg)
        return cfg, state

    def cycle_toggle(cs):
        """Remove + re-add every edge of the given cycles in ONE batch:
        region == those cycles' members, graph unchanged after the step."""
        u = np.concatenate([c * cycle + np.arange(cycle) for c in cs]
                           ).astype(np.int32)
        v = np.concatenate([c * cycle + (np.arange(cycle) + 1) % cycle
                            for c in cs]).astype(np.int32)
        n = u.shape[0]
        kind = np.concatenate([np.full(n, dynamic.REM_EDGE, np.int32),
                               np.full(n, dynamic.ADD_EDGE, np.int32)])
        return (np.stack([kind, np.concatenate([u, u]),
                          np.concatenate([v, v])]), None)

    # full-tier shape: cross edges chaining > vcap worth of cycles into one
    # giant SCC (then an untimed undo batch splits them back apart)
    span_cycles = min(n_cycles, 2 * vcap // cycle + 2)
    heads = (np.arange(span_cycles, dtype=np.int32) * cycle + cycle - 1)
    tails = ((np.arange(1, span_cycles + 1, dtype=np.int32) % span_cycles)
             * cycle)
    full_add = np.stack([np.full(span_cycles, dynamic.ADD_EDGE, np.int32),
                         heads, tails])
    full_rm = np.stack([np.full(span_cycles, dynamic.REM_EDGE, np.int32),
                        heads, tails])

    rng = np.random.default_rng(seed)
    batches = []
    for s in range(steps):
        if s % 12 == 10:   # full tier
            batches.append((full_add, full_rm))
        elif s % 12 == 11:  # dense tier: one cycle == dense_capacity
            batches.append(cycle_toggle([int(rng.integers(0, n_cycles))]))
        else:               # compact tier: a few cycles
            batches.append(cycle_toggle(
                rng.choice(n_cycles, size=touched_cycles, replace=False)))

    def pad(arr, n):
        k, u, v = arr
        pk = np.full(n, dynamic.NOP, np.int32)
        pu = np.zeros(n, np.int32)
        pv = np.zeros(n, np.int32)
        pk[:k.shape[0]] = k
        pu[:k.shape[0]] = u
        pv[:k.shape[0]] = v
        return dynamic.make_ops(pk, pu, pv)

    n_lanes = max(max(b[0].shape[1], 0 if b[1] is None else b[1].shape[1])
                  for b in batches)

    def drive(cfg, state):
        import jax
        # warm the (single) step shape so no run is charged compile time
        warm = pad((np.array([dynamic.NOP], np.int32),
                    np.zeros(1, np.int32), np.zeros(1, np.int32)), n_lanes)
        out = dynamic.apply_batch_async(state, warm, cfg)
        jax.block_until_ready(out[0].ccid)
        state = out[0]
        times, tiers = [], []
        for arr, undo in batches:
            ops = pad(arr, n_lanes)
            t0 = time.perf_counter()
            state, _, _, rstats = dynamic.apply_batch_async(state, ops,
                                                            cfg)
            jax.block_until_ready(state.ccid)
            times.append(time.perf_counter() - t0)
            tiers.append(int(rstats.tier))
            if undo is not None:  # restore the base graph out-of-band
                state, _, _, _ = dynamic.apply_batch_async(
                    state, pad(undo, n_lanes), cfg)
                jax.block_until_ready(state.ccid)
        return np.asarray(times), tiers

    cfg_t, st_t = build(tiered=True)
    cfg_f, st_f = build(tiered=False)
    times_t, tiers_t = drive(cfg_t, st_t)
    times_f, _ = drive(cfg_f, st_f)

    counts = {name: tiers_t.count(code)
              for code, name in enumerate(dynamic.TIER_NAMES)}
    rows, med = [], {}
    for code, name in enumerate(dynamic.TIER_NAMES):
        idx = [i for i, t in enumerate(tiers_t) if t == code]
        med_t = float(np.median(times_t[idx])) if idx else None
        med_f = float(np.median(times_f[idx])) if idx else None
        med[name] = {"tiered_s": med_t, "baseline_full_s": med_f,
                     "steps": len(idx)}
        rows.append((name, len(idx),
                     round(med_t * 1e3, 3) if idx else "",
                     round(med_f * 1e3, 3) if idx else "",
                     round(med_f / med_t, 2) if idx else ""))
    assert counts["compact"] > 0, "workload never hit the compact tier"
    speedup = (med["compact"]["baseline_full_s"]
               / med["compact"]["tiered_s"])
    if assert_speedup:
        assert speedup > 1.0, (
            "compact-sparse repair did not beat full-sparse on the "
            f"small-region workload: {med['compact']['tiered_s']:.6f}s vs "
            f"{med['compact']['baseline_full_s']:.6f}s per step")
    report = {"nv": nv, "edge_capacity": edge_capacity, "cycle": cycle,
              "steps": steps, "tier_counts": counts,
              "median_step_s": med,
              "compact_vs_full_speedup": round(speedup, 3)}
    return rows, report


def run_replicas(counts=(1, 2), min_scaling=1.5, **stream_kw):
    """Replica-scaling section (PR-6): closed-loop read-your-writes
    rounds against a durable writer + N WAL-tailing read replicas
    (:func:`repro.launch.replica.run_replicated_stream`).

    Every reader round commits a touch write and then queries at
    ``AT_LEAST`` of its session floor, so each round must wait out
    replication lag; the replicas' staggered poll grids cut the
    expected freshness wait from ~poll/2 to ~poll/2N, which is where
    combined throughput scales with replica count on a latency-bound
    (not compute-bound) regime -- honest scaling on a 1-core host.
    Asserts >= ``min_scaling``x combined ops/s at ``counts[-1]``
    replicas vs ``counts[0]``."""
    import tempfile

    from repro.launch.replica import run_replicated_stream

    rows, combined = [], {}
    for n in counts:
        with tempfile.TemporaryDirectory() as d:
            rep = run_replicated_stream(d, replicas=n, **stream_kw)
        rows.append((f"replicas_x{n}", rep["ops"], rep["ops_per_s"],
                     rep["queries"], rep["queries_per_s"],
                     rep["combined_per_s"], n, rep["routed_stale"],
                     rep["replica_gen_waits"]))
        combined[n] = rep["combined_per_s"]
    scaling = round(combined[counts[-1]] / combined[counts[0]], 3)
    assert scaling >= min_scaling, (
        f"replica scaling too weak: {counts[-1]} replicas gave only "
        f"{scaling}x the combined throughput of {counts[0]} "
        f"({combined[counts[-1]]} vs {combined[counts[0]]} ops/s); "
        f"floor is {min_scaling}x")
    report = {"counts": list(counts),
              "rows": _dicts(rows, REPLICA_HEADER),
              "scaling": scaling, "floor": min_scaling}
    return rows, report


def run_tenancy(n_tenants=6, steps=20, nv=256, chunk=16,
                min_speedup=2.0):
    """Multi-tenant section (PR-8): the same N per-tenant workloads
    driven once through N *sequential* single-tenant
    :class:`SCCService` instances and once through ONE
    :class:`repro.tenancy.MultiTenantService` (vmapped
    :class:`~repro.tenancy.engine.TenantEngine` behind the admission
    :class:`~repro.tenancy.queue.WorkQueue`, one submitter thread per
    tenant).

    The multi-tenant path coalesces the T tenants' same-shape chunks
    into one vmapped dispatch and pays ONE host sync per wave (ok/ovf
    refs + fill-stats ride the same transfer), where the sequential
    baseline pays per chunk: a dispatch, the commit-gen sync, and the
    compaction-probe fill-stats sync.  Sized for the many-small-tenants
    serving regime (small per-tenant chunks) where that fixed per-chunk
    cost dominates the sequential path.  Asserts aggregate multi-tenant
    ops/s >= ``min_speedup`` x the sequential baseline, the engine's
    compiled-entry registry stayed under its
    ``(tenant_batches x scan_lengths x buckets x cfgs)`` bound, and the
    final per-tenant labellings are **bit-identical** between the two
    paths (tenancy is an execution strategy, not a semantics change).

    Reports per-tenant p50/p95 submit->resolve latency (the serving-
    fairness axis), queue depth / flush causes / pool hit rate, and
    stacked-lane occupancy."""
    import threading

    from repro.launch import workload
    from repro.tenancy import MultiTenantService

    mod = configs.get("smscc")
    cfg = mod.config(n_vertices=nv, edge_capacity=max(nv, 256),
                     max_probes=64, max_outer=64, max_inner=64)
    buckets = (chunk,)

    def chunks_for(i):
        out = []
        for step in range(steps):
            ops = workload.op_stream(
                nv, chunk, step=step,
                add_frac=1.0 if step == 0 else 0.7, seed=1000 + i)
            out.append((np.asarray(ops.kind, np.int32),
                        np.asarray(ops.u, np.int32),
                        np.asarray(ops.v, np.int32)))
        return out

    workloads = [chunks_for(i) for i in range(n_tenants)]
    timed_ops = n_tenants * (steps - 1) * chunk

    # --- sequential baseline: N independent single-tenant services ----
    seq = [SCCService(cfg, buckets=buckets, scan_lengths=SCAN_LENGTHS)
           for _ in range(n_tenants)]
    for svc, wl in zip(seq, workloads):     # warm the jit caches
        svc._apply_chunk(*wl[0])
    t0 = time.perf_counter()
    for svc, wl in zip(seq, workloads):
        for k, u, v in wl[1:]:
            svc._apply_chunk(k, u, v)
    seq_wall = time.perf_counter() - t0

    # --- multi-tenant: one engine + queue, a submitter per tenant -----
    mts = MultiTenantService(cfg, buckets=buckets,
                             scan_lengths=SCAN_LENGTHS,
                             tenant_batches=(1, 2, n_tenants),
                             coalesce_ops=n_tenants * chunk,
                             flush_deadline_s=0.01)
    tids = [mts.create_tenant() for _ in range(n_tenants)]
    sessions = [mts.session(tid) for tid in tids]

    def drive_one(sess, wl, lo, hi):
        for k, u, v in wl[lo:hi]:
            sess._apply_ops(k, u, v)

    def fan_out(lo, hi):
        ts = [threading.Thread(target=drive_one, args=(s, w, lo, hi))
              for s, w in zip(sessions, workloads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()

    fan_out(0, 1)                           # warm the vmapped entries
    t0 = time.perf_counter()
    fan_out(1, steps)
    multi_wall = time.perf_counter() - t0

    # bit-identity: the vmapped/coalesced path is an execution strategy,
    # not a semantics change
    for svc, sess, tid in zip(seq, sessions, tids):
        assert int(sess.gen) == int(svc.gen), \
            f"tenant {tid}: gen {int(sess.gen)} != oracle {int(svc.gen)}"
        assert np.array_equal(np.asarray(sess.state.ccid),
                              np.asarray(svc.state.ccid)), \
            f"tenant {tid}: labelling diverged from single-tenant oracle"

    agg = mts.stats()
    eng, q = agg["engine"], agg["queue"]
    assert eng["compile_count"] <= eng["compile_bound"], (
        f"tenant-entry compile bound violated: {eng['compile_count']} > "
        f"{eng['compile_bound']}")
    seq_rate = round(timed_ops / seq_wall, 1)
    multi_rate = round(timed_ops / multi_wall, 1)
    speedup = round(seq_wall / multi_wall, 3)
    assert speedup >= min_speedup, (
        f"multi-tenant coalescing too weak: {n_tenants} tenants gave "
        f"only {speedup}x the sequential baseline ({multi_rate} vs "
        f"{seq_rate} ops/s); floor is {min_speedup}x")
    rows = [("sequential_x%d" % n_tenants, timed_ops, seq_rate,
             round(seq_wall, 3), 1.0),
            ("multi_tenant_x%d" % n_tenants, timed_ops, multi_rate,
             round(multi_wall, 3), speedup)]
    per_tenant = []
    for tid in tids:
        ts = mts.tenant_stats(tid)
        per_tenant.append({"tid": tid, "gen": ts["gen"],
                           "fallback_chunks": ts["fallback_chunks"],
                           "p50_s": ts["p50_s"], "p95_s": ts["p95_s"]})
    report = {"tenants": n_tenants, "steps": steps, "chunk": chunk,
              "ops": timed_ops,
              "seq_ops_per_s": seq_rate, "multi_ops_per_s": multi_rate,
              "speedup": speedup, "floor": min_speedup,
              "compile_count": eng["compile_count"],
              "compile_bound": eng["compile_bound"],
              "occupancy": eng["occupancy"],
              "queue": {k: q[k] for k in
                        ("depth_max_ops", "waves", "rejects",
                         "flush_causes", "pool")},
              "per_tenant": per_tenant}
    mts.close()
    return rows, report


def run_availability_section(window_s=0.8, replicas=2, min_ratio=0.5,
                             min_write_ratio=0.5):
    """Degraded-window serving (PR-9/PR-10): closed-loop query
    throughput through a supervised ReplicaSet in a steady window vs a
    window where one replica is killed and supervisor-restarted, then
    closed-loop *write* throughput in a steady window vs a window where
    the leased writer is crashed and a replica promoted mid-window
    (:func:`repro.launch.chaos.run_availability`).  The query caller is
    latency-bound, so transparent failover should keep the read ratio
    near 1.0; writes pay one lease TTL plus the takeover, so the write
    ratio floor is 0.5x over a window that dwarfs the TTL (losing more
    than half of it means promotion or client reroute is broken)."""
    from repro.launch.chaos import run_availability

    rep = run_availability(window_s=window_s, replicas=replicas)
    rep["floor"] = min_ratio
    rep["write_floor"] = min_write_ratio
    rows = [
        ("steady", rep["steady_per_s"], rep["steady_faults"], 1.0),
        ("replica_killed", rep["faulted_per_s"], rep["faulted_faults"],
         rep["ratio"]),
        ("write_steady", rep["write_steady_per_s"],
         rep["write_steady_faults"], 1.0),
        ("writer_crashed", rep["write_faulted_per_s"],
         rep["write_faulted_faults"], rep["write_availability"]),
    ]
    assert rep["ratio"] >= min_ratio, (
        f"availability collapsed under a replica kill: degraded-window "
        f"throughput ratio {rep['ratio']} < {min_ratio} floor")
    assert rep["write_availability"] >= min_write_ratio, (
        f"write availability collapsed under writer loss: faulted-"
        f"window ratio {rep['write_availability']} < {min_write_ratio} "
        f"floor")
    assert rep["promotions"] >= 1, (
        "the writer crash never promoted a replica: the write-"
        "availability window measured a dead store")
    return rows, rep


HEADER = ["mix", "ops", "ops_per_s", "queries", "queries_per_s",
          "combined_per_s", "compiled_shapes", "grows", "compactions",
          "final_capacity", "steady_ops", "repair_skipped_steps",
          "scanned_chunks"]
OVERLAP_HEADER = ["mode", "ops", "ops_per_s", "queries", "queries_per_s",
                  "combined_per_s", "readers"]
OVERHEAD_HEADER = ["path", "ops", "combined_per_s", "wall_s"]
REPAIR_HEADER = ["tier", "steps", "tiered_median_ms",
                 "full_baseline_median_ms", "speedup"]
REPLICA_HEADER = ["mode", "ops", "ops_per_s", "queries", "queries_per_s",
                  "combined_per_s", "replicas", "routed_stale",
                  "gen_waits"]
TENANCY_HEADER = ["mode", "ops", "ops_per_s", "wall_s", "speedup"]
AVAIL_HEADER = ["phase", "per_s", "typed_faults", "ratio"]


def _dicts(rows, header):
    return [dict(zip(header, r)) for r in rows]


def _kernel_impl_info(nv, edge_capacity):
    """What the ``'auto'`` sparse_impl resolved to for this run's shapes
    -- recorded so a trajectory point taken on a TPU host (Pallas sweeps)
    is never compared against a CPU point (XLA oracle) by accident."""
    from repro.kernels.frontier_expand import ops as frontier_ops
    from repro.kernels.hash_probe import ops as hash_probe_ops
    return {
        "sparse_impl": "auto",
        "frontier_expand": frontier_ops.resolve_impl("auto", nv),
        "hash_probe": hash_probe_ops.resolve_impl("auto", edge_capacity),
    }


def append_report(path, report):
    """Append-friendly perf trajectory: ``{"runs": [...]}`` with one
    labelled entry per recorded run.  A pre-schema single-run file (the
    PR-4 format) is migrated in place as the first trajectory point."""
    runs = []
    if os.path.exists(path):
        with open(path) as f:
            existing = json.load(f)
        if isinstance(existing, dict) and \
                isinstance(existing.get("runs"), list):
            runs = existing["runs"]
        elif isinstance(existing, dict) and "bench" in existing:
            existing.setdefault("label", "pr4-baseline")
            runs = [existing]  # pre-schema single-run file: migrate
        else:
            # never silently destroy the committed perf trajectory --
            # an unrecognized file is the operator's to resolve
            raise RuntimeError(
                f"{path} exists but is not a bench_stream trajectory "
                f"(neither a runs-schema nor a pre-schema report); "
                f"refusing to overwrite it")
    runs.append(report)
    with open(path, "w") as f:
        json.dump({"schema": "bench_stream/v2", "runs": runs}, f,
                  indent=2)
        f.write("\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CPU-friendly run (CI: exercises grow + "
                         "replay + both mix extremes + the steady-state "
                         "gate/scan phase + reader overlap + the facade-"
                         "overhead bound + the repair-tier speedup "
                         "end-to-end)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale graph (slow; accelerator advised)")
    ap.add_argument("--readers", type=int, default=2,
                    help="reader threads for the overlap comparison")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="append the machine-readable report to the "
                         "perf-trajectory file recorded by scripts/ci.sh")
    ap.add_argument("--label", default=None,
                    help="trajectory label for this run (default: mode)")
    args = ap.parse_args()
    if args.smoke:
        # capacity starts undersized on purpose so the smoke run also
        # covers table growth; chunk = 4 x the large bucket so the scan
        # engine's K=4 super-chunks are exercised end-to-end
        buckets = (32, 128)
        nv_used, cap_used = 256, 256
        rows = run(nv=nv_used, edge_capacity=cap_used, n_ops=1024,
                   chunk=512, buckets=buckets, n_queries=256,
                   mixes=("update_heavy", "query_heavy"))
        overlap = run_overlap(nv=256, edge_capacity=1024, n_ops=1024,
                              chunk=128, buckets=buckets, n_queries=256,
                              readers=args.readers)
        overhead, overhead_frac = run_client_overhead(
            nv=256, edge_capacity=1024, n_ops=1024, chunk=128,
            buckets=buckets, n_queries=256)
        repair, repair_rep = run_repair_tiers(nv=4096,
                                              edge_capacity=2 ** 14,
                                              steps=36)
        replicas, replicas_rep = run_replicas()
        tenancy, tenancy_rep = run_tenancy(n_tenants=6, steps=16,
                                           nv=256, chunk=16)
        avail, avail_rep = run_availability_section(window_s=0.6)
    elif args.full:
        buckets = (1024, 4096)
        # chunk = 4 x the large bucket: the mixes run K=4 super-chunks
        nv_used, cap_used = 2 ** 17, 2 ** 18
        rows = run(nv=nv_used, edge_capacity=cap_used, n_ops=2 ** 17,
                   chunk=2 ** 14, buckets=buckets, n_queries=2 ** 15)
        overlap = run_overlap(nv=2 ** 17, edge_capacity=2 ** 18,
                              n_ops=2 ** 17, chunk=4096,
                              buckets=buckets, n_queries=2 ** 15,
                              readers=args.readers)
        overhead, overhead_frac = run_client_overhead(
            nv=2 ** 17, edge_capacity=2 ** 18, n_ops=2 ** 16,
            chunk=4096, buckets=buckets, n_queries=2 ** 14)
        repair, repair_rep = run_repair_tiers(nv=2 ** 16,
                                              edge_capacity=2 ** 18,
                                              steps=60, touched_cycles=4)
        replicas, replicas_rep = run_replicas(counts=(1, 2, 3),
                                              n_ops=1920, nv=2048)
        tenancy, tenancy_rep = run_tenancy(n_tenants=6, steps=48,
                                           nv=512, chunk=16)
        avail, avail_rep = run_availability_section(window_s=1.5,
                                                    replicas=3)
    else:
        buckets = (128, 512)
        nv_used, cap_used = 4096, 4096
        rows = run(buckets=buckets, chunk=2048)
        overlap = run_overlap(buckets=buckets, readers=args.readers)
        overhead, overhead_frac = run_client_overhead(buckets=buckets)
        repair, repair_rep = run_repair_tiers()
        replicas, replicas_rep = run_replicas(counts=(1, 2, 3))
        tenancy, tenancy_rep = run_tenancy(n_tenants=6, steps=24,
                                           nv=512, chunk=16)
        avail, avail_rep = run_availability_section()
    common.emit(rows, HEADER)
    common.emit(overlap, OVERLAP_HEADER)
    common.emit(overhead, OVERHEAD_HEADER)
    print(f"client overhead_frac: {overhead_frac}")
    common.emit(repair, REPAIR_HEADER)
    common.emit(replicas, REPLICA_HEADER)
    print(f"replica scaling: {replicas_rep['scaling']}x at "
          f"{replicas_rep['counts'][-1]} vs {replicas_rep['counts'][0]} "
          f"replicas (floor {replicas_rep['floor']}x)")
    common.emit(tenancy, TENANCY_HEADER)
    print(f"tenancy speedup: {tenancy_rep['speedup']}x aggregate over "
          f"{tenancy_rep['tenants']} sequential single-tenant services "
          f"(floor {tenancy_rep['floor']}x, compile "
          f"{tenancy_rep['compile_count']}/{tenancy_rep['compile_bound']})")
    common.emit(avail, AVAIL_HEADER)
    print(f"availability under replica kill: {avail_rep['ratio']}x of "
          f"the steady window ({avail_rep['restarts']} supervisor "
          f"restart(s), floor {avail_rep['floor']}x)")
    print(f"write availability under writer loss: "
          f"{avail_rep['write_availability']}x of the steady window "
          f"({avail_rep['promotions']} promotion(s), floor "
          f"{avail_rep['write_floor']}x)")
    if args.json:
        mode = "smoke" if args.smoke else "full" if args.full else "default"
        report = {
            "bench": "bench_stream",
            "mode": mode,
            "label": args.label or mode,
            "n_buckets": len(buckets),
            "n_scan_lengths": len(SCAN_LENGTHS),
            "repair_tier_count": len(dynamic.TIER_NAMES),
            "mixes": _dicts(rows, HEADER),
            "overlap": _dicts(overlap, OVERLAP_HEADER),
            "client_overhead": {
                "paths": _dicts(overhead, OVERHEAD_HEADER),
                "overhead_frac": overhead_frac,
            },
            "repair_tiers": repair_rep,
            "replicas": replicas_rep,
            "tenancy": tenancy_rep,
            "availability": avail_rep,
            "kernel_impl": _kernel_impl_info(nv_used, cap_used),
        }
        append_report(args.json, report)
        print(f"appended run '{report['label']}' to {args.json}")


if __name__ == "__main__":
    main()
