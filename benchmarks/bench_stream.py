"""Streaming-service throughput: sustained ops/sec across workload mixes.

The paper (Fig 4/5) measures an *on-line* system: threads apply an
unbounded update stream while readers run SameSCC queries.  This bench
drives :class:`repro.core.service.SCCService` -- grow-and-replay, bucketed
batch scheduling, periodic compaction -- with the paper's mix axes:

  update-heavy   90% inserts, no queries        (Fig 4b analogue)
  balanced       50/50 add/remove + queries     (Fig 4a analogue)
  query-heavy    mostly reader batches          (Fig 5 analogue)

Reported: sustained update ops/s, query ops/s, number of compiled step
shapes (must stay bounded by bucket-count x capacity-growth count no
matter the stream length), table grows, compactions.

    PYTHONPATH=src python -m benchmarks.bench_stream [--smoke] [--full]
"""
from __future__ import annotations

import argparse

from repro import configs
from repro.core import graph_state as gs
from repro.core.service import SCCService
from repro.launch import stream
from benchmarks import common


def booted_service(cfg, buckets):
    """Service over a graph with every vertex slot live (singleton SCCs):
    edge inserts then land immediately, so an undersized table must grow."""
    return SCCService(cfg, buckets=buckets, state=gs.all_singletons(cfg))

MIXES = {
    "update_heavy": dict(add_frac=0.9, query_frac=0.0),
    "balanced": dict(add_frac=0.5, query_frac=0.5),
    "query_heavy": dict(add_frac=0.5, query_frac=1.0),
}


def run(nv=4096, edge_capacity=4096, n_ops=16384, chunk=512,
        buckets=(128, 512), n_queries=2048, mixes=None, seed=0):
    """One service per mix (fresh table so growth cost is included)."""
    smscc = configs.get("smscc")
    rows = []
    for name in (mixes or MIXES):
        mix = MIXES[name]
        cfg = smscc.config(n_vertices=nv, edge_capacity=edge_capacity,
                           max_probes=64, max_outer=64, max_inner=128)
        svc = booted_service(cfg, buckets)
        rep = stream.run_stream(
            svc, n_ops=n_ops, chunk=chunk, n_queries=n_queries,
            seed=seed, **mix)
        rows.append((name, rep["ops"], rep["ops_per_s"], rep["queries"],
                     rep["queries_per_s"], rep["compile_count"],
                     rep["grows"], rep["compactions"],
                     rep["edge_capacity"]))
        # grows AND capacity-escalating compactions each mint a new
        # GraphConfig (hence up to len(buckets) fresh step shapes)
        n_cfgs = 1 + rep["grows"] + rep["compactions"]
        assert rep["compile_count"] <= len(buckets) * n_cfgs, (
            "per-chunk recompilation detected: "
            f"{rep['compile_count']} compiled shapes for "
            f"{len(buckets)} buckets x {n_cfgs} configs")
    return rows


HEADER = ["mix", "ops", "ops_per_s", "queries", "queries_per_s",
          "compiled_shapes", "grows", "compactions", "final_capacity"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CPU-friendly run (CI: exercises grow + "
                         "replay + both mix extremes end-to-end)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale graph (slow; accelerator advised)")
    args = ap.parse_args()
    if args.smoke:
        # capacity starts undersized on purpose so the smoke run also
        # covers grow-and-replay
        rows = run(nv=256, edge_capacity=256, n_ops=1024, chunk=128,
                   buckets=(32, 128), n_queries=256,
                   mixes=("update_heavy", "query_heavy"))
    elif args.full:
        rows = run(nv=2 ** 17, edge_capacity=2 ** 18, n_ops=2 ** 17,
                   chunk=4096, buckets=(1024, 4096), n_queries=2 ** 15)
    else:
        rows = run()
    common.emit(rows, HEADER)


if __name__ == "__main__":
    main()
