"""Kernel micro-benchmarks: Pallas (interpret) vs jnp oracle.

Wall-times on CPU interpret mode are NOT TPU performance; the value here
is (a) correctness at bench scale and (b) the oracle-path timing that the
CPU examples actually use.  TPU projections live in §Roofline.
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from repro.kernels import embedding_bag as eb
from repro.kernels import flash_attention as fa
from repro.kernels import reach_blockmm as rb
from benchmarks import common


def run(quick=False):
    rows = []
    n = 128 if quick else 256
    rng = np.random.default_rng(0)
    adj = jnp.asarray(rng.random((n, n)) < 0.02)
    f = jnp.asarray(rng.random((n, 64)) < 0.01)
    t, _ = common.time_fn(
        lambda a, b: rb.frontier_step(a, b, impl="xla"), adj, f)
    rows.append(("reach_frontier_xla", n, round(t * 1e3, 3)))
    t, _ = common.time_fn(
        lambda a, b: rb.frontier_step(a, b, block=128,
                                      impl="pallas_interpret"), adj, f)
    rows.append(("reach_frontier_pallas_interp", n, round(t * 1e3, 3)))

    s, d = (128, 32) if quick else (256, 64)
    q = jnp.asarray(rng.normal(size=(1, 4, s, d)).astype(np.float32))
    t, _ = common.time_fn(
        lambda q: fa.mha(q, q, q, causal=True, impl="xla"), q)
    rows.append(("flash_attn_xla", s, round(t * 1e3, 3)))
    t, _ = common.time_fn(
        lambda q: fa.mha(q, q, q, causal=True, bq=64, bk=64,
                         impl="pallas_interpret"), q)
    rows.append(("flash_attn_pallas_interp", s, round(t * 1e3, 3)))

    v, dd, b, l = (1000, 32, 64, 16) if quick else (10000, 64, 256, 32)
    table = jnp.asarray(rng.normal(size=(v, dd)).astype(np.float32))
    ids = jnp.asarray(rng.integers(-1, v, (b, l)), jnp.int32)
    t, _ = common.time_fn(
        lambda t_, i: eb.embedding_bag(t_, i, impl="xla"), table, ids)
    rows.append(("embedding_bag_xla", b, round(t * 1e3, 3)))
    t, _ = common.time_fn(
        lambda t_, i: eb.embedding_bag(t_, i, bb=8, bv=128,
                                       impl="pallas_interpret"),
        table, ids)
    rows.append(("embedding_bag_pallas_interp", b, round(t * 1e3, 3)))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    rows = run(quick=ap.parse_args().quick)
    common.emit(rows, ["kernel", "size", "ms"])


if __name__ == "__main__":
    main()
