"""Paper Fig 4 (a/b/c): throughput of mixed add/remove workloads.

The paper reports ops/sec over 20 s for 1..60 threads; our concurrency
unit is the batch lane, so throughput is reported vs batch size B for:

  Seq     sequential_apply   -- one op at a time, localized repair
  Coarse  coarse_apply       -- one op at a time, full recompute ("global
                                lock" semantics: no locality exploited)
  SMSCC   dynamic.apply_batch -- B lanes, one unified localized repair
  Client  repro.api.GraphClient -- the same B lanes as typed ops through
                                the full public stack (facade + service
                                scheduler + pipelined window)

Mixes: --mix 50 (50/50 add/rem, Fig 4a), 90 (Fig 4b), 10 (Fig 4c).
Variants: --no-vertex-ops restricts to edges (paper's `woDV` mode).
"""
from __future__ import annotations

import argparse

from repro.api import GraphClient, updates_from_arrays
from repro.core import baselines, dynamic
from repro.core.service import SCCService
from repro.launch import workload
from benchmarks import common


def run(mix=50, nv=2048, batches=(16, 64, 256, 1024), seq_ops=64,
        include_vertex_ops=True, iters=3, quick=False):
    if quick:
        nv, batches, seq_ops, iters = 512, (16, 128), 32, 2
    cfg, state0 = common.make_engine(nv=nv)
    add_frac = mix / 100.0
    rows = []

    # baselines: per-op application of a seq_ops-long stream
    for name, fn in (("seq", baselines.sequential_apply),
                     ("coarse", baselines.coarse_apply)):
        ops = workload.op_stream(nv, seq_ops, step=0, add_frac=add_frac,
                                 include_vertex_ops=include_vertex_ops)
        t, _ = common.time_fn(lambda o: fn(state0, o, cfg), ops,
                              iters=iters)
        rows.append((f"mix{mix}", name, seq_ops, round(seq_ops / t, 1),
                     round(t * 1e3, 2)))

    # SMSCC batched
    for b in batches:
        ops = workload.op_stream(nv, b, step=1, add_frac=add_frac,
                                 include_vertex_ops=include_vertex_ops)
        t, _ = common.time_fn(
            lambda o: dynamic.apply_batch(state0, o, cfg), ops,
            iters=iters)
        rows.append((f"mix{mix}", f"smscc_b{b}", b, round(b / t, 1),
                     round(t * 1e3, 2)))

    # full public stack: the same lanes as typed ops through a GraphClient
    # session (sustained-service semantics, so repeated timing iterations
    # legitimately mutate the service)
    for b in batches:
        ops = workload.op_stream(nv, b, step=1, add_frac=add_frac,
                                 include_vertex_ops=include_vertex_ops)
        typed = updates_from_arrays(ops.kind, ops.u, ops.v)
        svc = SCCService(cfg, buckets=(b,), state=state0)
        client = GraphClient(svc)
        t, _ = common.time_fn(client.submit_many, typed, iters=iters)
        client.close()
        rows.append((f"mix{mix}", f"client_b{b}", b, round(b / t, 1),
                     round(t * 1e3, 2)))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mix", type=int, default=50)
    ap.add_argument("--no-vertex-ops", action="store_true")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    rows = run(mix=args.mix, include_vertex_ops=not args.no_vertex_ops,
               quick=args.quick)
    common.emit(rows, ["workload", "algo", "ops", "ops_per_s", "ms"])


if __name__ == "__main__":
    main()
