"""Quantifying the paper's core claims on-device:

1. **Locality of repair**: one batch-atomic update step (localized
   repair) vs a from-scratch recompute of the same state -- the paper's
   limited-Tarjan/Kosaraju advantage, measured.
2. **Beyond-paper round-collapse**: hashed-priority pointer doubling
   (`shortcut=True`) vs the paper-faithful O(diameter) sweeps, on a
   shallow random graph and a high-diameter ring.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.core import dynamic, graph_state as gs
from repro.launch import workload
from benchmarks import common


def run(quick=False):
    nv = 1024 if quick else 4096
    rows = []
    # --- locality: localized repair vs recompute, same graph -------------
    cfg = gs.GraphConfig(n_vertices=nv, edge_capacity=4 * nv,
                         max_probes=128, max_outer=64, max_inner=2 * nv)
    ring = np.arange(nv)
    st = gs.from_arrays(cfg, ring, (ring + 1) % nv)
    st = dynamic.recompute(st, cfg)
    ops = workload.op_stream(nv, 256, step=0, add_frac=0.5)
    t_local, _ = common.time_fn(
        lambda: dynamic.apply_batch(st, ops, cfg), iters=3)
    t_full, _ = common.time_fn(lambda: dynamic.recompute(st, cfg), iters=3)
    rows.append(("ring", "localized_repair_step", 256,
                 round(t_local * 1e3, 2), ""))
    rows.append(("ring", "full_recompute", nv,
                 round(t_full * 1e3, 2),
                 f"locality gain {t_full / t_local:.1f}x"))

    # --- shortcut: rounds-collapse on the diameter adversary -------------
    fast = dataclasses.replace(cfg, shortcut=True)
    t_fast, _ = common.time_fn(lambda: dynamic.recompute(st, fast), iters=3)
    rows.append(("ring", "recompute_shortcut", nv,
                 round(t_fast * 1e3, 2),
                 f"doubling gain {t_full / t_fast:.0f}x"))

    # shallow random graph: shortcut must not regress
    cfg_r = gs.GraphConfig(n_vertices=nv, edge_capacity=8 * nv,
                           max_probes=128, max_outer=64, max_inner=128)
    fast_r = dataclasses.replace(cfg_r, shortcut=True)
    rng = np.random.default_rng(0)
    st_r = gs.from_arrays(cfg_r, rng.integers(0, nv, 4 * nv),
                          rng.integers(0, nv, 4 * nv))
    st_r = dynamic.recompute(st_r, cfg_r)
    t_base, _ = common.time_fn(
        lambda: dynamic.apply_batch(st_r, ops, cfg_r), iters=3)
    t_sc, _ = common.time_fn(
        lambda: dynamic.apply_batch(st_r, ops, fast_r), iters=3)
    rows.append(("random", "apply_batch_baseline", 256,
                 round(t_base * 1e3, 2), ""))
    rows.append(("random", "apply_batch_shortcut", 256,
                 round(t_sc * 1e3, 2),
                 f"gain {t_base / t_sc:.2f}x"))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    rows = run(quick=ap.parse_args().quick)
    common.emit(rows, ["graph", "measure", "n", "ms", "note"])


if __name__ == "__main__":
    main()
