"""Quickstart: the SMSCC dynamic-SCC engine in 40 lines.

Builds a graph, applies a mixed update batch atomically, queries
communities -- the public API surface of the paper's contribution.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import community, dynamic, graph_state as gs

# 1. capacity-bounded engine (vertices 0..63, up to 256 edges)
cfg = gs.GraphConfig(n_vertices=64, edge_capacity=256, max_probes=64,
                     max_outer=65, max_inner=66)
state = gs.empty(cfg)

# 2. create vertices 0..9 in ONE atomic batch
ops = dynamic.make_ops([dynamic.ADD_VERTEX] * 10, list(range(10)), [0] * 10)
state, ok = dynamic.apply_batch(state, ops, cfg)
print("added vertices:", ok.tolist())

# 3. wire two cycles plus a bridge: {0,1,2} and {3,4}, 2->3
edges = [(0, 1), (1, 2), (2, 0), (3, 4), (4, 3), (2, 3)]
ops = dynamic.make_ops([dynamic.ADD_EDGE] * len(edges),
                       [u for u, _ in edges], [v for _, v in edges])
state, ok = dynamic.apply_batch(state, ops, cfg)
print("communities:", community.belongs_to_community(
    state, jnp.arange(5)).tolist())            # -> [0, 0, 0, 3, 3]

# 4. the paper's Fig-2 moment: a back edge merges everything
state, _ = dynamic.apply_batch(
    state, dynamic.make_ops([dynamic.ADD_EDGE], [4], [0]), cfg)
print("after AddEdge(4,0):", community.belongs_to_community(
    state, jnp.arange(5)).tolist())            # -> [0, 0, 0, 0, 0]
print("checkSCC(1, 4):",
      bool(community.check_scc(state, jnp.array([1]), jnp.array([4]))[0]))

# 5. the Fig-3 moment: deleting the bridge splits it again
state, _ = dynamic.apply_batch(
    state, dynamic.make_ops([dynamic.REM_EDGE], [2], [3]), cfg)
print("after RemoveEdge(2,3):", community.belongs_to_community(
    state, jnp.arange(5)).tolist())            # -> [0, 0, 0, 3, 3]
print("n_sccs:", int(state.n_ccs))
