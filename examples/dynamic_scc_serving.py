"""End-to-end driver: a fault-tolerant dynamic-SCC serving loop.

This is the paper's system run the way it would run in production:
  * a sustained stream of update batches + query batches (the paper's
    mixed workload, Fig 4/5),
  * periodic atomic checkpoints of the WHOLE GraphState (the engine's
    "database") with crash-safe restore -- kill it mid-run and restart to
    see it resume at the checkpointed batch cursor,
  * throughput + straggler accounting per batch,
  * periodic GC (edge-table compaction = the paper's hazard-pointer GC).

    PYTHONPATH=src python examples/dynamic_scc_serving.py [--steps N]
"""
import argparse
import os
import time

import jax
import numpy as np

from repro.ckpt import checkpoint
from repro.core import community, dynamic, edge_table as et
from repro.core import graph_state as gs
from repro.data import pipeline

NV = 4096
BATCH = 256
QUERIES = 1024
CKPT_DIR = "/tmp/smscc_serving_ckpt"
GC_EVERY = 20


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--reset", action="store_true")
    args = ap.parse_args()
    if args.reset and os.path.exists(CKPT_DIR):
        for f in os.listdir(CKPT_DIR):
            os.remove(os.path.join(CKPT_DIR, f))

    cfg = gs.GraphConfig(n_vertices=NV, edge_capacity=2 ** 15,
                         max_probes=128, max_outer=64, max_inner=128)
    rng = np.random.default_rng(0)
    state = gs.from_arrays(cfg, rng.integers(0, NV, 8000),
                           rng.integers(0, NV, 8000))
    state = dynamic.recompute(state, cfg)
    cursor = 0

    # crash recovery: resume from the latest intact checkpoint
    restored, step = checkpoint.restore(
        CKPT_DIR, {"state": state, "cursor": np.int64(0)})
    if restored is not None:
        state, cursor = restored["state"], int(restored["cursor"])
        print(f"[recovery] resumed at batch {cursor}")

    times = []
    stragglers = 0
    t_start = time.perf_counter()
    for step in range(cursor, args.steps):
        ops = pipeline.op_stream(NV, BATCH, step=step, add_frac=0.6)
        qu = rng.integers(0, NV, QUERIES)
        qv = rng.integers(0, NV, QUERIES)
        t0 = time.perf_counter()
        state, ok = dynamic.apply_batch(state, ops, cfg)
        same = community.check_scc(state, qu, qv)
        jax.block_until_ready(same)
        dt = time.perf_counter() - t0
        times.append(dt)
        med = sorted(times[-50:])[len(times[-50:]) // 2]
        if len(times) > 5 and dt > 3 * med:
            stragglers += 1
            print(f"[straggler] batch {step}: {dt*1e3:.0f}ms vs median "
                  f"{med*1e3:.0f}ms")
        if (step + 1) % 10 == 0:
            checkpoint.save(CKPT_DIR, step + 1,
                            {"state": state, "cursor": np.int64(step + 1)})
            print(f"[ckpt] batch {step+1} | "
                  f"{BATCH/med:.0f} updates/s, {QUERIES/med:.0f} queries/s"
                  f" | {int(state.n_ccs)} SCCs | overflow="
                  f"{int(state.overflow)}")
        if (step + 1) % GC_EVERY == 0:
            live, tomb = et.fill_stats(state.edges)
            state = state._replace(
                edges=et.compact(state.edges, cfg.max_probes))
            print(f"[gc] compacted edge table ({int(tomb)} tombstones)")

    total = time.perf_counter() - t_start
    done = args.steps - cursor
    print(f"\nserved {done} batches in {total:.1f}s | "
          f"{done*BATCH/total:.0f} updates/s | "
          f"{done*QUERIES/total:.0f} queries/s | stragglers={stragglers}")


if __name__ == "__main__":
    main()
