"""End-to-end driver: a fault-tolerant dynamic-SCC serving loop.

This is the paper's system run the way it would run in production, now on
top of the streaming service layer (:mod:`repro.core.service`):
  * a sustained stream of update chunks applied through the service's
    pipelined in-flight window, overlapped with **concurrent reader
    threads** issuing coalesced snapshot queries through a
    :class:`repro.core.broker.QueryBroker` (the paper's mixed workload,
    Fig 4/5), all cut into bucketed static batch shapes so compilation
    count stays bounded,
  * **grow-and-replay**: the edge table starts deliberately small; when
    probe-bound overflow drops an insert, the service rehashes into a
    larger capacity and replays it -- no edge is ever lost,
  * periodic atomic checkpoints of the WHOLE GraphState (the engine's
    "database") with crash-safe restore -- kill it mid-run and restart to
    see it resume at the checkpointed chunk cursor.  The checkpoint
    records the (possibly grown) edge capacity so restore rebuilds the
    right template shapes,
  * throughput + straggler accounting per chunk; GC (edge-table
    compaction) happens inside the service when tombstones pile up.

    PYTHONPATH=src python examples/dynamic_scc_serving.py [--steps N]
                                                          [--readers N]
    PYTHONPATH=src python examples/dynamic_scc_serving.py --smoke  # CI
"""
import argparse
import dataclasses
import os
import tempfile
import threading
import time

import numpy as np

from repro.ckpt import checkpoint
from repro.core import dynamic, graph_state as gs
from repro.core.broker import QueryBroker
from repro.core.service import SCCService
from repro.data import pipeline

NV = 4096
BATCH = 256
QUERIES = 1024
CKPT_DIR = "/tmp/smscc_serving_ckpt"
CKPT_EVERY = 10


def build_service(cfg: gs.GraphConfig, nv: int, batch: int, preload: int):
    """Preloaded service: random digraph loaded THROUGH the service so the
    deliberately undersized table grows (and replays) instead of silently
    dropping edges the way a raw bulk insert would."""
    rng = np.random.default_rng(0)
    svc = SCCService(cfg, buckets=(64, batch), state=gs.all_singletons(cfg))
    svc.apply(np.full(preload, dynamic.ADD_EDGE, np.int32),
              rng.integers(0, nv, preload), rng.integers(0, nv, preload))
    st = svc.stats()
    print(f"[preload] {st['live_edges']} edges | capacity "
          f"{st['edge_capacity']} (grows={st['grows']}, "
          f"replayed={st['replayed_ops']})")
    return svc


def reader_loop(broker: QueryBroker, stop: threading.Event, nv: int,
                n_queries: int, seed: int, out: dict):
    """Free-running reader: coalesced SameSCC (+ occasional reachability)
    batches; checks its observed generations never go backwards.  Any
    failure is stashed in ``out`` and re-raised by the main thread (a
    daemon thread's own traceback cannot fail the CI smoke)."""
    rng = np.random.default_rng(seed)
    last_gen = -1
    try:
        while not stop.is_set():
            qu = rng.integers(0, nv, n_queries)
            qv = rng.integers(0, nv, n_queries)
            snap = broker.same_scc(qu, qv)
            assert snap.gen >= last_gen, "reader saw generation regress"
            last_gen = snap.gen
            out["queries"] += n_queries
            if rng.random() < 0.25:
                snap = broker.reachable(qu[:64], qv[:64])
                last_gen = max(last_gen, snap.gen)
                out["queries"] += 64
    except BaseException as e:
        out["error"] = e
        stop.set()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--readers", type=int, default=2,
                    help="concurrent reader threads (0 = updates only)")
    ap.add_argument("--reset", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CPU-friendly run against a throwaway "
                         "checkpoint dir (the CI docs gate)")
    args = ap.parse_args()
    if args.smoke:
        nv, batch, queries, preload = 512, 128, 256, 400
        steps = min(args.steps, 6)
        ckpt_dir = tempfile.mkdtemp(prefix="smscc_serving_smoke_")
        ckpt_every = 3
    else:
        nv, batch, queries, preload = NV, BATCH, QUERIES, 4000
        steps = args.steps
        ckpt_dir = CKPT_DIR
        ckpt_every = CKPT_EVERY
    if args.reset and os.path.exists(ckpt_dir):
        for f in os.listdir(ckpt_dir):
            os.remove(os.path.join(ckpt_dir, f))

    cfg = gs.GraphConfig(n_vertices=nv, edge_capacity=max(512, nv),
                         max_probes=128, max_outer=64, max_inner=128)
    svc = None
    cursor = 0

    # crash recovery: the meta leaves restore first (extra npz keys are
    # ignored), telling us what edge capacity the state template needs --
    # the table may have grown beyond the boot config before the crash.
    try:
        meta, _ = checkpoint.restore(
            ckpt_dir, {"cursor": np.int64(0),
                       "edge_capacity": np.int64(cfg.edge_capacity)})
    except KeyError:  # checkpoint from an older format: start fresh, and
        # clear the stale files so a future torn-LATEST fallback cannot
        # resurrect them over newer new-format progress
        print("[recovery] unreadable (old-format) checkpoint removed")
        for f in os.listdir(ckpt_dir):
            os.remove(os.path.join(ckpt_dir, f))
        meta = None
    if meta is not None:
        cap = int(meta["edge_capacity"])
        ck_cfg = dataclasses.replace(cfg, edge_capacity=cap)
        tpl = {"state": gs.empty(ck_cfg), "cursor": np.int64(0),
               "edge_capacity": np.int64(cap)}
        restored, _ = checkpoint.restore(ckpt_dir, tpl)
        svc = SCCService(ck_cfg, buckets=(64, batch),
                         state=restored["state"])
        cursor = int(restored["cursor"])
        print(f"[recovery] resumed at chunk {cursor} (capacity {cap})")
    if svc is None:  # no (usable) checkpoint: pay the preload only now
        svc = build_service(cfg, nv, batch, preload)

    # the reader path: a broker-fed thread pool querying the committed
    # snapshot while the update pipeline runs
    broker = QueryBroker(svc, buckets=(64, queries)).start()
    stop = threading.Event()
    reader_stats = [{"queries": 0} for _ in range(args.readers)]
    readers = [threading.Thread(
        target=reader_loop, args=(broker, stop, nv, queries, 100 + i,
                                  reader_stats[i]), daemon=True)
        for i in range(args.readers)]
    for t in readers:
        t.start()

    times = []
    stragglers = 0
    t_start = time.perf_counter()
    try:
        for step in range(cursor, steps):
            ops = pipeline.op_stream(nv, batch, step=step, add_frac=0.7)
            t0 = time.perf_counter()
            svc.apply(np.asarray(ops.kind), np.asarray(ops.u),
                      np.asarray(ops.v))
            dt = time.perf_counter() - t0
            times.append(dt)
            med = sorted(times[-50:])[len(times[-50:]) // 2]
            if len(times) > 5 and dt > 3 * med:
                stragglers += 1
                print(f"[straggler] chunk {step}: {dt*1e3:.0f}ms vs median "
                      f"{med*1e3:.0f}ms")
            if (step + 1) % ckpt_every == 0:
                st = svc.stats()
                checkpoint.save(
                    ckpt_dir, step + 1,
                    {"state": svc.state, "cursor": np.int64(step + 1),
                     "edge_capacity": np.int64(svc.cfg.edge_capacity)})
                print(f"[ckpt] chunk {step+1} | {batch/med:.0f} updates/s"
                      f" | {st['n_ccs']} SCCs | gen={st['gen']}"
                      f" | capacity={st['edge_capacity']}"
                      f" (grows={st['grows']}, "
                      f"replayed={st['replayed_ops']},"
                      f" compactions={st['compactions']})")
    finally:
        stop.set()
        for t in readers:
            t.join()
        broker.stop()
    for r in reader_stats:
        if "error" in r:
            raise r["error"]

    total = time.perf_counter() - t_start
    done = steps - cursor
    n_queries = sum(r["queries"] for r in reader_stats)
    print(f"\nserved {done} chunks in {total:.1f}s | "
          f"{done*batch/total:.0f} updates/s | "
          f"{n_queries/total:.0f} queries/s ({args.readers} readers, "
          f"{broker.stats()['coalescing']:.0f} coalesced/flush) | "
          f"stragglers={stragglers} | "
          f"compiled shapes={svc.compile_count} | "
          f"pipelined={svc.pipelined_chunks} "
          f"fallback={svc.fallback_chunks}")


if __name__ == "__main__":
    main()
