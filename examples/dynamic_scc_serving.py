"""End-to-end driver: a fault-tolerant dynamic-SCC serving loop.

This is the paper's system run the way it would run in production, now
entirely behind the typed public API (:class:`repro.api.GraphClient`):
  * a sustained stream of typed update ops applied through an updater
    client session (the service's pipelined in-flight window underneath),
    overlapped with **concurrent reader sessions** — one ``GraphClient``
    per reader thread over a shared dispatcher-fed
    :class:`repro.core.broker.QueryBroker` — issuing coalesced typed
    snapshot queries (the paper's mixed workload, Fig 4/5), all cut into
    bucketed static batch shapes so compilation count stays bounded,
  * **grow-and-replay**: the edge table starts deliberately small; when
    probe-bound overflow drops an insert, the service rehashes into a
    larger capacity and replays it -- no edge is ever lost,
  * periodic atomic checkpoints of the WHOLE GraphState (the engine's
    "database") with crash-safe restore -- kill it mid-run and restart to
    see it resume at the checkpointed chunk cursor.  The checkpoint
    records the (possibly grown) edge capacity so restore rebuilds the
    right template shapes, and the generation counter so restore can
    assert **gen continuity**: the restored client resumes exactly at the
    committed generation the checkpoint saw,
  * throughput + straggler accounting per chunk; GC (edge-table
    compaction) happens inside the service when tombstones pile up.

    PYTHONPATH=src python examples/dynamic_scc_serving.py [--steps N]
                                                          [--readers N]
    PYTHONPATH=src python examples/dynamic_scc_serving.py --smoke  # CI
"""
import argparse
import dataclasses
import os
import tempfile
import threading
import time

import numpy as np

from repro.api import AddEdge, GraphClient, Reachable, SameSCC
from repro.ckpt import checkpoint
from repro.core import graph_state as gs
from repro.core.broker import QueryBroker
from repro.core.service import SCCService
from repro.launch.stream import typed_op_stream

NV = 4096
BATCH = 256
QUERIES = 1024
CKPT_DIR = "/tmp/smscc_serving_ckpt"
CKPT_EVERY = 10


def preload_graph(client: GraphClient, nv: int, preload: int):
    """Preload a random digraph THROUGH the typed client so the
    deliberately undersized table grows (and replays) instead of silently
    dropping edges the way a raw bulk insert would."""
    rng = np.random.default_rng(0)
    client.submit_many([AddEdge(int(a), int(b)) for a, b in
                        zip(rng.integers(0, nv, preload),
                            rng.integers(0, nv, preload))])
    st = client.stats()
    print(f"[preload] {st['live_edges']} edges | capacity "
          f"{st['edge_capacity']} (grows={st['grows']}, "
          f"replayed={st['replayed_ops']})")


def reader_loop(client: GraphClient, stop: threading.Event, nv: int,
                n_queries: int, seed: int, out: dict):
    """Free-running reader session: coalesced typed SameSCC (+ occasional
    Reachable) batches; checks its observed generations never go
    backwards.  Any failure is stashed in ``out`` and re-raised by the
    main thread (a daemon thread's own traceback cannot fail the CI
    smoke)."""
    rng = np.random.default_rng(seed)
    last_gen = -1
    try:
        while not stop.is_set():
            qu = rng.integers(0, nv, n_queries)
            qv = rng.integers(0, nv, n_queries)
            res = client.submit_many(
                [SameSCC(int(a), int(b)) for a, b in zip(qu, qv)])
            assert res[0].gen >= last_gen, "reader saw generation regress"
            last_gen = res[0].gen
            out["queries"] += n_queries
            if rng.random() < 0.25:
                res = client.submit_many(
                    [Reachable(int(a), int(b)) for a, b in
                     zip(qu[:64], qv[:64])])
                last_gen = max(last_gen, res[0].gen)
                out["queries"] += 64
    except BaseException as e:
        out["error"] = e
        stop.set()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--readers", type=int, default=2,
                    help="concurrent reader threads (0 = updates only)")
    ap.add_argument("--reset", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CPU-friendly run against a throwaway "
                         "checkpoint dir (the CI docs gate)")
    args = ap.parse_args()
    if args.smoke:
        nv, batch, queries, preload = 512, 128, 256, 400
        steps = min(args.steps, 6)
        ckpt_dir = tempfile.mkdtemp(prefix="smscc_serving_smoke_")
        ckpt_every = 3
    else:
        nv, batch, queries, preload = NV, BATCH, QUERIES, 4000
        steps = args.steps
        ckpt_dir = CKPT_DIR
        ckpt_every = CKPT_EVERY
    if args.reset and os.path.exists(ckpt_dir):
        for f in os.listdir(ckpt_dir):
            os.remove(os.path.join(ckpt_dir, f))

    cfg = gs.GraphConfig(n_vertices=nv, edge_capacity=max(512, nv),
                         max_probes=128, max_outer=64, max_inner=128)
    svc = None
    cursor = 0

    # crash recovery: the meta leaves restore first (extra npz keys are
    # ignored), telling us what edge capacity the state template needs --
    # the table may have grown beyond the boot config before the crash --
    # and what committed generation the checkpoint captured.
    try:
        meta, _ = checkpoint.restore(
            ckpt_dir, {"cursor": np.int64(0),
                       "edge_capacity": np.int64(cfg.edge_capacity),
                       "gen": np.int64(0)})
    except KeyError:  # checkpoint from an older format: start fresh, and
        # clear the stale files so a future torn-LATEST fallback cannot
        # resurrect them over newer new-format progress
        print("[recovery] unreadable (old-format) checkpoint removed")
        for f in os.listdir(ckpt_dir):
            os.remove(os.path.join(ckpt_dir, f))
        meta = None
    if meta is not None:
        cap = int(meta["edge_capacity"])
        ck_cfg = dataclasses.replace(cfg, edge_capacity=cap)
        tpl = {"state": gs.empty(ck_cfg), "cursor": np.int64(0),
               "edge_capacity": np.int64(cap), "gen": np.int64(0)}
        restored, _ = checkpoint.restore(ckpt_dir, tpl)
        svc = SCCService(ck_cfg, buckets=(64, batch),
                         state=restored["state"])
        cursor = int(restored["cursor"])
        # gen continuity: the restored service (and therefore every new
        # client session, whose read-your-writes token seeds from it)
        # resumes exactly at the generation the checkpoint committed.
        saved_gen = int(meta["gen"])
        assert svc.gen == saved_gen == int(restored["state"].gen), (
            f"generation discontinuity across restore: service at "
            f"{svc.gen}, checkpoint recorded {saved_gen}")
        print(f"[recovery] resumed at chunk {cursor} (capacity {cap}, "
              f"gen {saved_gen})")
    if svc is None:
        svc = SCCService(cfg, buckets=(64, batch),
                         state=gs.all_singletons(cfg))

    # one shared broker; per-session typed clients on top
    broker = QueryBroker(svc, buckets=(64, queries)).start()
    updater = GraphClient(svc, broker=broker)
    if cursor == 0 and int(gs.live_edge_count(svc.state)) == 0:
        preload_graph(updater, nv, preload)  # no usable checkpoint
    assert updater.token == svc.gen  # session token tracks the commit line

    # the reader path: per-thread client sessions over the shared broker
    stop = threading.Event()
    reader_stats = [{"queries": 0} for _ in range(args.readers)]
    readers = [threading.Thread(
        target=reader_loop,
        args=(GraphClient(svc, broker=broker), stop, nv, queries, 100 + i,
              reader_stats[i]), daemon=True)
        for i in range(args.readers)]
    for t in readers:
        t.start()

    times = []
    stragglers = 0
    t_start = time.perf_counter()
    try:
        for step in range(cursor, steps):
            ops = typed_op_stream(nv, batch, step=step, add_frac=0.7)
            t0 = time.perf_counter()
            updater.submit_many(ops)
            dt = time.perf_counter() - t0
            times.append(dt)
            med = sorted(times[-50:])[len(times[-50:]) // 2]
            if len(times) > 5 and dt > 3 * med:
                stragglers += 1
                print(f"[straggler] chunk {step}: {dt*1e3:.0f}ms vs median "
                      f"{med*1e3:.0f}ms")
            if (step + 1) % ckpt_every == 0:
                st = updater.stats()
                checkpoint.save(
                    ckpt_dir, step + 1,
                    {"state": svc.state, "cursor": np.int64(step + 1),
                     "edge_capacity": np.int64(svc.cfg.edge_capacity),
                     "gen": np.int64(svc.gen)})
                print(f"[ckpt] chunk {step+1} | {batch/med:.0f} updates/s"
                      f" | {st['n_ccs']} SCCs | gen={st['gen']}"
                      f" | capacity={st['edge_capacity']}"
                      f" (grows={st['grows']}, "
                      f"replayed={st['replayed_ops']},"
                      f" compactions={st['compactions']})")
    finally:
        stop.set()
        for t in readers:
            t.join()
        broker.stop()
    for r in reader_stats:
        if "error" in r:
            raise r["error"]

    total = time.perf_counter() - t_start
    done = steps - cursor
    n_queries = sum(r["queries"] for r in reader_stats)
    st = updater.stats()
    print(f"\nserved {done} chunks in {total:.1f}s | "
          f"{done*batch/total:.0f} updates/s | "
          f"{n_queries/total:.0f} queries/s ({args.readers} readers, "
          f"{st['coalescing']:.0f} coalesced/flush) | "
          f"stragglers={stragglers} | "
          f"compiled shapes={st['compile_count']} | "
          f"pipelined={st['pipelined_chunks']} "
          f"fallback={st['fallback_chunks']} "
          f"gen_waits={st['gen_waits']}")


if __name__ == "__main__":
    main()
