"""End-to-end driver: a fault-tolerant dynamic-SCC serving loop.

This is the paper's system run the way it would run in production, now on
top of the streaming service layer (:mod:`repro.core.service`):
  * a sustained stream of update chunks + snapshot query batches (the
    paper's mixed workload, Fig 4/5), cut into bucketed static batch
    shapes so compilation count stays bounded,
  * **grow-and-replay**: the edge table starts deliberately small; when
    probe-bound overflow drops an insert, the service rehashes into a
    larger capacity and replays it -- no edge is ever lost,
  * periodic atomic checkpoints of the WHOLE GraphState (the engine's
    "database") with crash-safe restore -- kill it mid-run and restart to
    see it resume at the checkpointed chunk cursor.  The checkpoint
    records the (possibly grown) edge capacity so restore rebuilds the
    right template shapes,
  * throughput + straggler accounting per chunk; GC (edge-table
    compaction) happens inside the service when tombstones pile up.

    PYTHONPATH=src python examples/dynamic_scc_serving.py [--steps N]
"""
import argparse
import dataclasses
import os
import time

import numpy as np

from repro.ckpt import checkpoint
from repro.core import dynamic, graph_state as gs
from repro.core.service import SCCService
from repro.data import pipeline

NV = 4096
BATCH = 256
QUERIES = 1024
CKPT_DIR = "/tmp/smscc_serving_ckpt"
CKPT_EVERY = 10


def build_service(cfg: gs.GraphConfig):
    """Preloaded service: random digraph loaded THROUGH the service so the
    deliberately undersized table grows (and replays) instead of silently
    dropping edges the way a raw bulk insert would."""
    rng = np.random.default_rng(0)
    svc = SCCService(cfg, buckets=(64, BATCH), state=gs.all_singletons(cfg))
    n = 4000
    svc.apply(np.full(n, dynamic.ADD_EDGE, np.int32),
              rng.integers(0, NV, n), rng.integers(0, NV, n))
    st = svc.stats()
    print(f"[preload] {st['live_edges']} edges | capacity "
          f"{st['edge_capacity']} (grows={st['grows']}, "
          f"replayed={st['replayed_ops']})")
    return svc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--reset", action="store_true")
    args = ap.parse_args()
    if args.reset and os.path.exists(CKPT_DIR):
        for f in os.listdir(CKPT_DIR):
            os.remove(os.path.join(CKPT_DIR, f))

    cfg = gs.GraphConfig(n_vertices=NV, edge_capacity=2 ** 12,
                         max_probes=128, max_outer=64, max_inner=128)
    svc = None
    cursor = 0

    # crash recovery: the meta leaves restore first (extra npz keys are
    # ignored), telling us what edge capacity the state template needs --
    # the table may have grown beyond the boot config before the crash.
    try:
        meta, _ = checkpoint.restore(
            CKPT_DIR, {"cursor": np.int64(0),
                       "edge_capacity": np.int64(cfg.edge_capacity)})
    except KeyError:  # checkpoint from an older format: start fresh, and
        # clear the stale files so a future torn-LATEST fallback cannot
        # resurrect them over newer new-format progress
        print("[recovery] unreadable (old-format) checkpoint removed")
        for f in os.listdir(CKPT_DIR):
            os.remove(os.path.join(CKPT_DIR, f))
        meta = None
    if meta is not None:
        cap = int(meta["edge_capacity"])
        ck_cfg = dataclasses.replace(cfg, edge_capacity=cap)
        tpl = {"state": gs.empty(ck_cfg), "cursor": np.int64(0),
               "edge_capacity": np.int64(cap)}
        restored, _ = checkpoint.restore(CKPT_DIR, tpl)
        svc = SCCService(ck_cfg, buckets=(64, BATCH),
                         state=restored["state"])
        cursor = int(restored["cursor"])
        print(f"[recovery] resumed at chunk {cursor} (capacity {cap})")
    if svc is None:  # no (usable) checkpoint: pay the preload only now
        svc = build_service(cfg)

    rng = np.random.default_rng(1)
    times = []
    stragglers = 0
    t_start = time.perf_counter()
    for step in range(cursor, args.steps):
        ops = pipeline.op_stream(NV, BATCH, step=step, add_frac=0.7)
        qu = rng.integers(0, NV, QUERIES)
        qv = rng.integers(0, NV, QUERIES)
        t0 = time.perf_counter()
        svc.apply(np.asarray(ops.kind), np.asarray(ops.u),
                  np.asarray(ops.v))
        same = svc.same_scc(qu, qv)
        reach = svc.reachable(qu[:64], qv[:64])
        assert same.gen == reach.gen  # one committed snapshot per chunk
        dt = time.perf_counter() - t0
        times.append(dt)
        med = sorted(times[-50:])[len(times[-50:]) // 2]
        if len(times) > 5 and dt > 3 * med:
            stragglers += 1
            print(f"[straggler] chunk {step}: {dt*1e3:.0f}ms vs median "
                  f"{med*1e3:.0f}ms")
        if (step + 1) % CKPT_EVERY == 0:
            st = svc.stats()
            checkpoint.save(
                CKPT_DIR, step + 1,
                {"state": svc.state, "cursor": np.int64(step + 1),
                 "edge_capacity": np.int64(svc.cfg.edge_capacity)})
            print(f"[ckpt] chunk {step+1} | "
                  f"{BATCH/med:.0f} updates/s, {QUERIES/med:.0f} queries/s"
                  f" | {st['n_ccs']} SCCs | gen={st['gen']}"
                  f" | capacity={st['edge_capacity']}"
                  f" (grows={st['grows']}, replayed={st['replayed_ops']},"
                  f" compactions={st['compactions']})")

    total = time.perf_counter() - t_start
    done = args.steps - cursor
    print(f"\nserved {done} chunks in {total:.1f}s | "
          f"{done*BATCH/total:.0f} updates/s | "
          f"{done*QUERIES/total:.0f} queries/s | stragglers={stragglers} | "
          f"compiled shapes={svc.compile_count}")


if __name__ == "__main__":
    main()
