"""LM training driver: a reduced-config qwen3-style model end-to-end
(data pipeline -> sharded train step -> checkpoint/resume -> loss curve).

On CPU this runs a ~3M-param config for 60 steps in about a minute; the
same driver with ``--arch qwen3-14b --full`` is the production entry
(launch/train.py wires the production mesh).

    PYTHONPATH=src python examples/train_lm.py
"""
import dataclasses

import jax

from repro import configs
from repro.data import pipeline
from repro.models import transformer as tf
from repro.optim import optimizer
from repro.train import trainer


def main():
    smoke = configs.get("qwen3-14b").smoke_config()
    cfg = dataclasses.replace(smoke, n_layers=2, d_model=64, vocab=512)
    params = tf.init(jax.random.PRNGKey(0), cfg)
    print(f"training {cfg.name}: {tf.common.count_params(params):,} params")

    def loss_fn(p, batch):
        return tf.loss_fn(p, batch, cfg)

    def data_fn(step):
        return pipeline.lm_batch(cfg.vocab, batch=16, seq=64, step=step)

    t = trainer.Trainer(
        loss_fn, params,
        optimizer.AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=60),
        trainer.TrainerConfig(total_steps=60, ckpt_dir="/tmp/lm_ckpt",
                              ckpt_every=25, log_every=10),
        data_fn)
    log = t.run()
    print("loss curve:")
    for step, m in log:
        print(f"  step {step:3d}  loss {m['loss']:.3f}  "
              f"ce {m.get('ce', m['loss']):.3f}  lr {m['lr']:.2e}")
    first, last = log[0][1]["loss"], log[-1][1]["loss"]
    assert last < first, "loss did not decrease"
    print(f"loss {first:.2f} -> {last:.2f}  "
          f"(stragglers flagged: {t.straggler_events})")


if __name__ == "__main__":
    main()
