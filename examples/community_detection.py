"""The paper's §5.3 application: on-line community detection.

A social-graph stream (80% membership checks / 20% friendship updates,
paper Fig 5c) runs against the typed client API: updates and community
queries (`SameSCC`, `CommunityOf`, `CommunitySizes`) all go through one
:class:`repro.api.GraphClient` session, so every membership answer
carries the generation stamp of the committed snapshot it read (the
wait-free-query analogue) — no raw engine state ever reaches this driver.

    PYTHONPATH=src python examples/community_detection.py
"""
import numpy as np

from repro.api import AddEdge, CommunityOf, CommunitySizes, GraphClient, SameSCC
from repro.core import graph_state as gs
from repro.core.service import SCCService
from repro.launch.stream import typed_op_stream

NV = 1024
cfg = gs.GraphConfig(n_vertices=NV, edge_capacity=2 ** 13, max_probes=128,
                     max_outer=64, max_inner=128)

# bootstrap a random social graph through the client (every user starts as
# a singleton community; friendships stream in as typed ops)
rng = np.random.default_rng(0)
svc = SCCService(cfg, buckets=(256, 1024), state=gs.all_singletons(cfg))
client = GraphClient(svc)
client.submit_many([AddEdge(int(a), int(b)) for a, b in
                    zip(rng.integers(0, NV, 3000),
                        rng.integers(0, NV, 3000))])
st = client.stats()
print(f"bootstrap: {st['n_ccs']} communities over {NV} users "
      f"(gen {st['gen']})")

for step in range(5):
    # 20% updates (friend/unfriend) -- one typed chunk through the client
    ops = typed_op_stream(NV, 64, step=step, add_frac=0.7,
                          include_vertex_ops=False)
    accepted = sum(r.value for r in client.submit_many(ops))
    # 80% queries -- coalesced by the broker against one committed snapshot
    qu = rng.integers(0, NV, 256)
    qv = rng.integers(0, NV, 256)
    res = client.submit_many(
        [SameSCC(int(a), int(b)) for a, b in zip(qu, qv)]
        + [CommunitySizes()])
    same, sizes = res[:-1], res[-1]
    rep = int(np.argmax(sizes.value))
    print(f"step {step}: applied {accepted}/64 updates, "
          f"{sum(r.value for r in same)}/256 pairs share a community, "
          f"largest community = {int(sizes.value[rep])} users (rep {rep}), "
          f"total = {client.stats()['n_ccs']} @gen {sizes.gen}")

# friend suggestions: same-community cohort matrix from CommunityOf labels
cohort = [int(x) for x in rng.integers(0, NV, 8)]
labels = client.submit_many([CommunityOf(u) for u in cohort])
lab = np.asarray([r.value for r in labels])
ok = lab < NV
pairs = (lab[:, None] == lab[None, :]) & ok[:, None] & ok[None, :]
print("suggestion matrix for cohort", cohort)
print(pairs.astype(int))
client.close()
