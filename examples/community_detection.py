"""The paper's §5.3 application: on-line community detection.

A social-graph stream (80% membership checks / 20% friendship updates,
paper Fig 5c) runs against the dynamic engine; every batch is atomic, and
queries read a consistent snapshot (the wait-free-query analogue).

    PYTHONPATH=src python examples/community_detection.py
"""
import numpy as np

from repro.core import community, dynamic, graph_state as gs
from repro.data import pipeline

NV = 1024
cfg = gs.GraphConfig(n_vertices=NV, edge_capacity=2 ** 13, max_probes=128,
                     max_outer=64, max_inner=128)

# bootstrap a random social graph
rng = np.random.default_rng(0)
state = gs.from_arrays(cfg, rng.integers(0, NV, 3000),
                       rng.integers(0, NV, 3000))
state = dynamic.recompute(state, cfg)
print(f"bootstrap: {int(state.n_ccs)} communities over "
      f"{int(gs.live_vertex_count(state))} users")

for step in range(5):
    # 20% updates (friend/unfriend) -- one atomic batch
    ops = pipeline.op_stream(NV, 64, step=step, add_frac=0.7,
                             include_vertex_ops=False)
    state, ok = dynamic.apply_batch(state, ops, cfg)
    # 80% queries -- one vectorized gather over the same snapshot
    qu = rng.integers(0, NV, 256)
    qv = rng.integers(0, NV, 256)
    same = community.check_scc(state, qu, qv)
    rep, size = community.largest_community(state)
    print(f"step {step}: applied {int(ok.sum())}/64 updates, "
          f"{int(same.sum())}/256 pairs share a community, "
          f"largest community = {int(size)} users (rep {int(rep)}), "
          f"total = {int(state.n_ccs)}")

# friend suggestions: same-community cohort matrix
cohort = np.asarray(rng.integers(0, NV, 8))
pairs = community.same_community_pairs(state, cohort)
print("suggestion matrix for cohort", cohort.tolist())
print(np.asarray(pairs).astype(int))
