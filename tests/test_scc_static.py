"""Static parallel SCC (trim + coloring) vs the python Tarjan oracle."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 env has no hypothesis: seeded shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import scc
from oracle import tarjan_ccid

NV = 24
MAXI = NV + 2


def run_scc(edges, nv=NV, active=None):
    src = jnp.array([u for u, _ in edges] + [0], jnp.int32)[:max(len(edges), 1)]
    dst = jnp.array([v for _, v in edges] + [0], jnp.int32)[:max(len(edges), 1)]
    if not edges:
        src = jnp.zeros((1,), jnp.int32)
        dst = jnp.zeros((1,), jnp.int32)
        live = jnp.zeros((1,), bool)
    else:
        live = jnp.ones((len(edges),), bool)
    if active is None:
        active = jnp.ones((nv,), bool)
    lab = scc.scc_static(src, dst, live, active,
                         max_outer=nv, max_inner=MAXI)
    return np.asarray(lab)


def canon(lab, active=None, nv=NV):
    out = []
    for i, l in enumerate(lab):
        if active is not None and not active[i]:
            out.append(nv)
        else:
            out.append(int(l))
    return out


def test_paper_fig1():
    """Fig 1(a): three SCCs -- {8,9,10} pattern recreated as labelled sets."""
    # SCC A = {0,1,2} cycle, SCC B = {3,4} cycle, SCC C = {5}, A->B->C chain
    edges = [(0, 1), (1, 2), (2, 0), (3, 4), (4, 3), (2, 3), (4, 5)]
    lab = run_scc(edges, nv=6)
    assert lab[:6].tolist() == [0, 0, 0, 3, 3, 5]


def test_paper_fig2_addedge_merge():
    """Fig 2: adding (8,3)-style back edge merges all three SCCs."""
    edges = [(0, 1), (1, 2), (2, 0), (3, 4), (4, 3), (2, 3), (4, 5), (5, 0)]
    lab = run_scc(edges, nv=6)
    assert lab[:6].tolist() == [0] * 6


def test_empty_and_singletons():
    lab = run_scc([], nv=4)
    assert lab[:4].tolist() == [0, 1, 2, 3]


def test_masked_region_only():
    """Inactive vertices must not relay reachability (limited sweep)."""
    # 0 -> 1 -> 2 -> 0 but 1 inactive: no cycle within active set
    edges = [(0, 1), (1, 2), (2, 0)]
    active = jnp.array([True, False, True] + [True] * (NV - 3))
    lab = run_scc(edges, active=active)
    assert lab[0] == 0 and lab[2] == 2
    assert lab[1] == np.iinfo(np.int32).max  # sentinel for inactive


def test_long_cycle_and_tail():
    n = 20
    cyc = [(i, (i + 1) % 12) for i in range(12)]          # 12-cycle
    tail = [(i, i + 1) for i in range(12, n - 1)]          # DAG tail
    lab = run_scc(cyc + tail + [(11, 12)], nv=n)
    assert lab[:12].tolist() == [0] * 12
    assert lab[12:n].tolist() == list(range(12, n))


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, NV - 1), st.integers(0, NV - 1)),
                min_size=0, max_size=80))
def test_random_vs_tarjan(edge_list):
    edges = list(dict.fromkeys(edge_list))  # dedupe, keep order
    lab = run_scc(edges)
    want = tarjan_ccid(NV, edges)
    assert lab[:NV].tolist() == want


@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.integers(0, NV - 1), st.integers(0, NV - 1)),
                min_size=0, max_size=60),
       st.lists(st.booleans(), min_size=NV, max_size=NV))
def test_random_masked_vs_tarjan(edge_list, alive):
    edges = list(dict.fromkeys(edge_list))
    active = jnp.array(alive)
    lab = run_scc(edges, active=active)
    want = tarjan_ccid(NV, edges, alive)
    got = [int(l) if alive[i] else NV
           for i, l in enumerate(lab[:NV])]
    want = [w if alive[i] else NV for i, w in enumerate(want)]
    assert got == want


def test_dense_region_matches_sparse():
    rng = np.random.default_rng(0)
    for trial in range(5):
        e = rng.integers(0, NV, (60, 2))
        edges = [(int(a), int(b)) for a, b in e]
        src = jnp.array([u for u, _ in edges], jnp.int32)
        dst = jnp.array([v for _, v in edges], jnp.int32)
        live = jnp.ones((len(edges),), bool)
        region = jnp.asarray(rng.random(NV) < 0.7)
        sparse = scc.scc_static(src, dst, live, region,
                                max_outer=NV, max_inner=MAXI)
        dense, fits = scc.scc_dense_region(src, dst, live, region, NV)
        assert bool(fits)
        np.testing.assert_array_equal(
            np.where(np.asarray(region), np.asarray(dense), 0),
            np.where(np.asarray(region), np.asarray(sparse), 0))
