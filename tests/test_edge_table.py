"""Edge-table (batched open-addressing hash set) vs a python-set oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 env has no hypothesis: seeded shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import edge_table as et

CAP = 64
PROBES = CAP  # full-table probe bound: no spurious overflow in tests

# jitted wrappers: the oracle test applies ~1.5k single-op batches; eager
# dispatch of the probe loops dominates wall time, the jit cache makes the
# whole run a handful of compiles.
_insert = jax.jit(et.insert, static_argnames=("max_probes",))
_remove = jax.jit(et.remove, static_argnames=("max_probes",))
_lookup = jax.jit(et.lookup, static_argnames=("max_probes",))


def to_np(x):
    return np.asarray(x)


def test_insert_lookup_roundtrip():
    t = et.empty(CAP)
    u = jnp.array([1, 2, 3, 1], jnp.int32)
    v = jnp.array([9, 8, 7, 9], jnp.int32)  # (1,9) duplicated in batch
    t, ins, _ = et.insert(t, u, v, PROBES)
    assert to_np(ins).tolist() == [True, True, True, False]
    found, _ = et.lookup(t, u, v, PROBES)
    assert to_np(found).all()
    found, _ = et.lookup(t, jnp.array([9], jnp.int32),
                         jnp.array([1], jnp.int32), PROBES)
    assert not to_np(found).any()


def test_remove_and_tombstone_chain():
    t = et.empty(CAP)
    u = jnp.arange(10, dtype=jnp.int32)
    v = (u * 7 + 1) % 11
    t, ins, _ = et.insert(t, u, v, PROBES)
    assert to_np(ins).all()
    # remove half; duplicates in removal batch -> only first succeeds
    ru = jnp.array([0, 2, 4, 4], jnp.int32)
    rv = to_np(v)[[0, 2, 4, 4]]
    t, rem = et.remove(t, ru, jnp.asarray(rv), PROBES)
    assert to_np(rem).tolist() == [True, True, True, False]
    found, _ = et.lookup(t, u, v, PROBES)
    assert to_np(found).tolist() == [False, True, False, True, False,
                                     True, True, True, True, True]
    # compact rebuilds without tombstones; membership preserved
    t2 = et.compact(t, PROBES)
    found2, _ = et.lookup(t2, u, v, PROBES)
    assert to_np(found2).tolist() == to_np(found).tolist()
    live, tomb = et.fill_stats(t2)
    assert int(tomb) == 0 and int(live) == 7


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.booleans(),
                          st.integers(0, 15), st.integers(0, 15)),
                min_size=1, max_size=48))
def test_against_set_oracle(ops):
    """Random interleaving of inserts/removes == python set semantics when
    applied batch-by-batch of size 1."""
    t = et.empty(CAP)
    oracle = set()
    for is_ins, u, v in ops:
        uu = jnp.array([u], jnp.int32)
        vv = jnp.array([v], jnp.int32)
        if is_ins:
            t, okj, _ = _insert(t, uu, vv, max_probes=PROBES)
            ok = (u, v) not in oracle
            oracle.add((u, v))
        else:
            t, okj = _remove(t, uu, vv, max_probes=PROBES)
            ok = (u, v) in oracle
            oracle.discard((u, v))
        assert bool(okj[0]) == ok
    # final membership must match exactly
    all_u = jnp.array([a for a, _ in [(x, y) for x in range(16)
                                      for y in range(16)]], jnp.int32)
    all_v = jnp.array([b for _, b in [(x, y) for x in range(16)
                                      for y in range(16)]], jnp.int32)
    found, _ = _lookup(t, all_u, all_v, max_probes=PROBES)
    got = {(int(a), int(b)) for a, b, f in
           zip(to_np(all_u), to_np(all_v), to_np(found)) if f}
    assert got == oracle


def test_batch_insert_matches_sequential_order():
    """Intra-batch duplicate keys: exactly the first lane wins."""
    t = et.empty(CAP)
    u = jnp.array([5, 5, 5], jnp.int32)
    v = jnp.array([6, 6, 6], jnp.int32)
    t, ins, _ = et.insert(t, u, v, PROBES)
    assert to_np(ins).tolist() == [True, False, False]
    live, _ = et.fill_stats(t)
    assert int(live) == 1


def test_remove_incident():
    t = et.empty(CAP)
    u = jnp.array([0, 1, 2, 3], jnp.int32)
    v = jnp.array([1, 2, 3, 0], jnp.int32)
    t, _, _ = et.insert(t, u, v, PROBES)
    mask = jnp.zeros((8,), bool).at[1].set(True)
    t, _ = et.remove_incident(t, mask)
    found, _ = et.lookup(t, u, v, PROBES)
    assert to_np(found).tolist() == [False, False, True, True]


def test_overflow_reports_failure():
    t = et.empty(8)
    u = jnp.arange(16, dtype=jnp.int32)
    v = jnp.arange(16, dtype=jnp.int32) + 100
    t, ins, failed = et.insert(t, u, v, 8)
    assert int(jnp.sum(ins)) == 8  # table full: exactly capacity inserts
    # the table's own overflow report: exactly the dropped lanes, and
    # disjoint from the placed ones
    assert int(jnp.sum(failed)) == 8
    assert not bool(jnp.any(ins & failed))
    # duplicates and already-present keys are NOT overflow
    t2 = et.empty(8)
    du = jnp.array([1, 1, 1], jnp.int32)
    dv = jnp.array([2, 2, 2], jnp.int32)
    t2, ins2, failed2 = et.insert(t2, du, dv, 8)
    assert to_np(ins2).tolist() == [True, False, False]
    assert not to_np(failed2).any()
    _, _, failed3 = et.insert(t2, du, dv, 8)
    assert not to_np(failed3).any()
