"""Reduced-config smoke tests: one forward/train step per architecture
family on CPU, asserting shapes and finiteness; plus equivariance property
tests for the geometric GNNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # heavyweight model/launch suite: full run only

from repro.graph import batching
from repro.models import moe as moe_lib
from repro.models import transformer as tf
from repro.models.gnn import common as gc
from repro.models.gnn import egnn, gatedgcn, mace, nequip
from repro.models.recsys import mind

KEY = jax.random.PRNGKey(0)


def tiny_lm(**kw):
    base = dict(name="tiny", n_layers=2, d_model=32, n_heads=4,
                n_kv_heads=2, head_dim=8, d_ff=64, vocab=128,
                dtype=jnp.float32)
    base.update(kw)
    return tf.LMConfig(**base)


def lm_batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab, (b, s + 1))
    return {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32)}


# ------------------------------------------------------------------- LM ---

@pytest.mark.parametrize("kw", [
    dict(),                                        # plain GQA
    dict(qk_norm=True),                            # qwen3-style
    dict(window=8),                                # danube SWA
    dict(window=8, local_global=2),                # gemma3-style mix
    dict(tie_embeddings=False),
])
def test_lm_forward_variants(kw):
    cfg = tiny_lm(**kw)
    params = tf.init(KEY, cfg)
    loss, metrics = tf.loss_fn(params, lm_batch(cfg), cfg)
    assert np.isfinite(float(loss))
    assert float(loss) > 0


def test_lm_moe():
    mcfg = moe_lib.MoEConfig(n_experts=4, top_k=2, d_model=32, d_ff=32,
                             n_shared_experts=1)
    cfg = tiny_lm(moe=mcfg)
    params = tf.init(KEY, cfg)
    loss, metrics = tf.loss_fn(params, lm_batch(cfg), cfg)
    assert np.isfinite(float(loss))
    assert float(metrics["aux"]) > 0


def test_moe_dispatch_equivalence():
    """einsum vs sort dispatch agree when capacity is not binding."""
    d = 16
    x = jax.random.normal(jax.random.PRNGKey(1), (24, d))
    cfg_e = moe_lib.MoEConfig(n_experts=4, top_k=2, d_model=d, d_ff=8,
                              capacity_factor=4.0, dispatch="einsum")
    cfg_s = moe_lib.MoEConfig(n_experts=4, top_k=2, d_model=d, d_ff=8,
                              capacity_factor=4.0, dispatch="sort")
    params = moe_lib.init(jax.random.PRNGKey(2), cfg_e)
    y_e, aux_e = moe_lib.apply(params, x, cfg_e)
    y_s, aux_s = moe_lib.apply(params, x, cfg_s)
    np.testing.assert_allclose(np.asarray(y_e), np.asarray(y_s),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(aux_e), float(aux_s), rtol=1e-5)


def test_lm_grad_step_decreases_loss():
    cfg = tiny_lm()
    params = tf.init(KEY, cfg)
    batch = lm_batch(cfg)

    @jax.jit
    def step(p):
        (l, _), g = jax.value_and_grad(
            lambda p: tf.loss_fn(p, batch, cfg), has_aux=True)(p)
        return l, jax.tree.map(lambda a, b: a - 0.5 * b, p, g)

    l0, params = step(params)
    for _ in range(5):
        l1, params = step(params)
    assert float(l1) < float(l0)


def test_lm_prefill_decode_matches_full():
    """Decode token-by-token == teacher-forced forward logits."""
    cfg = tiny_lm(window=8, local_global=2)
    params = tf.init(KEY, cfg)
    b, s = 2, 12
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    # full forward logits at every position
    x = jnp.take(params["embed"], toks, axis=0)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    h, _, _ = tf._scan_layers(cfg, params, x, positions)
    h = tf.common.rms_norm(h, params["ln_f"])
    full_logits = tf._logits(cfg, params, h)
    # prefill 6, decode 6
    cache, logits_p = tf.prefill(params, toks[:, :6], cfg, cache_len=s + 4)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(full_logits[:, 5]),
                               rtol=2e-4, atol=2e-4)
    logits_d = logits_p
    for t in range(6, s):
        logits_d, cache = tf.decode_step(params, cache, toks[:, t], cfg)
        np.testing.assert_allclose(np.asarray(logits_d),
                                   np.asarray(full_logits[:, t]),
                                   rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------------ GNN ---

def graph_batch(task="energy", n_graphs=3, n_nodes=5, n_edges=10, d_feat=6,
                n_classes=3, seed=0):
    g = batching.pack_dense_batch(n_graphs, n_nodes, n_edges, seed=seed)
    rng = np.random.default_rng(seed)
    n = n_graphs * n_nodes
    batch = {
        "src": g.src, "dst": g.dst, "edge_mask": g.edge_mask,
        "node_mask": g.node_mask.astype(jnp.float32),
        "graph_id": g.graph_id,
        "x": jnp.asarray(rng.normal(size=(n, d_feat)).astype(np.float32)),
        "pos": jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32)),
    }
    if task == "energy":
        batch["energy"] = jnp.asarray(
            rng.normal(size=(n_graphs,)).astype(np.float32))
        batch["forces"] = jnp.asarray(
            rng.normal(size=(n, 3)).astype(np.float32))
    else:
        batch["labels"] = jnp.asarray(rng.integers(0, n_classes, n))
    return batch


GNN_CASES = [
    ("egnn", egnn, egnn.EGNNConfig),
    ("gatedgcn", gatedgcn, gatedgcn.GatedGCNConfig),
    ("nequip", nequip, nequip.NequIPConfig),
    ("mace", mace, mace.MACEConfig),
]


@pytest.mark.parametrize("name,mod,cfg_cls", GNN_CASES)
@pytest.mark.parametrize("task", ["energy", "node_class"])
def test_gnn_smoke(name, mod, cfg_cls, task):
    kw = dict(n_layers=2, d_feat=6, task=task, n_classes=3, n_graphs=3)
    if cfg_cls is not gatedgcn.GatedGCNConfig:
        pass
    if cfg_cls in (nequip.NequIPConfig, mace.MACEConfig):
        kw["d_hidden"] = 8
    elif cfg_cls is egnn.EGNNConfig:
        kw["d_hidden"] = 16
    else:
        kw["d_hidden"] = 16
    cfg = cfg_cls(**kw)
    params = mod.init(KEY, cfg)
    batch = graph_batch(task=task)
    loss, metrics = mod.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss)), (name, task, metrics)


@pytest.mark.parametrize("name,mod,cfg_cls", GNN_CASES[2:])  # nequip, mace
def test_equivariant_energy_invariance(name, mod, cfg_cls):
    """Rotating all positions must not change energies (E(3) invariance)."""
    cfg = cfg_cls(n_layers=2, d_hidden=8, d_feat=6, n_graphs=3)
    params = mod.init(KEY, cfg)
    batch = graph_batch()
    e1 = mod.node_energy(params, batch["pos"], batch, cfg)
    rot = gc.random_rotation(jax.random.PRNGKey(7))
    e2 = mod.node_energy(params, batch["pos"] @ rot.T, batch, cfg)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2),
                               rtol=5e-4, atol=5e-5)


def test_egnn_pos_equivariance():
    """EGNN updated positions rotate with the input rotation."""
    cfg = egnn.EGNNConfig(n_layers=2, d_hidden=16, d_feat=6, n_graphs=3)
    params = egnn.init(KEY, cfg)
    batch = graph_batch()
    _, pos1 = egnn._forward(params, batch["pos"], batch, cfg)
    rot = gc.random_rotation(jax.random.PRNGKey(8))
    _, pos2 = egnn._forward(params, batch["pos"] @ rot.T, batch, cfg)
    np.testing.assert_allclose(np.asarray(pos1 @ rot.T), np.asarray(pos2),
                               rtol=2e-3, atol=2e-4)


def test_tensor_product_equivariance():
    """Every TP path commutes with rotations."""
    rng = np.random.default_rng(0)
    rot = gc.random_rotation(jax.random.PRNGKey(9))
    n, c = 4, 3
    f = {"l0": jnp.asarray(rng.normal(size=(n, c)).astype(np.float32)),
         "l1": jnp.asarray(rng.normal(size=(n, c, 3)).astype(np.float32)),
         "l2": gc.sym_traceless(jnp.asarray(
             rng.normal(size=(n, c, 3, 3)).astype(np.float32)))}
    g = {"l0": jnp.asarray(rng.normal(size=(n, c)).astype(np.float32)),
         "l1": jnp.asarray(rng.normal(size=(n, c, 3)).astype(np.float32)),
         "l2": gc.sym_traceless(jnp.asarray(
             rng.normal(size=(n, c, 3, 3)).astype(np.float32)))}
    fr, gr = gc.rotate_feats(f, rot), gc.rotate_feats(g, rot)
    for (la, lb, lo), fn in gc.TP_PATHS.items():
        out = fn(f[f"l{la}"], g[f"l{lb}"])
        out_r = fn(fr[f"l{la}"], gr[f"l{lb}"])
        want = gc.rotate_feats({f"l{lo}": out, "l0": f["l0"] * 0}, rot)[
            f"l{lo}"] if lo > 0 else out
        np.testing.assert_allclose(np.asarray(out_r), np.asarray(want),
                                   rtol=2e-4, atol=2e-5,
                                   err_msg=f"path {(la, lb, lo)}")


# --------------------------------------------------------------- recsys ---

def test_mind_train_and_serve():
    cfg = mind.MINDConfig(n_items=200, embed_dim=16, seq_len=10,
                          n_interests=3, n_neg=16, profile_vocab=32,
                          profile_len=4)
    params = mind.init(KEY, cfg)
    rng = np.random.default_rng(0)
    b = 8
    batch = {
        "behavior": jnp.asarray(rng.integers(-1, 200, (b, 10)), jnp.int32),
        "profile": jnp.asarray(rng.integers(-1, 32, (b, 4)), jnp.int32),
        "target": jnp.asarray(rng.integers(0, 200, (b,)), jnp.int32),
        "negatives": jnp.asarray(rng.integers(0, 200, (16,)), jnp.int32),
    }
    loss, metrics = mind.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))
    u = mind.interests(params, batch["behavior"], batch["profile"], cfg)
    assert u.shape == (b, 3, 16)
    assert np.isfinite(np.asarray(u)).all()
    batch["candidates"] = jnp.asarray(
        rng.integers(0, 200, (b, 40)), jnp.int32)
    scores = mind.serve_score(params, batch, cfg)
    assert scores.shape == (b, 40)
    vals, idx = mind.retrieve_topk(params, batch, cfg, k=5)
    assert idx.shape == (b, 5)


def test_mind_interests_differ():
    """Capsules must break symmetry (distinct interests)."""
    cfg = mind.MINDConfig(n_items=100, embed_dim=8, seq_len=6,
                          n_interests=2, n_neg=4, profile_vocab=16,
                          profile_len=2)
    params = mind.init(KEY, cfg)
    rng = np.random.default_rng(1)
    behavior = jnp.asarray(rng.integers(0, 100, (4, 6)), jnp.int32)
    profile = jnp.asarray(rng.integers(0, 16, (4, 2)), jnp.int32)
    u = mind.interests(params, behavior, profile, cfg)
    diff = np.abs(np.asarray(u[:, 0]) - np.asarray(u[:, 1])).max()
    assert diff > 1e-3


def test_chunked_attention_matches_xla():
    """The §Perf online-softmax chunked path == materialized-score path."""
    rng = np.random.default_rng(11)
    b, s, h, hkv, dh = 2, 32, 4, 2, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, hkv, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, hkv, dh)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    for window in (0, 8):
        ref = tf._attention_xla(q, k, v, pos, pos, jnp.int32(window))
        got = tf._attention_chunked(q, k, v, pos, pos, jnp.int32(window),
                                    chunk=8)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


def test_chunked_attention_full_model():
    cfg = tiny_lm(attn_impl="chunked", window=8, local_global=2)
    params = tf.init(KEY, cfg)
    loss, _ = tf.loss_fn(params, lm_batch(cfg), cfg)
    cfg2 = tiny_lm(window=8, local_global=2)
    loss2, _ = tf.loss_fn(params, lm_batch(cfg2), cfg2)
    np.testing.assert_allclose(float(loss), float(loss2), rtol=1e-5)


def test_smscc_label_spec_none_unchanged():
    """label_spec plumbing must not change results (None on 1 device)."""
    from repro.core import dynamic, graph_state as gs
    cfg = gs.GraphConfig(n_vertices=16, edge_capacity=64, max_probes=64,
                         max_outer=17, max_inner=18)
    st_ = gs.empty(cfg)
    ops = dynamic.make_ops(
        [dynamic.ADD_VERTEX] * 4 + [dynamic.ADD_EDGE] * 3,
        [0, 1, 2, 3, 0, 1, 2], [0, 0, 0, 0, 1, 2, 0])
    st_, ok = dynamic.apply_batch(st_, ops, cfg)
    assert np.asarray(st_.ccid[:4]).tolist() == [0, 0, 0, 3]


def test_moe_grouped_dispatch_equivalence():
    """Grouped einsum dispatch == ungrouped when capacity is ample."""
    d = 16
    x = jax.random.normal(jax.random.PRNGKey(4), (32, d))
    base = dict(n_experts=4, top_k=2, d_model=d, d_ff=8,
                capacity_factor=8.0)
    cfg_1 = moe_lib.MoEConfig(**base, n_groups=1)
    cfg_4 = moe_lib.MoEConfig(**base, n_groups=4)
    params = moe_lib.init(jax.random.PRNGKey(5), cfg_1)
    y1, a1 = moe_lib.apply(params, x, cfg_1)
    y4, a4 = moe_lib.apply(params, x, cfg_4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4),
                               rtol=1e-4, atol=1e-5)


def test_nequip_edge_chunking_equivalence():
    """Chunked edge streaming == unchunked conv (bitwise-close)."""
    import dataclasses
    cfg = nequip.NequIPConfig(n_layers=2, d_hidden=8, d_feat=6, n_graphs=3)
    cfg_c = dataclasses.replace(cfg, edge_chunk=10)  # 30 edges -> 3 chunks
    params = nequip.init(KEY, cfg)
    batch = graph_batch()
    e1 = nequip.node_energy(params, batch["pos"], batch, cfg)
    e2 = nequip.node_energy(params, batch["pos"], batch, cfg_c)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2),
                               rtol=1e-5, atol=1e-6)


def test_nequip_edge_chunking_grad_equivalence():
    """Custom-VJP chunked conv: first-order grads (params and positions)
    match the unchunked path.  (Chunking is first-order only: the chunked
    big-graph cells are all classification; force training -- grad of
    grad -- runs unchunked.)"""
    import dataclasses
    cfg = nequip.NequIPConfig(n_layers=2, d_hidden=4, d_feat=6, n_graphs=3)
    cfg_c = dataclasses.replace(cfg, edge_chunk=10)
    params = nequip.init(KEY, cfg)
    batch = graph_batch()

    def e_sum(p, pos, c):
        return jnp.sum(nequip.node_energy(p, pos, batch, c))

    for argnum in (0, 1):
        g1 = jax.grad(e_sum, argnum)(params, batch["pos"], cfg)
        g2 = jax.grad(e_sum, argnum)(params, batch["pos"], cfg_c)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            # fp32 accumulation order differs chunked vs unchunked
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=1e-5)
