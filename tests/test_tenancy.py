"""Multi-tenant engine + service: differential vs single-tenant oracles.

The tenancy subsystem's contract is that stacking tenants behind one
vmapped engine is an *execution strategy*, not a semantics change: every
tenant's acks, generation trajectory, labelling, and live edge set must
be bit-identical to a lone :class:`repro.core.service.SCCService` fed
the same chunks -- including when another tenant forces the overflow
grow-and-replay fallback, and across an evict/rehydrate round trip
through the PR-6 durable store.  The admission queue's backpressure and
flush-trigger behaviour is pinned separately at the queue layer.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import dynamic, graph_state as gs
from repro.core.service import SCCService
from repro.tenancy import (MultiTenantService, QueueFull, TenantEngine,
                           TransferBufferPool, WorkQueue)

NV = 24


def tiny_cfg(edge_capacity=64, nv=NV):
    return gs.GraphConfig(n_vertices=nv, edge_capacity=edge_capacity,
                          max_probes=8, max_outer=nv + 1,
                          max_inner=nv + 2)


ENGINE_KNOBS = dict(buckets=(8, 16), scan_lengths=(1, 4))
ORACLE_KNOBS = dict(buckets=(8, 16), scan_lengths=(1, 4))


def oracle_for(cfg):
    return SCCService(cfg, **ORACLE_KNOBS)


def rand_chunk(rng, n, nv=NV):
    """Mixed update chunk: mostly edge churn, some vertex churn."""
    kind = rng.choice(
        [dynamic.ADD_EDGE, dynamic.ADD_EDGE, dynamic.ADD_EDGE,
         dynamic.REM_EDGE, dynamic.ADD_VERTEX, dynamic.ADD_VERTEX,
         dynamic.REM_VERTEX], size=n).astype(np.int32)
    u = rng.integers(0, nv, n).astype(np.int32)
    v = rng.integers(0, nv, n).astype(np.int32)
    return kind, u, v


def assert_tenant_matches(engine_state, engine_cfg, engine_gen, oracle,
                          ctx=""):
    assert engine_gen == int(oracle.gen), ctx
    assert engine_cfg == oracle.cfg, ctx
    assert np.array_equal(np.asarray(engine_state.ccid),
                          np.asarray(oracle.state.ccid)), ctx
    got_edges = SCCService(engine_cfg, state=engine_state).edge_set()
    assert got_edges == oracle.edge_set(), ctx


# --------------------------------------------------------------- engine


def test_engine_differential_vs_oracles():
    """3 tenants, 14 interleaved waves of random mixed chunks (varying
    sizes -> different buckets, shape-grouped dispatches, tenant-batch
    padding, idle tenants): acks, gens, labels, and edge sets must match
    three independent single-tenant services bit-for-bit."""
    cfg = tiny_cfg()
    eng = TenantEngine(tenant_batches=(1, 2, 3), **ENGINE_KNOBS)
    tids = ["a", "b", "c"]
    for tid in tids:
        eng.create_tenant(tid, cfg)
    oracles = {tid: oracle_for(cfg) for tid in tids}
    rng = np.random.default_rng(7)
    for round_i in range(14):
        wave, want = [], {}
        for tid in tids:
            if round_i and rng.random() < 0.25:
                continue            # idle tenant: must not be stepped
            n = int(rng.integers(1, 25))
            kind, u, v = rand_chunk(rng, n)
            wave.append((tid, kind, u, v))
            want[tid] = oracles[tid]._apply_ops(kind, u, v)
        res = eng.apply_chunks(wave)
        for tid, (want_ok, want_gen) in want.items():
            got_ok, got_gen = res[tid]
            assert np.array_equal(got_ok, np.asarray(want_ok)), \
                (round_i, tid)
            assert got_gen == want_gen, (round_i, tid)
        for tid in tids:
            assert eng.tenant_gen(tid) == int(oracles[tid].gen), \
                (round_i, tid)
    for tid in tids:
        assert_tenant_matches(eng.tenant_state(tid), eng.tenant_cfg(tid),
                              eng.tenant_gen(tid), oracles[tid], tid)
    assert eng.compile_count <= eng.compile_bound


def test_engine_overflow_isolation():
    """Tenant 'hog' overflows its tiny table and takes the solo
    grow-and-replay fallback; the victims sharing its dispatches must
    commit from the same wave untouched (zero fallbacks) and everyone
    stays bit-identical to their oracle."""
    cfg = tiny_cfg(edge_capacity=8)
    eng = TenantEngine(tenant_batches=(1, 2, 3), **ENGINE_KNOBS)
    tids = ["hog", "v1", "v2"]
    for tid in tids:
        eng.create_tenant(tid, cfg)
    oracles = {tid: oracle_for(cfg) for tid in tids}
    rng = np.random.default_rng(11)
    boot = np.arange(NV, dtype=np.int32)
    for tid in tids:
        kind = np.full(NV, dynamic.ADD_VERTEX, np.int32)
        want = oracles[tid]._apply_ops(kind, boot, boot)
        got = eng.apply_chunks([(tid, kind, boot, boot)])[tid]
        assert np.array_equal(got[0], np.asarray(want[0]))
    for round_i in range(6):
        wave, want = [], {}
        # hog: dense distinct-edge adds, guaranteed past capacity 8
        ku = rng.integers(0, NV, 16).astype(np.int32)
        kv = rng.integers(0, NV, 16).astype(np.int32)
        kind = np.full(16, dynamic.ADD_EDGE, np.int32)
        wave.append(("hog", kind, ku, kv))
        want["hog"] = oracles["hog"]._apply_ops(kind, ku, kv)
        for tid in ("v1", "v2"):
            k, u, v = rand_chunk(rng, 4)
            k[:] = np.where(k == dynamic.ADD_EDGE, dynamic.NOP, k)
            wave.append((tid, k, u, v))
            want[tid] = oracles[tid]._apply_ops(k, u, v)
        res = eng.apply_chunks(wave)
        for tid in tids:
            got_ok, got_gen = res[tid]
            assert np.array_equal(got_ok, np.asarray(want[tid][0])), \
                (round_i, tid)
            assert got_gen == want[tid][1], (round_i, tid)
    hog = eng.tenant_telemetry("hog")
    assert hog["fallback_chunks"] > 0, "hog never overflowed"
    assert hog["grows"] > 0
    assert eng.tenant_cfg("hog").edge_capacity > 8
    for tid in ("v1", "v2"):
        tel = eng.tenant_telemetry(tid)
        assert tel["fallback_chunks"] == 0, f"{tid} was dragged off " \
            "the fast path by another tenant's overflow"
    for tid in tids:
        assert_tenant_matches(eng.tenant_state(tid), eng.tenant_cfg(tid),
                              eng.tenant_gen(tid), oracles[tid], tid)


def test_engine_compile_bound():
    """The compiled-entry registry stays under the asserted
    ``tenant_batches x scan_lengths x buckets x cfgs`` ceiling no matter
    how chunks arrive, and idle-shape entries are never minted."""
    cfg = tiny_cfg()
    eng = TenantEngine(buckets=(8,), scan_lengths=(1,),
                       tenant_batches=(1, 2))
    for tid in ("a", "b", "c"):
        eng.create_tenant(tid, cfg)
    rng = np.random.default_rng(3)
    for _ in range(4):
        wave = [(tid, *rand_chunk(rng, 8)) for tid in ("a", "b", "c")]
        eng.apply_chunks(wave)
    # 3 tenants split as tb=2 + tb=1 over one bucket/scan/cfg
    assert eng.compile_count == 2
    assert eng.compile_count <= eng.compile_bound == 2


# -------------------------------------------------------------- service


def test_service_clients_differential():
    """Typed per-tenant GraphClient sessions over the admission queue:
    update acks and RYW generations match per-tenant oracles."""
    from repro.api import AddEdge, AddVertex, SameSCC

    cfg = tiny_cfg()
    mts = MultiTenantService(cfg, tenant_batches=(1, 2), coalesce_ops=64,
                             flush_deadline_s=0.0, **ENGINE_KNOBS)
    t0, t1 = mts.create_tenant(), mts.create_tenant()
    oracles = {t0: oracle_for(cfg), t1: oracle_for(cfg)}
    clients = {tid: mts.client(tid) for tid in (t0, t1)}
    rng = np.random.default_rng(5)
    for tid in (t0, t1):
        ops = [AddVertex(i) for i in range(NV)]
        res = clients[tid].submit_many(ops)
        kind = np.full(NV, dynamic.ADD_VERTEX, np.int32)
        ids = np.arange(NV, dtype=np.int32)
        want_ok, want_gen = oracles[tid]._apply_ops(kind, ids, ids)
        assert [r.value for r in res] == np.asarray(want_ok).tolist()
        assert all(r.gen == want_gen for r in res)
    for _ in range(5):
        for tid in (t0, t1):
            pairs = rng.integers(0, NV, (6, 2)).astype(np.int32)
            ops = [AddEdge(int(a), int(b)) for a, b in pairs]
            res = clients[tid].submit_many(ops)
            kind = np.full(6, dynamic.ADD_EDGE, np.int32)
            want_ok, want_gen = oracles[tid]._apply_ops(
                kind, pairs[:, 0], pairs[:, 1])
            assert [r.value for r in res] == np.asarray(want_ok).tolist()
            assert all(r.gen == want_gen for r in res)
    # queries answer from the committed per-tenant lane
    for tid in (t0, t1):
        qs = [SameSCC(int(a), int(b)) for a, b in
              rng.integers(0, NV, (8, 2))]
        got = [r.value for r in clients[tid].submit_many(qs)]
        from repro.core.service import same_scc_on
        want = same_scc_on(oracles[tid].state, oracles[tid].cfg,
                           [q.u for q in qs], [q.v for q in qs])
        assert got == np.asarray(want).tolist()
        assert mts.tenant_gen(tid) == int(oracles[tid].gen)
    for tid in (t0, t1):
        clients[tid].close()
    mts.close()


def test_service_evict_rehydrate_roundtrip(tmp_path):
    """Evict parks the tenant on disk (lane released, stats preserved);
    the next touch rebuilds it from snapshot + WAL tail bit-identically,
    and post-rehydration writes keep matching the oracle."""
    cfg = tiny_cfg()
    mts = MultiTenantService(cfg, tenant_batches=(1, 2),
                             directory=str(tmp_path), coalesce_ops=64,
                             flush_deadline_s=0.0, **ENGINE_KNOBS)
    tid = mts.create_tenant()
    other = mts.create_tenant()
    oracle = oracle_for(cfg)
    sess = mts.session(tid)
    rng = np.random.default_rng(9)
    boot = np.arange(NV, dtype=np.int32)
    kind = np.full(NV, dynamic.ADD_VERTEX, np.int32)
    sess._apply_ops(kind, boot, boot)
    oracle._apply_ops(kind, boot, boot)
    for _ in range(4):
        k, u, v = rand_chunk(rng, 12)
        got = sess._apply_ops(k, u, v)
        want = oracle._apply_ops(k, u, v)
        assert np.array_equal(got[0], np.asarray(want[0]))
        assert got[1] == want[1]
    pre_gen = mts.tenant_gen(tid)
    pre_ccid = np.asarray(sess.state.ccid)

    mts.evict(tid)
    st = mts.tenant_stats(tid)
    assert st["resident"] is False and st["evictions"] == 1
    assert st["gen"] == pre_gen          # parked stats stay queryable
    assert mts.tenant_gen(tid) == pre_gen
    occ = mts.engine.occupancy()
    assert occ["tenants"] == 1, "evicted lane was not released"
    assert other in mts.engine.tenant_ids()

    # touch: state read rehydrates bit-identically
    assert np.array_equal(np.asarray(sess.state.ccid), pre_ccid)
    assert mts.tenant_stats(tid)["rehydrations"] == 1
    assert mts.tenant_gen(tid) == pre_gen
    # and the rehydrated tenant keeps tracking the oracle
    for _ in range(3):
        k, u, v = rand_chunk(rng, 10)
        got = sess._apply_ops(k, u, v)
        want = oracle._apply_ops(k, u, v)
        assert np.array_equal(got[0], np.asarray(want[0]))
        assert got[1] == want[1]
    assert_tenant_matches(sess.state, sess.cfg, mts.tenant_gen(tid),
                          oracle, "post-rehydration")
    mts.close()


# ---------------------------------------------------------------- queue


def test_queue_backpressure_and_flush_triggers():
    """Over-budget submits are rejected immediately with a retry hint
    (never block-and-grow); an under-budget lone submit flushes by
    deadline; a size-triggered wave coalesces multiple tenants."""
    gate = threading.Event()
    waves = []

    def apply_fn(reqs):
        gate.wait(10)
        waves.append(sorted(t for t, *_ in reqs))
        return {t: (np.ones(k.shape[0], bool), 1) for t, k, u, v in reqs}

    q = WorkQueue(apply_fn, max_pending_ops=8, coalesce_ops=64,
                  flush_deadline_s=0.01)
    z4 = np.zeros(4, np.int32)
    leader = threading.Thread(target=lambda: q.submit("a", z4, z4, z4))
    leader.start()
    time.sleep(0.1)          # leader hit its deadline, is inside apply_fn
    follower = threading.Thread(target=lambda: q.submit(
        "b", np.zeros(8, np.int32), np.zeros(8, np.int32),
        np.zeros(8, np.int32)))
    follower.start()
    time.sleep(0.05)         # follower admitted: budget now full
    with pytest.raises(QueueFull) as ei:
        q.submit("c", z4, z4, z4)
    assert ei.value.retry_after > 0
    assert q.stats()["rejects"] == 1
    gate.set()
    leader.join(5)
    follower.join(5)
    assert not leader.is_alive() and not follower.is_alive()
    assert q.stats()["flush_causes"]["deadline"] >= 1
    assert ["a"] in waves and ["b"] in waves

    # size trigger: two tenants' chunks coalesce into one wave
    q2 = WorkQueue(apply_fn, max_pending_ops=64, coalesce_ops=8,
                   flush_deadline_s=5.0)
    gate.clear()
    waves.clear()
    ts = [threading.Thread(target=lambda t=t: q2.submit(t, z4, z4, z4))
          for t in ("x", "y")]
    for t in ts:
        t.start()
    time.sleep(0.1)
    gate.set()
    for t in ts:
        t.join(5)
        assert not t.is_alive()
    assert q2.stats()["flush_causes"]["size"] >= 1
    assert ["x", "y"] in waves, f"no coalesced wave in {waves}"


def test_transfer_pool_reuse():
    """Steady-state submits recycle pooled buffers (no allocation)."""
    pool = TransferBufferPool(buckets=(8, 32), per_bucket=2)
    a = pool.acquire(5)
    assert a.cap == 8
    pool.release(a)
    b = pool.acquire(7)
    assert b is a, "freelist buffer was not reused"
    big = pool.acquire(100)          # oversize: one-off exact alloc
    assert big.cap == 100
    pool.release(big)                # not pooled
    assert pool.acquire(100) is not big
    s = pool.stats()
    assert s["hits"] == 1 and s["misses"] >= 2
