"""Tiered region-compacted repair engine vs the full-sparse baseline.

Covers the tentpole contracts of the tiered dispatcher in
``dynamic._apply_batch_impl`` phase 5:

  * differential: dense / compact-sparse / full-sparse tiers are
    bit-identical to the untiered full-sparse path over random op mixes;
  * tier selection is monotone in region size and degrades cleanly to the
    full sweep on edge-capacity overflow;
  * the dense tier genuinely feeds the injected ``reach_blockmm``
    boolean mat-mul (Pallas) and its products agree with the jnp fallback
    on random regions;
  * the per-step telemetry (tier, region vertex/edge counts) reaches
    ``SCCService.stats()`` and ``GraphClient.stats()``.
"""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 env has no hypothesis: seeded shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import dynamic, graph_state as gs, scc
from repro.kernels import reach_blockmm as rb

NV = 32
_BASE = dict(n_vertices=NV, edge_capacity=256, max_probes=256,
             max_outer=NV + 1, max_inner=NV + 2)
CFG_FULL = gs.GraphConfig(**_BASE)
# compact tier only (regions of <= 16 vertices, 8/64 edge-slot buckets)
CFG_COMPACT = gs.GraphConfig(**_BASE, region_vertex_capacity=16,
                             region_edge_buckets=(8, 64))
# all three tiers; the dense tier runs the Pallas kernel in interpret mode
CFG_TIERED = gs.GraphConfig(**_BASE, dense_capacity=8,
                            dense_matmul_impl="pallas_interpret",
                            region_vertex_capacity=16,
                            region_edge_buckets=(8, 64))
# compact tier whose edge registry is easy to overflow (vertices fit,
# edges do not)
CFG_TINY_EDGES = gs.GraphConfig(**_BASE, region_vertex_capacity=16,
                                region_edge_buckets=(8,))


def fresh(cfg):
    st_ = gs.empty(cfg)
    ops = dynamic.make_ops([dynamic.ADD_VERTEX] * NV, list(range(NV)),
                           [0] * NV)
    st_, ok = dynamic.apply_batch(st_, ops, cfg)
    assert np.asarray(ok).all()
    return st_


def labels(state):
    return np.asarray(state.ccid).tolist()


def step(state, op_list, cfg):
    ops = dynamic.make_ops([k for k, _, _ in op_list],
                           [u for _, u, _ in op_list],
                           [v for _, _, v in op_list])
    state, ok, _, rstats = dynamic.apply_batch_async(state, ops, cfg)
    return state, np.asarray(ok).tolist(), rstats


def cycle_ops(ids):
    return [(dynamic.ADD_EDGE, ids[i], ids[(i + 1) % len(ids)])
            for i in range(len(ids))]


OPS_STRATEGY = st.lists(
    st.tuples(st.integers(0, 3), st.integers(0, NV - 1),
              st.integers(0, NV - 1)),
    min_size=1, max_size=24)


@settings(max_examples=15, deadline=None)
@given(OPS_STRATEGY)
def test_tiers_differential_random_mixes(op_list):
    """Every tier config reproduces the untiered path bit-exactly, per-op
    results included, over random mixed histories."""
    states = {cfg: fresh(cfg) for cfg in (CFG_FULL, CFG_COMPACT,
                                          CFG_TIERED, CFG_TINY_EDGES)}
    for i in range(0, len(op_list), 6):
        batch = op_list[i:i + 6]
        outs = {}
        for cfg in states:
            states[cfg], ok, _ = step(states[cfg], batch, cfg)
            outs[cfg] = (labels(states[cfg]), ok)
        want = outs[CFG_FULL]
        for cfg in (CFG_COMPACT, CFG_TIERED, CFG_TINY_EDGES):
            assert outs[cfg] == want, batch


def test_all_three_tiers_fire_and_agree():
    """Growing cycle merges walk the dispatcher through dense -> compact
    -> full, each bit-identical to the untiered baseline."""
    want_tier = {4: dynamic.TIER_DENSE, 12: dynamic.TIER_COMPACT,
                 20: dynamic.TIER_FULL}
    for k, want in want_tier.items():
        s_full = fresh(CFG_FULL)
        s_tier = fresh(CFG_TIERED)
        s_full, ok_full, _ = step(s_full, cycle_ops(list(range(k))),
                                  CFG_FULL)
        s_tier, ok_tier, rstats = step(s_tier, cycle_ops(list(range(k))),
                                       CFG_TIERED)
        assert int(rstats.tier) == want, k
        assert int(rstats.region_vertices) == k
        assert int(rstats.region_edges) == k
        assert labels(s_full) == labels(s_tier)
        assert ok_full == ok_tier


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(2, NV - 1), min_size=2, max_size=5))
def test_tier_selection_monotone_in_region_size(sizes):
    """A strictly larger affected region never selects a smaller tier."""
    picks = []
    for k in sorted(set(sizes)):
        s = fresh(CFG_TIERED)
        _, _, rstats = step(s, cycle_ops(list(range(k))), CFG_TIERED)
        picks.append((k, int(rstats.tier)))
    tiers = [t for _, t in picks]
    assert tiers == sorted(tiers), picks


def test_edge_capacity_overflow_falls_back_to_full():
    """Region vertices fit the compact tier but its edge registry cannot
    hold the live intra-region edges: dispatch must degrade to the full
    sweep and still produce the exact partition."""
    k4 = [(dynamic.ADD_EDGE, u, v) for u in range(4) for v in range(4)
          if u != v]  # 12 edges > the 8-slot registry of CFG_TINY_EDGES
    s_full = fresh(CFG_FULL)
    s_tiny = fresh(CFG_TINY_EDGES)
    s_full, _, _ = step(s_full, k4, CFG_FULL)
    s_tiny, _, rstats = step(s_tiny, k4, CFG_TINY_EDGES)
    assert int(rstats.tier) == dynamic.TIER_FULL
    assert int(rstats.region_vertices) == 4  # fits vcap; edges overflowed
    assert int(rstats.region_edges) == 12
    assert labels(s_full) == labels(s_tiny)


def test_compact_region_roundtrip_labels():
    """scc_compact_region == scc_static on the same region mask (the
    bit-identity the compact tier relies on), across random graphs."""
    rng = np.random.default_rng(7)
    for trial in range(5):
        e = rng.integers(0, NV, (70, 2))
        src = jnp.asarray(e[:, 0], jnp.int32)
        dst = jnp.asarray(e[:, 1], jnp.int32)
        live = jnp.asarray(rng.random(70) < 0.9)
        region = jnp.asarray(rng.random(NV) < 0.6)
        want = scc.scc_static(src, dst, live, region, max_outer=NV,
                              max_inner=NV + 2)
        got, fits = scc.scc_compact_region(src, dst, live, region, NV, 128,
                                           max_outer=NV, max_inner=NV + 2)
        assert bool(fits)
        np.testing.assert_array_equal(
            np.where(np.asarray(region), np.asarray(got), 0),
            np.where(np.asarray(region), np.asarray(want), 0))


def test_compact_region_preserves_unassigned_sentinel():
    """When max_outer is exhausted mid-region, slots scc_static left
    unassigned must surface as the INT32_MAX sentinel from the compact
    tier too -- never a clipped real vertex id."""
    # two SCC layers: cycle {0,1} -> cycle {2,3}; max_outer=1 assigns only
    # the source layer and must leave {2,3} at the sentinel
    src = jnp.array([0, 1, 2, 3, 1], jnp.int32)
    dst = jnp.array([1, 0, 3, 2, 2], jnp.int32)
    live = jnp.ones((5,), bool)
    region = jnp.zeros((NV,), bool).at[:4].set(True)
    want = scc.scc_static(src, dst, live, region, max_outer=1,
                          max_inner=NV)
    got, fits = scc.scc_compact_region(src, dst, live, region, 16, 16,
                                       max_outer=1, max_inner=NV)
    assert bool(fits)
    np.testing.assert_array_equal(np.asarray(got)[:4], np.asarray(want)[:4])
    sent = np.iinfo(np.int32).max
    assert np.asarray(want)[2] == sent  # the scenario really starves


def test_injected_matmul_matches_fallback_on_random_regions():
    """Satellite: the Pallas product the dense tier now feeds agrees with
    the jnp fallback product on random region adjacencies."""
    rng = np.random.default_rng(3)
    for trial in range(4):
        e = rng.integers(0, NV, (60, 2))
        src = jnp.asarray(e[:, 0], jnp.int32)
        dst = jnp.asarray(e[:, 1], jnp.int32)
        live = jnp.ones((60,), bool)
        region = jnp.asarray(rng.random(NV) < 0.5)

        def injected(a, b):
            return rb.bool_matmul(a, b, block=32, impl="pallas_interpret")

        lab_k, fits = scc.scc_dense_region(src, dst, live, region, NV,
                                           matmul=injected)
        lab_j, _ = scc.scc_dense_region(src, dst, live, region, NV)
        assert bool(fits)
        np.testing.assert_array_equal(np.asarray(lab_k), np.asarray(lab_j))
        # and the raw closure products themselves
        adj, _, _, _ = scc.gather_region(src, dst, live, region, NV)
        np.testing.assert_array_equal(
            np.asarray(scc.closure_dense(adj, injected)),
            np.asarray(scc.closure_dense(adj, None)))


def test_dense_tier_runs_injected_kernel_product():
    """The dense tier's labels under the tiered config (Pallas product)
    equal the labels under an identical config forced onto the jnp oracle
    product -- the kernel is genuinely in the dataflow, not bypassed."""
    cfg_xla = gs.GraphConfig(**_BASE, dense_capacity=8,
                             dense_matmul_impl="xla",
                             region_vertex_capacity=16,
                             region_edge_buckets=(8, 64))
    s_pallas = fresh(CFG_TIERED)
    s_xla = fresh(cfg_xla)
    ops = cycle_ops(list(range(5)))
    s_pallas, _, rs1 = step(s_pallas, ops, CFG_TIERED)
    s_xla, _, rs2 = step(s_xla, ops, cfg_xla)
    assert int(rs1.tier) == int(rs2.tier) == dynamic.TIER_DENSE
    assert labels(s_pallas) == labels(s_xla)


def test_service_and_client_surface_tier_telemetry():
    """Per-step tier telemetry flows SCCService.stats() -> GraphClient."""
    from repro.api import AddEdge, GraphClient
    from repro.core.service import SCCService

    svc = SCCService(CFG_TIERED, buckets=(8, 32),
                     state=gs.all_singletons(CFG_TIERED))
    client = GraphClient(svc)
    client.submit_many([AddEdge(u, (u + 1) % 4) for u in range(4)])  # dense
    client.submit_many(
        [AddEdge(u, (u + 1) % 12) for u in range(12)])  # compact
    client.submit_many(
        [AddEdge(u, (u + 1) % 20) for u in range(20)])  # full
    s = client.stats()
    assert s["repair_dense_steps"] >= 1
    assert s["repair_compact_steps"] >= 1
    assert s["repair_full_steps"] >= 1
    n_steps = (s["repair_dense_steps"] + s["repair_compact_steps"]
               + s["repair_full_steps"])
    assert n_steps >= 3  # one per bucket batch, replay batches included
    assert s["repair_region_v_max"] == 20
    assert s["repair_region_e_max"] >= 20  # final merge sees the whole ring
    client.close()
