"""Data pipeline / optimizer / compression / checkpoint / trainer tests,
including the preemption-resume determinism property."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint
from repro.data import pipeline
from repro.optim import compression, optimizer
from repro.train import trainer


# ----------------------------------------------------------------- data ---

def test_lm_batch_deterministic_and_sharded():
    b1 = pipeline.lm_batch(64, 8, 12, step=3)
    b2 = pipeline.lm_batch(64, 8, 12, step=3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = pipeline.lm_batch(64, 8, 12, step=4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    s0 = pipeline.lm_batch(64, 8, 12, step=3,
                           info=pipeline.ShardInfo(0, 2))
    s1 = pipeline.lm_batch(64, 8, 12, step=3,
                           info=pipeline.ShardInfo(1, 2))
    assert s0["tokens"].shape == (4, 12)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_op_stream_mix():
    from repro.core import dynamic
    ops = pipeline.op_stream(100, 4000, step=0, add_frac=0.9)
    kinds = np.asarray(ops.kind)
    adds = np.isin(kinds, [dynamic.ADD_EDGE, dynamic.ADD_VERTEX]).mean()
    assert 0.85 < adds < 0.95


def test_molecule_and_nodeclass_batches():
    mb = pipeline.molecule_batch(4, 6, 10, 5, step=0)
    assert mb["x"].shape == (24, 5) and mb["energy"].shape == (4,)
    nb = pipeline.node_class_graph(50, 200, 8, 4, seed=1)
    assert nb["labels"].shape == (50,)


# ------------------------------------------------------------- optimizer ---

def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    cfg = optimizer.AdamWConfig(lr=0.3, weight_decay=0.0, warmup_steps=0,
                                total_steps=200, schedule="const")
    state = optimizer.init(params)
    for _ in range(150):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, m = optimizer.update(grads, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05
    assert float(m["grad_norm"]) >= 0


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = optimizer.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(optimizer.global_norm(clipped)), 1.0,
                               rtol=1e-5)


def test_schedule_shapes():
    cfg = optimizer.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lr0 = float(optimizer.schedule(cfg, jnp.int32(0)))
    lr10 = float(optimizer.schedule(cfg, jnp.int32(10)))
    lr100 = float(optimizer.schedule(cfg, jnp.int32(100)))
    assert lr0 == 0.0 and abs(lr10 - 1.0) < 1e-6
    assert abs(lr100 - cfg.min_lr_frac) < 1e-6


# ------------------------------------------------------------ compression ---

def test_error_feedback_reduces_bias():
    """With error feedback the *accumulated* quantized sum tracks the true
    sum much better than naive per-step quantization."""
    rng = np.random.default_rng(0)
    g_seq = [jnp.asarray(rng.normal(size=(64,)).astype(np.float32)) * 0.01
             for _ in range(50)]
    ef = compression.init({"g": g_seq[0]})
    acc_ef, acc_naive, acc_true = (np.zeros(64) for _ in range(3))
    for g in g_seq:
        out, ef = compression.compressed_psum({"g": g}, ef, None)
        acc_ef += np.asarray(out["g"])
        q, s, _ = compression.compress(g, jnp.zeros_like(g))
        acc_naive += np.asarray(compression.decompress(q, s))
        acc_true += np.asarray(g)
    err_ef = np.abs(acc_ef - acc_true).max()
    err_naive = np.abs(acc_naive - acc_true).max()
    assert err_ef <= err_naive * 1.5  # ef accumulates bounded error
    assert err_ef < 0.01


# ------------------------------------------------------------- checkpoint ---

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(5), "b": {"c": jnp.ones((2, 3)),
                                      "d": [jnp.zeros(2), jnp.ones(1)]}}
    checkpoint.save(str(tmp_path), 7, tree)
    got, step = checkpoint.restore(str(tmp_path), tree)
    assert step == 7
    np.testing.assert_array_equal(got["b"]["d"][1], tree["b"]["d"][1])


def test_checkpoint_gc_and_latest(tmp_path):
    tree = {"x": jnp.zeros(3)}
    for s in (1, 2, 3, 4, 5):
        checkpoint.save(str(tmp_path), s, tree, keep=2)
    files = [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
    assert sorted(files) == ["ckpt_4.npz", "ckpt_5.npz"]
    assert checkpoint.latest_step(str(tmp_path)) == 5


def test_checkpoint_torn_latest_falls_back(tmp_path):
    tree = {"x": jnp.zeros(3)}
    checkpoint.save(str(tmp_path), 1, tree)
    checkpoint.save(str(tmp_path), 2, tree)
    # corrupt LATEST's checksum target
    os.remove(os.path.join(tmp_path, "ckpt_2.npz"))
    assert checkpoint.latest_step(str(tmp_path)) == 1
    got, step = checkpoint.restore(str(tmp_path), tree)
    assert step == 1


# ---------------------------------------------------------------- trainer ---

def _toy_trainer(tmp_path=None, total=12, compress=False):
    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        loss = jnp.mean((pred - batch["y"]) ** 2)
        return loss, {}

    def data_fn(step):
        rng = np.random.default_rng(step)
        x = jnp.asarray(rng.normal(size=(16, 4)).astype(np.float32))
        w_true = jnp.asarray([[1.0], [-2.0], [0.5], [3.0]])
        return {"x": x, "y": x @ w_true}

    params = {"w": jnp.zeros((4, 1))}
    tcfg = trainer.TrainerConfig(
        total_steps=total, ckpt_dir=str(tmp_path) if tmp_path else None,
        ckpt_every=5, log_every=1, grad_compression=compress)
    ocfg = optimizer.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                                 schedule="const")
    return trainer.Trainer(loss_fn, params, ocfg, tcfg, data_fn)


def test_trainer_learns():
    t = _toy_trainer(total=60)
    log = t.run()
    assert log[-1][1]["loss"] < log[0][1]["loss"] * 0.1


def test_preemption_resume_identical(tmp_path):
    """Crash after step 7, resume from ckpt -> bit-identical final params."""
    t_full = _toy_trainer(None, total=12)
    t_full.run()
    w_full = np.asarray(t_full.state["params"]["w"])

    t_a = _toy_trainer(tmp_path, total=12)
    t_a.run(steps=7)
    t_a.save()
    del t_a  # "preemption"
    t_b = _toy_trainer(tmp_path, total=12)
    assert t_b.step == 7  # restored cursor
    t_b.run()
    np.testing.assert_array_equal(np.asarray(t_b.state["params"]["w"]),
                                  w_full)


def test_trainer_with_compression_learns():
    t = _toy_trainer(total=60, compress=True)
    log = t.run()
    assert log[-1][1]["loss"] < log[0][1]["loss"] * 0.2


def test_straggler_counter():
    t = _toy_trainer(total=30)
    t.run()
    # synthetic slow step
    t._watch_straggler(100.0)
    assert t.straggler_events >= 1
