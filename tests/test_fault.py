"""Fault-injection + failure-domain suite (PR-9).

Pins the hardening contracts of :mod:`repro.fault` and the layers it
exercises:

  * the error taxonomy: retryable flags, ``retry_after`` hints, and the
    guarantee that every serving failure is a *typed*
    :class:`~repro.fault.errors.FaultError` (bare RuntimeErrors are a
    contract breach the chaos driver also polices);
  * :class:`~repro.fault.inject.FaultPlan` determinism (a plan is a
    pure function of its seed) and the filesystem shims: EIO / ENOSPC /
    torn-write injection on the WAL with ``repair_tail`` recovering the
    valid prefix;
  * the durable store's DEGRADED state machine: a WAL fault flips
    writes to typed ``Unavailable(retry_after)`` while reads keep
    serving the committed snapshot; probes re-attach when the disk
    heals; a client with retries rides the whole window through and
    the store never loses or double-applies an acked chunk;
  * ``GraphClient`` retry policy: bounded backoff honoring
    ``retry_after``, ``DeadlineExceeded`` on budget exhaustion,
    non-retryable errors surfacing immediately, and (session, seq)
    idempotent resubmit;
  * failure-path shutdown ordering: broker/replica-set stops release
    every parked gen-waiter with a typed error -- no hangs, no bare
    RuntimeError -- and in-flight ReplicaSet queries fail over to a
    healthy peer;
  * the LogTailer-vs-trim window: a segment vanishing underneath the
    cursor (poll or constructor) is a typed resync signal
    (``WalTrimmed``), which :meth:`Replica.tail_once` absorbs as a
    snapshot fast-forward, never an exception.
"""
import os
import threading
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, strategies as st

from repro.api import AddEdge, Consistency, GraphClient, SameSCC
from repro.ckpt import oplog
from repro.ckpt.durable import DEGRADED, HEALTHY, DurableService, wal_dir
from repro.core import graph_state as gs
from repro.core.broker import QueryBroker
from repro.core.replicas import Replica, ReplicaSet
from repro.core.service import SCCService
from repro.fault import errors as fault_errors
from repro.fault.inject import (FaultPlan, FsFault, ReplicaKill, Stall,
                                fire_kills, injected)

NV = 24
KNOBS = dict(buckets=(8,), proactive_grow=True)


def tiny_cfg():
    return gs.GraphConfig(n_vertices=NV, edge_capacity=64, max_probes=16,
                          max_outer=NV + 1, max_inner=NV + 2)


def make_writer(directory, **durable_kw):
    cfg = tiny_cfg()
    durable_kw.setdefault("snapshot_every", 0)
    durable_kw.setdefault("recover_probe_s", 0.0)
    return DurableService(cfg, str(directory),
                          state=gs.all_singletons(cfg), sync_every=1,
                          **durable_kw, **KNOBS)


def chunk(rng, n=8):
    return (rng.integers(2, 4, n).astype(np.int32),
            rng.integers(0, NV, n).astype(np.int32),
            rng.integers(0, NV, n).astype(np.int32))


def leaves_equal(a, b):
    import jax
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


# ------------------------------------------------------------ taxonomy ---


def test_taxonomy_retryable_flags_and_hierarchy():
    from repro.tenancy.queue import QueueFull

    assert not fault_errors.FaultError("x").retryable
    assert fault_errors.Unavailable("x").retryable
    assert QueueFull(0.1).retryable
    for klass in (fault_errors.DeadlineExceeded,
                  fault_errors.BrokerStopped,
                  fault_errors.CapacityExhausted, fault_errors.WalGap,
                  fault_errors.WalTrimmed, fault_errors.WalCorrupt):
        e = klass("x")
        assert not e.retryable, klass
        assert isinstance(e, fault_errors.FaultError)
        assert isinstance(e, RuntimeError)  # compat: old callers keep
        #                                      catching RuntimeError
    assert issubclass(QueueFull, fault_errors.Unavailable)
    e = fault_errors.Unavailable("busy", retry_after=0.25)
    assert e.retry_after == 0.25
    assert fault_errors.Unavailable("busy").retry_after is None


# ----------------------------------------------------------- fault plan ---


def test_fault_plan_is_a_pure_function_of_seed():
    for profile in ("disk-fault", "replica-kill", "mixed"):
        a = FaultPlan.generate(7, profile, replicas=3, horizon_gens=48)
        b = FaultPlan.generate(7, profile, replicas=3, horizon_gens=48)
        assert a.events == b.events
    plans = [FaultPlan.generate(s, "mixed").events for s in range(8)]
    assert len(set(plans)) > 1  # seeds actually vary the schedule
    mixed = FaultPlan.generate(3, "mixed", replicas=2)
    assert mixed.fs and mixed.kills  # both domains scheduled
    disk = FaultPlan.generate(3, "disk-fault")
    assert disk.fs and not disk.kills
    kills = FaultPlan.generate(3, "replica-kill")
    assert kills.kills and not kills.fs


def test_fault_plan_counts_calls_per_op_and_match():
    plan = FaultPlan(fs=(FsFault("write", "wal", first=2, count=1),))
    path = "/store/wal/wal_00000001.seg"
    assert plan.check_fs("write", path) is None  # call 0
    assert plan.check_fs("fsync", path) is None  # other op: no tick
    assert plan.check_fs("write", path) is None  # call 1
    assert plan.check_fs("write", path) is not None  # call 2: in window
    assert plan.check_fs("write", path) is None  # window passed
    assert plan.check_fs("write", "/elsewhere/data.bin") is None


def test_fs_injection_eio_enospc_and_torn(tmp_path):
    d = str(tmp_path / "seg")
    w = oplog.OpLogWriter(d, sync_every=1)
    k, u, v = (np.zeros(2, np.int32),) * 3
    w.append(0, k, u, v)

    plan = FaultPlan(fs=(FsFault("write", "seg", first=0, count=1,
                                 error="enospc"),))
    with injected(plan):
        with pytest.raises(OSError) as ei:
            w.append(1, k, u, v)
        assert ei.value.errno == 28  # ENOSPC
        assert plan.triggered and plan.triggered[0][1] == "enospc"
    w.discard_tail()

    # torn write: a prefix of the record lands, then EIO -- append
    # rolls its own torn bytes back, so the log is already clean on
    # disk and repair_tail finds nothing left to drop
    plan = FaultPlan(fs=(FsFault("write", "seg", first=0, count=1,
                                 error="torn", tear_frac=0.5),))
    with injected(plan):
        with pytest.raises(OSError) as ei:
            w.append(1, k, u, v)
        assert ei.value.errno == 5  # EIO
    w.close()
    records, clean, _ = oplog.read_segment(
        oplog.list_segments(d)[-1][1])
    assert [r.gen_before for r in records] == [0]  # torn bytes invisible
    assert clean  # append truncated its own torn bytes
    assert oplog.repair_tail(d) == 0


def test_fsync_injection_hits_oplog_sync(tmp_path):
    d = str(tmp_path / "seg")
    w = oplog.OpLogWriter(d, sync_every=100)  # batch so sync() has work
    k, u, v = (np.zeros(2, np.int32),) * 3
    w.append(0, k, u, v)
    plan = FaultPlan(fs=(FsFault("fsync", "seg", first=0, count=1),))
    with injected(plan):
        with pytest.raises(OSError):
            w.sync()


def test_failed_append_rolls_back_its_own_record(tmp_path):
    # the fsync embedded in append() fails AFTER the record's bytes are
    # fully written: the never-acknowledged record must not survive on
    # disk (recovery would replay it ahead of a different chunk later
    # logged at the same generation)
    d = str(tmp_path / "seg")
    w = oplog.OpLogWriter(d, sync_every=1)
    k, u, v = (np.zeros(2, np.int32),) * 3
    w.append(0, k, u, v)
    plan = FaultPlan(fs=(FsFault("fsync", "seg", first=0, count=1),))
    with injected(plan):
        with pytest.raises(OSError):
            w.append(1, k, u, v)
    w.close()
    assert [r.gen_before for r in oplog.read_log(d)] == [0]


def test_drop_unapplied_tail_removes_unacked_records(tmp_path):
    d = str(tmp_path / "seg")
    w = oplog.OpLogWriter(d, sync_every=1)
    k, u, v = (np.zeros(2, np.int32),) * 3
    w.append(0, k, u, v)  # applied: the writer advanced to gen 1
    w.append(1, k, u, v)  # applied: gen 2
    w.append(2, k, u, v)  # a failed append whose rollback missed disk
    w.close()
    assert oplog.drop_unapplied_tail(d, 2) > 0
    assert [r.gen_before for r in oplog.read_log(d)] == [0, 1]
    assert oplog.drop_unapplied_tail(d, 2) == 0  # idempotent


# ------------------------------------------------------- degraded mode ---


def test_degraded_store_keeps_reads_and_recovers(tmp_path):
    svc = make_writer(tmp_path)
    rng = np.random.default_rng(0)
    svc._apply_ops(*chunk(rng))
    gen0, state0 = svc.gen, svc.state

    plan = FaultPlan(fs=(FsFault("write", "wal", first=0, count=2),))
    with injected(plan):
        with pytest.raises(fault_errors.Unavailable) as ei:
            svc._apply_ops(*chunk(rng))
        assert ei.value.retry_after is not None
        assert svc.health == DEGRADED
        assert svc.gen == gen0  # nothing applied
        # reads keep answering from the committed snapshot
        broker = QueryBroker(svc, buckets=(8,))
        fut = broker.submit("same_scc", [0, 1], [1, 2])
        assert broker.resolve(fut).gen == gen0
        # while degraded, updates bounce with typed Unavailable
        with pytest.raises(fault_errors.Unavailable):
            svc._apply_ops(*chunk(rng))
        assert svc.unavailable_rejects >= 1
    # plan disarmed = disk healed: the next update probes and succeeds
    ok, gen = svc._apply_ops(*chunk(rng))
    assert svc.health == HEALTHY and gen == gen0 + 1
    assert svc.degraded_count == 1 and svc.recovered_count == 1
    assert leaves_equal(state0, state0)
    svc.close()
    # acked history (and nothing else) survives on disk
    reopened = DurableService.open(str(tmp_path))
    assert reopened.gen == gen
    assert leaves_equal(reopened.state, svc.state)
    reopened.close()


def test_degraded_window_with_retrying_client_loses_nothing(tmp_path):
    svc = make_writer(tmp_path)
    client = GraphClient(svc, max_retries=16, backoff_base_s=0.001,
                         backoff_cap_s=0.01)
    oracle = SCCService(tiny_cfg(), state=gs.all_singletons(tiny_cfg()),
                        **KNOBS)
    ops = [AddEdge(int(a), int((a * 5 + 1) % NV)) for a in range(12)]
    plan = FaultPlan(fs=(FsFault("write", "wal", first=2, count=3),
                         FsFault("fsync", "wal", first=4, count=2)))
    with injected(plan):
        for op in ops:
            client.submit_many([op])  # retries ride out the window
    assert plan.triggered  # the faults really fired
    assert svc.degraded_count >= 1 and svc.health == HEALTHY
    assert client.retries >= 1
    for op in ops:
        oracle._apply_ops(*_encode_one(op))
    assert svc.gen == oracle.gen
    assert leaves_equal(svc.state, oracle.state)
    svc.close()
    reopened = DurableService.open(str(tmp_path))
    assert reopened.gen == oracle.gen
    assert leaves_equal(reopened.state, oracle.state)
    reopened.close()


def test_abandoned_failed_chunk_never_resurrects(tmp_path):
    """A chunk whose WAL append fails and which the client then gives
    up on (no retry) must not shadow a *different* chunk later logged
    at the same generation -- neither on recovery nor for replicas."""
    svc = make_writer(tmp_path)
    rng = np.random.default_rng(7)
    svc._apply_ops(*chunk(rng))
    gen0 = svc.gen
    chunk_a = chunk(rng)  # will fail; the client never retries it
    plan = FaultPlan(fs=(FsFault("fsync", "wal", first=0, count=1),))
    with injected(plan):
        with pytest.raises(fault_errors.Unavailable):
            svc._apply_ops(*chunk_a)  # fully written, fsync fails
    assert svc.gen == gen0
    chunk_b = chunk(rng)  # a DIFFERENT chunk, acked at the same gen
    ok, gen1 = svc._apply_ops(*chunk_b)
    assert gen1 > gen0 and svc.health == HEALTHY
    svc.close()
    reopened = DurableService.open(str(tmp_path))
    assert reopened.gen == gen1  # replayed B, never A
    assert leaves_equal(reopened.state, svc.state)
    reopened.close()


def test_attach_drops_failed_record_when_rollback_missed_disk(
        tmp_path, monkeypatch):
    """Belt-and-suspenders: even when append's own rollback cannot
    reach the sick disk, the re-attach probe truncates the
    valid-but-unapplied record before reopening the log."""
    svc = make_writer(tmp_path)
    rng = np.random.default_rng(8)
    svc._apply_ops(*chunk(rng))
    gen0 = svc.gen

    def no_disk(self, pos):  # rollback loses the race with the disk:
        self._pos = pos      # only the bookkeeping resets
        self._last_span = None
        self._unsynced = 0

    monkeypatch.setattr(oplog.OpLogWriter, "_discard_to", no_disk)
    plan = FaultPlan(fs=(FsFault("fsync", "wal", first=0, count=1),))
    with injected(plan):
        with pytest.raises(fault_errors.Unavailable):
            svc._apply_ops(*chunk(rng))  # record bytes survive on disk
    monkeypatch.undo()
    assert svc.gen == gen0
    recs = oplog.read_log(wal_dir(str(tmp_path)))
    assert recs and recs[-1].gen_before == gen0  # orphan really there
    ok, gen1 = svc._apply_ops(*chunk(rng))  # probe re-attaches + drops
    assert gen1 > gen0 and svc.health == HEALTHY
    svc.close()
    reopened = DurableService.open(str(tmp_path))
    assert reopened.gen == gen1
    assert leaves_equal(reopened.state, svc.state)
    reopened.close()


def _encode_one(op):
    from repro.api.ops import encode_updates
    return encode_updates([op])


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnraisableExceptionWarning")
def test_snapshot_failure_degrades_cadence_not_serving(tmp_path):
    # (np.savez's ZipFile.__del__ complains after the injected tear
    # closed its file mid-write -- expected debris of this fault)
    svc = make_writer(tmp_path / "store", snapshot_every=1)
    rng = np.random.default_rng(1)
    plan = FaultPlan(fs=(FsFault("write", "ckpt_", first=0, count=50),))
    with injected(plan):
        svc._apply_ops(*chunk(rng))  # commit is acked...
        for _ in range(50):  # ...even though its snapshot kick fails
            if svc.snapshot_failures:
                break
            time.sleep(0.02)
    assert svc.snapshot_failures >= 1
    assert svc.health == HEALTHY  # snapshot misses never block serving
    ok, gen = svc._apply_ops(*chunk(rng))
    svc.close()
    reopened = DurableService.open(str(tmp_path / "store"))
    assert reopened.gen == gen  # WAL still covers every commit
    reopened.close()


# ------------------------------------------------------- client retries ---


class _FlakyService:
    """Service stub: fails the first ``n_fail`` update chunks."""

    def __init__(self, n_fail, error=None):
        self.gen = 0
        self.n_fail = n_fail
        self.error = error or fault_errors.Unavailable(
            "transient", retry_after=0.002)
        self.attempts = 0

    def _apply_ops(self, kind, u, v, *, session=None, seq=None):
        self.attempts += 1
        if self.attempts <= self.n_fail:
            raise self.error
        self.gen += 1
        return np.ones(len(kind), bool), self.gen


def test_client_retries_transient_unavailable():
    svc = _FlakyService(3)
    client = GraphClient(svc, max_retries=8, backoff_base_s=0.001,
                         backoff_cap_s=0.004)
    res = client.submit_many([AddEdge(0, 1)])
    assert res[0].gen == 1 and svc.attempts == 4
    assert client.retries == 3
    assert client.token == 1  # RYW token advanced on the final success


def test_client_retry_exhaustion_reraises_the_typed_error():
    svc = _FlakyService(100)
    client = GraphClient(svc, max_retries=3, backoff_base_s=0.001,
                         backoff_cap_s=0.002)
    with pytest.raises(fault_errors.Unavailable):
        client.submit_many([AddEdge(0, 1)])
    assert svc.attempts == 4  # 1 + max_retries


def test_client_deadline_exceeded_is_typed_and_chains():
    svc = _FlakyService(100)
    client = GraphClient(svc, deadline_s=0.02, max_retries=1000,
                         backoff_base_s=0.005, backoff_cap_s=0.01)
    with pytest.raises(fault_errors.DeadlineExceeded) as ei:
        client.submit_many([AddEdge(0, 1)])
    assert isinstance(ei.value.__cause__, fault_errors.Unavailable)
    assert client.deadline_failures == 1


def test_client_does_not_retry_non_retryable_faults():
    svc = _FlakyService(100,
                        error=fault_errors.CapacityExhausted("full"))
    client = GraphClient(svc, max_retries=8)
    with pytest.raises(fault_errors.CapacityExhausted):
        client.submit_many([AddEdge(0, 1)])
    assert svc.attempts == 1  # no blind retries of deterministic errors


def test_client_honors_retry_after_hint():
    svc = _FlakyService(1, error=fault_errors.Unavailable(
        "wait", retry_after=0.05))
    client = GraphClient(svc, max_retries=2, backoff_base_s=0.0001,
                         backoff_cap_s=1.0)
    t0 = time.monotonic()
    client.submit_many([AddEdge(0, 1)])
    assert time.monotonic() - t0 >= 0.045  # waited the server hint


def test_idempotent_resubmit_dedups_on_session_seq():
    cfg = tiny_cfg()
    svc = SCCService(cfg, state=gs.all_singletons(cfg), **KNOBS)
    k, u, v = _encode_one(AddEdge(1, 2))
    ok1, gen1 = svc._apply_ops(k, u, v, session="s1", seq=1)
    # a retried chunk (same session+seq) returns the recorded ack and
    # does NOT advance the generation (never double-applied)
    ok2, gen2 = svc._apply_ops(k, u, v, session="s1", seq=1)
    assert gen2 == gen1 and np.array_equal(ok1, ok2)
    assert svc.deduped_resubmits == 1
    # a new seq (or another session) applies normally
    _, gen3 = svc._apply_ops(k, u, v, session="s1", seq=2)
    assert gen3 == gen1 + 1
    _, gen4 = svc._apply_ops(k, u, v, session="s2", seq=2)
    assert gen4 == gen3 + 1
    assert svc.stats()["deduped_resubmits"] == 1


# ------------------------------------------- shutdown / waiter release ---


@settings(max_examples=8)
@given(st.integers(1, 4), st.integers(1, 3))
def test_broker_stop_releases_parked_gen_waiters_typed(n_waiters,
                                                       extra_gen):
    cfg = tiny_cfg()
    svc = SCCService(cfg, state=gs.all_singletons(cfg), **KNOBS)
    broker = QueryBroker(svc, buckets=(8,))
    broker.start()
    results: list = []
    floor = svc.gen + extra_gen  # a generation that never commits

    def waiter():
        fut = broker.submit("same_scc", [0], [1], min_gen=floor)
        try:
            results.append(broker.resolve(fut, min_gen=floor))
        except BaseException as e:
            results.append(e)

    threads = [threading.Thread(target=waiter) for _ in range(n_waiters)]
    for t in threads:
        t.start()
    deadline = time.monotonic() + 5.0
    while broker.stats()["gen_waits"] < n_waiters and \
            time.monotonic() < deadline:
        time.sleep(0.002)
    broker.stop()
    for t in threads:
        t.join(timeout=5.0)
        assert not t.is_alive(), "parked waiter hung across stop()"
    assert len(results) == n_waiters
    for r in results:
        assert type(r) is fault_errors.BrokerStopped, r


def test_broker_resolve_timeout_raises_deadline_exceeded():
    cfg = tiny_cfg()
    svc = SCCService(cfg, state=gs.all_singletons(cfg), **KNOBS)
    broker = QueryBroker(svc, buckets=(8,))
    broker.start()
    fut = broker.submit("same_scc", [0], [1], min_gen=svc.gen + 10)
    with pytest.raises(fault_errors.DeadlineExceeded):
        broker.resolve(fut, min_gen=svc.gen + 10, timeout=0.05)
    broker.stop()


def test_broker_inline_resolve_deadline_is_tight():
    # inline mode (no dispatcher): the internal gen-wait slices must be
    # clamped to the remaining deadline, not overshoot it by ~0.5s
    cfg = tiny_cfg()
    svc = SCCService(cfg, state=gs.all_singletons(cfg), **KNOBS)
    broker = QueryBroker(svc, buckets=(8,))
    fut = broker.submit("same_scc", [0], [1], min_gen=svc.gen + 10)
    t0 = time.monotonic()
    with pytest.raises(fault_errors.DeadlineExceeded):
        broker.resolve(fut, min_gen=svc.gen + 10, timeout=0.05)
    assert time.monotonic() - t0 < 0.3


def test_queue_full_and_ticket_timeout_are_typed():
    from repro.tenancy.queue import QueueFull, WorkQueue

    def flush(batch):
        return {tid: (np.ones(len(k), bool), 1) for tid, k, u, v in batch}

    q = WorkQueue(flush, max_pending_ops=4, coalesce_ops=64,
                  flush_deadline_s=0.2)
    k, u, v = (np.zeros(5, np.int32),) * 3
    with pytest.raises(QueueFull) as ei:
        q.submit("t0", k, u, v)  # 5 ops > 4-op budget: immediate bounce
    assert ei.value.retryable and ei.value.retry_after is not None
    assert isinstance(ei.value, fault_errors.Unavailable)
    assert q.rejects == 1

    # ticket timeout: a non-leader waiter whose wave has not flushed yet
    # surfaces the typed DeadlineExceeded, not a bare hang
    k1 = np.zeros(1, np.int32)
    leader = threading.Thread(target=lambda: q.submit("t0", k1, k1, k1))
    leader.start()
    time.sleep(0.05)  # leadership taken, parked on the flush deadline
    with pytest.raises(fault_errors.DeadlineExceeded):
        q.submit("t1", k1, k1, k1, timeout=0.01)
    leader.join(timeout=5.0)
    assert not leader.is_alive()


# -------------------------------------------------- replica set faults ---


def _replicated(tmp_path, n=2, **rset_kw):
    svc = make_writer(tmp_path)
    rng = np.random.default_rng(2)
    svc._apply_ops(*chunk(rng))
    rset = ReplicaSet(str(tmp_path), n, query_buckets=(8,),
                      auto_tail=False, **rset_kw)
    for r in rset.replicas:
        while r.tail_once():
            pass
    return svc, rset


def test_replica_kill_flips_health_and_routing(tmp_path):
    svc, rset = _replicated(tmp_path)
    assert len(rset.healthy_replicas) == 2
    rset.replicas[0].kill()
    assert not rset.replicas[0].healthy
    assert rset.healthy_replicas == [rset.replicas[1]]
    for _ in range(4):  # all routing lands on the survivor
        fut = rset.submit("same_scc", [0], [1])
        assert rset._owner[fut][0] is rset.replicas[1]
        rset.resolve(fut)
    svc.close()


def test_no_healthy_replica_raises_unavailable_with_hint(tmp_path):
    svc, rset = _replicated(tmp_path)
    for r in rset.replicas:
        r.kill()
    with pytest.raises(fault_errors.Unavailable) as ei:
        rset.submit("same_scc", [0], [1])
    assert ei.value.retryable and ei.value.retry_after > 0
    svc.close()


def test_in_flight_query_fails_over_to_healthy_peer(tmp_path):
    svc, rset = _replicated(tmp_path)
    fut = rset.submit("same_scc", [0], [1])
    owner = rset._owner[fut][0]
    owner.kill()  # dies mid-flight: broker releases fut typed
    snap = rset.resolve(fut)  # transparently resubmitted + answered
    assert snap.gen >= 1
    assert rset.failovers == 1
    svc.close()


def test_replica_set_stop_mid_failover_releases_waiters_typed(tmp_path):
    svc, rset = _replicated(tmp_path)
    floor = svc.gen + 5  # never commits
    fut = rset.submit("same_scc", [0], [1], min_gen=floor)
    results: list = []

    def waiter():
        try:
            results.append(rset.resolve(fut, min_gen=floor))
        except BaseException as e:
            results.append(e)

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.05)
    rset.stop()
    t.join(timeout=5.0)
    assert not t.is_alive(), "rset.stop() left a resolve hanging"
    assert len(results) == 1
    assert isinstance(results[0], fault_errors.FaultError), results[0]
    # stopped set refuses new work with the typed stop error
    with pytest.raises(fault_errors.BrokerStopped):
        rset.submit("same_scc", [0], [1])
    svc.close()


def test_supervisor_restarts_killed_replica(tmp_path):
    svc = make_writer(tmp_path)
    rng = np.random.default_rng(3)
    svc._apply_ops(*chunk(rng))
    rset = ReplicaSet(str(tmp_path), 2, query_buckets=(8,),
                      poll_interval=0.01, supervise=True,
                      health_check_s=0.02)
    try:
        victim = rset.replicas[0]
        victim.kill()
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            if rset.restarts >= 1 and len(rset.healthy_replicas) == 2:
                break
            time.sleep(0.01)
        assert rset.restarts >= 1, "supervisor never restarted the kill"
        assert rset.replicas[0] is not victim  # fresh snapshot boot
        assert rset.quarantined >= 1
        # the replacement serves: converges to the writer's gen
        rset.wait_all_for_gen(svc.gen, timeout=5.0)
        fut = rset.submit("same_scc", [0], [1], min_gen=svc.gen)
        assert rset.resolve(fut, min_gen=svc.gen).gen >= svc.gen
    finally:
        rset.stop()
        svc.close()


def test_supervisor_quarantines_dead_replica_once_only(tmp_path):
    # with the restart budget exhausted, a replica that stays dead must
    # not be re-shutdown and re-counted on every supervisor sweep
    svc = make_writer(tmp_path)
    rng = np.random.default_rng(9)
    svc._apply_ops(*chunk(rng))
    rset = ReplicaSet(str(tmp_path), 2, query_buckets=(8,),
                      poll_interval=0.01, supervise=True,
                      health_check_s=0.01, max_restarts=0)
    try:
        rset.replicas[0].kill()
        deadline = time.monotonic() + 5.0
        while rset.quarantined < 1 and time.monotonic() < deadline:
            time.sleep(0.01)
        time.sleep(0.1)  # many sweeps later...
        assert rset.quarantined == 1  # ...still counted exactly once
        assert rset.restarts == 0
        assert len(rset.healthy_replicas) == 1
    finally:
        rset.stop()
        svc.close()


def test_fire_kills_is_gen_scheduled_and_once_only(tmp_path):
    svc, rset = _replicated(tmp_path)
    plan = FaultPlan(kills=(ReplicaKill(replica_id=1, at_gen=3),))
    assert fire_kills(plan, rset, writer_gen=2) == []  # too early
    assert rset.replicas[1].healthy
    fired = fire_kills(plan, rset, writer_gen=3)
    assert fired == [plan.kills[0]]
    assert not rset.replicas[1].healthy
    assert fire_kills(plan, rset, writer_gen=9) == []  # once only
    svc.close()


# ------------------------------------------------- tailer vs trim race ---


def _fill_segments(svc, rng, n=6):
    for _ in range(n):
        svc._apply_ops(*chunk(rng))


def test_tailer_poll_raises_typed_wal_trimmed(tmp_path):
    svc = make_writer(tmp_path, segment_bytes=64)  # rotate every chunk
    rng = np.random.default_rng(4)
    tailer = oplog.LogTailer(wal_dir(str(tmp_path)), from_gen=0)
    _fill_segments(svc, rng)
    assert tailer.poll(2)  # cursor sits in an early segment
    svc.snapshot_now()  # trims every segment the snapshot covers
    with pytest.raises(fault_errors.WalTrimmed):
        while True:
            tailer.poll()
            break  # pragma: no cover -- poll must raise first
    svc.close()


def test_replica_absorbs_trim_as_resync_not_exception(tmp_path):
    svc = make_writer(tmp_path, segment_bytes=64)
    rng = np.random.default_rng(5)
    rep = Replica(str(tmp_path), query_buckets=(8,), auto_tail=False)
    _fill_segments(svc, rng)
    assert rep.tail_once(2) == 2  # cursor parked in an early segment
    svc.snapshot_now()
    before = rep.resyncs
    applied = rep.tail_once()  # trimmed underneath: resync, no raise
    assert rep.resyncs == before + 1 and applied == 0
    while rep.tail_once() or rep.gen < svc.gen:
        pass
    assert rep.gen == svc.gen
    assert leaves_equal(rep.service.state, svc.state)
    svc.close()


def test_tailer_constructor_survives_trim_race(tmp_path, monkeypatch):
    svc = make_writer(tmp_path, segment_bytes=64)
    rng = np.random.default_rng(6)
    _fill_segments(svc, rng)
    # the race: a segment is listed, then trimmed before its header is
    # read -- the constructor must re-list, not leak FileNotFoundError
    real = oplog.segment_base_gen
    calls = {"n": 0}

    def flaky(path):
        calls["n"] += 1
        if calls["n"] <= 2:
            raise FileNotFoundError(path)
        return real(path)

    monkeypatch.setattr(oplog, "segment_base_gen", flaky)
    tailer = oplog.LogTailer(wal_dir(str(tmp_path)), from_gen=svc.gen)
    assert calls["n"] > 2  # retried through the race
    assert tailer.poll() == []

    # and when the segments never stop vanishing, the typed signal
    # (WalTrimmed) surfaces instead of an infinite loop
    calls["n"] = -10_000
    with pytest.raises(fault_errors.WalTrimmed):
        oplog.LogTailer(wal_dir(str(tmp_path)), from_gen=svc.gen)
    svc.close()


def test_tailer_empty_directory_still_file_not_found(tmp_path):
    os.makedirs(str(tmp_path / "w"), exist_ok=True)
    with pytest.raises(FileNotFoundError):
        oplog.LogTailer(str(tmp_path / "w"))


# ------------------------------------------------------------ stalls -----


def test_stall_injection_delays_broker_flush():
    cfg = tiny_cfg()
    svc = SCCService(cfg, state=gs.all_singletons(cfg), **KNOBS)
    broker = QueryBroker(svc, buckets=(8,))
    plan = FaultPlan(stalls=(Stall("broker_flush", first=0, count=1,
                                   seconds=0.05),))
    with injected(plan):
        t0 = time.monotonic()
        fut = broker.submit("same_scc", [0], [1])
        snap = broker.resolve(fut)
        assert time.monotonic() - t0 >= 0.045
    assert snap.gen == svc.gen


# --------------------------------------------------------- chaos smoke ---


@pytest.mark.slow
def test_chaos_soak_tiny(tmp_path):
    from repro.launch.chaos import run_chaos_soak

    rep = run_chaos_soak(str(tmp_path), seed=0, profile="mixed",
                         n_chunks=12, chunk=8, nv=48, replicas=2,
                         poll_interval=0.01, n_queries=4)
    assert rep["violations"] == []
    assert rep["acked"] + len(rep["failed"]) == rep["chunks"]


def test_client_end_to_end_over_degraded_replicated_store(tmp_path):
    """Integration: writer + replicas + typed client riding a WAL fault
    window -- acked writes visible through AT_LEAST reads afterwards."""
    svc = make_writer(tmp_path)
    rset = ReplicaSet(str(tmp_path), 2, query_buckets=(8,),
                      auto_tail=False)
    wclient = GraphClient(svc, max_retries=16, backoff_base_s=0.001,
                          backoff_cap_s=0.01)
    plan = FaultPlan(fs=(FsFault("write", "wal", first=1, count=2),))
    with injected(plan):
        for i in range(6):
            wclient.submit_many([AddEdge(i, (i + 1) % NV)])
    assert plan.triggered and svc.health == HEALTHY
    for r in rset.replicas:
        while r.tail_once():
            pass
    rclient = GraphClient(svc, broker=rset)
    res = rclient.submit_many(
        [SameSCC(0, 1)], consistency=Consistency.AT_LEAST(svc.gen))
    assert res[0].gen >= svc.gen
    svc.close()
