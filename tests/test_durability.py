"""Crash-injection suite for the durable write-ahead op log (PR-6).

Pins the durability tentpole contracts:

  * the segmented WAL round-trips typed-op records across rotations, and
    a log truncated at ANY byte offset (torn final record) yields a
    clean record *prefix* -- never garbage, never a record invented from
    partial bytes -- and ``repair_tail`` makes the store appendable
    again;
  * crash-anywhere recovery is **bit-identical**: for random typed-op
    streams, killing the store at an arbitrary WAL truncation offset or
    at any segment boundary and recovering (latest snapshot + WAL tail)
    lands exactly on some committed generation of the uninterrupted
    reference run -- same state leaves, same ``same_scc`` /
    ``community_of`` answers;
  * the two independent recovery paths agree: ``DurableService.open``
    vs :func:`repro.ckpt.durable.scratch_replay` (generation-0 snapshot
    + full log);
  * mid-snapshot crashes (torn LATEST, deleted newest npz) fall back to
    an older snapshot and converge through a longer replay;
  * ``open(to_gen=g)`` time-travels read-only to any committed
    generation;
  * a chunk whose apply fails (capacity exhausted, growth forbidden) is
    rolled back out of the WAL: recovery never replays it.

The configs are tiny and FIXED across examples/cases so the jit cache
is shared by every replay in the module.
"""
import os
import shutil
import tempfile

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 env has no hypothesis: seeded shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.ckpt import checkpoint, oplog  # noqa: F401
from repro.core import dynamic
from repro.ckpt.durable import (DurableService, scratch_replay, snap_dir,
                                wal_dir)
from repro.core import graph_state as gs
from repro.core import service as svc_mod
from repro.core.service import SCCService

NV = 24
KNOBS = dict(buckets=(8,), proactive_grow=True)
QU = np.arange(8, dtype=np.int32) % NV
QV = (QU * 5 + 3) % NV


def tiny_cfg():
    return gs.GraphConfig(n_vertices=NV, edge_capacity=64, max_probes=16,
                          max_outer=NV + 1, max_inner=NV + 2)


def chunked(op_list, size=8):
    for i in range(0, len(op_list), size):
        batch = op_list[i:i + size]
        yield (np.asarray([o[0] for o in batch], np.int32),
               np.asarray([o[1] for o in batch], np.int32),
               np.asarray([o[2] for o in batch], np.int32))


def reference_run(op_list):
    """Uninterrupted in-memory run; returns the service plus the full
    per-commit history {gen: (state, cfg)} and per-chunk acks."""
    cfg = tiny_cfg()
    svc = SCCService(cfg, state=gs.all_singletons(cfg), **KNOBS)
    hist = {svc.gen: (svc.state, svc.cfg)}
    acks = []
    for kind, u, v in chunked(op_list):
        ok, gen = svc._apply_ops(kind, u, v)
        acks.append((np.asarray(ok).tolist(), gen))
        hist[svc.gen] = (svc.state, svc.cfg)
    return svc, hist, acks


def assert_state_equal(got_state, want_state, ctx=""):
    import jax
    got = jax.tree_util.tree_leaves(got_state)
    want = jax.tree_util.tree_leaves(want_state)
    assert len(got) == len(want), ctx
    for a, b in zip(got, want):
        assert np.array_equal(np.asarray(a), np.asarray(b)), ctx


def assert_matches_reference(recovered, hist, ctx=""):
    """Recovered service sits bit-identically on SOME committed
    generation of the reference run, answers included."""
    g = recovered.gen
    assert g in hist, f"{ctx}: recovered gen {g} is not a commit point"
    ref_state, ref_cfg = hist[g]
    assert_state_equal(recovered.state, ref_state, ctx)
    assert np.array_equal(
        svc_mod.same_scc_on(recovered.state, recovered.cfg, QU, QV),
        svc_mod.same_scc_on(ref_state, ref_cfg, QU, QV)), ctx
    assert np.array_equal(
        svc_mod.community_of_on(recovered.state, recovered.cfg, QU),
        svc_mod.community_of_on(ref_state, ref_cfg, QU)), ctx
    return g


OPS_STRATEGY = st.lists(
    st.tuples(st.integers(0, 3), st.integers(0, NV - 1),
              st.integers(0, NV - 1)),
    min_size=4, max_size=40)


# ------------------------------------------------------------ WAL unit ----


def test_oplog_roundtrip_rotation_and_torn_tail(tmp_path):
    """Segmented append/read round-trip; truncation at EVERY byte offset
    of the final segment yields a clean record prefix; repair_tail makes
    the torn store appendable again."""
    d = str(tmp_path / "wal")
    rng = np.random.default_rng(0)
    w = oplog.OpLogWriter(d, segment_bytes=200, sync_every=1)
    want, gen = [], 0
    for i in range(12):
        n = int(rng.integers(1, 6))
        kind = rng.integers(0, 4, n).astype(np.int32)
        u = rng.integers(0, NV, n).astype(np.int32)
        v = rng.integers(0, NV, n).astype(np.int32)
        w.append(gen, kind, u, v)
        want.append((gen, kind.tolist(), u.tolist(), v.tolist()))
        gen += 1
        w.maybe_rotate(gen)
    w.close()
    assert len(oplog.list_segments(d)) > 2, "rotation did not happen"

    def flat(records):
        return [(r.gen_before, np.asarray(r.kind).tolist(),
                 np.asarray(r.u).tolist(), np.asarray(r.v).tolist())
                for r in records]

    assert flat(oplog.read_log(d)) == want

    last_seq, last_path = oplog.list_segments(d)[-1]
    blob = open(last_path, "rb").read()
    n_prev = len(want) - len(oplog.read_segment(last_path)[0])
    for off in range(len(blob) + 1):
        torn = str(tmp_path / "torn")
        shutil.rmtree(torn, ignore_errors=True)
        shutil.copytree(d, torn)
        tpath = os.path.join(torn, os.path.basename(last_path))
        with open(tpath, "r+b") as f:
            f.truncate(off)
        got = flat(oplog.read_log(torn))
        assert got == want[:len(got)], f"offset {off}: not a prefix"
        assert len(got) >= n_prev, f"offset {off}: lost sealed segments"
        # repair + append: the store must accept new records afterwards
        dropped = oplog.repair_tail(torn)
        assert dropped >= 0
        w2 = oplog.OpLogWriter(torn, segment_bytes=200, sync_every=1,
                               start_gen=gen)
        w2.append(gen, np.asarray([0], np.int32),
                  np.asarray([1], np.int32), np.asarray([2], np.int32))
        w2.close()
        again = flat(oplog.read_log(torn))
        assert again == got + [(gen, [0], [1], [2])], f"offset {off}"


def test_oplog_trim_keeps_coverage(tmp_path):
    """trim(min_gen) never deletes the segment that covers min_gen."""
    d = str(tmp_path / "wal")
    w = oplog.OpLogWriter(d, segment_bytes=64, sync_every=1)
    one = np.asarray([1], np.int32)
    for g in range(10):
        w.append(g, one * 3, one, one * 2)
        w.maybe_rotate(g + 1)
    w.close()
    oplog.trim(d, 7)
    records = oplog.read_log(d)
    gens = [r.gen_before for r in records]
    assert gens[0] <= 7 and gens[-1] == 9
    assert gens == list(range(gens[0], 10))


# ------------------------------------------------- crash-anywhere prop ----


@settings(max_examples=6, deadline=None)
@given(OPS_STRATEGY, st.integers(0, 10 ** 9), st.integers(0, 3))
def test_crash_replay_bit_identical(op_list, crash_seed, snap_every):
    """The tentpole property: run a random typed-op stream through a
    durable writer (tiny segments -> several rotations, optionally
    async snapshots), then crash it by (a) dropping whole tail segments
    (crash at every segment boundary) and (b) truncating the last
    remaining segment at an arbitrary byte offset (torn final record).
    Every recovery lands bit-identically on a committed generation of
    the uninterrupted reference run, and both recovery paths (latest
    snapshot + tail vs generation-0 snapshot + full log) agree."""
    base = tempfile.mkdtemp(prefix="scc-dur-")
    try:
        ref, hist, ref_acks = reference_run(op_list)
        store = os.path.join(base, "store")
        dsvc = DurableService(
            tiny_cfg(), store, state=gs.all_singletons(tiny_cfg()),
            sync_every=1, segment_bytes=192,
            snapshot_every=snap_every, snapshot_keep=10 ** 6,
            trim_on_snapshot=False, **KNOBS)
        for (kind, u, v), (want_ok, want_gen) in zip(chunked(op_list),
                                                     ref_acks):
            ok, gen = dsvc._apply_ops(kind, u, v)
            # live durable run == plain run, ack for ack
            assert np.asarray(ok).tolist() == want_ok
            assert gen == want_gen
        dsvc.close()
        assert dsvc.gen == ref.gen

        # intact recovery reaches the final generation both ways
        whole = os.path.join(base, "whole")
        shutil.copytree(store, whole)
        rec = DurableService.open(whole, snapshot_every=0)
        assert assert_matches_reference(rec, hist, "intact") == ref.gen
        scr = scratch_replay(whole)
        assert_state_equal(scr.state, rec.state, "scratch vs open")
        rec.close()

        def strip_late_snapshots(copy):
            # a store crash-cut to an earlier WAL prefix cannot contain
            # snapshots that postdate the cut: keep only the boot one
            for f in os.listdir(snap_dir(copy)):
                if f.startswith("ckpt_") and f != "ckpt_0.npz":
                    os.remove(os.path.join(snap_dir(copy), f))

        # crash at every segment boundary: only the first i segments
        # survived the crash
        segs = oplog.list_segments(wal_dir(store))
        for i in range(1, len(segs) + 1):
            cut = os.path.join(base, f"cut{i}")
            shutil.copytree(store, cut)
            strip_late_snapshots(cut)
            for seq, path in oplog.list_segments(wal_dir(cut))[i:]:
                os.remove(path)
            rec = DurableService.open(cut, snapshot_every=0)
            g = assert_matches_reference(rec, hist, f"boundary {i}")
            assert_state_equal(scratch_replay(cut, to_gen=g).state,
                               rec.state, f"boundary {i}: paths differ")
            rec.close()
            shutil.rmtree(cut)

        # torn tail: truncate the last segment at an arbitrary offset
        rng = np.random.default_rng(crash_seed)
        last_path = segs[-1][1]
        size = os.path.getsize(last_path)
        for off in {int(rng.integers(0, size + 1)) for _ in range(4)}:
            torn = os.path.join(base, f"torn{off}")
            shutil.copytree(store, torn)
            strip_late_snapshots(torn)
            with open(os.path.join(wal_dir(torn),
                                   os.path.basename(last_path)),
                      "r+b") as f:
                f.truncate(off)
            rec = DurableService.open(torn, snapshot_every=0)
            g = assert_matches_reference(rec, hist, f"torn @{off}")
            assert_state_equal(scratch_replay(torn, to_gen=g).state,
                               rec.state, f"torn @{off}: paths differ")
            rec.close()
            shutil.rmtree(torn)
    finally:
        shutil.rmtree(base, ignore_errors=True)


# ---------------------------------------------------- snapshots / misc ----


def _seed_store(base, n_chunks=6, seed=11, **durable_kw):
    rng = np.random.default_rng(seed)
    op_list = [(int(k), int(u), int(v)) for k, u, v in
               zip(rng.integers(0, 4, n_chunks * 8),
                   rng.integers(0, NV, n_chunks * 8),
                   rng.integers(0, NV, n_chunks * 8))]
    ref, hist, _ = reference_run(op_list)
    kw = dict(sync_every=1, segment_bytes=256, snapshot_every=0,
              snapshot_keep=10 ** 6, trim_on_snapshot=False)
    kw.update(durable_kw)
    dsvc = DurableService(tiny_cfg(), base,
                          state=gs.all_singletons(tiny_cfg()),
                          **kw, **KNOBS)
    for kind, u, v in chunked(op_list):
        dsvc._apply_ops(kind, u, v)
    return dsvc, ref, hist


def test_mid_snapshot_crash_falls_back(tmp_path):
    """A crash that tears the snapshot machinery (stale LATEST pointing
    at a bad npz; newest npz deleted outright) falls back to an older
    snapshot and recovers to the same final state through more WAL."""
    store = str(tmp_path / "store")
    dsvc, ref, hist = _seed_store(store)
    dsvc.snapshot_now()
    for kind, u, v in chunked([(3, 1, 2), (3, 2, 1), (1, 1, 2)] * 3):
        dsvc._apply_ops(kind, u, v)
        hist[dsvc.gen] = (dsvc.state, dsvc.cfg)
    dsvc.snapshot_now()
    dsvc.close()
    sd = snap_dir(store)
    steps = sorted(
        int(f.split("_")[1].split(".")[0]) for f in os.listdir(sd)
        if f.startswith("ckpt_") and f.endswith(".npz"))
    assert len(steps) >= 3  # boot + two manual snapshots

    # corrupt the newest snapshot's payload: LATEST checksum mismatch
    crash1 = str(tmp_path / "crash1")
    shutil.copytree(store, crash1)
    with open(os.path.join(snap_dir(crash1),
                           f"ckpt_{steps[-1]}.npz"), "r+b") as f:
        f.seek(0)
        f.write(b"\0" * 16)
    rec = DurableService.open(crash1, snapshot_every=0)
    assert rec.gen == dsvc.gen
    assert_state_equal(rec.state, hist[dsvc.gen][0], "corrupt npz")
    assert rec.replayed_wal_records > 0  # really took the longer replay
    rec.close()

    # delete the newest snapshot file entirely (LATEST now dangling)
    crash2 = str(tmp_path / "crash2")
    shutil.copytree(store, crash2)
    os.remove(os.path.join(snap_dir(crash2), f"ckpt_{steps[-1]}.npz"))
    rec = DurableService.open(crash2, snapshot_every=0)
    assert rec.gen == dsvc.gen
    assert_state_equal(rec.state, hist[dsvc.gen][0], "deleted npz")
    rec.close()


def test_time_travel_open_to_gen(tmp_path):
    """open(to_gen=g) lands read-only on the first commit >= g and is
    bit-identical to the reference run there."""
    store = str(tmp_path / "store")
    dsvc, ref, hist = _seed_store(store)
    dsvc.close()
    commits = sorted(hist)
    for g in (commits[1], commits[len(commits) // 2], commits[-1]):
        rec = DurableService.open(store, to_gen=g)
        landed = assert_matches_reference(rec, hist, f"to_gen={g}")
        assert landed >= g
        assert min(c for c in commits if c >= g) == landed
        assert rec._wal is None  # read-only: no WAL attached
        rec.close()


def test_failed_chunk_rolled_back_out_of_wal(tmp_path):
    """A chunk the service rejects wholesale (table full, growth
    forbidden) must leave no WAL record behind: recovery replays the
    accepted history only, and later appends still work."""
    cfg = gs.GraphConfig(n_vertices=NV, edge_capacity=16, max_probes=16,
                         max_outer=NV + 1, max_inner=NV + 2)
    store = str(tmp_path / "store")
    dsvc = DurableService(cfg, store, state=gs.all_singletons(cfg),
                          buckets=(8,), max_edge_capacity=16,
                          sync_every=1, snapshot_every=0)
    pairs = [(a, b) for a in range(NV) for b in range(NV) if a != b]
    one = np.full(8, dynamic.ADD_EDGE, np.int32)
    gens = [0]
    for lo in (0, 8):  # fill the 16-slot table in two committed chunks
        dsvc._apply_ops(
            one, np.asarray([p[0] for p in pairs[lo:lo + 8]], np.int32),
            np.asarray([p[1] for p in pairs[lo:lo + 8]], np.int32))
        gens.append(dsvc.gen)
    good_gen = dsvc.gen
    with pytest.raises(Exception):
        # 8 fresh edges cannot fit in a full capacity-16 table and
        # growth is forbidden: the one-chunk apply fails wholesale
        dsvc._apply_ops(one,
                        np.asarray([p[0] for p in pairs[16:24]], np.int32),
                        np.asarray([p[1] for p in pairs[16:24]], np.int32))
    assert dsvc.gen == good_gen
    assert dsvc.stats()["wal_rollbacks"] == 1
    dsvc._apply_ops(one[:1], np.asarray([pairs[9][0]], np.int32),
                    np.asarray([pairs[9][1]], np.int32))
    final_state, final_gen = dsvc.state, dsvc.gen
    dsvc.close()
    recs = oplog.read_log(wal_dir(store))
    assert [r.gen_before for r in recs] == gens
    rec = DurableService.open(store, snapshot_every=0)
    assert rec.gen == final_gen
    assert_state_equal(rec.state, final_state, "post-rollback recovery")
    rec.close()


def test_snapshot_trim_bounds_log_and_recovery_still_works(tmp_path):
    """With trim_on_snapshot, old segments disappear once a snapshot
    covers them -- and recovery (snapshot + shorter tail) still equals
    the live state."""
    store = str(tmp_path / "store")
    dsvc, ref, hist = _seed_store(store, n_chunks=10, segment_bytes=128,
                                  snapshot_every=3,
                                  trim_on_snapshot=True, snapshot_keep=3)
    if dsvc._snap_thread is not None:
        dsvc._snap_thread.join()
    dsvc.snapshot_now()
    live_state, live_gen = dsvc.state, dsvc.gen
    dsvc.close()
    recs = oplog.read_log(wal_dir(store))
    assert not recs or recs[0].gen_before > 0, "trim never dropped gen-0"
    rec = DurableService.open(store, snapshot_every=0)
    assert rec.gen == live_gen
    assert_state_equal(rec.state, live_state, "trimmed recovery")
    rec.close()
