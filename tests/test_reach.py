"""Direct unit/property tests of the reachability substrate."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 env has no hypothesis: seeded shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import reach

NV = 20


def _graph(edge_list):
    if not edge_list:
        return (jnp.zeros((1,), jnp.int32), jnp.zeros((1,), jnp.int32),
                jnp.zeros((1,), bool))
    src = jnp.asarray([u for u, _ in edge_list], jnp.int32)
    dst = jnp.asarray([v for _, v in edge_list], jnp.int32)
    return src, dst, jnp.ones((len(edge_list),), bool)


def _oracle_reach(edges, seeds, allowed, nv=NV):
    reach_set = {s for s in seeds if allowed[s]}
    frontier = set(reach_set)
    while frontier:
        nxt = set()
        for u, v in edges:
            if u in reach_set and allowed[u] and allowed[v] \
                    and v not in reach_set:
                nxt.add(v)
        reach_set |= nxt
        frontier = nxt
    return reach_set


EDGES = st.lists(st.tuples(st.integers(0, NV - 1), st.integers(0, NV - 1)),
                 min_size=0, max_size=50)


@settings(max_examples=25, deadline=None)
@given(EDGES, st.sets(st.integers(0, NV - 1), min_size=1, max_size=4),
       st.lists(st.booleans(), min_size=NV, max_size=NV))
def test_forward_reach_vs_oracle(edges, seeds, allowed):
    src, dst, live = _graph(edges)
    seed_m = jnp.zeros((NV,), bool).at[jnp.asarray(sorted(seeds))].set(True)
    allowed_m = jnp.asarray(allowed)
    got, _ = reach.forward_reach(src, dst, live, seed_m, allowed_m, NV + 1)
    want = _oracle_reach(edges, seeds, allowed)
    assert {i for i in range(NV) if got[i]} == want


@settings(max_examples=20, deadline=None)
@given(EDGES, st.integers(0, NV - 1), st.integers(0, NV - 1))
def test_is_reachable(edges, u, v):
    src, dst, live = _graph(edges)
    allowed = jnp.ones((NV,), bool)
    got = bool(reach.is_reachable(src, dst, live, u, v, allowed, NV + 1))
    want = v in _oracle_reach(edges, {u}, [True] * NV)
    assert got == want


@settings(max_examples=15, deadline=None)
@given(EDGES)
def test_multi_forward_reach_matches_single(edges):
    src, dst, live = _graph(edges)
    allowed = jnp.ones((NV,), bool)
    seeds = jnp.zeros((3, NV), bool).at[jnp.arange(3), jnp.arange(3)].set(
        True)
    multi, _ = reach.multi_forward_reach(src, dst, live, seeds, allowed,
                                         NV + 1)
    for b in range(3):
        single, _ = reach.forward_reach(src, dst, live, seeds[b], allowed,
                                        NV + 1)
        np.testing.assert_array_equal(np.asarray(multi[b]),
                                      np.asarray(single))


@settings(max_examples=15, deadline=None)
@given(EDGES, st.sets(st.integers(0, NV - 1), min_size=1, max_size=3),
       st.sets(st.integers(0, NV - 1), min_size=1, max_size=3))
def test_fused_equals_separate(edges, sf, sb):
    src, dst, live = _graph(edges)
    allowed = jnp.ones((NV,), bool)
    seed_f = jnp.zeros((NV,), bool).at[jnp.asarray(sorted(sf))].set(True)
    seed_b = jnp.zeros((NV,), bool).at[jnp.asarray(sorted(sb))].set(True)
    fw1, _ = reach.forward_reach(src, dst, live, seed_f, allowed, NV + 1)
    bw1, _ = reach.backward_reach(src, dst, live, seed_b, allowed, NV + 1)
    fw2, bw2, _ = reach.fused_fw_bw_reach(src, dst, live, seed_f, seed_b,
                                          allowed, NV + 1)
    np.testing.assert_array_equal(np.asarray(fw1), np.asarray(fw2))
    np.testing.assert_array_equal(np.asarray(bw1), np.asarray(bw2))


def test_priority_hash_bijective_inverse():
    v = jnp.arange(10000, dtype=jnp.int32)
    p = reach._prio(v)
    np.testing.assert_array_equal(np.asarray(reach._unprio(p)),
                                  np.asarray(v))
    assert len(np.unique(np.asarray(p))) == 10000
    assert 10000 < reach.SENT_PREIMAGE  # sentinel guard


@settings(max_examples=20, deadline=None)
@given(EDGES, st.lists(st.booleans(), min_size=NV, max_size=NV))
def test_min_prio_witness_vs_oracle(edges, alive):
    """witness[v] = argmin-priority over {u : u ⇝ v within active}."""
    src, dst, live = _graph(edges)
    active = jnp.asarray(alive)
    wit, _ = reach.propagate_min_prio(src, dst, live, active, 4 * NV)
    pri = np.asarray(reach._prio(jnp.arange(NV, dtype=jnp.int32)))
    for v in range(NV):
        if not alive[v]:
            assert int(wit[v]) == NV
            continue
        reachers = [u for u in range(NV) if alive[u] and
                    v in _oracle_reach(edges, {u}, alive)]
        want = min(reachers, key=lambda u: pri[u])
        assert int(wit[v]) == want, (v, reachers)


@settings(max_examples=20, deadline=None)
@given(EDGES)
def test_min_labels_shortcut_same_fixpoint(edges):
    src, dst, live = _graph(edges)
    allowed = jnp.ones((NV,), bool)
    labels = jnp.arange(NV, dtype=jnp.int32)
    a, _ = reach.propagate_min_labels(src, dst, live, labels, allowed,
                                      2 * NV)
    b, _ = reach.propagate_min_labels(src, dst, live, labels, allowed,
                                      2 * NV, shortcut=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
