"""Launch-layer tests: partition specs, mesh construction (subprocess with
512 fake devices -- main test process keeps 1 device per the mandate),
and step building + abstract lowering on the production mesh."""
import json
import subprocess
import sys
import textwrap

import jax
import pytest


from repro import configs
from repro.launch import partition
from jax.sharding import PartitionSpec as P


def test_lm_param_specs_match_tree():
    from repro.models import transformer as tf
    for arch in ("qwen3-14b", "moonshot-v1-16b-a3b"):
        cfg = configs.get(arch).smoke_config()
        params = jax.eval_shape(
            lambda: tf.init(jax.random.PRNGKey(0), cfg))

        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}

        specs = partition.lm_param_specs(cfg, FakeMesh())
        # same tree structure => every param has a spec
        jax.tree.map(lambda sds, sp: None, params, specs,
                     is_leaf=lambda x: isinstance(x, P))


def test_divisibility_fallbacks():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    cfg = configs.get("qwen3-14b").config()
    specs = partition.lm_param_specs(cfg, FakeMesh())
    # vocab 151936 % 16 == 0 -> embed sharded on model
    assert specs["embed"][0] == "model"
    # kv dim 8*128=1024 % 16 == 0 -> sharded
    assert specs["layers"]["wk"][2] == "model"


PROD_MESH_TEST = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import jax
    from repro.launch import mesh as mesh_lib, steps
    m1 = mesh_lib.make_production_mesh()
    assert m1.devices.shape == (16, 16), m1.devices.shape
    m2 = mesh_lib.make_production_mesh(multi_pod=True)
    assert m2.devices.shape == (2, 16, 16)
    assert m2.axis_names == ("pod", "data", "model")
    # build + LOWER (not compile: compile is the dry-run's job) a few cells
    for arch, shape in [("gatedgcn", "molecule"),
                        ("mind", "serve_p99"),
                        ("smscc", "community_query")]:
        b = steps.build(arch, shape, m2)
        with m2:
            jax.jit(b.fn, in_shardings=b.in_shardings,
                    out_shardings=b.out_shardings).lower(*b.args)
    # skipped long-context cells return None
    assert steps.build("qwen3-14b", "long_500k", m1) is None
    print("MESH_OK")
""")


def test_production_mesh_and_lowering_subprocess():
    """512-device mesh construction + sharded lowering in a subprocess
    (keeps this process at 1 device)."""
    r = subprocess.run([sys.executable, "-c", PROD_MESH_TEST],
                       capture_output=True, text=True, timeout=540,
                       env={"PYTHONPATH": "src",
                            "PATH": "/usr/bin:/bin",
                            # skip accelerator-plugin probing: backend
                            # discovery hangs ~7 min in a stripped env
                            "JAX_PLATFORMS": "cpu"},
                       cwd="/root/repo")
    assert "MESH_OK" in r.stdout, r.stderr[-2000:]


def test_main_process_single_device():
    assert len(jax.devices()) == 1  # smoke tests must see 1 device


def test_dryrun_collective_parser():
    from repro.launch import dryrun
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(bf16[1,128]{1,0} %x), replica_groups={}
  %ar = f32[256]{0} all-reduce(f32[256]{0} %y), to_apply=%add
  %rs = f32[16]{0} reduce-scatter(f32[256]{0} %z), dimensions={0}
    """
    out = dryrun.collective_bytes(hlo)
    assert out["all-gather"] == 8 * 128 * 2     # result side (gathered)
    assert out["all-reduce"] == 256 * 4
    assert out["reduce-scatter"] == 256 * 4     # operand side (pre-reduce)
    assert out["count_all-reduce"] == 1


ELASTIC_TEST = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.ckpt import checkpoint

    ckpt_dir = sys.argv[1]
    tree_like = {"w": jnp.zeros((16, 4)), "m": jnp.zeros((16, 4)),
                 "step": jnp.zeros((), jnp.int32)}
    restored, step = checkpoint.restore(ckpt_dir, tree_like)
    assert step == 3, step
    # place the restored (host) arrays onto a 4x2 mesh the ORIGINAL
    # single-device run never saw -- the elastic-restart path
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    w = jax.device_put(restored["w"],
                       NamedSharding(mesh, P("data", "model")))
    assert len(w.sharding.device_set) == 8
    np.testing.assert_array_equal(
        np.asarray(w), np.arange(64, dtype=np.float32).reshape(16, 4))
    print("ELASTIC_OK")
""")


def test_elastic_restore_different_mesh(tmp_path):
    """Checkpoint written by a 1-device run restores onto an 8-device
    (4x2) mesh: shardings are axis-name trees, so only device placement
    changes (elasticity per DESIGN.md §5)."""
    import jax.numpy as jnp
    from repro.ckpt import checkpoint
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(16, 4),
            "m": jnp.ones((16, 4)), "step": jnp.int32(3)}
    checkpoint.save(str(tmp_path), 3, tree)
    r = subprocess.run(
        [sys.executable, "-c", ELASTIC_TEST, str(tmp_path)],
        capture_output=True, text=True, timeout=300,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo")
    assert "ELASTIC_OK" in r.stdout, r.stderr[-1500:]
