"""segment ops / EmbeddingBag / sampler / packing unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 env has no hypothesis: seeded shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.graph import batching, sampler, segment_ops as so


def test_segment_softmax_normalizes():
    logits = jnp.array([1.0, 2.0, 3.0, -1.0, 0.5])
    seg = jnp.array([0, 0, 1, 1, 1])
    p = so.segment_softmax(logits, seg, 3)
    np.testing.assert_allclose(float(p[0] + p[1]), 1.0, rtol=1e-6)
    np.testing.assert_allclose(float(p[2] + p[3] + p[4]), 1.0, rtol=1e-6)


def test_segment_mean_std():
    x = jnp.array([[1.0], [3.0], [10.0]])
    seg = jnp.array([0, 0, 1])
    m = so.segment_mean(x, seg, 2)
    np.testing.assert_allclose(np.asarray(m), [[2.0], [10.0]], rtol=1e-6)
    s = so.segment_std(x, seg, 2)
    np.testing.assert_allclose(float(s[0, 0]), 1.0, rtol=1e-3)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 5), st.integers(1, 7), st.integers(2, 9),
       st.sampled_from(["sum", "mean", "max"]))
def test_embedding_bag_vs_manual(b, l, v, mode):
    rng = np.random.default_rng(b * 100 + l * 10 + v)
    table = jnp.asarray(rng.normal(size=(v, 3)).astype(np.float32))
    ids = rng.integers(-1, v, (b, l))  # -1 = padding
    out = so.embedding_bag(table, jnp.asarray(ids), mode=mode)
    for i in range(b):
        rows = [np.asarray(table)[j] for j in ids[i] if j >= 0]
        if not rows:
            want = np.zeros(3)
        elif mode == "sum":
            want = np.sum(rows, axis=0)
        elif mode == "mean":
            want = np.mean(rows, axis=0)
        else:
            want = np.max(rows, axis=0)
        np.testing.assert_allclose(np.asarray(out)[i], want, rtol=1e-5,
                                   atol=1e-6)


def test_embedding_bag_offsets_mode():
    table = jnp.eye(4, dtype=jnp.float32)
    ids = jnp.array([0, 1, 2, 3, 3], jnp.int32)
    offsets = jnp.array([0, 2, 4], jnp.int32)  # bags: [0,1], [2,3], [3]
    out = so.embedding_bag(table, ids, offsets=offsets, mode="sum")
    np.testing.assert_allclose(np.asarray(out),
                               [[1, 1, 0, 0], [0, 0, 1, 1], [0, 0, 0, 1]])


def test_embedding_bag_grad_flows():
    table = jnp.ones((5, 2), jnp.float32)
    ids = jnp.array([[0, 1], [2, -1]], jnp.int32)

    def loss(t):
        return jnp.sum(so.embedding_bag(t, ids) ** 2)

    g = jax.grad(loss)(table)
    assert np.asarray(g)[3].sum() == 0  # untouched row
    assert np.asarray(g)[0].sum() != 0


def test_coo_spmm_matches_dense():
    rng = np.random.default_rng(0)
    n, e = 6, 20
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    w = rng.normal(size=e).astype(np.float32)
    x = rng.normal(size=(n, 4)).astype(np.float32)
    a = np.zeros((n, n), np.float32)
    for s, d, ww in zip(src, dst, w):
        a[d, s] += ww
    got = so.coo_spmm(jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w),
                      jnp.asarray(x), n)
    np.testing.assert_allclose(np.asarray(got), a @ x, rtol=1e-4, atol=1e-5)


def test_sampler_shapes_and_determinism():
    csr = sampler.make_synthetic_csr(200, 8, seed=1)
    seeds = jnp.arange(16, dtype=jnp.int32)
    key = jax.random.PRNGKey(0)
    blocks, inputs = sampler.sample_blocks(csr, seeds, [15, 10], key)
    assert blocks[-1].src.shape == (16 * 15,)      # innermost (seed) layer
    assert blocks[0].src.shape == (16 * 15 * 10,)  # widest layer
    assert inputs.shape == (16 * 15 * 10,)
    blocks2, inputs2 = sampler.sample_blocks(csr, seeds, [15, 10], key)
    np.testing.assert_array_equal(np.asarray(inputs), np.asarray(inputs2))


def test_sampler_isolated_nodes_self_loop():
    # node 3 has no out-edges
    csr = sampler.build_csr(np.array([0, 1]), np.array([1, 2]), 4)
    blk, nxt = sampler.sample_block(csr, jnp.array([3], jnp.int32), 4,
                                    jax.random.PRNGKey(0))
    assert np.asarray(blk.src).tolist() == [3, 3, 3, 3]


def test_pack_dense_batch():
    g = batching.pack_dense_batch(4, 5, 8, seed=0)
    assert g.src.shape == (4 * 8,)
    assert g.node_mask.sum() == 4 * 5
    # edges stay within their own graph
    gid_src = np.asarray(g.graph_id)[np.asarray(g.src)]
    gid_dst = np.asarray(g.graph_id)[np.asarray(g.dst)]
    m = np.asarray(g.edge_mask)
    np.testing.assert_array_equal(gid_src[m], gid_dst[m])
