"""Streaming SCC service: grow-and-replay, bucketed scheduling, snapshots.

Differential tests drive :class:`repro.core.service.SCCService` past its
edge-table capacity and check labels, live edge set, and per-op results
against the sequential python oracle after every chunk.  The per-op
comparison replays the oracle in the documented per-bucket linearization
(REM_VERTEX -> REM_EDGE -> ADD_VERTEX -> ADD_EDGE, lane order in a phase)
-- the same contract `test_dynamic.test_batch_atomicity` pins for one
batch, extended across the scheduler's bucket cuts.
"""
import zlib

import numpy as np
import pytest

from repro.core import dynamic, edge_table as et, graph_state as gs
from repro.core.service import SCCService
from repro.launch.stream import BucketedScheduler
from oracle import SeqSCC

NV = 24
PHASE = {dynamic.REM_VERTEX: 0, dynamic.REM_EDGE: 1,
         dynamic.ADD_VERTEX: 2, dynamic.ADD_EDGE: 3}


def tiny_cfg(edge_capacity=32, max_probes=4, nv=NV):
    return gs.GraphConfig(n_vertices=nv, edge_capacity=edge_capacity,
                          max_probes=max_probes, max_outer=nv + 1,
                          max_inner=nv + 2)


def boot(svc: SCCService, oracle: SeqSCC, n=NV):
    ok = svc._apply_chunk([dynamic.ADD_VERTEX] * n, list(range(n)), [0] * n)
    assert ok.all()
    for i in range(n):
        assert oracle.add_vertex(i)


def oracle_replay(oracle: SeqSCC, sched: BucketedScheduler, kind, u, v):
    """Sequential oracle results under the per-bucket phase linearization."""
    want = np.zeros(len(kind), bool)
    for sl, _ in sched.plan(len(kind)):
        order = sorted(range(sl.start, sl.stop),
                       key=lambda i: (PHASE[int(kind[i])], i))
        for i in order:
            k, uu, vv = int(kind[i]), int(u[i]), int(v[i])
            if k == dynamic.ADD_EDGE:
                want[i] = oracle.add_edge(uu, vv)
            elif k == dynamic.REM_EDGE:
                want[i] = oracle.remove_edge(uu, vv)
            elif k == dynamic.ADD_VERTEX:
                want[i] = oracle.add_vertex(uu)
            else:
                want[i] = oracle.remove_vertex(uu)
    return want


def check_against_oracle(svc, oracle, kind, u, v):
    ok = svc._apply_chunk(kind, u, v)
    want = oracle_replay(oracle, svc._sched, kind, u, v)
    assert ok.tolist() == want.tolist()
    assert np.asarray(svc.state.ccid).tolist() == oracle.ccid()
    assert svc.edge_set() == oracle.edges


def collide(cfg, base_u, base_v, avoid=()):
    """A key hashing to the same slot as (base_u, base_v) (max_probes=1
    collision constructor)."""
    cap = cfg.edge_capacity
    target = int(et._hash(np.int32(base_u), np.int32(base_v), cap))
    for uu in range(cfg.n_vertices):
        for vv in range(cfg.n_vertices):
            if (uu, vv) in avoid or (uu, vv) == (base_u, base_v):
                continue
            if int(et._hash(np.int32(uu), np.int32(vv), cap)) == target:
                return uu, vv
    raise AssertionError("no colliding key in the id range")


# ------------------------------------------------------------ rehash ------


def test_rehash_preserves_live_set_and_drops_tombs():
    rng = np.random.default_rng(3)
    table = et.empty(256)
    u = rng.integers(0, 64, 120).astype(np.int32)
    v = rng.integers(0, 64, 120).astype(np.int32)
    table, _, _ = et.insert(table, u, v, 32)
    table, _ = et.remove(table, u[:40], v[:40], 32)
    live_before = {(int(s), int(d)) for s, d, st in
                   zip(np.asarray(table.src), np.asarray(table.dst),
                       np.asarray(table.state)) if st == int(et.LIVE)}
    bigger = et.rehash(table, 512, 32)
    assert bigger.src.shape[0] == 512
    live_after = {(int(s), int(d)) for s, d, st in
                  zip(np.asarray(bigger.src), np.asarray(bigger.dst),
                      np.asarray(bigger.state)) if st == int(et.LIVE)}
    assert live_after == live_before
    assert int(np.sum(np.asarray(bigger.state) == int(et.TOMB))) == 0
    found, _ = et.lookup(bigger, u, v, 32)
    live, _ = et.fill_stats(bigger)
    assert int(live) == len(live_before)
    # every surviving key is findable at the new capacity (lanes may repeat
    # keys, so compare per-lane membership, not counts)
    assert np.asarray(found).tolist() == [
        (int(a), int(b)) in live_before for a, b in zip(u, v)]


# -------------------------------------------------- grow-and-replay -------


def test_grow_and_replay_differential():
    """Randomized stream past table capacity: labels + edge set + per-op
    results must match the oracle after every chunk; zero lost edges."""
    svc = SCCService(tiny_cfg(), buckets=(8, 16))
    oracle = SeqSCC(NV)
    boot(svc, oracle)
    rng = np.random.default_rng(7)
    for step in range(18):
        n = int(rng.integers(1, 20))
        kind = rng.choice([dynamic.ADD_EDGE] * 3 + [dynamic.REM_EDGE], n)
        u = rng.integers(0, NV, n)
        v = rng.integers(0, NV, n)
        check_against_oracle(svc, oracle, kind, u, v)
    # the point of the test: the initial 32-slot table must have overflowed
    assert svc.grow_count > 0 and svc.replayed_ops > 0
    assert int(svc.state.overflow) > 0  # counter kept its audit trail
    assert svc.cfg.edge_capacity > 32
    # no lost edges: every oracle edge is in the table
    assert svc.edge_set() == oracle.edges


def test_grow_and_replay_min_probes_migration():
    """max_probes=1 stresses the migration path itself: keys that fit at
    one capacity may collide at the rehash target, so grow() must keep
    escalating until every live edge survives -- no silent drops."""
    svc = SCCService(tiny_cfg(edge_capacity=8, max_probes=1), buckets=(8,))
    oracle = SeqSCC(NV)
    boot(svc, oracle)
    rng = np.random.default_rng(13)
    for step in range(8):
        n = int(rng.integers(1, 9))
        kind = rng.choice([dynamic.ADD_EDGE] * 3 + [dynamic.REM_EDGE], n)
        u = rng.integers(0, NV, n)
        v = rng.integers(0, NV, n)
        check_against_oracle(svc, oracle, kind, u, v)
    assert svc.grow_count > 0
    assert svc.edge_set() == oracle.edges


def test_duplicate_insert_overflow():
    """Two lanes insert the same overflowing key: after grow-and-replay the
    first lane wins, the duplicate still reports False, one copy stored."""
    cfg = tiny_cfg(edge_capacity=32, max_probes=1)
    svc = SCCService(cfg, buckets=(8,))
    oracle = SeqSCC(NV)
    boot(svc, oracle)
    ok = svc._apply_chunk([dynamic.ADD_EDGE], [0], [1])
    assert ok.all() and oracle.add_edge(0, 1)
    cu, cv = collide(cfg, 0, 1)
    ok = svc._apply_chunk([dynamic.ADD_EDGE] * 2, [cu, cu], [cv, cv])
    assert oracle.add_edge(cu, cv) and not oracle.add_edge(cu, cv)
    assert ok.tolist() == [True, False]
    assert svc.grow_count >= 1
    assert svc.edge_set() == oracle.edges
    assert np.asarray(svc.state.ccid).tolist() == oracle.ccid()


def test_remove_then_readd_overflow():
    """Key removed (tombstoned), slot reused by a colliding key, then the
    original key re-added: probe bound overflows, grow-and-replay restores
    both keys exactly once."""
    cfg = tiny_cfg(edge_capacity=32, max_probes=1)
    svc = SCCService(cfg, buckets=(8,))
    oracle = SeqSCC(NV)
    boot(svc, oracle)
    assert svc._apply_chunk([dynamic.ADD_EDGE], [0], [1]).all()
    oracle.add_edge(0, 1)
    assert svc._apply_chunk([dynamic.REM_EDGE], [0], [1]).all()
    oracle.remove_edge(0, 1)
    cu, cv = collide(cfg, 0, 1)
    assert svc._apply_chunk([dynamic.ADD_EDGE], [cu], [cv]).all()  # reuses tomb
    oracle.add_edge(cu, cv)
    assert svc.grow_count == 0  # tombstone reuse: no growth yet
    ok = svc._apply_chunk([dynamic.ADD_EDGE], [0], [1])  # now the slot is taken
    oracle.add_edge(0, 1)
    assert ok.all()
    assert svc.grow_count >= 1 and svc.replayed_ops >= 1
    assert svc.edge_set() == oracle.edges
    assert np.asarray(svc.state.ccid).tolist() == oracle.ccid()


# ------------------------------------------------ scheduler equivalence ---


MIXES = {
    "add_heavy": dict(p_add=0.85, p_vertex=0.0),
    "remove_heavy": dict(p_add=0.3, p_vertex=0.0),
    "vertex_churn": dict(p_add=0.6, p_vertex=0.45),
}


@pytest.mark.parametrize("mix", sorted(MIXES))
def test_scheduler_equivalence(mix):
    """A stream chunked through bucketed padded batches == one sequential
    oracle replay: same per-op results, same final SCC partition."""
    p = MIXES[mix]
    svc = SCCService(tiny_cfg(edge_capacity=256, max_probes=16),
                     buckets=(8, 32))
    oracle = SeqSCC(NV)
    boot(svc, oracle)
    rng = np.random.default_rng(zlib.adler32(mix.encode()))
    for step in range(10):
        n = int(rng.integers(1, 40))
        is_add = rng.random(n) < p["p_add"]
        is_vertex = rng.random(n) < p["p_vertex"]
        kind = np.where(is_add,
                        np.where(is_vertex, dynamic.ADD_VERTEX,
                                 dynamic.ADD_EDGE),
                        np.where(is_vertex, dynamic.REM_VERTEX,
                                 dynamic.REM_EDGE))
        u = rng.integers(0, NV, n)
        v = rng.integers(0, NV, n)
        check_against_oracle(svc, oracle, kind, u, v)
    assert int(svc.state.n_ccs) == len(
        {c for c in oracle.ccid() if c < NV})


def test_bucket_plan_covers_and_bounds_shapes():
    sched = BucketedScheduler((8, 32, 128))
    for n in (1, 7, 8, 9, 40, 128, 129, 300, 1000):
        plan = sched.plan(n)
        # contiguous cover of [0, n)
        assert plan[0][0].start == 0 and plan[-1][0].stop == n
        for (a, _), (b, _) in zip(plan, plan[1:]):
            assert a.stop == b.start
        # only registered shapes; padding only in the final bucket
        for sl, b in plan[:-1]:
            assert b in sched.buckets and sl.stop - sl.start == b
        sl, b = plan[-1]
        assert b in sched.buckets and sl.stop - sl.start <= b


def test_compile_count_bounded_by_buckets():
    """Arbitrary chunk lengths never add step shapes beyond the bucket
    registry (per graph config) -- the no-per-chunk-recompile guarantee."""
    svc = SCCService(tiny_cfg(edge_capacity=256, max_probes=16),
                     buckets=(8, 16))
    oracle = SeqSCC(NV)
    boot(svc, oracle)
    rng = np.random.default_rng(11)
    for n in (1, 3, 8, 11, 16, 23, 31, 5, 17, 29):
        kind = rng.choice([dynamic.ADD_EDGE] * 2 + [dynamic.REM_EDGE],
                          int(n))
        u = rng.integers(0, NV, int(n))
        v = rng.integers(0, NV, int(n))
        check_against_oracle(svc, oracle, kind, u, v)
    assert svc.grow_count == 0  # capacity was generous
    assert svc.compile_count <= 2  # == len(buckets)


# --------------------------------------------------------- snapshots ------


def test_snapshot_queries_generation_stamped():
    svc = SCCService(tiny_cfg(edge_capacity=256, max_probes=16),
                     buckets=(8,))
    oracle = SeqSCC(NV)
    boot(svc, oracle)
    edges = [(0, 1), (1, 2), (2, 0), (3, 4), (4, 3), (2, 3)]
    ok = svc._apply_chunk([dynamic.ADD_EDGE] * len(edges),
                   [e[0] for e in edges], [e[1] for e in edges])
    assert ok.all()

    same = svc.same_scc([0, 0, 3, 0], [2, 3, 4, 23])
    assert same.value.tolist() == [True, False, True, False]

    reach = svc.reachable([0, 4, 0, 5], [4, 0, 0, 0])
    assert reach.value.tolist() == [True, False, True, False]

    members = svc.scc_members(1)
    want = np.zeros(NV, bool)
    want[[0, 1, 2]] = True
    assert members.value.tolist() == want.tolist()

    # all three saw the same committed snapshot
    assert same.gen == reach.gen == members.gen == svc.gen
    g0 = svc.gen
    svc._apply_chunk([dynamic.ADD_EDGE], [4], [0])  # merges everything
    same2 = svc.same_scc([0], [4])
    assert same2.value.tolist() == [True]
    assert same2.gen > g0  # new generation observed after commit

    # dead-vertex contracts
    svc._apply_chunk([dynamic.REM_VERTEX], [4], [0])
    assert not svc.same_scc([4], [4]).value.item()
    assert not svc.reachable([4], [4]).value.item()
    assert not svc.scc_members(4).value.any()

    # out-of-range ids answer False/empty, never alias a clipped vertex
    assert svc.same_scc([NV + 76, -1], [0, 0]).value.tolist() == [False] * 2
    assert svc.reachable([NV + 76, -1], [0, 0]).value.tolist() == [False] * 2
    assert not svc.scc_members(NV + 76).value.any()
    assert not svc.scc_members(-1).value.any()


def test_apply_rolls_back_on_unrecoverable_overflow():
    """If growth is capped and a chunk cannot replay, apply() must leave
    the service exactly at the last committed snapshot (all-or-nothing)."""
    svc = SCCService(tiny_cfg(edge_capacity=8, max_probes=1), buckets=(8,),
                     max_edge_capacity=8)
    oracle = SeqSCC(NV)
    boot(svc, oracle)
    edges_before = None
    with pytest.raises(RuntimeError):
        rng = np.random.default_rng(2)
        for _ in range(40):  # max_probes=1 at capacity 8 overflows fast
            u = rng.integers(0, NV, 8)
            v = rng.integers(0, NV, 8)
            edges_before = svc.edge_set()
            gen_before = svc.gen
            svc._apply_chunk(np.full(8, dynamic.ADD_EDGE), u, v)
        raise AssertionError("stream never overflowed the capped table")
    # the failing chunk left no trace: same snapshot, same cfg
    assert svc.edge_set() == edges_before
    assert svc.gen == gen_before
    assert svc.cfg.edge_capacity == 8
    # and the service still works for ops that fit
    if edges_before:
        eu, ev = next(iter(edges_before))
        ok = svc._apply_chunk([dynamic.REM_EDGE], [eu], [ev])
        assert ok.all()


def test_compaction_triggers_on_tombstones():
    svc = SCCService(tiny_cfg(edge_capacity=32, max_probes=16),
                     buckets=(16,), compact_tomb_frac=0.2)
    oracle = SeqSCC(NV)
    boot(svc, oracle)
    rng = np.random.default_rng(5)
    pairs = [(int(a), int(b)) for a, b in
             zip(rng.integers(0, NV, 12), rng.integers(0, NV, 12))]
    pairs = sorted(set(pairs))
    svc._apply_chunk([dynamic.ADD_EDGE] * len(pairs),
              [p[0] for p in pairs], [p[1] for p in pairs])
    svc._apply_chunk([dynamic.REM_EDGE] * len(pairs),
              [p[0] for p in pairs], [p[1] for p in pairs])
    for p in pairs:
        oracle.add_edge(*p)
        oracle.remove_edge(*p)
    assert svc.compaction_count >= 1
    _, tomb = et.fill_stats(svc.state.edges)
    assert int(tomb) == 0
    assert svc.edge_set() == oracle.edges == set()
