"""Per-assigned-architecture smoke tests: a REDUCED config of each arch
family runs one forward/train step on CPU, asserting output shapes and
finiteness (mandate deliverable f)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # heavyweight model/launch suite: full run only

from repro import configs
from repro.data import pipeline

KEY = jax.random.PRNGKey(0)

LM_ARCHS = ["moonshot-v1-16b-a3b", "qwen3-moe-235b-a22b",
            "h2o-danube-3-4b", "qwen3-14b", "gemma3-12b"]
GNN_ARCHS = ["mace", "egnn", "nequip", "gatedgcn"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_arch_smoke(arch):
    from repro.models import transformer as tf
    mod = configs.get(arch)
    cfg = mod.smoke_config()
    params = tf.init(KEY, cfg)
    batch = pipeline.lm_batch(cfg.vocab, 2, 16, step=0)
    loss, metrics = tf.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss)) and float(loss) > 0
    # serve path: prefill + one decode step
    cache, logits = tf.prefill(params, batch["tokens"][:, :8], cfg,
                               cache_len=20)
    assert logits.shape == (2, cfg.vocab)
    logits2, cache = tf.decode_step(
        params, cache, batch["tokens"][:, 8], cfg)
    assert logits2.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_arch_full_config_consistency(arch):
    """Full configs carry the exact assigned dims (no allocation)."""
    mod = configs.get(arch)
    cfg = mod.config()
    want = {
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 163840),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 151936),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 32000),
        "qwen3-14b": (40, 5120, 40, 8, 151936),
        "gemma3-12b": (48, 3840, 16, 8, 262144),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.vocab)
    assert got == want
    if arch == "moonshot-v1-16b-a3b":
        assert cfg.moe.n_experts == 64 and cfg.moe.top_k == 6
        # the assigned dims (48L x 64e x 1408) give ~28B total / ~4B
        # active; the hf "16b-a3b" label is nominal -- assigned dims win
        assert 25e9 < cfg.n_params() < 32e9
        assert 3e9 < cfg.n_active_params() < 6e9
    if arch == "qwen3-moe-235b-a22b":
        assert cfg.moe.n_experts == 128 and cfg.moe.top_k == 8
        assert 200e9 < cfg.n_params() < 260e9
        assert 15e9 < cfg.n_active_params() < 30e9
    if arch == "qwen3-14b":
        assert 12e9 < cfg.n_params() < 17e9
    if arch == "gemma3-12b":
        # 5 local : 1 global interleave
        w = np.asarray(cfg.windows)
        assert (w == 0).sum() == 8 and (w == 1024).sum() == 40


@pytest.mark.parametrize("arch", GNN_ARCHS)
@pytest.mark.parametrize("task", ["energy", "node_class"])
def test_gnn_arch_smoke(arch, task):
    mod = configs.get(arch)
    cfg = mod.smoke_config(task=task, n_classes=5)
    model = mod.MODULE
    params = model.init(KEY, cfg)
    if task == "energy":
        batch = pipeline.molecule_batch(cfg.n_graphs, 6, 12, cfg.d_feat,
                                        step=0)
    else:
        batch = pipeline.node_class_graph(24, 80, cfg.d_feat, 5)
        batch["labels"] = batch["labels"] % 5
        cfg = dataclasses.replace(cfg, n_graphs=1)
    loss, metrics = model.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss)), (arch, task, metrics)


def test_mind_arch_smoke():
    mod = configs.get("mind")
    cfg = mod.smoke_config()
    model = mod.MODULE
    params = model.init(KEY, cfg)
    batch = pipeline.mind_batch(cfg.n_items, 8, cfg.seq_len,
                                cfg.profile_vocab, cfg.profile_len,
                                cfg.n_neg, step=0)
    loss, metrics = model.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))
    full = mod.config()
    assert full.n_items == 2 ** 21 and full.embed_dim == 64
    assert full.n_interests == 4 and full.capsule_iters == 3


def test_smscc_arch_smoke():
    from repro.core import dynamic, graph_state as gs
    mod = configs.get("smscc")
    cfg = mod.smoke_config()
    state = gs.empty(cfg)
    ops = pipeline.op_stream(cfg.n_vertices, 16, step=0, add_frac=0.8)
    state, ok = dynamic.apply_batch(state, ops, cfg)
    assert state.ccid.shape == (cfg.n_vertices,)
    assert int(state.overflow) == 0


def test_registry_covers_all_assigned():
    assert len(configs.all_archs(include_paper=False)) == 10
    for arch in configs.all_archs():
        mod = configs.get(arch)
        assert hasattr(mod, "SHAPES") and hasattr(mod, "FAMILY")
        assert len(mod.SHAPES) >= 3


def test_shape_cell_count():
    """40 assigned cells: 5 LM x 4 + 4 GNN x 4 + 1 recsys x 4."""
    n = 0
    for arch in configs.all_archs(include_paper=False):
        n += len(configs.get(arch).SHAPES)
    assert n == 40
