"""Kernel-vs-oracle differential fuzz for the sparse Pallas kernels.

frontier_expand and hash_probe back the always-on sweeps (every FW/BW
fixpoint round, every table probe), so their contract is *bit-identity*
with the ``'xla'`` oracle -- not approximate agreement.  The harness
fuzzes the kernels in interpret mode on CPU over randomized region
shapes and edge distributions (hypothesis when available, the seeded
shim otherwise) and pins the documented edge cases explicitly: empty
frontiers, duplicate edges, self-loops, all-lanes-active, and
capacity-edge shapes for frontier_expand; tombstone chains, probe
exhaustion (the ``failed`` flag), and re-adds for hash_probe.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, strategies as st


@pytest.fixture(scope="module", autouse=True)
def _fresh_compile_caches():
    # This fuzz module mints hundreds of small one-off executables on top
    # of a full-suite session that already compiled hundreds more; on the
    # CPU backend that much accumulated JIT code reproducibly segfaults
    # LLVM inside a later (tiny, otherwise-innocent) backend_compile.
    # Dropping the session's compiled-executable references first keeps
    # the fuzz sweep within the JIT's budget.  (jax.clear_caches is public
    # API; correctness is unaffected -- everything recompiles on demand.)
    jax.clear_caches()
    yield
    jax.clear_caches()

from repro.core import edge_table as et
from repro.core import reach, scc
from repro.kernels.frontier_expand import ops as fops
from repro.kernels.frontier_expand import ref as fref
from repro.kernels.hash_probe import ops as hops
from repro.kernels.hash_probe import ref as href

KERNEL = "pallas_interpret"  # the CPU-executable Pallas path
SENT = int(fref.SENTINEL)


def _eq(got, want, ctx=""):
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                  err_msg=ctx)


# ------------------------------------------------------ frontier_expand ---

@st.composite
def frontier_case(draw):
    """(nv, dst, msg) with adversarial distributions: hot destinations
    (duplicate edges), sentinel-heavy lanes (inactive frontier), ties."""
    nv = draw(st.sampled_from([1, 2, 7, 24, 64, 128, 129, 200]))
    e = draw(st.sampled_from([0, 1, 5, 64, 255, 256, 257, 500]))
    hot = draw(st.booleans())  # all edges land on few vertices
    f = draw(st.sampled_from([1, 1, 2, 3, 9]))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    pool = min(3, nv) if hot else nv
    dst = rng.integers(0, pool, e).astype(np.int32)
    kind = draw(st.sampled_from(["dense", "sparse", "empty", "full"]))
    if kind == "empty":  # empty frontier: every message is the identity
        msg = np.full((f, e), SENT, np.uint32)
    elif kind == "full":  # all lanes active, heavy ties
        msg = rng.integers(0, 3, (f, e)).astype(np.uint32)
    elif kind == "dense":
        msg = rng.integers(0, 2**32, (f, e), dtype=np.uint64
                           ).astype(np.uint32)
    else:  # mostly-inactive lanes
        msg = np.where(rng.random((f, e)) < 0.15,
                       rng.integers(0, 2**31, (f, e), dtype=np.uint64),
                       SENT).astype(np.uint32)
    return nv, dst, msg


@given(frontier_case())
@settings(max_examples=40, deadline=None)
def test_frontier_min_matches_oracle(case):
    nv, dst, msg = case
    d = jnp.asarray(dst)
    m = jnp.asarray(msg)
    want = fref.frontier_min(d, m, nv)
    got = fops.frontier_min(d, m, nv, impl=KERNEL)
    _eq(got, want, f"nv={nv} e={dst.shape[0]} f={msg.shape[0]}")
    # the 1-D (single-frontier) entry squeezes through the same kernel
    got1 = fops.frontier_min(d, m[0], nv, impl=KERNEL)
    _eq(got1, want[0], "1-D squeeze path")


def test_frontier_min_capacity_edges():
    """Shapes ON the block boundaries (nv/e exact tile multiples, +-1)."""
    rng = np.random.default_rng(0)
    for nv in (127, 128, 129, 256):
        for e in (255, 256, 257):
            dst = jnp.asarray(rng.integers(0, nv, e), jnp.int32)
            msg = jnp.asarray(
                rng.integers(0, 2**32, e, dtype=np.uint64).astype(
                    np.uint32))
            _eq(fops.frontier_min(dst, msg, nv, impl=KERNEL),
                fref.frontier_min(dst, msg[None, :], nv)[0],
                f"nv={nv} e={e}")


def test_frontier_min_no_edges():
    out = fops.frontier_min(jnp.zeros((0,), jnp.int32),
                            jnp.zeros((0,), jnp.uint32), 17, impl=KERNEL)
    assert out.shape == (17,) and (np.asarray(out) == SENT).all()


@st.composite
def graph_case(draw):
    """Random COO graph with self-loops and duplicate edges (the edge
    table never dedupes its COO view of dead slots)."""
    nv = draw(st.sampled_from([4, 9, 24, 40]))
    e = draw(st.sampled_from([8, 40, 120]))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    src = rng.integers(0, nv, e).astype(np.int32)
    dst = rng.integers(0, nv, e).astype(np.int32)
    loops = rng.random(e) < 0.1
    dst = np.where(loops, src, dst)  # self-loops
    if e > 4:  # duplicate edges
        src[: e // 4] = src[e // 4: 2 * (e // 4)]
        dst[: e // 4] = dst[e // 4: 2 * (e // 4)]
    live = rng.random(e) < 0.8
    allowed = rng.random(nv) < draw(st.sampled_from([0.5, 1.0]))
    seeds = rng.random(nv) < 0.2
    return (nv, jnp.asarray(src), jnp.asarray(dst), jnp.asarray(live),
            jnp.asarray(allowed), jnp.asarray(seeds))


@given(graph_case())
@settings(max_examples=15, deadline=None)
def test_reach_sweeps_bit_identical(case):
    """Every reach.py fixpoint: kernel impl == 'xla' oracle, bit-for-bit
    (labels AND round counts -- the fixpoint must converge identically)."""
    nv, src, dst, live, allowed, seeds = case
    for impl in (KERNEL,):
        r_x, n_x = reach.forward_reach(src, dst, live, seeds, allowed, 16)
        r_k, n_k = reach.forward_reach(src, dst, live, seeds, allowed, 16,
                                       impl=impl)
        _eq(r_k, r_x, "forward_reach")
        assert int(n_k) == int(n_x)
        f_x, b_x, _ = reach.fused_fw_bw_reach(src, dst, live, seeds,
                                              seeds, allowed, 16)
        f_k, b_k, _ = reach.fused_fw_bw_reach(src, dst, live, seeds,
                                              seeds, allowed, 16,
                                              impl=impl)
        _eq(f_k, f_x, "fused fw")
        _eq(b_k, b_x, "fused bw")
        init = jnp.where(allowed, jnp.arange(nv, dtype=jnp.int32),
                         jnp.iinfo(jnp.int32).max)
        l_x, _ = reach.propagate_min_labels(src, dst, live, init, allowed,
                                            16)
        l_k, _ = reach.propagate_min_labels(src, dst, live, init, allowed,
                                            16, impl=impl)
        _eq(l_k, l_x, "propagate_min_labels")
        w_x, _ = reach.propagate_min_prio(src, dst, live, allowed, 16)
        w_k, _ = reach.propagate_min_prio(src, dst, live, allowed, 16,
                                          impl=impl)
        _eq(w_k, w_x, "propagate_min_prio")
        multi = jnp.stack([seeds, allowed & ~seeds, jnp.zeros_like(seeds)])
        m_x, _ = reach.multi_forward_reach(src, dst, live, multi, allowed,
                                           16)
        m_k, _ = reach.multi_forward_reach(src, dst, live, multi, allowed,
                                           16, impl=impl)
        _eq(m_k, m_x, "multi_forward_reach")


@given(graph_case(), st.booleans())
@settings(max_examples=8, deadline=None)
def test_scc_static_bit_identical(case, shortcut):
    nv, src, dst, live, allowed, _ = case
    want = scc.scc_static(src, dst, live, allowed, max_outer=8,
                          max_inner=16, shortcut=shortcut)
    got = scc.scc_static(src, dst, live, allowed, max_outer=8,
                         max_inner=16, shortcut=shortcut, impl=KERNEL)
    _eq(got, want, f"scc_static shortcut={shortcut}")


# ----------------------------------------------------------- hash_probe ---

@st.composite
def table_case(draw):
    """A table built through real et ops (inserts + removes => organic
    tombstone chains) plus a query batch of present/absent/removed keys."""
    cap = draw(st.sampled_from([8, 32, 64, 512]))
    seed = draw(st.integers(0, 2**31 - 1))
    load = draw(st.sampled_from([0.3, 0.7, 1.0]))  # 1.0 = saturated
    rng = np.random.default_rng(seed)
    n_ins = int(cap * load)
    u = rng.integers(0, 50, n_ins).astype(np.int32)
    v = rng.integers(0, 50, n_ins).astype(np.int32)
    table = et.empty(cap)
    table, _, _ = et.insert(table, jnp.asarray(u), jnp.asarray(v), cap)
    # tombstone ~a third of what went in
    n_rem = max(1, n_ins // 3)
    table, _ = et.remove(table, jnp.asarray(u[:n_rem]),
                         jnp.asarray(v[:n_rem]), cap)
    b = draw(st.sampled_from([1, 7, 33]))
    qu = rng.integers(0, 60, b).astype(np.int32)  # mix of hits/misses
    qv = rng.integers(0, 60, b).astype(np.int32)
    mp = draw(st.sampled_from(["one", "half", "cap", "over"]))
    max_probes = {"one": 1, "half": max(1, cap // 2), "cap": cap,
                  "over": 2 * cap}[mp]
    return table, jnp.asarray(qu), jnp.asarray(qv), max_probes


@given(table_case())
@settings(max_examples=30, deadline=None)
def test_hash_probe_matches_edge_table_lookup(case):
    table, qu, qv, max_probes = case
    want = et.lookup(table, qu, qv, max_probes)  # the fori-loop oracle
    got = et.lookup(table, qu, qv, max_probes, impl=KERNEL)
    _eq(got[0], want[0], f"found (cap={table.src.shape[0]}, "
                         f"max_probes={max_probes})")
    _eq(got[1], want[1], f"slot (cap={table.src.shape[0]}, "
                         f"max_probes={max_probes})")
    # and the standalone ref mirrors edge_table.lookup exactly
    base = et._hash(qu, qv, table.src.shape[0])
    rf, rs = href.probe(table.src, table.dst, table.state, base, qu, qv,
                        max_probes=max_probes)
    _eq(rf, want[0], "ref.probe found")
    _eq(rs, want[1], "ref.probe slot")


@given(table_case())
@settings(max_examples=12, deadline=None)
def test_hash_probe_insert_remove_bit_identical(case):
    """insert/remove route their membership probe through the kernel; the
    resulting tables, inserted masks, and failed flags must be identical."""
    table, qu, qv, max_probes = case
    t_x, ins_x, fail_x = et.insert(table, qu, qv, max_probes)
    t_k, ins_k, fail_k = et.insert(table, qu, qv, max_probes, impl=KERNEL)
    for a, b in zip(t_x, t_k):
        _eq(b, a, "insert table columns")
    _eq(ins_k, ins_x, "inserted mask")
    _eq(fail_k, fail_x, "failed mask")
    r_x, rem_x = et.remove(table, qu, qv, max_probes)
    r_k, rem_k = et.remove(table, qu, qv, max_probes, impl=KERNEL)
    for a, b in zip(r_x, r_k):
        _eq(b, a, "remove table columns")
    _eq(rem_k, rem_x, "removed mask")


def test_hash_probe_tombstone_chain():
    """A probe chain THROUGH a tombstone still finds the key behind it,
    and a lookup of the tombstoned key reports the tombstone slot as its
    insertion point -- under both impls."""
    cap = 16
    table = et.empty(cap)
    keys = jnp.asarray([[1, 2], [3, 4], [5, 6], [7, 8]], jnp.int32)
    table, _, _ = et.insert(table, keys[:, 0], keys[:, 1], cap)
    table, removed = et.remove(table, keys[:1, 0], keys[:1, 1], cap,
                               impl=KERNEL)
    assert bool(removed[0])
    assert int(jnp.sum(table.state == et.TOMB)) == 1
    for u, vv in ((3, 4), (5, 6), (7, 8)):  # survivors still found
        for impl in ("xla", KERNEL):
            f, _ = et.lookup(table, jnp.asarray([u]), jnp.asarray([vv]),
                             cap, impl=impl)
            assert bool(f[0]), (u, vv, impl)
    fx, sx = et.lookup(table, keys[:1, 0], keys[:1, 1], cap)
    fk, sk = et.lookup(table, keys[:1, 0], keys[:1, 1], cap, impl=KERNEL)
    assert not bool(fx[0]) and not bool(fk[0])
    assert int(sx[0]) == int(sk[0])  # same insertion point


def test_hash_probe_exhaustion_sets_failed():
    """Saturate a tiny table: overflowing lanes must raise ``failed``
    identically under both impls (the grow-and-replay trigger)."""
    cap = 8
    table = et.empty(cap)
    u = jnp.arange(2 * cap, dtype=jnp.int32)
    v = jnp.full((2 * cap,), 9, jnp.int32)
    t_x, ins_x, fail_x = et.insert(table, u, v, cap)
    t_k, ins_k, fail_k = et.insert(table, u, v, cap, impl=KERNEL)
    assert int(jnp.sum(fail_x)) == cap  # exactly the overflow
    _eq(fail_k, fail_x)
    _eq(ins_k, ins_x)
    for a, b in zip(t_x, t_k):
        _eq(b, a)
    # every lane that wanted a slot either placed or failed
    assert int(jnp.sum(ins_x) + jnp.sum(fail_x)) == 2 * cap


def test_hash_probe_readd_takes_no_slot():
    cap = 32
    table = et.empty(cap)
    u = jnp.asarray([3, 4, 5], jnp.int32)
    v = jnp.asarray([6, 7, 8], jnp.int32)
    table, ins, _ = et.insert(table, u, v, cap, impl=KERNEL)
    assert bool(ins.all())
    live_before = int(jnp.sum(table.state == et.LIVE))
    t2, ins2, fail2 = et.insert(table, u, v, cap, impl=KERNEL)
    assert not bool(ins2.any()) and not bool(fail2.any())
    assert int(jnp.sum(t2.state == et.LIVE)) == live_before
    for a, b in zip(table, t2):
        _eq(b, a, "re-add must not mutate the table")


def test_hash_probe_rehash_bit_identical():
    cap = 32
    rng = np.random.default_rng(5)
    table = et.empty(cap)
    table, _, _ = et.insert(
        table, jnp.asarray(rng.integers(0, 20, 24), jnp.int32),
        jnp.asarray(rng.integers(0, 20, 24), jnp.int32), cap)
    table, _ = et.remove(
        table, jnp.asarray(rng.integers(0, 20, 8), jnp.int32),
        jnp.asarray(rng.integers(0, 20, 8), jnp.int32), cap)
    for new_cap in (cap, 4 * cap):
        want = et.rehash(table, new_cap, new_cap)
        got = et.rehash(table, new_cap, new_cap, impl=KERNEL)
        for a, b in zip(want, got):
            _eq(b, a, f"rehash to {new_cap}")


def test_graph_config_validates_sparse_impl():
    from repro.core import graph_state as gs
    with pytest.raises(AssertionError):
        gs.GraphConfig(n_vertices=8, edge_capacity=16, sparse_impl="cuda")
    cfg = gs.GraphConfig(n_vertices=8, edge_capacity=16,
                         sparse_impl="pallas_interpret")
    assert cfg.sparse_impl == "pallas_interpret"
