"""Per-kernel correctness: Pallas vs pure-jnp oracle, swept over shapes
and dtypes per the mandate.

Every test parametrizes over ``IMPLS``: interpret mode always runs (that
is how the Pallas dataflow is exercised in tier-1 on CPU -- nothing
silently falls back to the oracle), and the native ``'pallas'`` impl
joins the sweep automatically on a real TPU backend.  Only the large
shapes carry the ``slow`` marker (pytest.ini excludes ``-m "not slow"``
from tier-1); every kernel keeps at least one fast interpret case.

The two sparse kernels (frontier_expand / hash_probe) have their own
differential fuzz harness in tests/test_sparse_kernels.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import scc
from repro.kernels import embedding_bag as eb
from repro.kernels import flash_attention as fa
from repro.kernels import reach_blockmm as rb

IMPLS = ["pallas_interpret"] + (
    ["pallas"] if jax.default_backend() == "tpu" else [])

slow = pytest.mark.slow


# ---------------------------------------------------------------- reach ---
@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("m,k,n", [
    (8, 8, 8),
    pytest.param(128, 128, 128, marks=slow),
    pytest.param(64, 256, 128, marks=slow),
    (200, 130, 70),
])
def test_bool_matmul_shapes(m, k, n, impl):
    rng = np.random.default_rng(m + k + n)
    a = jnp.asarray(rng.random((m, k)) < 0.1)
    b = jnp.asarray(rng.random((k, n)) < 0.1)
    got = rb.bool_matmul(a, b, block=128, impl=impl)
    want = rb.ref.bool_matmul(a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("block", [8, 32, pytest.param(128, marks=slow)])
def test_bool_matmul_blocks(block, impl):
    rng = np.random.default_rng(block)
    a = jnp.asarray(rng.random((96, 96)) < 0.05)
    b = jnp.asarray(rng.random((96, 96)) < 0.05)
    got = rb.bool_matmul(a, b, block=block, impl=impl)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(rb.ref.bool_matmul(a, b)))


@pytest.mark.parametrize("impl", IMPLS)
def test_frontier_step_and_closure(impl):
    rng = np.random.default_rng(0)
    n = 40
    adj = jnp.asarray(rng.random((n, n)) < 0.08)
    f = jnp.zeros((n, 4), bool).at[jnp.asarray([3, 11, 17, 29]),
                                   jnp.arange(4)].set(True)
    got = rb.frontier_step(adj, f, block=32, impl=impl)
    want = rb.ref.frontier_step(adj, f)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    clo_k = rb.closure(adj, block=32, impl=impl)
    clo_r = rb.ref.closure(adj)
    np.testing.assert_array_equal(np.asarray(clo_k), np.asarray(clo_r))


@pytest.mark.parametrize("impl", IMPLS)
def test_closure_feeds_dense_scc(impl):
    """kernel closure plugged into scc_dense_region == its jnp fallback."""
    rng = np.random.default_rng(1)
    nv, e = 24, 70
    src = jnp.asarray(rng.integers(0, nv, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, nv, e), jnp.int32)
    live = jnp.ones((e,), bool)
    region = jnp.ones((nv,), bool)

    def pallas_mm(a, b):
        return rb.bool_matmul(a, b, block=32, impl=impl)

    lab_k, _ = scc.scc_dense_region(src, dst, live, region, nv,
                                    matmul=pallas_mm)
    lab_j, _ = scc.scc_dense_region(src, dst, live, region, nv)
    np.testing.assert_array_equal(np.asarray(lab_k), np.asarray(lab_j))


# ----------------------------------------------------------- attention ---
@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("s,d,causal,window", [
    (64, 32, True, 0), (64, 32, False, 0),
    pytest.param(96, 16, True, 24, marks=slow),
    pytest.param(130, 32, True, 0, marks=slow),
    (70, 64, True, 16),
])
def test_flash_vs_ref(s, d, causal, window, impl):
    rng = np.random.default_rng(s + d)
    q = jnp.asarray(rng.normal(size=(1, 2, s, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2, s, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 2, s, d)).astype(np.float32))
    got = fa.mha(q, k, v, causal=causal, window=window, bq=32, bk=32,
                 impl=impl)
    want = fa.ref.mha(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("impl", IMPLS)
def test_flash_gqa_grouping(impl):
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(2, 4, 64, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 2, 64, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 2, 64, 16)).astype(np.float32))
    got = fa.mha(q, k, v, causal=True, bq=32, bk=32, impl=impl)
    want = fa.ref.mha(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("impl", IMPLS)
def test_flash_bf16(impl):
    rng = np.random.default_rng(9)
    mk = lambda: jnp.asarray(
        rng.normal(size=(1, 1, 64, 32)).astype(np.float32)).astype(
            jnp.bfloat16)
    q, k, v = mk(), mk(), mk()
    got = fa.mha(q, k, v, causal=True, bq=32, bk=32, impl=impl)
    want = fa.ref.mha(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("impl", IMPLS)
def test_flash_fully_masked_rows_finite(impl):
    """window smaller than block -> early rows see few keys; no NaNs."""
    q = jnp.ones((1, 1, 64, 16), jnp.float32)
    k = jnp.ones((1, 1, 64, 16), jnp.float32)
    v = jnp.ones((1, 1, 64, 16), jnp.float32)
    out = fa.mha(q, k, v, causal=True, window=4, bq=32, bk=32, impl=impl)
    assert np.isfinite(np.asarray(out)).all()


# -------------------------------------------------------- embedding bag ---
@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("b,l,v,d", [
    (4, 6, 50, 16),
    pytest.param(16, 32, 300, 64, marks=slow),
    (3, 5, 129, 8),
])
def test_embedding_bag_vs_ref(b, l, v, d, impl):
    rng = np.random.default_rng(b * l)
    table = jnp.asarray(rng.normal(size=(v, d)).astype(np.float32))
    ids = jnp.asarray(rng.integers(-1, v, (b, l)), jnp.int32)
    got = eb.embedding_bag(table, ids, bb=4, bv=64, impl=impl)
    want = eb.ref.embedding_bag(table, ids)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("impl", IMPLS)
def test_embedding_bag_weighted_and_mean(impl):
    rng = np.random.default_rng(3)
    table = jnp.asarray(rng.normal(size=(40, 8)).astype(np.float32))
    ids = jnp.asarray(rng.integers(-1, 40, (5, 7)), jnp.int32)
    w = jnp.asarray(rng.random((5, 7)).astype(np.float32))
    got = eb.embedding_bag(table, ids, weights=w, bb=4, bv=32, impl=impl)
    want = eb.ref.embedding_bag(table, ids, weights=w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    got_m = eb.embedding_bag(table, ids, mode="mean", bb=4, bv=32,
                             impl=impl)
    want_m = eb.ref.embedding_bag(table, ids, mode="mean")
    np.testing.assert_allclose(np.asarray(got_m), np.asarray(want_m),
                               rtol=1e-5, atol=1e-5)
