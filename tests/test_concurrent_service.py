"""Concurrent-reader pipeline: pipelined updater path + QueryBroker.

The contracts pinned here (see ``docs/SERVICE_API.md``):

* the pipelined in-flight fast path and the serial grow-and-replay path
  compute bit-identical results (callers cannot observe which ran);
* donation never invalidates the committed snapshot readers hold;
* every stamped query answer equals the sequential oracle's answer *at
  the stamped generation* -- and stamped generations are always committed
  generations (a reader can never observe an in-flight state);
* generations observed by any single reader are monotone.
"""
import collections
import threading
import time
import warnings

import numpy as np
import pytest

from repro.core import dynamic, graph_state as gs
from repro.core.broker import QueryBroker
from repro.core.service import SCCService
from oracle import SeqSCC

NV = 24
PHASE = {dynamic.REM_VERTEX: 0, dynamic.REM_EDGE: 1,
         dynamic.ADD_VERTEX: 2, dynamic.ADD_EDGE: 3}


def tiny_cfg(edge_capacity=32, max_probes=4, nv=NV):
    return gs.GraphConfig(n_vertices=nv, edge_capacity=edge_capacity,
                          max_probes=max_probes, max_outer=nv + 1,
                          max_inner=nv + 2)


def boot(svc: SCCService, oracle: SeqSCC | None = None, n=NV):
    ok = svc._apply_chunk([dynamic.ADD_VERTEX] * n, list(range(n)), [0] * n)
    assert ok.all()
    if oracle is not None:
        for i in range(n):
            assert oracle.add_vertex(i)


def mixed_stream(rng, n, p_add=0.7, p_vertex=0.15):
    is_add = rng.random(n) < p_add
    is_vertex = rng.random(n) < p_vertex
    kind = np.where(is_add,
                    np.where(is_vertex, dynamic.ADD_VERTEX,
                             dynamic.ADD_EDGE),
                    np.where(is_vertex, dynamic.REM_VERTEX,
                             dynamic.REM_EDGE))
    return kind, rng.integers(0, NV, n), rng.integers(0, NV, n)


# ------------------------------------------------ pipelined updater -------


@pytest.mark.parametrize("window", [1, 2, 8])
def test_pipelined_matches_serial_path(window):
    """Same overflowing stream through the in-flight pipeline and through
    the serial path: identical per-op results, labels, edge set, and
    generation -- including chunks that abort the fast path and fall back
    to grow-and-replay."""
    fast = SCCService(tiny_cfg(), buckets=(8, 16), inflight_window=window)
    serial = SCCService(tiny_cfg(), buckets=(8, 16), inflight_window=0)
    boot(fast)
    boot(serial)
    rng = np.random.default_rng(21)
    for step in range(14):
        kind, u, v = mixed_stream(rng, int(rng.integers(1, 24)),
                                  p_vertex=0.1)
        ok_fast = fast._apply_chunk(kind, u, v)
        ok_serial = serial._apply_chunk(kind, u, v)
        assert ok_fast.tolist() == ok_serial.tolist()
        assert np.asarray(fast.state.ccid).tolist() == \
            np.asarray(serial.state.ccid).tolist()
        assert fast.edge_set() == serial.edge_set()
        assert fast.gen == serial.gen
    # the tiny table must have overflowed, so the fast path aborted at
    # least once and both grow-and-replay histories agree
    assert fast.fallback_chunks > 0 and fast.pipelined_chunks > 0
    assert fast.grow_count == serial.grow_count > 0
    assert serial.pipelined_chunks == 0


def test_donated_pipeline_preserves_committed_snapshot():
    """Donation steps off a private copy: a snapshot (and Snapshot query
    values) taken before apply() must survive the next chunk unchanged."""
    with warnings.catch_warnings():
        # XLA:CPU does not implement donation and warns; the double-buffer
        # copy protocol is identical either way, which is what we pin here
        warnings.simplefilter("ignore")
        svc = SCCService(tiny_cfg(edge_capacity=128, max_probes=16),
                         buckets=(8, 16), donate=True)
        boot(svc)
        svc._apply_chunk([dynamic.ADD_EDGE] * 3, [0, 1, 2], [1, 2, 0])
        held = svc.state  # a reader's pinned snapshot
        held_ccid = np.array(held.ccid)
        held_gen = int(held.gen)
        snap = svc.same_scc([0, 1], [2, 5])
        rng = np.random.default_rng(3)
        for _ in range(5):
            kind, u, v = mixed_stream(rng, 16)
            svc._apply_chunk(kind, u, v)
        # the old snapshot's buffers are still alive and unchanged
        assert np.array(held.ccid).tolist() == held_ccid.tolist()
        assert int(held.gen) == held_gen
        assert snap.value.tolist() == [True, False]
        assert svc.gen > held_gen


def test_serial_and_pipelined_compile_entries_are_tracked():
    """compile_count distinguishes the two step paths: no overflow means
    only pipelined entries (<= len(buckets)); the serial entries appear
    only once a chunk falls back."""
    svc = SCCService(tiny_cfg(edge_capacity=256, max_probes=16),
                     buckets=(8, 16))
    boot(svc)
    rng = np.random.default_rng(5)
    for n in (3, 8, 11, 16, 5):
        kind = rng.choice([dynamic.ADD_EDGE] * 2 + [dynamic.REM_EDGE],
                          int(n))
        svc._apply_chunk(kind, rng.integers(0, NV, n), rng.integers(0, NV, n))
    assert svc.fallback_chunks == 0
    assert svc.compile_count <= 2  # == len(buckets), pipelined only


# ------------------------------------------------------ query broker ------


def test_broker_coalesces_into_one_flush():
    svc = SCCService(tiny_cfg(edge_capacity=256, max_probes=16),
                     buckets=(8,))
    boot(svc)
    svc._apply_chunk([dynamic.ADD_EDGE] * 4, [0, 1, 2, 3], [1, 2, 0, 4])
    broker = QueryBroker(svc, buckets=(4, 16))
    futs = [broker.submit("same_scc", [0, 1, 5], [1, 2, 6]),
            broker.submit("same_scc", [2], [0]),
            broker.submit("scc_members", [1, NV + 9]),
            broker.submit("reachable", [3, 0, -1], [4, 3, 0])]
    snap = broker.same_scc(0, 2)  # inline flush drains everything pending
    assert broker.flushes == 1
    assert broker.served == 10
    s_same, s_same2, s_mem, s_reach = [f.result(timeout=5) for f in futs]
    # all answers of one flush share one committed generation
    assert {s_same.gen, s_same2.gen, s_mem.gen, s_reach.gen,
            snap.gen} == {svc.gen}
    # values match the un-coalesced service queries (padding discarded)
    assert s_same.value.tolist() == \
        svc.same_scc([0, 1, 5], [1, 2, 6]).value.tolist()
    assert s_same2.value.tolist() == [True]
    assert s_mem.value[0].tolist() == svc.scc_members(1).value.tolist()
    assert not s_mem.value[1].any()  # out-of-range row is all-False
    assert s_reach.value.tolist() == \
        svc.reachable([3, 0, -1], [4, 3, 0]).value.tolist()
    assert snap.value.tolist() == [True]


def test_broker_dispatcher_survives_flush_errors(monkeypatch):
    """A flush that raises fails its own futures but must not kill the
    dispatcher: later submitters would otherwise hang forever on a dead
    thread."""
    from repro.core import service as svc_mod
    svc = SCCService(tiny_cfg(edge_capacity=256, max_probes=16),
                     buckets=(8,))
    boot(svc)
    svc._apply_chunk([dynamic.ADD_EDGE] * 2, [0, 1], [1, 0])
    real = svc_mod.same_scc_on
    calls = {"n": 0}

    def flaky(*a, **kw):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("injected device failure")
        return real(*a, **kw)

    monkeypatch.setattr(svc_mod, "same_scc_on", flaky)
    with QueryBroker(svc, buckets=(4,)) as broker:
        bad = broker.submit("same_scc", [0], [1])
        with pytest.raises(RuntimeError, match="injected"):
            bad.result(timeout=5)
        # dispatcher is still alive and answers the next query
        snap = broker.same_scc(0, 1)
        assert snap.value.tolist() == [True]
    # once stopped, new submissions are refused instead of queued forever
    with pytest.raises(RuntimeError):
        broker.submit("same_scc", [0], [1])


def test_broker_generations_monotone_across_commits():
    svc = SCCService(tiny_cfg(edge_capacity=256, max_probes=16),
                     buckets=(8,))
    boot(svc)
    broker = QueryBroker(svc, buckets=(8,))
    rng = np.random.default_rng(9)
    last = -1
    for _ in range(6):
        kind, u, v = mixed_stream(rng, 8)
        svc._apply_chunk(kind, u, v)
        snap = broker.same_scc(rng.integers(0, NV, 4),
                               rng.integers(0, NV, 4))
        assert snap.gen >= last
        assert snap.gen == svc.gen  # sequential caller sees latest commit
        last = snap.gen


# ------------------------------------- concurrent differential test -------


def _expected_same(cc, u, v):
    return cc[u] != NV and cc[u] == cc[v]


def _expected_reach(cc, edges, u, v):
    if cc[u] == NV or cc[v] == NV:
        return False
    adj = collections.defaultdict(list)
    for a, b in edges:
        adj[a].append(b)
    seen, frontier = {u}, [u]
    while frontier:
        nxt = []
        for x in frontier:
            for y in adj[x]:
                if y not in seen:
                    seen.add(y)
                    nxt.append(y)
        frontier = nxt
    return v in seen


def test_concurrent_readers_match_oracle_at_stamped_generation():
    """The acceptance contract: a reader pool against a live update stream.
    Every stamped answer equals the sequential oracle at that generation,
    every stamped generation is a *committed* generation (readers can
    never see the in-flight pipeline state), and each reader's observed
    generations are monotone."""
    svc = SCCService(tiny_cfg(edge_capacity=256, max_probes=16),
                     buckets=(8, 16))
    oracle = SeqSCC(NV)
    boot(svc, oracle)
    history = {svc.gen: (tuple(oracle.ccid()), frozenset(oracle.edges))}

    broker = QueryBroker(svc, buckets=(4, 8)).start()
    stop = threading.Event()
    results = [[] for _ in range(3)]  # (kind, gen, payload...) tuples
    errors = []

    def reader(i):
        rng = np.random.default_rng(40 + i)
        gens = []
        try:
            while not stop.is_set():
                qu = rng.integers(0, NV, 4)
                qv = rng.integers(0, NV, 4)
                roll = rng.random()
                if roll < 0.70:
                    s = broker.same_scc(qu, qv)
                    results[i].append(
                        ("same", s.gen, qu.copy(), qv.copy(),
                         s.value.copy()))
                elif roll < 0.85:
                    s = broker.scc_members(qu[:1])
                    results[i].append(
                        ("members", s.gen, int(qu[0]), s.value[0].copy()))
                else:
                    s = broker.reachable(qu[:2], qv[:2])
                    results[i].append(
                        ("reach", s.gen, qu[:2].copy(), qv[:2].copy(),
                         s.value.copy()))
                gens.append(s.gen)
        except Exception as e:
            errors.append(e)
        if gens != sorted(gens):
            errors.append(AssertionError(
                f"reader {i} generations not monotone: {gens}"))

    threads = [threading.Thread(target=reader, args=(i,), daemon=True)
               for i in range(3)]
    for t in threads:
        t.start()

    # live update stream, mirrored into the oracle under the documented
    # per-bucket phase linearization; history keyed by committed gen
    rng = np.random.default_rng(77)
    for step in range(12):
        n = int(rng.integers(1, 30))
        kind, u, v = mixed_stream(rng, n)
        ok = svc._apply_chunk(kind, u, v)
        want = np.zeros(n, bool)
        for sl, _ in svc._sched.plan(n):
            order = sorted(range(sl.start, sl.stop),
                           key=lambda i: (PHASE[int(kind[i])], i))
            for i in order:
                k, uu, vv = int(kind[i]), int(u[i]), int(v[i])
                if k == dynamic.ADD_EDGE:
                    want[i] = oracle.add_edge(uu, vv)
                elif k == dynamic.REM_EDGE:
                    want[i] = oracle.remove_edge(uu, vv)
                elif k == dynamic.ADD_VERTEX:
                    want[i] = oracle.add_vertex(uu)
                else:
                    want[i] = oracle.remove_vertex(uu)
        assert ok.tolist() == want.tolist()
        history[svc.gen] = (tuple(oracle.ccid()),
                            frozenset(oracle.edges))
        time.sleep(0.003)  # let readers interleave across generations

    stop.set()
    for t in threads:
        t.join()
    broker.stop()
    assert not errors, errors[0]

    n_checked = 0
    gens_seen = set()
    for per_reader in results:
        for rec in per_reader:
            gen = rec[1]
            # a stamped generation must be one the updater committed --
            # in-flight pipeline states are unobservable
            assert gen in history, f"uncommitted generation {gen} observed"
            cc, edges = history[gen]
            gens_seen.add(gen)
            if rec[0] == "same":
                _, _, qu, qv, val = rec
                for a, b, got in zip(qu, qv, val):
                    assert got == _expected_same(cc, int(a), int(b))
            elif rec[0] == "members":
                _, _, q, mask = rec
                want = [cc[w] == cc[q] and cc[q] != NV for w in range(NV)]
                assert mask.tolist() == want
            else:
                _, _, qu, qv, val = rec
                for a, b, got in zip(qu, qv, val):
                    assert got == _expected_reach(cc, edges, int(a),
                                                  int(b))
            n_checked += 1
    # the overlap was real: queries landed, across multiple generations
    assert n_checked > 0
    assert len(gens_seen) >= 2
