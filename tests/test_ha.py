"""Write-path high-availability suite (PR-10).

Pins the lease/epoch/fencing contract that makes writer failover safe:

  * :class:`repro.ha.lease.FileLease`: atomic fresh acquire, mutual
    exclusion while the holder heartbeats, monotone epoch bump on
    takeover, graceful release vs SIGKILL-style abandon;
  * the epoch-fenced WAL (:mod:`repro.ckpt.oplog`): v2 segment headers
    round-trip the writer epoch, legacy ``SCCWAL01`` segments read (and
    replay) as epoch 0, a fence marker makes every stale-epoch append
    raise :class:`~repro.fault.errors.Fenced` with NOTHING written, and
    the tail-repair utilities truncate a mixed-epoch log to the newest
    epoch's clean prefix;
  * :class:`~repro.ckpt.durable.DurableService` leadership: a writer
    whose lease was taken over self-fences with a typed
    :class:`~repro.fault.errors.NotLeader`; :meth:`Replica.promote`
    drains the fenced tail and produces a bit-identical next-epoch
    leader (differential oracle);
  * ``GraphClient`` failover behavior: ``NotLeader`` reroutes the
    session to ``leader_resolver()`` and resubmits; retry backoff uses
    seeded decorrelated jitter (deterministic under an injected RNG,
    never a lockstep geometric ladder);
  * multi-tenant lanes: an injected WAL fault on one tenant is a typed
    retryable reject chained to the cause, counted in that tenant's
    telemetry, and invisible to other tenants -- with the lane's store
    still bit-identical to its acked-op oracle afterwards.
"""
import os
import tempfile
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, strategies as st

from repro.api import AddEdge, GraphClient
from repro.api.ops import encode_updates
from repro.ckpt import oplog
from repro.ckpt.durable import FENCED, DurableService, wal_dir
from repro.core import graph_state as gs
from repro.core.replicas import Replica, ReplicaSet
from repro.core.service import SCCService
from repro.fault import errors as fault_errors
from repro.ha.lease import FileLease

NV = 24
KNOBS = dict(buckets=(8,), proactive_grow=True)


def tiny_cfg():
    return gs.GraphConfig(n_vertices=NV, edge_capacity=64, max_probes=16,
                          max_outer=NV + 1, max_inner=NV + 2)


def make_writer(directory, **durable_kw):
    cfg = tiny_cfg()
    durable_kw.setdefault("snapshot_every", 0)
    durable_kw.setdefault("recover_probe_s", 0.0)
    return DurableService(cfg, str(directory),
                          state=gs.all_singletons(cfg), sync_every=1,
                          **durable_kw, **KNOBS)


def chunk(rng, n=8):
    return (rng.integers(2, 4, n).astype(np.int32),
            rng.integers(0, NV, n).astype(np.int32),
            rng.integers(0, NV, n).astype(np.int32))


def leaves_equal(a, b):
    import jax
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def listing(directory):
    return sorted((f, os.path.getsize(os.path.join(directory, f)))
                  for f in os.listdir(directory))


def acquire_stale(lease, timeout_s=5.0):
    """Poll try_acquire until the current holder's lease goes stale."""
    deadline = time.monotonic() + timeout_s
    while not lease.try_acquire():
        assert time.monotonic() < deadline, "lease never went stale"
        time.sleep(lease.ttl_s / 5)


# ---------------------------------------------------------------- lease ---


def test_lease_fresh_acquire_is_exclusive_and_takeover_bumps_epoch(
        tmp_path):
    a = FileLease(str(tmp_path), "a", ttl_s=0.15)
    b = FileLease(str(tmp_path), "b", ttl_s=0.15)
    assert a.try_acquire() and a.epoch == 0 and a.valid
    assert not b.try_acquire()  # holder is alive (mtime fresh)
    a.renew()
    assert a.renewals == 1
    time.sleep(0.2)  # a stops renewing: the lease goes stale
    acquire_stale(b)
    assert b.epoch == 1 and b.takeovers == 1
    # the deposed holder's next renewal is a typed loss, flipping valid
    with pytest.raises(fault_errors.LeaseLost):
        a.renew()
    assert not a.valid and a.lost_reason is not None
    info = b.peek()
    assert (info.epoch, info.owner) == (1, "b")


def test_lease_release_hands_off_without_a_ttl_wait(tmp_path):
    a = FileLease(str(tmp_path), "a", ttl_s=30.0)  # huge TTL
    assert a.try_acquire()
    a.release()  # backdates mtime: successor need not wait 30s
    b = FileLease(str(tmp_path), "b", ttl_s=30.0)
    assert b.try_acquire() and b.epoch == 1


def test_lease_heartbeat_keeps_holder_alive_and_abandon_models_sigkill(
        tmp_path):
    a = FileLease(str(tmp_path), "a", ttl_s=0.15)
    assert a.try_acquire()
    a.start_heartbeat()
    time.sleep(0.5)  # several TTLs: the heartbeat must keep it fresh
    b = FileLease(str(tmp_path), "b", ttl_s=0.15)
    assert not b.try_acquire() and a.valid and a.renewals >= 2
    a.abandon()  # SIGKILL analogue: no backdate, heartbeat stops dead
    assert not b.try_acquire()  # still fresh: failover waits the TTL
    acquire_stale(b)
    assert b.epoch == 1


# ----------------------------------------------------- epoch-fenced WAL ---


@settings(max_examples=12)
@given(st.integers(0, 2 ** 31 - 1), st.integers(0, 2 ** 20))
def test_segment_header_roundtrips_epoch_and_base_gen(epoch, base_gen):
    with tempfile.TemporaryDirectory(prefix="scc-hdr-") as d:
        w = oplog.OpLogWriter(d, sync_every=1, start_gen=base_gen,
                              epoch=epoch)
        w.close()
        _, path = oplog.list_segments(d)[-1]
        hdr = oplog.segment_header(path)
        assert (hdr.base_gen, hdr.epoch) == (base_gen, epoch)
        assert hdr.size == oplog.SEG_HEADER_BYTES
        assert oplog.newest_epoch(d) == epoch


def test_fence_refuses_stale_appends_with_nothing_written(tmp_path):
    d = str(tmp_path)
    rng = np.random.default_rng(0)
    w = oplog.OpLogWriter(d, sync_every=1, start_gen=0)
    k, u, v = chunk(rng)
    w.append(0, k, u, v)
    oplog.write_fence(d, 1)
    before = listing(d)
    with pytest.raises(fault_errors.Fenced):
        w.append(1, *chunk(rng))
    assert listing(d) == before, "a fenced append left bytes behind"
    w.close()
    assert listing(d) == before
    # a resurrected writer at the dead epoch is refused before it can
    # even create a segment
    with pytest.raises(fault_errors.Fenced):
        oplog.OpLogWriter(d, sync_every=1, start_gen=2, epoch=0)
    assert listing(d) == before
    # everything appended before the fence stays durable and readable
    assert [r.gen_before for r in oplog.read_log(d)] == [0]
    # the next epoch appends freely
    w1 = oplog.OpLogWriter(d, sync_every=1, start_gen=1, epoch=1)
    w1.append(1, *chunk(rng))
    w1.close()
    assert [r.gen_before for r in oplog.read_log(d)] == [0, 1]


@settings(max_examples=8)
@given(st.integers(1, 4), st.integers(1, 4), st.integers(1, 48))
def test_mixed_epoch_tail_truncates_to_newest_epochs_clean_prefix(
        n_a, n_b, torn_words):
    """repair_tail / drop_unapplied_tail on a WAL whose tail spans a
    failover: epoch-0 segments, a fence, then epoch-1 segments ending in
    torn bytes.  Repair must drop exactly the junk; the unapplied-record
    cut must land inside the newest epoch; replay yields every surviving
    record across both epochs in order."""
    rng = np.random.default_rng(n_a * 101 + n_b)
    with tempfile.TemporaryDirectory(prefix="scc-mixed-") as d:
        gen = 0
        w0 = oplog.OpLogWriter(d, sync_every=1, start_gen=0)
        for _ in range(n_a):
            w0.append(gen, *chunk(rng))
            gen += 1
        w0.close()
        oplog.write_fence(d, 1)
        w1 = oplog.OpLogWriter(d, sync_every=1, start_gen=gen, epoch=1)
        for _ in range(n_b):
            w1.append(gen, *chunk(rng))
            gen += 1
        w1.close()
        segs = oplog.list_segments(d)
        assert oplog.segment_header(segs[0][1]).epoch == 0
        assert oplog.segment_header(segs[-1][1]).epoch == 1
        with open(segs[-1][1], "ab") as f:  # crash-torn tail
            f.write(b"\xde\xad" * torn_words)
        assert oplog.repair_tail(d) == 2 * torn_words
        recs = oplog.read_log(d)
        assert [r.gen_before for r in recs] == list(range(gen))
        assert oplog.newest_epoch(d) == 1
        # a valid-but-unacked record at the newest epoch's tail is cut
        # without touching the older epoch's segments
        assert oplog.drop_unapplied_tail(d, gen - 1) > 0
        recs = oplog.read_log(d)
        assert [r.gen_before for r in recs] == list(range(gen - 1))
        assert oplog.segment_header(segs[0][1]).epoch == 0


def test_v1_segments_read_and_replay_as_epoch_zero(tmp_path):
    """Back-compat: a pre-epoch store (SCCWAL01 headers) must recover
    bit-identically, reading every segment as epoch 0."""
    writer = make_writer(tmp_path)
    rng = np.random.default_rng(3)
    chunks = [chunk(rng) for _ in range(4)]
    for c in chunks:
        writer._apply_ops(*c)
    writer.close()
    wdir = wal_dir(str(tmp_path))
    for _, path in oplog.list_segments(wdir):  # rewrite headers as v1
        with open(path, "rb") as f:
            buf = f.read()
        hdr = oplog.parse_segment_header(buf, path)
        assert hdr.epoch == 0 and hdr.size == oplog.SEG_HEADER_BYTES
        with open(path, "wb") as f:
            f.write(oplog._SEG_HDR_V1.pack(oplog._SEG_MAGIC_V1,
                                           hdr.base_gen))
            f.write(buf[hdr.size:])
        v1 = oplog.segment_header(path)
        assert (v1.base_gen, v1.epoch, v1.size) == (hdr.base_gen, 0,
                                                    oplog._SEG_HDR_V1.size)
    assert oplog.newest_epoch(wdir) == 0
    reopened = DurableService.open(str(tmp_path), snapshot_every=0)
    cfg = tiny_cfg()
    oracle = SCCService(cfg, state=gs.all_singletons(cfg), **KNOBS)
    for c in chunks:
        oracle._apply_ops(*c)
    assert reopened.gen == oracle.gen
    assert leaves_equal(reopened.state, oracle.state)
    reopened.close()


# ------------------------------------------------- writer-side fencing ---


def test_writer_self_fences_when_its_lease_is_taken_over(tmp_path):
    lease_a = FileLease(str(tmp_path), "a", ttl_s=0.15)
    assert lease_a.try_acquire()
    writer = make_writer(tmp_path, lease=lease_a)
    rng = np.random.default_rng(5)
    writer._apply_ops(*chunk(rng))
    writer.crash()  # heartbeat stops dead, lease left behind
    lease_b = FileLease(str(tmp_path), "b", ttl_s=0.15)
    acquire_stale(lease_b)
    assert lease_b.epoch == 1
    with pytest.raises(fault_errors.NotLeader) as ei:
        writer._apply_ops(*chunk(rng))
    assert ei.value.retryable
    assert writer.health == FENCED
    assert writer.stats()["notleader_rejects"] >= 1
    writer.close()


def test_promotion_is_a_bit_identical_next_epoch_handoff(tmp_path):
    """Differential oracle across a promotion: old-leader chunks + new-
    leader chunks replayed through a plain in-memory service must equal
    the promoted leader AND a cold reopen -- and the dead writer stays
    typed-rejected."""
    cfg = tiny_cfg()
    lease_a = FileLease(str(tmp_path), "a", ttl_s=0.15)
    assert lease_a.try_acquire()
    writer = make_writer(tmp_path, lease=lease_a)
    rng = np.random.default_rng(11)
    chunks = [chunk(rng) for _ in range(5)]
    for c in chunks:
        writer._apply_ops(*c)
    writer.crash()
    rep = Replica(str(tmp_path), 0, query_buckets=(8,), auto_tail=False)
    lease_b = FileLease(str(tmp_path), "b", ttl_s=0.15)
    deadline = time.monotonic() + 5.0
    leader = None
    while leader is None:
        try:
            leader = rep.promote(lease_b, snapshot_every=0)
        except fault_errors.Unavailable:
            assert time.monotonic() < deadline, "promotion never won"
            time.sleep(0.03)
    try:
        assert leader.epoch == 1 and leader.gen == writer.gen
        more = [chunk(rng) for _ in range(3)]
        for c in more:
            leader._apply_ops(*c)
        # the deposed writer keeps bouncing typed errors, applies nothing
        with pytest.raises(fault_errors.NotLeader):
            writer._apply_ops(*chunk(rng))
        oracle = SCCService(cfg, state=gs.all_singletons(cfg), **KNOBS)
        for c in chunks + more:
            oracle._apply_ops(*c)
        assert leader.gen == oracle.gen
        assert leaves_equal(leader.state, oracle.state)
    finally:
        leader.close()
        rep.stop()
        writer.close()
    reopened = DurableService.open(str(tmp_path), snapshot_every=0)
    assert reopened.epoch >= 1  # cold recovery adopts the fenced epoch
    assert reopened.gen == oracle.gen
    assert leaves_equal(reopened.state, oracle.state)
    reopened.close()


def test_replicaset_supervisor_promotes_on_stale_writer_lease(tmp_path):
    lease = FileLease(str(tmp_path), "writer", ttl_s=0.15)
    assert lease.try_acquire()
    writer = make_writer(tmp_path, lease=lease)
    rng = np.random.default_rng(17)
    for _ in range(3):
        writer._apply_ops(*chunk(rng))
    rset = ReplicaSet(str(tmp_path), 2, query_buckets=(8,),
                      poll_interval=0.02, supervise=True,
                      health_check_s=0.03, promote_on_writer_loss=True,
                      lease_ttl_s=0.15,
                      writer_kwargs=dict(sync_every=1, snapshot_every=0))
    try:
        assert rset.leader is None  # healthy writer: nothing to promote
        time.sleep(0.4)
        assert rset.leader is None and rset.promotions == 0
        writer.crash()
        deadline = time.monotonic() + 8.0
        while rset.leader is None:
            assert time.monotonic() < deadline, (
                f"supervisor never promoted "
                f"(last={rset.last_promote_error})")
            time.sleep(0.02)
        leader = rset.leader
        assert rset.promotions == 1 and leader.epoch == 1
        leader._apply_ops(*chunk(rng))  # the new leader accepts writes
        assert leader.gen == writer.gen + 1
    finally:
        rset.stop()  # also closes the promoted leader
        writer.close()


# --------------------------------------------------- client failover ----


class _DeposedService:
    """Stub of a writer that lost leadership: every chunk bounces."""

    def __init__(self):
        self.gen = 0
        self.attempts = 0

    def _apply_ops(self, kind, u, v, *, session=None, seq=None):
        self.attempts += 1
        raise fault_errors.NotLeader("leadership moved", leader="peer",
                                     retry_after=0.001)

    def stats(self):
        return {}


class _LeaderService:
    """Stub of the current leader: applies everything."""

    def __init__(self):
        self.gen = 0
        self.applied = 0

    def _apply_ops(self, kind, u, v, *, session=None, seq=None):
        self.gen += 1
        self.applied += 1
        return np.ones(len(kind), bool), self.gen

    def stats(self):
        return {}


def test_client_reroutes_on_notleader_and_resubmits():
    import random
    old, new = _DeposedService(), _LeaderService()
    client = GraphClient(old, max_retries=4, backoff_base_s=1e-4,
                         backoff_cap_s=1e-3, rng=random.Random(0),
                         leader_resolver=lambda: new)
    res = client.submit_many([AddEdge(0, 1)])
    assert res[0].gen == 1 and new.applied == 1
    assert old.attempts == 1  # one bounce, then the session moved
    assert client.stats()["client_reroutes"] == 1
    client.submit_many([AddEdge(1, 2)])  # subsequent ops go straight
    assert old.attempts == 1 and new.applied == 2


def test_client_without_resolver_surfaces_notleader_after_retries():
    old = _DeposedService()
    client = GraphClient(old, max_retries=3, backoff_base_s=1e-4,
                         backoff_cap_s=1e-3)
    with pytest.raises(fault_errors.NotLeader):
        client.submit_many([AddEdge(0, 1)])
    assert old.attempts == 4  # initial + max_retries


class _Flaky:
    def __init__(self, n_fail):
        self.gen = 0
        self.n_fail = n_fail
        self.attempts = 0

    def _apply_ops(self, kind, u, v, *, session=None, seq=None):
        self.attempts += 1
        if self.attempts <= self.n_fail:
            raise fault_errors.Unavailable("transient",
                                           retry_after=0.0001)
        self.gen += 1
        return np.ones(len(kind), bool), self.gen


def test_retry_backoff_jitter_is_seeded_and_decorrelated(monkeypatch):
    import random

    def run(seed):
        waits = []
        monkeypatch.setattr(time, "sleep",
                            lambda s, rec=waits: rec.append(s))
        try:
            client = GraphClient(_Flaky(6), max_retries=8,
                                 backoff_base_s=0.004,
                                 backoff_cap_s=0.5,
                                 rng=random.Random(seed))
            client.submit_many([AddEdge(0, 1)])
        finally:
            monkeypatch.undo()
        return waits

    a, b, c = run(7), run(7), run(11)
    assert len(a) == 6
    assert a == b, "same RNG seed must reproduce the wait schedule"
    assert a != c, "different seeds must decorrelate the schedule"
    assert len(set(a)) > 1, "jitter collapsed to a fixed ladder"
    assert all(0.004 <= w <= 0.5 for w in a)


# ------------------------------------------------------- tenant lanes ----


def test_tenant_wal_fault_is_typed_isolated_and_counted(tmp_path):
    from repro.tenancy import MultiTenantService

    cfg = tiny_cfg()
    knobs = dict(buckets=(8,), scan_lengths=(1,))
    mts = MultiTenantService(cfg, directory=str(tmp_path),
                             tenant_batches=(1, 2), coalesce_ops=16,
                             flush_deadline_s=0.0, wal_sync_every=1,
                             **knobs)
    ta, tb = mts.create_tenant(), mts.create_tenant()
    ca = mts.client(ta, max_retries=0)
    cb = mts.client(tb, max_retries=0)
    ca.submit_many([AddEdge(0, 1)])
    cb.submit_many([AddEdge(1, 2)])
    h = mts._tenants[ta]
    real_append = h.wal.append
    state = {"failed": False}

    def sick_append(*args, **kw):
        if not state["failed"]:
            state["failed"] = True
            raise OSError(5, "injected tenant-lane disk fault")
        return real_append(*args, **kw)

    h.wal.append = sick_append
    with pytest.raises(fault_errors.Unavailable) as ei:
        ca.submit_many([AddEdge(2, 3)])
    assert ei.value.retryable and ei.value.retry_after is not None
    assert isinstance(ei.value.__cause__, OSError)
    # the fault is A's alone: B's lane flushes normally, telemetry
    # blames exactly one lane
    cb.submit_many([AddEdge(3, 4)])
    assert mts.tenant_stats(ta)["wal_faults"] == 1
    assert mts.tenant_stats(tb)["wal_faults"] == 0
    # the failed chunk was neither applied nor acked: a resubmit lands
    # exactly once and the lane stays oracle-identical, disk included
    ca.submit_many([AddEdge(2, 3)])
    oracle = SCCService(cfg, **knobs)
    for op in ([AddEdge(0, 1)], [AddEdge(2, 3)]):
        oracle._apply_ops(*encode_updates(op))
    assert mts.tenant_gen(ta) == oracle.gen == 2
    assert leaves_equal(mts._tenant_state(ta), oracle.state)
    mts.close()
    cold = DurableService.open(os.path.join(str(tmp_path), "tenants",
                                            ta), snapshot_every=0)
    assert cold.gen == oracle.gen
    assert leaves_equal(cold.state, oracle.state)
    cold.close()
