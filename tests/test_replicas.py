"""Read-replica suite (PR-6): WAL-tailing replicas behind the broker.

Pins the replication-layer contracts of ``repro.core.replicas``:

  * a :class:`Replica` bootstraps from the writer's snapshot and, after
    tailing the WAL, is **bit-identical** to the writer at every
    committed generation it passes through -- same state leaves, same
    ``same_scc`` / ``community_of`` answers;
  * ``AT_LEAST(gen)`` on a stale replica *defers*: the broker serves
    nothing for that request until the replica has tailed past ``gen``
    (``gen_waits`` telemetry), while floor-free requests on the same
    replica are never delayed behind it;
  * :class:`ReplicaSet` routing: requests whose floor some replica
    already covers go to a fresh replica (``routed_fresh``); requests
    nobody covers yet are parked on one replica (``routed_stale``) and
    served once it tails -- and a served stamp is never below the floor,
    so per-reader generation stamps stay monotone even when consecutive
    reads land on *different* replicas (the session-floor contract);
  * writer, tailing replica, and the sequential python oracle
    (``tests/oracle.py``) agree op-for-op on random mixed streams --
    per-op acks, labels, edge sets, generations;
  * a replica whose WAL cursor is trimmed underneath it (writer
    snapshotted + dropped old segments) resyncs from the newest
    snapshot and converges anyway;
  * the typed :class:`repro.api.GraphClient` runs writes through the
    writer and READ_YOUR_WRITES reads through a :class:`ReplicaSet`.

Everything here drives replicas manually (``auto_tail=False``) so the
tests are single-threaded and deterministic; the threaded tail/dispatch
path is exercised by the crash smoke and the replica bench
(``python -m repro.launch.replica``).
"""
import numpy as np
import pytest

from repro.api import (AddEdge, Consistency, GraphClient, RemoveEdge,
                       SameSCC)
from repro.ckpt.durable import DurableService
from repro.core import dynamic, graph_state as gs
from repro.core import service as svc_mod
from repro.core.replicas import Replica, ReplicaSet
from oracle import SeqSCC

NV = 24
KNOBS = dict(buckets=(8,), proactive_grow=True)
PHASE = {dynamic.REM_VERTEX: 0, dynamic.REM_EDGE: 1,
         dynamic.ADD_VERTEX: 2, dynamic.ADD_EDGE: 3}
QU = np.arange(8, dtype=np.int32) % NV
QV = (QU * 5 + 3) % NV


def tiny_cfg():
    return gs.GraphConfig(n_vertices=NV, edge_capacity=64, max_probes=16,
                          max_outer=NV + 1, max_inner=NV + 2)


def make_writer(directory, **durable_kw):
    cfg = tiny_cfg()
    durable_kw.setdefault("snapshot_every", 0)  # boot snapshot only
    return DurableService(cfg, str(directory), state=gs.all_singletons(cfg),
                          sync_every=1, **durable_kw, **KNOBS)


def random_chunk(rng, n=8):
    return (rng.integers(0, 4, n).astype(np.int32),
            rng.integers(0, NV, n).astype(np.int32),
            rng.integers(0, NV, n).astype(np.int32))


def drain(replica):
    while True:  # a resync applies nothing itself but re-seats the cursor
        before = replica.resyncs
        if replica.tail_once() == 0 and replica.resyncs == before:
            return


def assert_same_graph(a_state, a_cfg, b_state, b_cfg, ctx=""):
    import jax
    got = jax.tree_util.tree_leaves(a_state)
    want = jax.tree_util.tree_leaves(b_state)
    assert len(got) == len(want), ctx
    for x, y in zip(got, want):
        assert np.array_equal(np.asarray(x), np.asarray(y)), ctx
    assert np.array_equal(svc_mod.same_scc_on(a_state, a_cfg, QU, QV),
                          svc_mod.same_scc_on(b_state, b_cfg, QU, QV)), ctx
    assert np.array_equal(svc_mod.community_of_on(a_state, a_cfg, QU),
                          svc_mod.community_of_on(b_state, b_cfg, QU)), ctx


def oracle_chunk(oracle, kind, u, v):
    """Per-op oracle acks for ONE service chunk (ops phase-sorted within
    the chunk, like the engine's removal/insert phases)."""
    want = np.zeros(len(kind), bool)
    order = sorted(range(len(kind)),
                   key=lambda i: (PHASE[int(kind[i])], i))
    for i in order:
        k, uu, vv = int(kind[i]), int(u[i]), int(v[i])
        if k == dynamic.ADD_EDGE:
            want[i] = oracle.add_edge(uu, vv)
        elif k == dynamic.REM_EDGE:
            want[i] = oracle.remove_edge(uu, vv)
        elif k == dynamic.ADD_VERTEX:
            want[i] = oracle.add_vertex(uu)
        else:
            want[i] = oracle.remove_vertex(uu)
    return want


# --------------------------------------------------------- bootstrap ------


def test_replica_bootstraps_and_tails_bit_identical(tmp_path):
    """Boot-snapshot bootstrap + full tail == the writer, bit for bit;
    the replica's broker stamps answers with the replica generation."""
    writer = make_writer(tmp_path)
    rng = np.random.default_rng(7)
    for _ in range(6):
        writer._apply_ops(*random_chunk(rng))

    rep = Replica(str(tmp_path), auto_tail=False, query_buckets=(8,))
    assert rep.gen == 0, "bootstraps from the generation-0 boot snapshot"
    drain(rep)
    assert rep.gen == writer.gen
    assert rep.applied_records == 6
    assert_same_graph(rep.service.state, rep.service.cfg,
                      writer.state, writer.cfg, "after full tail")
    assert rep.service.edge_set() == writer.edge_set()

    snap = rep.broker.same_scc(QU, QV)  # inline flush, no dispatcher
    assert snap.gen == rep.gen
    assert np.array_equal(
        np.asarray(snap.value),
        svc_mod.same_scc_on(writer.state, writer.cfg, QU, QV))
    writer.close()


# ---------------------------------------------------------- gen-wait ------


def test_at_least_defers_on_stale_replica_until_tailed(tmp_path):
    """AT_LEAST(G) on a replica still below G is re-queued (gen_waits)
    and served only after the replica tails past G -- floor-free
    requests on the same replica are answered immediately meanwhile."""
    writer = make_writer(tmp_path)
    rng = np.random.default_rng(8)
    for _ in range(4):
        writer._apply_ops(*random_chunk(rng))
    goal = writer.gen

    rep = Replica(str(tmp_path), auto_tail=False, query_buckets=(8,))
    assert rep.tail_once(max_records=2) == 2
    stale_gen = rep.gen
    assert 0 < stale_gen < goal

    fut = rep.broker.submit("same_scc", QU, QV, min_gen=goal)
    assert rep.broker.flush() == 0, "stale replica must not answer"
    assert not fut.done()
    assert rep.broker.gen_waits == 1

    # a floor-free reader is not delayed behind the deferred request
    free = rep.broker.submit("same_scc", QU, QV)
    assert rep.broker.flush() == len(QU)
    assert free.result().gen == stale_gen
    assert not fut.done()
    assert rep.broker.gen_waits == 1, "deferral is counted once"

    drain(rep)
    assert rep.broker.flush() == len(QU)
    snap = fut.result()
    assert snap.gen >= goal
    assert np.array_equal(
        np.asarray(snap.value),
        svc_mod.same_scc_on(writer.state, writer.cfg, QU, QV))
    writer.close()


# ------------------------------------------------------------ routing -----


def test_replicaset_routes_fresh_and_parks_stale(tmp_path):
    """Floors some replica covers route fresh (never to a replica below
    the floor); uncovered floors park on one replica and serve once it
    tails -- stamps never dip below a session's floor even when reads
    hop replicas."""
    writer = make_writer(tmp_path)
    rng = np.random.default_rng(9)
    for _ in range(4):
        writer._apply_ops(*random_chunk(rng))
    g4 = writer.gen

    rs = ReplicaSet(str(tmp_path), 2, auto_tail=False, query_buckets=(8,))
    r0, r1 = rs.replicas
    drain(r0)                       # r0 at g4, r1 still at 0
    assert rs.min_gen == 0

    fut = rs.submit("same_scc", QU, QV, min_gen=g4)
    snap = rs.resolve(fut, min_gen=g4)
    assert rs.routed_fresh == 1 and rs.routed_stale == 0
    assert snap.gen >= g4
    assert r1.broker.served == 0, "a stale replica never saw the floor"

    # advance the writer past every replica: nobody is fresh
    writer._apply_ops(*random_chunk(rng))
    g5 = writer.gen
    fut = rs.submit("same_scc", QU, QV, min_gen=g5)
    assert rs.routed_stale == 1
    # without tail threads the stale route falls back to the most
    # caught-up replica (etas are inf) -- that is r0
    assert r0.tail_once() > 0 and r0.gen == g5
    snap = rs.resolve(fut, min_gen=g5)
    assert snap.gen >= g5

    # session floor across replicas: a reader holding stamp g5 queries
    # again; only fresh replicas qualify, so the stamp stays monotone
    floor = int(snap.gen)
    fut = rs.submit("same_scc", QU, QV, min_gen=floor)
    snap2 = rs.resolve(fut, min_gen=floor)
    assert snap2.gen >= floor
    assert r1.gen < floor and r1.broker.served == 0

    drain(r1)
    assert rs.wait_all_for_gen(g5, timeout=1.0) == g5
    s = rs.stats()
    assert s["replicas"] == 2
    assert s["routed_fresh"] + s["routed_stale"] == 3
    assert s["replica0_gen"] == s["replica1_gen"] == g5
    writer.close()


# ------------------------------------------------- oracle differential ----


def test_writer_replica_oracle_differential(tmp_path):
    """Random mixed streams: writer acks == sequential oracle acks, and
    after each round the tailing replica matches both -- labels, edge
    set, generation; its broker stamps are monotone per reader."""
    writer = make_writer(tmp_path)
    oracle = SeqSCC(NV)
    for i in range(NV):
        assert oracle.add_vertex(i)  # all_singletons boots everything live

    rep = Replica(str(tmp_path), auto_tail=False, query_buckets=(8,))
    rng = np.random.default_rng(17)
    last_stamp = -1
    for round_no in range(10):
        kind, u, v = random_chunk(rng)
        ok, gen = writer._apply_ops(kind, u, v)
        want = oracle_chunk(oracle, kind, u, v)
        assert np.asarray(ok).tolist() == want.tolist(), \
            f"round {round_no}: writer acks diverge from oracle"

        drain(rep)
        assert rep.gen == writer.gen == gen
        assert np.asarray(rep.service.state.ccid).tolist() == \
            np.asarray(writer.state.ccid).tolist() == oracle.ccid()
        assert rep.service.edge_set() == writer.edge_set() == oracle.edges

        snap = rep.broker.same_scc(QU, QV)
        assert snap.gen >= last_stamp, "per-reader stamps must be monotone"
        last_stamp = int(snap.gen)
        lab = oracle.ccid()
        want_q = [lab[int(a)] == lab[int(b)] and lab[int(a)] < NV
                  for a, b in zip(QU, QV)]
        assert np.asarray(snap.value).tolist() == want_q
    writer.close()


# -------------------------------------------------------------- resync ----


def test_replica_resyncs_after_wal_trim(tmp_path):
    """A snapshot+trim that drops segments under a lagging replica's
    cursor forces a snapshot resync; the replica still converges to the
    writer's exact state."""
    writer = make_writer(tmp_path, segment_bytes=128,
                         trim_on_snapshot=True)
    rng = np.random.default_rng(23)
    writer._apply_ops(*random_chunk(rng))
    rep = Replica(str(tmp_path), auto_tail=False, query_buckets=(8,))
    assert rep.tail_once(max_records=1) == 1  # cursor parked early

    for _ in range(8):
        writer._apply_ops(*random_chunk(rng))
    writer.snapshot_now()  # trims the WAL below the snapshot gen
    writer._apply_ops(*random_chunk(rng))

    drain(rep)
    assert rep.resyncs >= 1, "trimmed cursor must trigger a resync"
    assert rep.gen == writer.gen
    assert_same_graph(rep.service.state, rep.service.cfg,
                      writer.state, writer.cfg, "post-resync")
    writer.close()


# ------------------------------------------------------- typed client -----


def test_graph_client_over_replicaset_read_your_writes(tmp_path):
    """The deployment shape from docs/SERVICE_API.md: GraphClient writes
    through the durable writer and reads from a ReplicaSet under
    READ_YOUR_WRITES -- every stamp covers the session's last ack."""
    writer = make_writer(tmp_path)
    rs = ReplicaSet(str(tmp_path), 2, auto_tail=False, query_buckets=(8,))
    client = GraphClient(writer, broker=rs,
                         consistency=Consistency.READ_YOUR_WRITES)

    ack = client.submit(AddEdge(1, 2)).result()
    assert ack.value and ack.gen == writer.gen
    ack2 = client.submit(AddEdge(2, 1)).result()
    assert client.token == ack2.gen

    for r in rs.replicas:
        drain(r)
    got = client.submit(SameSCC(1, 2)).result()
    assert got.value is True
    assert got.gen >= ack2.gen, "RYW floor must cover the last ack"

    # breaking the cycle flows through the same path
    client.submit(RemoveEdge(2, 1)).result()
    for r in rs.replicas:
        drain(r)
    got = client.submit(SameSCC(1, 2)).result()
    assert got.value is False
    assert got.gen >= client.token
    assert rs.stats()["routed_fresh"] == 2
    client.close()  # shared broker: the set is stopped explicitly
    rs.stop()
    writer.close()
