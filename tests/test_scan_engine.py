"""Fused scan-based update engine + in-graph repair gate.

Pins the PR-5 tentpole contracts:

  * the repair gate is *conservative and exact*: for random op batches the
    gated step is bit-identical to the always-repair step (labels, per-op
    results, generation, SCC count), and counting instrumentation shows
    repair really is skipped (``TIER_SKIP``) on structure-preserving
    batches -- re-adding existing edges, adding edges inside one SCC,
    removing absent edges -- while structure-changing batches never skip;
  * ``dynamic.apply_batch_scan`` (K stacked chunks through one compiled
    ``lax.scan``) equals K sequential ``apply_batch`` steps bit-exactly,
    stacked telemetry included;
  * ``BucketedScheduler.super_chunks`` covers the bucket plan with
    registry scan lengths only, padding-compatible with ``chunks``;
  * service level: the scanned pipeline equals the serial grow-and-replay
    path (and the sequential oracle) on random overflowing mixed streams,
    overflow replays only from the offending super-chunk, and the
    ``scanned_chunks`` / ``repair_skipped_steps`` telemetry reaches
    ``GraphClient.stats()``.
"""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 env has no hypothesis: seeded shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import dynamic, graph_state as gs
from repro.core.service import SCCService
from repro.launch.stream import BucketedScheduler
from oracle import SeqSCC

NV = 24
PHASE = {dynamic.REM_VERTEX: 0, dynamic.REM_EDGE: 1,
         dynamic.ADD_VERTEX: 2, dynamic.ADD_EDGE: 3}


def cfg_pair(**kw):
    base = dict(n_vertices=NV, edge_capacity=256, max_probes=64,
                max_outer=NV + 1, max_inner=NV + 2)
    base.update(kw)
    return (gs.GraphConfig(**base, repair_gate=True),
            gs.GraphConfig(**base, repair_gate=False))


def booted(cfg):
    state = gs.all_singletons(cfg)
    return state


def step(state, op_list, cfg):
    ops = dynamic.make_ops([k for k, _, _ in op_list],
                           [u for _, u, _ in op_list],
                           [v for _, _, v in op_list])
    state, ok, ovf, rstats = dynamic.apply_batch_async(state, ops, cfg)
    return state, np.asarray(ok).tolist(), int(ovf), rstats


OPS_STRATEGY = st.lists(
    st.tuples(st.integers(0, 3), st.integers(0, NV - 1),
              st.integers(0, NV - 1)),
    min_size=1, max_size=30)


# ------------------------------------------------------- repair gate ------


@settings(max_examples=12, deadline=None)
@given(OPS_STRATEGY)
def test_gate_differential_random_mixes(op_list):
    """Gated apply_batch is bit-identical to always-repair over random
    mixed histories: labels, per-op results, overflow, gen, n_ccs."""
    cfg_g, cfg_u = cfg_pair()
    st_g, st_u = booted(cfg_g), booted(cfg_u)
    for i in range(0, len(op_list), 6):
        batch = op_list[i:i + 6]
        st_g, ok_g, ovf_g, _ = step(st_g, batch, cfg_g)
        st_u, ok_u, ovf_u, _ = step(st_u, batch, cfg_u)
        assert ok_g == ok_u, batch
        assert np.asarray(st_g.ccid).tolist() == \
            np.asarray(st_u.ccid).tolist(), batch
        assert ovf_g == ovf_u
        assert int(st_g.gen) == int(st_u.gen)
        assert int(st_g.n_ccs) == int(st_u.n_ccs)


def test_gate_skips_structure_preserving_batches():
    """Counting instrumentation: the canonical structure-preserving
    batches really skip (TIER_SKIP), structure-changing ones never do,
    and skipped steps leave the partition untouched."""
    cfg_g, cfg_u = cfg_pair()
    st_g = booted(cfg_g)
    ring = [(dynamic.ADD_EDGE, i, (i + 1) % 6) for i in range(6)]
    st_g, ok, _, rs = step(st_g, ring, cfg_g)
    assert all(ok)
    assert int(rs.tier) != dynamic.TIER_SKIP  # a merge: repair ran
    labels_before = np.asarray(st_g.ccid).tolist()

    skippers = [
        ring,                                   # re-add existing edges
        [(dynamic.ADD_EDGE, 0, 3),              # new edges inside one SCC
         (dynamic.ADD_EDGE, 4, 1)],
        [(dynamic.REM_EDGE, 7, 8)],             # remove an absent edge
        [(dynamic.REM_EDGE, 3, 0)],             # absent reverse direction
    ]
    for batch in skippers:
        prev = np.asarray(st_g.ccid).tolist()
        st_g, _, _, rs = step(st_g, batch, cfg_g)
        assert int(rs.tier) == dynamic.TIER_SKIP, batch
        assert int(rs.region_vertices) == 0 and int(rs.region_edges) == 0
        assert np.asarray(st_g.ccid).tolist() == prev, batch

    # intra-SCC chords were inserted above (graph changed, partition not)
    assert np.asarray(st_g.ccid).tolist() == labels_before

    # structure-changing batches must never skip (conservative direction)
    for batch, name in [
            ([(dynamic.REM_EDGE, 0, 1)], "intra-SCC edge removal"),
            ([(dynamic.ADD_EDGE, 10, 11)], "straddling insert"),
            ([(dynamic.REM_VERTEX, 2, 0)], "remove SCC member"),
    ]:
        st_chk = st_g
        st_chk, _, _, rs = step(st_chk, batch, cfg_g)
        assert int(rs.tier) != dynamic.TIER_SKIP, name

    # removing an isolated singleton is provably structure-preserving:
    # the gate's m_del predicate sees an empty region and skips
    st_g, ok, _, rs = step(st_g, [(dynamic.REM_VERTEX, 20, 0)], cfg_g)
    assert ok == [True]
    assert int(rs.tier) == dynamic.TIER_SKIP

    # and the ungated config reports a real tier on the very same history
    st_u = booted(cfg_u)
    st_u, _, _, rs_u = step(st_u, ring, cfg_u)
    st_u, _, _, rs_u = step(st_u, ring, cfg_u)  # re-add: empty region...
    assert int(rs_u.tier) != dynamic.TIER_SKIP  # ...but a tier still ran


# -------------------------------------------------------- scan engine -----


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 5), OPS_STRATEGY)
def test_scan_matches_sequential_steps(k, op_list):
    """apply_batch_scan over K stacked chunks == K sequential steps:
    final state, stacked ok/overflow/RepairStats, generation."""
    cfg, _ = cfg_pair(edge_capacity=64, max_probes=4)  # overflow-prone
    b = 6
    flat = (op_list * ((k * b) // len(op_list) + 1))[:k * b]
    kk = np.asarray([[o[0] for o in flat[r * b:(r + 1) * b]]
                     for r in range(k)], np.int32)
    uu = np.asarray([[o[1] for o in flat[r * b:(r + 1) * b]]
                     for r in range(k)], np.int32)
    vv = np.asarray([[o[2] for o in flat[r * b:(r + 1) * b]]
                     for r in range(k)], np.int32)
    state0 = booted(cfg)
    st_scan, ok_s, ovf_s, r_s = dynamic.apply_batch_scan(
        state0, dynamic.make_ops(kk, uu, vv), cfg)
    st_seq = state0
    oks, ovfs, tiers, rvs = [], [], [], []
    for r in range(k):
        st_seq, ok1, ovf1, r1 = dynamic.apply_batch_async(
            st_seq, dynamic.make_ops(kk[r], uu[r], vv[r]), cfg)
        oks.append(np.asarray(ok1))
        ovfs.append(int(ovf1))
        tiers.append(int(r1.tier))
        rvs.append(int(r1.region_vertices))
    assert np.asarray(st_scan.ccid).tolist() == \
        np.asarray(st_seq.ccid).tolist()
    assert np.asarray(ok_s).tolist() == np.stack(oks).tolist()
    assert np.asarray(ovf_s).tolist() == ovfs
    assert np.asarray(r_s.tier).tolist() == tiers
    assert np.asarray(r_s.region_vertices).tolist() == rvs
    assert int(st_scan.gen) == int(st_seq.gen) == k
    assert int(st_scan.overflow) == int(st_seq.overflow)


def test_super_chunks_cover_plan_with_registry_lengths():
    """super_chunks == chunks, re-grouped: same slices in order, stacked
    rows identical to the padded per-chunk batches, group sizes from the
    scan-length registry, one bucket shape per group."""
    sched = BucketedScheduler((8, 32))
    rng = np.random.default_rng(3)
    for n in (1, 7, 8, 40, 96, 131, 256 + 8 * 5 + 3):
        kind = rng.integers(0, 4, n).astype(np.int32)
        u = rng.integers(0, NV, n).astype(np.int32)
        v = rng.integers(0, NV, n).astype(np.int32)
        flat = list(sched.chunks(kind, u, v))
        grouped = list(sched.super_chunks(kind, u, v, (1, 4)))
        assert [sl for sls, _ in grouped for sl in sls] == \
            [sl for sl, _ in flat]
        got_rows = [row for _, ops in grouped
                    for row in np.asarray(ops.kind)]
        want_rows = [np.asarray(ops.kind) for _, ops in flat]
        assert len(got_rows) == len(want_rows)
        assert all(np.array_equal(g, w)
                   for g, w in zip(got_rows, want_rows))
        plan_by_slice = {(sl.start, sl.stop): b
                         for sl, b in sched.plan(n)}
        for sls, ops in grouped:
            assert len(sls) in (1, 4)  # registry lengths only
            assert ops.kind.shape[0] == len(sls)
            for sl in sls:  # every stacked row keeps its plan bucket
                assert ops.kind.shape[1] == plan_by_slice[(sl.start,
                                                           sl.stop)]


def oracle_replay(oracle, sched, kind, u, v):
    want = np.zeros(len(kind), bool)
    for sl, _ in sched.plan(len(kind)):
        order = sorted(range(sl.start, sl.stop),
                       key=lambda i: (PHASE[int(kind[i])], i))
        for i in order:
            k, uu, vv = int(kind[i]), int(u[i]), int(v[i])
            if k == dynamic.ADD_EDGE:
                want[i] = oracle.add_edge(uu, vv)
            elif k == dynamic.REM_EDGE:
                want[i] = oracle.remove_edge(uu, vv)
            elif k == dynamic.ADD_VERTEX:
                want[i] = oracle.add_vertex(uu)
            else:
                want[i] = oracle.remove_vertex(uu)
    return want


def test_service_scan_path_matches_serial_and_oracle():
    """Random overflowing mixed streams through the scanned pipeline, the
    serial path, and a proactively-growing service: identical per-op
    results, labels, edge sets, and generations; the oracle agrees."""
    def tiny():
        return gs.GraphConfig(n_vertices=NV, edge_capacity=32,
                              max_probes=4, max_outer=NV + 1,
                              max_inner=NV + 2)
    scan = SCCService(tiny(), buckets=(8, 16), scan_lengths=(1, 2, 4))
    serial = SCCService(tiny(), buckets=(8, 16), inflight_window=0)
    pro = SCCService(tiny(), buckets=(8, 16), scan_lengths=(1, 2, 4),
                     proactive_grow=True)
    oracle = SeqSCC(NV)
    for svc in (scan, serial, pro):
        assert svc._apply_chunk([dynamic.ADD_VERTEX] * NV, list(range(NV)),
                         [0] * NV).all()
    for i in range(NV):
        assert oracle.add_vertex(i)
    rng = np.random.default_rng(17)
    for _ in range(16):
        n = int(rng.integers(1, 64))
        is_add = rng.random(n) < 0.7
        is_vertex = rng.random(n) < 0.1
        kind = np.where(is_add,
                        np.where(is_vertex, dynamic.ADD_VERTEX,
                                 dynamic.ADD_EDGE),
                        np.where(is_vertex, dynamic.REM_VERTEX,
                                 dynamic.REM_EDGE))
        u = rng.integers(0, NV, n)
        v = rng.integers(0, NV, n)
        ok = scan._apply_chunk(kind, u, v)
        assert ok.tolist() == serial._apply_chunk(kind, u, v).tolist() \
            == pro._apply_chunk(kind, u, v).tolist()
        assert ok.tolist() == oracle_replay(oracle, scan._sched,
                                            kind, u, v).tolist()
        assert np.asarray(scan.state.ccid).tolist() == \
            np.asarray(serial.state.ccid).tolist() == \
            np.asarray(pro.state.ccid).tolist() == oracle.ccid()
        assert scan.edge_set() == serial.edge_set() == pro.edge_set() \
            == oracle.edges
        assert scan.gen == serial.gen
    # the stream exercised what it was built to exercise
    assert scan.scanned_chunks > 0 and scan.scan_dispatches > 0
    assert scan.fallback_chunks > 0  # tiny table: overflow replays ran
    assert scan.grow_count == serial.grow_count > 0


def test_overflow_replays_only_from_offending_super_chunk():
    """A chunk whose overflow sits in its SECOND super-chunk keeps the
    first super-chunk's fast-path work: results match the serial path
    bit-exactly and the resolved-clean prefix still counts as scanned."""
    def tiny():
        return gs.GraphConfig(n_vertices=NV, edge_capacity=32,
                              max_probes=64, max_outer=NV + 1,
                              max_inner=NV + 2)
    svc = SCCService(tiny(), buckets=(4,), scan_lengths=(1, 2))
    serial = SCCService(tiny(), buckets=(4,), inflight_window=0)
    for s in (svc, serial):
        assert s._apply_chunk([dynamic.ADD_VERTEX] * NV, list(range(NV)),
                       [0] * NV).all()
    # near-fill the 32-slot table (28 edges fit), then send a 16-op chunk:
    # plan [4, 4, 4, 4] -> super-chunks [2, 2].  Its first 8 ops duplicate
    # existing edges (benign), its last 8 add distinct NEW edges that
    # cannot fit (28 + 8 > 32) -- the overflow lands in the second
    # super-chunk, so the first one's fast-path work must survive.
    pairs = [(a, b) for a in range(NV) for b in range(NV) if a != b]
    fill = pairs[:28]
    ok_fill = svc._apply_chunk([dynamic.ADD_EDGE] * 28,
                        [p[0] for p in fill], [p[1] for p in fill])
    assert ok_fill.tolist() == serial._apply_chunk(
        [dynamic.ADD_EDGE] * 28, [p[0] for p in fill],
        [p[1] for p in fill]).tolist()
    assert svc.grow_count == 0, "fill phase was not supposed to overflow"
    kind = np.full(16, dynamic.ADD_EDGE, np.int32)
    u = np.asarray([p[0] for p in pairs[:8] + pairs[100:108]], np.int32)
    v = np.asarray([p[1] for p in pairs[:8] + pairs[100:108]], np.int32)
    before = svc.scanned_chunks
    ok = svc._apply_chunk(kind, u, v)
    assert ok.tolist() == serial._apply_chunk(kind, u, v).tolist()
    assert np.asarray(svc.state.ccid).tolist() == \
        np.asarray(serial.state.ccid).tolist()
    assert svc.edge_set() == serial.edge_set()
    assert svc.gen == serial.gen
    assert svc.fallback_chunks >= 1 and svc.grow_count >= 1
    # the clean first super-chunk was resolved (counted) before the
    # offending second one aborted the fast path
    assert svc.scanned_chunks == before + 2


def test_donated_abort_does_not_double_count_telemetry():
    """When a donating pipeline aborts (anchor state consumed, whole
    chunk restarts serially), the discarded fast-path prefix must not
    leave its repair/scanned telemetry behind: step counts must equal
    the serially-recorded work, exactly once per applied step."""
    import warnings

    def tiny():
        return gs.GraphConfig(n_vertices=NV, edge_capacity=32,
                              max_probes=64, max_outer=NV + 1,
                              max_inner=NV + 2)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # CPU ignores donation, warns
        donated = SCCService(tiny(), buckets=(4,), scan_lengths=(1, 2),
                             donate=True)
        serial = SCCService(tiny(), buckets=(4,), inflight_window=0)
        boot_n = 8
        pairs = [(a, b) for a in range(boot_n) for b in range(boot_n)
                 if a != b]
        fill = pairs[:28]
        extra = pairs[28:36]
        # the third chunk is 16 ops -> [2, 2] super-chunks with the
        # overflow in the SECOND one: the donated fast path's anchor was
        # consumed, so the whole chunk restarts serially -- the discarded
        # clean prefix's telemetry must not be recorded on top
        streams = [
            ([dynamic.ADD_VERTEX] * boot_n, list(range(boot_n)),
             [0] * boot_n),
            ([dynamic.ADD_EDGE] * 28, [p[0] for p in fill],
             [p[1] for p in fill]),
            ([dynamic.ADD_EDGE] * 16,
             [p[0] for p in fill[:8] + extra],
             [p[1] for p in fill[:8] + extra]),
        ]
        for kind, uu, vv in streams:
            assert donated._apply_chunk(kind, uu, vv).tolist() == \
                serial._apply_chunk(kind, uu, vv).tolist()
        assert donated.fallback_chunks >= 1
        # both services executed the identical step history after the
        # restart, so per-tier step counts must agree exactly -- the
        # aborted prefix contributes nothing
        assert donated.repair_tier_steps == serial.repair_tier_steps
        assert donated.repair_region_v_max == serial.repair_region_v_max


def test_scan_and_gate_telemetry_reach_client_stats():
    """repair_skipped_steps / scanned_chunks / scan_dispatches flow
    SCCService.stats() -> GraphClient.stats()."""
    from repro.api import AddEdge, GraphClient

    cfg = gs.GraphConfig(n_vertices=NV, edge_capacity=256, max_probes=64,
                         max_outer=NV + 1, max_inner=NV + 2)
    svc = SCCService(cfg, buckets=(8,), scan_lengths=(1, 4),
                     state=gs.all_singletons(cfg))
    client = GraphClient(svc)
    ring = [AddEdge(i, (i + 1) % 6) for i in range(6)]
    client.submit_many(ring)
    # 32 structure-preserving ops -> four 8-lane chunks -> one scan(4)
    client.submit_many((ring + ring[:2]) * 4)
    s = client.stats()
    assert s["repair_skipped_steps"] > 0
    assert s["scanned_chunks"] >= 4
    assert s["scan_dispatches"] >= 1
    assert s["fallback_chunks"] == 0
    client.close()


def test_compile_count_bounded_by_buckets_times_scan_lengths():
    """Arbitrary chunk lengths never mint step shapes beyond
    buckets x (scan lengths + serial path) per graph config."""
    cfg = gs.GraphConfig(n_vertices=NV, edge_capacity=512, max_probes=64,
                         max_outer=NV + 1, max_inner=NV + 2)
    svc = SCCService(cfg, buckets=(8, 16), scan_lengths=(1, 4),
                     state=gs.all_singletons(cfg))
    rng = np.random.default_rng(5)
    for n in (3, 8, 24, 64, 80, 31, 128, 11):
        kind = rng.choice([dynamic.ADD_EDGE] * 2 + [dynamic.REM_EDGE],
                          int(n))
        svc._apply_chunk(kind, rng.integers(0, NV, n), rng.integers(0, NV, n))
    assert svc.grow_count == 0  # capacity was generous
    bound = 2 * (2 + 1)  # buckets x (scan lengths + serial)
    assert svc.compile_count <= bound
    assert any(key[0] == "scan" for key in svc._compiled)


# ----------------------------------- sparse-kernel impl A/B (PR 7) --------


def test_service_bit_identical_across_sparse_impls():
    """The whole PR-5 scanned pipeline re-run under the Pallas sparse
    kernels (interpret mode on CPU; the same dataflow the native TPU
    impl compiles) against the XLA oracle impl on one op stream: per-op
    acks, labels, generations, edge sets, and per-tier repair step
    counts must be bit-identical.  The tiny edge table forces grow /
    rehash under the kernel impl too."""
    def mk(impl):
        cfg = gs.GraphConfig(n_vertices=NV, edge_capacity=32,
                             max_probes=4, max_outer=NV + 1,
                             max_inner=NV + 2, sparse_impl=impl)
        return SCCService(cfg, buckets=(8,), scan_lengths=(1, 2))

    pal, xla = mk("pallas_interpret"), mk("xla")
    assert pal.stats()["kernel_impl"]["frontier_expand"] \
        == "pallas_interpret"
    assert xla.stats()["kernel_impl"]["hash_probe"] == "xla"

    rng = np.random.default_rng(41)
    for s in (pal, xla):
        assert s._apply_chunk([dynamic.ADD_VERTEX] * NV, list(range(NV)),
                       [0] * NV).all()
    for step_no in range(6):
        n = int(rng.integers(4, 17))
        is_add = rng.random(n) < 0.75
        kind = np.where(is_add, dynamic.ADD_EDGE,
                        dynamic.REM_EDGE).astype(np.int32)
        u = rng.integers(0, NV, n)
        v = rng.integers(0, NV, n)
        ok_p = pal._apply_chunk(kind, u, v)
        ok_x = xla._apply_chunk(kind, u, v)
        assert ok_p.tolist() == ok_x.tolist(), step_no
        assert np.asarray(pal.state.ccid).tolist() == \
            np.asarray(xla.state.ccid).tolist(), step_no
        assert int(pal.state.n_ccs) == int(xla.state.n_ccs)
        assert pal.gen == xla.gen
    assert pal.edge_set() == xla.edge_set()
    assert pal.repair_tier_steps == xla.repair_tier_steps
    assert pal.grow_count == xla.grow_count > 0  # rehash ran under both
    # batched reachability queries agree under both impls
    qu, qv = [0, 3, 7, 22], [5, 3, 19, 1]
    assert pal.reachable(qu, qv).value.tolist() == \
        xla.reachable(qu, qv).value.tolist()


# --------------------------------------------- bulk expiry (ROADMAP 5c) ---


def test_bulk_expiry_sliding_window_matches_oracle_and_gates():
    """Sliding-window maintenance: every step inserts a fresh edge batch
    and bulk-expires the batch from W steps ago as ONE REM_EDGE chunk.
    The engine agrees with the sequential oracle throughout (acks,
    labels, edge set), and the repair gate's deletion predicate earns
    its keep on the expiry chunks specifically: expiries that only
    drop absent or intra-SCC-redundant edges skip repair (TIER_SKIP),
    expiries that break a cycle run a real tier."""
    from collections import deque

    cfg_g, _ = cfg_pair()
    svc = SCCService(cfg_g, buckets=(8,), proactive_grow=True,
                     state=gs.all_singletons(cfg_g))
    oracle = SeqSCC(NV)
    for i in range(NV):
        assert oracle.add_vertex(i)

    rng = np.random.default_rng(29)
    window, expiry_tiers = deque(), []
    for step_no in range(16):
        u = rng.integers(0, NV, 8).astype(np.int32)
        v = rng.integers(0, NV, 8).astype(np.int32)
        kind = np.full(8, dynamic.ADD_EDGE, np.int32)
        ok = svc._apply_chunk(kind, u, v)
        assert ok.tolist() == oracle_replay(oracle, svc._sched,
                                            kind, u, v).tolist(), step_no
        window.append((u, v))
        if len(window) > 3:  # the window slides: evict the oldest batch
            eu, ev = window.popleft()
            kind = np.full(8, dynamic.REM_EDGE, np.int32)
            before = dict(svc.repair_tier_steps)
            ok = svc._apply_chunk(kind, eu, ev)
            assert ok.tolist() == oracle_replay(
                oracle, svc._sched, kind, eu, ev).tolist(), step_no
            expiry_tiers.append(
                {k: svc.repair_tier_steps[k] - before[k] for k in before})
        assert np.asarray(svc.state.ccid).tolist() == oracle.ccid(), step_no
        assert svc.edge_set() == oracle.edges, step_no

    skipped = sum(d["skipped"] for d in expiry_tiers)
    real = sum(d[k] for d in expiry_tiers
               for k in ("dense", "compact", "full"))
    assert skipped > 0, "no expiry chunk was proved structure-preserving"
    assert real > 0, "no expiry chunk ran a real repair tier"
