"""SMSCC dynamic engine vs the sequential oracle (python Tarjan per op).

Covers: per-op return contracts (paper Algs 15/16/18/20), partition
correctness after arbitrary mixed batches, batch-atomicity (batched result
== sequential application in lane order), incremental merge (Fig 2) and
decremental split (Fig 3) scenarios, and the dense repair path.
"""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 env has no hypothesis: seeded shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import baselines, community, dynamic, graph_state as gs
from oracle import SeqSCC

NV = 16
CFG = gs.GraphConfig(n_vertices=NV, edge_capacity=256, max_probes=256,
                     max_outer=NV + 1, max_inner=NV + 2)
CFG_DENSE = gs.GraphConfig(n_vertices=NV, edge_capacity=256, max_probes=256,
                           max_outer=NV + 1, max_inner=NV + 2,
                           dense_capacity=NV)


def fresh(n_alive=NV, cfg=CFG):
    st_ = gs.empty(cfg)
    ops = dynamic.make_ops([dynamic.ADD_VERTEX] * n_alive,
                           list(range(n_alive)), [0] * n_alive)
    st_, ok = dynamic.apply_batch(st_, ops, cfg)
    assert np.asarray(ok).all()
    return st_


def labels(state):
    return np.asarray(state.ccid).tolist()


def apply_ops(state, ops_list, cfg=CFG, mode="batch"):
    ops = dynamic.make_ops([k for k, _, _ in ops_list],
                           [u for _, u, _ in ops_list],
                           [v for _, _, v in ops_list])
    if mode == "batch":
        return dynamic.apply_batch(state, ops, cfg)
    if mode == "seq":
        return baselines.sequential_apply(state, ops, cfg)
    if mode == "coarse":
        return baselines.coarse_apply(state, ops, cfg)
    raise ValueError(mode)


def test_add_vertex_contract():
    st_ = gs.empty(CFG)
    ops = [(dynamic.ADD_VERTEX, 3, 0), (dynamic.ADD_VERTEX, 3, 0),
           (dynamic.ADD_VERTEX, 5, 0)]
    st_, ok = apply_ops(st_, ops)
    assert np.asarray(ok).tolist() == [True, False, True]
    assert labels(st_)[3] == 3 and labels(st_)[5] == 5
    assert int(st_.n_ccs) == 2


def test_paper_fig2_incremental_merge():
    """AddEdge(8,3) analogue: back edge merges three chained SCCs."""
    st_ = fresh(6)
    base = [(dynamic.ADD_EDGE, u, v) for u, v in
            [(0, 1), (1, 2), (2, 0), (3, 4), (4, 3), (2, 3), (4, 5)]]
    st_, ok = apply_ops(st_, base)
    assert np.asarray(ok).all()
    assert labels(st_)[:6] == [0, 0, 0, 3, 3, 5]
    assert int(st_.n_ccs) == 3
    # the merging back edge
    st_, ok = apply_ops(st_, [(dynamic.ADD_EDGE, 5, 0)])
    assert np.asarray(ok).all()
    assert labels(st_)[:6] == [0] * 6
    assert int(st_.n_ccs) == 1


def test_paper_fig3_decremental_split():
    """RemoveEdge(8,7) analogue: one SCC breaks into two."""
    st_ = fresh(6)
    ring = [(dynamic.ADD_EDGE, u, v) for u, v in
            [(0, 1), (1, 2), (2, 3), (3, 0), (2, 0), (3, 2)]]
    st_, _ = apply_ops(st_, ring)
    assert labels(st_)[:4] == [0, 0, 0, 0]
    st_, ok = apply_ops(st_, [(dynamic.REM_EDGE, 0, 1)])
    assert bool(np.asarray(ok)[0])
    lab = labels(st_)
    # {2,3} stay strongly connected; 0 and 1 fall out
    assert lab[2] == lab[3] and lab[0] != lab[2] and lab[1] != lab[2]
    assert lab[0] != lab[1]


def test_remove_vertex_trims_edges():
    st_ = fresh(5)
    st_, _ = apply_ops(st_, [(dynamic.ADD_EDGE, u, v) for u, v in
                             [(0, 1), (1, 2), (2, 0), (2, 3), (3, 2)]])
    assert labels(st_)[:4] == [0, 0, 0, 0]
    st_, ok = apply_ops(st_, [(dynamic.REM_VERTEX, 2, 0)])
    assert bool(np.asarray(ok)[0])
    lab = labels(st_)
    assert lab[2] == NV  # dead sentinel
    assert len({lab[0], lab[1], lab[3]}) == 3  # all split
    # edges through 2 are gone: re-adding 2 restores nothing by itself
    st_, ok = apply_ops(st_, [(dynamic.ADD_VERTEX, 2, 0)])
    assert bool(np.asarray(ok)[0]) and labels(st_)[2] == 2
    assert not bool(community.check_scc(
        st_, jnp.array([0]), jnp.array([1]))[0])


def test_edge_contracts():
    st_ = fresh(3)
    ops = [(dynamic.ADD_EDGE, 0, 1),   # ok
           (dynamic.ADD_EDGE, 0, 1),   # dup in batch -> False
           (dynamic.ADD_EDGE, 0, 9),   # 9 dead -> False
           (dynamic.REM_EDGE, 1, 0)]   # absent -> False
    st_, ok = apply_ops(st_, ops)
    assert np.asarray(ok).tolist() == [True, False, False, False]
    st_, ok = apply_ops(st_, [(dynamic.REM_EDGE, 0, 1),
                              (dynamic.ADD_EDGE, 0, 1)])
    # linearization: removals before insertions -> both succeed
    assert np.asarray(ok).tolist() == [True, True]


OPS_STRATEGY = st.lists(
    st.tuples(st.integers(0, 3), st.integers(0, NV - 1),
              st.integers(0, NV - 1)),
    min_size=1, max_size=24)


@settings(max_examples=25, deadline=None)
@given(OPS_STRATEGY, st.integers(2, NV))
def test_random_history_vs_oracle(op_list, n0):
    """Sequential (B=1) application == python oracle, op by op."""
    st_ = fresh(n0)
    oracle = SeqSCC(NV)
    for i in range(n0):
        oracle.add_vertex(i)
    for kind, u, v in op_list:
        st_, ok = apply_ops(st_, [(kind, u, v)])
        if kind == dynamic.ADD_EDGE:
            want = oracle.add_edge(u, v)
        elif kind == dynamic.REM_EDGE:
            want = oracle.remove_edge(u, v)
        elif kind == dynamic.ADD_VERTEX:
            want = oracle.add_vertex(u)
        else:
            want = oracle.remove_vertex(u)
        assert bool(np.asarray(ok)[0]) == want, (kind, u, v)
        assert labels(st_) == oracle.ccid(), (kind, u, v)


@settings(max_examples=20, deadline=None)
@given(OPS_STRATEGY)
def test_batch_atomicity(op_list):
    """One batched step == the phase-ordered sequential history.

    The documented linearization: REM_VERTEX -> REM_EDGE -> ADD_VERTEX ->
    ADD_EDGE, lane order within a phase.
    """
    st_b = fresh(NV)
    st_s = fresh(NV)
    st_b, ok_b = apply_ops(st_b, op_list, mode="batch")
    phase_order = sorted(
        range(len(op_list)),
        key=lambda i: ({dynamic.REM_VERTEX: 0, dynamic.REM_EDGE: 1,
                        dynamic.ADD_VERTEX: 2, dynamic.ADD_EDGE: 3}
                       [op_list[i][0]], i))
    seq_ops = [op_list[i] for i in phase_order]
    st_s, ok_s = apply_ops(st_s, seq_ops, mode="seq")
    # same final partition
    assert labels(st_b) == labels(st_s)
    # same per-op results (reordered)
    got = np.asarray(ok_b)[phase_order].tolist()
    assert got == np.asarray(ok_s).tolist()


@settings(max_examples=10, deadline=None)
@given(OPS_STRATEGY)
def test_coarse_equals_batch_partition(op_list):
    """Coarse-grained baseline reaches the same partition sequentially."""
    st_1 = fresh(NV)
    st_2 = fresh(NV)
    st_1, _ = apply_ops(st_1, op_list, mode="seq")
    st_2, _ = apply_ops(st_2, op_list, mode="coarse")
    assert labels(st_1) == labels(st_2)


@settings(max_examples=15, deadline=None)
@given(OPS_STRATEGY)
def test_dense_path_matches_sparse(op_list):
    st_1 = fresh(NV, CFG)
    st_2 = fresh(NV, CFG_DENSE)
    st_1, ok1 = apply_ops(st_1, op_list, cfg=CFG, mode="batch")
    st_2, ok2 = apply_ops(st_2, op_list, cfg=CFG_DENSE, mode="batch")
    assert labels(st_1) == labels(st_2)
    assert np.asarray(ok1).tolist() == np.asarray(ok2).tolist()


def test_community_queries():
    st_ = fresh(6)
    st_, _ = apply_ops(st_, [(dynamic.ADD_EDGE, u, v) for u, v in
                             [(0, 1), (1, 0), (2, 3), (3, 2), (1, 2)]])
    same = community.check_scc(st_, jnp.array([0, 0, 2, 0]),
                               jnp.array([1, 2, 3, 9]))
    assert np.asarray(same).tolist() == [True, False, True, False]
    lab = community.belongs_to_community(st_, jnp.array([0, 1, 2, 3, 9]))
    assert np.asarray(lab).tolist() == [0, 0, 2, 2, NV]
    sizes = community.community_sizes(st_)
    assert int(sizes[0]) == 2 and int(sizes[2]) == 2
    rep, size = community.largest_community(st_)
    assert int(size) == 2
    pairs = community.same_community_pairs(st_, jnp.array([0, 1, 2]))
    assert np.asarray(pairs).tolist() == [[True, True, False],
                                          [True, True, False],
                                          [False, False, True]]


def test_generation_counter_and_counts():
    st_ = fresh(4)
    g0 = int(st_.gen)
    st_, _ = apply_ops(st_, [(dynamic.ADD_EDGE, 0, 1),
                             (dynamic.ADD_EDGE, 1, 0)])
    assert int(st_.gen) == g0 + 1
    assert int(st_.n_ccs) == 3  # {0,1}, {2}, {3}
    assert int(gs.live_edge_count(st_)) == 2
    assert int(gs.live_vertex_count(st_)) == 4


CFG_FUSED = gs.GraphConfig(n_vertices=NV, edge_capacity=256,
                           max_probes=256, max_outer=NV + 1,
                           max_inner=NV + 2, fuse_fwbw=True)


@settings(max_examples=15, deadline=None)
@given(OPS_STRATEGY)
def test_fused_fwbw_matches_baseline(op_list):
    """fuse_fwbw=True is a pure execution-schedule change: identical
    partitions and per-op results."""
    st_1 = fresh(NV, CFG)
    st_2 = fresh(NV, CFG_FUSED)
    st_1, ok1 = apply_ops(st_1, op_list, cfg=CFG, mode="batch")
    st_2, ok2 = apply_ops(st_2, op_list, cfg=CFG_FUSED, mode="batch")
    assert labels(st_1) == labels(st_2)
    assert np.asarray(ok1).tolist() == np.asarray(ok2).tolist()


CFG_FAST = gs.GraphConfig(n_vertices=NV, edge_capacity=256,
                          max_probes=256, max_outer=NV + 1,
                          max_inner=NV + 2, fuse_fwbw=True, shortcut=True)


@settings(max_examples=15, deadline=None)
@given(OPS_STRATEGY)
def test_shortcut_matches_baseline(op_list):
    """Pointer doubling changes rounds, never the fixpoint."""
    st_1 = fresh(NV, CFG)
    st_2 = fresh(NV, CFG_FAST)
    st_1, ok1 = apply_ops(st_1, op_list, cfg=CFG, mode="batch")
    st_2, ok2 = apply_ops(st_2, op_list, cfg=CFG_FAST, mode="batch")
    assert labels(st_1) == labels(st_2)
    assert np.asarray(ok1).tolist() == np.asarray(ok2).tolist()


def test_shortcut_reduces_rounds_on_chain():
    """A long label chain must converge in O(log n) rounds w/ doubling."""
    from repro.core import reach
    import jax.numpy as jnp
    n = 256
    src = jnp.arange(n - 1, dtype=jnp.int32)
    dst = jnp.arange(1, n, dtype=jnp.int32)
    live = jnp.ones((n - 1,), bool)
    allowed = jnp.ones((n,), bool)
    labels0 = jnp.arange(n, dtype=jnp.int32)
    _, r_plain = reach.propagate_min_labels(src, dst, live, labels0,
                                            allowed, n + 1)
    out, r_fast = reach.propagate_min_labels(src, dst, live, labels0,
                                             allowed, n + 1, shortcut=True)
    assert np.asarray(out).tolist() == [0] * n
    assert int(r_plain) >= n - 1
    assert int(r_fast) <= 12  # ~log2(256) + epsilon
