"""Pure-python reference implementations used as test oracles.

``tarjan_ccid`` returns the canonical labelling our engine uses: every
vertex is labelled with the minimum vertex id of its SCC; absent vertices
get the sentinel ``n_vertices``.  Iterative Tarjan (no recursion limit).
"""
from __future__ import annotations

from collections import defaultdict


def tarjan_ccid(n_vertices: int, edges, alive=None):
    """edges: iterable of (u, v); alive: optional bool mask/list."""
    if alive is None:
        alive = [True] * n_vertices
    adj = defaultdict(list)
    for u, v in edges:
        if alive[u] and alive[v]:
            adj[u].append(v)

    index = {}
    low = {}
    on_stack = set()
    stack = []
    sccs = []
    counter = [0]

    for root in range(n_vertices):
        if not alive[root] or root in index:
            continue
        # iterative DFS: (node, iterator position)
        work = [(root, 0)]
        while work:
            v, pi = work.pop()
            if pi == 0:
                index[v] = low[v] = counter[0]
                counter[0] += 1
                stack.append(v)
                on_stack.add(v)
            recurse = False
            nbrs = adj[v]
            for i in range(pi, len(nbrs)):
                w = nbrs[i]
                if w not in index:
                    work.append((v, i + 1))
                    work.append((w, 0))
                    recurse = True
                    break
                elif w in on_stack:
                    low[v] = min(low[v], index[w])
            if recurse:
                continue
            if low[v] == index[v]:
                comp = []
                while True:
                    w = stack.pop()
                    on_stack.discard(w)
                    comp.append(w)
                    if w == v:
                        break
                sccs.append(comp)
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[v])

    ccid = [n_vertices] * n_vertices
    for comp in sccs:
        m = min(comp)
        for v in comp:
            ccid[v] = m
    return ccid


class SeqSCC:
    """Sequential fully-dynamic oracle: python set-of-edges + Tarjan after
    every op.  Mirrors the paper's method contracts exactly."""

    def __init__(self, n_vertices: int):
        self.n = n_vertices
        self.alive = [False] * n_vertices
        self.edges = set()

    def add_vertex(self, u):
        if not (0 <= u < self.n) or self.alive[u]:
            return False
        self.alive[u] = True
        return True

    def remove_vertex(self, u):
        if not (0 <= u < self.n) or not self.alive[u]:
            return False
        self.alive[u] = False
        self.edges = {(a, b) for (a, b) in self.edges
                      if a != u and b != u}
        return True

    def add_edge(self, u, v):
        if not (0 <= u < self.n and 0 <= v < self.n):
            return False
        if not (self.alive[u] and self.alive[v]):
            return False
        if (u, v) in self.edges:
            return False
        self.edges.add((u, v))
        return True

    def remove_edge(self, u, v):
        if not (0 <= u < self.n and 0 <= v < self.n):
            return False
        if not (self.alive[u] and self.alive[v]):
            return False
        if (u, v) not in self.edges:
            return False
        self.edges.discard((u, v))
        return True

    def ccid(self):
        return tarjan_ccid(self.n, self.edges, self.alive)

    def check_scc(self, u, v):
        lab = self.ccid()
        return (self.alive[u] and self.alive[v] and lab[u] == lab[v])
