"""Minimal, dependency-free stand-in for the `hypothesis` API we use.

The tier-1 suite must collect and run in environments without hypothesis
(the container does not ship it).  Test modules import via:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, strategies as st

Semantics: `@given(...)` runs the test body `max_examples` times with
inputs drawn from seeded `random.Random` streams -- deterministic per test
(seed derives from the test's qualified name), no shrinking, no database.
`@settings(max_examples=N, deadline=...)` adjusts the example count and is
otherwise a no-op.  Only the strategy combinators used by this repo are
implemented: integers, booleans, lists, tuples, sets, sampled_from, just,
composite.

Set HC_MAX_EXAMPLES=<n> to cap the example count globally (CI knob).
"""
from __future__ import annotations

import os
import random
import types
import zlib

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    """A strategy is just a draw function: Random -> value."""

    def __init__(self, draw):
        self._draw = draw

    def example(self, rnd: random.Random):
        return self._draw(rnd)

    def map(self, f):
        return _Strategy(lambda rnd: f(self._draw(rnd)))

    def filter(self, pred, max_tries: int = 100):
        def draw(rnd):
            for _ in range(max_tries):
                x = self._draw(rnd)
                if pred(x):
                    return x
            raise ValueError("filter predicate too strict")
        return _Strategy(draw)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rnd: rnd.randint(min_value, max_value))


def booleans() -> _Strategy:
    return _Strategy(lambda rnd: bool(rnd.getrandbits(1)))


def just(value) -> _Strategy:
    return _Strategy(lambda rnd: value)


def sampled_from(seq) -> _Strategy:
    seq = list(seq)
    return _Strategy(lambda rnd: seq[rnd.randrange(len(seq))])


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10
          ) -> _Strategy:
    def draw(rnd):
        n = rnd.randint(min_size, max_size)
        return [elements.example(rnd) for _ in range(n)]
    return _Strategy(draw)


def tuples(*elements: _Strategy) -> _Strategy:
    return _Strategy(lambda rnd: tuple(e.example(rnd) for e in elements))


def sets(elements: _Strategy, min_size: int = 0, max_size: int = 10
         ) -> _Strategy:
    def draw(rnd):
        n = rnd.randint(min_size, max_size)
        out = set()
        # bounded attempts so tight element domains cannot loop forever
        for _ in range(max(50, 20 * (n + 1))):
            if len(out) >= n:
                break
            out.add(elements.example(rnd))
        if len(out) < min_size:
            raise ValueError("set strategy: element domain too small")
        return out
    return _Strategy(draw)


def composite(fn):
    """@st.composite: fn(draw, *args) -> value, called with a draw handle."""
    def make(*args, **kwargs):
        def draw_value(rnd):
            return fn(lambda strat: strat.example(rnd), *args, **kwargs)
        return _Strategy(draw_value)
    return make


def _example_cap(n: int) -> int:
    cap = os.environ.get("HC_MAX_EXAMPLES")
    return min(n, int(cap)) if cap else n


def given(*strategies: _Strategy):
    def decorate(fn):
        def runner():
            n = _example_cap(getattr(runner, "_hc_max_examples",
                                     _DEFAULT_MAX_EXAMPLES))
            seed = zlib.adler32(fn.__qualname__.encode())
            for i in range(n):
                rnd = random.Random(seed * 1_000_003 + i)
                args = [s.example(rnd) for s in strategies]
                try:
                    fn(*args)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{i} for {fn.__name__}: "
                        f"args={args!r}") from e
        # NOTE: deliberately no functools.wraps -- pytest follows
        # __wrapped__ for signatures and would demand fixtures named
        # after the strategy parameters.
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        runner.__qualname__ = fn.__qualname__
        runner._hc_given = True
        if hasattr(fn, "_hc_max_examples"):  # @settings applied under @given
            runner._hc_max_examples = fn._hc_max_examples
        return runner
    return decorate


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def decorate(fn):
        if getattr(fn, "_hc_given", False):
            fn._hc_max_examples = max_examples
            return fn
        # settings applied under @given: stash the count on the raw
        # function; given() picks it up via attribute copy below.
        fn._hc_max_examples = max_examples
        return fn
    return decorate


# `strategies` submodule-style alias so `from _hypothesis_compat import
# strategies as st` mirrors the hypothesis import shape.
strategies = types.SimpleNamespace(
    integers=integers, booleans=booleans, lists=lists, tuples=tuples,
    sets=sets, sampled_from=sampled_from, just=just, composite=composite,
)
