"""The typed client API: GraphClient, op vocabulary, consistency levels.

Contracts pinned here (see ``docs/SERVICE_API.md``):

* **differential**: a mixed typed-op stream (all four update kinds, all
  query kinds including the broker-path community queries) driven through
  one READ_YOUR_WRITES client session matches the sequential python
  oracle op for op -- updates under the documented per-bucket phase
  linearization, every query at exactly the submission-point state;
* **stamps**: generation stamps returned to a single client are monotone
  in submission order and (property test) never below the session's
  read-your-writes token at submission;
* **consistency levels**: LATEST never blocks, AT_LEAST blocks until a
  covering commit exists (and is answered at ``gen >= floor``),
  READ_YOUR_WRITES floors reads at the last acked update;
* the op encoders are the only typed<->raw bridge and reject misuse.
"""
import collections
import threading
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, strategies as st

from repro.api import (AddEdge, AddVertex, AtLeast, CommunityOf,
                       CommunitySizes, Consistency, GraphClient, Reachable,
                       RemoveEdge, RemoveVertex, SameSCC, SccMembers,
                       UpdateOp, encode_updates, updates_from_arrays)
from repro.core import dynamic, graph_state as gs
from repro.core.broker import QueryBroker
from repro.core.service import SCCService
from oracle import SeqSCC

NV = 20
PHASE = {dynamic.REM_VERTEX: 0, dynamic.REM_EDGE: 1,
         dynamic.ADD_VERTEX: 2, dynamic.ADD_EDGE: 3}


def tiny_cfg(edge_capacity=64, max_probes=8, nv=NV):
    return gs.GraphConfig(n_vertices=nv, edge_capacity=edge_capacity,
                          max_probes=max_probes, max_outer=nv + 1,
                          max_inner=nv + 2)


def make_client(consistency=Consistency.LATEST, **svc_kw):
    svc = SCCService(tiny_cfg(), buckets=svc_kw.pop("buckets", (8, 16)),
                     **svc_kw)
    return GraphClient(svc, consistency=consistency)


def booted(client: GraphClient, oracle: SeqSCC | None = None):
    res = client.submit_many([AddVertex(i) for i in range(NV)])
    assert all(r.value for r in res)
    if oracle is not None:
        for i in range(NV):
            assert oracle.add_vertex(i)


def oracle_apply(oracle: SeqSCC, op: UpdateOp) -> bool:
    if isinstance(op, AddEdge):
        return oracle.add_edge(op.u, op.v)
    if isinstance(op, RemoveEdge):
        return oracle.remove_edge(op.u, op.v)
    if isinstance(op, AddVertex):
        return oracle.add_vertex(op.u)
    return oracle.remove_vertex(op.u)


def oracle_replay_run(oracle: SeqSCC, sched, run):
    """Oracle results for one update run under the client's per-bucket
    phase linearization (the contract test_service pins for raw chunks)."""
    want = [False] * len(run)
    for sl, _ in sched.plan(len(run)):
        order = sorted(range(sl.start, sl.stop),
                       key=lambda i: (PHASE[run[i].KIND], i))
        for i in order:
            want[i] = oracle_apply(oracle, run[i])
    return want


def oracle_reachable(oracle: SeqSCC, u, v) -> bool:
    if not (0 <= u < oracle.n and 0 <= v < oracle.n):
        return False
    if not (oracle.alive[u] and oracle.alive[v]):
        return False
    adj = collections.defaultdict(list)
    for a, b in oracle.edges:
        adj[a].append(b)
    seen, frontier = {u}, [u]
    while frontier:
        nxt = []
        for x in frontier:
            for y in adj[x]:
                if y not in seen:
                    seen.add(y)
                    nxt.append(y)
        frontier = nxt
    return v in seen


def oracle_query(oracle: SeqSCC, op) -> object:
    cc = oracle.ccid()

    def lab(x):
        return cc[x] if 0 <= x < oracle.n else oracle.n

    if isinstance(op, SameSCC):
        return lab(op.u) < oracle.n and lab(op.u) == lab(op.v)
    if isinstance(op, Reachable):
        return oracle_reachable(oracle, op.u, op.v)
    if isinstance(op, SccMembers):
        return [lab(op.u) < oracle.n and cc[w] == lab(op.u)
                for w in range(oracle.n)]
    if isinstance(op, CommunityOf):
        return lab(op.u)
    # CommunitySizes
    hist = [0] * oracle.n
    for w in range(oracle.n):
        if cc[w] < oracle.n:
            hist[cc[w]] += 1
    return hist


def mixed_typed_stream(rng, n):
    """Random mix of every op kind (updates biased to keep a live graph)."""
    out = []
    for _ in range(n):
        roll = rng.random()
        a = int(rng.integers(0, NV))
        b = int(rng.integers(0, NV))
        if roll < 0.35:
            out.append(AddEdge(a, b))
        elif roll < 0.45:
            out.append(RemoveEdge(a, b))
        elif roll < 0.50:
            out.append(AddVertex(a))
        elif roll < 0.55:
            out.append(RemoveVertex(a))
        elif roll < 0.75:
            out.append(SameSCC(a, b))
        elif roll < 0.85:
            out.append(Reachable(a, b))
        elif roll < 0.90:
            out.append(SccMembers(a))
        elif roll < 0.97:
            out.append(CommunityOf(a))
        else:
            out.append(CommunitySizes())
    return out


# ------------------------------------------------------- differential -----


def test_mixed_typed_stream_differential_vs_oracle():
    """The acceptance contract: every result of a mixed typed stream
    (READ_YOUR_WRITES session) equals the sequential oracle at the op's
    submission point; stamps are monotone and cover the session token."""
    client = make_client(consistency=Consistency.READ_YOUR_WRITES)
    oracle = SeqSCC(NV)
    booted(client, oracle)
    sched = client.service._sched
    rng = np.random.default_rng(42)
    last_gen = -1
    for step in range(10):
        ops = mixed_typed_stream(rng, int(rng.integers(4, 28)))
        token_before = client.token
        results = client.submit_many(ops)
        assert len(results) == len(ops)
        # walk results in submission order, replaying update runs through
        # the oracle at run boundaries (the client's own batching rule)
        i = 0
        while i < len(results):
            r = results[i]
            if isinstance(r.op, UpdateOp):
                j = i
                while j < len(results) and isinstance(results[j].op,
                                                      UpdateOp):
                    j += 1
                run = [results[k].op for k in range(i, j)]
                want = oracle_replay_run(oracle, sched, run)
                got = [results[k].value for k in range(i, j)]
                assert got == want, f"update run mismatch at step {step}"
                i = j
                continue
            want = oracle_query(oracle, r.op)
            got = r.value.tolist() if isinstance(r.value, np.ndarray) \
                else r.value
            assert got == want, f"{r.op} mismatch at step {step}"
            # READ_YOUR_WRITES: stamped at or after the session token
            assert r.gen >= token_before
            i += 1
        # stamps monotone in submission order; token tracks acked updates
        gens = [r.gen for r in results]
        assert gens == sorted(gens)
        assert last_gen <= gens[0]
        last_gen = gens[-1]
        assert client.token == client.service.gen
    # final state agrees wholesale
    assert np.asarray(client.service.state.ccid).tolist() == oracle.ccid()
    assert client.service.edge_set() == oracle.edges
    client.close()


# ------------------------------------------------------ property test -----


@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 9), st.integers(0, NV - 1),
                          st.integers(0, NV - 1)),
                min_size=1, max_size=40))
def test_gen_stamps_monotone_and_cover_ryw_token(raw):
    """Property: generation stamps returned to a single client session are
    monotone non-decreasing in submission order, and under
    READ_YOUR_WRITES no query is ever answered below the session token at
    its submission."""
    def to_op(code, a, b):
        return [AddEdge(a, b), AddEdge(a, b), RemoveEdge(a, b),
                AddVertex(a), RemoveVertex(a), SameSCC(a, b),
                Reachable(a, b), SccMembers(a), CommunityOf(a),
                CommunitySizes()][code]

    client = make_client(consistency=Consistency.READ_YOUR_WRITES)
    booted(client)
    stamps = []
    for code, a, b in raw:
        token = client.token
        res = client.submit(to_op(code, a, b)).result()
        stamps.append(res.gen)
        if not isinstance(res.op, UpdateOp):
            assert res.gen >= token, (res, token)
        else:
            assert client.token >= token
    assert stamps == sorted(stamps), stamps
    assert client.token <= client.service.gen
    client.close()


# -------------------------------------------------- consistency levels ----


def test_at_least_blocks_until_covering_commit():
    """AT_LEAST(g) with g beyond the committed line defers (gen-wait hook,
    visible in telemetry) and resolves only once a covering commit lands;
    AT_LEAST at or below the line never blocks."""
    svc = SCCService(tiny_cfg(), buckets=(8,))
    broker = QueryBroker(svc, buckets=(4,)).start()
    try:
        writer = GraphClient(svc, broker=broker)
        reader = GraphClient(svc, broker=broker)
        booted(writer)
        writer.submit_many([AddEdge(0, 1), AddEdge(1, 0)])
        g = svc.gen
        # at-or-below the committed line: answered promptly
        res = reader.submit(SameSCC(0, 1),
                            consistency=Consistency.AT_LEAST(g)).result(
                                timeout=5)
        assert res.value is True and res.gen >= g
        # beyond the line: must wait for the covering commit
        fut = reader.submit(SameSCC(0, 2),
                            consistency=Consistency.AT_LEAST(g + 1))
        time.sleep(0.15)
        assert not fut.done(), "AT_LEAST answered below its floor"
        writer.submit_many([AddEdge(1, 2), AddEdge(2, 0)])
        res = fut.result(timeout=5)
        assert res.gen >= g + 1
        assert res.value is True  # 0,1,2 now one SCC at the stamped gen
        assert broker.stats()["gen_waits"] > 0
    finally:
        broker.stop()


def test_at_least_inline_with_concurrent_writer():
    """Inline mode (no dispatcher): an AT_LEAST read parks on the
    service's commit condition until another session's write covers it."""
    svc = SCCService(tiny_cfg(), buckets=(8,))
    client = GraphClient(svc)
    booted(client)
    g = svc.gen

    def late_writer():
        time.sleep(0.15)
        w = GraphClient(svc)
        w.submit_many([AddEdge(0, 1)])
        w.close()

    t = threading.Thread(target=late_writer)
    t.start()
    res = client.submit(SameSCC(0, 1),
                        consistency=AtLeast(g + 1)).result(timeout=10)
    t.join()
    assert res.gen >= g + 1
    client.close()


def test_read_your_writes_token_advances_with_acks():
    client = make_client(consistency=Consistency.READ_YOUR_WRITES)
    booted(client)
    t0 = client.token
    assert t0 == client.service.gen  # seeded at the committed line
    res = client.submit_many([AddEdge(0, 1)])
    assert client.token == res[0].gen > t0
    q = client.submit(SameSCC(0, 1)).result()
    assert q.gen >= client.token
    client.close()


def test_stopped_broker_fails_uncoverable_floor():
    """stop() must not hang on a floor no commit will ever cover: the
    deferred request is failed instead."""
    svc = SCCService(tiny_cfg(), buckets=(8,))
    broker = QueryBroker(svc, buckets=(4,)).start()
    client = GraphClient(svc, broker=broker)
    booted(client)
    fut = client.submit(SameSCC(0, 1),
                        consistency=Consistency.AT_LEAST(svc.gen + 100))
    time.sleep(0.1)
    broker.stop()  # must return, not deadlock
    with pytest.raises(RuntimeError):
        fut.result(timeout=5)


# --------------------------------------------------- community queries ----


def test_community_queries_through_broker():
    """CommunityOf/CommunitySizes are broker kinds: coalesced, stamped,
    sentinel-correct -- and consistent with the (min-id) label contract."""
    svc = SCCService(tiny_cfg(), buckets=(8,))
    client = GraphClient(svc)
    booted(client)
    client.submit_many([AddEdge(0, 1), AddEdge(1, 2), AddEdge(2, 0),
                        AddEdge(3, 4), AddEdge(4, 3), RemoveVertex(5)])
    res = client.submit_many([CommunityOf(0), CommunityOf(1),
                              CommunityOf(3), CommunityOf(5),
                              CommunityOf(NV + 3), CommunitySizes()])
    labs, hist = [r.value for r in res[:-1]], res[-1].value
    assert labs[0] == labs[1] == 0       # min-id canonical label
    assert labs[2] == 3
    assert labs[3] == NV                 # dead vertex: sentinel
    assert labs[4] == NV                 # out-of-range: sentinel, no alias
    assert hist[0] == 3 and hist[3] == 2 and hist[5] == 0
    assert int(hist.sum()) == NV - 1     # one vertex removed
    assert len({r.gen for r in res}) == 1 == len({res[0].gen, svc.gen})
    # broker wrappers agree with the client path
    snap = client.broker.community_of([0, 5])
    assert snap.value.tolist() == [0, NV]
    assert client.broker.community_sizes().value.tolist() == hist.tolist()
    client.close()


# ----------------------------------------------------- vocabulary/misc ----


def test_encoders_roundtrip_and_reject_misuse():
    ops = [AddEdge(1, 2), RemoveEdge(2, 3), AddVertex(4), RemoveVertex(5)]
    kind, u, v = encode_updates(ops)
    assert kind.tolist() == [dynamic.ADD_EDGE, dynamic.REM_EDGE,
                             dynamic.ADD_VERTEX, dynamic.REM_VERTEX]
    assert u.tolist() == [1, 2, 4, 5]
    assert v.tolist() == [2, 3, 0, 0]
    assert updates_from_arrays(kind, u, v) == ops
    # NOP lanes (scheduler padding) decode away
    assert updates_from_arrays([dynamic.NOP], [0], [0]) == []
    with pytest.raises(TypeError):
        encode_updates([AddEdge(0, 1), SameSCC(0, 1)])
    client = make_client()
    with pytest.raises(TypeError):
        client.submit("add_edge")
    with pytest.raises(TypeError):
        client.submit_many([AddEdge(0, 1), "same_scc"])
    with pytest.raises(TypeError):  # unknown consistency level
        client.submit_many([SameSCC(0, 1)], consistency="latest")
    client.close()


def test_ops_are_frozen_values():
    op = AddEdge(1, 2)
    with pytest.raises(Exception):
        op.u = 9
    assert op == AddEdge(1, 2) and op != AddEdge(2, 1)
    assert SameSCC(1, 2) != Reachable(1, 2)


def test_client_stats_unify_service_and_broker():
    client = make_client()
    booted(client)
    client.submit_many([AddEdge(0, 1), SameSCC(0, 1)])
    s = client.stats()
    for key in ("gen", "pipelined_chunks", "fallback_chunks",
                "compile_count", "grows", "flushes", "served",
                "gen_waits", "coalescing", "client_updates",
                "client_queries", "ryw_token"):
        assert key in s, key
    assert s["client_updates"] == NV + 1
    assert s["client_queries"] == 1
    client.close()


def test_sessions_share_service_updates_serialize():
    """Two client sessions over one service: interleaved typed updates
    serialize on the service's update lock; both observe a single commit
    line (and the final state matches one sequential history)."""
    svc = SCCService(tiny_cfg(), buckets=(8,))
    a = GraphClient(svc)
    b = GraphClient(svc)
    booted(a)
    errors = []

    def worker(client, seed):
        try:
            rng = np.random.default_rng(seed)
            for _ in range(6):
                u, v = int(rng.integers(0, NV)), int(rng.integers(0, NV))
                client.submit_many([AddEdge(u, v), SameSCC(u, v)])
        except Exception as e:
            errors.append(e)

    ts = [threading.Thread(target=worker, args=(c, s))
          for c, s in ((a, 1), (b, 2))]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errors, errors[0]
    assert a.gen == b.gen == svc.gen
    # commit line is one total order: both sessions' tokens are covered
    assert max(a.token, b.token) <= svc.gen
    a.close()
    b.close()


def test_gen_continuity_across_checkpoint_restore(tmp_path):
    """The serving example's recovery contract in miniature: a checkpoint
    round-trips the generation counter, and a client session over the
    restored service resumes exactly at the recorded committed gen."""
    from repro.ckpt import checkpoint

    client = make_client()
    booted(client)
    client.submit_many([AddEdge(0, 1), AddEdge(1, 0), RemoveVertex(7)])
    svc = client.service
    saved_gen = svc.gen
    checkpoint.save(str(tmp_path), 1,
                    {"state": svc.state, "gen": np.int64(saved_gen)})
    tpl = {"state": gs.empty(svc.cfg), "gen": np.int64(0)}
    restored, _ = checkpoint.restore(str(tmp_path), tpl)
    svc2 = SCCService(svc.cfg, buckets=(8, 16), state=restored["state"])
    client2 = GraphClient(svc2, consistency=Consistency.READ_YOUR_WRITES)
    assert int(restored["gen"]) == saved_gen
    assert client2.gen == saved_gen == client2.token
    # and the restored session answers at (or after) the restored line
    res = client2.submit(SameSCC(0, 1)).result()
    assert res.value is True and res.gen >= saved_gen
    client.close()
    client2.close()
