import os
import sys

# make the in-tree oracle helpers importable regardless of how pytest is
# invoked (the mandated command is `PYTHONPATH=src pytest tests/`)
sys.path.insert(0, os.path.dirname(__file__))
