"""Deterministic, shard-aware synthetic data streams.

Every source is a pure function of (seed, step, shard) -- no files, no
state.  That buys three production properties for free:

  * **restart determinism**: the checkpoint stores only the step cursor;
    resuming re-generates the identical batch sequence;
  * **shard-affinity**: each data-parallel shard seeds with its own
    (step, shard) pair, so hosts never exchange data;
  * **elasticity**: a restart on a different data-parallel extent simply
    re-partitions the per-step global batch (generation is keyed by
    global example index, not by shard count).

Streams: LM token sequences with a learnable affine-mod structure, packed
molecule batches, node-classification graphs, and recsys interactions.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph import batching, sampler


@dataclasses.dataclass(frozen=True)
class ShardInfo:
    shard: int = 0
    n_shards: int = 1


def _rng(seed: int, step: int, shard: int = 0):
    return np.random.default_rng(
        np.random.SeedSequence([seed, step, shard]))


# ------------------------------------------------------------------- LM ---

def lm_batch(vocab: int, batch: int, seq: int, step: int,
             info: ShardInfo = ShardInfo(), seed: int = 0,
             structured: bool = True):
    """Next-token batch.  ``structured`` makes it learnable: token t+1 is
    (a*t + b) mod V with per-sequence (a, b), 10% noise."""
    b_local = batch // info.n_shards
    rng = _rng(seed, step, info.shard)
    if not structured:
        toks = rng.integers(0, vocab, (b_local, seq + 1))
    else:
        a = rng.integers(1, 8, (b_local, 1))
        c = rng.integers(0, vocab, (b_local, 1))
        t0 = rng.integers(0, vocab, (b_local, 1))
        toks = np.zeros((b_local, seq + 1), np.int64)
        toks[:, :1] = t0
        for i in range(1, seq + 1):
            toks[:, i] = (a[:, 0] * toks[:, i - 1] + c[:, 0]) % vocab
        noise = rng.random((b_local, seq + 1)) < 0.1
        toks = np.where(noise, rng.integers(0, vocab, toks.shape), toks)
    return {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32)}


# ------------------------------------------------------------------ GNN ---

def molecule_batch(n_graphs: int, n_nodes: int, n_edges: int, d_feat: int,
                   step: int, info: ShardInfo = ShardInfo(), seed: int = 0):
    g_local = n_graphs // info.n_shards
    rng = _rng(seed, step, info.shard)
    g = batching.pack_dense_batch(g_local, n_nodes, n_edges,
                                  seed=int(rng.integers(0, 2 ** 31)))
    n = g_local * n_nodes
    pos = rng.normal(size=(n, 3)).astype(np.float32)
    x = rng.normal(size=(n, d_feat)).astype(np.float32)
    # a learnable target: energy = Σ pairwise-sq-dist within graph (masked)
    energy = np.zeros(g_local, np.float32)
    pos_r = pos.reshape(g_local, n_nodes, 3)
    for i in range(g_local):
        d = pos_r[i][:, None] - pos_r[i][None, :]
        energy[i] = 0.01 * np.sum(d * d)
    return {
        "src": g.src, "dst": g.dst, "edge_mask": g.edge_mask,
        "node_mask": g.node_mask.astype(jnp.float32),
        "graph_id": g.graph_id,
        "x": jnp.asarray(x), "pos": jnp.asarray(pos),
        "energy": jnp.asarray(energy),
        "forces": jnp.zeros((n, 3), jnp.float32),
    }


def node_class_graph(n_nodes: int, n_edges: int, d_feat: int,
                     n_classes: int, seed: int = 0):
    """A fixed full-batch classification graph (Cora/products stand-in).

    Labels correlate with a random linear probe of features so models can
    learn; homophilous edges (prefer same-class endpoints).
    """
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    w = rng.normal(size=(d_feat, n_classes)).astype(np.float32)
    labels = np.argmax(x @ w + 0.5 * rng.normal(size=(n_nodes, n_classes)),
                       axis=1)
    src = rng.integers(0, n_nodes, n_edges)
    # half the edges rewired to same-class targets (homophily)
    dst = rng.integers(0, n_nodes, n_edges)
    return {
        "src": jnp.asarray(src, jnp.int32),
        "dst": jnp.asarray(dst, jnp.int32),
        "edge_mask": jnp.ones((n_edges,), bool),
        "node_mask": jnp.ones((n_nodes,), jnp.float32),
        "graph_id": jnp.zeros((n_nodes,), jnp.int32),
        "x": jnp.asarray(x),
        "pos": jnp.asarray(rng.normal(size=(n_nodes, 3)).astype(np.float32)),
        "labels": jnp.asarray(labels, jnp.int32),
    }


def sampled_block_batch(csr: sampler.CSRGraph, features, labels,
                        batch_nodes: int, fanouts, step: int,
                        info: ShardInfo = ShardInfo(), seed: int = 0):
    """minibatch_lg: seeds + fanout-sampled blocks flattened to one edge
    list local to the minibatch (GraphSAGE-style)."""
    n_local = batch_nodes // info.n_shards
    rng = _rng(seed, step, info.shard)
    n_total = features.shape[0]
    seeds = jnp.asarray(rng.integers(0, n_total, n_local), jnp.int32)
    key = jax.random.PRNGKey(int(rng.integers(0, 2 ** 31)))
    blocks, inputs = sampler.sample_blocks(csr, seeds, list(fanouts), key)
    # union node set = all frontier nodes (dups fine); relabel locally
    node_ids = jnp.concatenate([inputs] +
                               [b.src for b in blocks[1:]] + [seeds])
    # build one flat edge list over the concatenated node table
    srcs, dsts = [], []
    offset = 0
    # widest block first: src at [offset : offset+|src|], dst into next seg
    for b in blocks:
        srcs.append(jnp.arange(b.src.shape[0], dtype=jnp.int32) + offset)
        nxt = offset + b.src.shape[0]
        dsts.append(b.dst_local + nxt)
        offset = nxt
    src = jnp.concatenate(srcs)
    dst = jnp.concatenate(dsts)
    n = int(node_ids.shape[0])
    return {
        "src": src, "dst": dst,
        "edge_mask": jnp.ones(src.shape, bool),
        "node_mask": jnp.ones((n,), jnp.float32),
        "graph_id": jnp.zeros((n,), jnp.int32),
        "x": jnp.take(features, node_ids, axis=0),
        "pos": jnp.zeros((n, 3), jnp.float32),
        "labels": jnp.take(labels, node_ids, axis=0),
    }


# --------------------------------------------------------------- recsys ---

def mind_batch(n_items: int, batch: int, seq_len: int, profile_vocab: int,
               profile_len: int, n_neg: int, step: int,
               info: ShardInfo = ShardInfo(), seed: int = 0):
    """Interactions with latent-interest structure: each user draws 2
    interest clusters; behaviors and target come from them (learnable)."""
    b_local = batch // info.n_shards
    rng = _rng(seed, step, info.shard)
    n_clusters = 64
    cluster_of = (np.arange(n_items) * 2654435761 % n_clusters)
    user_c = rng.integers(0, n_clusters, (b_local, 2))
    # sample behaviors from the user's clusters
    items = rng.integers(0, n_items, (b_local, seq_len * 4))
    ok = (cluster_of[items] == user_c[:, :1]) | \
        (cluster_of[items] == user_c[:, 1:2])
    behavior = np.full((b_local, seq_len), -1, np.int64)
    for i in range(b_local):
        sel = items[i][ok[i]][:seq_len]
        behavior[i, :len(sel)] = sel
        if len(sel) == 0:
            behavior[i, 0] = items[i, 0]
    target = np.where(
        ok.any(1), items[np.arange(b_local), np.argmax(ok, axis=1)],
        items[:, 0])
    return {
        "behavior": jnp.asarray(behavior, jnp.int32),
        "profile": jnp.asarray(
            rng.integers(0, profile_vocab, (b_local, profile_len)),
            jnp.int32),
        "target": jnp.asarray(target, jnp.int32),
        "negatives": jnp.asarray(rng.integers(0, n_items, (n_neg,)),
                                 jnp.int32),
    }


# ------------------------------------------------------------ SCC (paper) ---

def op_stream(n_vertices: int, batch: int, step: int, add_frac: float,
              info: ShardInfo = ShardInfo(), seed: int = 0,
              include_vertex_ops: bool = True):
    """Deprecated alias: moved to :func:`repro.launch.workload.op_stream`.

    The paper workload generator lives with the serving stack now; this
    stub keeps old imports working bit-for-bit (same (seed, step, shard)
    stream) and will be removed with the rest of the legacy data package.
    """
    from repro.launch import workload
    return workload.op_stream(
        n_vertices, batch, step, add_frac,
        info=workload.ShardInfo(info.shard, info.n_shards), seed=seed,
        include_vertex_ops=include_vertex_ops)
