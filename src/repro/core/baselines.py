"""Baselines matching the paper's §7 comparison.

paper                          here
-----                          ----
Sequential (1 thread, no locks)  ``sequential_apply``: one op at a time
                                  (scan), each with its own localized repair
                                  -- the dynamic algorithm without any
                                  intra-batch parallelism.
Coarse-grained (one global lock) ``coarse_apply``: one op at a time where
                                  every op's repair is a *full* static
                                  recompute -- global mutual exclusion means
                                  no locality can be exploited.
SMSCC (n threads, fine locks)    ``dynamic.apply_batch``: B lanes per step,
                                  one unified localized repair.

Throughput is reported against batch size B (our stand-in for thread
count); see benchmarks/bench_mix.py.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import dynamic, graph_state as gs, scc


def _slice_ops(ops: dynamic.OpBatch, i):
    return dynamic.OpBatch(
        kind=jax.lax.dynamic_slice_in_dim(ops.kind, i, 1),
        u=jax.lax.dynamic_slice_in_dim(ops.u, i, 1),
        v=jax.lax.dynamic_slice_in_dim(ops.v, i, 1))


@partial(jax.jit, static_argnames=("cfg",))
def sequential_apply(state: gs.GraphState, ops: dynamic.OpBatch,
                     cfg: gs.GraphConfig):
    """Apply ops one at a time (localized repair per op)."""
    b = ops.kind.shape[0]

    def body(carry, i):
        st = carry
        st, ok = dynamic.apply_batch(st, _slice_ops(ops, i), cfg)
        return st, ok[0]

    state, oks = jax.lax.scan(body, state, jnp.arange(b))
    return state, oks


@partial(jax.jit, static_argnames=("cfg",))
def coarse_apply(state: gs.GraphState, ops: dynamic.OpBatch,
                 cfg: gs.GraphConfig):
    """Apply ops one at a time with a FULL static recompute per op."""
    b = ops.kind.shape[0]

    def body(carry, i):
        st = carry
        # structural change via the batch machinery (B=1)...
        st, ok = dynamic.apply_batch(st, _slice_ops(ops, i), cfg)
        # ...then throw the locality away: recompute everything, as a global
        # lock + from-scratch algorithm would.
        st = dynamic.recompute(st, cfg)
        return st, ok[0]

    state, oks = jax.lax.scan(body, state, jnp.arange(b))
    return state, oks


@partial(jax.jit, static_argnames=("cfg",))
def static_per_batch_apply(state: gs.GraphState, ops: dynamic.OpBatch,
                           cfg: gs.GraphConfig):
    """Ablation: batched structural apply + full recompute (no locality)."""
    state, ok = dynamic.apply_batch(state, ops, cfg)
    # overwrite the localized labels with a from-scratch pass
    state = dynamic.recompute(state, cfg)
    return state, ok
