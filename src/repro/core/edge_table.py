"""Batched open-addressing hash set over directed edges.

This is the TPU-native replacement for the paper's lock-based lazy list-set:

  paper (lazy list, per-node locks)        here (linear probing, batched)
  ---------------------------------        -------------------------------
  locate(key) pointer walk                 bounded probe loop (vectorized)
  lock(pred); lock(curr); validate         scatter-``min`` claim of a slot:
                                           the lowest op index wins, losers
                                           re-probe -- an obstruction-free
                                           "lock" with deterministic winners
  logical delete (marked = true)           TOMB state (kept for probe chains)
  physical delete / GC                     :func:`compact` rebuild pass

All operations take a *batch* of keys and run in O(max_probes) data-parallel
rounds, entirely inside ``jit``.  The (src, dst, state) columns double as a
COO edge list for the SCC sweeps, so there is no separate adjacency copy.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.hash_probe import ops as hash_probe

EMPTY = jnp.int8(0)
LIVE = jnp.int8(1)
TOMB = jnp.int8(2)


class EdgeTable(NamedTuple):
    src: jax.Array  # int32[C]
    dst: jax.Array  # int32[C]
    state: jax.Array  # int8[C]  EMPTY | LIVE | TOMB


def empty(capacity: int) -> EdgeTable:
    assert capacity & (capacity - 1) == 0, "capacity must be a power of two"
    return EdgeTable(
        src=jnp.zeros((capacity,), jnp.int32),
        dst=jnp.zeros((capacity,), jnp.int32),
        state=jnp.zeros((capacity,), jnp.int8),
    )


def _hash(u: jax.Array, v: jax.Array, capacity: int) -> jax.Array:
    """Fibonacci-ish mixing of the (u, v) pair into [0, capacity)."""
    u = u.astype(jnp.uint32)
    v = v.astype(jnp.uint32)
    h = u * jnp.uint32(0x9E3779B1) ^ (v + jnp.uint32(0x85EBCA77) + (u << 6) + (u >> 2))
    h = h ^ (h >> 15)
    h = h * jnp.uint32(0x2C1B3C6D)
    h = h ^ (h >> 12)
    return (h & jnp.uint32(capacity - 1)).astype(jnp.int32)


def lookup(table: EdgeTable, u: jax.Array, v: jax.Array, max_probes: int,
           *, impl: str = "xla") -> Tuple[jax.Array, jax.Array]:
    """Batched membership probe.

    Returns ``(found: bool[B], slot: int32[B])``; ``slot`` is the LIVE slot
    of the key when found, else the first EMPTY/TOMB slot seen (insertion
    point), else -1 when the probe bound was exhausted.

    ``impl`` picks the probe engine (GraphConfig.sparse_impl semantics):
    the sequential fori_loop below is the ``'xla'`` oracle; the Pallas
    panel sweep (:mod:`repro.kernels.hash_probe`) is bit-identical to it.
    """
    cap = table.src.shape[0]
    base = _hash(u, v, cap)
    b = u.shape[0]
    if hash_probe.resolve_impl(impl, cap) != "xla":
        return hash_probe.probe(table.src, table.dst, table.state, base,
                                u, v, max_probes=max_probes, impl=impl)

    def body(i, carry):
        done, found, slot, free = carry
        pos = (base + i) & (cap - 1)
        st = table.state[pos]
        s, d = table.src[pos], table.dst[pos]
        hit = (st == LIVE) & (s == u) & (d == v)
        is_empty = st == EMPTY
        is_free = st != LIVE
        # remember the first non-live slot as the insertion point
        free = jnp.where((~done) & is_free & (free < 0), pos, free)
        slot = jnp.where((~done) & hit, pos, slot)
        found = found | ((~done) & hit)
        # probing stops at a hit or at a truly EMPTY slot (chain end)
        done = done | hit | is_empty
        return done, found, slot, free

    done = jnp.zeros((b,), jnp.bool_)
    found = jnp.zeros((b,), jnp.bool_)
    slot = jnp.full((b,), -1, jnp.int32)
    free = jnp.full((b,), -1, jnp.int32)
    done, found, slot, free = jax.lax.fori_loop(
        0, max_probes, body, (done, found, slot, free))
    return found, jnp.where(found, slot, free)


def insert(table: EdgeTable, u: jax.Array, v: jax.Array, max_probes: int,
           enable: jax.Array | None = None, *, impl: str = "xla"
           ) -> Tuple[EdgeTable, jax.Array, jax.Array]:
    """Batched insert.  Returns ``(table, inserted: bool[B], failed: bool[B])``.

    ``inserted`` is False for keys already present, duplicate keys within the
    batch (only the first wins -- matching a sequential application order),
    disabled lanes, and probe-bound overflow.  ``failed`` isolates the last
    case: lanes that *wanted* a slot (enabled, key absent, not an intra-batch
    duplicate) but exhausted the probe bound -- the table's own overflow
    report, so callers never need a second probe sweep to detect dropped
    keys.
    """
    cap = table.src.shape[0]
    b = u.shape[0]
    if enable is None:
        enable = jnp.ones((b,), jnp.bool_)

    # intra-batch dedupe: an op is a duplicate if an earlier enabled op has
    # the same key.  B is small (<= few thousand), so O(B log B) sort is fine.
    order = jnp.argsort(v, stable=True)
    order = order[jnp.argsort(u[order], stable=True)]  # lexsort by (u, v)
    su, sv, se = u[order], v[order], enable[order]
    same_prev = jnp.concatenate([
        jnp.zeros((1,), jnp.bool_),
        (su[1:] == su[:-1]) & (sv[1:] == sv[:-1])])
    # within each equal-key run, the first *enabled* op wins; later enabled
    # ops are duplicates (== the sequential order's return values).
    def dup_scan(carry, x):
        same, en = x
        run_carry = jnp.where(same, carry, False)  # reset at run start
        is_dup = run_carry & en
        return run_carry | en, is_dup
    _, dup_sorted = jax.lax.scan(dup_scan, jnp.zeros((), jnp.bool_),
                                 (same_prev, se))
    dup = jnp.zeros((b,), jnp.bool_).at[order].set(dup_sorted)
    enable = enable & ~dup

    # membership probe through the impl hook; the claim loop below stays
    # XLA -- it is an inherently serial linearization (scatter-min winner
    # per round), not a sweep
    found, _ = lookup(table, u, v, max_probes, impl=impl)
    want = enable & ~found

    base = _hash(u, v, cap)

    def round_body(i, carry):
        table, placed, probe = carry
        pending = want & ~placed
        pos = (base + probe) & (cap - 1)
        st = table.state[pos]
        free = st != LIVE
        contend = pending & free
        # scatter-min claim: lowest op index wins the slot this round
        claims = jnp.full((cap,), b, jnp.int32)
        claims = claims.at[jnp.where(contend, pos, cap - 1)].min(
            jnp.where(contend, jnp.arange(b, dtype=jnp.int32), b))
        win = contend & (claims[pos] == jnp.arange(b, dtype=jnp.int32))
        wpos = jnp.where(win, pos, cap)  # out-of-range scatter = drop
        table = EdgeTable(
            src=table.src.at[wpos].set(u, mode="drop"),
            dst=table.dst.at[wpos].set(v, mode="drop"),
            state=table.state.at[wpos].set(LIVE, mode="drop"),
        )
        placed = placed | win
        probe = jnp.where(pending & ~win, probe + 1, probe)
        return table, placed, probe

    placed = jnp.zeros((b,), jnp.bool_)
    probe = jnp.zeros((b,), jnp.int32)
    table, placed, _ = jax.lax.fori_loop(
        0, max_probes, round_body, (table, placed, probe))
    return table, placed, want & ~placed


def remove(table: EdgeTable, u: jax.Array, v: jax.Array, max_probes: int,
           enable: jax.Array | None = None, *, impl: str = "xla"
           ) -> Tuple[EdgeTable, jax.Array]:
    """Batched remove (logical delete -> TOMB).  Returns (table, removed[B])."""
    b = u.shape[0]
    if enable is None:
        enable = jnp.ones((b,), jnp.bool_)
    found, slot = lookup(table, u, v, max_probes, impl=impl)
    hit = found & enable
    # duplicate removals of the same key in one batch target the same slot;
    # both see LIVE pre-state, but sequentially only the first succeeds.
    first = jnp.zeros((b,), jnp.bool_)
    claims = jnp.full((table.src.shape[0],), b, jnp.int32)
    cap = table.src.shape[0]
    claims = claims.at[jnp.where(hit, slot, cap - 1)].min(
        jnp.where(hit, jnp.arange(b, dtype=jnp.int32), b))
    first = hit & (claims[slot] == jnp.arange(b, dtype=jnp.int32))
    wpos = jnp.where(first, slot, cap)
    table = table._replace(state=table.state.at[wpos].set(TOMB, mode="drop"))
    return table, first


def remove_incident(table: EdgeTable, v_mask: jax.Array) -> Tuple[EdgeTable, jax.Array]:
    """Tombstone every LIVE edge with an endpoint in ``v_mask`` (bool[NV]).

    This is the paper's "trim the SCC-Graph after RemoveVertex" -- with a
    dense table it is one masked compare over the columns instead of a walk.
    Returns (table, was_removed mask over slots).
    """
    live = table.state == LIVE
    kill = live & (v_mask[table.src] | v_mask[table.dst])
    return table._replace(
        state=jnp.where(kill, TOMB, table.state)), kill


def rehash(table: EdgeTable, new_capacity: int, max_probes: int,
           *, impl: str = "xla") -> EdgeTable:
    """Migrate every LIVE entry into a fresh table of ``new_capacity``.

    The grow half of grow-and-replay: the host detects probe-bound overflow
    (``GraphState.overflow`` delta), picks a geometrically larger capacity,
    and calls this inside jit (``new_capacity`` is static, so each target
    capacity compiles once).  Tombstones are dropped for free, so
    ``rehash(t, cap(t))`` == :func:`compact`.
    """
    assert new_capacity & (new_capacity - 1) == 0, (
        "new_capacity must be a power of two")
    live = table.state == LIVE
    fresh = empty(new_capacity)
    fresh, _, _ = insert(fresh, table.src, table.dst, max_probes,
                         enable=live, impl=impl)
    return fresh


def compact(table: EdgeTable, max_probes: int, *, impl: str = "xla"
            ) -> EdgeTable:
    """GC pass: rebuild the table without tombstones (hazard-pointer
    analogue) -- rehash at the current capacity."""
    return rehash(table, table.src.shape[0], max_probes, impl=impl)


def fill_stats(table: EdgeTable):
    live = jnp.sum(table.state == LIVE)
    tomb = jnp.sum(table.state == TOMB)
    return live, tomb
