"""SMSCC: batched fully-dynamic SCC maintenance (the paper's contribution).

The paper's concurrency unit is a POSIX thread applying one operation under
fine-grained locks; ours is a *lane* of an operation batch applied by one
compiled dataflow step.  ``apply_batch`` consumes a :class:`GraphState` and
an :class:`OpBatch` and produces the state after *some* linearization of the
batch plus per-op boolean results matching the paper's method contracts:

  AddVertex(u)     true iff u was absent          (paper Alg. 20)
  RemoveVertex(u)  true iff u was present         (paper Alg. 18)
  AddEdge(u,v)     true iff u,v present & edge absent   (paper Alg. 15)
  RemoveEdge(u,v)  true iff u,v present & edge present  (paper Alg. 16)

The fixed linearization order inside a batch is
``RemoveVertex -> RemoveEdge -> AddVertex -> AddEdge`` with ties broken by
lane index (scatter-min claims), so results always equal a sequential
history -- the batch-atomic analogue of the paper's linearizability.

Repair (the paper's §5.1/§5.2, *locality of repair*):

  * deletions can only split the SCCs they touched: those classes are
    collected in ``M_del``;
  * insertions can only merge SCCs on a ``v ⇝ u`` path: every vertex of any
    such path lies in ``FW(new heads) ∩ BW(new tails)`` = ``C_ins``;
  * one masked static-SCC pass over ``M = M_del ∪ C_ins`` restores the
    partition; labels outside M are untouched.

M is a union of (pre-batch) SCCs plus fully-included broken classes, and
every post-batch SCC that changed has all its internal paths inside M, so
the masked recomputation is exact (proof sketch in DESIGN.md §2).

The masked pass itself is *tiered* so its per-round work is proportional
to the region, not the table (the other half of locality of repair):

  tier 0 dense    |M| <= dense_capacity: densify the region and close it
                  with boolean mat-muls through the injected Pallas
                  ``reach_blockmm`` kernel (MXU on TPU);
  tier 1 compact  |M| <= region_vertex_capacity and the region's live
                  edges fit a bucket of ``region_edge_buckets``: compact
                  the region once into bounded static sub-arrays and run
                  the scc_static fixpoints there -- O(region edges) per
                  round;
  tier 2 full     overflow fallback: scc_static over the full edge table.

Tier choice is a runtime ``lax.cond`` inside the one compiled step (no
extra compilations); every tier produces bit-identical labels.  The
chosen tier and the region's vertex/edge counts are returned as
:class:`RepairStats` next to the overflow delta, and surfaced by
``SCCService.stats()``.

Two step-level fusions keep the *update-heavy* path fast (the paper's
Fig 4/5 regime, where most ops do not change SCC structure):

  * the **repair gate** (``GraphConfig.repair_gate``, on by default) wraps
    all of phase 5 in a ``lax.cond`` on a cheap in-graph predicate --
    a step with no straddling insert and no deletion-affected SCC member
    has a provably empty region, so the whole repair is skipped
    (``RepairStats.tier == TIER_SKIP``) at O(batch) cost, bit-identically;
  * the **scan engine** (:func:`apply_batch_scan`) runs K same-bucket
    chunks through the step inside one compiled ``lax.scan``, carrying the
    state and stacking per-step ``ok``/overflow/:class:`RepairStats`
    outputs, so the service dispatches (and host-syncs) once per
    super-chunk instead of per chunk.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import edge_table as et
from repro.core import graph_state as gs
from repro.core import reach, scc
from repro.kernels.reach_blockmm import ops as reach_blockmm

ADD_EDGE = 0
REM_EDGE = 1
ADD_VERTEX = 2
REM_VERTEX = 3
NOP = 4

INT32_MAX = jnp.iinfo(jnp.int32).max

# Repair-tier codes / names / stats pytree live in graph_state (the scan
# entry stacks RepairStats leaves, and keeping the pytree next to
# GraphState avoids a dynamic<->graph_state import cycle); re-exported
# here because this module is the tier dispatcher's home.
TIER_DENSE = gs.TIER_DENSE
TIER_COMPACT = gs.TIER_COMPACT
TIER_FULL = gs.TIER_FULL
TIER_SKIP = gs.TIER_SKIP
TIER_NAMES = gs.TIER_NAMES
RepairStats = gs.RepairStats


class OpBatch(NamedTuple):
    kind: jax.Array  # int32[B] in {ADD_EDGE..NOP}
    u: jax.Array     # int32[B]
    v: jax.Array     # int32[B]  (ignored for vertex ops)


def make_ops(kind, u, v) -> OpBatch:
    return OpBatch(kind=jnp.asarray(kind, jnp.int32),
                   u=jnp.asarray(u, jnp.int32),
                   v=jnp.asarray(v, jnp.int32))


def _first_claim(cand, target, nv, b):
    """Lane wins iff it is the lowest-indexed candidate lane for its target
    vertex -- the batched analogue of 'first thread to get the lock'."""
    idx = jnp.arange(b, dtype=jnp.int32)
    claims = jnp.full((nv + 1,), b, jnp.int32)
    claims = claims.at[jnp.where(cand, target, nv)].min(
        jnp.where(cand, idx, b))
    return cand & (claims[target] == idx)


def _apply_batch_impl(state: gs.GraphState, ops: OpBatch,
                      cfg: gs.GraphConfig):
    """One batch-atomic SMSCC step.

    Returns ``(new_state, ok: bool[B], ovf_delta: int32[], RepairStats)``.
    The overflow *delta* and the repair stats are dedicated output buffers
    (never aliased to the input state) so a pipelined caller can donate
    ``state`` into the next step and still inspect them later without
    touching donated memory.
    """
    nv = cfg.n_vertices
    b = ops.kind.shape[0]
    vid = jnp.arange(nv, dtype=jnp.int32)

    v_alive = state.v_alive
    ccid = state.ccid  # working labels; sentinel nv for dead slots
    edges = state.edges
    ok = jnp.zeros((b,), jnp.bool_)

    in_range = (ops.u >= 0) & (ops.u < nv) & \
        jnp.where((ops.kind == ADD_EDGE) | (ops.kind == REM_EDGE),
                  (ops.v >= 0) & (ops.v < nv), True)

    # ---- Phase 1: RemoveVertex --------------------------------------------
    is_remv = (ops.kind == REM_VERTEX) & in_range
    cand = is_remv & v_alive[jnp.clip(ops.u, 0, nv - 1)]
    win_remv = _first_claim(cand, ops.u, nv, b)
    ok = jnp.where(win_remv, True, ok)
    killed = jnp.zeros((nv,), jnp.bool_).at[
        jnp.where(win_remv, ops.u, nv)].set(True, mode="drop")
    # deletion-affected classes: the old class of every killed vertex
    affected_rep = jnp.zeros((nv + 1,), jnp.bool_)
    affected_rep = affected_rep.at[
        jnp.where(killed, jnp.minimum(ccid, nv), nv)].set(True, mode="drop")
    v_alive = v_alive & ~killed
    # the paper's "trim after RemoveVertex": drop all incident edges at once
    edges, _ = et.remove_incident(edges, killed)
    ccid = jnp.where(killed, nv, ccid)

    # ---- Phase 2: RemoveEdge ----------------------------------------------
    is_reme = (ops.kind == REM_EDGE) & in_range
    ends_ok = v_alive[jnp.clip(ops.u, 0, nv - 1)] & \
        v_alive[jnp.clip(ops.v, 0, nv - 1)]
    edges, removed = et.remove(edges, ops.u, ops.v, cfg.max_probes,
                               enable=is_reme & ends_ok,
                               impl=cfg.sparse_impl)
    ok = jnp.where(removed, True, ok)
    same_class = ccid[jnp.clip(ops.u, 0, nv - 1)] == \
        ccid[jnp.clip(ops.v, 0, nv - 1)]
    hit = removed & same_class
    affected_rep = affected_rep.at[
        jnp.where(hit, jnp.minimum(ccid[jnp.clip(ops.u, 0, nv - 1)], nv),
                  nv)].set(True, mode="drop")

    # ---- Phase 3: AddVertex (paper: new SCC at CCHead, ccCount++) ---------
    is_addv = (ops.kind == ADD_VERTEX) & in_range
    cand = is_addv & ~v_alive[jnp.clip(ops.u, 0, nv - 1)]
    win_addv = _first_claim(cand, ops.u, nv, b)
    ok = jnp.where(win_addv, True, ok)
    born = jnp.zeros((nv,), jnp.bool_).at[
        jnp.where(win_addv, ops.u, nv)].set(True, mode="drop")
    v_alive = v_alive | born
    ccid = jnp.where(born, vid, ccid)  # fresh singleton SCC

    # ---- Phase 4: AddEdge --------------------------------------------------
    is_adde = (ops.kind == ADD_EDGE) & in_range
    ends_ok = v_alive[jnp.clip(ops.u, 0, nv - 1)] & \
        v_alive[jnp.clip(ops.v, 0, nv - 1)]
    enable = is_adde & ends_ok
    edges, inserted, dropped = et.insert(edges, ops.u, ops.v,
                                         cfg.max_probes, enable=enable,
                                         impl=cfg.sparse_impl)
    ok = jnp.where(inserted, True, ok)
    # overflow accounting straight from the table's own probe-exhaustion
    # report -- the host must grow the table and replay these lanes.
    ovf = jnp.sum(dropped).astype(jnp.int32)

    # ---- Phase 5: unified localized repair ---------------------------------
    src, dst, live = edges.src, edges.dst, edges.state == et.LIVE

    # deletion side: all members of affected classes (live labels are < nv,
    # so the junk slot [nv] written by inactive lanes is never read here)
    m_del = v_alive & affected_rep[jnp.minimum(ccid, nv)]
    # insertion side: FW(inserted heads) ∩ BW(inserted tails), but only for
    # edges that straddle two current classes (paper Alg. 15 line 226 check)
    straddle = inserted & (ccid[jnp.clip(ops.u, 0, nv - 1)] !=
                           ccid[jnp.clip(ops.v, 0, nv - 1)])

    def run_repair(_):
        seed_f = jnp.zeros((nv,), jnp.bool_).at[
            jnp.where(straddle, ops.v, nv)].set(True, mode="drop")
        seed_b = jnp.zeros((nv,), jnp.bool_).at[
            jnp.where(straddle, ops.u, nv)].set(True, mode="drop")
        if cfg.fuse_fwbw:
            fw, bw, _ = reach.fused_fw_bw_reach(
                src, dst, live, seed_f, seed_b, v_alive, cfg.max_inner,
                spec=cfg.label_spec, impl=cfg.sparse_impl)
        else:
            fw, _ = reach.forward_reach(src, dst, live, seed_f, v_alive,
                                        cfg.max_inner, spec=cfg.label_spec,
                                        impl=cfg.sparse_impl)
            bw, _ = reach.backward_reach(src, dst, live, seed_b, v_alive,
                                         cfg.max_inner,
                                         spec=cfg.label_spec,
                                         impl=cfg.sparse_impl)
        region = (m_del | (fw & bw)) & v_alive
        region_v = jnp.sum(region).astype(jnp.int32)
        region_e = jnp.sum(live & region[src] & region[dst]
                           ).astype(jnp.int32)

        # Tiered repair dispatch: the region is the same for every tier;
        # each tier is a cheaper execution of the identical masked
        # static-SCC pass.  Tiers nest smallest-first via lax.cond (one
        # compiled program per cfg -- tier choice is a runtime branch,
        # never a recompile).
        def repair_full(_):
            lab = scc.scc_static(src, dst, live, region,
                                 max_outer=cfg.max_outer,
                                 max_inner=cfg.max_inner,
                                 spec=cfg.label_spec,
                                 shortcut=cfg.shortcut,
                                 impl=cfg.sparse_impl)
            return lab, jnp.int32(TIER_FULL)

        dispatch = repair_full

        # (2) compact sparse: region fits the bounded compact COO.  Edge
        # slots come from the geometric bucket registry; the smallest
        # bucket that holds the region's live edges wins (lax.switch over
        # static shapes).
        e_buckets = tuple(b for b in cfg.region_edge_buckets
                          if b < cfg.edge_capacity)
        if 0 < cfg.region_vertex_capacity < nv and e_buckets:
            vcap = cfg.region_vertex_capacity

            def compact_branch(ecap):
                def run(_):
                    lab, _fits = scc.scc_compact_region(
                        src, dst, live, region, vcap, ecap,
                        max_outer=cfg.max_outer, max_inner=cfg.max_inner,
                        shortcut=cfg.shortcut, impl=cfg.sparse_impl)
                    return lab, jnp.int32(TIER_COMPACT)
                return run

            branches = [compact_branch(b) for b in e_buckets]
            bucket_idx = jnp.minimum(
                jnp.sum((region_e > jnp.asarray(e_buckets, jnp.int32))
                        .astype(jnp.int32)), len(e_buckets) - 1)
            fits_compact = (region_v <= vcap) & (region_e <= e_buckets[-1])

            def repair_compact(_):
                return jax.lax.switch(bucket_idx, branches, None)

            def dispatch(_, fits=fits_compact, below=repair_compact,
                         above=dispatch):
                return jax.lax.cond(fits, below, above, None)

        # (1) dense MXU: small enough to densify; the adjacency closure
        # runs through the injected reach_blockmm boolean mat-mul (Pallas
        # on TPU, interpret-mode validation on CPU, jnp oracle under
        # impl='xla').
        if cfg.dense_capacity > 0:
            def repair_dense(_):
                def matmul(a, b):
                    return reach_blockmm.bool_matmul(
                        a, b, impl=cfg.dense_matmul_impl)
                lab, _fits = scc.scc_dense_region(src, dst, live, region,
                                                  cfg.dense_capacity,
                                                  matmul=matmul)
                return lab, jnp.int32(TIER_DENSE)

            fits_dense = region_v <= cfg.dense_capacity

            def dispatch(_, fits=fits_dense, below=repair_dense,
                         above=dispatch):
                return jax.lax.cond(fits, below, above, None)

        new_lab, tier = dispatch(None)
        repair = RepairStats(tier=tier, region_vertices=region_v,
                             region_edges=region_e)
        return jnp.where(region, new_lab, ccid), repair

    if cfg.repair_gate:
        # In-graph repair gate: the region is M_del ∪ (FW ∩ BW), FW/BW are
        # seeded only by straddling inserts, so `no straddle and no
        # deletion-affected member` proves the region EMPTY -- every tier's
        # masked pass would be the identity on ccid.  Skipping is therefore
        # exact (bit-identical labels), not merely conservative; the
        # conservative direction (repair may run on a batch that turns out
        # structure-preserving, e.g. a RemoveEdge inside an SCC that stays
        # strongly connected) errs safe.  lax.cond keeps it one compiled
        # program: a structure-preserving step costs O(batch + NV) instead
        # of O(region fixpoint).
        need_repair = jnp.any(m_del) | jnp.any(straddle)

        def skip_repair(_):
            return ccid, gs.repair_skipped()

        ccid, repair = jax.lax.cond(need_repair, run_repair, skip_repair,
                                    None)
    else:
        ccid, repair = run_repair(None)

    ccid = jnp.where(v_alive, ccid, nv)

    new_state = gs.GraphState(
        v_alive=v_alive,
        ccid=ccid,
        edges=edges,
        n_ccs=state.n_ccs,  # recomputed below
        gen=state.gen + 1,
        overflow=state.overflow + ovf,
    )
    new_state = gs.recount_ccs(new_state)
    return new_state, ok, ovf, repair


@partial(jax.jit, static_argnames=("cfg",))
def apply_batch(state: gs.GraphState, ops: OpBatch, cfg: gs.GraphConfig):
    """One batch-atomic SMSCC step.  Returns (new_state, ok: bool[B])."""
    new_state, ok, _, _ = _apply_batch_impl(state, ops, cfg)
    return new_state, ok


# In-flight variants for the concurrent-reader pipeline: both return the
# per-step overflow delta and repair telemetry as extra outputs so the host
# can defer its only sync point behind a window of dispatched steps.  The
# donating entry hands the input state's buffers to XLA for reuse — callers
# must guarantee nothing else (in particular no committed reader snapshot)
# still references them.
apply_batch_async = jax.jit(_apply_batch_impl, static_argnames=("cfg",))
_apply_batch_donated = jax.jit(_apply_batch_impl, static_argnames=("cfg",),
                               donate_argnums=(0,))


def apply_batch_inflight(state: gs.GraphState, ops: OpBatch,
                         cfg: gs.GraphConfig, *, donate: bool = False):
    """Dispatch one step without forcing any host sync.

    Returns ``(new_state, ok, ovf_delta, RepairStats)`` as in-flight
    device values.  With ``donate=True`` the input state's buffers are
    donated to the output (saves a full state copy per step on
    accelerators; ignored with a warning on CPU, where XLA does not
    implement donation).
    """
    fn = _apply_batch_donated if donate else apply_batch_async
    return fn(state, ops, cfg)


# --------------------------------------------------------------------------
# Fused multi-chunk scan engine
# --------------------------------------------------------------------------

def _apply_batch_scan_impl(state: gs.GraphState, ops: OpBatch,
                           cfg: gs.GraphConfig):
    """K stacked bucket-shaped chunks through the full 5-phase step inside
    ONE compiled program.

    ``ops`` carries ``int32[K, B]`` leaves (K same-bucket chunks stacked
    along a scan axis); ``lax.scan`` threads the :class:`GraphState` carry
    through the K steps and stacks the per-step outputs, so the host pays
    one dispatch (and later one transfer) per *super-chunk* instead of per
    chunk.  Each scan step is the unmodified ``_apply_batch_impl`` -- the
    linearization, per-op results, overflow accounting, and labels are
    bit-identical to K sequential ``apply_batch`` calls.

    Returns ``(new_state, ok: bool[K, B], ovf_delta: int32[K],
    RepairStats with int32[K] leaves)``; all three trailing outputs are
    dedicated buffers (never aliased to the carry), so a donating caller
    can hand ``state`` to the next super-chunk and still resolve them.
    """

    def body(st, op):
        st, ok, ovf, repair = _apply_batch_impl(st, op, cfg)
        return st, (ok, ovf, repair)

    state, (ok, ovf, repair) = jax.lax.scan(body, state, ops)
    return state, ok, ovf, repair


apply_batch_scan = jax.jit(_apply_batch_scan_impl, static_argnames=("cfg",))
_apply_batch_scan_donated = jax.jit(_apply_batch_scan_impl,
                                    static_argnames=("cfg",),
                                    donate_argnums=(0,))


def apply_batch_scan_inflight(state: gs.GraphState, ops: OpBatch,
                              cfg: gs.GraphConfig, *, donate: bool = False):
    """Dispatch one K-chunk super-chunk without forcing any host sync.

    The scan analogue of :func:`apply_batch_inflight`: one jit entry per
    ``(K, bucket, cfg)`` from the service's scan-length registry, so the
    compile count stays bounded by ``buckets x scan_lengths`` per config.
    """
    fn = _apply_batch_scan_donated if donate else apply_batch_scan
    return fn(state, ops, cfg)


@partial(jax.jit, static_argnames=("cfg",))
def recompute(state: gs.GraphState, cfg: gs.GraphConfig) -> gs.GraphState:
    """Full static SCC of the current graph (bulk-load / oracle path)."""
    src, dst, live = gs.edge_coo(state)
    lab = scc.scc_static(src, dst, live, state.v_alive,
                         max_outer=cfg.max_outer, max_inner=cfg.max_inner,
                         spec=cfg.label_spec, shortcut=cfg.shortcut,
                         impl=cfg.sparse_impl)
    ccid = jnp.where(state.v_alive, lab, cfg.n_vertices)
    return gs.recount_ccs(state._replace(ccid=ccid, gen=state.gen + 1))
