"""Streaming SCC service: the paper's on-line system around ``apply_batch``.

The paper (arXiv:1804.01276) runs SMSCC as a *service*: a fixed thread pool
applies an unbounded stream of graph updates while readers issue wait-free
SameSCC/reachability queries.  ``dynamic.apply_batch`` is our compiled
analogue of one scheduling quantum; this module supplies the host-side
machinery that turns it into a long-running service:

grow-and-replay
    ``apply_batch`` can only report probe-bound overflow (the
    ``GraphState.overflow`` counter); it cannot grow the hash table because
    its shapes are static.  The service watches the per-step overflow
    *delta*, identifies exactly the AddEdge lanes whose key is missing from
    the post-step table, rehashes the table into a geometrically larger
    capacity (``edge_table.rehash``, jitted once per target capacity), and
    replays the failed lanes.  Invariant: **no accepted edge is ever lost**
    -- after ``apply()`` returns, the table contains every edge a
    sequential unbounded-table execution would contain, and the reported
    per-op results match that sequential history.

bucketed scheduling
    An unbounded stream has unbounded batch lengths; jit would recompile
    per length.  The scheduler (:class:`repro.launch.stream.BucketedScheduler`)
    cuts the stream into a small fixed set of padded shapes (NOP padding),
    so total XLA compilations are bounded by ``len(buckets) x #capacities``
    regardless of stream length.  ``compile_count`` tracks this bound.

snapshot queries
    States are immutable pytrees; the service keeps a pointer to the last
    *committed* state (updated only after a chunk fully applies, including
    any replay).  Readers therefore always see a consistent generation --
    the batched analogue of the paper's wait-free reader guarantee -- and
    every query result is stamped with the generation it was computed at.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import community, dynamic, edge_table as et
from repro.core import graph_state as gs

_MAX_GROW_ROUNDS = 16


class Snapshot(NamedTuple):
    """A query result stamped with the SCC-partition generation it saw."""
    value: np.ndarray
    gen: int


@partial(jax.jit, static_argnames=("new_capacity", "max_probes"))
def _rehash(table: et.EdgeTable, new_capacity: int, max_probes: int):
    return et.rehash(table, new_capacity, max_probes)


@partial(jax.jit, static_argnames=("max_inner",))
def _reachable_batch(state: gs.GraphState, u, v, max_inner: int):
    """bool[Q]: u[i] ⇝ v[i] over live edges (u==v and alive counts)."""
    nv = state.ccid.shape[0]
    uu = jnp.clip(u, 0, nv - 1)
    vv = jnp.clip(v, 0, nv - 1)
    src, dst, live = gs.edge_coo(state)
    seeds = jnp.zeros((u.shape[0], nv), jnp.bool_).at[
        jnp.arange(u.shape[0]), uu].set(True)
    from repro.core import reach
    reached, _ = reach.multi_forward_reach(src, dst, live, seeds,
                                           state.v_alive, max_inner)
    ok = state.v_alive[uu] & state.v_alive[vv]
    return ok & reached[jnp.arange(u.shape[0]), vv]


@jax.jit
def _members(state: gs.GraphState, u):
    """bool[NV]: vertices in u's SCC (empty mask when u is dead)."""
    nv = state.ccid.shape[0]
    uu = jnp.clip(u, 0, nv - 1)
    lab = jnp.where(state.v_alive[uu], state.ccid[uu], nv)
    return state.v_alive & (state.ccid == lab)


class SCCService:
    """Host-side streaming wrapper: grow-and-replay + bucketed scheduling +
    generation-stamped snapshot queries over ``dynamic.apply_batch``."""

    def __init__(self, cfg: gs.GraphConfig,
                 buckets: Sequence[int] = (64, 256, 1024),
                 state: gs.GraphState | None = None,
                 grow_factor: int = 2,
                 max_edge_capacity: int | None = None,
                 compact_tomb_frac: float = 0.25):
        from repro.launch.stream import BucketedScheduler
        self._cfg = cfg
        self._state = gs.empty(cfg) if state is None else state
        self._sched = BucketedScheduler(buckets)
        self._grow_factor = grow_factor
        self._max_edge_capacity = max_edge_capacity
        self._compact_tomb_frac = compact_tomb_frac
        self._committed = self._state
        # telemetry
        self._compiled: set = set()
        self.grow_count = 0
        self.replayed_ops = 0
        self.compaction_count = 0

    # ------------------------------------------------------------ state ---

    @property
    def cfg(self) -> gs.GraphConfig:
        return self._cfg

    @property
    def state(self) -> gs.GraphState:
        """Latest committed state (safe to checkpoint / query)."""
        return self._committed

    @property
    def gen(self) -> int:
        return int(self._committed.gen)

    @property
    def compile_count(self) -> int:
        """Distinct (batch-shape, graph-config) pairs stepped so far -- an
        upper bound on *update-step* compiles.  Table rehashes (one per
        target capacity) and query batches (one per query shape) have
        their own, separately-cached jit entries not counted here."""
        return len(self._compiled)

    # ---------------------------------------------------------- updates ---

    def apply(self, kind, u, v) -> np.ndarray:
        """Apply a variable-length op stream chunk; returns ok: bool[N].

        The chunk is cut into padded bucket batches; each batch goes
        through grow-and-replay so no AddEdge is ever dropped.  Results
        match the documented per-batch linearization applied bucket by
        bucket.
        """
        kind = np.asarray(kind, np.int32)
        u = np.asarray(u, np.int32)
        v = np.asarray(v, np.int32)
        ok = np.zeros(kind.shape[0], bool)
        entry_state, entry_cfg = self._state, self._cfg
        entry_stats = (set(self._compiled), self.grow_count,
                       self.replayed_ops, self.compaction_count)
        try:
            for sl, ops in self._sched.chunks(kind, u, v):
                n_real = sl.stop - sl.start
                ok[sl] = self._apply_padded(ops)[:n_real]
            self._maybe_compact()
        except Exception:
            # all-or-nothing chunk: never let a half-applied batch, a cfg
            # that no longer matches the table, or telemetry for aborted
            # work leak into the next apply()'s commit
            self._state, self._cfg = entry_state, entry_cfg
            (self._compiled, self.grow_count, self.replayed_ops,
             self.compaction_count) = entry_stats
            raise
        self._committed = self._state
        return ok

    def _apply_padded(self, ops: dynamic.OpBatch, depth: int = 0
                      ) -> np.ndarray:
        if depth > _MAX_GROW_ROUNDS:
            raise RuntimeError("grow-and-replay did not converge; "
                               "max_edge_capacity too small for workload?")
        self._compiled.add((int(ops.kind.shape[0]), self._cfg))
        prev_ovf = int(self._state.overflow)
        self._state, ok = dynamic.apply_batch(self._state, ops, self._cfg)
        ok = np.asarray(ok).copy()
        if int(self._state.overflow) == prev_ovf:
            return ok
        failed = self._failed_add_lanes(ops, ok)
        if not failed.any():  # overflow already resolved by a later lane
            return ok
        self.grow()
        idx = np.nonzero(failed)[0]
        self.replayed_ops += len(idx)
        for sl, sub in self._sched.chunks(
                np.asarray(ops.kind)[idx], np.asarray(ops.u)[idx],
                np.asarray(ops.v)[idx]):
            n_real = sl.stop - sl.start
            sub_ok = self._apply_padded(sub, depth + 1)[:n_real]
            ok[idx[sl]] = sub_ok
        return ok

    def _failed_add_lanes(self, ops: dynamic.OpBatch, ok: np.ndarray
                          ) -> np.ndarray:
        """AddEdge lanes the table dropped on probe-bound overflow.

        A lane failed iff it is an in-range AddEdge, reported False, both
        endpoints are alive *after* the step (RemoveVertex linearizes
        first, so dead-endpoint lanes were never enabled), and its key is
        absent from the post-step table (present keys mean the False was a
        legitimate duplicate/already-present result).
        """
        kind = np.asarray(ops.kind)
        u = np.asarray(ops.u)
        v = np.asarray(ops.v)
        nv = self._cfg.n_vertices
        in_range = (u >= 0) & (u < nv) & (v >= 0) & (v < nv)
        cand = (kind == dynamic.ADD_EDGE) & in_range & ~ok
        if not cand.any():
            return cand
        alive = np.asarray(self._state.v_alive)
        cand &= alive[np.clip(u, 0, nv - 1)] & alive[np.clip(v, 0, nv - 1)]
        if not cand.any():
            return cand
        found, _ = et.lookup(self._state.edges, ops.u, ops.v,
                             self._cfg.max_probes)
        return cand & ~np.asarray(found)

    def grow(self, new_capacity: int | None = None):
        """Rehash the edge table into a larger power-of-two capacity and
        re-point ``cfg`` (subsequent steps re-jit under the new config)."""
        cap = new_capacity or self._cfg.edge_capacity * self._grow_factor
        table, cap = self._rehash_preserving(cap)
        self._state = self._state._replace(edges=table)
        self._cfg = dataclasses.replace(self._cfg, edge_capacity=cap)
        self.grow_count += 1

    def _rehash_preserving(self, cap: int):
        """Rehash into ``cap``, doubling further until every live edge
        survives migration.

        ``insert`` can itself exhaust the probe bound at the *target*
        capacity (different keys may collide there that did not collide at
        the source size), and it reports that only through its discarded
        ``placed`` mask -- so we verify by live count and retry bigger.
        """
        live_before, _ = et.fill_stats(self._state.edges)
        for _ in range(_MAX_GROW_ROUNDS):
            if self._max_edge_capacity and cap > self._max_edge_capacity:
                raise RuntimeError(
                    f"edge table would exceed max_edge_capacity "
                    f"({cap} > {self._max_edge_capacity})")
            table = _rehash(self._state.edges, cap, self._cfg.max_probes)
            live_after, _ = et.fill_stats(table)
            if int(live_after) == int(live_before):
                return table, cap
            cap *= self._grow_factor
        raise RuntimeError("table migration kept losing edges; "
                           "max_probes too small for workload?")

    def _maybe_compact(self):
        _, tomb = et.fill_stats(self._state.edges)
        if int(tomb) > self._compact_tomb_frac * self._cfg.edge_capacity:
            # rehash at the current capacity == compact, but verified: a
            # compaction that would drop an edge escalates to a grow.
            table, cap = self._rehash_preserving(self._cfg.edge_capacity)
            self._state = self._state._replace(edges=table)
            self._cfg = dataclasses.replace(self._cfg, edge_capacity=cap)
            self.compaction_count += 1

    # ---------------------------------------------------------- queries ---
    # All queries read the last *committed* state: a consistent snapshot
    # whose generation is returned alongside the value (the linearization
    # point of the paper's wait-free readers).

    def _in_range(self, ids) -> np.ndarray:
        ids = np.asarray(ids)
        return (ids >= 0) & (ids < self._cfg.n_vertices)

    def same_scc(self, u, v) -> Snapshot:
        """Batched SameSCC(u, v) (paper checkSCC, Alg. 23): absent or
        out-of-range endpoints answer False, never alias a real vertex."""
        st = self._committed
        res = community.check_scc(st, jnp.asarray(u, jnp.int32),
                                  jnp.asarray(v, jnp.int32))
        res = np.asarray(res) & self._in_range(u) & self._in_range(v)
        return Snapshot(res, int(st.gen))

    def reachable(self, u, v) -> Snapshot:
        """Batched reachability u[i] ⇝ v[i] on the committed snapshot."""
        st = self._committed
        res = _reachable_batch(st, jnp.asarray(u, jnp.int32),
                               jnp.asarray(v, jnp.int32),
                               self._cfg.max_inner)
        res = np.asarray(res) & self._in_range(u) & self._in_range(v)
        return Snapshot(res, int(st.gen))

    def scc_members(self, u) -> Snapshot:
        """bool[NV] membership mask of u's SCC on the committed snapshot."""
        st = self._committed
        if not self._in_range(u).all():
            return Snapshot(np.zeros(self._cfg.n_vertices, bool),
                            int(st.gen))
        res = _members(st, jnp.asarray(u, jnp.int32))
        return Snapshot(np.asarray(res), int(st.gen))

    # ------------------------------------------------------------- misc ---

    def edge_set(self) -> set:
        """Host copy of the live edge set (test/debug helper)."""
        t = self._committed.edges
        live = np.asarray(t.state) == int(et.LIVE)
        src = np.asarray(t.src)[live]
        dst = np.asarray(t.dst)[live]
        return set(zip(src.tolist(), dst.tolist()))

    def stats(self) -> dict:
        live, tomb = et.fill_stats(self._committed.edges)
        return {
            "gen": self.gen,
            "n_ccs": int(self._committed.n_ccs),
            "live_edges": int(live),
            "tombstones": int(tomb),
            "edge_capacity": self._cfg.edge_capacity,
            "overflow_total": int(self._committed.overflow),
            "grows": self.grow_count,
            "replayed_ops": self.replayed_ops,
            "compactions": self.compaction_count,
            "compile_count": self.compile_count,
        }
