"""Streaming SCC service: the paper's on-line system around ``apply_batch``.

The paper (arXiv:1804.01276) runs SMSCC as a *service*: a fixed thread pool
applies an unbounded stream of graph updates while readers issue wait-free
SameSCC/reachability queries.  ``dynamic.apply_batch`` is our compiled
analogue of one scheduling quantum; this module supplies the host-side
machinery that turns it into a long-running service:

grow-and-replay
    ``apply_batch`` can only report probe-bound overflow (the
    ``GraphState.overflow`` counter); it cannot grow the hash table because
    its shapes are static.  The service watches the per-step overflow
    *delta*, identifies exactly the AddEdge lanes whose key is missing from
    the post-step table, rehashes the table into a geometrically larger
    capacity (``edge_table.rehash``, jitted once per target capacity), and
    replays the failed lanes.  Invariant: **no accepted edge is ever lost**
    -- after ``apply()`` returns, the table contains every edge a
    sequential unbounded-table execution would contain, and the reported
    per-op results match that sequential history.

bucketed scheduling
    An unbounded stream has unbounded batch lengths; jit would recompile
    per length.  The scheduler (:class:`repro.launch.stream.BucketedScheduler`)
    cuts the stream into a small fixed set of padded shapes (NOP padding),
    so total XLA compilations are bounded by ``len(buckets) x #capacities``
    regardless of stream length.  ``compile_count`` tracks this bound.

snapshot queries
    States are immutable pytrees; the service keeps a pointer to the last
    *committed* state (updated only after a chunk fully applies, including
    any replay).  Readers therefore always see a consistent generation --
    the batched analogue of the paper's wait-free reader guarantee -- and
    every query result is stamped with the generation it was computed at.

concurrent-reader pipeline
    The updater path no longer forces a device->host sync per step: a
    chunk's bucket batches are dispatched through
    ``dynamic.apply_batch_inflight`` (async dispatch, optional buffer
    donation between steps), and the only host sync -- the per-step
    overflow delta -- is resolved behind a bounded in-flight window.  A
    chunk whose window stays overflow-free commits in one shot; any
    overflow aborts the fast path and the chunk re-runs on the serial
    grow-and-replay path from the untouched committed snapshot, so results
    are bit-identical either way.  The committed snapshot is
    double-buffered against donation (the pipeline steps off a private
    device copy), which is what lets a :class:`repro.core.broker.QueryBroker`
    serve readers from ``service.state`` while the next update step is
    still executing.  See ``docs/ARCHITECTURE.md`` for the full request
    lifecycle and ``docs/SERVICE_API.md`` for the consistency contract.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from functools import partial
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import community, dynamic, edge_table as et
from repro.core import graph_state as gs

_MAX_GROW_ROUNDS = 16


class Snapshot(NamedTuple):
    """A query result stamped with the SCC-partition generation it saw."""
    value: np.ndarray
    gen: int


@partial(jax.jit, static_argnames=("new_capacity", "max_probes"))
def _rehash(table: et.EdgeTable, new_capacity: int, max_probes: int):
    return et.rehash(table, new_capacity, max_probes)


@partial(jax.jit, static_argnames=("max_inner",))
def _reachable_batch(state: gs.GraphState, u, v, max_inner: int):
    """bool[Q]: u[i] ⇝ v[i] over live edges (u==v and alive counts)."""
    nv = state.ccid.shape[0]
    uu = jnp.clip(u, 0, nv - 1)
    vv = jnp.clip(v, 0, nv - 1)
    src, dst, live = gs.edge_coo(state)
    seeds = jnp.zeros((u.shape[0], nv), jnp.bool_).at[
        jnp.arange(u.shape[0]), uu].set(True)
    from repro.core import reach
    reached, _ = reach.multi_forward_reach(src, dst, live, seeds,
                                           state.v_alive, max_inner)
    ok = state.v_alive[uu] & state.v_alive[vv]
    return ok & reached[jnp.arange(u.shape[0]), vv]


@jax.jit
def _members(state: gs.GraphState, u):
    """bool[NV]: vertices in u's SCC (empty mask when u is dead)."""
    nv = state.ccid.shape[0]
    uu = jnp.clip(u, 0, nv - 1)
    lab = jnp.where(state.v_alive[uu], state.ccid[uu], nv)
    return state.v_alive & (state.ccid == lab)


@jax.jit
def _members_batch(state: gs.GraphState, u):
    """bool[Q, NV]: row i is the membership mask of u[i]'s SCC."""
    nv = state.ccid.shape[0]
    uu = jnp.clip(u, 0, nv - 1)
    lab = jnp.where(state.v_alive[uu], state.ccid[uu], nv)
    return state.v_alive[None, :] & (state.ccid[None, :] == lab[:, None])


def _ids_in_range(ids, nv: int) -> np.ndarray:
    ids = np.asarray(ids)
    return (ids >= 0) & (ids < nv)


# Snapshot-query primitives shared by SCCService and QueryBroker: each
# answers against an explicit pinned state (NOT the service's live pointer),
# which is what lets the broker serve a whole coalesced batch from one
# consistent generation.

def same_scc_on(state: gs.GraphState, cfg: gs.GraphConfig, u, v
                ) -> np.ndarray:
    """bool[Q]: SameSCC on a pinned snapshot; out-of-range ids answer
    False, never alias a clipped vertex."""
    res = community.check_scc(state, jnp.asarray(u, jnp.int32),
                              jnp.asarray(v, jnp.int32))
    return np.asarray(res) & _ids_in_range(u, cfg.n_vertices) \
        & _ids_in_range(v, cfg.n_vertices)


def reachable_on(state: gs.GraphState, cfg: gs.GraphConfig, u, v
                 ) -> np.ndarray:
    """bool[Q]: u[i] ⇝ v[i] on a pinned snapshot."""
    res = _reachable_batch(state, jnp.asarray(u, jnp.int32),
                           jnp.asarray(v, jnp.int32), cfg.max_inner)
    return np.asarray(res) & _ids_in_range(u, cfg.n_vertices) \
        & _ids_in_range(v, cfg.n_vertices)


def members_on(state: gs.GraphState, cfg: gs.GraphConfig, u) -> np.ndarray:
    """bool[Q, NV]: SCC membership masks on a pinned snapshot; rows of
    out-of-range ids are all-False."""
    res = np.array(_members_batch(state, jnp.asarray(u, jnp.int32)))
    res[~_ids_in_range(u, cfg.n_vertices)] = False
    return res


def community_of_on(state: gs.GraphState, cfg: gs.GraphConfig, u
                    ) -> np.ndarray:
    """int32[Q]: community (SCC) id on a pinned snapshot; out-of-range or
    dead ids answer the sentinel ``n_vertices``, never alias a clipped
    vertex (paper blongsToCommunity contract)."""
    lab = np.array(community.belongs_to_community(
        state, jnp.asarray(u, jnp.int32)))
    lab[~_ids_in_range(u, cfg.n_vertices)] = cfg.n_vertices
    return lab


def community_sizes_on(state: gs.GraphState, cfg: gs.GraphConfig
                       ) -> np.ndarray:
    """int32[NV]: community-size histogram (indexed by representative id)
    on a pinned snapshot."""
    return np.asarray(community.community_sizes(state))


class SCCService:
    """Host-side streaming wrapper: grow-and-replay + bucketed scheduling +
    generation-stamped snapshot queries over ``dynamic.apply_batch``."""

    def __init__(self, cfg: gs.GraphConfig,
                 buckets: Sequence[int] = (64, 256, 1024),
                 state: gs.GraphState | None = None,
                 grow_factor: int = 2,
                 max_edge_capacity: int | None = None,
                 compact_tomb_frac: float = 0.25,
                 inflight_window: int = 8,
                 donate: bool | None = None):
        from repro.launch.stream import BucketedScheduler
        self._cfg = cfg
        self._state = gs.empty(cfg) if state is None else state
        self._sched = BucketedScheduler(buckets)
        self._grow_factor = grow_factor
        self._max_edge_capacity = max_edge_capacity
        self._compact_tomb_frac = compact_tomb_frac
        # concurrent pipeline: how many dispatched steps may be in flight
        # before the oldest overflow delta is resolved (0 = serial path
        # only, the pre-pipeline behaviour); donation defaults to on
        # wherever XLA implements it (not CPU).
        self._inflight_window = inflight_window
        self._donate = (jax.default_backend() != "cpu"
                        ) if donate is None else donate
        self._committed = self._state
        # update-path serialization (many GraphClient sessions may share
        # one service) + commit notification for consistency-level waits
        self._apply_lock = threading.RLock()
        self._commit_cv = threading.Condition()
        # telemetry
        self._compiled: set = set()
        self.grow_count = 0
        self.replayed_ops = 0
        self.compaction_count = 0
        self.pipelined_chunks = 0
        self.fallback_chunks = 0
        # per-step repair-tier telemetry (dynamic.RepairStats resolved
        # lazily, next to the overflow delta)
        self.repair_tier_steps = {name: 0 for name in dynamic.TIER_NAMES}
        self.repair_region_v_max = 0
        self.repair_region_e_max = 0

    # ------------------------------------------------------------ state ---

    @property
    def cfg(self) -> gs.GraphConfig:
        return self._cfg

    @property
    def state(self) -> gs.GraphState:
        """Latest committed state (safe to checkpoint / query)."""
        return self._committed

    @property
    def gen(self) -> int:
        return int(self._committed.gen)

    @property
    def compile_count(self) -> int:
        """Distinct (step-path, batch-shape, graph-config) entries stepped
        so far -- an upper bound on *update-step* compiles.  The pipelined
        fast path and the serial replay path are counted as separate
        entries, so the bound is ``2 x len(buckets)`` per graph config
        (the serial entries only ever materialize on chunks that
        overflowed; on non-donating backends both paths actually share
        one jit entry, so real compiles come in under the bound).  Repair
        tiers never mint entries: tier dispatch is a runtime branch
        inside the one compiled step program.  Table
        rehashes (one per target capacity) and query batches (one per
        query shape) have their own, separately-cached jit entries not
        counted here."""
        return len(self._compiled)

    # ---------------------------------------------------------- updates ---

    def apply(self, kind, u, v) -> np.ndarray:
        """Deprecated raw entry point -- prefer
        :class:`repro.api.GraphClient` (typed ops, consistency levels).

        Kept as a shim for the internal layer and its tests; the CI gate
        (``scripts/ci.sh``) rejects ``.apply(`` call sites in examples,
        benchmarks, and the launch layer.
        """
        return self._apply_chunk(kind, u, v)

    def _apply_ops(self, kind, u, v):
        """GraphClient entry: apply a chunk and report the commit gen it
        is covered by, atomically w.r.t. concurrent client sessions."""
        with self._apply_lock:
            ok = self._apply_chunk(kind, u, v)
            return ok, self.gen

    def _apply_chunk(self, kind, u, v) -> np.ndarray:
        """Apply a variable-length op stream chunk; returns ok: bool[N].

        The chunk is cut into padded bucket batches; each batch goes
        through grow-and-replay so no AddEdge is ever dropped.  Results
        match the documented per-batch linearization applied bucket by
        bucket.

        Fast path: all batches are dispatched as in-flight device steps
        (no per-batch host sync; buffers donated step-to-step when the
        backend supports it) and the chunk commits after one deferred
        overflow check.  Any overflow aborts the fast path and the chunk
        re-runs on the serial grow-and-replay path from the untouched
        committed snapshot -- the two paths compute identical results, so
        callers cannot observe which one ran.
        """
        kind = np.asarray(kind, np.int32)
        u = np.asarray(u, np.int32)
        v = np.asarray(v, np.int32)
        with self._apply_lock:
            entry_state, entry_cfg = self._state, self._cfg
            entry_stats = (set(self._compiled), self.grow_count,
                           self.replayed_ops, self.compaction_count,
                           self.pipelined_chunks, self.fallback_chunks,
                           dict(self.repair_tier_steps),
                           self.repair_region_v_max,
                           self.repair_region_e_max)
            try:
                ok = None
                if self._inflight_window > 0:
                    ok = self._apply_pipelined(kind, u, v)
                if ok is None:  # overflow (or pipeline off): serial path
                    self.fallback_chunks += 1
                    self._state, self._cfg = entry_state, entry_cfg
                    ok = np.zeros(kind.shape[0], bool)
                    for sl, ops in self._sched.chunks(kind, u, v):
                        n_real = sl.stop - sl.start
                        ok[sl] = self._apply_padded(ops)[:n_real]
                else:
                    self.pipelined_chunks += 1
                self._maybe_compact()
            except Exception:
                # all-or-nothing chunk: never let a half-applied batch, a
                # cfg that no longer matches the table, or telemetry for
                # aborted work leak into the next chunk's commit
                self._state, self._cfg = entry_state, entry_cfg
                (self._compiled, self.grow_count, self.replayed_ops,
                 self.compaction_count, self.pipelined_chunks,
                 self.fallback_chunks, self.repair_tier_steps,
                 self.repair_region_v_max,
                 self.repair_region_e_max) = entry_stats
                raise
            with self._commit_cv:
                self._committed = self._state
                self._commit_cv.notify_all()
        return ok

    def wait_for_gen(self, gen: int, timeout: float | None = None) -> int:
        """Block until the committed generation reaches ``gen`` (the
        consistency-level hook used by AT_LEAST / READ_YOUR_WRITES reads);
        returns the committed generation at wake-up.  Every commit
        notifies under ``_commit_cv`` (the pointer is only ever advanced
        inside it), so a plain wait cannot miss a wakeup."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._commit_cv:
            while self.gen < gen:
                if deadline is None:
                    self._commit_cv.wait()
                else:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        break
                    self._commit_cv.wait(remaining)
            return self.gen

    def _apply_pipelined(self, kind, u, v) -> np.ndarray | None:
        """Dispatch the whole chunk without per-batch host syncs.

        Steps are enqueued back-to-back; each step's overflow delta is a
        dedicated output resolved only once ``inflight_window`` newer
        steps have been dispatched (or at drain).  Returns the per-op ok
        vector, or ``None`` if any step overflowed -- in which case
        nothing was committed and the caller replays the chunk on the
        serial grow-and-replay path.

        When donating, the pipeline steps off a private device copy of the
        committed snapshot (double buffering): readers keep a valid
        ``self._committed`` while XLA reuses the pipeline's own buffers
        step-to-step.
        """
        state = self._committed
        if self._donate:
            state = jax.tree_util.tree_map(jnp.copy, state)
        pending = []  # (chunk slice, in-flight ok device array)
        repair = []  # in-flight dynamic.RepairStats per step
        window: collections.deque = collections.deque()  # ovf deltas
        for sl, ops in self._sched.chunks(kind, u, v):
            self._compiled.add(
                ("pipelined", int(ops.kind.shape[0]), self._cfg))
            state, ok_dev, ovf, rstats = dynamic.apply_batch_inflight(
                state, ops, self._cfg, donate=self._donate)
            pending.append((sl, ok_dev))
            repair.append(rstats)
            window.append(ovf)
            if len(window) > self._inflight_window:
                if int(window.popleft()) != 0:
                    return None
        while window:
            if int(window.popleft()) != 0:
                return None
        self._state = state
        for rstats in repair:  # everything already executed: cheap syncs
            self._record_repair(rstats)
        ok = np.zeros(kind.shape[0], bool)
        for sl, ok_dev in pending:
            ok[sl] = np.asarray(ok_dev)[: sl.stop - sl.start]
        return ok

    def _record_repair(self, rstats: dynamic.RepairStats):
        self.repair_tier_steps[dynamic.TIER_NAMES[int(rstats.tier)]] += 1
        self.repair_region_v_max = max(self.repair_region_v_max,
                                       int(rstats.region_vertices))
        self.repair_region_e_max = max(self.repair_region_e_max,
                                       int(rstats.region_edges))

    def _apply_padded(self, ops: dynamic.OpBatch, depth: int = 0
                      ) -> np.ndarray:
        if depth > _MAX_GROW_ROUNDS:
            raise RuntimeError("grow-and-replay did not converge; "
                               "max_edge_capacity too small for workload?")
        self._compiled.add((int(ops.kind.shape[0]), self._cfg))
        self._state, ok, ovf, rstats = dynamic.apply_batch_async(
            self._state, ops, self._cfg)
        ok = np.asarray(ok).copy()
        self._record_repair(rstats)
        if int(ovf) == 0:
            return ok
        failed = self._failed_add_lanes(ops, ok)
        if not failed.any():  # overflow already resolved by a later lane
            return ok
        self.grow()
        idx = np.nonzero(failed)[0]
        self.replayed_ops += len(idx)
        for sl, sub in self._sched.chunks(
                np.asarray(ops.kind)[idx], np.asarray(ops.u)[idx],
                np.asarray(ops.v)[idx]):
            n_real = sl.stop - sl.start
            sub_ok = self._apply_padded(sub, depth + 1)[:n_real]
            ok[idx[sl]] = sub_ok
        return ok

    def _failed_add_lanes(self, ops: dynamic.OpBatch, ok: np.ndarray
                          ) -> np.ndarray:
        """AddEdge lanes the table dropped on probe-bound overflow.

        A lane failed iff it is an in-range AddEdge, reported False, both
        endpoints are alive *after* the step (RemoveVertex linearizes
        first, so dead-endpoint lanes were never enabled), and its key is
        absent from the post-step table (present keys mean the False was a
        legitimate duplicate/already-present result).
        """
        kind = np.asarray(ops.kind)
        u = np.asarray(ops.u)
        v = np.asarray(ops.v)
        nv = self._cfg.n_vertices
        in_range = (u >= 0) & (u < nv) & (v >= 0) & (v < nv)
        cand = (kind == dynamic.ADD_EDGE) & in_range & ~ok
        if not cand.any():
            return cand
        alive = np.asarray(self._state.v_alive)
        cand &= alive[np.clip(u, 0, nv - 1)] & alive[np.clip(v, 0, nv - 1)]
        if not cand.any():
            return cand
        found, _ = et.lookup(self._state.edges, ops.u, ops.v,
                             self._cfg.max_probes)
        return cand & ~np.asarray(found)

    def grow(self, new_capacity: int | None = None):
        """Rehash the edge table into a larger power-of-two capacity and
        re-point ``cfg`` (subsequent steps re-jit under the new config)."""
        cap = new_capacity or self._cfg.edge_capacity * self._grow_factor
        table, cap = self._rehash_preserving(cap)
        self._state = self._state._replace(edges=table)
        self._cfg = dataclasses.replace(self._cfg, edge_capacity=cap)
        self.grow_count += 1

    def _rehash_preserving(self, cap: int):
        """Rehash into ``cap``, doubling further until every live edge
        survives migration.

        ``insert`` can itself exhaust the probe bound at the *target*
        capacity (different keys may collide there that did not collide at
        the source size), and it reports that only through its discarded
        ``placed`` mask -- so we verify by live count and retry bigger.
        """
        live_before, _ = et.fill_stats(self._state.edges)
        for _ in range(_MAX_GROW_ROUNDS):
            if self._max_edge_capacity and cap > self._max_edge_capacity:
                raise RuntimeError(
                    f"edge table would exceed max_edge_capacity "
                    f"({cap} > {self._max_edge_capacity})")
            table = _rehash(self._state.edges, cap, self._cfg.max_probes)
            live_after, _ = et.fill_stats(table)
            if int(live_after) == int(live_before):
                return table, cap
            cap *= self._grow_factor
        raise RuntimeError("table migration kept losing edges; "
                           "max_probes too small for workload?")

    def _maybe_compact(self):
        _, tomb = et.fill_stats(self._state.edges)
        if int(tomb) > self._compact_tomb_frac * self._cfg.edge_capacity:
            # rehash at the current capacity == compact, but verified: a
            # compaction that would drop an edge escalates to a grow.
            table, cap = self._rehash_preserving(self._cfg.edge_capacity)
            self._state = self._state._replace(edges=table)
            self._cfg = dataclasses.replace(self._cfg, edge_capacity=cap)
            self.compaction_count += 1

    # ---------------------------------------------------------- queries ---
    # All queries read the last *committed* state: a consistent snapshot
    # whose generation is returned alongside the value (the linearization
    # point of the paper's wait-free readers).

    def _in_range(self, ids) -> np.ndarray:
        return _ids_in_range(ids, self._cfg.n_vertices)

    def same_scc(self, u, v) -> Snapshot:
        """Batched SameSCC(u, v) (paper checkSCC, Alg. 23): absent or
        out-of-range endpoints answer False, never alias a real vertex."""
        st = self._committed
        return Snapshot(same_scc_on(st, self._cfg, u, v), int(st.gen))

    def reachable(self, u, v) -> Snapshot:
        """Batched reachability u[i] ⇝ v[i] on the committed snapshot."""
        st = self._committed
        return Snapshot(reachable_on(st, self._cfg, u, v), int(st.gen))

    def scc_members(self, u) -> Snapshot:
        """bool[NV] membership mask of u's SCC on the committed snapshot."""
        st = self._committed
        if not self._in_range(u).all():
            return Snapshot(np.zeros(self._cfg.n_vertices, bool),
                            int(st.gen))
        res = _members(st, jnp.asarray(u, jnp.int32))
        return Snapshot(np.asarray(res), int(st.gen))

    def community_of(self, u) -> Snapshot:
        """Batched blongsToCommunity (paper §5.3) on the committed
        snapshot; int32 labels, sentinel ``n_vertices`` for absent ids."""
        st = self._committed
        return Snapshot(community_of_on(st, self._cfg, u), int(st.gen))

    def community_sizes(self) -> Snapshot:
        """Community-size histogram on the committed snapshot."""
        st = self._committed
        return Snapshot(community_sizes_on(st, self._cfg), int(st.gen))

    # ------------------------------------------------------------- misc ---

    def edge_set(self) -> set:
        """Host copy of the live edge set (test/debug helper)."""
        t = self._committed.edges
        live = np.asarray(t.state) == int(et.LIVE)
        src = np.asarray(t.src)[live]
        dst = np.asarray(t.dst)[live]
        return set(zip(src.tolist(), dst.tolist()))

    def stats(self) -> dict:
        live, tomb = et.fill_stats(self._committed.edges)
        return {
            "gen": self.gen,
            "n_ccs": int(self._committed.n_ccs),
            "live_edges": int(live),
            "tombstones": int(tomb),
            "edge_capacity": self._cfg.edge_capacity,
            "overflow_total": int(self._committed.overflow),
            "grows": self.grow_count,
            "replayed_ops": self.replayed_ops,
            "compactions": self.compaction_count,
            "compile_count": self.compile_count,
            "pipelined_chunks": self.pipelined_chunks,
            "fallback_chunks": self.fallback_chunks,
            "repair_dense_steps": self.repair_tier_steps["dense"],
            "repair_compact_steps": self.repair_tier_steps["compact"],
            "repair_full_steps": self.repair_tier_steps["full"],
            "repair_region_v_max": self.repair_region_v_max,
            "repair_region_e_max": self.repair_region_e_max,
        }
