"""Streaming SCC service: the paper's on-line system around ``apply_batch``.

The paper (arXiv:1804.01276) runs SMSCC as a *service*: a fixed thread pool
applies an unbounded stream of graph updates while readers issue wait-free
SameSCC/reachability queries.  ``dynamic.apply_batch`` is our compiled
analogue of one scheduling quantum; this module supplies the host-side
machinery that turns it into a long-running service:

grow-and-replay
    ``apply_batch`` can only report probe-bound overflow (the
    ``GraphState.overflow`` counter); it cannot grow the hash table because
    its shapes are static.  The service watches the per-step overflow
    *delta*, identifies exactly the AddEdge lanes whose key is missing from
    the post-step table, rehashes the table into a geometrically larger
    capacity (``edge_table.rehash``, jitted once per target capacity), and
    replays the failed lanes.  Invariant: **no accepted edge is ever lost**
    -- after ``apply()`` returns, the table contains every edge a
    sequential unbounded-table execution would contain, and the reported
    per-op results match that sequential history.

bucketed scheduling
    An unbounded stream has unbounded batch lengths; jit would recompile
    per length.  The scheduler (:class:`repro.launch.stream.BucketedScheduler`)
    cuts the stream into a small fixed set of padded shapes (NOP padding),
    so total XLA compilations are bounded by ``len(buckets) x #capacities``
    regardless of stream length.  ``compile_count`` tracks this bound.

snapshot queries
    States are immutable pytrees; the service keeps a pointer to the last
    *committed* state (updated only after a chunk fully applies, including
    any replay).  Readers therefore always see a consistent generation --
    the batched analogue of the paper's wait-free reader guarantee -- and
    every query result is stamped with the generation it was computed at.

concurrent-reader pipeline + fused scan engine
    The updater path no longer forces a device->host sync (or even a
    dispatch) per step: runs of same-bucket batches are stacked into
    *super-chunks* from a geometric scan-length registry and dispatched
    through ``dynamic.apply_batch_scan_inflight`` -- one fused
    ``lax.scan`` program per (scan length, bucket, cfg), one dispatch
    and one deferred ``jax.device_get`` of the stacked
    (ok, overflow, RepairStats) tuple per super-chunk, optional buffer
    donation between super-chunks -- resolved behind a bounded in-flight
    window.  A chunk whose window stays overflow-free commits in one
    shot; overflow aborts the fast path and the serial grow-and-replay
    path replays from the first chunk of the offending super-chunk
    (resolved-clean prefix kept) when its input state is still alive,
    else from the untouched committed snapshot -- results are
    bit-identical every way.  The committed snapshot is double-buffered
    against donation (the pipeline steps off a private device copy),
    which is what lets a :class:`repro.core.broker.QueryBroker` serve
    readers from ``service.state`` while the next update step is still
    executing.  With ``proactive_grow`` the service additionally
    rehashes ahead of a chunk whose deduped AddEdge lanes cannot fit,
    keeping growth waves off the dispatch critical path.  See
    ``docs/ARCHITECTURE.md`` for the full request lifecycle and
    ``docs/SERVICE_API.md`` for the consistency contract.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from functools import partial
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import community, dynamic, edge_table as et
from repro.core import graph_state as gs
from repro.fault import errors as fault_errors

_MAX_GROW_ROUNDS = 16


class Snapshot(NamedTuple):
    """A query result stamped with the SCC-partition generation it saw."""
    value: np.ndarray
    gen: int


@partial(jax.jit, static_argnames=("new_capacity", "max_probes", "impl"))
def _rehash(table: et.EdgeTable, new_capacity: int, max_probes: int,
            impl: str = "xla"):
    return et.rehash(table, new_capacity, max_probes, impl=impl)


@partial(jax.jit, static_argnames=("max_inner", "impl"))
def _reachable_batch(state: gs.GraphState, u, v, max_inner: int,
                     impl: str = "xla"):
    """bool[Q]: u[i] ⇝ v[i] over live edges (u==v and alive counts)."""
    nv = state.ccid.shape[0]
    uu = jnp.clip(u, 0, nv - 1)
    vv = jnp.clip(v, 0, nv - 1)
    src, dst, live = gs.edge_coo(state)
    seeds = jnp.zeros((u.shape[0], nv), jnp.bool_).at[
        jnp.arange(u.shape[0]), uu].set(True)
    from repro.core import reach
    reached, _ = reach.multi_forward_reach(src, dst, live, seeds,
                                           state.v_alive, max_inner,
                                           impl=impl)
    ok = state.v_alive[uu] & state.v_alive[vv]
    return ok & reached[jnp.arange(u.shape[0]), vv]


@jax.jit
def _members(state: gs.GraphState, u):
    """bool[NV]: vertices in u's SCC (empty mask when u is dead)."""
    nv = state.ccid.shape[0]
    uu = jnp.clip(u, 0, nv - 1)
    lab = jnp.where(state.v_alive[uu], state.ccid[uu], nv)
    return state.v_alive & (state.ccid == lab)


@jax.jit
def _members_batch(state: gs.GraphState, u):
    """bool[Q, NV]: row i is the membership mask of u[i]'s SCC."""
    nv = state.ccid.shape[0]
    uu = jnp.clip(u, 0, nv - 1)
    lab = jnp.where(state.v_alive[uu], state.ccid[uu], nv)
    return state.v_alive[None, :] & (state.ccid[None, :] == lab[:, None])


def _ids_in_range(ids, nv: int) -> np.ndarray:
    ids = np.asarray(ids)
    return (ids >= 0) & (ids < nv)


# Snapshot-query primitives shared by SCCService and QueryBroker: each
# answers against an explicit pinned state (NOT the service's live pointer),
# which is what lets the broker serve a whole coalesced batch from one
# consistent generation.

def same_scc_on(state: gs.GraphState, cfg: gs.GraphConfig, u, v
                ) -> np.ndarray:
    """bool[Q]: SameSCC on a pinned snapshot; out-of-range ids answer
    False, never alias a clipped vertex."""
    res = community.check_scc(state, jnp.asarray(u, jnp.int32),
                              jnp.asarray(v, jnp.int32))
    return np.asarray(res) & _ids_in_range(u, cfg.n_vertices) \
        & _ids_in_range(v, cfg.n_vertices)


def reachable_on(state: gs.GraphState, cfg: gs.GraphConfig, u, v
                 ) -> np.ndarray:
    """bool[Q]: u[i] ⇝ v[i] on a pinned snapshot."""
    res = _reachable_batch(state, jnp.asarray(u, jnp.int32),
                           jnp.asarray(v, jnp.int32), cfg.max_inner,
                           impl=cfg.sparse_impl)
    return np.asarray(res) & _ids_in_range(u, cfg.n_vertices) \
        & _ids_in_range(v, cfg.n_vertices)


def members_on(state: gs.GraphState, cfg: gs.GraphConfig, u) -> np.ndarray:
    """bool[Q, NV]: SCC membership masks on a pinned snapshot; rows of
    out-of-range ids are all-False."""
    res = np.array(_members_batch(state, jnp.asarray(u, jnp.int32)))
    res[~_ids_in_range(u, cfg.n_vertices)] = False
    return res


def community_of_on(state: gs.GraphState, cfg: gs.GraphConfig, u
                    ) -> np.ndarray:
    """int32[Q]: community (SCC) id on a pinned snapshot; out-of-range or
    dead ids answer the sentinel ``n_vertices``, never alias a clipped
    vertex (paper blongsToCommunity contract)."""
    lab = np.array(community.belongs_to_community(
        state, jnp.asarray(u, jnp.int32)))
    lab[~_ids_in_range(u, cfg.n_vertices)] = cfg.n_vertices
    return lab


def community_sizes_on(state: gs.GraphState, cfg: gs.GraphConfig
                       ) -> np.ndarray:
    """int32[NV]: community-size histogram (indexed by representative id)
    on a pinned snapshot."""
    return np.asarray(community.community_sizes(state))


class SCCService:
    """Host-side streaming wrapper: grow-and-replay + bucketed scheduling +
    generation-stamped snapshot queries over ``dynamic.apply_batch``."""

    def __init__(self, cfg: gs.GraphConfig,
                 buckets: Sequence[int] = (64, 256, 1024),
                 state: gs.GraphState | None = None,
                 grow_factor: int = 2,
                 max_edge_capacity: int | None = None,
                 compact_tomb_frac: float = 0.25,
                 inflight_window: int = 8,
                 donate: bool | None = None,
                 scan_lengths: Sequence[int] = (1, 4, 16),
                 proactive_grow: bool = False):
        from repro.launch.stream import BucketedScheduler
        self._cfg = cfg
        self._state = gs.empty(cfg) if state is None else state
        self._sched = BucketedScheduler(buckets)
        self._grow_factor = grow_factor
        self._max_edge_capacity = max_edge_capacity
        self._compact_tomb_frac = compact_tomb_frac
        # concurrent pipeline: how many dispatched super-chunks may be in
        # flight before the oldest (ok, ovf, repair) tuple is resolved
        # (0 = serial path only, the pre-pipeline behaviour); donation
        # defaults to on wherever XLA implements it (not CPU).
        self._inflight_window = inflight_window
        self._donate = (jax.default_backend() != "cpu"
                        ) if donate is None else donate
        # scan-length registry (geometric, like the bucket registry): a
        # run of K same-bucket chunks is cut into the largest registered
        # lengths and each group runs as ONE fused lax.scan dispatch with
        # one deferred host transfer.  1 is always in the registry, so no
        # super-chunk is ever padded with NOP steps (generation counting
        # stays identical to the serial path).
        self._scan_lengths = tuple(sorted({int(s) for s in scan_lengths}
                                          | {1}))
        # proactive growth: rehash ahead of a chunk whose AddEdge lanes
        # cannot possibly fit the current table (live + adds > capacity),
        # instead of letting the chunk overflow and replay.  Pure
        # heuristic -- reactive grow-and-replay remains the correctness
        # backstop -- but it keeps growth off the dispatch critical path
        # (no doomed pipelined execution, no serial re-run, fewer step
        # recompiles per growth wave).
        self._proactive_grow = proactive_grow
        # host-side upper bound on the live edge count (true live never
        # exceeds capacity, so this needs no boot sync); tightened
        # whenever a rehash or the proactive probe pays a sync anyway
        self._live_ub = cfg.edge_capacity
        self._committed = self._state
        # update-path serialization (many GraphClient sessions may share
        # one service) + commit notification for consistency-level waits
        self._apply_lock = threading.RLock()
        self._commit_cv = threading.Condition()
        # idempotent re-submit window: per client session, the last
        # applied (seq, ok, gen) -- a retried chunk whose first attempt
        # actually committed (the ack was lost to a fault downstream)
        # returns the recorded result instead of double-applying
        self._session_results: collections.OrderedDict = \
            collections.OrderedDict()
        self._session_window = 4096
        self.deduped_resubmits = 0
        # telemetry
        self._compiled: set = set()
        self.grow_count = 0
        self.proactive_grows = 0
        self.replayed_ops = 0
        self.compaction_count = 0
        self.pipelined_chunks = 0
        self.fallback_chunks = 0
        self.scanned_chunks = 0
        self.scan_dispatches = 0
        # per-step repair-tier telemetry (dynamic.RepairStats resolved
        # lazily, next to the overflow delta; "skipped" counts steps the
        # repair gate proved structure-preserving)
        self.repair_tier_steps = {name: 0 for name in dynamic.TIER_NAMES}
        self.repair_region_v_max = 0
        self.repair_region_e_max = 0

    # ------------------------------------------------------------ state ---

    @property
    def cfg(self) -> gs.GraphConfig:
        return self._cfg

    @property
    def state(self) -> gs.GraphState:
        """Latest committed state (safe to checkpoint / query)."""
        return self._committed

    @property
    def gen(self) -> int:
        return int(self._committed.gen)

    @property
    def compile_count(self) -> int:
        """Distinct (step-path, batch-shape, graph-config) entries stepped
        so far -- an upper bound on *update-step* compiles.  Per graph
        config the entries are: one fused-scan program per (scan length
        > 1, bucket) pair, one single-step pipelined program per bucket
        (super-chunks of length 1 reuse it), and one serial
        grow-and-replay program per bucket -- the bound is
        ``len(buckets) x (len(scan_lengths) + 1)`` per config.  The
        serial entries only ever materialize on chunks that overflowed;
        on non-donating backends the single-step pipelined and serial
        paths actually share one jit entry, so real compiles come in
        under the bound.  Repair tiers and the repair gate never mint
        entries: both are runtime branches inside the one compiled step
        program.  Table rehashes (one per target capacity) and query
        batches (one per query shape) have their own, separately-cached
        jit entries not counted here."""
        return len(self._compiled)

    # ---------------------------------------------------------- updates ---

    def _apply_ops(self, kind, u, v, *, session=None, seq=None):
        """GraphClient entry: apply a chunk and report the commit gen it
        is covered by, atomically w.r.t. concurrent client sessions.

        ``(session, seq)`` is the client's idempotency key: a re-submit
        of the session's last applied sequence number returns the
        recorded (ok, gen) without re-applying -- the retry safety net
        when an ack is lost to a downstream fault.  The window is one
        chunk deep per session, which is exactly what a serial retrying
        client needs (it never has two chunks in flight)."""
        with self._apply_lock:
            if session is not None:
                hit = self._session_results.get(session)
                if hit is not None and hit[0] == seq:
                    self.deduped_resubmits += 1
                    return hit[1], hit[2]
            ok = self._apply_chunk(kind, u, v)
            if session is not None:
                self._session_results[session] = (seq, ok, self.gen)
                self._session_results.move_to_end(session)
                while len(self._session_results) > self._session_window:
                    self._session_results.popitem(last=False)
            return ok, self.gen

    _STAT_ATTRS = ("grow_count", "proactive_grows", "replayed_ops",
                   "compaction_count", "pipelined_chunks",
                   "fallback_chunks", "scanned_chunks", "scan_dispatches",
                   "repair_region_v_max", "repair_region_e_max")

    def _stats_snapshot(self) -> dict:
        snap = {a: getattr(self, a) for a in self._STAT_ATTRS}
        snap["_compiled"] = set(self._compiled)
        snap["repair_tier_steps"] = dict(self.repair_tier_steps)
        return snap

    def _stats_restore(self, snap: dict):
        for a in self._STAT_ATTRS:
            setattr(self, a, snap[a])
        self._compiled = snap["_compiled"]
        self.repair_tier_steps = snap["repair_tier_steps"]

    def _apply_chunk(self, kind, u, v) -> np.ndarray:
        """Apply a variable-length op stream chunk; returns ok: bool[N].

        The chunk is cut into padded bucket batches; each batch goes
        through grow-and-replay so no AddEdge is ever dropped.  Results
        match the documented per-batch linearization applied bucket by
        bucket.

        Fast path: the bucket batches are grouped into scan-length
        super-chunks and dispatched as fused in-flight ``lax.scan`` steps
        (one dispatch and one deferred host transfer per super-chunk;
        buffers donated super-chunk-to-super-chunk when the backend
        supports it) and the chunk commits after the deferred overflow
        checks drain clean.  Overflow anywhere aborts the fast path and
        the chunk re-runs on the serial grow-and-replay path, replaying
        only from the first chunk of the offending super-chunk when its
        input state is still alive (always, unless donation consumed it
        -- then from the untouched committed snapshot).  Every path
        computes identical results, so callers cannot observe which ran.
        """
        kind = np.asarray(kind, np.int32)
        u = np.asarray(u, np.int32)
        v = np.asarray(v, np.int32)
        with self._apply_lock:
            entry_state, entry_cfg = self._state, self._cfg
            entry_stats = self._stats_snapshot()
            try:
                if self._proactive_grow:
                    self._maybe_grow_proactive(kind, u, v)
                # the chunk's base: after any proactive growth (a replay
                # from scratch must not undo the rehash, only the ops)
                base_state, base_cfg = self._state, self._cfg
                ok, replay = None, (0, None)
                if self._inflight_window > 0:
                    ok, replay = self._apply_pipelined(kind, u, v)
                if replay is not None:  # overflow (or pipeline off)
                    start, restore = replay
                    self.fallback_chunks += 1
                    if restore is None:  # donated / pipeline off: restart
                        start = 0
                        self._state, self._cfg = base_state, base_cfg
                        ok = np.zeros(kind.shape[0], bool)
                    else:  # prefix super-chunks stay applied
                        self._state = restore
                    for sl, ops in self._sched.chunks(kind[start:],
                                                      u[start:], v[start:]):
                        n_real = sl.stop - sl.start
                        ok[start + sl.start:start + sl.start + n_real] = \
                            self._apply_padded(ops)[:n_real]
                else:
                    self.pipelined_chunks += 1
                # inserts can only add this chunk's AddEdge lanes; keep
                # the host-side live bound current without a sync
                self._live_ub = min(
                    self._cfg.edge_capacity,
                    self._live_ub + int(np.sum(kind == dynamic.ADD_EDGE)))
                self._maybe_compact()
            except Exception:
                # all-or-nothing chunk: never let a half-applied batch, a
                # cfg that no longer matches the table, or telemetry for
                # aborted work leak into the next chunk's commit
                self._state, self._cfg = entry_state, entry_cfg
                self._stats_restore(entry_stats)
                raise
            with self._commit_cv:
                self._committed = self._state
                self._commit_cv.notify_all()
        return ok

    def wait_for_gen(self, gen: int, timeout: float | None = None) -> int:
        """Block until the committed generation reaches ``gen`` (the
        consistency-level hook used by AT_LEAST / READ_YOUR_WRITES reads);
        returns the committed generation at wake-up.  Every commit
        notifies under ``_commit_cv`` (the pointer is only ever advanced
        inside it), so a plain wait cannot miss a wakeup."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._commit_cv:
            while self.gen < gen:
                if deadline is None:
                    self._commit_cv.wait()
                else:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        break
                    self._commit_cv.wait(remaining)
            return self.gen

    def _maybe_grow_proactive(self, kind: np.ndarray, u: np.ndarray,
                              v: np.ndarray):
        """Grow ahead of a chunk whose AddEdge lanes cannot all fit.

        Heuristic trigger, exact effect.  The chunk's AddEdge keys are
        deduped and probed against the table (re-adds of live edges can
        never take a slot), so a steady-state re-add chunk never
        triggers a spurious rehash; the chunk's remove lanes are
        subtracted as a crude proxy for same-chunk frees (edge removals
        and vertex-kill incident trims land *before* the adds in each
        batch's phase order, so churn-heavy mixes keep fitting the
        table).  The cheap host-side live upper bound short-circuits
        the device probe in the common no-pressure case.  The effect is
        exact (rehash preserves every live edge) and a missed or
        under-prediction is harmless: reactive grow-and-replay still
        backstops any probe-bound overflow.
        """
        adds = kind == dynamic.ADD_EDGE
        n_add_raw = int(np.sum(adds))
        if n_add_raw == 0:
            return
        if self._live_ub + n_add_raw <= self._cfg.edge_capacity:
            return  # cannot overflow even if every add is new: no sync
        live = int(et.fill_stats(self._state.edges)[0])
        self._live_ub = live  # refresh the bound while we paid the sync
        n_rem = int(np.sum((kind == dynamic.REM_EDGE)
                           | (kind == dynamic.REM_VERTEX)))
        keys = np.unique(np.stack([u[adds], v[adds]], axis=1), axis=0)
        if live + keys.shape[0] - n_rem <= self._cfg.edge_capacity:
            return  # crude estimate fits: skip the table probe
        # the crude estimate indicates growth: confirm by probing the
        # deduped keys against the table, so re-adds of live edges never
        # trigger a rehash.  Padded to a power-of-two lane count so the
        # probe's cached XLA shapes stay bounded per capacity.
        n_keys = keys.shape[0]
        n_pad = 1 << max(0, (n_keys - 1).bit_length())
        ku = np.full(n_pad, -1, np.int32)
        kv = np.full(n_pad, -1, np.int32)
        ku[:n_keys] = keys[:, 0]
        kv[:n_keys] = keys[:, 1]
        found, _ = et.lookup(self._state.edges, jnp.asarray(ku),
                             jnp.asarray(kv), self._cfg.max_probes,
                             impl=self._cfg.sparse_impl)
        n_new = int(np.sum(~np.asarray(found)[:n_keys]))
        predicted = live + n_new - n_rem
        if predicted <= self._cfg.edge_capacity:
            return
        cap = self._cfg.edge_capacity
        while cap < 2 * predicted:  # land at <= 50% load
            cap *= self._grow_factor
        if self._max_edge_capacity:
            while cap > self._max_edge_capacity:
                cap //= self._grow_factor
            if cap <= self._cfg.edge_capacity:
                return  # capped out: let the reactive path report it
        self.grow(cap)
        self.proactive_grows += 1

    class _InFlight(NamedTuple):
        """One dispatched super-chunk awaiting its deferred resolution."""
        slices: list          # chunk slices covered, in scan order
        ok: object            # bool[K, B] (or bool[B] when K == 1) device
        ovf: object           # int32[K] (or int32[]) device
        rstats: object        # RepairStats, int32[K] (or []) leaves
        entry: object         # input GraphState; None when donated away
        scanned: bool         # ran through the fused scan program

    def _apply_pipelined(self, kind, u, v
                         ) -> tuple:
        """Dispatch the whole chunk as fused super-chunks, no per-batch
        host syncs.

        The bucket batches are grouped by the scan-length registry; each
        group of K > 1 runs as ONE ``dynamic.apply_batch_scan`` dispatch
        (singletons reuse the single-step in-flight entry).  A
        super-chunk's (ok, overflow, repair) outputs are resolved in ONE
        ``jax.device_get`` only once ``inflight_window`` newer
        super-chunks have been dispatched (or at drain).

        Returns ``(ok, replay)``: ``replay`` is ``None`` when the whole
        chunk applied cleanly (``self._state`` advanced, ``ok``
        complete), else ``(start, state)`` -- the caller must re-run ops
        from chunk offset ``start`` on the serial grow-and-replay path.
        ``state`` is the offending super-chunk's input state (its prefix
        is already applied and ``ok[:start]`` filled), or ``None`` when
        donation consumed it, in which case the whole chunk must restart
        (``start`` is then ignored).

        When donating, the pipeline steps off a private device copy of
        the current state (double buffering): readers keep a valid
        ``self._committed`` while XLA reuses the pipeline's own buffers
        super-chunk-to-super-chunk.  On non-donating backends each
        in-flight record keeps its input state alive (at most
        ``inflight_window + 1`` states) -- the partial-replay anchor.
        """
        state = self._state
        if self._donate:
            state = jax.tree_util.tree_map(jnp.copy, state)
        ok = np.zeros(kind.shape[0], bool)
        pending: collections.deque = collections.deque()
        # telemetry of resolved-clean super-chunks, committed only for
        # work that stays applied: recording eagerly would double-count
        # the prefix when a donated pipeline aborts and the whole chunk
        # replays through _apply_padded (which records its own steps)
        repair_rows: list = []
        scanned = 0

        def resolve_oldest():
            """One host transfer for the oldest super-chunk; returns the
            record iff it overflowed (else applies its ok rows/stats)."""
            nonlocal scanned
            rec = pending.popleft()
            ok_h, ovf_h, r_h = jax.device_get((rec.ok, rec.ovf,
                                               rec.rstats))
            if np.any(ovf_h):
                return rec
            for sl, row in zip(rec.slices, np.atleast_2d(ok_h)):
                ok[sl] = row[: sl.stop - sl.start]
            repair_rows.extend(zip(np.atleast_1d(r_h.tier),
                                   np.atleast_1d(r_h.region_vertices),
                                   np.atleast_1d(r_h.region_edges)))
            if rec.scanned:
                scanned += len(rec.slices)
            return None

        def commit_telemetry():
            for t, rv, re_ in repair_rows:
                self._record_repair(int(t), int(rv), int(re_))
            self.scanned_chunks += scanned

        bad = None
        for slices, ops in self._sched.super_chunks(kind, u, v,
                                                    self._scan_lengths):
            k, b = len(slices), int(ops.kind.shape[1])
            entry = None if self._donate else state
            if k == 1:
                self._compiled.add(("pipelined", b, self._cfg))
                state, ok_dev, ovf, rstats = dynamic.apply_batch_inflight(
                    state, dynamic.OpBatch(ops.kind[0], ops.u[0],
                                           ops.v[0]),
                    self._cfg, donate=self._donate)
            else:
                self._compiled.add(("scan", k, b, self._cfg))
                state, ok_dev, ovf, rstats = \
                    dynamic.apply_batch_scan_inflight(
                        state, ops, self._cfg, donate=self._donate)
                self.scan_dispatches += 1
            pending.append(self._InFlight(slices, ok_dev, ovf, rstats,
                                          entry, k > 1))
            if len(pending) > self._inflight_window:
                bad = resolve_oldest()
                if bad is not None:
                    break
        while bad is None and pending:
            bad = resolve_oldest()
        if bad is not None:
            if bad.entry is not None:  # prefix stays applied: record it
                commit_telemetry()
            return ok, (bad.slices[0].start, bad.entry)
        self._state = state
        commit_telemetry()
        return ok, None

    def _record_repair(self, tier: int, region_v: int, region_e: int):
        self.repair_tier_steps[dynamic.TIER_NAMES[tier]] += 1
        self.repair_region_v_max = max(self.repair_region_v_max, region_v)
        self.repair_region_e_max = max(self.repair_region_e_max, region_e)

    def _apply_padded(self, ops: dynamic.OpBatch, depth: int = 0
                      ) -> np.ndarray:
        if depth > _MAX_GROW_ROUNDS:
            raise fault_errors.CapacityExhausted(
                "grow-and-replay did not converge; "
                "max_edge_capacity too small for workload?")
        self._compiled.add((int(ops.kind.shape[0]), self._cfg))
        self._state, ok_dev, ovf_dev, rstats = dynamic.apply_batch_async(
            self._state, ops, self._cfg)
        # one coalesced host transfer for the step's whole telemetry tuple
        ok_h, ovf, r_h = jax.device_get((ok_dev, ovf_dev, rstats))
        ok = np.array(ok_h)  # own the buffer: replay writes into it below
        self._record_repair(int(r_h.tier), int(r_h.region_vertices),
                            int(r_h.region_edges))
        if int(ovf) == 0:
            return ok
        failed = self._failed_add_lanes(ops, ok)
        if not failed.any():  # overflow already resolved by a later lane
            return ok
        self.grow()
        idx = np.nonzero(failed)[0]
        self.replayed_ops += len(idx)
        for sl, sub in self._sched.chunks(
                np.asarray(ops.kind)[idx], np.asarray(ops.u)[idx],
                np.asarray(ops.v)[idx]):
            n_real = sl.stop - sl.start
            sub_ok = self._apply_padded(sub, depth + 1)[:n_real]
            ok[idx[sl]] = sub_ok
        return ok

    def _failed_add_lanes(self, ops: dynamic.OpBatch, ok: np.ndarray
                          ) -> np.ndarray:
        """AddEdge lanes the table dropped on probe-bound overflow.

        A lane failed iff it is an in-range AddEdge, reported False, both
        endpoints are alive *after* the step (RemoveVertex linearizes
        first, so dead-endpoint lanes were never enabled), and its key is
        absent from the post-step table (present keys mean the False was a
        legitimate duplicate/already-present result).
        """
        kind = np.asarray(ops.kind)
        u = np.asarray(ops.u)
        v = np.asarray(ops.v)
        nv = self._cfg.n_vertices
        in_range = (u >= 0) & (u < nv) & (v >= 0) & (v < nv)
        cand = (kind == dynamic.ADD_EDGE) & in_range & ~ok
        if not cand.any():
            return cand
        alive = np.asarray(self._state.v_alive)
        cand &= alive[np.clip(u, 0, nv - 1)] & alive[np.clip(v, 0, nv - 1)]
        if not cand.any():
            return cand
        found, _ = et.lookup(self._state.edges, ops.u, ops.v,
                             self._cfg.max_probes,
                             impl=self._cfg.sparse_impl)
        return cand & ~np.asarray(found)

    def grow(self, new_capacity: int | None = None):
        """Rehash the edge table into a larger power-of-two capacity and
        re-point ``cfg`` (subsequent steps re-jit under the new config)."""
        cap = new_capacity or self._cfg.edge_capacity * self._grow_factor
        table, cap = self._rehash_preserving(cap)
        self._state = self._state._replace(edges=table)
        self._cfg = dataclasses.replace(self._cfg, edge_capacity=cap)
        self.grow_count += 1

    def _rehash_preserving(self, cap: int):
        """Rehash into ``cap``, doubling further until every live edge
        survives migration.

        ``insert`` can itself exhaust the probe bound at the *target*
        capacity (different keys may collide there that did not collide at
        the source size), and it reports that only through its discarded
        ``placed`` mask -- so we verify by live count and retry bigger.
        """
        live_before, _ = et.fill_stats(self._state.edges)
        for _ in range(_MAX_GROW_ROUNDS):
            if self._max_edge_capacity and cap > self._max_edge_capacity:
                raise fault_errors.CapacityExhausted(
                    f"edge table would exceed max_edge_capacity "
                    f"({cap} > {self._max_edge_capacity})")
            table = _rehash(self._state.edges, cap, self._cfg.max_probes,
                            impl=self._cfg.sparse_impl)
            live_after, _ = et.fill_stats(table)
            if int(live_after) == int(live_before):
                self._live_ub = int(live_after)  # sync already paid
                return table, cap
            cap *= self._grow_factor
        raise fault_errors.CapacityExhausted(
            "table migration kept losing edges; "
            "max_probes too small for workload?")

    def _maybe_compact(self):
        _, tomb = et.fill_stats(self._state.edges)
        if int(tomb) > self._compact_tomb_frac * self._cfg.edge_capacity:
            # rehash at the current capacity == compact, but verified: a
            # compaction that would drop an edge escalates to a grow.
            table, cap = self._rehash_preserving(self._cfg.edge_capacity)
            self._state = self._state._replace(edges=table)
            self._cfg = dataclasses.replace(self._cfg, edge_capacity=cap)
            self.compaction_count += 1

    # ---------------------------------------------------------- queries ---
    # All queries read the last *committed* state: a consistent snapshot
    # whose generation is returned alongside the value (the linearization
    # point of the paper's wait-free readers).

    def _in_range(self, ids) -> np.ndarray:
        return _ids_in_range(ids, self._cfg.n_vertices)

    def same_scc(self, u, v) -> Snapshot:
        """Batched SameSCC(u, v) (paper checkSCC, Alg. 23): absent or
        out-of-range endpoints answer False, never alias a real vertex."""
        st = self._committed
        return Snapshot(same_scc_on(st, self._cfg, u, v), int(st.gen))

    def reachable(self, u, v) -> Snapshot:
        """Batched reachability u[i] ⇝ v[i] on the committed snapshot."""
        st = self._committed
        return Snapshot(reachable_on(st, self._cfg, u, v), int(st.gen))

    def scc_members(self, u) -> Snapshot:
        """bool[NV] membership mask of u's SCC on the committed snapshot."""
        st = self._committed
        if not self._in_range(u).all():
            return Snapshot(np.zeros(self._cfg.n_vertices, bool),
                            int(st.gen))
        res = _members(st, jnp.asarray(u, jnp.int32))
        return Snapshot(np.asarray(res), int(st.gen))

    def community_of(self, u) -> Snapshot:
        """Batched blongsToCommunity (paper §5.3) on the committed
        snapshot; int32 labels, sentinel ``n_vertices`` for absent ids."""
        st = self._committed
        return Snapshot(community_of_on(st, self._cfg, u), int(st.gen))

    def community_sizes(self) -> Snapshot:
        """Community-size histogram on the committed snapshot."""
        st = self._committed
        return Snapshot(community_sizes_on(st, self._cfg), int(st.gen))

    # ------------------------------------------------------------- misc ---

    def edge_set(self) -> set:
        """Host copy of the live edge set (test/debug helper)."""
        t = self._committed.edges
        live = np.asarray(t.state) == int(et.LIVE)
        src = np.asarray(t.src)[live]
        dst = np.asarray(t.dst)[live]
        return set(zip(src.tolist(), dst.tolist()))

    def stats(self) -> dict:
        from repro.kernels.frontier_expand import ops as frontier_ops
        from repro.kernels.hash_probe import ops as hash_probe_ops
        from repro.kernels.reach_blockmm import ops as blockmm_ops
        live, tomb = et.fill_stats(self._committed.edges)
        return {
            # what each kernel hook actually resolves to on this backend
            # at the current capacities ('auto' is size-dependent)
            "kernel_impl": {
                "sparse_impl": self._cfg.sparse_impl,
                "frontier_expand": frontier_ops.resolve_impl(
                    self._cfg.sparse_impl, self._cfg.n_vertices),
                "hash_probe": hash_probe_ops.resolve_impl(
                    self._cfg.sparse_impl, self._cfg.edge_capacity),
                "dense_matmul": blockmm_ops._resolve(
                    self._cfg.dense_matmul_impl),
            },
            "gen": self.gen,
            "n_ccs": int(self._committed.n_ccs),
            "live_edges": int(live),
            "tombstones": int(tomb),
            "edge_capacity": self._cfg.edge_capacity,
            "overflow_total": int(self._committed.overflow),
            "grows": self.grow_count,
            "proactive_grows": self.proactive_grows,
            "replayed_ops": self.replayed_ops,
            "compactions": self.compaction_count,
            "compile_count": self.compile_count,
            "pipelined_chunks": self.pipelined_chunks,
            "fallback_chunks": self.fallback_chunks,
            "scanned_chunks": self.scanned_chunks,
            "scan_dispatches": self.scan_dispatches,
            "repair_dense_steps": self.repair_tier_steps["dense"],
            "repair_compact_steps": self.repair_tier_steps["compact"],
            "repair_full_steps": self.repair_tier_steps["full"],
            "repair_skipped_steps": self.repair_tier_steps["skipped"],
            "repair_region_v_max": self.repair_region_v_max,
            "repair_region_e_max": self.repair_region_e_max,
            "deduped_resubmits": self.deduped_resubmits,
        }
