"""Dynamic SCC-Graph state: the TPU-native analogue of the paper's SCC-Graph.

The paper (Sa, 2018) stores the graph as a three-level lazy linked list
(SCC list -> vertex list -> edge list) with per-node locks and logical
(``marked``) deletion.  On TPU there is no shared mutable heap, so the same
information lives in fixed-capacity dense arrays:

  * vertices are slots ``0..n_vertices-1`` with an ``v_alive`` mask
    (``marked`` inverted),
  * edges live in an open-addressing hash table (:mod:`repro.core.edge_table`)
    whose ``(src, dst, live)`` columns double as a COO edge list for the
    vectorized sweeps,
  * the SCC membership ("which vertex list do I sit in") is a label array
    ``ccid[v]`` whose canonical value is the minimum vertex id in the SCC --
    labels form a semilattice under ``min`` which is what lets concurrent
    (batched) updates merge without locks.

Everything in this module is a pure function of pytrees; all shapes are
static so every operation jits and pjits.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import edge_table as et

# Sentinel label meaning "no SCC / dead vertex".  Any value >= n_vertices works.
INT32_MAX = jnp.iinfo(jnp.int32).max

# Repair-tier codes reported in RepairStats.tier, ordered by preference:
# the phase-5 dispatcher picks the smallest tier the affected region fits,
# and TIER_SKIP records that the repair gate proved the step needed no
# repair at all (the region was empty, so every tier would be a no-op).
TIER_DENSE = 0     # region densified, closed on the MXU (reach_blockmm)
TIER_COMPACT = 1   # region compacted to bounded COO, sparse fixpoints there
TIER_FULL = 2      # full-table sparse fixpoints (overflow fallback)
TIER_SKIP = 3      # repair gate: structure-preserving step, phase 5 skipped
TIER_NAMES = ("dense", "compact", "full", "skipped")


class RepairStats(NamedTuple):
    """Per-step repair telemetry (device scalars; stacked to int32[K]
    leaves by the ``apply_batch_scan`` entry and resolved lazily by the
    service next to the overflow delta)."""
    tier: jax.Array             # int32[]  TIER_DENSE..TIER_SKIP
    region_vertices: jax.Array  # int32[]  |M_del ∪ (FW ∩ BW)| this step
    region_edges: jax.Array     # int32[]  live intra-region edges this step


def repair_skipped() -> RepairStats:
    """The stats a gated (structure-preserving) step reports: no tier ran,
    no region was materialized."""
    return RepairStats(tier=jnp.int32(TIER_SKIP),
                       region_vertices=jnp.int32(0),
                       region_edges=jnp.int32(0))


@dataclasses.dataclass(frozen=True)
class GraphConfig:
    """Static (non-traced) capacities of the dynamic graph."""

    n_vertices: int  # vertex-slot capacity; ids in [0, n_vertices)
    edge_capacity: int  # hash-table capacity; power of two; keep <=50% load
    max_probes: int = 64  # linear-probing bound per batched table op
    max_outer: int = 128  # SCC peel rounds bound
    max_inner: int = 256  # reachability / fixpoint rounds bound (>= diameter)
    dense_capacity: int = 0  # >0 enables dense blocked repair path (Pallas)
    # which reach_blockmm.bool_matmul implementation the dense tier feeds:
    # 'auto' = Pallas MXU kernel on TPU / interpret-mode validation on CPU,
    # 'pallas' / 'pallas_interpret' force those, 'xla' = jnp oracle fallback
    dense_matmul_impl: str = "auto"
    # which implementation backs the *sparse* hot loop: every FW/BW
    # fixpoint round (kernels.frontier_expand segment-min) and every
    # edge-table probe (kernels.hash_probe fused sweep).  'auto' = Pallas
    # on TPU within the kernels' size ceilings, XLA scatter/probe-loop
    # otherwise; 'pallas' / 'pallas_interpret' force the kernel; 'xla' is
    # the differential oracle the fuzz suites A/B against.  Unlike
    # dense_matmul_impl, CPU 'auto' resolves to 'xla' (not interpret):
    # these sweeps are always-on, and interpret-executing them would
    # regress every step by orders of magnitude -- the interpret path is
    # exercised by the forced-impl test suites instead.
    sparse_impl: str = "auto"
    # compact-sparse repair tier: >0 (and < n_vertices) compacts affected
    # regions of at most this many vertices into bounded sub-arrays so each
    # fixpoint round costs O(region) instead of O(table capacity)
    region_vertex_capacity: int = 0
    # geometric registry of compact-COO edge capacities (static shapes, so
    # the per-config compile count stays bounded by the registry size);
    # buckets >= edge_capacity are dropped at dispatch (no smaller than the
    # full table means no win).  The smallest bucket that holds the
    # region's live edges is chosen per step; none fitting -> full sweep.
    region_edge_buckets: tuple = (256, 4096, 65536)
    # optional PartitionSpec for the NV-sized label/frontier arrays inside
    # the repair fixpoints (None = replicated + all-reduce merge; a
    # 'model'-axis spec turns the merges into reduce-scatter-style
    # exchanges -- the §Perf collective-term knob)
    label_spec: object = None
    # fuse the FW and BW reachability sweeps of the repair into ONE
    # fixpoint over a stacked [2, NV] frontier: halves both the round
    # count and the per-round collective launches (§Perf knob)
    fuse_fwbw: bool = False
    # Shiloach-Vishkin pointer doubling in the coloring sweep: label
    # chains collapse in O(log diameter) rounds (§Perf knob)
    shortcut: bool = False
    # in-graph repair gate: wrap all of phase 5 (the FW/BW sweeps and the
    # tiered masked static-SCC pass) in a lax.cond on a cheap on-device
    # predicate computed from the batch -- a step whose region is provably
    # empty (no straddling insert, no deletion-affected class) costs
    # O(batch) instead of O(region fixpoint).  The predicate is exact for
    # skipping (empty region == repair is a no-op), so gated and ungated
    # runs are bit-identical; gating only changes RepairStats (TIER_SKIP).
    repair_gate: bool = True

    def __post_init__(self):
        assert self.edge_capacity & (self.edge_capacity - 1) == 0, (
            "edge_capacity must be a power of two")
        # normalize so configs differing only in registry spelling hash the
        # same (GraphConfig is a static jit argument)
        object.__setattr__(self, "region_edge_buckets",
                           tuple(sorted(set(int(b) for b in
                                            self.region_edge_buckets))))
        assert all(b > 0 for b in self.region_edge_buckets), (
            "region_edge_buckets must be positive")
        assert self.region_vertex_capacity >= 0
        assert self.sparse_impl in ("auto", "pallas", "pallas_interpret",
                                    "xla"), self.sparse_impl


class GraphState(NamedTuple):
    """The dynamic SCC-Graph.  A pytree of arrays; capacities are static."""

    v_alive: jax.Array  # bool[NV]   vertex slot is live
    ccid: jax.Array  # int32[NV]  canonical SCC label (min id in SCC); NV if dead
    edges: et.EdgeTable  # hash table over (src, dst)
    n_ccs: jax.Array  # int32[]    live SCC count  (paper: ``ccCount``)
    gen: jax.Array  # int32[]    bumped whenever the SCC partition changes
    overflow: jax.Array  # int32[]    # of table-op failures (host must grow)


def empty(cfg: GraphConfig) -> GraphState:
    nv = cfg.n_vertices
    return GraphState(
        v_alive=jnp.zeros((nv,), jnp.bool_),
        ccid=jnp.full((nv,), nv, jnp.int32),
        edges=et.empty(cfg.edge_capacity),
        n_ccs=jnp.zeros((), jnp.int32),
        gen=jnp.zeros((), jnp.int32),
        overflow=jnp.zeros((), jnp.int32),
    )


def from_arrays(cfg: GraphConfig, src, dst, n_active_vertices=None) -> GraphState:
    """Bulk-load a static graph (host path, used by tests/benches).

    ``ccid`` is *not* computed here; call :func:`repro.core.scc.recompute` on
    the result (or go through ``dynamic.apply_batch``).
    """
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    state = empty(cfg)
    nv = cfg.n_vertices
    if n_active_vertices is None:
        n_active_vertices = nv
    v_alive = (jnp.arange(nv) < n_active_vertices)
    # overflow = keys the table itself reports dropped on probe exhaustion
    # (duplicates in the input are found / deduped, so they do not count).
    table, _, failed = et.insert(state.edges, src, dst, cfg.max_probes,
                                 impl=cfg.sparse_impl)
    state = state._replace(
        v_alive=v_alive,
        edges=table,
        overflow=state.overflow + jnp.sum(failed).astype(jnp.int32),
    )
    return state


def all_singletons(cfg: GraphConfig) -> GraphState:
    """Every vertex slot live, each its own SCC, no edges -- the standard
    boot state for stream drivers (edge ops land immediately)."""
    nv = cfg.n_vertices
    return recount_ccs(empty(cfg)._replace(
        v_alive=jnp.ones((nv,), jnp.bool_),
        ccid=jnp.arange(nv, dtype=jnp.int32)))


def edge_coo(state: GraphState):
    """(src, dst, live_mask) view of the edge table, for segment-op sweeps."""
    t = state.edges
    live = t.state == et.LIVE
    return t.src, t.dst, live


def live_edge_count(state: GraphState) -> jax.Array:
    return jnp.sum(state.edges.state == et.LIVE).astype(jnp.int32)


def live_vertex_count(state: GraphState) -> jax.Array:
    return jnp.sum(state.v_alive).astype(jnp.int32)


def recount_ccs(state: GraphState) -> GraphState:
    """n_ccs = #representatives (v alive with ccid[v] == v).

    Canonical labels are the min id of the SCC, which is itself a member, so
    counting fixed points of the label map counts components exactly.
    """
    nv = state.ccid.shape[0]
    reps = state.v_alive & (state.ccid == jnp.arange(nv, dtype=jnp.int32))
    return state._replace(n_ccs=jnp.sum(reps).astype(jnp.int32))
