# The paper's primary contribution: dynamic SCC maintenance as a batched,
# jit/pjit-able functional engine.  See DESIGN.md §2 for the shared-memory
# -> TPU-dataflow mapping.
from repro.core import (  # noqa: F401
    baselines,
    community,
    dynamic,
    edge_table,
    graph_state,
    reach,
    scc,
)

# service/broker are imported lazily by consumers (they pull in the
# launch-layer scheduler), not eagerly here.
