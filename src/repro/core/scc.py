"""Static parallel SCC: trim -> coloring -> masked backward sweep.

This is the repair engine the dynamic algorithm (:mod:`repro.core.dynamic`)
calls on the *affected region only* -- the TPU-native stand-in for the
paper's limited Tarjan (merge) and limited Kosaraju (split) passes.  The
algorithm is the Slota-multistep / Orzan-coloring family, chosen because
every phase is an edge-parallel map + segment reduction (VPU) or, on the
dense path, a blocked boolean mat-mul (MXU):

  outer round (bounded by ``max_outer``):
    1. **trim** to fixpoint: peel vertices with zero live in- or out-degree
       inside the unassigned set; each peeled vertex is its own SCC.  This
       kills DAG-like tails that would cost the coloring pass one round each.
    2. **color**: forward min-label propagation; colors are constant on SCCs
       and every color class has exactly one *root* r with color[r] == r,
       which is the minimum vertex id of its SCC whenever it is assignable.
    3. **backward sweep**: from all roots simultaneously, walk reversed
       edges restricted to the root's color class; every vertex reached is
       strongly connected to its root.  Assign ``ccid = color`` there.

Labels are *canonical*: ccid[v] == min vertex id of v's SCC, matching the
paper's invariant that an SCC's identity is stable while its membership is.

The repair engine runs in three tiers over the same affected region
(:mod:`repro.core.dynamic` dispatches per step, smallest first):

  * dense (`scc_dense_region`): gather the region into a compact adjacency
    matrix and close it with O(log R) boolean mat-mul squarings -- the
    Pallas ``reach_blockmm`` kernel's job on the MXU;
  * compact sparse (`scc_compact_region`): gather region vertices and live
    intra-region edges once into bounded static sub-arrays
    (`compact_region`) and rerun the trim/color/backward fixpoints there,
    so each round costs O(region edges) instead of O(table capacity);
  * full sparse (`scc_static` over the full COO): the overflow fallback
    when the region exceeds every compact capacity.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import reach

INT32_MAX = jnp.iinfo(jnp.int32).max


def _degrees(src, dst, emask, nv):
    indeg = jax.ops.segment_sum(emask.astype(jnp.int32), dst, nv)
    outdeg = jax.ops.segment_sum(emask.astype(jnp.int32), src, nv)
    return indeg, outdeg


def trim(src, dst, live, unassigned, vid, ccid, max_iters: int):
    """Iteratively peel zero-in/out-degree vertices into singleton SCCs."""
    nv = unassigned.shape[0]

    def body(carry):
        unassigned, ccid = carry
        emask = live & unassigned[src] & unassigned[dst]
        indeg, outdeg = _degrees(src, dst, emask, nv)
        peel = unassigned & ((indeg == 0) | (outdeg == 0))
        ccid = jnp.where(peel, vid, ccid)
        return (unassigned & ~peel, ccid), jnp.any(peel)

    (unassigned, ccid), _ = reach._fixpoint(body, (unassigned, ccid),
                                            max_iters)
    return unassigned, ccid


@partial(jax.jit, static_argnames=("max_outer", "max_inner", "spec",
                                   "shortcut", "impl"))
def scc_static(src, dst, live, active, *, max_outer: int, max_inner: int,
               spec=None, shortcut: bool = False, impl: str = "xla"):
    """SCC labels of the subgraph induced by ``active`` over live edges.

    Returns int32[NV]: min-member-id label for active vertices, INT32_MAX
    sentinel elsewhere.  ``max_outer`` bounds coloring rounds (>= number of
    'layers' of SCCs after trimming); ``max_inner`` bounds propagation
    rounds (>= region diameter).  ``spec`` optionally pins the NV-array
    sharding inside the fixpoints (GraphConfig.label_spec).
    """
    nv = active.shape[0]
    vid = jnp.arange(nv, dtype=jnp.int32)
    ccid = jnp.full((nv,), INT32_MAX, jnp.int32)
    unassigned = active

    def outer_cond(carry):
        unassigned, _, it = carry
        return jnp.any(unassigned) & (it < max_outer)

    def outer_body(carry):
        unassigned, ccid, it = carry
        # (1) trim
        unassigned, ccid = trim(src, dst, live, unassigned, vid, ccid,
                                max_inner)
        # (2) forward-min and backward-min witnesses within unassigned:
        # fwd[v] = min-priority vertex reaching v, bwd[v] = min-priority
        # vertex v reaches.  A vertex sits in a finished SCC exactly when
        # fwd == bwd == w (then w ⇝ v and v ⇝ w).  Both sweeps are
        # min-label propagations, so both accelerate under hashed-priority
        # pointer doubling (shortcut=True) -- unlike the classic coloring
        # + boolean backward sweep, whose backward phase is pinned at
        # O(diameter) rounds.
        if shortcut:
            fwd, _ = reach.propagate_min_prio(
                src, dst, live, unassigned, max_inner, spec=spec,
                impl=impl)
            bwd, _ = reach.propagate_min_prio(
                dst, src, live, unassigned, max_inner, spec=spec,
                impl=impl)
            done = unassigned & (fwd == bwd) & (fwd < nv)
            # canonical label = min member id of each witness group
            grp = jnp.where(done, fwd, nv)
            min_id = jnp.full((nv + 1,), INT32_MAX, jnp.int32).at[
                grp].min(jnp.where(done, vid, INT32_MAX))
            ccid = jnp.where(done, min_id[jnp.minimum(fwd, nv)], ccid)
        else:
            init = jnp.where(unassigned, vid, INT32_MAX)
            fwd, _ = reach.propagate_min_labels(
                src, dst, live, init, unassigned, max_inner, spec=spec,
                impl=impl)
            bwd, _ = reach.propagate_min_labels(
                dst, src, live, init, unassigned, max_inner, spec=spec,
                impl=impl)
            done = unassigned & (fwd == bwd)
            ccid = jnp.where(done, fwd, ccid)
        unassigned = unassigned & ~done
        return unassigned, ccid, it + 1

    _, ccid, _ = jax.lax.while_loop(
        outer_cond, outer_body, (unassigned, ccid, jnp.int32(0)))
    return ccid


# ---------------------------------------------------------------------------
# Compact-sparse region path
# ---------------------------------------------------------------------------

def _enumerate_region(region_mask, capacity: int):
    """Stable (ascending-global-id) enumeration of region members into
    ``capacity`` slots.  Returns ``(pos_of int32[NV], ids int32[capacity],
    valid bool[capacity])``; non-members and overflow land in a clamped
    junk slot that ``ids`` never sees.  Order preservation is what both
    compact tiers' bit-identity rests on: the min compact index and the
    min global id of any subset name the same vertex."""
    nv = region_mask.shape[0]
    pos_of = jnp.cumsum(region_mask) - 1
    pos_of = jnp.where(region_mask, pos_of, capacity)
    pos_of = jnp.minimum(pos_of, capacity).astype(jnp.int32)
    ids = jnp.full((capacity + 1,), -1, jnp.int32).at[pos_of].set(
        jnp.arange(nv, dtype=jnp.int32), mode="drop")[:capacity]
    return pos_of, ids, ids >= 0


def compact_region(src, dst, live, region_mask, v_capacity: int,
                   e_capacity: int):
    """Pack the affected region into bounded compact COO arrays.

    Region vertices are enumerated stably (ascending global id) into
    ``v_capacity`` slots; live intra-region edges into ``e_capacity``
    compact-index edge slots.  Returns
    ``(csrc, cdst, celive, ids, valid, pos_of, fits)``:

      * ``csrc/cdst`` int32[EC], ``celive`` bool[EC] -- the compacted edge
        list over compact vertex indices [0, v_capacity);
      * ``ids`` int32[VC] -- global id of each compact slot (-1 unused),
        ``valid`` its occupancy mask, ``pos_of`` int32[NV] the inverse map;
      * ``fits`` bool[] -- False when either capacity is exceeded (the
        caller must fall back to the full-sparse sweep).

    The enumeration is order-preserving, so the min compact index and the
    min global id of any vertex subset name the same vertex -- canonical
    min-member-id labels survive the compaction round trip bit-exactly.
    """
    v_count = jnp.sum(region_mask)
    e_in = live & region_mask[src] & region_mask[dst]
    e_count = jnp.sum(e_in)
    fits = (v_count <= v_capacity) & (e_count <= e_capacity)
    pos_of, ids, valid = _enumerate_region(region_mask, v_capacity)
    # stable enumeration of live intra-region edges; overflowing or
    # non-region edges land in the sliced-off junk slot
    epos = jnp.cumsum(e_in) - 1
    epos = jnp.where(e_in, epos, e_capacity)
    epos = jnp.minimum(epos, e_capacity).astype(jnp.int32)
    cap_src = jnp.minimum(pos_of[src], v_capacity - 1)
    cap_dst = jnp.minimum(pos_of[dst], v_capacity - 1)
    csrc = jnp.zeros((e_capacity + 1,), jnp.int32).at[epos].set(
        cap_src, mode="drop")[:e_capacity]
    cdst = jnp.zeros((e_capacity + 1,), jnp.int32).at[epos].set(
        cap_dst, mode="drop")[:e_capacity]
    celive = jnp.zeros((e_capacity + 1,), jnp.bool_).at[epos].set(
        e_in, mode="drop")[:e_capacity]
    return csrc, cdst, celive, ids, valid, pos_of, fits


def scc_compact_region(src, dst, live, region_mask, v_capacity: int,
                       e_capacity: int, *, max_outer: int, max_inner: int,
                       shortcut: bool = False, impl: str = "xla"):
    """SCC labels of the region via the compact-sparse tier.

    Gathers the region once into static ``(v_capacity, e_capacity)``
    sub-arrays and reruns the :func:`scc_static` fixpoints there, so every
    trim/color/backward round costs O(region) gathers and scatters instead
    of O(table capacity).  Returns ``(ccid int32[NV], fits bool[])`` --
    labels valid where ``region_mask`` (INT32_MAX sentinel elsewhere) and
    bit-identical to :func:`scc_static` on the uncompacted
    ``(src, dst, live, region_mask)`` operands: both
    produce canonical min-member-id labels and the compact enumeration is
    order-preserving.
    """
    nv = region_mask.shape[0]
    csrc, cdst, celive, ids, valid, _, fits = compact_region(
        src, dst, live, region_mask, v_capacity, e_capacity)
    # no spec: the whole point is that compact operands are small enough to
    # stay replicated, round after round
    clab = scc_static(csrc, cdst, celive, valid, max_outer=max_outer,
                      max_inner=max_inner, shortcut=shortcut, impl=impl)
    # a slot scc_static left unassigned (sentinel; only possible when
    # max_outer was exhausted) must stay the sentinel globally too, exactly
    # as the full-sparse tier would report it -- never a clipped real id
    glab = jnp.where(valid & (clab < v_capacity),
                     ids[jnp.clip(clab, 0, v_capacity - 1)], INT32_MAX)
    ccid = jnp.full((nv,), INT32_MAX, jnp.int32)
    ccid = ccid.at[jnp.where(valid, ids, nv)].set(glab, mode="drop")
    return ccid, fits


# ---------------------------------------------------------------------------
# Dense (MXU) region path
# ---------------------------------------------------------------------------

def gather_region(src, dst, live, region_mask, capacity: int):
    """Pack up to ``capacity`` region vertices into a dense adjacency.

    Returns (adj bool[R, R], ids int32[R], valid bool[R], fits bool[]).
    ``fits`` is False when the region has more members than ``capacity``;
    the caller must then fall back to the sparse path.
    """
    count = jnp.sum(region_mask)
    fits = count <= capacity
    pos_of, ids, valid = _enumerate_region(region_mask, capacity)
    # scatter live intra-region edges into the dense block
    e_in = live & region_mask[src] & region_mask[dst]
    r, c = pos_of[src], pos_of[dst]
    r = jnp.where(e_in, r, capacity)  # OOB -> dropped
    c = jnp.where(e_in, c, capacity)
    adj = jnp.zeros((capacity + 1, capacity + 1), jnp.bool_)
    adj = adj.at[r, c].set(True, mode="drop")
    return adj[:capacity, :capacity], ids, valid, fits


def closure_dense(adj, matmul=None):
    """Reflexive-transitive closure via O(log R) boolean squarings.

    ``matmul`` is the boolean-semiring product hook; the Pallas kernel
    (kernels.reach_blockmm) is injected here by the dynamic engine, with the
    pure-jnp product as the oracle/fallback.
    """
    r = adj.shape[0]
    reach_m = adj | jnp.eye(r, dtype=jnp.bool_)
    if matmul is None:
        def matmul(a, b):
            return jnp.einsum("ij,jk->ik", a.astype(jnp.float32),
                              b.astype(jnp.float32)) > 0.0
    n_steps = max(1, math.ceil(math.log2(max(r, 2))))
    for _ in range(n_steps):
        reach_m = reach_m | matmul(reach_m, reach_m)
    return reach_m


def scc_dense_region(src, dst, live, region_mask, capacity: int,
                     matmul=None):
    """SCC labels for a (small) region on the dense MXU path.

    Returns (ccid_region int32[NV] -- labels only valid where region_mask --
    fits bool[]).  Labels are min-member-id, identical to ``scc_static``.
    """
    nv = region_mask.shape[0]
    adj, ids, valid, fits = gather_region(src, dst, live, region_mask,
                                          capacity)
    clo = closure_dense(adj, matmul)
    both = clo & clo.T  # strongly connected pairs
    both = both & valid[None, :] & valid[:, None]
    # label = min id over the strongly-connected row
    big = jnp.where(valid, ids, INT32_MAX)
    lab = jnp.min(jnp.where(both, big[None, :], INT32_MAX), axis=1)
    ccid = jnp.full((nv,), INT32_MAX, jnp.int32)
    ccid = ccid.at[jnp.where(valid, ids, nv)].set(lab, mode="drop")
    return ccid, fits
