"""Read replicas over the durable WAL: restore a snapshot, tail the log,
serve QueryBroker traffic.

The paper's readers are wait-free against one shared-memory object; the
replication layer scales that read path past one process: a
:class:`Replica` bootstraps from the writer's latest graph snapshot
(written by :class:`repro.ckpt.durable.DurableService` -- a fresh store
always has a generation-0 boot snapshot), then *tails* the write-ahead
log, applying each record through the standard service update path.
Because records replay with the writer's own decision knobs (bucket
registry, growth policy -- carried in the snapshot meta), a replica's
state is bit-identical to the writer's at every committed generation it
passes through, so its :class:`repro.core.broker.QueryBroker` serves the
exact same consistency contract: `AT_LEAST(gen)` answers only after the
replica has tailed past ``gen`` (the broker's gen-wait defers early
arrivals), and per-reader generation stamps stay monotone.

:class:`ReplicaSet` fans N replicas behind one broker-shaped facade
(``submit``/``resolve``/``stats``/``stop``): each query batch routes to
a replica that already satisfies its consistency floor when one exists
(freshest-first; round-robin among the qualified), falling back to the
most caught-up replica otherwise -- with staggered tail cycles this
hides replication lag, which is where the replica-count throughput
scaling in ``benchmarks/bench_stream.py`` comes from.  A replica that
finds the log trimmed underneath its cursor (the writer snapshotted and
dropped old segments) resyncs from the newest snapshot and keeps going.
"""
from __future__ import annotations

import itertools
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Sequence

from repro.ckpt import checkpoint, oplog
from repro.ckpt.durable import decision_kwargs, snap_dir, wal_dir
from repro.core.broker import QueryBroker
from repro.core.service import SCCService

__all__ = ["Replica", "ReplicaSet"]


class Replica:
    """One read replica: snapshot-restored service + WAL tailer + broker.

    ``auto_tail=False`` (tests) disables the background threads; drive
    the replica manually with :meth:`tail_once` and inline broker
    flushes.
    """

    def __init__(self, directory: str, replica_id: int = 0, *,
                 query_buckets: Sequence[int] = (64, 256, 1024),
                 poll_interval: float = 0.002, poll_offset: float = 0.0,
                 max_records_per_poll: int | None = 64,
                 auto_tail: bool = True, **service_kwargs):
        self._dir = directory
        self.replica_id = replica_id
        self._poll_interval = poll_interval
        self._poll_offset = poll_offset
        self._max_records = max_records_per_poll
        self._service_kwargs = service_kwargs
        st, cfg, meta, _ = checkpoint.restore_graph_snapshot(
            snap_dir(directory))
        if st is None:
            raise FileNotFoundError(
                f"no graph snapshot under {directory!r} -- replicas "
                f"bootstrap from the writer's boot snapshot")
        # the WRITER's decision knobs: replaying records through the same
        # bucketed update path reproduces its exact gen trajectory
        self._svc = SCCService(cfg, state=st,
                               **decision_kwargs(meta), **service_kwargs)
        self._tailer = oplog.LogTailer(wal_dir(directory),
                                       from_gen=self._svc.gen)
        self.broker = QueryBroker(self._svc, buckets=query_buckets)
        self.applied_records = 0
        self.apply_failures = 0
        self.resyncs = 0
        self.error: BaseException | None = None
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        if auto_tail:
            self.broker.start()
            self._thread = threading.Thread(
                target=self._run, name=f"scc-replica-{replica_id}",
                daemon=True)
            self._thread.start()

    # ------------------------------------------------------------ state ---

    @property
    def service(self) -> SCCService:
        return self._svc

    @property
    def gen(self) -> int:
        return self._svc.gen

    def wait_for_gen(self, gen: int, timeout: float | None = None) -> int:
        return self._svc.wait_for_gen(gen, timeout)

    def next_tick_eta(self) -> float:
        """Seconds until this replica's next scheduled WAL pull
        (``inf`` without a tail thread) -- the routing signal for
        requests no replica can answer yet: any replica reaches a
        durable record at its next tick, so the soonest tick wins."""
        if self._thread is None:
            return float("inf")
        now = time.monotonic()
        period = self._poll_interval
        phase = (now - self._poll_offset) / period
        return (int(phase) + 1) * period + self._poll_offset - now

    # ---------------------------------------------------------- tailing ---

    def tail_once(self, max_records: int | None = -1) -> int:
        """Apply newly completed WAL records; returns how many.  The
        default batch cap is the constructor's ``max_records_per_poll``;
        pass ``None`` for an unbounded pull."""
        if max_records == -1:
            max_records = self._max_records
        try:
            records = self._tailer.poll(max_records)
        except (FileNotFoundError, IOError):
            # segments trimmed underneath the cursor (or writer-side
            # corruption): jump forward via the newest snapshot
            self._resync()
            return 0
        n = 0
        for rec in records:
            if rec.gen_before < self._svc.gen:
                continue  # already covered by the snapshot we booted from
            if rec.gen_before > self._svc.gen:
                self._resync()  # gap: our segment window moved on
                return n
            try:
                self._svc._apply_ops(rec.kind, rec.u, rec.v)
            except Exception:
                # the writer hit the same deterministic failure and rolled
                # the record back (all-or-nothing chunks); our cursor now
                # points past truncated bytes -- re-seat it at our gen.
                # A record that keeps failing in place is a real fault.
                self.apply_failures += 1
                if self.apply_failures > 3 + self.applied_records:
                    raise
                self._tailer = oplog.LogTailer(wal_dir(self._dir),
                                               from_gen=self._svc.gen)
                return n
            self.applied_records += 1
            n += 1
        return n

    def _resync(self):
        """Fast-forward from the newest snapshot (only ever forward --
        a snapshot older than our state is ignored)."""
        st, cfg, meta, _ = checkpoint.restore_graph_snapshot(
            snap_dir(self._dir))
        if st is None:
            return
        if int(meta["gen"]) > self._svc.gen:
            svc = self._svc
            with svc._apply_lock:
                svc._state, svc._cfg = st, cfg
                svc._live_ub = cfg.edge_capacity
                with svc._commit_cv:
                    svc._committed = st
                    svc._commit_cv.notify_all()
        self._tailer = oplog.LogTailer(wal_dir(self._dir),
                                       from_gen=self._svc.gen)
        self.resyncs += 1

    def _run(self):
        """Pull loop on a wall-clock-aligned grid: ticks land at
        ``k * poll_interval + poll_offset``, so a ReplicaSet can stagger
        its members' pull phases evenly across the period -- the
        freshness wait a reader sees drops from ~period/2 (one replica)
        to ~period/2N (N staggered replicas), which is the lag-hiding
        the replica-scaling bench measures.  Each tick is ONE unbounded
        pull -- the durable prefix as of tick time; records appended
        while it applies wait for the next tick (chasing them would
        degenerate into busy-tailing whenever the writer is active)."""
        period = self._poll_interval
        while not self._stop.is_set():
            try:
                self.tail_once(max_records=None)
            except BaseException as e:  # surfaced via stats/stop
                self.error = e
                return
            now = time.monotonic()
            phase = (now - self._poll_offset) / period
            next_tick = (int(phase) + 1) * period + self._poll_offset
            self._stop.wait(max(1e-4, next_tick - now))

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self.broker.stop()
        if self.error is not None:
            raise self.error

    def stats(self) -> dict:
        out = {f"replica{self.replica_id}_{k}": val
               for k, val in self.broker.stats().items()}
        out[f"replica{self.replica_id}_gen"] = self.gen
        out[f"replica{self.replica_id}_applied"] = self.applied_records
        out[f"replica{self.replica_id}_resyncs"] = self.resyncs
        return out


class ReplicaSet:
    """Broker-shaped facade over N replicas with freshness-aware routing.

    Drop-in where a :class:`QueryBroker` is expected (a
    :class:`repro.api.GraphClient` takes it as its ``broker``, typically
    with the *writer* service as the update path -- writes go to the
    writer, reads to the replicas, and READ_YOUR_WRITES floors flow
    through ``min_gen`` to a replica that has tailed far enough).
    """

    def __init__(self, directory: str, n: int = 2, *,
                 query_buckets: Sequence[int] = (64, 256, 1024),
                 poll_interval: float = 0.002,
                 auto_tail: bool = True, **replica_kwargs):
        assert n >= 1
        self.replicas: List[Replica] = [
            Replica(directory, i, query_buckets=query_buckets,
                    poll_interval=poll_interval,
                    poll_offset=i * poll_interval / n,
                    auto_tail=auto_tail, **replica_kwargs)
            for i in range(n)]
        self._rr = itertools.count()
        self._owner: Dict[Future, QueryBroker] = {}
        self._lock = threading.Lock()
        self.routed_fresh = 0
        self.routed_stale = 0

    # ------------------------------------------------- broker interface ---

    def submit(self, kind: str, u, v=None, min_gen: int = 0) -> Future:
        fresh = [r for r in self.replicas if r.gen >= min_gen]
        if fresh:
            rep = fresh[next(self._rr) % len(fresh)]
            self.routed_fresh += 1
        else:
            # nobody fresh yet.  The floor comes from an acked write, so
            # its WAL record is already durable: EVERY tailing replica
            # will cover it at its next pull tick -- route to the replica
            # whose tick lands first (staggered sets: ~period/N away),
            # not the currently-most-caught-up one (it pulled most
            # recently, so its next tick is the FURTHEST away).  Without
            # tail threads (manual tests) etas are inf and the key falls
            # back to the most caught-up replica.
            rep = min(self.replicas,
                      key=lambda r: (r.next_tick_eta(), -r.gen))
            self.routed_stale += 1
        fut = rep.broker.submit(kind, u, v, min_gen=min_gen)
        with self._lock:
            self._owner[fut] = rep.broker
        return fut

    def resolve(self, fut: Future, min_gen: int = 0):
        with self._lock:
            broker = self._owner.pop(fut, None)
        if broker is None or broker.dispatching:
            return fut.result()
        return broker.resolve(fut, min_gen=min_gen)

    @property
    def dispatching(self) -> bool:
        return any(r.broker.dispatching for r in self.replicas)

    def stop(self):
        errors = []
        for r in self.replicas:
            try:
                r.stop()
            except BaseException as e:
                errors.append(e)
        if errors:
            raise errors[0]

    def __enter__(self) -> "ReplicaSet":
        return self

    def __exit__(self, *exc):
        self.stop()

    # -------------------------------------------------------- telemetry ---

    @property
    def min_gen(self) -> int:
        return min(r.gen for r in self.replicas)

    def wait_all_for_gen(self, gen: int, timeout: float | None = None):
        """Block until every replica has tailed to ``gen`` (test/bench
        convergence barrier)."""
        for r in self.replicas:
            r.wait_for_gen(gen, timeout)
        return self.min_gen

    def stats(self) -> dict:
        out = {"replicas": len(self.replicas),
               "routed_fresh": self.routed_fresh,
               "routed_stale": self.routed_stale,
               "served": sum(r.broker.served for r in self.replicas),
               "flushes": sum(r.broker.flushes for r in self.replicas),
               "gen_waits": sum(r.broker.gen_waits
                                for r in self.replicas)}
        for r in self.replicas:
            out.update(r.stats())
        return out
