"""Read replicas over the durable WAL: restore a snapshot, tail the log,
serve QueryBroker traffic.

The paper's readers are wait-free against one shared-memory object; the
replication layer scales that read path past one process: a
:class:`Replica` bootstraps from the writer's latest graph snapshot
(written by :class:`repro.ckpt.durable.DurableService` -- a fresh store
always has a generation-0 boot snapshot), then *tails* the write-ahead
log, applying each record through the standard service update path.
Because records replay with the writer's own decision knobs (bucket
registry, growth policy -- carried in the snapshot meta), a replica's
state is bit-identical to the writer's at every committed generation it
passes through, so its :class:`repro.core.broker.QueryBroker` serves the
exact same consistency contract: `AT_LEAST(gen)` answers only after the
replica has tailed past ``gen`` (the broker's gen-wait defers early
arrivals), and per-reader generation stamps stay monotone.

:class:`ReplicaSet` fans N replicas behind one broker-shaped facade
(``submit``/``resolve``/``stats``/``stop``): each query batch routes to
a replica that already satisfies its consistency floor when one exists
(freshest-first; round-robin among the qualified), falling back to the
most caught-up replica otherwise -- with staggered tail cycles this
hides replication lag, which is where the replica-count throughput
scaling in ``benchmarks/bench_stream.py`` comes from.  A replica that
finds the log trimmed underneath its cursor (the writer snapshotted and
dropped old segments) resyncs from the newest snapshot and keeps going.

Failure domains (PR 9 hardening; docs/ARCHITECTURE.md §Failure
domains): routing only considers *healthy* replicas -- one whose tail
loop died, was :meth:`Replica.kill`-ed by fault injection, or has
missed ``health_misses`` consecutive poll deadlines is quarantined.  A
query in flight on a replica that dies fails over transparently: the
dead broker releases the future with a typed
:class:`~repro.fault.errors.BrokerStopped` and the set resubmits it to
a healthy peer (queries are read-only, so a resubmit is always safe).
With ``supervise=True`` a supervisor thread restarts dead replicas via
snapshot fast-forward -- a fresh :class:`Replica` bootstraps from the
newest snapshot exactly like ``_resync``, so recovery time is one
snapshot restore, not a full log replay.  With no healthy replica at
all, ``submit`` raises :class:`~repro.fault.errors.Unavailable` with a
``retry_after`` of one poll interval.
"""
from __future__ import annotations

import itertools
import threading
import time
from concurrent.futures import Future
from concurrent.futures import TimeoutError as _FutureTimeout
from typing import Dict, List, Sequence, Tuple

import os

from repro.ckpt import checkpoint, oplog
from repro.ckpt.durable import (DurableService, decision_kwargs, snap_dir,
                                wal_dir)
from repro.core.broker import QueryBroker
from repro.core.service import SCCService
from repro.fault import errors as fault_errors

__all__ = ["Replica", "ReplicaSet"]


class Replica:
    """One read replica: snapshot-restored service + WAL tailer + broker.

    ``auto_tail=False`` (tests) disables the background threads; drive
    the replica manually with :meth:`tail_once` and inline broker
    flushes.
    """

    def __init__(self, directory: str, replica_id: int = 0, *,
                 query_buckets: Sequence[int] = (64, 256, 1024),
                 poll_interval: float = 0.002, poll_offset: float = 0.0,
                 max_records_per_poll: int | None = 64,
                 auto_tail: bool = True, health_misses: int = 25,
                 stale_floor_s: float = 2.0, **service_kwargs):
        self._dir = directory
        self.replica_id = replica_id
        self._poll_interval = poll_interval
        self._poll_offset = poll_offset
        self._max_records = max_records_per_poll
        self._service_kwargs = service_kwargs
        self._health_misses = health_misses
        self._stale_floor_s = stale_floor_s
        self._killed = False
        self._last_tick = time.monotonic()
        st, cfg, meta, _ = checkpoint.restore_graph_snapshot(
            snap_dir(directory))
        if st is None:
            raise FileNotFoundError(
                f"no graph snapshot under {directory!r} -- replicas "
                f"bootstrap from the writer's boot snapshot")
        # the WRITER's decision knobs: replaying records through the same
        # bucketed update path reproduces its exact gen trajectory
        self._decision_kwargs = decision_kwargs(meta)
        self._svc = SCCService(cfg, state=st,
                               **self._decision_kwargs, **service_kwargs)
        self._tailer = oplog.LogTailer(wal_dir(directory),
                                       from_gen=self._svc.gen)
        self.broker = QueryBroker(self._svc, buckets=query_buckets)
        self.applied_records = 0
        self.apply_failures = 0
        self.resyncs = 0
        self.error: BaseException | None = None
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        if auto_tail:
            self.broker.start()
            self._thread = threading.Thread(
                target=self._run, name=f"scc-replica-{replica_id}",
                daemon=True)
            self._thread.start()

    # ------------------------------------------------------------ state ---

    @property
    def service(self) -> SCCService:
        return self._svc

    @property
    def gen(self) -> int:
        return self._svc.gen

    def wait_for_gen(self, gen: int, timeout: float | None = None) -> int:
        return self._svc.wait_for_gen(gen, timeout)

    @property
    def healthy(self) -> bool:
        """Routing health: False once the replica was killed, its tail
        loop died on an error, or (with a tail thread) it has missed
        ``health_misses`` consecutive poll deadlines -- the quarantine
        signal.  The miss threshold is floored at ``stale_floor_s`` so a
        one-off long apply (first-touch compiles) does not flap it."""
        if self._killed or self.error is not None:
            return False
        t = self._thread
        if t is None:
            return True  # manual mode: driven explicitly, never stale
        if not t.is_alive():
            return False
        stale = max(self._health_misses * self._poll_interval,
                    self._stale_floor_s)
        return (time.monotonic() - self._last_tick) < stale

    def kill(self):
        """Fault injection: 'crash' this replica abruptly.  The tail
        loop is told to exit (not joined -- the kill point must not wait
        on a mid-apply tick), routing health flips False immediately,
        and the broker releases every parked future with a typed
        :class:`~repro.fault.errors.BrokerStopped` (the ReplicaSet's
        failover signal)."""
        self._killed = True
        self._stop.set()
        self.broker.stop()

    def next_tick_eta(self) -> float:
        """Seconds until this replica's next scheduled WAL pull
        (``inf`` without a tail thread) -- the routing signal for
        requests no replica can answer yet: any replica reaches a
        durable record at its next tick, so the soonest tick wins."""
        if self._thread is None:
            return float("inf")
        now = time.monotonic()
        period = self._poll_interval
        phase = (now - self._poll_offset) / period
        return (int(phase) + 1) * period + self._poll_offset - now

    # ---------------------------------------------------------- tailing ---

    def tail_once(self, max_records: int | None = -1) -> int:
        """Apply newly completed WAL records; returns how many.  The
        default batch cap is the constructor's ``max_records_per_poll``;
        pass ``None`` for an unbounded pull."""
        if max_records == -1:
            max_records = self._max_records
        try:
            records = self._tailer.poll(max_records)
        except (FileNotFoundError, IOError, fault_errors.WalTrimmed,
                fault_errors.WalCorrupt):
            # segments trimmed underneath the cursor (or writer-side
            # corruption): a resync *signal*, never a failure -- jump
            # forward via the newest snapshot (it covers everything a
            # trim dropped; that is the trim precondition)
            self._resync()
            return 0
        n = 0
        for rec in records:
            if rec.gen_before < self._svc.gen:
                continue  # already covered by the snapshot we booted from
            if rec.gen_before > self._svc.gen:
                self._resync()  # gap: our segment window moved on
                return n
            try:
                self._svc._apply_ops(rec.kind, rec.u, rec.v)
            except Exception:
                # the writer hit the same deterministic failure and rolled
                # the record back (all-or-nothing chunks); our cursor now
                # points past truncated bytes -- re-seat it at our gen.
                # A record that keeps failing in place is a real fault.
                self.apply_failures += 1
                if self.apply_failures > 3 + self.applied_records:
                    raise
                self._tailer = oplog.LogTailer(wal_dir(self._dir),
                                               from_gen=self._svc.gen)
                return n
            self.applied_records += 1
            n += 1
        return n

    def _resync(self):
        """Fast-forward from the newest snapshot (only ever forward --
        a snapshot older than our state is ignored)."""
        st, cfg, meta, _ = checkpoint.restore_graph_snapshot(
            snap_dir(self._dir))
        if st is None:
            return
        if int(meta["gen"]) > self._svc.gen:
            svc = self._svc
            with svc._apply_lock:
                svc._state, svc._cfg = st, cfg
                svc._live_ub = cfg.edge_capacity
                with svc._commit_cv:
                    svc._committed = st
                    svc._commit_cv.notify_all()
        self._tailer = oplog.LogTailer(wal_dir(self._dir),
                                       from_gen=self._svc.gen)
        self.resyncs += 1

    # -------------------------------------------------------- promotion ---

    def promote(self, lease, **durable_kwargs) -> DurableService:
        """Become the durable writer: the failover half of the HA story.

        ``lease`` must be acquirable (fresh, stale, or already held by
        this caller) -- its post-acquire epoch is the new fencing token.
        The order is what makes the handoff exactly-once:

        1. **take the lease** (epoch bump E = old + 1);
        2. **fence the WAL at E** -- from this instant the old writer's
           next append raises ``Fenced`` with nothing written, while any
           append that completed before it is durable on disk;
        3. **repair + drain the tail to the fenced end** -- every acked
           op (and any durable-but-unacked record, the standard recovery
           convention) is applied to this replica's state;
        4. **open the epoch-E writer** over that state -- a
           :class:`~repro.ckpt.durable.DurableService` sharing this
           replica's committed pytree, appending epoch-E segments.

        The replica keeps serving reads (its broker never stops) and
        resumes tailing afterwards, now following its own writer's log.
        Raises :class:`~repro.fault.errors.Unavailable` when the lease
        cannot be taken (holder still alive / lost the takeover race).
        """
        if not lease.try_acquire():
            raise fault_errors.Unavailable(
                f"replica {self.replica_id} could not take the write "
                f"lease (holder alive or takeover race lost)",
                retry_after=lease.ttl_s)
        # pause tailing so the drain below owns the tailer exclusively
        resume = self._thread is not None
        if resume:
            self._stop.set()
            self._thread.join()
            self._thread = None
        oplog.write_fence(wal_dir(self._dir), lease.epoch)
        oplog.repair_tail(wal_dir(self._dir))
        for _ in range(100_000):
            before = self._svc.gen
            if self.tail_once(max_records=None) == 0 \
                    and self._svc.gen == before:
                break
        else:
            raise fault_errors.WalGap(
                f"replica {self.replica_id} could not drain the WAL "
                f"tail to the fenced end (no progress)")
        leader = DurableService(
            self._svc._cfg, self._dir, state=self._svc._committed,
            boot_snapshot=False, _defer_wal=True, lease=lease,
            **self._decision_kwargs, **durable_kwargs)
        leader._attach_wal()  # opens the first epoch-E segment
        if resume:
            self._stop = threading.Event()
            self._thread = threading.Thread(
                target=self._run, name=f"scc-replica-{self.replica_id}",
                daemon=True)
            self._thread.start()
        return leader

    def _run(self):
        """Pull loop on a wall-clock-aligned grid: ticks land at
        ``k * poll_interval + poll_offset``, so a ReplicaSet can stagger
        its members' pull phases evenly across the period -- the
        freshness wait a reader sees drops from ~period/2 (one replica)
        to ~period/2N (N staggered replicas), which is the lag-hiding
        the replica-scaling bench measures.  Each tick is ONE unbounded
        pull -- the durable prefix as of tick time; records appended
        while it applies wait for the next tick (chasing them would
        degenerate into busy-tailing whenever the writer is active)."""
        period = self._poll_interval
        while not self._stop.is_set():
            # heartbeat stamped at tick START as well as end: a single
            # long apply (first-touch compile, large batch) must read as
            # one slow tick, not health_misses missed polls -- otherwise
            # the supervisor shuts a live replica down mid-apply and the
            # restart recompiles, looping the quarantine
            self._last_tick = time.monotonic()
            try:
                self.tail_once(max_records=None)
            except BaseException as e:  # surfaced via stats/stop
                self.error = e
                return
            self._last_tick = time.monotonic()  # health heartbeat
            now = time.monotonic()
            phase = (now - self._poll_offset) / period
            next_tick = (int(phase) + 1) * period + self._poll_offset
            self._stop.wait(max(1e-4, next_tick - now))

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self.broker.stop()
        if self.error is not None:
            raise self.error

    def shutdown(self) -> BaseException | None:
        """Quarantine-path stop: like :meth:`stop` but never raises --
        the supervisor tears down an already-failed replica and needs
        the error as a value, not a crash of its own loop."""
        try:
            self.stop()
        except BaseException as e:
            return e
        return None

    def stats(self) -> dict:
        out = {f"replica{self.replica_id}_{k}": val
               for k, val in self.broker.stats().items()}
        out[f"replica{self.replica_id}_gen"] = self.gen
        out[f"replica{self.replica_id}_applied"] = self.applied_records
        out[f"replica{self.replica_id}_resyncs"] = self.resyncs
        out[f"replica{self.replica_id}_healthy"] = self.healthy
        return out


class ReplicaSet:
    """Broker-shaped facade over N replicas with freshness-aware routing.

    Drop-in where a :class:`QueryBroker` is expected (a
    :class:`repro.api.GraphClient` takes it as its ``broker``, typically
    with the *writer* service as the update path -- writes go to the
    writer, reads to the replicas, and READ_YOUR_WRITES floors flow
    through ``min_gen`` to a replica that has tailed far enough).
    """

    def __init__(self, directory: str, n: int = 2, *,
                 query_buckets: Sequence[int] = (64, 256, 1024),
                 poll_interval: float = 0.002,
                 auto_tail: bool = True, supervise: bool = False,
                 health_check_s: float | None = None,
                 max_restarts: int = 8,
                 promote_on_writer_loss: bool = False,
                 lease_ttl_s: float = 0.5,
                 writer_kwargs: dict | None = None, **replica_kwargs):
        assert n >= 1
        self._dir = directory
        self._n = n
        self._query_buckets = query_buckets
        self._poll_interval = poll_interval
        self._auto_tail = auto_tail
        self._replica_kwargs = replica_kwargs
        self.replicas: List[Replica] = [
            self._spawn_replica(i) for i in range(n)]
        self._rr = itertools.count()
        self._owner: Dict[Future, Tuple[Replica, str, object, object,
                                        int]] = {}
        self._lock = threading.Lock()
        self._stopped = False
        self.routed_fresh = 0
        self.routed_stale = 0
        self.quarantined = 0
        self.restarts = 0
        self.failovers = 0
        self._max_restarts = max_restarts
        self._health_check_s = health_check_s if health_check_s \
            is not None else max(4 * poll_interval, 0.02)
        # writer failover: when the store's write lease goes stale (the
        # leader's heartbeat died), the supervisor promotes the most
        # caught-up healthy replica into a new DurableService leader
        self._promote = bool(promote_on_writer_loss)
        self._lease_ttl_s = float(lease_ttl_s)
        self._writer_kwargs = dict(writer_kwargs or {})
        self._leader: DurableService | None = None
        self.promotions = 0
        self.promote_failures = 0
        self.last_promote_error: BaseException | None = None
        self._sup_stop = threading.Event()
        self._sup_thread: threading.Thread | None = None
        if supervise or self._promote:
            self._sup_thread = threading.Thread(
                target=self._supervise, name="scc-replica-supervisor",
                daemon=True)
            self._sup_thread.start()

    def _spawn_replica(self, i: int) -> Replica:
        return Replica(self._dir, i, query_buckets=self._query_buckets,
                       poll_interval=self._poll_interval,
                       poll_offset=i * self._poll_interval / self._n,
                       auto_tail=self._auto_tail, **self._replica_kwargs)

    # -------------------------------------------------------- supervisor --

    def _supervise(self):
        """Quarantine dead replicas and restart them via snapshot
        fast-forward: a replacement :class:`Replica` bootstraps from the
        newest snapshot (the same forward-only jump as ``_resync``) and
        tails from there -- recovery cost is one snapshot restore."""
        seen: set = set()  # replicas already quarantined (strong refs:
        # an id()-keyed set could alias a collected replica's reuse)
        while not self._sup_stop.wait(self._health_check_s):
            if self._promote and self._leader is None \
                    and not self._stopped:
                self._maybe_promote()
            for i, rep in enumerate(list(self.replicas)):
                if rep.healthy or self._stopped:
                    continue
                if rep not in seen:  # quarantine + teardown once only
                    seen.add(rep)
                    with self._lock:
                        self.quarantined += 1
                    rep.shutdown()  # releases parked waiters, typed
                with self._lock:
                    exhausted = self.restarts >= self._max_restarts
                if exhausted:
                    continue  # stays dead; routing ignores it
                try:
                    fresh = self._spawn_replica(i)
                except Exception:
                    continue  # store unreadable right now; next tick
                with self._lock:
                    raced_stop = self._stopped
                    if not raced_stop:
                        self.replicas[i] = fresh
                        self.restarts += 1
                if raced_stop:  # raced a stop(): tear it down
                    fresh.shutdown()

    def _maybe_promote(self):
        """Writer-failover check: a lease file that exists but has gone
        stale means the leader's heartbeat died -- promote the most
        caught-up healthy replica.  No lease file means the deployment
        never elected a writer; promoting would CREATE a split brain
        instead of healing one, so the supervisor stands down."""
        from repro.ha.lease import FileLease
        lease = FileLease(
            self._dir, owner=f"replicaset-{os.getpid()}",
            ttl_s=self._lease_ttl_s)
        info = lease.peek()
        if info is None or info.age_s < self._lease_ttl_s:
            return  # no HA deployment here, or the writer is alive
        cands = self.healthy_replicas
        if not cands:
            return
        rep = max(cands, key=lambda r: r.gen)
        try:
            leader = rep.promote(lease, **self._writer_kwargs)
        except fault_errors.Unavailable:
            return  # takeover race lost / writer revived: not a failure
        except Exception as e:
            self.promote_failures += 1
            self.last_promote_error = e
            return
        with self._lock:
            self._leader = leader
            self.promotions += 1

    @property
    def leader(self) -> DurableService | None:
        """The writer this set promoted after a failover (None until a
        promotion happened).  Clients pass ``lambda: rset.leader`` as
        their ``leader_resolver`` to reroute updates on ``NotLeader``."""
        return self._leader

    @property
    def healthy_replicas(self) -> List[Replica]:
        return [r for r in self.replicas if r.healthy]

    # ------------------------------------------------- broker interface ---

    def submit(self, kind: str, u, v=None, min_gen: int = 0) -> Future:
        for _attempt in range(self._n + 2):
            if self._stopped:
                raise fault_errors.BrokerStopped("ReplicaSet is stopped")
            healthy = self.healthy_replicas
            if not healthy:
                raise fault_errors.Unavailable(
                    "no healthy replica (all killed/quarantined); "
                    "supervisor restart pending",
                    retry_after=max(self._health_check_s,
                                    self._poll_interval))
            fresh = [r for r in healthy if r.gen >= min_gen]
            if fresh:
                rep = fresh[next(self._rr) % len(fresh)]
            else:
                # nobody fresh yet.  The floor comes from an acked
                # write, so its WAL record is already durable: EVERY
                # tailing replica will cover it at its next pull tick --
                # route to the replica whose tick lands first (staggered
                # sets: ~period/N away), not the currently-most-caught-
                # up one (it pulled most recently, so its next tick is
                # the FURTHEST away).  Without tail threads (manual
                # tests) etas are inf and the key falls back to the most
                # caught-up replica.
                rep = min(healthy,
                          key=lambda r: (r.next_tick_eta(), -r.gen))
            try:
                fut = rep.broker.submit(kind, u, v, min_gen=min_gen)
            except fault_errors.BrokerStopped:
                continue  # replica died between the health check and
                # the submit: pick again among the survivors
            if fresh:
                self.routed_fresh += 1
            else:
                self.routed_stale += 1
            with self._lock:
                self._owner[fut] = (rep, kind, u, v, min_gen)
            return fut
        raise fault_errors.Unavailable(
            "replica routing did not converge (replicas dying faster "
            "than the supervisor restarts them)",
            retry_after=self._health_check_s)

    def resolve(self, fut: Future, min_gen: int = 0,
                timeout: float | None = None):
        """Resolve with transparent failover: when the owning replica
        dies mid-flight (its broker releases the future with a typed
        ``BrokerStopped``), the query -- read-only, hence always safe to
        re-issue -- is resubmitted to a healthy peer.  Bounded attempts;
        ``Unavailable`` surfaces when no peer is left."""
        deadline = None if timeout is None else \
            time.monotonic() + timeout
        attempts = self._n + 2
        for _attempt in range(attempts):
            with self._lock:
                owner = self._owner.pop(fut, None)
            remaining = None if deadline is None else \
                max(0.0, deadline - time.monotonic())
            try:
                if owner is None:
                    return fut.result(timeout=remaining)
                rep = owner[0]
                if rep.broker.dispatching:
                    return fut.result(timeout=remaining)
                return rep.broker.resolve(fut, min_gen=min_gen,
                                          timeout=remaining)
            except fault_errors.BrokerStopped:
                if owner is None:
                    raise  # nothing recorded to replay it from
                if _attempt + 1 == attempts:
                    break  # out of attempts: a resubmit here would be
                    # abandoned (queued forever, its _owner entry leaked)
                self.failovers += 1
                _, kind, u, v, mg = owner
                fut = self.submit(kind, u, v, min_gen=mg)
            except _FutureTimeout:
                raise fault_errors.DeadlineExceeded(
                    f"replica query unresolved after {timeout:.3f}s"
                ) from None
        raise fault_errors.Unavailable(
            "query failover did not converge",
            retry_after=self._health_check_s)

    @property
    def dispatching(self) -> bool:
        return any(r.broker.dispatching for r in self.replicas)

    def stop(self):
        """Stop the supervisor, then every replica.  All parked waiters
        are released with typed errors by the per-replica broker stops
        (``BrokerStopped``); replica tail errors surface afterwards --
        kills injected by a fault plan are expected and not re-raised."""
        with self._lock:
            self._stopped = True
        self._sup_stop.set()
        if self._sup_thread is not None:
            self._sup_thread.join()
            self._sup_thread = None
        errors = []
        for r in self.replicas:
            e = r.shutdown()
            if e is not None:
                errors.append(e)
        if self._leader is not None:
            try:  # the set promoted it, the set closes it (graceful
                self._leader.close()  # handoff: lease mtime backdated)
            except Exception as e:
                errors.append(e)
        if errors:
            raise errors[0]

    def __enter__(self) -> "ReplicaSet":
        return self

    def __exit__(self, *exc):
        self.stop()

    # -------------------------------------------------------- telemetry ---

    @property
    def min_gen(self) -> int:
        reps = self.healthy_replicas or self.replicas
        return min(r.gen for r in reps)

    def wait_all_for_gen(self, gen: int, timeout: float | None = None):
        """Block until every *healthy* replica has tailed to ``gen``
        (test/bench convergence barrier; dead replicas would never get
        there and must not hang the caller)."""
        for r in self.replicas:
            if r.healthy:
                r.wait_for_gen(gen, timeout)
        return self.min_gen

    def stats(self) -> dict:
        out = {"replicas": len(self.replicas),
               "healthy": len(self.healthy_replicas),
               "routed_fresh": self.routed_fresh,
               "routed_stale": self.routed_stale,
               "quarantined": self.quarantined,
               "restarts": self.restarts,
               "failovers": self.failovers,
               "promotions": self.promotions,
               "promote_failures": self.promote_failures,
               "served": sum(r.broker.served for r in self.replicas),
               "flushes": sum(r.broker.flushes for r in self.replicas),
               "gen_waits": sum(r.broker.gen_waits
                                for r in self.replicas)}
        for r in self.replicas:
            out.update(r.stats())
        return out
