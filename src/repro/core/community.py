"""Wait-free queries & the paper's community-detection application (§5.3).

The paper's ``checkSCC``/``blongsToCommunity`` are wait-free list scans; the
TPU analogue is stronger: a query batch is one vectorized gather over the
label array, so thousands of membership checks cost one memory sweep and
never interfere with update steps (functional state: readers see a
consistent snapshot by construction -- the linearization point is the state
version ``gen`` they read).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import graph_state as gs


@jax.jit
def check_scc(state: gs.GraphState, u, v):
    """Batched checkSCC(u, v): same strongly connected component?

    u, v: int32[Q].  Returns bool[Q]; false when either endpoint is absent
    (paper Alg. 23 contract).
    """
    nv = state.ccid.shape[0]
    u = jnp.clip(u, 0, nv - 1)
    v = jnp.clip(v, 0, nv - 1)
    alive = state.v_alive[u] & state.v_alive[v]
    return alive & (state.ccid[u] == state.ccid[v])


@jax.jit
def belongs_to_community(state: gs.GraphState, u):
    """Batched blongsToCommunity(u): the community (SCC) id of u.

    Returns int32[Q]; the sentinel ``n_vertices`` for absent vertices.
    """
    nv = state.ccid.shape[0]
    uu = jnp.clip(u, 0, nv - 1)
    lab = jnp.where(state.v_alive[uu], state.ccid[uu], nv)
    return lab


@jax.jit
def community_sizes(state: gs.GraphState):
    """Histogram of community sizes, indexed by representative id."""
    nv = state.ccid.shape[0]
    idx = jnp.where(state.v_alive, state.ccid, nv)
    return jax.ops.segment_sum(state.v_alive.astype(jnp.int32),
                               jnp.minimum(idx, nv), num_segments=nv + 1)[:nv]


@jax.jit
def largest_community(state: gs.GraphState):
    """(representative id, size) of the largest SCC."""
    sizes = community_sizes(state)
    rep = jnp.argmax(sizes)
    return rep.astype(jnp.int32), sizes[rep]


@jax.jit
def same_community_pairs(state: gs.GraphState, users):
    """All-pairs community matrix for a user cohort (friend-suggestion app).

    users: int32[K] -> bool[K, K]; entry (i, j) = suggest i<->j candidate.
    """
    lab = belongs_to_community(state, users)
    nv = state.ccid.shape[0]
    ok = lab < nv
    return (lab[:, None] == lab[None, :]) & ok[:, None] & ok[None, :]
