"""Masked multi-source reachability -- the TPU analogue of the paper's DFS.

The paper's ``DFSFW``/``DFSBW`` (Algorithms 10/11) walk pointers serially.
On TPU, reachability is round-synchronous frontier propagation: each round
is one edge-parallel gather + scatter-max over the COO edge table; rounds
are bounded by the diameter of the *masked* region (the paper's "limited"
property -- sweeps never leave the affected region).

Three execution paths:
  * sparse (this module): ``O(E)`` work per round on the VPU via segment ops
    over the full edge table; the overflow fallback for huge regions.
  * compact sparse: the same fixpoints run over region-compacted operands
    (:func:`repro.core.scc.compact_region`) -- every function here is
    shape-generic, so the repair engine feeds it bounded sub-arrays and
    each round costs O(region edges) instead of O(table capacity).
  * dense  (:mod:`repro.kernels.reach_blockmm`): boolean-semiring blocked
    mat-mul on the MXU; right when the region is compact enough to densify.

Every function is a pure jit-able map; fixpoints are ``lax.while_loop`` with
an explicit ``changed`` flag plus an iteration cap (static bound).

Every sweep's per-round reduction is ONE primitive -- a segment-min of
uint32 edge messages into destination vertices (booleans ride the
min-semiring: reached -> 0, blocked -> SENTINEL) -- routed through
:func:`repro.kernels.frontier_expand.ops.frontier_min`.  ``impl`` selects
the engine per GraphConfig.sparse_impl: the XLA scatter-min oracle or the
Pallas panel kernel, bit-identical by construction and by the
differential fuzz suite (tests/test_sparse_kernels.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.frontier_expand import ops as frontier

SENT = frontier.SENTINEL  # uint32 min-semiring identity
ZERO_U32 = jnp.uint32(0)


def _fixpoint(body, init, max_iters: int):
    """while any-change and iters < cap: state = body(state).

    ``body`` maps state -> (state, changed: bool[]).
    """

    def cond(carry):
        _, changed, it = carry
        return changed & (it < max_iters)

    def step(carry):
        state, _, it = carry
        state, changed = body(state)
        return state, changed, it + 1

    state, _, iters = jax.lax.while_loop(
        cond, step, (init, jnp.bool_(True), jnp.int32(0)))
    return state, iters


def _constrain(x, spec):
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def forward_reach(src, dst, live, seeds, allowed, max_iters: int,
                  spec=None, impl: str = "xla"):
    """bool[NV]: vertices reachable from ``seeds`` along live edges, staying
    inside ``allowed`` (both endpoints).  Seeds outside ``allowed`` are
    dropped.  Returns (reached, rounds).  ``spec`` optionally pins the
    frontier's sharding (see GraphConfig.label_spec)."""
    nv = seeds.shape[0]
    reached0 = _constrain(seeds & allowed, spec)

    def body(reached):
        msg = jnp.where(reached[src] & live, ZERO_U32, SENT)
        incoming = frontier.frontier_min(dst, msg, nv, impl=impl)
        nxt = _constrain(reached | ((incoming == 0) & allowed), spec)
        return nxt, jnp.any(nxt != reached)

    return _fixpoint(body, reached0, max_iters)


def backward_reach(src, dst, live, seeds, allowed, max_iters: int,
                   spec=None, impl: str = "xla"):
    """Reachability along *reversed* edges (paper's DFSBW / incoming list)."""
    return forward_reach(dst, src, live, seeds, allowed, max_iters,
                         spec=spec, impl=impl)


def propagate_min_labels(src, dst, live, labels, allowed, max_iters: int,
                         spec=None, shortcut: bool = False,
                         impl: str = "xla"):
    """Forward min-label propagation to fixpoint (the 'coloring' sweep).

    labels[v] converges to min(labels[u] : u ⇝ v within allowed, incl. v).
    Vertices outside ``allowed`` keep their input label and do not relay.
    Returns (labels, rounds).

    ``shortcut=True`` adds Shiloach-Vishkin pointer doubling per round:
    lab[v] <- min(lab[v], lab[lab[v]]).  Sound: lab[v]=u certifies u ⇝ v
    inside ``allowed`` and lab[u]=w certifies w ⇝ u, so w ⇝ v by
    transitivity; the fixpoint is unchanged but label chains collapse in
    O(log diameter) rounds instead of O(diameter) -- the §Perf
    round-count knob for the dominant coloring loop.
    """
    nv = labels.shape[0]
    sentinel = jnp.iinfo(labels.dtype).max
    # labels ride the kernel's uint32 min-semiring: non-negative int32
    # labels order-embed into uint32, and clamping the incoming minimum
    # back to the dtype sentinel makes the round-trip exact
    assert jnp.iinfo(labels.dtype).bits <= 32, labels.dtype

    def body(lab):
        msg = jnp.where(live & allowed[src], lab[src].astype(jnp.uint32),
                        SENT)
        incoming = frontier.frontier_min(dst, msg, nv, impl=impl)
        incoming = jnp.minimum(incoming, jnp.uint32(sentinel)).astype(
            lab.dtype)
        nxt = jnp.where(allowed, jnp.minimum(lab, incoming), lab)
        if shortcut:
            hop = nxt[jnp.clip(nxt, 0, nv - 1)]
            nxt = jnp.where(allowed & (nxt < sentinel),
                            jnp.minimum(nxt, hop), nxt)
        nxt = _constrain(nxt, spec)
        return nxt, jnp.any(nxt != lab)

    return _fixpoint(body, labels, max_iters)


def multi_forward_reach(src, dst, live, seeds, allowed, max_iters: int,
                        impl: str = "xla"):
    """Batched reachability: seeds/result are bool[B, NV].

    One gather/segment-min per round moves all B frontiers simultaneously
    -- the B axis is the kernel's frontier dimension (and, on the dense
    tier, what feeds the MXU).
    """
    nv = seeds.shape[1]
    reached0 = seeds & allowed[None, :]

    def body(reached):
        msg = jnp.where(reached[:, src] & live[None, :], ZERO_U32, SENT)
        incoming = frontier.frontier_min(dst, msg, nv, impl=impl)
        nxt = reached | ((incoming == 0) & allowed[None, :])
        return nxt, jnp.any(nxt != reached)

    return _fixpoint(body, reached0, max_iters)


# Bijective priority hash (odd multiplier mod 2^32) + modular inverse.
# Random-looking priorities break monotone id runs: with raw ids, a path
# whose ids increase propagates min-labels one hop per round and pointer
# doubling is useless (the witness pointer is a self-loop).  With hashed
# priorities the expected run length is O(1), so doubling collapses any
# path in O(polylog) rounds in BOTH edge directions.
P_MUL = 0x9E3779B1
P_INV = pow(P_MUL, -1, 2 ** 32)
PRIO_SENT = jnp.uint32(0xFFFFFFFF)
# the vertex whose priority equals the sentinel (guard: ids must stay
# below it; it is ~3.9e9, far above any practical n_vertices)
SENT_PREIMAGE = (0xFFFFFFFF * P_INV) % (2 ** 32)


def _prio(v):
    return v.astype(jnp.uint32) * jnp.uint32(P_MUL)


def _unprio(p):
    return (p * jnp.uint32(P_INV)).astype(jnp.int32)


def propagate_min_prio(src, dst, live, active, max_iters: int, spec=None,
                       impl: str = "xla"):
    """Witness propagation with pointer doubling under hashed priorities.

    Returns (witness int32[NV], rounds): witness[v] = the vertex with
    minimum hashed priority among {u : u ⇝ v within active} (v itself
    included); n/a slots return nv.  Swap (src, dst) for the reachable-set
    version.  Expected O(polylog) rounds on any topology -- the §Perf
    upgrade over raw-id coloring, whose worst case is O(diameter).
    """
    nv = active.shape[0]
    assert nv < SENT_PREIMAGE
    vid = jnp.arange(nv, dtype=jnp.int32)
    lab0 = jnp.where(active, _prio(vid), PRIO_SENT)

    def body(lab):
        msg = jnp.where(live & active[src], lab[src], PRIO_SENT)
        incoming = frontier.frontier_min(dst, msg, nv, impl=impl)
        nxt = jnp.where(active, jnp.minimum(lab, incoming), lab)
        # pointer jump through the witness vertex
        w = jnp.clip(_unprio(nxt), 0, nv - 1)
        hop = nxt[w]
        nxt = jnp.where(active & (nxt != PRIO_SENT),
                        jnp.minimum(nxt, hop), nxt)
        nxt = _constrain(nxt, spec)
        return nxt, jnp.any(nxt != lab)

    lab, rounds = _fixpoint(body, lab0, max_iters)
    witness = jnp.where(lab != PRIO_SENT, _unprio(lab), nv)
    return witness, rounds


def fused_fw_bw_reach(src, dst, live, seed_f, seed_b, allowed,
                      max_iters: int, spec=None, impl: str = "xla"):
    """FW(seed_f) and BW(seed_b) in ONE fixpoint over a stacked [2, NV]
    frontier -- the two sweeps of the paper's repair run simultaneously,
    so the round count is max(d_fw, d_bw) instead of d_fw + d_bw and each
    round issues a single (2x wider) merge instead of two."""
    nv = allowed.shape[0]
    reached0 = jnp.stack([seed_f & allowed, seed_b & allowed])
    if spec is not None:
        reached0 = jax.lax.with_sharding_constraint(
            reached0, jax.sharding.PartitionSpec(None, *spec))

    def body(reached):
        msg_f = jnp.where(reached[0][src] & live, ZERO_U32, SENT)
        msg_b = jnp.where(reached[1][dst] & live, ZERO_U32, SENT)
        inc_f = frontier.frontier_min(dst, msg_f, nv, impl=impl)
        inc_b = frontier.frontier_min(src, msg_b, nv, impl=impl)
        new = jnp.stack([inc_f == 0, inc_b == 0])
        nxt = reached | (new & allowed[None, :])
        if spec is not None:
            nxt = jax.lax.with_sharding_constraint(
                nxt, jax.sharding.PartitionSpec(None, *spec))
        return nxt, jnp.any(nxt != reached)

    reached, rounds = _fixpoint(body, reached0, max_iters)
    return reached[0], reached[1], rounds


def is_reachable(src, dst, live, u, v, allowed, max_iters: int,
                 impl: str = "xla"):
    """Paper's ``isReachable`` (used by AddEdge step 4): scalar u ⇝ v?"""
    nv = allowed.shape[0]
    seeds = jnp.zeros((nv,), jnp.bool_).at[u].set(True)
    reached, _ = forward_reach(src, dst, live, seeds, allowed, max_iters,
                               impl=impl)
    return reached[v]
