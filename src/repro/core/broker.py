"""Thread-safe reader path for the streaming SCC service.

The paper's readers (arXiv:1804.01276, and the non-blocking sibling
arXiv:1809.00896) run *concurrently* with a fixed pool of update threads
and are wait-free: a query never blocks an update and always observes a
consistent state.  Our compiled analogue: reader threads hand their point
queries to a :class:`QueryBroker`, which coalesces everything pending into
one padded batched device call per query kind against a single *pinned*
committed snapshot, then distributes the generation-stamped answers.

Consistency contract (see ``docs/SERVICE_API.md``):

* every flush pins ``service.state`` exactly once -- all answers of that
  flush share one generation, and the pinned state is always a fully
  committed snapshot (the service never publishes in-flight pipeline
  states, and the pipeline donates only its own private double buffer);
* the snapshot is pinned *after* the pending set is collected, so a
  reader that saw generation ``g`` and then submits again can only be
  answered at a generation ``>= g`` (monotone reads per reader);
* padding lanes target vertex 0 on the snapshot but their results are
  discarded before distribution, so they can never alias a real answer.

Compilations stay bounded: coalesced batches are cut/padded to the
broker's own bucket registry (the same ``prefill_bs{N}`` trick as the
update path), so query-step compiles are at most ``len(buckets)`` per
query kind per graph config.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core import service as svc_mod

__all__ = ["QueryBroker"]

_KINDS = ("same_scc", "reachable", "scc_members")


class QueryBroker:
    """Coalesces concurrent reader queries into batched snapshot calls.

    Two operating modes:

    * **dispatcher thread** (``start()`` / ``stop()``, or use the broker
      as a context manager): a background thread drains the pending set
      whenever it is non-empty -- readers just call the blocking wrappers.
    * **inline**: without a dispatcher, blocking wrappers flush the
      pending set themselves (and piggyback on whichever thread got there
      first), which keeps single-threaded callers and tests simple.
    """

    def __init__(self, service, buckets: Sequence[int] = (64, 256, 1024)):
        from repro.launch.stream import BucketedScheduler
        self._svc = service
        self._sched = BucketedScheduler(buckets)
        self._cv = threading.Condition()
        self._pending: Dict[str, List[Tuple[np.ndarray, np.ndarray,
                                            Future]]] = {
            k: [] for k in _KINDS}
        self._thread: threading.Thread | None = None
        self._stopping = False
        # telemetry
        self.flushes = 0
        self.served = 0
        self.max_coalesced = 0

    # ------------------------------------------------------- submission ---

    def submit(self, kind: str, u, v=None) -> Future:
        """Queue a query batch; returns a Future resolving to a
        :class:`repro.core.service.Snapshot`."""
        assert kind in _KINDS, f"unknown query kind {kind!r}"
        u = np.atleast_1d(np.asarray(u, np.int32))
        v = np.zeros_like(u) if v is None \
            else np.atleast_1d(np.asarray(v, np.int32))
        assert u.shape == v.shape
        fut: Future = Future()
        with self._cv:
            if self._stopping:
                raise RuntimeError("QueryBroker is stopped")
            self._pending[kind].append((u, v, fut))
            self._cv.notify()
        return fut

    def same_scc(self, u, v) -> svc_mod.Snapshot:
        """Blocking SameSCC through the coalescer."""
        return self._resolve(self.submit("same_scc", u, v))

    def reachable(self, u, v) -> svc_mod.Snapshot:
        """Blocking reachability through the coalescer."""
        return self._resolve(self.submit("reachable", u, v))

    def scc_members(self, u) -> svc_mod.Snapshot:
        """Blocking membership-mask query; value is bool[Q, NV]."""
        return self._resolve(self.submit("scc_members", u))

    def _resolve(self, fut: Future) -> svc_mod.Snapshot:
        if self._thread is None or not self._thread.is_alive():
            # inline mode: some thread must drain the queue; a concurrent
            # flush may already have taken our request, in which case this
            # flush is a cheap no-op and result() waits for the other one.
            self.flush()
        return fut.result()

    # ---------------------------------------------------------- flushing --

    def flush(self) -> int:
        """Answer everything pending against ONE pinned committed snapshot;
        returns the number of point queries served."""
        with self._cv:
            batch = {k: reqs for k, reqs in self._pending.items() if reqs}
            for k in batch:
                self._pending[k] = []
        if not batch:
            return 0
        # Pin AFTER collecting the batch: a reader already answered at gen
        # g resubmits only after its result arrived, hence after the flush
        # that pinned g -- commits are monotone, so this pin sees >= g.
        # cfg may be read mid-grow relative to st, but the only mutable
        # field (edge_capacity) never enters a query: n_vertices/max_inner
        # are fixed for the service's lifetime.
        st = self._svc.state
        cfg = self._svc.cfg
        try:
            gen = int(st.gen)
            served = 0
            for kind, reqs in batch.items():
                served += self._flush_kind(kind, reqs, st, cfg, gen)
        except BaseException as e:
            for reqs in batch.values():
                for _, _, fut in reqs:
                    if not fut.done():
                        fut.set_exception(e)
            raise
        self.flushes += 1
        self.served += served
        return served

    def _flush_kind(self, kind, reqs, st, cfg, gen) -> int:
        u = np.concatenate([r[0] for r in reqs])
        v = np.concatenate([r[1] for r in reqs])
        n = u.shape[0]
        self.max_coalesced = max(self.max_coalesced, n)
        if kind == "scc_members":
            out = np.zeros((n, cfg.n_vertices), bool)
        else:
            out = np.zeros(n, bool)
        for sl, b in self._sched.plan(n):
            pu = np.zeros(b, np.int32)
            pv = np.zeros(b, np.int32)
            k = sl.stop - sl.start
            pu[:k] = u[sl]
            pv[:k] = v[sl]
            if kind == "same_scc":
                out[sl] = svc_mod.same_scc_on(st, cfg, pu, pv)[:k]
            elif kind == "reachable":
                out[sl] = svc_mod.reachable_on(st, cfg, pu, pv)[:k]
            else:
                out[sl] = svc_mod.members_on(st, cfg, pu)[:k]
        pos = 0
        for ru, _, fut in reqs:
            k = ru.shape[0]
            fut.set_result(svc_mod.Snapshot(out[pos:pos + k], gen))
            pos += k
        return n

    # ------------------------------------------------------- dispatcher ---

    def start(self) -> "QueryBroker":
        """Spawn the background dispatcher thread (idempotent)."""
        with self._cv:
            self._stopping = False
            if self._thread is not None and self._thread.is_alive():
                return self
            self._thread = threading.Thread(
                target=self._run, name="scc-query-broker", daemon=True)
            self._thread.start()
        return self

    def stop(self):
        """Drain outstanding queries, then stop the dispatcher."""
        with self._cv:
            self._stopping = True
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        # a dispatcher that died on a flush error may leave pending
        # futures behind -- fail them rather than hang their readers
        with self._cv:
            leftovers = [fut for reqs in self._pending.values()
                         for _, _, fut in reqs]
            for k in self._pending:
                self._pending[k] = []
        for fut in leftovers:
            if not fut.done():
                fut.set_exception(RuntimeError("QueryBroker stopped"))

    def _run(self):
        while True:
            with self._cv:
                while not self._stopping and \
                        not any(self._pending.values()):
                    self._cv.wait(timeout=0.05)
                if self._stopping and not any(self._pending.values()):
                    return
            try:
                self.flush()
            except BaseException:
                # flush already failed its own collected futures; keep the
                # dispatcher alive so later submitters are not orphaned
                # waiting on a thread that silently died
                continue

    def __enter__(self) -> "QueryBroker":
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    def stats(self) -> dict:
        return {"flushes": self.flushes, "served": self.served,
                "max_coalesced": self.max_coalesced,
                "coalescing": round(self.served / self.flushes, 2)
                if self.flushes else 0.0}
