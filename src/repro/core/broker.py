"""Thread-safe reader path for the streaming SCC service.

The paper's readers (arXiv:1804.01276, and the non-blocking sibling
arXiv:1809.00896) run *concurrently* with a fixed pool of update threads
and are wait-free: a query never blocks an update and always observes a
consistent state.  Our compiled analogue: reader threads hand their point
queries to a :class:`QueryBroker`, which coalesces everything pending into
one padded batched device call per query kind against a single *pinned*
committed snapshot, then distributes the generation-stamped answers.  The
paper's §5.3 community application rides the same path: ``community_of``
(blongsToCommunity) and ``community_sizes`` are broker kinds, not
raw-state helpers.

Consistency contract (see ``docs/SERVICE_API.md``):

* every flush pins ``service.state`` exactly once -- all answers of that
  flush share one generation, and the pinned state is always a fully
  committed snapshot (the service never publishes in-flight pipeline
  states, and the pipeline donates only its own private double buffer);
* the snapshot is pinned *after* the pending set is collected, so a
  reader that saw generation ``g`` and then submits again can only be
  answered at a generation ``>= g`` (monotone reads per reader);
* **gen-wait hook**: a request may carry ``min_gen`` -- the floor behind
  the client API's ``AT_LEAST`` / ``READ_YOUR_WRITES`` consistency
  levels.  A flush whose pinned generation is below a request's floor
  defers that request (re-queued, ``gen_waits`` telemetry) and answers it
  on a later flush once the service commits past the floor; requests
  whose floor is already covered are never delayed by waiting ones;
* padding lanes target vertex 0 on the snapshot but their results are
  discarded before distribution, so they can never alias a real answer.

Compilations stay bounded: coalesced batches are cut/padded to the
broker's own bucket registry (the same ``prefill_bs{N}`` trick as the
update path), so query-step compiles are at most ``len(buckets)`` per
query kind per graph config.

This module is the *internal* reader surface: multi-threaded callers
should hold a :class:`repro.api.GraphClient` per session rather than
calling the string-kind ``submit`` directly (the CI gate rejects
string-kind submits outside ``src/repro/core``).
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from concurrent.futures import TimeoutError as _FutureTimeout
from typing import Dict, List, NamedTuple, Sequence, Set

import numpy as np

from repro.core import service as svc_mod
from repro.fault import errors as fault_errors
from repro.fault.inject import maybe_stall

__all__ = ["QueryBroker"]

_KINDS = ("same_scc", "reachable", "scc_members", "community_of",
          "community_sizes")


class _Req(NamedTuple):
    u: np.ndarray
    v: np.ndarray
    min_gen: int
    fut: Future


class QueryBroker:
    """Coalesces concurrent reader queries into batched snapshot calls.

    Two operating modes:

    * **dispatcher thread** (``start()`` / ``stop()``, or use the broker
      as a context manager): a background thread drains the pending set
      whenever it is non-empty -- readers just call the blocking wrappers.
    * **inline**: without a dispatcher, blocking wrappers flush the
      pending set themselves (and piggyback on whichever thread got there
      first), which keeps single-threaded callers and tests simple.
    """

    def __init__(self, service, buckets: Sequence[int] = (64, 256, 1024)):
        from repro.launch.stream import BucketedScheduler
        self._svc = service
        self._sched = BucketedScheduler(buckets)
        self._cv = threading.Condition()
        self._pending: Dict[str, List[_Req]] = {k: [] for k in _KINDS}
        self._thread: threading.Thread | None = None
        self._stopping = False
        # telemetry; _waited tracks requests already counted in gen_waits
        # so flush retries do not re-count the same deferred query
        self.flushes = 0
        self.served = 0
        self.max_coalesced = 0
        self.gen_waits = 0
        self._waited: Set[Future] = set()

    # ------------------------------------------------------- submission ---

    def submit(self, kind: str, u, v=None, min_gen: int = 0) -> Future:
        """Queue a query batch; returns a Future resolving to a
        :class:`repro.core.service.Snapshot`.

        ``min_gen`` is the consistency floor: the answer's generation is
        guaranteed ``>= min_gen`` (the request waits for such a commit).
        """
        assert kind in _KINDS, f"unknown query kind {kind!r}"
        u = np.atleast_1d(np.asarray(u, np.int32))
        v = np.zeros_like(u) if v is None \
            else np.atleast_1d(np.asarray(v, np.int32))
        assert u.shape == v.shape
        fut: Future = Future()
        with self._cv:
            if self._stopping:
                raise fault_errors.BrokerStopped("QueryBroker is stopped")
            self._pending[kind].append(_Req(u, v, int(min_gen), fut))
            self._cv.notify()
        return fut

    def same_scc(self, u, v, min_gen: int = 0) -> svc_mod.Snapshot:
        """Blocking SameSCC through the coalescer."""
        return self.resolve(self.submit("same_scc", u, v, min_gen=min_gen),
                            min_gen=min_gen)

    def reachable(self, u, v, min_gen: int = 0) -> svc_mod.Snapshot:
        """Blocking reachability through the coalescer."""
        return self.resolve(
            self.submit("reachable", u, v, min_gen=min_gen),
            min_gen=min_gen)

    def scc_members(self, u, min_gen: int = 0) -> svc_mod.Snapshot:
        """Blocking membership-mask query; value is bool[Q, NV]."""
        return self.resolve(
            self.submit("scc_members", u, min_gen=min_gen),
            min_gen=min_gen)

    def community_of(self, u, min_gen: int = 0) -> svc_mod.Snapshot:
        """Blocking community-id query; value is int32[Q] (sentinel
        ``n_vertices`` for absent ids)."""
        return self.resolve(
            self.submit("community_of", u, min_gen=min_gen),
            min_gen=min_gen)

    def community_sizes(self, min_gen: int = 0) -> svc_mod.Snapshot:
        """Blocking community-size histogram; value is int32[NV]."""
        return self.resolve(
            self.submit("community_sizes", [0], min_gen=min_gen),
            min_gen=min_gen)

    @property
    def dispatching(self) -> bool:
        """True when a background dispatcher thread is draining queries."""
        t = self._thread
        return t is not None and t.is_alive()

    def resolve(self, fut: Future, min_gen: int = 0,
                timeout: float | None = None) -> svc_mod.Snapshot:
        """Drive ``fut`` to completion and return its Snapshot.

        With a dispatcher running this just waits.  In inline mode some
        thread must drain the queue: flush here, waiting for the service
        to commit past ``min_gen`` first when the request carries a floor
        (a concurrent flush may already have taken the request, in which
        case our flush is a cheap no-op and ``result()`` waits for the
        other one).

        ``timeout`` bounds the whole wait; expiry raises
        :class:`~repro.fault.errors.DeadlineExceeded` (the request stays
        queued -- it is read-only, so a late answer is simply dropped).
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        while not fut.done() and not self.dispatching:
            if deadline is not None and time.monotonic() >= deadline:
                raise fault_errors.DeadlineExceeded(
                    f"query unresolved after {timeout:.3f}s "
                    f"(floor {min_gen}, committed {self._svc.gen})")
            if min_gen:
                # clamp the commit wait to the remaining deadline so a
                # caller-supplied timeout is honored tightly, not
                # overshot by up to a full wait slice
                slice_t = 0.5 if deadline is None else \
                    min(0.5, max(0.0, deadline - time.monotonic()))
                self._svc.wait_for_gen(min_gen, timeout=slice_t)
            served = self.flush()
            if fut.done():
                break
            if served == 0 and (not min_gen or self._svc.gen >= min_gen):
                # nothing here we could serve: either another thread's
                # flush owns our request (its result is imminent), or our
                # own flush re-queued it and a commit raced past the
                # floor between the pin and this check -- wait briefly,
                # then loop so the next flush serves the re-queued case
                # rather than assuming the former (which would hang).
                slice_t = 0.05 if deadline is None else \
                    min(0.05, max(0.0, deadline - time.monotonic()))
                try:
                    return fut.result(timeout=slice_t)
                except _FutureTimeout:
                    continue
        if deadline is not None:
            try:
                return fut.result(
                    timeout=max(0.0, deadline - time.monotonic()))
            except _FutureTimeout:
                raise fault_errors.DeadlineExceeded(
                    f"query unresolved after {timeout:.3f}s "
                    f"(floor {min_gen})") from None
        return fut.result()

    # ---------------------------------------------------------- flushing --

    def flush(self, fail_waiting: bool = False) -> int:
        """Answer everything pending whose consistency floor the pinned
        committed snapshot covers; returns the number of point queries
        served.  Requests still waiting on a commit are re-queued (or
        failed, with ``fail_waiting=True`` -- the stop path)."""
        maybe_stall("broker_flush")
        with self._cv:
            batch = {k: reqs for k, reqs in self._pending.items() if reqs}
            for k in batch:
                self._pending[k] = []
        if not batch:
            return 0
        # Pin AFTER collecting the batch: a reader already answered at gen
        # g resubmits only after its result arrived, hence after the flush
        # that pinned g -- commits are monotone, so this pin sees >= g.
        # cfg may be read mid-grow relative to st, but the only mutable
        # field (edge_capacity) never enters a query: n_vertices/max_inner
        # are fixed for the service's lifetime.
        st = self._svc.state
        cfg = self._svc.cfg
        gen = int(st.gen)
        # gen-wait hook: split off requests whose floor is above the
        # pinned generation; they wait for a later commit without
        # delaying the ready ones.
        waiting: List[tuple] = []  # (kind, request)
        ready = {}
        for kind, reqs in batch.items():
            rd = [r for r in reqs if r.min_gen <= gen]
            waiting.extend((kind, r) for r in reqs if r.min_gen > gen)
            if rd:
                ready[kind] = rd
        if waiting:
            for _, r in waiting:  # count each deferred query once
                if r.fut not in self._waited:
                    self._waited.add(r.fut)
                    self.gen_waits += 1
            if fail_waiting:
                for _, r in waiting:
                    self._waited.discard(r.fut)
                    if not r.fut.done():
                        r.fut.set_exception(fault_errors.BrokerStopped(
                            f"QueryBroker stopped before generation "
                            f"{r.min_gen} committed (at {gen})"))
            else:
                with self._cv:
                    for kind, r in waiting:
                        self._pending[kind].append(r)
                    self._cv.notify()
        if not ready:
            return 0
        for reqs in ready.values():  # leaving the pending system for good
            for r in reqs:
                self._waited.discard(r.fut)
        try:
            served = 0
            for kind, reqs in ready.items():
                served += self._flush_kind(kind, reqs, st, cfg, gen)
        except BaseException as e:
            for reqs in ready.values():
                for r in reqs:
                    if not r.fut.done():
                        r.fut.set_exception(e)
            raise
        self.flushes += 1
        self.served += served
        return served

    def _flush_kind(self, kind, reqs: List[_Req], st, cfg, gen) -> int:
        if kind == "community_sizes":
            # no per-lane ids: one histogram sweep answers every request
            hist = svc_mod.community_sizes_on(st, cfg)
            for r in reqs:
                r.fut.set_result(svc_mod.Snapshot(hist, gen))
            return len(reqs)
        u = np.concatenate([r.u for r in reqs])
        v = np.concatenate([r.v for r in reqs])
        n = u.shape[0]
        self.max_coalesced = max(self.max_coalesced, n)
        if kind == "scc_members":
            out = np.zeros((n, cfg.n_vertices), bool)
        elif kind == "community_of":
            out = np.full(n, cfg.n_vertices, np.int32)
        else:
            out = np.zeros(n, bool)
        for sl, b in self._sched.plan(n):
            pu = np.zeros(b, np.int32)
            pv = np.zeros(b, np.int32)
            k = sl.stop - sl.start
            pu[:k] = u[sl]
            pv[:k] = v[sl]
            if kind == "same_scc":
                out[sl] = svc_mod.same_scc_on(st, cfg, pu, pv)[:k]
            elif kind == "reachable":
                out[sl] = svc_mod.reachable_on(st, cfg, pu, pv)[:k]
            elif kind == "community_of":
                out[sl] = svc_mod.community_of_on(st, cfg, pu)[:k]
            else:
                out[sl] = svc_mod.members_on(st, cfg, pu)[:k]
        pos = 0
        for r in reqs:
            k = r.u.shape[0]
            r.fut.set_result(svc_mod.Snapshot(out[pos:pos + k], gen))
            pos += k
        return n

    # ------------------------------------------------------- dispatcher ---

    def _min_pending_floor(self) -> int:
        with self._cv:
            floors = [r.min_gen for reqs in self._pending.values()
                      for r in reqs]
        return min(floors) if floors else 0

    def start(self) -> "QueryBroker":
        """Spawn the background dispatcher thread (idempotent)."""
        with self._cv:
            self._stopping = False
            if self._thread is not None and self._thread.is_alive():
                return self
            self._thread = threading.Thread(
                target=self._run, name="scc-query-broker", daemon=True)
            self._thread.start()
        return self

    def stop(self):
        """Drain outstanding queries, then stop the dispatcher.  Requests
        whose consistency floor is still uncommitted are failed rather
        than left waiting for a generation that may never arrive."""
        with self._cv:
            self._stopping = True
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        # a dispatcher that died on a flush error may leave pending
        # futures behind -- fail them rather than hang their readers
        with self._cv:
            leftovers = [r.fut for reqs in self._pending.values()
                         for r in reqs]
            for k in self._pending:
                self._pending[k] = []
            self._waited.clear()
        for fut in leftovers:
            if not fut.done():
                fut.set_exception(
                    fault_errors.BrokerStopped("QueryBroker stopped"))

    def _run(self):
        while True:
            with self._cv:
                while not self._stopping and \
                        not any(self._pending.values()):
                    self._cv.wait(timeout=0.05)
                if self._stopping and not any(self._pending.values()):
                    return
            try:
                served = self.flush(fail_waiting=self._stopping)
            except BaseException:
                # flush already failed its own collected futures; keep the
                # dispatcher alive so later submitters are not orphaned
                # waiting on a thread that silently died
                continue
            if served == 0 and any(self._pending.values()):
                # everything pending is gen-deferred: block on the next
                # service commit instead of spinning on flush()
                self._svc.wait_for_gen(self._min_pending_floor(),
                                       timeout=0.05)

    def __enter__(self) -> "QueryBroker":
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    def stats(self) -> dict:
        return {"flushes": self.flushes, "served": self.served,
                "max_coalesced": self.max_coalesced,
                "gen_waits": self.gen_waits,
                "coalescing": round(self.served / self.flushes, 2)
                if self.flushes else 0.0}
