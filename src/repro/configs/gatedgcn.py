"""gatedgcn [arXiv:2003.00982 benchmark config]: n_layers=16 d_hidden=70,
gated edge aggregation."""
from repro.configs.gnn_shapes import gnn_shapes
from repro.models.gnn import gatedgcn as model

FAMILY = "gnn"
SHAPES = gnn_shapes()
MODULE = model


def config(**kw):
    return model.GatedGCNConfig(n_layers=16, d_hidden=70, **kw)


def smoke_config(**kw):
    base = dict(n_layers=3, d_hidden=16, d_feat=6, n_graphs=2)
    base.update(kw)
    return model.GatedGCNConfig(**base)
