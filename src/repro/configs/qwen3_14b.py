"""LEGACY (seed-era LM arch config): unused by the SMSCC serving reproduction;
kept for the seed's shape tests.  Do not extend.

qwen3-14b [hf:Qwen/Qwen3-14B]: 40L d_model=5120 40H (GQA kv=8)
d_ff=17408 vocab=151936, qk-norm, full attention."""
import jax.numpy as jnp

from repro.configs.lm_shapes import lm_shapes
from repro.models import transformer as tf

FAMILY = "lm"
SHAPES = lm_shapes(long_context_ok=False)


def config(dtype=jnp.bfloat16, **kw):
    return tf.LMConfig(
        name="qwen3-14b", n_layers=40, d_model=5120, n_heads=40,
        n_kv_heads=8, head_dim=128, d_ff=17408, vocab=151936,
        qk_norm=True, tie_embeddings=False, rope_theta=1e6, dtype=dtype,
        **kw)


def smoke_config():
    return tf.LMConfig(
        name="qwen3-14b-smoke", n_layers=2, d_model=64, n_heads=8,
        n_kv_heads=2, head_dim=8, d_ff=128, vocab=256, qk_norm=True,
        tie_embeddings=False, dtype=jnp.float32)
