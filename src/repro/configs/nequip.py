"""nequip [arXiv:2101.03164]: n_layers=5 d_hidden=32 l_max=2 n_rbf=8
cutoff=5, O(3)-equivariant tensor-product interatomic potential."""
from repro.configs.gnn_shapes import gnn_shapes
from repro.models.gnn import nequip as model

FAMILY = "gnn"
SHAPES = gnn_shapes()
MODULE = model


def config(**kw):
    return model.NequIPConfig(n_layers=5, d_hidden=32, l_max=2, n_rbf=8,
                              cutoff=5.0, **kw)


def smoke_config(**kw):
    base = dict(n_layers=2, d_hidden=8, l_max=2, n_rbf=4, d_feat=6,
                n_graphs=2)
    base.update(kw)
    return model.NequIPConfig(**base)
