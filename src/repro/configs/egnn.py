"""egnn [arXiv:2102.09844]: n_layers=4 d_hidden=64, E(n)-equivariant."""
from repro.configs.gnn_shapes import gnn_shapes
from repro.models.gnn import egnn as model

FAMILY = "gnn"
SHAPES = gnn_shapes()
MODULE = model


def config(**kw):
    return model.EGNNConfig(n_layers=4, d_hidden=64, **kw)


def smoke_config(**kw):
    base = dict(n_layers=2, d_hidden=16, d_feat=6, n_graphs=2)
    base.update(kw)
    return model.EGNNConfig(**base)
