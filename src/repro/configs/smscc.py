"""The paper's own system config: SMSCC dynamic-SCC engine at fleet scale.

Shapes mirror the paper's workload axes (Fig 4/5): one compiled step
applies a batch of mixed graph updates (the thread-count analogue is the
lane count B) and a wait-free query batch.
"""
from repro.core import graph_state as gs

FAMILY = "smscc"

# Scan-length registry for the fused update engine (geometric, mirrors the
# batch-bucket registry): runs of same-bucket chunks are stacked into
# lax.scan super-chunks of the largest registered length that fits, so the
# service pays one dispatch + one host sync per super-chunk.  1 is always
# implied (no NOP-step padding); compile shapes stay bounded by
# buckets x scan lengths per config.  SCCService default; drivers that
# build their own service should pass scan_lengths=SCAN_LENGTHS.
SCAN_LENGTHS = (1, 4, 16)

SHAPES = {
    "update_1m": dict(kind="update", n_vertices=2 ** 20,
                      edge_capacity=2 ** 23, batch=8192),
    "update_16m": dict(kind="update", n_vertices=2 ** 24,
                       edge_capacity=2 ** 26, batch=65536),
    "community_query": dict(kind="query", n_vertices=2 ** 20,
                            edge_capacity=2 ** 23, batch=262144),
}


def config(n_vertices=2 ** 20, edge_capacity=2 ** 23, **kw):
    base = dict(max_probes=64, max_outer=64, max_inner=256)
    # tiered repair: the compact-sparse tier is on by default (scaled to
    # the graph -- regions up to 1/8 of the vertex slots compact into
    # bounded sub-arrays, so fixpoint rounds cost O(region) not O(table)).
    # The dense MXU tier stays opt-in (dense_capacity=N): its Pallas
    # kernel pays off on real TPUs, not under CPU interpret mode.
    base.update(region_vertex_capacity=max(64, n_vertices // 8),
                region_edge_buckets=(256, 4096, 65536))
    # in-graph repair gate: on by default -- structure-preserving steps
    # (the common case in the paper's update-heavy mixes) skip phase 5
    # entirely at O(batch) cost, bit-identically (dynamic.TIER_SKIP).
    base.update(repair_gate=True)
    base.update(kw)
    return gs.GraphConfig(n_vertices=n_vertices,
                          edge_capacity=edge_capacity, **base)


def smoke_config(**kw):
    base = dict(n_vertices=64, edge_capacity=256, max_probes=256,
                max_outer=65, max_inner=66)
    base.update(kw)
    return gs.GraphConfig(**base)
