"""mace [arXiv:2206.07697]: n_layers=2 d_hidden=128 l_max=2
correlation_order=3 n_rbf=8, E(3)-equivariant (higher-order ACE
message passing, Cartesian-irrep realization -- DESIGN.md §2)."""
from repro.configs.gnn_shapes import gnn_shapes
from repro.models.gnn import mace as model

FAMILY = "gnn"
SHAPES = gnn_shapes()
MODULE = model


def config(**kw):
    return model.MACEConfig(n_layers=2, d_hidden=128, l_max=2,
                            correlation=3, n_rbf=8, **kw)


def smoke_config(**kw):
    base = dict(n_layers=2, d_hidden=8, l_max=2, correlation=3, n_rbf=4,
                d_feat=6, n_graphs=2)
    base.update(kw)
    return model.MACEConfig(**base)
