"""LEGACY (seed-era LM arch config): unused by the SMSCC serving reproduction;
kept for the seed's shape tests.  Do not extend.

gemma3-12b [hf:google/gemma-3-12b-pt]: 48L d_model=3840 16H (GQA kv=8)
head_dim=256 d_ff=15360 vocab=262144, 5:1 local:global attention
(local window 1024), 128k-class context -- the hybrid pattern makes
long_500k decode legal (only 8 global layers carry the full-length KV).
"""
import jax.numpy as jnp

from repro.configs.lm_shapes import lm_shapes
from repro.models import transformer as tf

FAMILY = "lm"
SHAPES = lm_shapes(long_context_ok=True)


def config(dtype=jnp.bfloat16, **kw):
    return tf.LMConfig(
        name="gemma3-12b", n_layers=48, d_model=3840, n_heads=16,
        n_kv_heads=8, head_dim=256, d_ff=15360, vocab=262144,
        window=1024, local_global=5, rope_theta=1e6, dtype=dtype, **kw)


def smoke_config():
    return tf.LMConfig(
        name="gemma3-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab=256, window=8,
        local_global=2, dtype=jnp.float32)
