"""LEGACY (seed-era LM arch config): unused by the SMSCC serving reproduction;
kept for the seed's shape tests.  Do not extend.

h2o-danube-3-4b [arXiv:2401.16818]: 24L d_model=3840 32H (GQA kv=8)
d_ff=10240 vocab=32000, llama+mistral mix with sliding-window attention
(window 4096, all layers) -- the bounded KV makes long_500k decode legal.
"""
import jax.numpy as jnp

from repro.configs.lm_shapes import lm_shapes
from repro.models import transformer as tf

FAMILY = "lm"
SHAPES = lm_shapes(long_context_ok=True)


def config(dtype=jnp.bfloat16, **kw):
    return tf.LMConfig(
        name="h2o-danube-3-4b", n_layers=24, d_model=3840, n_heads=32,
        n_kv_heads=8, head_dim=120, d_ff=10240, vocab=32000,
        window=4096, rope_theta=1e4, dtype=dtype, **kw)


def smoke_config():
    return tf.LMConfig(
        name="danube-smoke", n_layers=2, d_model=64, n_heads=8,
        n_kv_heads=2, head_dim=8, d_ff=128, vocab=256, window=16,
        dtype=jnp.float32)
