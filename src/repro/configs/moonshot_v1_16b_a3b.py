"""LEGACY (seed-era LM arch config): unused by the SMSCC serving reproduction;
kept for the seed's shape tests.  Do not extend.

moonshot-v1-16b-a3b [hf:moonshotai/Moonlight-16B-A3B]:
48L d_model=2048 16H (GQA kv=16) expert d_ff=1408 vocab=163840,
MoE 64 experts top-6 (+2 shared experts, Moonlight's DeepSeek-style
layout; we run all layers MoE for scan homogeneity -- noted DESIGN.md §6).
"""
import jax.numpy as jnp

from repro.configs.lm_shapes import lm_shapes
from repro.models import moe, transformer as tf

FAMILY = "lm"
SHAPES = lm_shapes(long_context_ok=False)


def config(dtype=jnp.bfloat16, **kw):
    m = moe.MoEConfig(n_experts=64, top_k=6, d_model=2048, d_ff=1408,
                      n_shared_experts=2, **kw.pop("moe_kw", {}))
    return tf.LMConfig(
        name="moonshot-v1-16b-a3b", n_layers=48, d_model=2048, n_heads=16,
        n_kv_heads=16, head_dim=128, d_ff=1408, vocab=163840, moe=m,
        rope_theta=5e4, dtype=dtype, **kw)


def smoke_config():
    m = moe.MoEConfig(n_experts=4, top_k=2, d_model=64, d_ff=32,
                      n_shared_experts=1)
    return tf.LMConfig(
        name="moonshot-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=32, vocab=256, moe=m,
        dtype=jnp.float32)
