"""Architecture registry: one module per assigned arch (exact public
configs) plus the paper's own SMSCC engine config.  ``get(name)`` returns
the module; every module exposes FAMILY, SHAPES, config(), smoke_config().
"""
from __future__ import annotations

import importlib

ARCHS = [
    "moonshot_v1_16b_a3b",
    "qwen3_moe_235b_a22b",
    "h2o_danube_3_4b",
    "qwen3_14b",
    "gemma3_12b",
    "mace",
    "egnn",
    "nequip",
    "gatedgcn",
    "mind",
    "smscc",
]

ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def get(name: str):
    mod = ALIASES.get(name, name).replace("-", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def all_archs(include_paper: bool = True):
    return ARCHS if include_paper else [a for a in ARCHS if a != "smscc"]
