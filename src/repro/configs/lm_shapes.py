"""LEGACY (seed-era LM arch config): unused by the SMSCC serving reproduction;
kept for the seed's shape tests.  Do not extend.

The four assigned LM input shapes (shared across the 5 LM archs)."""

TRAIN_4K = dict(kind="train", seq=4096, global_batch=256)
PREFILL_32K = dict(kind="prefill", seq=32768, global_batch=32)
DECODE_32K = dict(kind="decode", seq=32768, global_batch=128)
LONG_500K = dict(kind="decode", seq=524288, global_batch=1)


def lm_shapes(long_context_ok: bool, skip_reason: str = ""):
    shapes = {
        "train_4k": dict(TRAIN_4K),
        "prefill_32k": dict(PREFILL_32K),
        "decode_32k": dict(DECODE_32K),
        "long_500k": dict(LONG_500K),
    }
    if not long_context_ok:
        shapes["long_500k"]["skip"] = (
            skip_reason or "pure full-attention arch: 500k decode mandates "
            "sub-quadratic attention (DESIGN.md §4)")
    return shapes
