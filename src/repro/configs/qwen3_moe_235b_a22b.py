"""LEGACY (seed-era LM arch config): unused by the SMSCC serving reproduction;
kept for the seed's shape tests.  Do not extend.

qwen3-moe-235b-a22b [hf:Qwen/Qwen3-235B-A22B family]:
94L d_model=4096 64H (GQA kv=4) expert d_ff=1536 vocab=151936,
MoE 128 experts top-8, qk-norm (qwen3 family trait).
"""
import jax.numpy as jnp

from repro.configs.lm_shapes import lm_shapes
from repro.models import moe, transformer as tf

FAMILY = "lm"
SHAPES = lm_shapes(long_context_ok=False)


def config(dtype=jnp.bfloat16, **kw):
    m = moe.MoEConfig(n_experts=128, top_k=8, d_model=4096, d_ff=1536,
                      **kw.pop("moe_kw", {}))
    return tf.LMConfig(
        name="qwen3-moe-235b-a22b", n_layers=94, d_model=4096, n_heads=64,
        n_kv_heads=4, head_dim=128, d_ff=1536, vocab=151936, moe=m,
        qk_norm=True, tie_embeddings=False, rope_theta=1e6, dtype=dtype,
        **kw)


def smoke_config():
    m = moe.MoEConfig(n_experts=4, top_k=2, d_model=64, d_ff=32)
    return tf.LMConfig(
        name="qwen3-moe-smoke", n_layers=2, d_model=64, n_heads=8,
        n_kv_heads=2, head_dim=8, d_ff=32, vocab=256, moe=m, qk_norm=True,
        tie_embeddings=False, dtype=jnp.float32)
