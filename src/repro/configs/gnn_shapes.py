"""The four assigned GNN input shapes (shared across the 4 GNN archs).

minibatch_lg block shapes follow the sampler layout
(data/pipeline.sampled_block_batch): widest layer first, node table =
inputs ++ inner-frontiers ++ seeds.
"""

FULL_GRAPH_SM = dict(kind="train_full", n_nodes=2708, n_edges=10556,
                     d_feat=1433, n_classes=7)          # Cora
MINIBATCH_LG = dict(kind="train_sampled", n_nodes=232965,
                    n_edges=114615892, batch_nodes=1024,
                    fanouts=(15, 10), d_feat=602, n_classes=41)  # Reddit
OGB_PRODUCTS = dict(kind="train_full", n_nodes=2449029, n_edges=61859140,
                    d_feat=100, n_classes=47)
MOLECULE = dict(kind="train_mol", n_nodes=30, n_edges=64, batch=128,
                d_feat=16)


def gnn_shapes():
    return {
        "full_graph_sm": dict(FULL_GRAPH_SM),
        "minibatch_lg": dict(MINIBATCH_LG),
        "ogb_products": dict(OGB_PRODUCTS),
        "molecule": dict(MOLECULE),
    }


def sampled_block_dims(shape):
    """(n_local_nodes, n_local_edges) of a minibatch_lg block batch."""
    b = shape["batch_nodes"]
    f = list(shape["fanouts"])
    # frontier sizes: seeds=b, after f[0]: b*f[0], after f[1]: b*f[0]*f[1]
    fronts = [b]
    for x in f:
        fronts.append(fronts[-1] * x)
    n_nodes = sum(fronts)              # seeds + all frontiers
    n_edges = sum(fronts[1:])          # one edge per sampled neighbor
    return n_nodes, n_edges
