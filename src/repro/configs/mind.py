"""mind [arXiv:1904.08030]: embed_dim=64 n_interests=4 capsule_iters=3,
multi-interest dynamic routing over a 2^21-row item table."""
from repro.models.recsys import mind as model

FAMILY = "recsys"
MODULE = model

SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512, n_cand=2048),
    "serve_bulk": dict(kind="serve", batch=262144, n_cand=256),
    "retrieval_cand": dict(kind="serve", batch=1, n_cand=1_000_000),
}


def config(**kw):
    base = dict(n_items=2 ** 21, embed_dim=64, seq_len=50, n_interests=4,
                capsule_iters=3, n_neg=1024, profile_vocab=8192,
                profile_len=8)
    base.update(kw)
    return model.MINDConfig(**base)


def smoke_config(**kw):
    base = dict(n_items=256, embed_dim=16, seq_len=8, n_interests=4,
                capsule_iters=3, n_neg=16, profile_vocab=32, profile_len=4)
    base.update(kw)
    return model.MINDConfig(**base)
