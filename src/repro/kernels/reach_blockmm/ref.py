"""Pure-jnp oracle for the boolean-semiring mat-mul kernel."""
from __future__ import annotations

import jax.numpy as jnp


def bool_matmul(a, b):
    """a: bool[M,K], b: bool[K,N] -> bool[M,N] over (∨, ∧)."""
    prod = jnp.einsum("mk,kn->mn", a.astype(jnp.float32),
                      b.astype(jnp.float32))
    return prod > 0.0


def frontier_step(adj, frontier):
    """F' = (Aᵀ F) ∨ F : one synchronous round of multi-source forward
    reachability; adj[i, j] = edge i -> j, frontier[v, s] = source s reached v."""
    return bool_matmul(adj.T, frontier) | frontier


def closure(adj):
    """Reflexive-transitive closure by squaring."""
    n = adj.shape[0]
    r = adj | jnp.eye(n, dtype=bool)
    steps = max(1, (n - 1).bit_length())
    for _ in range(steps):
        r = bool_matmul(r, r)
    return r
