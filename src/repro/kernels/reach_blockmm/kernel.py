"""Boolean-semiring blocked mat-mul on the MXU (Pallas TPU kernel).

The paper's reachability DFS becomes, on the dense repair path, repeated
application of  F' = (A^T ⊙ F) ∨ F  -- a matrix product over the
({0,1}, ∨, ∧) semiring.  The MXU has no boolean mode, so the kernel runs
the product in float32 (1.0 = true) and *saturates* once per output tile:
``out = (acc > 0)``.  OR-accumulation == saturating add, which is exactly
why a semilattice update needs no locks (DESIGN.md §2): float addition of
non-negative indicators is associative and the threshold is idempotent.

Tiling: (bm × bk) @ (bk × bn) MXU tiles, grid (M/bm, N/bn, K/bk) with the
contraction axis innermost so each output tile stays resident in VMEM
across its K panel sweep.  All tile dims default to 128 -- one MXU pass
per tile pair, VMEM footprint 3·128²·4B ≈ 192 KiB « 16 MiB/core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, o_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                          preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _saturate():
        o_ref[...] = (o_ref[...] > 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def bool_matmul_f32(a: jax.Array, b: jax.Array, *, bm: int = 128,
                    bn: int = 128, bk: int = 128,
                    interpret: bool = True) -> jax.Array:
    """(a ⊙ b) over the boolean semiring; a, b are {0,1} float32 arrays.

    Shapes must be multiples of the tile dims (ops.py pads).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    n_k = k // bk
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b)
