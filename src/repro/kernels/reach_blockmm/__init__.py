from repro.kernels.reach_blockmm.ops import bool_matmul, closure, frontier_step  # noqa: F401
from repro.kernels.reach_blockmm import ref  # noqa: F401
