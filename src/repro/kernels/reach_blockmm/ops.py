"""jit'd public wrappers around the boolean mat-mul kernel.

``impl='auto'`` runs the Pallas kernel natively on TPU, in interpret mode on
CPU (correctness validation), and falls back to the jnp oracle when
explicitly requested ('xla') -- the fallback is what multi-pod dry-runs
lower, since Mosaic kernels only compile for real TPU targets.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.reach_blockmm import kernel, ref


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _resolve(impl: str) -> str:
    if impl != "auto":
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "pallas_interpret"


def bool_matmul(a, b, *, block: int = 128, impl: str = "auto"):
    """Boolean-semiring product of bool[M,K] @ bool[K,N] -> bool[M,N]."""
    impl = _resolve(impl)
    if impl == "xla":
        return ref.bool_matmul(a, b)
    m, n = a.shape[0], b.shape[1]
    af = _pad_to(_pad_to(a.astype(jnp.float32), block, 0), block, 1)
    bf = _pad_to(_pad_to(b.astype(jnp.float32), block, 0), block, 1)
    out = kernel.bool_matmul_f32(af, bf, bm=block, bn=block, bk=block,
                                 interpret=(impl == "pallas_interpret"))
    return out[:m, :n] > 0.0


def frontier_step(adj, frontier, *, block: int = 128, impl: str = "auto"):
    """One synchronous reachability round: F' = (Aᵀ F) ∨ F."""
    return bool_matmul(adj.T, frontier, block=block, impl=impl) | frontier


def closure(adj, *, block: int = 128, impl: str = "auto"):
    """Reflexive-transitive closure by repeated squaring (log2 N products)."""
    n = adj.shape[0]
    r = adj | jnp.eye(n, dtype=bool)
    steps = max(1, (n - 1).bit_length())
    for _ in range(steps):
        r = bool_matmul(r, r, block=block, impl=impl)
    return r
