# Pallas TPU kernels for the compute hot-spots, each with an ops.py jit'd
# wrapper and a ref.py pure-jnp oracle (validated via interpret=True on CPU):
#   reach_blockmm   boolean-semiring blocked mat-mul (paper's dense repair)
#   frontier_expand segment-min frontier expansion (sparse FW/BW sweeps)
#   hash_probe      fused open-addressing probe sweep (edge-table lookups)
#   flash_attention blocked online-softmax GQA attention (LM hot path)
#   embedding_bag   one-hot-matmul embedding bag (recsys hot path)
from repro.kernels import (  # noqa: F401
    embedding_bag, flash_attention, frontier_expand, hash_probe,
    reach_blockmm)
