"""Pallas hash-probe kernel: fused open-addressing lookup sweep."""
from repro.kernels.hash_probe.ops import (  # noqa: F401
    AUTO_MAX_CAP, probe, resolve_impl)
