"""Public hash-probe wrapper: resolve impl, pad, reconstruct (found, slot).

Same 'auto' asymmetry as frontier_expand: lookups back every table op on
the always-on update path, so CPU 'auto' is the XLA probe loop and the
Pallas paths are covered by the forced-'pallas_interpret' differential
suites.  On TPU, 'auto' additionally falls back to 'xla' above
AUTO_MAX_CAP -- the panel sweep reads the whole table per batch
(O(B + C) panels), which beats the serial O(max_probes) gather walk only
while the table fits a few VMEM-sized sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.hash_probe import kernel, ref

AUTO_MAX_CAP = 1 << 16


def resolve_impl(impl: str, cap: int | None = None) -> str:
    if impl != "auto":
        return impl
    if jax.default_backend() == "tpu" and (cap is None
                                           or cap <= AUTO_MAX_CAP):
        return "pallas"
    return "xla"


def probe(src, dst, state, base, u, v, *, max_probes: int,
          impl: str = "auto", bb: int = 8, bc: int = 512):
    """Batched open-addressing membership probe.

    src/dst: int32[C], state: int{8,32}[C] (0=EMPTY/1=LIVE/2=TOMB), base:
    int32[B] hashed start slots, u/v: int32[B] keys; C a power of two.
    Returns ``(found: bool[B], slot: int32[B])`` with
    :func:`repro.core.edge_table.lookup` semantics, bit-identical across
    impls.
    """
    cap = src.shape[0]
    impl = resolve_impl(impl, cap)
    if impl == "xla":
        return ref.probe(src, dst, state, base, u, v,
                         max_probes=max_probes)
    b = u.shape[0]
    bc = min(bc, cap)
    bp = b if b <= bb else -(-b // bb) * bb
    bb_eff = min(bb, max(bp, 1))

    def row(x, pad_to, fill):
        x = x.astype(jnp.int32).reshape(1, -1)
        return jnp.pad(x, ((0, 0), (0, pad_to - x.shape[1])),
                       constant_values=fill)

    hit_off, empty_off, free_off = kernel.probe_sweep(
        row(u, bp, -1), row(v, bp, -1), row(base, bp, 0),
        row(src, cap, 0), row(dst, cap, 0), row(state, cap, 0),
        max_probes=max_probes, bb=bb_eff, bc=bc,
        interpret=(impl == "pallas_interpret"))
    hit_off = hit_off[0, :b]
    empty_off = empty_off[0, :b]
    free_off = free_off[0, :b]
    # the sequential walk stops at min(hit, empty): it found the key iff
    # the first match precedes the first EMPTY; otherwise it reports the
    # first non-LIVE slot it saw (or -1 when the window held none)
    found = hit_off < empty_off
    mask = cap - 1
    pos_hit = (base + hit_off) & mask
    pos_free = jnp.where(free_off < max_probes, (base + free_off) & mask,
                         -1)
    return found, jnp.where(found, pos_hit, pos_free)
