"""jnp oracle for the hash-probe kernel.

A verbatim mirror of :func:`repro.core.edge_table.lookup`'s bounded probe
walk, factored out of the table (it takes the hashed ``base`` instead of
hashing) so the kernel suite can differential-test against it without an
edge_table import cycle.  edge_table's own ``'xla'`` path keeps its
original loop; equivalence of all three is asserted by
tests/test_sparse_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EMPTY, LIVE, TOMB = 0, 1, 2


def probe(src, dst, state, base, u, v, *, max_probes: int):
    """(found: bool[B], slot: int32[B]) -- slot is the LIVE hit slot when
    found, else the first EMPTY/TOMB slot seen (insertion point), else -1
    on probe exhaustion.  Probing stops at a hit or a truly EMPTY slot."""
    cap = src.shape[0]
    b = u.shape[0]

    def body(i, carry):
        done, found, slot, free = carry
        pos = (base + i) & (cap - 1)
        st = state[pos]
        hit = (st == LIVE) & (src[pos] == u) & (dst[pos] == v)
        is_empty = st == EMPTY
        is_free = st != LIVE
        free = jnp.where((~done) & is_free & (free < 0), pos, free)
        slot = jnp.where((~done) & hit, pos, slot)
        found = found | ((~done) & hit)
        done = done | hit | is_empty
        return done, found, slot, free

    done = jnp.zeros((b,), jnp.bool_)
    found = jnp.zeros((b,), jnp.bool_)
    slot = jnp.full((b,), -1, jnp.int32)
    free = jnp.full((b,), -1, jnp.int32)
    done, found, slot, free = jax.lax.fori_loop(
        0, max_probes, body, (done, found, slot, free))
    return found, jnp.where(found, slot, free)
