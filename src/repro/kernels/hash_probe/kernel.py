"""Open-addressing probe walk as a data-parallel panel sweep (Pallas TPU).

The sequential probe loop (``edge_table.lookup``) is O(max_probes) serial
rounds of gather -> compare -> select per batch; each round is a
random-index gather, the classic scatter/gather roofline.  The fused
formulation: sweep the table in ``bc``-wide panels and reduce, per query
lane, three *offset minima* over the lane's probe window
``off(slot) = (slot - hash(u, v)) & (C - 1)``:

  min_hit    first in-window LIVE slot matching the key,
  min_empty  first in-window EMPTY slot (where the sequential walk stops),
  min_free   first in-window non-LIVE slot (the insertion point).

Because a probe window is a *contiguous* run of offsets, the sequential
walk's outcome is a pure function of those minima (ops.py reconstructs
``(found, slot)`` bit-identically): the walk hits iff the first match
precedes the first EMPTY, and the insertion point is the first non-LIVE
offset.  TOMB chains and wrap-around fall out of the modular offset.

Grid ``(B/bb, C/bc)`` with the table axis innermost, so each lane tile's
three minima stay resident across the sweep (init to the SENTINEL
``max_probes`` at panel 0).  All arrays are (1, N) lane-major rows; the
compare broadcast is (1, bb, bc) -- bb=8, bc=512 stays ~40 KiB of VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EMPTY, LIVE, TOMB = 0, 1, 2


def _kernel(u_ref, v_ref, base_ref, src_ref, dst_ref, st_ref,
            hit_ref, empty_ref, free_ref, *, cap: int, max_probes: int,
            bc: int):
    j = pl.program_id(1)  # table panel

    @pl.when(j == 0)
    def _init():
        hit_ref[...] = jnp.full_like(hit_ref, max_probes)
        empty_ref[...] = jnp.full_like(empty_ref, max_probes)
        free_ref[...] = jnp.full_like(free_ref, max_probes)

    u3 = u_ref[...][:, :, None]                            # (1, bb, 1)
    v3 = v_ref[...][:, :, None]
    base3 = base_ref[...][:, :, None]
    slots = j * bc + jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, bc), 2)                          # (1, 1, bc)
    # first-visit offset of each slot in this lane's probe sequence; the
    # power-of-two mask makes negatives wrap exactly like the walk does
    off = (slots - base3) & (cap - 1)                      # (1, bb, bc)
    inw = off < max_probes
    s3 = src_ref[...][:, None, :]                          # (1, 1, bc)
    d3 = dst_ref[...][:, None, :]
    st3 = st_ref[...][:, None, :]
    sent = jnp.int32(max_probes)
    hit = inw & (st3 == LIVE) & (s3 == u3) & (d3 == v3)
    is_empty = inw & (st3 == EMPTY)
    is_free = inw & (st3 != LIVE)
    hit_ref[...] = jnp.minimum(
        hit_ref[...], jnp.min(jnp.where(hit, off, sent), axis=2))
    empty_ref[...] = jnp.minimum(
        empty_ref[...], jnp.min(jnp.where(is_empty, off, sent), axis=2))
    free_ref[...] = jnp.minimum(
        free_ref[...], jnp.min(jnp.where(is_free, off, sent), axis=2))


@functools.partial(jax.jit,
                   static_argnames=("max_probes", "bb", "bc", "interpret"))
def probe_sweep(u, v, base, src, dst, state, *, max_probes: int, bb: int,
                bc: int, interpret: bool = True):
    """u/v/base: int32[1, Bp]; src/dst/state: int32[1, C] table rows.

    Bp % bb == 0 and C % bc == 0 (ops.py pads/choses).  Returns three
    int32[1, Bp] offset minima (SENTINEL = max_probes).
    """
    bp = u.shape[1]
    cap = src.shape[1]
    assert bp % bb == 0 and cap % bc == 0, (bp, cap, bb, bc)
    spec_b = pl.BlockSpec((1, bb), lambda i, j: (0, i))
    spec_t = pl.BlockSpec((1, bc), lambda i, j: (0, j))
    out = jax.ShapeDtypeStruct((1, bp), jnp.int32)
    return pl.pallas_call(
        functools.partial(_kernel, cap=cap, max_probes=max_probes, bc=bc),
        grid=(bp // bb, cap // bc),
        in_specs=[spec_b, spec_b, spec_b, spec_t, spec_t, spec_t],
        out_specs=[spec_b, spec_b, spec_b],
        out_shape=[out, out, out],
        interpret=interpret,
    )(u, v, base, src, dst, state)
