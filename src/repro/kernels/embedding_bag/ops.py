"""Public EmbeddingBag wrapper: pads to tile multiples, handles modes."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag import kernel, ref


def _resolve(impl: str) -> str:
    if impl != "auto":
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "pallas_interpret"


def embedding_bag(table, ids, *, mode: str = "sum", weights=None,
                  bb: int = 8, bv: int = 128, impl: str = "auto"):
    """table: f32[V, D]; ids: int32[B, L], -1 = padding -> f32[B, D]."""
    impl = _resolve(impl)
    if impl == "xla":
        return ref.embedding_bag(table, ids, mode=mode, weights=weights)

    b, l = ids.shape
    v, d = table.shape
    if weights is None:
        weights = jnp.ones((b, l), jnp.float32)
    if mode == "mean":
        cnt = jnp.sum(ids >= 0, axis=1, keepdims=True).astype(jnp.float32)
    # pad batch to bb, vocab to bv
    bp = -(-b // bb) * bb
    vp = -(-v // bv) * bv
    ids_p = jnp.pad(ids, ((0, bp - b), (0, 0)), constant_values=-1)
    w_p = jnp.pad(weights.astype(jnp.float32), ((0, bp - b), (0, 0)))
    tab_p = jnp.pad(table.astype(jnp.float32), ((0, vp - v), (0, 0)))
    out = kernel.embedding_bag_counts(
        ids_p, w_p, tab_p, bb=bb, bv=bv,
        interpret=(impl == "pallas_interpret"))[:b]
    if mode == "sum":
        return out
    if mode == "mean":
        return out / jnp.maximum(cnt, 1.0)
    raise ValueError(f"mode {mode!r} not supported by the kernel path")
