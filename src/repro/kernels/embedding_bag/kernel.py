"""EmbeddingBag as a one-hot-counts MXU mat-mul (Pallas TPU kernel).

Random-row gathers from a sharded HBM table are the recsys hot path.  The
TPU-native formulation: sweep the vocabulary in (bv × D) panels; for each
panel build the bag×panel *count matrix* C[b, w] = Σ_l [ids[b, l] == w]
(optionally weighted) on the VPU and accumulate ``out += C @ panel`` on the
MXU.  Lookups become dense FLOPs -- the classic trade when gather bandwidth,
not compute, is the roofline term (and exactly how a one-hot dispatch MoE
router works, see models/moe.py).

Per grid step VMEM: ids (bb·L·4B) + panel (bv·D·4B) + eq broadcast
(bb·L·bv·1B as bf16/f32 intermediate) + out (bb·D·4B).  Defaults bb=8,
bv=128, L≤512, D≤256 keep it ≈ 2.5 MiB « 16 MiB.

The vocab axis is the inner grid dim, so each bag tile's accumulator stays
resident across the vocabulary sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(ids_ref, w_ref, tab_ref, o_ref, *, bv: int, n_v: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ids = ids_ref[...]                                     # (bb, L) int32
    wgt = w_ref[...]                                       # (bb, L) f32
    vocab = j * bv + jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, bv), 2)                          # (1, 1, bv)
    eq = (ids[:, :, None] == vocab).astype(jnp.float32)    # (bb, L, bv)
    counts = jnp.sum(eq * wgt[:, :, None], axis=1)         # (bb, bv)
    o_ref[...] += jnp.dot(counts, tab_ref[...],
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bb", "bv", "interpret"))
def embedding_bag_counts(ids, weights, table, *, bb: int = 8, bv: int = 128,
                         interpret: bool = True):
    """ids: int32[Bp, L] (-1 = pad), weights: f32[Bp, L], table: f32[Vp, D].

    Bp % bb == 0 and Vp % bv == 0 (ops.py pads).  Returns f32[Bp, D]
    weighted-sum bags.
    """
    bp, l = ids.shape
    vp, d = table.shape
    assert bp % bb == 0 and vp % bv == 0, (bp, vp, bb, bv)
    n_v = vp // bv
    return pl.pallas_call(
        functools.partial(_kernel, bv=bv, n_v=n_v),
        grid=(bp // bb, n_v),
        in_specs=[
            pl.BlockSpec((bb, l), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, l), lambda i, j: (i, 0)),
            pl.BlockSpec((bv, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, d), jnp.float32),
        interpret=interpret,
    )(ids, weights, table)
