"""Pure-jnp oracle: the take + segment/readout EmbeddingBag."""
from __future__ import annotations

import jax.numpy as jnp

from repro.graph import segment_ops


def embedding_bag(table, ids, *, mode: str = "sum", weights=None):
    """table: [V, D]; ids: int[B, L] with -1 padding -> [B, D]."""
    return segment_ops.embedding_bag(table, ids, mode=mode, weights=weights)
