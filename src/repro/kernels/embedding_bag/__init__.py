from repro.kernels.embedding_bag.ops import embedding_bag  # noqa: F401
from repro.kernels.embedding_bag import ref  # noqa: F401
