from repro.kernels.flash_attention.ops import mha  # noqa: F401
from repro.kernels.flash_attention import ref  # noqa: F401
