"""Public GQA attention wrapper around the flash kernel.

``impl``: 'pallas' (TPU native) | 'pallas_interpret' (CPU validation) |
'xla' (oracle; what dry-runs lower) | 'auto'.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel, ref


def _resolve(impl: str) -> str:
    if impl != "auto":
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "pallas_interpret"


def mha(q, k, v, *, causal: bool = True, window: int = 0,
        bq: int = 128, bk: int = 128, impl: str = "auto"):
    """Grouped-query attention.  q: [B,H,S,D]; k,v: [B,Hkv,S,D] -> [B,H,S,D].

    window > 0 enables causal sliding-window attention of that width.
    """
    impl = _resolve(impl)
    if impl == "xla":
        return ref.mha(q, k, v, causal=causal, window=window)

    b, h, s, d = q.shape
    hkv = k.shape[1]
    if hkv != h:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)

    bq_ = min(bq, _round_tile(s))
    bk_ = min(bk, _round_tile(s))
    sp = -(-s // max(bq_, bk_)) * max(bq_, bk_)
    pad = sp - s
    if pad:
        widths = ((0, 0), (0, 0), (0, pad), (0, 0))
        q = jnp.pad(q, widths)
        k = jnp.pad(k, widths)
        v = jnp.pad(v, widths)

    def one(qh, kh, vh):
        return kernel.flash_one_head(
            qh, kh, vh, causal=causal, window=window, s_real=s,
            bq=bq_, bk=bk_, interpret=(impl == "pallas_interpret"))

    out = jax.vmap(jax.vmap(one))(q, k, v)
    return out[:, :, :s, :]


def _round_tile(s: int) -> int:
    """Largest power-of-two tile <= s (min 8 sublanes)."""
    t = 8
    while t * 2 <= min(s, 128):
        t *= 2
    return t
