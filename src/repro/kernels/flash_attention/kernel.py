"""Blocked online-softmax attention (FlashAttention) as a Pallas TPU kernel.

One (batch, head) slice per vmap lane; inside, the grid is
(S/bq query tiles) × (S/bk kv tiles) with the kv axis innermost, so the
query tile's running max ``m``, normalizer ``l`` and accumulator ``acc``
stay in VMEM scratch across the kv sweep -- no S×S score matrix ever
materializes (that is the whole point: the memory term drops from O(S²)
to O(S·D)).

Masks (causal / sliding window / key-padding) are applied as -inf before
the online-softmax update; fully-masked rows are kept NaN-free with the
standard "safe max" trick.  VMEM per step: q,k,v,acc tiles + 2 (bq,128)
vectors ≈ (3·bq·D + bk·D + 2·bq·128)·4B; with bq=bk=128, D=128 that is
~320 KiB, comfortably inside the ~16 MiB VMEM budget, and both matmuls
are (128, D)·(D, 128)-shaped MXU work.

A production kernel would also shrink the kv grid per query tile
(skipping fully-masked blocks); here masked blocks are executed-and-
discarded for simplicity -- the dry-run path uses the XLA fallback anyway.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int, s_real: int,
            n_k: int, bq: int, bk: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...]
    k = k_ref[...]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    rows = jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + i * bq
    cols = jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1) + j * bk
    mask = cols < s_real  # key padding
    if causal:
        mask &= rows >= cols
    if window > 0:
        mask &= (rows - cols) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...][:, :1]                    # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)     # (bq, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    safe_m = jnp.where(m_new <= NEG_INF, 0.0, m_new)
    p = jnp.where(mask, jnp.exp(s - safe_m), 0.0)             # (bq, bk)
    alpha = jnp.where(m_prev <= NEG_INF, 0.0,
                      jnp.exp(m_prev - safe_m))               # (bq, 1)
    l_new = l_ref[...][:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v_ref[...], preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == n_k - 1)
    def _finalize():
        l = l_ref[...][:, :1]
        o_ref[...] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "s_real", "bq", "bk", "interpret"))
def flash_one_head(q, k, v, *, causal: bool, window: int, s_real: int,
                   bq: int = 128, bk: int = 128,
                   interpret: bool = True):
    """q: [Sp, D], k/v: [Sp, D] (padded to tile multiples) -> [Sp, D]."""
    sp, d = q.shape
    assert sp % bq == 0 and sp % bk == 0, (sp, bq, bk)
    n_q, n_k = sp // bq, sp // bk
    scale = 1.0 / (d ** 0.5)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          window=window, s_real=s_real, n_k=n_k,
                          bq=bq, bk=bk),
        grid=(n_q, n_k),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
