"""Pure-jnp oracle for blocked attention: materialized-scores softmax."""
from __future__ import annotations

import jax.numpy as jnp


def mha(q, k, v, *, causal: bool = True, window: int = 0):
    """q: [B,H,S,D]; k,v: [B,Hkv,S,D] (Hkv divides H). Returns [B,H,S,D]."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    if hkv != h:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = 1.0 / (d ** 0.5)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    qi = jnp.arange(s)[:, None]
    kj = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= qi >= kj
    if window > 0:
        mask &= (qi - kj) < window
    scores = jnp.where(mask, scores, -jnp.inf)
    p = _softmax(scores)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v).astype(q.dtype)


def _softmax(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    e = jnp.where(jnp.isfinite(x), jnp.exp(x - m), 0.0)
    z = jnp.sum(e, axis=-1, keepdims=True)
    return e / jnp.where(z == 0.0, 1.0, z)
