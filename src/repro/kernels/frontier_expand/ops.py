"""Public frontier-expansion wrapper: resolve impl, pad, dispatch.

``impl='auto'`` is deliberately asymmetric to ``dense_matmul_impl``: the
sparse sweep is the *always-on* hot loop (every fixpoint round of every
repair), not an opt-in tier, so 'auto' resolves to the XLA scatter on CPU
instead of interpret mode -- interpret-executing an O(E x NV) panel sweep
per round would regress the whole service by orders of magnitude.  The
Pallas paths stay covered on CPU by the differential suites
(tests/test_sparse_kernels.py, test_scan_engine.py), which force
'pallas_interpret' explicitly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.frontier_expand import kernel, ref

SENTINEL = jnp.uint32(kernel.SENTINEL)

# 'auto' stops densifying above this vertex count even on TPU: the panel
# kernel visits O(E * NV / (bv * be)) tiles per round while the XLA
# scatter stays O(E); past ~2^18 vertices the one-hot trade loses.  The
# compact repair tier (region_vertex_capacity, typically <= 2^12) and
# query frontiers sit far below it.
AUTO_MAX_NV = 1 << 18


def resolve_impl(impl: str, nv: int | None = None) -> str:
    if impl != "auto":
        return impl
    if jax.default_backend() == "tpu" and (nv is None or nv <= AUTO_MAX_NV):
        return "pallas"
    return "xla"


def frontier_min(dst, msg, nv: int, *, impl: str = "auto",
                 bf: int = 8, bv: int = 128, be: int = 256):
    """Segment-min of per-edge messages into their destination vertices.

    dst: int32[E]; msg: uint32[E] or uint32[F, E].  Returns uint32[NV] /
    uint32[F, NV]: out[v] = min(msg[e] : dst[e] == v), SENTINEL where no
    edge lands.  One frontier-expansion round in the min-semiring (bool
    reachability maps reached -> 0, blocked -> SENTINEL); bit-identical
    across impls.
    """
    impl = resolve_impl(impl, nv)
    squeeze = msg.ndim == 1
    m2 = msg[None, :] if squeeze else msg
    if impl == "xla":
        out = ref.frontier_min(dst, m2, nv)
        return out[0] if squeeze else out
    f, e = m2.shape
    fp = f if f <= bf else -(-f // bf) * bf
    bf_eff = min(bf, max(fp, 1))
    ep = max(be, -(-e // be) * be)
    nvp = -(-nv // bv) * bv
    # pad lanes can never land: dst -1 matches no panel vertex id, and the
    # padded messages are the min identity anyway
    dst_p = jnp.pad(dst.reshape(1, -1).astype(jnp.int32),
                    ((0, 0), (0, ep - e)), constant_values=-1)
    msg_p = jnp.pad(m2.astype(jnp.uint32), ((0, fp - f), (0, ep - e)),
                    constant_values=np.uint32(kernel.SENTINEL))
    out = kernel.segment_min_u32(
        dst_p, msg_p, nvp=nvp, bf=bf_eff, bv=bv, be=be,
        interpret=(impl == "pallas_interpret"))[:f, :nv]
    return out[0] if squeeze else out
