"""jnp oracle for the frontier-expansion segment-min.

This IS the sweep the repo shipped before the kernel existed -- one
edge-parallel scatter-min per round -- kept verbatim as the ``'xla'``
differential baseline (and the production CPU path, where a scatter beats
any panel sweep).
"""
from __future__ import annotations

import jax.numpy as jnp

SENTINEL = 0xFFFFFFFF  # uint32 identity of the min-semiring


def frontier_min(dst, msg, nv: int):
    """out[f, v] = min(msg[f, e] : dst[e] == v), SENTINEL where no edge
    lands.  dst: int32[E]; msg: uint32[F, E] -> uint32[F, NV]."""
    f = msg.shape[0]
    return jnp.full((f, nv), SENTINEL, jnp.uint32).at[:, dst].min(
        msg.astype(jnp.uint32))
