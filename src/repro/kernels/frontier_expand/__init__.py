"""Pallas frontier-expansion kernel: segment-min of edge messages."""
from repro.kernels.frontier_expand.ops import (  # noqa: F401
    AUTO_MAX_NV, SENTINEL, frontier_min, resolve_impl)
