"""Frontier expansion as a one-hot panel sweep (Pallas TPU kernel).

One round of sparse frontier propagation is a *segment-min*: every live
edge (src -> dst) carries a uint32 message (0/SENTINEL for boolean
reachability, a hashed priority or min-label otherwise) and each vertex
takes the minimum over its incoming messages.  XLA lowers that to a
serialized scatter-min; the TPU-native formulation is the same one-hot
trade as ``kernels/embedding_bag``: sweep the vertex space in ``bv``-wide
panels, build the panel x edge-block membership mask
``eq[v, e] = (dst[e] == v)`` on the VPU, and min-reduce the masked
messages into a resident output tile.  Gathers become dense compares --
the right trade exactly when scatter bandwidth, not compute, is the
roofline term (compact repair regions, batched query frontiers).

Grid is ``(F/bf, NV/bv, E/be)`` with the edge axis innermost, so each
(frontier, vertex-panel) output tile stays resident across the whole edge
sweep; it is initialized to SENTINEL at edge-block 0 (the min-semiring
identity), mirroring the ``@pl.when(j == 0)`` accumulator idiom of the
other kernels.  Per grid step VMEM: dst (be*4B) + msg (bf*be*4B) + the
(bf, bv, be) masked broadcast + out (bf*bv*4B) -- defaults bf<=8, bv=128,
be=256 keep it under ~1.2 MiB << 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SENTINEL = 0xFFFFFFFF  # uint32 identity of the min-semiring


def _kernel(dst_ref, msg_ref, o_ref, *, bv: int):
    i = pl.program_id(1)  # vertex panel
    k = pl.program_id(2)  # edge block

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, SENTINEL)

    d = dst_ref[...]                                       # (1, be) int32
    m = msg_ref[...]                                       # (bf, be) u32
    vids = i * bv + jax.lax.broadcasted_iota(
        jnp.int32, (bv, d.shape[1]), 0)                    # (bv, be)
    eq = d == vids                                         # (bv, be)
    contrib = jnp.where(eq[None, :, :], m[:, None, :],
                        jnp.uint32(SENTINEL))              # (bf, bv, be)
    o_ref[...] = jnp.minimum(o_ref[...], jnp.min(contrib, axis=2))


@functools.partial(jax.jit,
                   static_argnames=("nvp", "bf", "bv", "be", "interpret"))
def segment_min_u32(dst, msg, *, nvp: int, bf: int, bv: int, be: int,
                    interpret: bool = True):
    """dst: int32[1, Ep] (pad = -1), msg: uint32[Fp, Ep] -> uint32[Fp, NVp].

    Fp % bf == 0, Ep % be == 0, NVp % bv == 0 (ops.py pads).
    """
    fp, ep = msg.shape
    assert fp % bf == 0 and ep % be == 0 and nvp % bv == 0, \
        (fp, ep, nvp, bf, be, bv)
    return pl.pallas_call(
        functools.partial(_kernel, bv=bv),
        grid=(fp // bf, nvp // bv, ep // be),
        in_specs=[
            pl.BlockSpec((1, be), lambda f, i, k: (0, k)),
            pl.BlockSpec((bf, be), lambda f, i, k: (f, k)),
        ],
        out_specs=pl.BlockSpec((bf, bv), lambda f, i, k: (f, i)),
        out_shape=jax.ShapeDtypeStruct((fp, nvp), jnp.uint32),
        interpret=interpret,
    )(dst, msg)
