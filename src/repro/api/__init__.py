# The public client surface of the repo: a typed request/response API over
# the SCCService update pipeline and the QueryBroker reader path.  Callers
# build ops from repro.api (AddEdge, SameSCC, ...) and submit them through
# a GraphClient; the raw (kind, u, v) lane convention and string query
# kinds are internal to src/repro/core (enforced by scripts/ci.sh).
from repro.api.client import (  # noqa: F401
    AtLeast,
    Consistency,
    GraphClient,
    Result,
)
from repro.api.ops import (  # noqa: F401
    AddEdge,
    AddVertex,
    CommunityOf,
    CommunitySizes,
    Op,
    QueryOp,
    Reachable,
    RemoveEdge,
    RemoveVertex,
    SameSCC,
    SccMembers,
    UpdateOp,
    encode_updates,
    updates_from_arrays,
)
