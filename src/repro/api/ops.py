"""The typed op vocabulary of the public client API.

The paper models SMSCC as one *concurrent graph object*: a fixed pool of
threads issues AddEdge / RemoveEdge / AddVertex / RemoveVertex updates and
wait-free SameSCC / reachability / community queries against a single
coherent abstract object (arXiv:1804.01276; the interface-first framing is
arXiv:1710.08296).  This module is that object's request vocabulary: every
operation a client can issue is a small frozen dataclass, and the *only*
place the raw ``(kind, u, v)`` integer convention survives is the encoder
pair below, which packs homogeneous runs of typed ops into the compiled
core's :class:`repro.core.dynamic.OpBatch` shapes (and back).  The compiled
engine is untouched; drivers stop re-inventing parallel-array encodings.

Vocabulary:

=====================  =========  ==========================================
op                     category   result value
=====================  =========  ==========================================
``AddEdge(u, v)``      update     ``bool`` — accepted (edge was absent)
``RemoveEdge(u, v)``   update     ``bool`` — accepted (edge was present)
``AddVertex(u)``       update     ``bool`` — accepted (vertex was absent)
``RemoveVertex(u)``    update     ``bool`` — accepted (vertex was present)
``SameSCC(u, v)``      query      ``bool`` — same strongly connected comp.
``Reachable(u, v)``    query      ``bool`` — u ⇝ v over live edges
``SccMembers(u)``      query      ``bool[NV]`` — u's SCC membership mask
``CommunityOf(u)``     query      ``int`` — community (SCC) id; the
                                  sentinel ``n_vertices`` when u is absent
``CommunitySizes()``   query      ``int32[NV]`` — community-size histogram
                                  indexed by representative id
=====================  =========  ==========================================
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, List, Sequence, Tuple

import numpy as np

from repro.core import dynamic

__all__ = [
    "Op", "UpdateOp", "QueryOp",
    "AddEdge", "RemoveEdge", "AddVertex", "RemoveVertex",
    "SameSCC", "Reachable", "SccMembers", "CommunityOf", "CommunitySizes",
    "encode_updates", "updates_from_arrays",
]


@dataclasses.dataclass(frozen=True, slots=True)
class Op:
    """Base of every request the client API accepts."""


@dataclasses.dataclass(frozen=True, slots=True)
class UpdateOp(Op):
    """A graph mutation; routed to the SCCService update pipeline."""
    KIND: ClassVar[int]


@dataclasses.dataclass(frozen=True, slots=True)
class QueryOp(Op):
    """A read; routed to the QueryBroker against a committed snapshot."""
    BROKER_KIND: ClassVar[str]


# ------------------------------------------------------------- updates ---


@dataclasses.dataclass(frozen=True, slots=True)
class AddEdge(UpdateOp):
    u: int
    v: int
    KIND: ClassVar[int] = dynamic.ADD_EDGE


@dataclasses.dataclass(frozen=True, slots=True)
class RemoveEdge(UpdateOp):
    u: int
    v: int
    KIND: ClassVar[int] = dynamic.REM_EDGE


@dataclasses.dataclass(frozen=True, slots=True)
class AddVertex(UpdateOp):
    u: int
    KIND: ClassVar[int] = dynamic.ADD_VERTEX
    v: ClassVar[int] = 0  # lane placeholder: vertex ops carry no target


@dataclasses.dataclass(frozen=True, slots=True)
class RemoveVertex(UpdateOp):
    u: int
    KIND: ClassVar[int] = dynamic.REM_VERTEX
    v: ClassVar[int] = 0  # lane placeholder: vertex ops carry no target


# -------------------------------------------------------------- queries ---


@dataclasses.dataclass(frozen=True, slots=True)
class SameSCC(QueryOp):
    u: int
    v: int
    BROKER_KIND: ClassVar[str] = "same_scc"


@dataclasses.dataclass(frozen=True, slots=True)
class Reachable(QueryOp):
    u: int
    v: int
    BROKER_KIND: ClassVar[str] = "reachable"


@dataclasses.dataclass(frozen=True, slots=True)
class SccMembers(QueryOp):
    u: int
    BROKER_KIND: ClassVar[str] = "scc_members"


@dataclasses.dataclass(frozen=True, slots=True)
class CommunityOf(QueryOp):
    u: int
    BROKER_KIND: ClassVar[str] = "community_of"


@dataclasses.dataclass(frozen=True, slots=True)
class CommunitySizes(QueryOp):
    BROKER_KIND: ClassVar[str] = "community_sizes"


_KIND_TO_CLS = {
    dynamic.ADD_EDGE: AddEdge,
    dynamic.REM_EDGE: RemoveEdge,
    dynamic.ADD_VERTEX: AddVertex,
    dynamic.REM_VERTEX: RemoveVertex,
}


# ------------------------------------------------------------- encoders ---


def encode_updates(ops: Sequence[UpdateOp]
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pack a homogeneous run of update ops into ``(kind, u, v)`` arrays.

    The single sanctioned bridge from the typed vocabulary to the compiled
    core's lane convention (NOP padding stays an internal concern of the
    bucketed scheduler).  Vertex ops carry ``v = 0`` (ignored by the step).
    """
    n = len(ops)
    try:
        # fromiter keeps the per-op cost to one attribute read (queries
        # lack KIND and fail the encode, which is the type check)
        kind = np.fromiter((op.KIND for op in ops), np.int32, n)
        u = np.fromiter((op.u for op in ops), np.int32, n)
        v = np.fromiter((op.v for op in ops), np.int32, n)
    except AttributeError as e:
        raise TypeError(f"encode_updates got a non-update op: {e}") from e
    return kind, u, v


def updates_from_arrays(kind, u, v) -> List[UpdateOp]:
    """Decode a legacy ``(kind, u, v)`` stream into typed update ops.

    The migration bridge for array-native generators
    (:func:`repro.launch.workload.op_stream`): NOP lanes are dropped, every
    other lane becomes its dataclass.
    """
    kind = np.asarray(kind)
    u = np.asarray(u)
    v = np.asarray(v)
    out: List[UpdateOp] = []
    for k, uu, vv in zip(kind.tolist(), u.tolist(), v.tolist()):
        if k == dynamic.NOP:
            continue
        cls = _KIND_TO_CLS[k]
        if cls in (AddEdge, RemoveEdge):
            out.append(cls(uu, vv))
        else:
            out.append(cls(uu))
    return out
