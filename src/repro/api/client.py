"""`GraphClient`: the unified request/response surface of the repo.

The paper's SMSCC is a *linearizable concurrent graph object*: one
abstract object against which a pool of threads issues updates and
wait-free queries, every response justified by some sequential history
(arXiv:1804.01276, §2; the object-interface framing is arXiv:1710.08296).
Internally this repo implements that object as two cooperating halves — the
:class:`repro.core.service.SCCService` update pipeline and the
:class:`repro.core.broker.QueryBroker` reader path — but neither half is
the *object*: callers used to juggle raw ``(kind, u, v)`` arrays for one
and string query kinds for the other.  ``GraphClient`` is the missing
facade:

* **one vocabulary** — every request is a typed op from
  :mod:`repro.api.ops`; homogeneous runs are packed into the compiled
  core's batch shapes by the encoders, so the engine is untouched;
* **one response shape** — every answer is a :class:`Result` carrying the
  generation stamp of the committed snapshot that justified it (the
  API-level rendering of the paper's linearization points);
* **explicit consistency** — reads run under
  :data:`Consistency.LATEST` (any committed generation — the historical
  behaviour), :meth:`Consistency.AT_LEAST` (block until the committed
  generation covers an explicit floor), or
  :data:`Consistency.READ_YOUR_WRITES` (block until the committed
  generation covers the client's last acknowledged update — per-client
  token, maintained automatically).

A ``GraphClient`` instance is a *session*: use one per logical caller
(e.g. one per reader thread).  Many clients may share one service and one
broker — updates serialize on the service's update lock, queries coalesce
in the broker.  Per-client submission order is preserved across the
update/query boundary: updates are acknowledged only after their chunk
commits, and a later read's floor (its consistency level) can never admit
a snapshot older than the session has already observed under
READ_YOUR_WRITES.
"""
from __future__ import annotations

import dataclasses
import itertools
import random
import time
from concurrent.futures import Future
from typing import Any, Iterable, Iterator, List, NamedTuple, Sequence, \
    Tuple

import numpy as np

from repro.api.ops import (CommunityOf, CommunitySizes, Op, QueryOp,
                           SccMembers, UpdateOp, encode_updates)
from repro.fault import errors as fault_errors

__all__ = ["GraphClient", "Result", "Consistency", "AtLeast"]

# process-unique client session ids: the idempotency namespace for
# retried update chunks (the service dedups on (session, seq))
_SESSION_IDS = itertools.count()


# -------------------------------------------------------- consistency ----


@dataclasses.dataclass(frozen=True, slots=True)
class _Level:
    name: str

    def __repr__(self):
        return f"Consistency.{self.name}"


@dataclasses.dataclass(frozen=True, slots=True)
class AtLeast:
    """Read floor: answer only at a committed generation ``>= gen``."""
    gen: int

    def __repr__(self):
        return f"Consistency.AT_LEAST({self.gen})"


class Consistency:
    """The read-consistency levels of the client API.

    ===================  ====================================================
    level                guarantee for the answering snapshot's generation
    ===================  ====================================================
    ``LATEST``           any committed generation (never blocks)
    ``AT_LEAST(g)``      ``gen >= g`` — blocks until such a commit exists
    ``READ_YOUR_WRITES`` ``gen >= `` the client's last acked update
                         generation (its session token) — blocks until the
                         client's own writes are visible
    ===================  ====================================================

    All levels read *committed* snapshots only; stronger levels narrow
    which committed generations may answer, they never expose in-flight
    state.
    """
    LATEST = _Level("LATEST")
    READ_YOUR_WRITES = _Level("READ_YOUR_WRITES")
    AT_LEAST = AtLeast


# ------------------------------------------------------------- result ----


class Result(NamedTuple):
    """One op's response: the value plus its generation stamp.

    ``gen`` is the generation of the committed snapshot the value was
    computed against (queries) or that the op's chunk committed (updates).
    Update values are the acceptance booleans of the paper's method
    contracts; query values are per-op scalars/arrays (see
    :mod:`repro.api.ops` for the table).  (A NamedTuple, not a dataclass:
    results are minted per op on the hot path, and tuple construction is
    what keeps the facade inside its benchmarked overhead bound.)
    """
    op: Op
    value: Any
    gen: int


# ------------------------------------------------------------- client ----


def _runs(ops: Iterable[Op]) -> Iterator[Tuple[str, List[Op]]]:
    """Maximal homogeneous runs: consecutive updates batch into one service
    chunk; consecutive same-kind queries coalesce into one broker request.
    Run boundaries are exactly the client's ordering obligations."""
    run: List[Op] = []
    cat = None
    for op in ops:
        if isinstance(op, UpdateOp):
            c = "update"
        elif isinstance(op, QueryOp):
            c = op.BROKER_KIND
        else:
            raise TypeError(f"not an api op: {op!r}")
        if c != cat and run:
            yield cat, run
            run = []
        cat = c
        run.append(op)
    if run:
        yield cat, run


class GraphClient:
    """Typed client session over one SCCService (+ QueryBroker).

    ``broker=None`` makes the client own a private broker in inline mode
    (flushes happen on the submitting thread — single-threaded callers and
    tests need no dispatcher).  Pass a shared, started broker to coalesce
    queries across many client sessions.  A client instance is not itself
    thread-safe (it carries the per-session read-your-writes token); give
    each thread its own client over the shared service/broker.
    """

    def __init__(self, service, broker=None,
                 consistency=Consistency.LATEST, *,
                 deadline_s: float | None = None, max_retries: int = 8,
                 backoff_base_s: float = 0.005,
                 backoff_cap_s: float = 0.25, rng=None,
                 leader_resolver=None):
        from repro.core.broker import QueryBroker
        self._svc = service
        self._broker = QueryBroker(service) if broker is None else broker
        self._owns_broker = broker is None
        self._consistency = consistency
        # read-your-writes token: floor generation for RYW reads.  Seeded
        # with the creation-time committed gen (already committed, so it
        # never blocks) and advanced to each acked update's commit gen.
        self._token = int(service.gen)
        # failure-domain knobs (docs/SERVICE_API.md §Failure semantics):
        # retryable FaultErrors (Unavailable/QueueFull) are resubmitted
        # with bounded, decorrelated-jittered exponential backoff --
        # each wait draws uniformly from [base, 3*previous_wait],
        # floored by the server's retry_after hint and capped at
        # backoff_cap_s -- inside the per-op deadline (deadline_s=None:
        # no time bound, max_retries still applies).  The jitter
        # de-synchronizes sessions that all saw the same fault (a
        # deterministic schedule retries in lockstep: a thundering herd
        # on a freshly promoted writer); `rng` injects the source so
        # tests stay deterministic.  Updates are idempotent under
        # retry: every chunk carries (session_id, seq) and the service
        # dedups re-submits, so a chunk whose ack was lost is never
        # double-applied through the WAL.
        self._deadline_s = deadline_s
        self._max_retries = int(max_retries)
        self._backoff_base_s = float(backoff_base_s)
        self._backoff_cap_s = float(backoff_cap_s)
        self._rng = random.Random() if rng is None else rng
        # writer-failover reroute: on NotLeader the client swaps its
        # update target for whatever the resolver currently names (e.g.
        # ``lambda: rset.leader or old_writer``) before the next retry
        self._leader_resolver = leader_resolver
        self.session_id = f"gc{next(_SESSION_IDS)}"
        self._seq = 0
        self.retries = 0
        self.reroutes = 0
        self.deadline_failures = 0
        self.updates_submitted = 0
        self.queries_submitted = 0

    # ------------------------------------------------------- properties --

    @property
    def service(self):
        return self._svc

    @property
    def broker(self):
        return self._broker

    @property
    def gen(self) -> int:
        """Latest committed generation of the underlying service."""
        return int(self._svc.gen)

    @property
    def token(self) -> int:
        """The session's read-your-writes floor (last acked update gen)."""
        return self._token

    # -------------------------------------------------------- submission --

    def submit(self, op: Op, consistency=None,
               deadline_s: float | None = None) -> "Future[Result]":
        """Issue one op; resolves to its :class:`Result`.

        Updates are acknowledged synchronously (the returned future is
        already done — the chunk committed, retried under the client's
        retry policy if the store was transiently unavailable).  Queries
        resolve when the broker flushes: immediately on this thread in
        inline mode (with retries + the per-op deadline), or
        asynchronously when a dispatcher is running (the deadline/retry
        policy does not chase an async future; a failure arrives as the
        future's typed exception).
        """
        fut: Future = Future()
        if isinstance(op, UpdateOp):
            fut.set_result(self._apply_updates([op], deadline_s)[0])
            return fut
        if not isinstance(op, QueryOp):
            raise TypeError(f"not an api op: {op!r}")
        min_gen = self._min_gen(consistency)
        self.queries_submitted += 1
        if self._broker.dispatching:
            bfut = self._submit_query_run(op.BROKER_KIND, [op], min_gen)

            def _chain(f):
                try:
                    fut.set_result(self._result_of(op, f.result(), 0))
                except BaseException as e:  # surfaced via fut.result()
                    fut.set_exception(e)
            bfut.add_done_callback(_chain)
            return fut

        def attempt(remaining):
            bfut = self._submit_query_run(op.BROKER_KIND, [op], min_gen)
            return self._broker.resolve(bfut, min_gen=min_gen,
                                        timeout=remaining)
        snap = self._with_retry(
            attempt, self._deadline_s if deadline_s is None
            else deadline_s)
        fut.set_result(self._result_of(op, snap, 0))
        return fut

    def submit_many(self, ops: Sequence[Op], consistency=None,
                    deadline_s: float | None = None) -> List[Result]:
        """Issue a mixed op sequence; returns one :class:`Result` per op,
        in submission order.

        Consecutive updates are packed into one service chunk (one commit,
        one shared stamp); consecutive same-kind queries coalesce into one
        broker request.  Runs execute strictly in order, so generation
        stamps returned to this client are monotone non-decreasing across
        the whole sequence — and under READ_YOUR_WRITES every query stamp
        is ``>=`` the session token at its submission.
        """
        results: List[Result] = []
        eff_deadline = self._deadline_s if deadline_s is None \
            else deadline_s
        for cat, run in _runs(ops):
            if cat == "update":
                results.extend(self._apply_updates(run, eff_deadline))
                continue
            min_gen = self._min_gen(consistency)
            self.queries_submitted += len(run)

            def attempt(remaining, cat=cat, run=run, min_gen=min_gen):
                bfut = self._submit_query_run(cat, run, min_gen)
                return self._broker.resolve(bfut, min_gen=min_gen,
                                            timeout=remaining)
            snap = self._with_retry(attempt, eff_deadline)
            # run-level value decode (one C-level conversion per run, not
            # one isinstance chain + numpy index per op)
            gen = int(snap.gen)
            if cat == "community_sizes":
                hist = np.asarray(snap.value)
                results.extend(Result(op, hist, gen) for op in run)
            elif cat == "scc_members":
                masks = np.asarray(snap.value)
                results.extend(Result(op, masks[i], gen)
                               for i, op in enumerate(run))
            else:  # bool / int lanes
                vals = snap.value.tolist()
                results.extend(Result(op, val, gen)
                               for op, val in zip(run, vals))
        return results

    # ---------------------------------------------------------- internals --

    def _min_gen(self, consistency) -> int:
        c = self._consistency if consistency is None else consistency
        if c is Consistency.LATEST:
            return 0
        if c is Consistency.READ_YOUR_WRITES:
            return self._token
        if isinstance(c, AtLeast):
            return int(c.gen)
        raise TypeError(f"unknown consistency level: {c!r}")

    def _reroute(self, e: fault_errors.FaultError):
        """Swap the update target after a ``NotLeader``: whatever the
        resolver names right now becomes ``self._svc`` (the update
        attempt closures read it at call time, so the very next retry
        lands on the new leader)."""
        if self._leader_resolver is None:
            return
        try:
            new = self._leader_resolver()
        except Exception:
            return  # resolver hiccup: retry against the old target
        if new is not None and new is not self._svc:
            self._svc = new
            self.reroutes += 1

    def _with_retry(self, attempt, deadline_s: float | None):
        """Run ``attempt(remaining_s)`` under the retry policy: retryable
        :class:`~repro.fault.errors.FaultError`\\ s are re-attempted with
        decorrelated-jitter exponential backoff -- each wait draws
        uniformly from ``[base, 3*prev_wait]``, floored by the server's
        ``retry_after`` hint and capped at ``backoff_cap_s`` -- until
        ``max_retries`` attempts or the deadline is spent, whichever
        first.  A :class:`~repro.fault.errors.NotLeader` additionally
        reroutes the session to ``leader_resolver()`` before the next
        attempt.  Deadline exhaustion raises
        :class:`~repro.fault.errors.DeadlineExceeded` (chaining the last
        transient error); retry exhaustion re-raises the last typed
        error itself."""
        deadline = None if deadline_s is None \
            else time.monotonic() + deadline_s
        delay = self._backoff_base_s
        last: BaseException | None = None
        for n in range(self._max_retries + 1):
            remaining = None if deadline is None \
                else deadline - time.monotonic()
            if remaining is not None and remaining <= 0:
                self.deadline_failures += 1
                raise fault_errors.DeadlineExceeded(
                    f"op deadline {deadline_s}s spent after {n} "
                    f"attempts (last: {last})") from last
            try:
                return attempt(remaining)
            except fault_errors.FaultError as e:
                if not e.retryable or n == self._max_retries:
                    raise
                last = e
                if isinstance(e, fault_errors.NotLeader):
                    self._reroute(e)
                # decorrelated jitter (AWS-style): spread concurrent
                # sessions' retries apart instead of marching them in
                # lockstep into the server that just came back
                delay = min(self._rng.uniform(self._backoff_base_s,
                                              max(self._backoff_base_s,
                                                  delay * 3)),
                            self._backoff_cap_s)
                wait = min(max(delay, e.retry_after or 0.0),
                           self._backoff_cap_s)
                if deadline is not None and \
                        time.monotonic() + wait >= deadline:
                    self.deadline_failures += 1
                    raise fault_errors.DeadlineExceeded(
                        f"op deadline {deadline_s}s cannot cover the "
                        f"next backoff ({wait:.3f}s; last: {e})") from e
                self.retries += 1
                time.sleep(wait)
        raise AssertionError("unreachable")  # loop always raises/returns

    def _apply_updates(self, run: List[Op],
                       deadline_s: float | None = None) -> List[Result]:
        kind, u, v = encode_updates(run)
        # one idempotency key per chunk: a retry re-submits the SAME
        # (session, seq), so a first attempt that committed but lost its
        # ack (fault after the WAL append) is deduped, never re-applied
        self._seq += 1
        seq = self._seq

        def attempt(_remaining):
            return self._svc._apply_ops(kind, u, v,
                                        session=self.session_id, seq=seq)
        ok, gen = self._with_retry(
            attempt, self._deadline_s if deadline_s is None
            else deadline_s)
        self._token = max(self._token, gen)
        self.updates_submitted += len(run)
        return [Result(op, val, gen)
                for op, val in zip(run, np.asarray(ok).tolist())]

    def _submit_query_run(self, kind: str, run: List[Op], min_gen: int):
        if kind == "community_sizes":
            # one histogram per flush answers the whole run
            return self._broker.submit(kind, [0], min_gen=min_gen)
        u = [op.u for op in run]
        if kind in ("scc_members", "community_of"):
            return self._broker.submit(kind, u, min_gen=min_gen)
        return self._broker.submit(kind, u, [op.v for op in run],
                                   min_gen=min_gen)

    @staticmethod
    def _result_of(op: Op, snap, i: int) -> Result:
        if isinstance(op, CommunitySizes):
            value: Any = np.asarray(snap.value)
        elif isinstance(op, SccMembers):
            value = np.asarray(snap.value[i])
        elif isinstance(op, CommunityOf):
            value = int(snap.value[i])
        else:
            value = bool(snap.value[i])
        return Result(op, value, int(snap.gen))

    # ---------------------------------------------------------- telemetry --

    def stats(self) -> dict:
        """One unified telemetry dict: service (pipelined/fallback chunks,
        grows, compile bound; the fused-update-engine counters
        ``scanned_chunks`` / ``scan_dispatches`` -- chunks and dispatches
        that ran through the ``lax.scan`` super-chunk path -- and
        ``repair_skipped_steps`` -- steps the in-graph repair gate proved
        structure-preserving, next to the per-tier
        ``repair_{dense,compact,full}_steps``), broker (coalesced
        flushes, gen waits), and session counters."""
        s = dict(self._svc.stats())
        s.update(self._broker.stats())
        s.update(client_updates=self.updates_submitted,
                 client_queries=self.queries_submitted,
                 client_retries=self.retries,
                 client_reroutes=self.reroutes,
                 client_deadline_failures=self.deadline_failures,
                 ryw_token=self._token)
        return s

    # ---------------------------------------------------------- lifecycle --

    def close(self):
        """Stop the private broker (no-op for a shared one)."""
        if self._owns_broker:
            self._broker.stop()

    def __enter__(self) -> "GraphClient":
        return self

    def __exit__(self, *exc):
        self.close()
