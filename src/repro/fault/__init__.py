"""Fault-injection layer + structured error taxonomy.

:mod:`repro.fault.errors` -- the typed errors every serving layer raises
(retryability + ``retry_after`` drive ``GraphClient``'s retry loop).
:mod:`repro.fault.inject` -- seeded :class:`FaultPlan` schedules and the
filesystem / replica-kill / stall injection shims the chaos driver
(:mod:`repro.launch.chaos`) arms against a live service.
"""
from repro.fault.errors import (BrokerStopped, CapacityExhausted,
                                DeadlineExceeded, FaultError, Unavailable,
                                WalCorrupt, WalGap, WalTrimmed)
from repro.fault.inject import (FaultPlan, FsFault, ReplicaKill, Stall,
                                active_plan, clear, injected, install)

__all__ = ["FaultError", "Unavailable", "DeadlineExceeded",
           "BrokerStopped", "CapacityExhausted", "WalGap", "WalTrimmed",
           "WalCorrupt", "FaultPlan", "FsFault", "ReplicaKill", "Stall",
           "install", "clear", "injected", "active_plan"]
