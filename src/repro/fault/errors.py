"""Structured error taxonomy for the serving stack.

Failure-domain hardening needs errors a caller can *dispatch on*: which
failures are safe to retry (and how long to wait), which mean the answer
will never arrive, and which mean the store itself is unhealthy.  Every
serving layer (service, broker, replicas, queue, durable store) raises
these instead of ad-hoc ``RuntimeError``\\ s; ``GraphClient``'s retry loop
keys off :attr:`FaultError.retryable` / :attr:`FaultError.retry_after`.

Taxonomy (see docs/SERVICE_API.md §Failure semantics for the contract
table)::

    FaultError(RuntimeError)          base; retryable=False
    ├── Unavailable                   transient; retryable=True, carries
    │   │                             retry_after (seconds hint)
    │   └── QueueFull                 admission queue rejected the chunk
    │       (repro.tenancy.queue)
    ├── NotLeader                     this node lost write leadership;
    │                                 retryable=True, carries a leader
    │                                 hint -- clients reroute + resubmit
    ├── DeadlineExceeded              the caller's time budget ran out
    ├── BrokerStopped                 query path shut down under the op
    ├── CapacityExhausted             config limit hit (max_edge_capacity,
    │                                 non-converging growth) -- durable
    ├── Fenced                        a higher writer epoch owns the WAL;
    │                                 the stale writer wrote NOTHING
    ├── LeaseLost                     lease renewal found the lease taken
    │                                 over (internal leadership signal)
    ├── WalGap                        log/store continuity violated
    ├── WalTrimmed                    tailer cursor trimmed underneath
    │                                 (internal resync signal)
    └── WalCorrupt                    torn record behind a newer segment

``FaultError`` subclasses :class:`RuntimeError` so pre-existing callers
catching ``RuntimeError`` keep working; "no bare RuntimeError" in tests
and the chaos driver means the *exact* type, never a taxonomy member.
"""
from __future__ import annotations

__all__ = ["FaultError", "Unavailable", "NotLeader", "DeadlineExceeded",
           "BrokerStopped", "CapacityExhausted", "Fenced", "LeaseLost",
           "WalGap", "WalTrimmed", "WalCorrupt"]


class FaultError(RuntimeError):
    """Base of the serving stack's typed errors.

    ``retryable`` -- True when the same request may be re-submitted
    verbatim and can succeed once the transient condition clears.
    ``retry_after`` -- optional server-side hint (seconds) for when a
    retry has a chance; ``GraphClient`` takes the max of this and its
    own exponential backoff.
    """

    retryable: bool = False

    def __init__(self, *args, retry_after: float | None = None):
        super().__init__(*args)
        self.retry_after = retry_after


class Unavailable(FaultError):
    """Transient refusal: the op was NOT applied and may be retried.

    Raised by the durable store while DEGRADED (WAL disk fault -- reads
    keep serving, writes bounce), by a ReplicaSet with no healthy
    replica, and by admission control (:class:`~repro.tenancy.queue.
    QueueFull`)."""

    retryable = True


class NotLeader(FaultError):
    """This node is not (or no longer) the durable writer.

    Raised by a :class:`~repro.ckpt.durable.DurableService` that lost or
    abandoned its lease, got fenced by a higher-epoch writer, or was
    crash-injected out of leadership.  Retryable: the op was NOT applied
    here, and a client that reroutes to the current leader (``leader``
    hint when known, else its ``leader_resolver``) may resubmit the SAME
    ``(session, seq)`` chunk -- the idempotent dedup window makes the
    handoff exactly-once for acked ops."""

    retryable = True

    def __init__(self, *args, leader: str | None = None,
                 retry_after: float | None = None):
        super().__init__(*args, retry_after=retry_after)
        self.leader = leader


class DeadlineExceeded(FaultError):
    """The caller's per-op time budget elapsed (possibly across retries).

    Not retryable by the client loop -- the budget is already spent; the
    *caller* may issue a fresh op with a fresh deadline."""


class BrokerStopped(FaultError):
    """The query path shut down while the request was in flight.

    A parked request (gen-wait) fails with this instead of hanging on a
    generation that will never commit.  ``ReplicaSet`` treats it as a
    failover signal (the request is read-only: resubmitting to a healthy
    peer is always safe)."""


class CapacityExhausted(FaultError):
    """A configured hard limit was hit (``max_edge_capacity``, growth or
    migration that cannot converge).  Deterministic for the same state +
    chunk, hence never retryable."""


class Fenced(FaultError):
    """A higher writer epoch owns this WAL directory.

    Raised by :class:`~repro.ckpt.oplog.OpLogWriter` *before any byte is
    written* when a fence marker or segment with a newer epoch exists:
    the raising writer is stale (a resurrected pre-failover leader) and
    must never append again.  Not retryable on this node -- the durable
    store translates it into :class:`NotLeader` for clients."""


class LeaseLost(FaultError):
    """Lease renewal discovered the lease was taken over (or the lease
    file vanished).  Internal leadership signal: the holder must stop
    acting as the writer; its WAL epoch is already fenced by the
    takeover, so even a race here cannot split the log."""


class WalGap(FaultError):
    """Log continuity violated: a record's ``gen_before`` does not meet
    the store's generation during replay, or a rollback was requested
    with nothing to roll back.  Recovery-stopping corruption."""


class WalTrimmed(FaultError):
    """A tailer's cursor segment vanished (``trim`` raced the tailer).

    Internal signal, not a failure: the owner resyncs from the newest
    snapshot (every trimmed record is covered by one) and keeps going."""


class WalCorrupt(FaultError):
    """A torn/invalid record sits *behind* a newer segment -- the writer
    moved on, so the bytes will never complete.  Tailers resync; the
    writer-side recovery path repairs to the valid prefix."""
