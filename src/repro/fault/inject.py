"""Deterministic, seed-driven fault injection for the serving stack.

The chaos driver (:mod:`repro.launch.chaos`) and the fault tests arm a
:class:`FaultPlan` -- a pre-computed schedule of fault events derived
from a seed -- and the serving layers consult it at their natural fault
points *while serving* (not just at boot):

* filesystem faults: :func:`fs_open` / :func:`fs_fsync` are the I/O
  entry points of :mod:`repro.ckpt.oplog` and
  :mod:`repro.ckpt.checkpoint`.  An armed :class:`FsFault` makes the
  Nth matching write/fsync/open raise ``EIO``/``ENOSPC``, or *tear*
  the write (a prefix of the bytes lands, then the error) -- the
  mid-record torn-tail case the WAL's CRC framing exists for;
* replica kills: :func:`fire_kills` stops replica tails abruptly once
  the writer passes a scheduled generation (the in-process analogue of
  SIGKILLing a replica process; the multi-process analogue lives in
  ``repro.launch.replica --supervised``);
* stalls: :func:`maybe_stall` injects latency at queue/broker drain
  points to widen race windows.

Determinism: a plan is a pure function of its seed
(:meth:`FaultPlan.generate`), and per-call-site counters make the Nth
matching call fault regardless of wall-clock timing, so a chaos run's
fault *schedule* is reproducible even though thread interleavings are
not.  With no plan armed the hooks are a single global read -- safe to
leave in the production path.
"""
from __future__ import annotations

import contextlib
import dataclasses
import errno as _errno
import os
import threading
import time
from typing import Dict, List, Tuple

import numpy as np

__all__ = ["FsFault", "ReplicaKill", "Stall", "FaultPlan", "install",
           "clear", "injected", "active_plan", "fs_open", "fs_fsync",
           "maybe_stall", "fire_kills"]


# ------------------------------------------------------------- events ----


@dataclasses.dataclass(frozen=True)
class FsFault:
    """Fault the ``[first, first+count)``-th filesystem calls whose path
    contains ``match`` (counted per ``(op, match)`` key).

    ``op`` is one of ``write`` / ``fsync`` / ``open``; ``error`` is
    ``eio`` / ``enospc`` / ``torn`` (torn: a ``tear_frac`` prefix of the
    bytes is written before the EIO -- only meaningful for ``write``).
    """
    op: str
    match: str
    first: int
    count: int = 1
    error: str = "eio"
    tear_frac: float = 0.5


@dataclasses.dataclass(frozen=True)
class ReplicaKill:
    """Abruptly stop replica ``replica_id``'s tail once the writer's
    committed generation reaches ``at_gen``."""
    replica_id: int
    at_gen: int


@dataclasses.dataclass(frozen=True)
class Stall:
    """Sleep ``seconds`` inside the ``[first, first+count)``-th drain
    passes of the injection point named ``match`` (e.g. ``broker_flush``,
    ``queue_wave``)."""
    match: str
    first: int
    count: int = 1
    seconds: float = 0.02


# --------------------------------------------------------------- plan ----


class FaultPlan:
    """A seeded schedule of fault events plus its trigger bookkeeping.

    The event tuples are immutable and comparable (determinism tests
    compare whole plans); the mutable counters live here, guarded by one
    lock, so a single plan can be armed across many threads.
    """

    def __init__(self, fs: Tuple[FsFault, ...] = (),
                 kills: Tuple[ReplicaKill, ...] = (),
                 stalls: Tuple[Stall, ...] = (), seed: int | None = None):
        self.fs = tuple(fs)
        self.kills = tuple(kills)
        self.stalls = tuple(stalls)
        self.seed = seed
        self._lock = threading.Lock()
        self._fs_counts: Dict[Tuple[str, str], int] = {}
        self._stall_counts: Dict[str, int] = {}
        self._fired_kills: set = set()
        self.triggered: List[Tuple[str, str, str]] = []  # (op, error, path)

    def __repr__(self):
        return (f"FaultPlan(seed={self.seed}, fs={self.fs}, "
                f"kills={self.kills}, stalls={self.stalls})")

    @property
    def events(self) -> tuple:
        """The immutable schedule (what determinism tests compare)."""
        return (self.fs, self.kills, self.stalls)

    @classmethod
    def generate(cls, seed: int, profile: str = "mixed", *,
                 replicas: int = 2, horizon_gens: int = 64) -> "FaultPlan":
        """Derive a plan from ``seed``.  Profiles: ``disk-fault`` (WAL
        write/fsync faults only), ``replica-kill`` (tail kills only),
        ``mixed`` (both).  Same seed + profile => identical plan."""
        assert profile in ("disk-fault", "replica-kill", "mixed"), profile
        rng = np.random.default_rng(seed)
        fs: List[FsFault] = []
        kills: List[ReplicaKill] = []
        if profile in ("disk-fault", "mixed"):
            for _ in range(int(rng.integers(1, 3))):
                op = ("write", "fsync")[int(rng.integers(0, 2))]
                error = ("eio", "enospc", "torn")[int(rng.integers(0, 3))]
                if op == "fsync" and error == "torn":
                    error = "eio"  # fsync has no bytes to tear
                fs.append(FsFault(
                    op=op, match="wal",
                    first=int(rng.integers(3, max(4, horizon_gens // 2))),
                    count=int(rng.integers(2, 6)), error=error,
                    tear_frac=float(rng.uniform(0.1, 0.9))))
        if profile in ("replica-kill", "mixed"):
            kills.append(ReplicaKill(
                replica_id=int(rng.integers(0, max(1, replicas))),
                at_gen=int(rng.integers(horizon_gens // 4,
                                        max(2, 3 * horizon_gens // 4)))))
        stalls: List[Stall] = []
        if profile == "mixed":
            stalls.append(Stall(
                match="broker_flush",
                first=int(rng.integers(2, max(3, horizon_gens))),
                count=2, seconds=0.01))
        return cls(fs=fs, kills=kills, stalls=tuple(stalls), seed=seed)

    # ------------------------------------------------------ consultation --

    def check_fs(self, op: str, path: str) -> FsFault | None:
        """Advance the per-``(op, match)`` counters for this call and
        return the fault it lands in, if any."""
        hit = None
        with self._lock:
            seen = set()
            for f in self.fs:
                if f.op != op or f.match not in path:
                    continue
                key = (op, f.match)
                if key not in seen:  # one tick per call per key
                    seen.add(key)
                    self._fs_counts[key] = self._fs_counts.get(key, 0) + 1
                idx = self._fs_counts[key] - 1
                if hit is None and f.first <= idx < f.first + f.count:
                    hit = f
        return hit

    def check_stall(self, match: str) -> Stall | None:
        with self._lock:
            relevant = [s for s in self.stalls if s.match == match]
            if not relevant:
                return None
            self._stall_counts[match] = \
                self._stall_counts.get(match, 0) + 1
            idx = self._stall_counts[match] - 1
            for s in relevant:
                if s.first <= idx < s.first + s.count:
                    return s
        return None


# --------------------------------------------------- global arming -------

_PLAN: FaultPlan | None = None


def install(plan: FaultPlan | None) -> None:
    """Arm ``plan`` process-wide (None disarms)."""
    global _PLAN
    _PLAN = plan


def clear() -> None:
    install(None)


def active_plan() -> FaultPlan | None:
    return _PLAN


@contextlib.contextmanager
def injected(plan: FaultPlan):
    """Arm ``plan`` for the duration of the block."""
    install(plan)
    try:
        yield plan
    finally:
        clear()


# ------------------------------------------------------- fs shims --------


def _raise_fs(fault: FsFault, path: str, op: str):
    plan = _PLAN
    if plan is not None:
        with plan._lock:
            plan.triggered.append((op, fault.error, path))
    eno = _errno.ENOSPC if fault.error == "enospc" else _errno.EIO
    raise OSError(eno, f"injected {fault.error} on {op}", path)


class _FaultyFile:
    """Write-mode file wrapper consulting the armed plan per write.

    Installed unconditionally on write-mode opens so a plan armed
    *after* the file was opened (mid-serving faults) still bites."""

    def __init__(self, f, path: str):
        self._f = f
        self._path = path

    def write(self, data):
        plan = _PLAN
        if plan is not None:
            fault = plan.check_fs("write", self._path)
            if fault is not None:
                if fault.error == "torn" and data:
                    cut = max(0, int(len(data) * fault.tear_frac))
                    self._f.write(data[:cut])
                    self._f.flush()
                _raise_fs(fault, self._path, "write")
        return self._f.write(data)

    def __getattr__(self, name):
        return getattr(self._f, name)

    # dunder lookup bypasses __getattr__, so delegate explicitly
    def __enter__(self):
        self._f.__enter__()
        return self

    def __exit__(self, *exc):
        return self._f.__exit__(*exc)

    def __iter__(self):
        return iter(self._f)


def fs_open(path: str, mode: str = "rb"):
    """``open()`` with fault-plan consultation; write modes come back
    wrapped so every later ``write`` is also a fault point."""
    plan = _PLAN
    if plan is not None:
        fault = plan.check_fs("open", path)
        if fault is not None:
            _raise_fs(fault, path, "open")
    f = open(path, mode)
    if any(c in mode for c in "wxa+"):
        return _FaultyFile(f, path)
    return f


def fs_fsync(f) -> None:
    """``os.fsync`` with fault-plan consultation (accepts a plain file
    or a :class:`_FaultyFile`)."""
    path = str(getattr(f, "_path", None) or getattr(f, "name", ""))
    plan = _PLAN
    if plan is not None:
        fault = plan.check_fs("fsync", path)
        if fault is not None:
            _raise_fs(fault, path, "fsync")
    os.fsync(f.fileno())


# --------------------------------------------------- other injectors -----


def maybe_stall(match: str) -> float:
    """Sleep if the armed plan schedules a stall at this point; returns
    the injected seconds (0.0 when nothing fired)."""
    plan = _PLAN
    if plan is None:
        return 0.0
    s = plan.check_stall(match)
    if s is None:
        return 0.0
    time.sleep(s.seconds)
    return s.seconds


def fire_kills(plan: FaultPlan, replica_set, writer_gen: int) -> list:
    """Fire every not-yet-fired :class:`ReplicaKill` whose generation the
    writer has reached; returns the fired events.  The chaos driver calls
    this between chunks (the plan is gen-scheduled, not time-scheduled,
    so the schedule is reproducible)."""
    fired = []
    for k in plan.kills:
        with plan._lock:
            if k in plan._fired_kills or writer_gen < k.at_gen:
                continue
            plan._fired_kills.add(k)
        reps = replica_set.replicas
        reps[k.replica_id % len(reps)].kill()
        fired.append(k)
    return fired
