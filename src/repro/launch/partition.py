"""PartitionSpec rules per model family.

Naming convention (mesh axes): 'pod' and 'data' carry batch/edge/op
parallelism; 'model' carries tensor/expert/vocab/node parallelism.  All
rules are expressed against *axis names*, so the same specs drive the
16x16 single-pod mesh, the 2x16x16 multi-pod mesh, and any host mesh --
that name-indirection is what makes checkpoints elastically re-shardable.

LM strategy (baseline recorded in EXPERIMENTS.md §Roofline):
  * weights: FSDP over 'data' on the d_model axis x TP over 'model' on the
    ffn/heads/vocab axis (ZeRO-3-style; optimizer state inherits the same
    specs, so ZeRO-1 is subsumed);
  * activations: batch over ('pod','data'); residual stream
    sequence-sharded over 'model' between layers (Megatron SP -- required
    to fit the 94L x 4k-token carry);
  * MoE experts over 'model', expert d_model axis over 'data';
  * KV caches: batch over ('pod','data'), cache length over 'model' for
    decode shapes (sequence-sharded attention, psum over the length axis).

GNN: edge arrays over ('pod','data'); node arrays over 'model' (row
sharding); labels/readouts follow nodes.

RecSys: batch over ('pod','data'); embedding tables row-sharded over
'model'; candidate axis over 'model' for retrieval scoring.

SMSCC: edge-table columns over ('pod','data') -- the shards are the
paper's "threads"; label arrays replicated (baseline) with all-reduce
merges (the semilattice argument in DESIGN.md §2).
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def _dp(mesh):
    axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return axes if len(axes) > 1 else axes[0]


def _divisible(n: int, mesh, axis: str) -> bool:
    return n % mesh.shape[axis] == 0


# ------------------------------------------------------------------- LM ---

def lm_param_specs(cfg, mesh):
    dp = "data"  # FSDP axis (weights stay pod-replicated; grads psum pods)
    d_ok = _divisible(cfg.d_model, mesh, "data")
    fsdp = dp if d_ok else None
    layers = {
        "ln1": P(None, None), "ln2": P(None, None),
        "wq": P(None, fsdp, "model"),
        "wk": P(None, fsdp, "model") if _divisible(
            cfg.n_kv_heads * cfg.head_dim, mesh, "model")
        else P(None, fsdp, None),
        "wv": P(None, fsdp, "model") if _divisible(
            cfg.n_kv_heads * cfg.head_dim, mesh, "model")
        else P(None, fsdp, None),
        "wo": P(None, "model", fsdp),
    }
    if cfg.qk_norm:
        layers["q_norm"] = P(None, None)
        layers["k_norm"] = P(None, None)
    if cfg.moe is not None:
        moe = {
            "router": P(None, None, "model") if _divisible(
                cfg.moe.n_experts, mesh, "model") else P(None, None, None),
            "w_gate": P(None, "model", fsdp, None),
            "w_up": P(None, "model", fsdp, None),
            "w_down": P(None, "model", None, fsdp),
        }
        if cfg.moe.n_shared_experts:
            moe["shared"] = {
                "w_gate": P(None, fsdp, "model"),
                "w_up": P(None, fsdp, "model"),
                "w_down": P(None, "model", fsdp),
            }
        layers["moe"] = moe
    else:
        layers["ffn"] = {
            "w_gate": P(None, fsdp, "model"),
            "w_up": P(None, fsdp, "model"),
            "w_down": P(None, "model", fsdp),
        }
    specs = {
        "embed": P("model", fsdp) if _divisible(cfg.vocab, mesh, "model")
        else P(None, fsdp),
        "layers": layers,
        "ln_f": P(None),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(fsdp, "model") if _divisible(
            cfg.vocab, mesh, "model") else P(fsdp, None)
    return specs


def lm_batch_specs(mesh):
    dp = _dp(mesh)
    return {"tokens": P(dp, None), "labels": P(dp, None)}


def lm_cache_specs(cfg, mesh, batch: int):
    """KV cache sharding for decode shapes."""
    dp = _dp(mesh)
    n_dp = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        n_dp *= mesh.shape[a]
    if batch % n_dp == 0 and batch >= n_dp:
        # batch-sharded cache, length over 'model' (seq-sharded attention)
        return {"k": P(None, dp, "model", None, None),
                "v": P(None, dp, "model", None, None),
                "pos": P()}
    # batch too small (long-context bs=1): shard length over data+model
    return {"k": P(None, None, ("data", "model"), None, None),
            "v": P(None, None, ("data", "model"), None, None),
            "pos": P()}


# ------------------------------------------------------------------ GNN ---

def gnn_param_specs(params):
    """GNN weights are small: replicate everything."""
    return jax.tree.map(lambda _: P(), params)


def gnn_node_axis(mesh, n_nodes: int):
    """Widest mesh-axis combo that divides the (padded) node count --
    node tensors on 10^6-node graphs must shard across every chip."""
    dp = _dp(mesh)
    dp_t = dp if isinstance(dp, tuple) else (dp,)
    full = dp_t + ("model",)
    size = 1
    for a in full:
        size *= mesh.shape[a]
    if n_nodes % size == 0:
        return full
    if n_nodes % mesh.shape["model"] == 0:
        return "model"
    return None


def gnn_batch_specs(mesh, n_nodes: int, n_edges: int, node_ax="auto"):
    dp = _dp(mesh)
    if node_ax == "auto":
        node_ax = gnn_node_axis(mesh, n_nodes)
    edge_ax = dp
    return {
        "src": P(edge_ax), "dst": P(edge_ax), "edge_mask": P(edge_ax),
        "node_mask": P(node_ax), "graph_id": P(node_ax),
        "x": P(node_ax, None), "pos": P(node_ax, None),
        "labels": P(node_ax), "energy": P(None), "forces": P(node_ax, None),
    }


# --------------------------------------------------------------- recsys ---

def mind_param_specs(cfg, mesh):
    row = "model" if cfg.n_items % mesh.shape["model"] == 0 else None
    prow = "model" if cfg.profile_vocab % mesh.shape["model"] == 0 else None
    return {
        "item_embed": P(row, None),
        "profile_embed": P(prow, None),
        "S": P(None, None),
        "b_init": P(None, None),
        "proj": P(None, None),
    }


def mind_batch_specs(mesh, batch: int, with_candidates: bool = False,
                     cand: int = 0):
    dp = _dp(mesh)
    n_dp = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        n_dp *= mesh.shape[a]
    bax = dp if batch % n_dp == 0 and batch >= n_dp else None
    specs = {"behavior": P(bax, None), "profile": P(bax, None),
             "target": P(bax), "negatives": P(None)}
    if with_candidates:
        cax = "model" if cand % mesh.shape["model"] == 0 else None
        specs["candidates"] = P(bax, cax)
    return specs


# ---------------------------------------------------------------- smscc ---

def smscc_state_specs(mesh):
    dp = _dp(mesh)
    from repro.core import edge_table as et
    from repro.core import graph_state as gs
    return gs.GraphState(
        v_alive=P(None), ccid=P(None),
        edges=et.EdgeTable(src=P(dp), dst=P(dp), state=P(dp)),
        n_ccs=P(), gen=P(), overflow=P())


def smscc_ops_specs(mesh):
    dp = _dp(mesh)
    from repro.core import dynamic
    return dynamic.OpBatch(kind=P(dp), u=P(dp), v=P(dp))


# ------------------------------------------------------------ optimizer ---

def opt_state_specs(param_specs):
    """AdamW moments inherit parameter specs (FSDP => ZeRO sharding)."""
    from repro.optim import optimizer as opt
    return opt.OptState(m=param_specs, v=param_specs, count=P())
