# Launch layer: production mesh, partitioning rules, step builders,
# multi-pod dry-run, and end-to-end train/serve drivers.
