"""Bucketed stream scheduling + typed-client stream drivers.

An on-line service sees arbitrary-length op chunks; under jit every new
batch length is a fresh XLA compilation.  The scheduler therefore admits
only a small registry of static batch shapes (the ``prefill_bs{N}``
bucket-registry pattern from production LLM serving): a chunk of length N
is cut greedily into the largest buckets that fit, and the tail is padded
with NOP lanes up to the smallest bucket that holds it.  Total
compilations are bounded by ``len(buckets)`` per graph config, independent
of stream length.

The drivers (`run_stream`, `run_concurrent_stream`) speak the public
typed API: workload generators produce :mod:`repro.api.ops` op streams,
and every update/query goes through a :class:`repro.api.GraphClient`
session — the raw ``(kind, u, v)`` convention stays behind the facade.
"""
from __future__ import annotations

import threading
import time
from typing import Iterator, List, Sequence, Tuple

import numpy as np

from repro.core import dynamic

__all__ = ["BucketedScheduler", "run_stream", "run_concurrent_stream",
           "StreamReport", "typed_op_stream"]


class BucketedScheduler:
    """Cuts (kind, u, v) arrays into NOP-padded static-shape OpBatches."""

    def __init__(self, buckets: Sequence[int] = (64, 256, 1024)):
        assert buckets, "need at least one bucket size"
        self.buckets: Tuple[int, ...] = tuple(sorted(set(int(b)
                                                         for b in buckets)))
        assert all(b > 0 for b in self.buckets)

    def plan(self, n: int) -> List[Tuple[slice, int]]:
        """[(slice into the chunk, bucket size)] covering [0, n)."""
        out: List[Tuple[slice, int]] = []
        pos = 0
        while pos < n:
            rest = n - pos
            fits = [b for b in self.buckets if b <= rest]
            # largest full bucket, else smallest bucket that covers the tail
            b = fits[-1] if fits else min(
                b for b in self.buckets if b >= rest)
            take = min(b, rest)
            out.append((slice(pos, pos + take), b))
            pos += take
        return out

    def chunks(self, kind, u, v) -> Iterator[
            Tuple[slice, dynamic.OpBatch]]:
        """Yield (slice, padded OpBatch); lanes past the slice are NOPs."""
        kind = np.asarray(kind, np.int32)
        u = np.asarray(u, np.int32)
        v = np.asarray(v, np.int32)
        for sl, b in self.plan(kind.shape[0]):
            pk = np.full(b, dynamic.NOP, np.int32)
            pu = np.zeros(b, np.int32)
            pv = np.zeros(b, np.int32)
            n = sl.stop - sl.start
            pk[:n] = kind[sl]
            pu[:n] = u[sl]
            pv[:n] = v[sl]
            yield sl, dynamic.make_ops(pk, pu, pv)

    def super_chunks(self, kind, u, v,
                     scan_lengths: Sequence[int] = (1, 4, 16)
                     ) -> Iterator[Tuple[List[slice], dynamic.OpBatch]]:
        """Group the bucket plan into stacked *super-chunks* for the fused
        ``dynamic.apply_batch_scan`` entry.

        Maximal runs of equal-bucket plan entries are cut greedily into
        the largest ``scan_lengths`` that fit (the registry always
        includes 1, so no run is ever NOP-step padded -- a super-chunk
        contains only real plan entries and the linearization is exactly
        the per-bucket order of :meth:`chunks`).  Yields
        ``([slice, ...], OpBatch)`` where the batch carries
        ``int32[K, B]`` leaves, one stacked row per covered slice.
        Compile shapes stay bounded by ``len(buckets) x
        len(scan_lengths)`` per graph config.
        """
        lens = tuple(sorted({int(s) for s in scan_lengths} | {1}))
        assert all(s > 0 for s in lens), "scan lengths must be positive"
        kind = np.asarray(kind, np.int32)
        u = np.asarray(u, np.int32)
        v = np.asarray(v, np.int32)
        plan = self.plan(kind.shape[0])
        i = 0
        while i < len(plan):
            b = plan[i][1]
            j = i
            while j < len(plan) and plan[j][1] == b:
                j += 1
            while i < j:  # cut the equal-bucket run [i, j) into scan steps
                k = max(s for s in lens if s <= j - i)
                group = plan[i:i + k]
                pk = np.full((k, b), dynamic.NOP, np.int32)
                pu = np.zeros((k, b), np.int32)
                pv = np.zeros((k, b), np.int32)
                for r, (sl, _) in enumerate(group):
                    n = sl.stop - sl.start
                    pk[r, :n] = kind[sl]
                    pu[r, :n] = u[sl]
                    pv[r, :n] = v[sl]
                yield ([sl for sl, _ in group],
                       dynamic.make_ops(pk, pu, pv))
                i += k


class StreamReport(dict):
    """Flat metrics dict with a pretty printer."""

    def pretty(self) -> str:
        return " | ".join(f"{k}={v}" for k, v in self.items())


def typed_op_stream(nv: int, n: int, *, step: int, add_frac: float,
                    seed: int = 0, include_vertex_ops: bool = True):
    """One deterministic chunk of typed update ops (paper workload mix)."""
    from repro.api import updates_from_arrays
    from repro.launch import workload

    ops = workload.op_stream(nv, n, step=step, add_frac=add_frac,
                             seed=seed,
                             include_vertex_ops=include_vertex_ops)
    return updates_from_arrays(np.asarray(ops.kind), np.asarray(ops.u),
                               np.asarray(ops.v))


def run_stream(service, n_ops: int, *, add_frac: float = 0.6,
               query_frac: float = 0.0, chunk: int = 512,
               n_queries: int = 256, include_vertex_ops: bool = True,
               seed: int = 0) -> StreamReport:
    """Drive ``service`` with a synthetic mixed workload (paper Fig 4/5)
    through a single typed :class:`repro.api.GraphClient` session.

    ``query_frac`` interleaves SameSCC/reachability query batches between
    update chunks; throughput is reported separately for updates and
    queries.  Deterministic in ``seed``.
    """
    from repro.api import GraphClient, Reachable, SameSCC
    from repro.core.broker import QueryBroker

    nv = service.cfg.n_vertices
    rng = np.random.default_rng(seed)
    n_reach = min(32, n_queries)
    # bucket registry matched to the two query shapes issued below, and to
    # run_concurrent_stream's registry, so serial/concurrent comparisons
    # share identical compiled query shapes
    client = GraphClient(service, broker=QueryBroker(
        service, buckets=tuple(sorted({n_queries, n_reach}))))
    applied = 0
    queries = 0
    accepted = 0
    t_update = 0.0
    t_query = 0.0
    step = 0
    try:
        while applied < n_ops:
            n = min(chunk, n_ops - applied)
            ops = typed_op_stream(nv, n, step=step, add_frac=add_frac,
                                  seed=seed,
                                  include_vertex_ops=include_vertex_ops)
            t0 = time.perf_counter()
            results = client.submit_many(ops)
            t_update += time.perf_counter() - t0
            accepted += sum(r.value for r in results)
            applied += n
            step += 1
            if query_frac > 0 and rng.random() < query_frac:
                qu = rng.integers(0, nv, n_queries)
                qv = rng.integers(0, nv, n_queries)
                same_ops = [SameSCC(int(a), int(b))
                            for a, b in zip(qu, qv)]
                reach_ops = [Reachable(int(a), int(b))
                             for a, b in zip(qu[:n_reach], qv[:n_reach])]
                t0 = time.perf_counter()
                same = client.submit_many(same_ops)
                reach_ = client.submit_many(reach_ops)
                t_query += time.perf_counter() - t0
                assert same[0].gen == reach_[0].gen, \
                    "snapshot generation drifted"
                queries += n_queries + n_reach
    finally:
        client.close()
    wall = t_update + t_query
    rep = StreamReport(
        ops=applied, accepted=accepted, queries=queries,
        update_s=round(t_update, 4), query_s=round(t_query, 4),
        ops_per_s=int(applied / t_update) if t_update else 0,
        queries_per_s=int(queries / t_query) if t_query else 0,
        combined_per_s=int((applied + queries) / wall) if wall else 0,
    )
    rep.update(client.stats())
    return rep


def run_concurrent_stream(service, n_ops: int, *, readers: int = 2,
                          add_frac: float = 0.6, chunk: int = 512,
                          n_queries: int = 256, reach_queries: int = 32,
                          include_vertex_ops: bool = True, seed: int = 0,
                          query_buckets: Sequence[int] | None = None
                          ) -> StreamReport:
    """The paper's actual serving shape: ``readers`` query threads overlap
    a live update stream (Fig 4/5's concurrent mode).

    The main thread applies the same deterministic typed update stream as
    :func:`run_stream` through its own :class:`repro.api.GraphClient`
    session; meanwhile each reader thread holds its own client session
    over one shared, dispatcher-fed :class:`repro.core.broker.QueryBroker`
    and issues coalesced SameSCC (and occasional reachability) batches,
    checking that the generations it observes are monotone.  Queries are
    free-running: throughput is whatever the readers manage while the
    updates execute, the point being that ``combined_per_s`` beats the
    serial interleaving of :func:`run_stream` on the same update mix.
    """
    from repro.api import GraphClient, Reachable, SameSCC
    from repro.core.broker import QueryBroker

    nv = service.cfg.n_vertices
    # bucket registry sized to the two request shapes readers issue, so a
    # lone reachability batch is never padded up to the SameSCC size
    buckets = query_buckets or tuple(sorted(
        {n_queries} | ({reach_queries} if reach_queries else set())))
    broker = QueryBroker(service, buckets=buckets).start()
    updater = GraphClient(service, broker=broker)
    stop = threading.Event()
    q_counts = [0] * readers
    errors: list = []

    def reader(i: int):
        client = GraphClient(service, broker=broker)
        rng = np.random.default_rng(seed + 7919 * (i + 1))
        last_gen = -1
        try:
            while not stop.is_set():
                qu = rng.integers(0, nv, n_queries)
                qv = rng.integers(0, nv, n_queries)
                res = client.submit_many(
                    [SameSCC(int(a), int(b)) for a, b in zip(qu, qv)])
                gen = res[0].gen
                if gen < last_gen:
                    raise AssertionError(
                        f"reader {i} saw generation go backwards: "
                        f"{gen} < {last_gen}")
                last_gen = gen
                q_counts[i] += n_queries
                if reach_queries and rng.random() < 0.25:
                    res = client.submit_many(
                        [Reachable(int(a), int(b)) for a, b in
                         zip(qu[:reach_queries], qv[:reach_queries])])
                    last_gen = max(last_gen, res[0].gen)
                    q_counts[i] += reach_queries
        except Exception as e:  # surfaced after join
            errors.append(e)

    threads = [threading.Thread(target=reader, args=(i,), daemon=True)
               for i in range(readers)]
    applied = accepted = step = 0
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    try:
        while applied < n_ops:
            n = min(chunk, n_ops - applied)
            ops = typed_op_stream(nv, n, step=step, add_frac=add_frac,
                                  seed=seed,
                                  include_vertex_ops=include_vertex_ops)
            results = updater.submit_many(ops)
            accepted += sum(r.value for r in results)
            applied += n
            step += 1
    finally:
        stop.set()
        for t in threads:
            t.join()
        broker.stop()
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    queries = sum(q_counts)
    rep = StreamReport(
        ops=applied, accepted=accepted, queries=queries, readers=readers,
        wall_s=round(wall, 4),
        ops_per_s=int(applied / wall) if wall else 0,
        queries_per_s=int(queries / wall) if wall else 0,
        combined_per_s=int((applied + queries) / wall) if wall else 0,
    )
    rep.update(updater.stats())
    return rep
