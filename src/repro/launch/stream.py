"""Bucketed stream scheduling for the SCC service.

An on-line service sees arbitrary-length op chunks; under jit every new
batch length is a fresh XLA compilation.  The scheduler therefore admits
only a small registry of static batch shapes (the ``prefill_bs{N}``
bucket-registry pattern from production LLM serving): a chunk of length N
is cut greedily into the largest buckets that fit, and the tail is padded
with NOP lanes up to the smallest bucket that holds it.  Total
compilations are bounded by ``len(buckets)`` per graph config, independent
of stream length.
"""
from __future__ import annotations

import threading
import time
from typing import Iterator, List, Sequence, Tuple

import numpy as np

from repro.core import dynamic

__all__ = ["BucketedScheduler", "run_stream", "run_concurrent_stream",
           "StreamReport"]


class BucketedScheduler:
    """Cuts (kind, u, v) arrays into NOP-padded static-shape OpBatches."""

    def __init__(self, buckets: Sequence[int] = (64, 256, 1024)):
        assert buckets, "need at least one bucket size"
        self.buckets: Tuple[int, ...] = tuple(sorted(set(int(b)
                                                         for b in buckets)))
        assert all(b > 0 for b in self.buckets)

    def plan(self, n: int) -> List[Tuple[slice, int]]:
        """[(slice into the chunk, bucket size)] covering [0, n)."""
        out: List[Tuple[slice, int]] = []
        pos = 0
        while pos < n:
            rest = n - pos
            fits = [b for b in self.buckets if b <= rest]
            # largest full bucket, else smallest bucket that covers the tail
            b = fits[-1] if fits else min(
                b for b in self.buckets if b >= rest)
            take = min(b, rest)
            out.append((slice(pos, pos + take), b))
            pos += take
        return out

    def chunks(self, kind, u, v) -> Iterator[
            Tuple[slice, dynamic.OpBatch]]:
        """Yield (slice, padded OpBatch); lanes past the slice are NOPs."""
        kind = np.asarray(kind, np.int32)
        u = np.asarray(u, np.int32)
        v = np.asarray(v, np.int32)
        for sl, b in self.plan(kind.shape[0]):
            pk = np.full(b, dynamic.NOP, np.int32)
            pu = np.zeros(b, np.int32)
            pv = np.zeros(b, np.int32)
            n = sl.stop - sl.start
            pk[:n] = kind[sl]
            pu[:n] = u[sl]
            pv[:n] = v[sl]
            yield sl, dynamic.make_ops(pk, pu, pv)


class StreamReport(dict):
    """Flat metrics dict with a pretty printer."""

    def pretty(self) -> str:
        return " | ".join(f"{k}={v}" for k, v in self.items())


def run_stream(service, n_ops: int, *, add_frac: float = 0.6,
               query_frac: float = 0.0, chunk: int = 512,
               n_queries: int = 256, include_vertex_ops: bool = True,
               seed: int = 0) -> StreamReport:
    """Drive ``service`` with a synthetic mixed workload (paper Fig 4/5).

    ``query_frac`` interleaves SameSCC/reachability query batches between
    update chunks; throughput is reported separately for updates and
    queries.  Deterministic in ``seed``.
    """
    from repro.data import pipeline

    nv = service.cfg.n_vertices
    rng = np.random.default_rng(seed)
    applied = 0
    queries = 0
    accepted = 0
    t_update = 0.0
    t_query = 0.0
    step = 0
    while applied < n_ops:
        n = min(chunk, n_ops - applied)
        ops = pipeline.op_stream(nv, n, step=step, add_frac=add_frac,
                                 seed=seed,
                                 include_vertex_ops=include_vertex_ops)
        t0 = time.perf_counter()
        ok = service.apply(np.asarray(ops.kind), np.asarray(ops.u),
                           np.asarray(ops.v))
        t_update += time.perf_counter() - t0
        accepted += int(ok.sum())
        applied += n
        step += 1
        if query_frac > 0 and rng.random() < query_frac:
            qu = rng.integers(0, nv, n_queries)
            qv = rng.integers(0, nv, n_queries)
            n_reach = min(32, n_queries)  # reach sweeps cost O(E) per round
            t0 = time.perf_counter()
            same = service.same_scc(qu, qv)
            reach_ = service.reachable(qu[:n_reach], qv[:n_reach])
            t_query += time.perf_counter() - t0
            assert same.gen == reach_.gen, "snapshot generation drifted"
            queries += n_queries + n_reach
    wall = t_update + t_query
    rep = StreamReport(
        ops=applied, accepted=accepted, queries=queries,
        update_s=round(t_update, 4), query_s=round(t_query, 4),
        ops_per_s=int(applied / t_update) if t_update else 0,
        queries_per_s=int(queries / t_query) if t_query else 0,
        combined_per_s=int((applied + queries) / wall) if wall else 0,
    )
    rep.update(service.stats())
    return rep


def run_concurrent_stream(service, n_ops: int, *, readers: int = 2,
                          add_frac: float = 0.6, chunk: int = 512,
                          n_queries: int = 256, reach_queries: int = 32,
                          include_vertex_ops: bool = True, seed: int = 0,
                          query_buckets: Sequence[int] | None = None
                          ) -> StreamReport:
    """The paper's actual serving shape: ``readers`` query threads overlap
    a live update stream (Fig 4/5's concurrent mode).

    The main thread applies the same deterministic update stream as
    :func:`run_stream`; meanwhile each reader thread issues coalesced
    SameSCC (and occasional reachability) batches through a
    :class:`repro.core.broker.QueryBroker`, checking that the generations
    it observes are monotone.  Queries are free-running: throughput is
    whatever the readers manage while the updates execute, the point being
    that ``combined_per_s`` beats the serial interleaving of
    :func:`run_stream` on the same update mix.
    """
    from repro.core.broker import QueryBroker
    from repro.data import pipeline

    nv = service.cfg.n_vertices
    # bucket registry sized to the two request shapes readers issue, so a
    # lone reachability batch is never padded up to the SameSCC size
    buckets = query_buckets or tuple(sorted(
        {n_queries} | ({reach_queries} if reach_queries else set())))
    broker = QueryBroker(service, buckets=buckets).start()
    stop = threading.Event()
    q_counts = [0] * readers
    errors: list = []

    def reader(i: int):
        rng = np.random.default_rng(seed + 7919 * (i + 1))
        last_gen = -1
        try:
            while not stop.is_set():
                qu = rng.integers(0, nv, n_queries)
                qv = rng.integers(0, nv, n_queries)
                snap = broker.same_scc(qu, qv)
                if snap.gen < last_gen:
                    raise AssertionError(
                        f"reader {i} saw generation go backwards: "
                        f"{snap.gen} < {last_gen}")
                last_gen = snap.gen
                q_counts[i] += n_queries
                if reach_queries and rng.random() < 0.25:
                    snap = broker.reachable(qu[:reach_queries],
                                            qv[:reach_queries])
                    last_gen = max(last_gen, snap.gen)
                    q_counts[i] += reach_queries
        except Exception as e:  # surfaced after join
            errors.append(e)

    threads = [threading.Thread(target=reader, args=(i,), daemon=True)
               for i in range(readers)]
    applied = accepted = step = 0
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    try:
        while applied < n_ops:
            n = min(chunk, n_ops - applied)
            ops = pipeline.op_stream(nv, n, step=step, add_frac=add_frac,
                                     seed=seed,
                                     include_vertex_ops=include_vertex_ops)
            ok = service.apply(np.asarray(ops.kind), np.asarray(ops.u),
                               np.asarray(ops.v))
            accepted += int(ok.sum())
            applied += n
            step += 1
    finally:
        stop.set()
        for t in threads:
            t.join()
        broker.stop()
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    queries = sum(q_counts)
    rep = StreamReport(
        ops=applied, accepted=accepted, queries=queries, readers=readers,
        wall_s=round(wall, 4),
        ops_per_s=int(applied / wall) if wall else 0,
        queries_per_s=int(queries / wall) if wall else 0,
        combined_per_s=int((applied + queries) / wall) if wall else 0,
    )
    rep.update(service.stats())
    rep.update(broker.stats())
    return rep
