"""The paper's synthetic update workload (live home).

``op_stream`` is the deterministic mixed Add/Remove (V+E) batch
generator every SCC driver and benchmark feeds from -- it moved here
from ``repro.data.pipeline`` (the seed-era LM/recsys data package, now
LEGACY) because it is serving-stack infrastructure, not training data.
``repro.data.pipeline.op_stream`` remains as a delegating alias.

Every batch is a pure function of (seed, step, shard): restart
determinism (a driver restart re-generates the identical stream),
shard-affinity (each shard seeds with its own (step, shard) pair), and
elasticity come for free.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["ShardInfo", "op_stream"]


@dataclasses.dataclass(frozen=True)
class ShardInfo:
    shard: int = 0
    n_shards: int = 1


def _rng(seed: int, step: int, shard: int = 0):
    return np.random.default_rng(
        np.random.SeedSequence([seed, step, shard]))


def op_stream(n_vertices: int, batch: int, step: int, add_frac: float,
              info: ShardInfo = ShardInfo(), seed: int = 0,
              include_vertex_ops: bool = True):
    """Paper workload generator: mixed Add/Remove (V+E) batches.

    add_frac = fraction of insert ops (paper Fig 4: 0.5 / 0.9 / 0.1).
    """
    from repro.core import dynamic
    b_local = batch // info.n_shards
    rng = _rng(seed, step, info.shard)
    is_add = rng.random(b_local) < add_frac
    is_vertex = (rng.random(b_local) < 0.2) if include_vertex_ops \
        else np.zeros(b_local, bool)
    kind = np.where(is_add,
                    np.where(is_vertex, dynamic.ADD_VERTEX,
                             dynamic.ADD_EDGE),
                    np.where(is_vertex, dynamic.REM_VERTEX,
                             dynamic.REM_EDGE))
    u = rng.integers(0, n_vertices, b_local)
    v = rng.integers(0, n_vertices, b_local)
    return dynamic.make_ops(kind, u, v)
