import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver:
  1. builds the sharded step (launch/steps.py) with ShapeDtypeStruct
     stand-ins -- no host allocation;
  2. ``jax.jit(fn, in_shardings, out_shardings).lower(*args).compile()``
     under the production mesh -- GSPMD partitioning must succeed, proving
     the distribution config is coherent;
  3. captures ``memory_analysis()`` (per-device bytes: proves it fits),
     ``cost_analysis()`` (FLOPs / bytes for §Roofline), and parses the
     post-SPMD HLO for collective operand bytes per collective kind;
  4. derives the three roofline terms vs the v5e constants and appends a
     JSON record to the results file.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""
import argparse
import json
import re
import time
import traceback

import jax

from repro import configs as cfg_registry
from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_lib

# TPU v5e-class hardware constants (per mandate)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
SHAPE_RE = re.compile(r"\b((?:f|bf|s|u|pred|s8|u8)\d*)\[([\d,]*)\]")

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
               "s64": 8, "s32": 4, "s16": 2, "s8": 1,
               "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1}


def _bytes_of_shape(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Per-kind traffic estimate: max(result bytes, operand bytes) of every
    collective op.  Result-side counts the gathered tensor for all-gather;
    operand-side counts the pre-reduce tensor for reduce-scatter; the two
    coincide for all-reduce / all-to-all / collective-permute."""
    out = {}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m or "-done(" in line:
            continue  # skip async -done halves (counted at -start)
        kind = m.group(1)
        head, _, tail = line.partition(m.group(0))
        res = sum(_bytes_of_shape(d, s) for d, s in SHAPE_RE.findall(head))
        opd = sum(_bytes_of_shape(d, s) for d, s in SHAPE_RE.findall(tail))
        b = max(res, opd)
        out[kind] = out.get(kind, 0) + b
        out.setdefault("count_" + kind, 0)
        out["count_" + kind] += 1
    return out


def _compile_bundle(bundle, mesh):
    t0 = time.time()
    with mesh:
        jitted = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                         out_shardings=bundle.out_shardings,
                         donate_argnums=bundle.donate)
        lowered = jitted.lower(*bundle.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    return compiled, t_lower, t_compile


def _extract(compiled) -> dict:
    out = {}
    try:
        mem = compiled.memory_analysis()
        out["memory"] = {
            k: int(getattr(mem, k)) for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:  # pragma: no cover
        out["memory"] = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        out["cost"] = {k: float(v) for k, v in cost.items()
                       if k == "flops" or k == "bytes accessed"}
    except Exception as e:  # pragma: no cover
        out["cost"] = {"error": str(e)}
    try:
        hlo = compiled.as_text()
        out["collectives"] = collective_bytes(hlo)
        out["hlo_bytes"] = len(hlo)
    except Exception as e:  # pragma: no cover
        out["collectives"] = {"error": str(e)}
    return out


def _coll_total(coll: dict) -> float:
    return float(sum(v for k, v in coll.items()
                     if not k.startswith("count_")
                     and isinstance(v, (int, float))))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             lm_variants: bool = True, overrides=None,
             tag: str = "baseline") -> dict:
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    bundle = steps_lib.build(arch, shape_name, mesh, overrides=overrides)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "x".join(str(s) for s in mesh.devices.shape),
           "chips": int(n_chips), "tag": tag,
           "overrides": {k: str(v) for k, v in (overrides or {}).items()}}
    if bundle is None:
        rec["status"] = "skipped"
        rec["reason"] = cfg_registry.get(arch).SHAPES[shape_name]["skip"]
        return rec

    compiled, t_lower, t_compile = _compile_bundle(bundle, mesh)
    rec["status"] = "ok"
    rec["lower_s"] = round(t_lower, 1)
    rec["compile_s"] = round(t_compile, 1)
    rec.update(_extract(compiled))
    del compiled

    flops = rec.get("cost", {}).get("flops", 0.0)
    mem_bytes = rec.get("cost", {}).get("bytes accessed", 0.0)
    coll_bytes = _coll_total(rec.get("collectives", {}))

    # LM scans hide per-layer work inside a while body that XLA cost
    # analysis counts ONCE.  Meter with unrolled 1- and 2-layer twins:
    #   per_layer = c(2) - c(1);  total = c(1) + (L-1) * per_layer.
    fam = cfg_registry.get(arch).FAMILY
    if fam == "lm" and lm_variants:
        n_layers = cfg_registry.get(arch).config().n_layers
        v = {}
        for k in (1, 2):
            b_k = steps_lib.build(arch, shape_name, mesh, lm_layers=k,
                                  overrides=overrides)
            c_k, _, _ = _compile_bundle(b_k, mesh)
            v[k] = _extract(c_k)
            del c_k
        rec["variants"] = v

        def _lin(get):
            c1, c2 = get(v[1]), get(v[2])
            return c1 + (n_layers - 1) * max(c2 - c1, 0.0)

        flops = _lin(lambda r: r.get("cost", {}).get("flops", 0.0))
        mem_bytes = _lin(
            lambda r: r.get("cost", {}).get("bytes accessed", 0.0))
        coll_bytes = _lin(lambda r: _coll_total(r.get("collectives", {})))
        rec["metering"] = "unrolled L1/L2 extrapolation"
    elif fam == "smscc":
        rec["metering"] = ("while-bodies counted once: terms are per "
                           "fixpoint round; multiply by measured rounds "
                           "(benchmarks/bench_mix.py reports them)")
    elif fam == "gnn" and bundle.meta.get("edge_chunks", 1) > 1:
        # the shipped config streams edges through a scan whose body XLA
        # counts once; meter FLOPs/bytes/collectives on an unchunked twin
        # (compile-only static analysis -- the giant temps never allocate)
        b_t = steps_lib.build(arch, shape_name, mesh,
                              overrides={"edge_chunk": 0})
        c_t, _, _ = _compile_bundle(b_t, mesh)
        tw = _extract(c_t)
        del c_t
        rec["metering_twin"] = tw
        flops = tw.get("cost", {}).get("flops", flops)
        mem_bytes = tw.get("cost", {}).get("bytes accessed", mem_bytes)
        coll_bytes = _coll_total(tw.get("collectives", {}))
        rec["metering"] = "unchunked twin for flops; memory from shipped"
    else:
        rec["metering"] = "scans unrolled; direct cost analysis"

    model_flops = bundle.meta.get("model_flops", 0)
    rec["meta"] = {k: v for k, v in bundle.meta.items()}
    rec["roofline"] = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": mem_bytes / HBM_BW,
        "collective_s": coll_bytes / ICI_BW,
        "model_flops_total": model_flops,
        "hlo_flops_per_chip": flops,
        "useful_ratio": (model_flops / n_chips) / flops if flops else None,
    }
    terms = {k: rec["roofline"][k] for k in
             ("compute_s", "memory_s", "collective_s")}
    rec["roofline"]["bottleneck"] = max(terms, key=terms.get)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="dryrun_results.jsonl")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in cfg_registry.all_archs():
            for shape in cfg_registry.get(arch).SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    done = set()
    try:
        with open(args.out) as f:
            for line in f:
                r = json.loads(line)
                if r.get("status") in ("ok", "skipped"):
                    done.add((r["arch"], r["shape"], r["mesh"]))
    except FileNotFoundError:
        pass

    for arch, shape in cells:
        for mp in meshes:
            mesh_name = "2x16x16" if mp else "16x16"
            if (arch, shape, mesh_name) in done:
                print(f"[dryrun] skip cached {arch}:{shape}:{mesh_name}")
                continue
            print(f"[dryrun] {arch}:{shape} mesh={mesh_name} ...",
                  flush=True)
            try:
                rec = run_cell(arch, shape, mp)
            except Exception as e:
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "status": "error", "error": str(e),
                       "trace": traceback.format_exc()[-2000:]}
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
            print(f"[dryrun]   -> {rec['status']} "
                  f"compile={rec.get('compile_s', '-')}s "
                  f"bottleneck={rec.get('roofline', {}).get('bottleneck', '-')}",
                  flush=True)


if __name__ == "__main__":
    main()
