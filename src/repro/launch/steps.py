"""Step builders: (arch × shape × mesh) -> a lowerable, sharded step.

``build(arch, shape, mesh)`` returns a StepBundle:
  fn             the step callable (train/prefill/decode/serve/update)
  args           ShapeDtypeStruct pytree stand-ins (no allocation)
  in_shardings / out_shardings   NamedSharding trees
  meta           dict: model_flops (analytic "useful" FLOPs/step),
                 tokens/items per step, notes, skip reason if any

Shapes whose global dims don't divide the mesh are padded up front
(masked tails) -- recorded in meta['padded'].
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs as cfg_registry
from repro.configs import gnn_shapes as gshapes
from repro.launch import partition
from repro.models import transformer as tf
from repro.optim import optimizer


class StepBundle(NamedTuple):
    name: str
    fn: Any
    args: tuple
    in_shardings: Any
    out_shardings: Any
    meta: dict
    donate: tuple = ()  # arg indices donated (in-place update at XLA level)


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _dp_size(mesh):
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def _dp(mesh):
    axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return axes if len(axes) > 1 else axes[0]


def _pad_to(n: int, m: int) -> int:
    return -(-n // m) * m


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


OPT_CFG = optimizer.AdamWConfig(lr=3e-4, total_steps=100_000,
                                warmup_steps=2000)


# ------------------------------------------------------------------- LM ---

def lm_model_flops(cfg: tf.LMConfig, kind: str, batch: int, seq: int):
    """Analytic 'useful' FLOPs per step (mandate: 6·N·D train, 2·N·D fwd,
    plus attention term; MoE counts active params only)."""
    n_active = cfg.n_active_params()
    if kind == "train":
        tokens = batch * seq
        base = 6 * n_active * tokens
        attn = 0
        for l in range(cfg.n_layers):
            w = int(cfg.windows[l])
            eff = seq if w == 0 else min(seq, w)
            # causal: ~seq*eff/2 scored pairs, *2 matmuls (QK^T, PV), *2 MACs
            attn += 3 * 4 * batch * cfg.n_heads * cfg.head_dim * \
                (seq * eff // 2)  # fwd+bwd(2x)
        return base + attn
    if kind == "prefill":
        tokens = batch * seq
        base = 2 * n_active * tokens
        attn = 0
        for l in range(cfg.n_layers):
            w = int(cfg.windows[l])
            eff = seq if w == 0 else min(seq, w)
            attn += 4 * batch * cfg.n_heads * cfg.head_dim * (seq * eff // 2)
        return base + attn
    # decode: one token against a seq-long cache
    base = 2 * n_active * batch
    attn = 0
    for l in range(cfg.n_layers):
        w = int(cfg.windows[l])
        eff = seq if w == 0 else min(seq, w)
        attn += 4 * batch * cfg.n_heads * cfg.head_dim * eff
    return base + attn


GROUP_TOKENS = 4096  # GShard dispatch group size (capacity = 4096*k/E*cf)


def _lm_apply_shardings(cfg, mesh, kind, tokens: int):
    """Inject activation/MoE sharding constraints appropriate to mesh."""
    dp = _dp(mesh)
    upd = {}
    if kind in ("train", "prefill"):
        upd["act_spec"] = P(dp, "model", None)   # Megatron SP on seq
        upd["remat"] = "full" if kind == "train" else "none"
        # online-softmax KV-chunked attention is the shipped default: the
        # materialized-score path blows the 32k-prefill memory budget
        # (§Perf ablation 'materialized_attn')
        upd["attn_impl"] = "chunked"
    if cfg.moe is not None:
        # GShard groups of ~4k tokens: per-token dispatch cost E*C*D stays
        # ~1x the expert FFN cost (C grows with group size, so per-shard
        # groups would blow the one-hot einsums up ~Tg/4096x -- measured,
        # see EXPERIMENTS.md §Perf iteration log).  Groups stay a multiple
        # of the dp extent so each shard owns whole groups.
        n_dp = _dp_size(mesh)
        n_groups = max(1, tokens // GROUP_TOKENS)
        if n_groups % n_dp != 0 or tokens % n_groups != 0:
            n_groups = n_dp if tokens % n_dp == 0 else 1
        if kind == "decode":
            n_groups = 1
        moe = dataclasses.replace(
            cfg.moe,
            n_groups=n_groups,
            disp_spec=P(dp, None, "model", None),
            expert_spec=P("model", dp, None, None))
        upd["moe"] = moe
    return dataclasses.replace(cfg, **upd)


def apply_overrides(cfg, overrides):
    """dataclasses.replace with dotted 'moe.*' routing (hillclimb knob)."""
    if not overrides:
        return cfg
    moe_over = {k[4:]: v for k, v in overrides.items()
                if k.startswith("moe.")}
    top = {k: v for k, v in overrides.items() if "." not in k}
    if moe_over and getattr(cfg, "moe", None) is not None:
        top["moe"] = dataclasses.replace(cfg.moe, **moe_over)
    return dataclasses.replace(cfg, **top)


def build_lm(arch_mod, shape_name: str, shape: dict, mesh,
             layers_override=None, overrides=None):
    cfg = arch_mod.config()
    kind = shape['kind']
    tokens = shape["global_batch"] * (shape["seq"] if kind != "decode"
                                      else 1)
    cfg = _lm_apply_shardings(cfg, mesh, kind, tokens)
    cfg = apply_overrides(cfg, overrides)
    if layers_override is not None:
        # FLOP-metering variant: unrolled K-layer twin of the same cell
        # (XLA cost analysis counts a while body once; see dryrun.py)
        cfg = dataclasses.replace(cfg, n_layers=layers_override,
                                  scan_unroll=True)
    seq, batch = shape["seq"], shape["global_batch"]

    params_sds = jax.eval_shape(lambda: tf.init(jax.random.PRNGKey(0), cfg))
    pspecs = partition.lm_param_specs(cfg, mesh)
    dp = _dp(mesh)
    meta = {"model_flops": lm_model_flops(cfg, kind, batch, seq),
            "tokens": batch * (seq if kind != "decode" else 1),
            "params": cfg.n_params(), "active_params": cfg.n_active_params()}

    if kind == "train":
        opt_sds = jax.eval_shape(optimizer.init, params_sds)
        ospecs = partition.opt_state_specs(pspecs)
        bspecs = partition.lm_batch_specs(mesh)
        batch_sds = {"tokens": _sds((batch, seq), jnp.int32),
                     "labels": _sds((batch, seq), jnp.int32)}

        def train_step(params, opt_state, b):
            (loss, _), grads = jax.value_and_grad(
                lambda p: tf.loss_fn(p, b, cfg), has_aux=True)(params)
            params, opt_state, _ = optimizer.update(
                grads, opt_state, params, OPT_CFG)
            return params, opt_state, loss

        return StepBundle(
            f"{cfg.name}:{shape_name}", train_step,
            (params_sds, opt_sds, batch_sds),
            (_ns(mesh, pspecs), _ns(mesh, ospecs), _ns(mesh, bspecs)),
            (_ns(mesh, pspecs), _ns(mesh, ospecs),
             NamedSharding(mesh, P())),
            meta, donate=(0, 1))

    cache_specs = partition.lm_cache_specs(cfg, mesh, batch)
    if kind == "prefill":
        toks_sds = _sds((batch, seq), jnp.int32)

        def prefill_step(params, toks):
            return tf.prefill(params, toks, cfg, cache_len=seq)

        cache_out = {"k": cache_specs["k"], "v": cache_specs["v"],
                     "pos": P()}
        return StepBundle(
            f"{cfg.name}:{shape_name}", prefill_step,
            (params_sds, toks_sds),
            (_ns(mesh, pspecs), NamedSharding(mesh, P(dp, None))),
            (_ns(mesh, cache_out),
             NamedSharding(mesh, P(dp, "model"))),
            meta)

    # decode: serve_step = one new token against a seq-long KV cache
    kv_shape = (cfg.n_layers, batch, seq, cfg.n_kv_heads, cfg.head_dim)
    cache_sds = {"k": _sds(kv_shape, cfg.dtype),
                 "v": _sds(kv_shape, cfg.dtype),
                 "pos": _sds((), jnp.int32)}
    tok_sds = _sds((batch,), jnp.int32)

    def decode(params, cache, tok):
        return tf.decode_step(params, cache, tok, cfg)

    bax = dp if (batch % _dp_size(mesh) == 0 and batch >= _dp_size(mesh)) \
        else None
    tok_spec = P(bax)
    logits_spec = P(bax, "model" if cfg.vocab % mesh.shape["model"] == 0
                    else None)
    return StepBundle(
        f"{cfg.name}:{shape_name}", decode,
        (params_sds, cache_sds, tok_sds),
        (_ns(mesh, pspecs), _ns(mesh, cache_specs),
         NamedSharding(mesh, tok_spec)),
        (NamedSharding(mesh, logits_spec), _ns(mesh, cache_specs)),
        meta, donate=(1,))


# ------------------------------------------------------------------ GNN ---

def gnn_model_flops(arch: str, cfg, n_nodes: int, n_edges: int) -> int:
    """Analytic per-step useful FLOPs (fwd+bwd ~ 3x fwd), per family."""
    c = cfg.d_hidden
    if arch == "gatedgcn":
        fwd = n_edges * (3 * 2 * c * c) + n_nodes * (2 * 2 * c * c)
        fwd *= cfg.n_layers
    elif arch == "egnn":
        fwd = n_edges * (2 * (2 * c + 1) * c + 2 * c * c + 2 * c * c) + \
            n_nodes * (2 * 2 * c * c)
        fwd *= cfg.n_layers
    else:  # nequip / mace: radial MLP + per-path TP + mixing
        n_paths = 15 if cfg.l_max >= 2 else (4 if cfg.l_max == 1 else 1)
        tp_cost = n_edges * n_paths * c * 18     # avg contraction cost
        radial = n_edges * 2 * (cfg.n_rbf * 32 + 32 * n_paths * c)
        mix = n_nodes * (cfg.l_max + 1) * 2 * c * c * 9
        fwd = (tp_cost + radial + mix) * cfg.n_layers
        if arch == "mace":
            fwd += cfg.n_layers * n_nodes * 2 * n_paths * c * 18  # B-products
    return 3 * fwd


def build_gnn(arch: str, arch_mod, shape_name: str, shape: dict, mesh,
              overrides=None):
    model = arch_mod.MODULE
    dp = _dp(mesh)
    n_model = mesh.shape["model"]
    n_dp = _dp_size(mesh)

    if shape["kind"] == "train_mol":
        n_graphs = shape["batch"]
        nn, ne = shape["n_nodes"], shape["n_edges"]
        n_nodes = n_graphs * nn
        n_edges = n_graphs * ne
        task, n_classes, d_feat = "energy", 2, shape["d_feat"]
    else:
        if shape["kind"] == "train_sampled":
            n_nodes, n_edges = gshapes.sampled_block_dims(shape)
        else:
            n_nodes, n_edges = shape["n_nodes"], shape["n_edges"]
        n_graphs = 1
        task, n_classes, d_feat = \
            "node_class", shape["n_classes"], shape["d_feat"]

    pad_n = _pad_to(n_nodes, n_dp * n_model)  # node arrays shard all chips
    pad_e = _pad_to(n_edges, n_dp * n_model)  # safe for either edge axis
    # scan_unroll: GNN layer counts are small enough to unroll outright,
    # which makes cost_analysis FLOPs exact (no while-body undercount).
    # edge/node constraints keep the big per-edge message tensors sharded
    # (unconstrained, GSPMD replicated them: 447 GiB/device on mace/ogb);
    # remat bounds saved activations across layers.
    # node-sharding default measured in §Perf (gnn_minibatch ladder):
    # small/minibatch graphs scatter cheapest into 'model'-only shards
    # (4x lower collective term than all-axis or replicated); only
    # 10^6+-node full-batch graphs need every axis for residency.
    if pad_n > 2 ** 20:
        node_ax = partition.gnn_node_axis(mesh, pad_n)
    else:
        node_ax = "model" if pad_n % n_model == 0 else None
    kw = dict(task=task, n_classes=n_classes, d_feat=d_feat,
              n_graphs=n_graphs, scan_unroll=True,
              edge_ax=dp, node_ax=node_ax, remat=True)
    if arch in ("nequip", "mace") and pad_e > 2 ** 22:
        # stream edges in 32 chunks: l<=2 message tensors never exceed
        # chunk x C x 9 floats (ogb_products would otherwise need
        # hundreds of GiB per device -- measured)
        kw["edge_chunk"] = pad_e // 32
    kw.update(overrides or {})
    node_ax = kw["node_ax"]  # overrides steer input sharding too
    cfg = arch_mod.config(**kw)

    batch_sds = {
        "src": _sds((pad_e,), jnp.int32), "dst": _sds((pad_e,), jnp.int32),
        "edge_mask": _sds((pad_e,), jnp.bool_),
        "node_mask": _sds((pad_n,), jnp.float32),
        "graph_id": _sds((pad_n,), jnp.int32),
        "x": _sds((pad_n, d_feat), jnp.float32),
        "pos": _sds((pad_n, 3), jnp.float32),
    }
    if task == "node_class":
        batch_sds["labels"] = _sds((pad_n,), jnp.int32)
    else:
        batch_sds["energy"] = _sds((n_graphs,), jnp.float32)
        batch_sds["forces"] = _sds((pad_n, 3), jnp.float32)

    params_sds = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0), cfg))
    pspecs = partition.gnn_param_specs(params_sds)
    ospecs = partition.opt_state_specs(pspecs)
    all_bspecs = partition.gnn_batch_specs(mesh, pad_n, pad_e,
                                           node_ax=node_ax)
    bspecs = {k: all_bspecs[k] for k in batch_sds}
    opt_sds = jax.eval_shape(optimizer.init, params_sds)

    def train_step(params, opt_state, b):
        (loss, _), grads = jax.value_and_grad(
            lambda p: model.loss_fn(p, b, cfg), has_aux=True)(params)
        params, opt_state, _ = optimizer.update(
            grads, opt_state, params, OPT_CFG)
        return params, opt_state, loss

    meta = {"model_flops": gnn_model_flops(arch, cfg, pad_n, pad_e),
            "nodes": pad_n, "edges": pad_e,
            "edge_chunks": (pad_e // kw["edge_chunk"])
            if kw.get("edge_chunk") else 1,
            "padded": (pad_n != n_nodes or pad_e != n_edges)}
    return StepBundle(
        f"{arch}:{shape_name}", train_step,
        (params_sds, opt_sds, batch_sds),
        (_ns(mesh, pspecs), _ns(mesh, ospecs), _ns(mesh, bspecs)),
        (_ns(mesh, pspecs), _ns(mesh, ospecs), NamedSharding(mesh, P())),
        meta, donate=(0, 1))


# --------------------------------------------------------------- recsys ---

def mind_model_flops(cfg, kind: str, batch: int, n_cand: int = 0) -> int:
    d, l, k = cfg.embed_dim, cfg.seq_len, cfg.n_interests
    routing = 2 * batch * l * d * d + \
        cfg.capsule_iters * (2 * batch * l * k * d * 2)
    profile = 2 * batch * cfg.profile_len * d
    fuse = 2 * batch * k * (2 * d) * d
    fwd = routing + profile + fuse
    if kind == "train":
        label_att = 2 * batch * k * d * 2
        softmax = 2 * batch * (cfg.n_neg + 1) * d
        return 3 * (fwd + label_att + softmax)
    return fwd + 2 * batch * k * n_cand * d


def build_mind(arch_mod, shape_name: str, shape: dict, mesh):
    from repro.models.recsys import mind as model
    cfg = arch_mod.config(scan_unroll=True)  # 3 routing iters: unroll
    batch = shape["batch"]
    dp = _dp(mesh)
    b_sds = {
        "behavior": _sds((batch, cfg.seq_len), jnp.int32),
        "profile": _sds((batch, cfg.profile_len), jnp.int32),
    }
    params_sds = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0), cfg))
    pspecs = partition.mind_param_specs(cfg, mesh)

    if shape["kind"] == "train":
        b_sds["target"] = _sds((batch,), jnp.int32)
        b_sds["negatives"] = _sds((cfg.n_neg,), jnp.int32)
        bspecs = partition.mind_batch_specs(mesh, batch)
        opt_sds = jax.eval_shape(optimizer.init, params_sds)
        ospecs = partition.opt_state_specs(pspecs)

        def train_step(params, opt_state, b):
            (loss, _), grads = jax.value_and_grad(
                lambda p: model.loss_fn(p, b, cfg), has_aux=True)(params)
            params, opt_state, _ = optimizer.update(
                grads, opt_state, params, OPT_CFG)
            return params, opt_state, loss

        meta = {"model_flops": mind_model_flops(cfg, "train", batch),
                "items": batch}
        return StepBundle(
            f"mind:{shape_name}", train_step,
            (params_sds, opt_sds, b_sds),
            (_ns(mesh, pspecs), _ns(mesh, ospecs), _ns(mesh, bspecs)),
            (_ns(mesh, pspecs), _ns(mesh, ospecs),
             NamedSharding(mesh, P())),
            meta, donate=(0, 1))

    n_cand = shape["n_cand"]
    b_sds["candidates"] = _sds((batch, n_cand), jnp.int32)
    bspecs = partition.mind_batch_specs(mesh, batch, with_candidates=True,
                                        cand=n_cand)
    bspecs = {k: bspecs[k] for k in b_sds}  # serve has no target/negatives

    def serve_step(params, b):
        return model.serve_score(params, b, cfg)

    cax = "model" if n_cand % mesh.shape["model"] == 0 else None
    out_spec = P(bspecs["behavior"][0], cax)
    meta = {"model_flops": mind_model_flops(cfg, "serve", batch, n_cand),
            "items": batch * max(n_cand, 1)}
    return StepBundle(
        f"mind:{shape_name}", serve_step,
        (params_sds, b_sds),
        (_ns(mesh, pspecs), _ns(mesh, bspecs)),
        NamedSharding(mesh, out_spec),
        meta)


# ---------------------------------------------------------------- smscc ---

def build_smscc(arch_mod, shape_name: str, shape: dict, mesh,
                overrides=None):
    from repro.core import dynamic, graph_state as gs, community
    cfg = arch_mod.config(n_vertices=shape["n_vertices"],
                          edge_capacity=shape["edge_capacity"],
                          **(overrides or {}))
    state_sds = jax.eval_shape(lambda: gs.empty(cfg))
    sspecs = partition.smscc_state_specs(mesh)
    dp = _dp(mesh)
    b = shape["batch"]
    # PER-ROUND useful work: one edge-parallel sweep (compare+scatter per
    # edge slot); queries are pure gathers (one compare per query).
    if shape["kind"] == "update":
        meta = {"model_flops": 2 * cfg.edge_capacity, "ops": b,
                "flops_unit": "per fixpoint round"}
    else:
        meta = {"model_flops": 2 * b, "ops": b}

    if shape["kind"] == "update":
        ops_sds = dynamic.OpBatch(kind=_sds((b,), jnp.int32),
                                  u=_sds((b,), jnp.int32),
                                  v=_sds((b,), jnp.int32))
        ospecs = partition.smscc_ops_specs(mesh)

        def update_step(state, ops):
            return dynamic.apply_batch(state, ops, cfg)

        return StepBundle(
            f"smscc:{shape_name}", update_step,
            (state_sds, ops_sds),
            (_ns(mesh, sspecs), _ns(mesh, ospecs)),
            (_ns(mesh, sspecs), NamedSharding(mesh, P(dp))),
            meta, donate=(0,))

    q_sds = (_sds((b,), jnp.int32), _sds((b,), jnp.int32))

    def query_step(state, u, v):
        return community.check_scc(state, u, v)

    return StepBundle(
        f"smscc:{shape_name}", query_step,
        (state_sds,) + q_sds,
        (_ns(mesh, sspecs), NamedSharding(mesh, P(dp)),
         NamedSharding(mesh, P(dp))),
        NamedSharding(mesh, P(dp)),
        meta)


# ---------------------------------------------------------------- entry ---

def build(arch: str, shape_name: str, mesh, lm_layers=None,
          overrides=None) -> Optional[StepBundle]:
    mod = cfg_registry.get(arch)
    shape = mod.SHAPES[shape_name]
    if shape.get("skip"):
        return None
    if mod.FAMILY == "lm":
        return build_lm(mod, shape_name, shape, mesh,
                        layers_override=lm_layers, overrides=overrides)
    if mod.FAMILY == "gnn":
        return build_gnn(arch.replace("-", "_"), mod, shape_name, shape,
                         mesh, overrides=overrides)
    if mod.FAMILY == "recsys":
        return build_mind(mod, shape_name, shape, mesh)
    if mod.FAMILY == "smscc":
        return build_smscc(mod, shape_name, shape, mesh,
                           overrides=overrides)
    raise ValueError(mod.FAMILY)
