"""Production mesh builders.

Single pod: (data=16, model=16) = 256 chips (one v5e pod's 16x16 torus).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is the
outer data-parallel ring (gradient/label reductions only -- the only
cross-pod traffic), 'model' stays intra-pod where ICI is fastest.

Functions, not module constants: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """The batch-sharding axes of a mesh ('pod' composes with 'data')."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
