"""Production training driver.

    python -m repro.launch.train --arch <id> [--smoke] [--steps N]
                                 [--ckpt-dir DIR] [--mesh host|prod]

With --smoke (default on CPU) the arch's reduced config trains for real;
with the production mesh this is the same code path the dry-run lowers --
the step function, shardings and data pipeline are shared
(launch/steps.py), so what compiles in the dry-run is what trains here.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro import configs
from repro.data import pipeline
from repro.launch import mesh as mesh_lib
from repro.optim import optimizer
from repro.train import trainer


def _lm_setup(mod, smoke: bool):
    from repro.models import transformer as tf
    cfg = mod.smoke_config() if smoke else mod.config()
    params = tf.init(jax.random.PRNGKey(0), cfg)
    batch, seq = (16, 64) if smoke else (256, 4096)

    def loss_fn(p, b):
        return tf.loss_fn(p, b, cfg)

    def data_fn(step):
        return pipeline.lm_batch(cfg.vocab, batch, seq, step=step)

    return cfg, params, loss_fn, data_fn


def _gnn_setup(mod, smoke: bool):
    model = mod.MODULE
    cfg = mod.smoke_config(task="node_class", n_classes=7) if smoke \
        else mod.config(task="node_class", n_classes=7, d_feat=64)
    graph = pipeline.node_class_graph(
        200 if smoke else 4096, 1000 if smoke else 32768,
        cfg.d_feat, cfg.n_classes, seed=0)
    params = model.init(jax.random.PRNGKey(0), cfg)

    def loss_fn(p, b):
        return model.loss_fn(p, b, cfg)

    return cfg, params, loss_fn, lambda step: graph


def _mind_setup(mod, smoke: bool):
    model = mod.MODULE
    cfg = mod.smoke_config() if smoke else mod.config()
    params = model.init(jax.random.PRNGKey(0), cfg)
    batch = 64 if smoke else 65536

    def loss_fn(p, b):
        return model.loss_fn(p, b, cfg)

    def data_fn(step):
        return pipeline.mind_batch(cfg.n_items, batch, cfg.seq_len,
                                   cfg.profile_vocab, cfg.profile_len,
                                   cfg.n_neg, step=step)

    return cfg, params, loss_fn, data_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--smoke", action="store_true", default=None)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()
    smoke = args.smoke if args.smoke is not None else \
        jax.default_backend() == "cpu"

    mod = configs.get(args.arch)
    if mod.FAMILY == "lm":
        cfg, params, loss_fn, data_fn = _lm_setup(mod, smoke)
    elif mod.FAMILY == "gnn":
        cfg, params, loss_fn, data_fn = _gnn_setup(mod, smoke)
    elif mod.FAMILY == "recsys":
        cfg, params, loss_fn, data_fn = _mind_setup(mod, smoke)
    else:
        raise SystemExit("use examples/dynamic_scc_serving.py for smscc")

    t = trainer.Trainer(
        loss_fn, params,
        optimizer.AdamWConfig(lr=1e-3, warmup_steps=10,
                              total_steps=args.steps),
        trainer.TrainerConfig(
            total_steps=args.steps, ckpt_dir=args.ckpt_dir,
            ckpt_every=max(args.steps // 4, 1), log_every=10,
            grad_compression=args.compress_grads),
        data_fn)
    log = t.run()
    for step, m in log:
        print(f"step {step:4d}  loss {m['loss']:.4f}")
    print(f"done: {len(t.step_times)} steps, "
          f"median {sorted(t.step_times)[len(t.step_times)//2]*1e3:.0f}"
          f"ms/step, stragglers={t.straggler_events}")


if __name__ == "__main__":
    main()
