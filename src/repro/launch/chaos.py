"""Chaos soak: recorded op stream under a seeded fault plan.

The durability suite proves recovery from a *single* crash point; this
driver holds the serving stack's failure-domain contract under a whole
schedule of faults injected **while serving** (:mod:`repro.fault`):
WAL write/fsync faults (EIO / ENOSPC / torn records) that flip the
writer DEGRADED mid-stream, and replica kills that force query failover
and supervisor restarts.  One soak run asserts, for a seeded
:class:`~repro.fault.inject.FaultPlan`:

* **no acked op is ever lost** -- the writer's final state is
  bit-identical to an in-memory oracle replaying exactly the
  acknowledged chunks, and so is a cold :meth:`DurableService.open` of
  the store afterwards (a chunk the client saw fail was never applied;
  a chunk the client saw ack survives every injected fault);
* **every failure is typed** -- a client only ever observes
  :class:`~repro.fault.errors.FaultError` subclasses (``Unavailable``,
  ``DeadlineExceeded``, ...); any bare exception is a violation;
* **availability never reaches zero while a replica is healthy** -- a
  per-round query probe through the :class:`ReplicaSet` must keep
  answering (transparent failover + supervisor restarts) whenever at
  least one replica is routable;
* **the store heals under fire** -- the plan's fault windows are
  finite, so the writer's rate-limited recovery probes must re-attach
  the WAL and return to HEALTHY *while the plan is still armed*.

Determinism: the fault *schedule* is a pure function of (seed,
profile) and fires on call/generation counters, not wall clock, so a
failing seed reproduces (thread interleavings still vary, but every
assertion above is interleaving-independent).

``--failover`` runs the writer-loss soak (:func:`run_writer_failover`):
SIGKILL-equivalent crash of the *leased* writer mid-stream, after which
the :class:`ReplicaSet` supervisor must promote a replica (lease
takeover bumps the WAL epoch and fences the dead writer's log), the
client must reroute on ``NotLeader`` and keep acking, no acked op may
be lost across the handoff (oracle replay bit-identical), and a
resurrected old-epoch writer must be refused with **nothing written**
(no split brain).  ``--tenant-soak`` holds the same zero-acked-loss /
typed-errors-only contract per tenant while disk faults bite the
per-tenant WAL dirs of a :class:`MultiTenantService`.

``--availability`` runs the companion windowed bench
(:func:`run_availability`): closed-loop query throughput in a steady
window vs a window where a replica is killed and supervisor-restarted,
then closed-loop *write* throughput in a steady window vs a window
where the leased writer is crashed and a replica promoted;
``benchmarks/bench_stream.py`` records both ratios and
``scripts/ci.sh`` gates them.
"""
from __future__ import annotations

import argparse
import itertools
import os
import sys
import tempfile
import time

__all__ = ["run_chaos_soak", "run_writer_failover", "run_tenant_soak",
           "run_availability"]


def run_chaos_soak(directory: str, *, seed: int = 0,
                   profile: str = "mixed", n_chunks: int = 40,
                   chunk: int = 16, nv: int = 192, replicas: int = 2,
                   poll_interval: float = 0.02, n_queries: int = 8,
                   deadline_s: float = 8.0) -> dict:
    """One soak run; returns a report dict whose ``violations`` list is
    empty iff every contract held (the driver never raises for a fault
    outcome -- only for harness bugs)."""
    import jax
    import numpy as np

    from repro.api import GraphClient, SameSCC
    from repro.api.ops import encode_updates
    from repro.ckpt.durable import DurableService, HEALTHY
    from repro.core import graph_state as gs
    from repro.core.replicas import ReplicaSet
    from repro.core.service import SCCService
    from repro.fault import errors as fault_errors
    from repro.fault.inject import FaultPlan, fire_kills, injected
    from repro.launch.replica import _writer_config
    from repro.launch.stream import typed_op_stream

    cfg = _writer_config(nv, edge_capacity=2048)
    writer = DurableService(
        cfg, directory, state=gs.all_singletons(cfg), buckets=(chunk,),
        proactive_grow=True, sync_every=1, segment_bytes=16 << 10,
        snapshot_every=8, snapshot_keep=4, recover_probe_s=0.01)
    rset = ReplicaSet(directory, replicas, query_buckets=(n_queries,),
                      poll_interval=poll_interval, supervise=True,
                      health_check_s=0.05)
    wclient = GraphClient(writer, deadline_s=deadline_s, max_retries=64,
                          backoff_base_s=0.002, backoff_cap_s=0.05)
    rclient = GraphClient(writer, broker=rset, deadline_s=deadline_s,
                          max_retries=16, backoff_base_s=0.002,
                          backoff_cap_s=0.05)
    rng = np.random.default_rng(seed + 101)

    acked: list = []  # the ledger the oracle replays
    failed: list = []
    violations: list = []

    # warm the compiled update/query paths off the fault clock -- the
    # warm chunk is acked, so it joins the ledger like any other
    warm = typed_op_stream(nv, chunk, step=1 << 20, add_frac=0.7,
                           seed=seed)
    wclient.submit_many(warm)
    acked.append(warm)
    rclient.submit_many([SameSCC(0, 1)] * n_queries)

    plan = FaultPlan.generate(seed, profile, replicas=replicas,
                              horizon_gens=n_chunks)
    probe_ok = probe_fail = 0
    with injected(plan):
        for step in range(n_chunks):
            ops = typed_op_stream(nv, chunk, step=step, add_frac=0.7,
                                  seed=seed)
            try:
                wclient.submit_many(ops)
                acked.append(ops)
            except fault_errors.FaultError as e:
                failed.append(type(e).__name__)  # typed reject: fine
            except Exception as e:  # contract breach: must be typed
                failed.append(type(e).__name__)
                violations.append(
                    f"untyped writer failure at step {step}: "
                    f"{type(e).__name__}: {e}")
            fire_kills(plan, rset, writer.gen)
            qu = rng.integers(0, nv, n_queries)
            qv = rng.integers(0, nv, n_queries)
            try:
                rclient.submit_many([SameSCC(int(a), int(b))
                                     for a, b in zip(qu, qv)])
                probe_ok += 1
            except fault_errors.FaultError:
                probe_fail += 1
                if rset.healthy_replicas:
                    violations.append(
                        f"query probe failed at step {step} with "
                        f"{len(rset.healthy_replicas)} healthy replicas")
            except Exception as e:
                probe_fail += 1
                violations.append(
                    f"untyped reader failure at step {step}: "
                    f"{type(e).__name__}: {e}")
        # heal under fire: fault windows are finite counters, so
        # repeated probes must re-attach the WAL with the plan armed
        heal_deadline = time.monotonic() + 10.0
        while writer.health != HEALTHY and \
                time.monotonic() < heal_deadline:
            writer.probe_recovery()
            time.sleep(0.01)
        if writer.health != HEALTHY:
            violations.append(
                "store did not recover after the fault window "
                f"(stuck on: {writer._degraded_error})")

    final_gen = writer.gen
    final_state = writer.state
    writer_stats = writer.stats()
    try:
        rset.wait_all_for_gen(final_gen, timeout=10.0)
        rs_stats = rset.stats()
        rset.stop()
    except Exception as e:
        rs_stats = {"failovers": -1, "restarts": -1}
        violations.append(
            f"replica teardown raised: {type(e).__name__}: {e}")
    writer.close()

    # oracle: replay exactly the acked chunks through a plain in-memory
    # service with the writer's decision knobs -- acked ops and nothing
    # else must reproduce the writer bit-for-bit
    oracle = SCCService(cfg, state=gs.all_singletons(cfg),
                        buckets=(chunk,), proactive_grow=True)
    for ops in acked:
        kind, u, v = encode_updates(ops)
        oracle._apply_ops(kind, u, v)
    if oracle.gen != final_gen:
        violations.append(
            f"acked-op oracle at gen {oracle.gen}, writer at "
            f"{final_gen}: an op was lost or double-applied")
    else:
        for a, b in zip(jax.tree_util.tree_leaves(final_state),
                        jax.tree_util.tree_leaves(oracle.state)):
            if not np.array_equal(np.asarray(a), np.asarray(b)):
                violations.append(
                    "writer state diverged from the acked-op oracle")
                break

    # cold disk recovery must land on the same state (plan disarmed:
    # this checks what the faults left on disk, not new faults)
    reopened = DurableService.open(directory, snapshot_every=0)
    if reopened.gen != oracle.gen:
        violations.append(
            f"disk recovery at gen {reopened.gen}, oracle at "
            f"{oracle.gen}: durability lost an acked op")
    else:
        for a, b in zip(jax.tree_util.tree_leaves(reopened.state),
                        jax.tree_util.tree_leaves(oracle.state)):
            if not np.array_equal(np.asarray(a), np.asarray(b)):
                violations.append(
                    "disk recovery diverged from the acked-op oracle")
                break
    reopened.close()

    return {
        "seed": seed, "profile": profile,
        "chunks": n_chunks + 1, "acked": len(acked),
        "failed": failed, "gen": final_gen,
        "fs_faults_planned": len(plan.fs),
        "fs_triggered": len(plan.triggered),
        "kills_planned": len(plan.kills),
        "kills_fired": len(plan._fired_kills),
        "probe_ok": probe_ok, "probe_fail": probe_fail,
        "degraded": writer_stats["degraded_count"],
        "recovered": writer_stats["recovered_count"],
        "rejects": writer_stats["unavailable_rejects"],
        "client_retries": wclient.retries + rclient.retries,
        "failovers": rs_stats["failovers"],
        "restarts": rs_stats["restarts"],
        "violations": violations,
    }


def run_writer_failover(directory: str, *, seed: int = 0,
                        n_chunks: int = 24, chunk: int = 16,
                        nv: int = 192, replicas: int = 2,
                        lease_ttl_s: float = 0.2,
                        poll_interval: float = 0.02,
                        deadline_s: float = 20.0) -> dict:
    """Writer-loss soak: crash the leased writer mid-stream and hold the
    high-availability contract end to end.

    The writer holds a :class:`~repro.ha.lease.FileLease`; its WAL epoch
    *is* the fencing token.  ``crash()`` is the in-process analogue of
    ``kill -9``: the heartbeat stops dead, nothing is released.  The
    supervisor must then notice the stale lease, promote the most
    caught-up replica (takeover bumps the epoch and fences the old log),
    and the client -- rerouted via ``leader_resolver`` on ``NotLeader``
    -- must keep acking ops.  Violations:

    * no promotion, or zero writes acked after the kill;
    * the promoted leader's epoch did not exceed the dead writer's;
    * an untyped client error during the handoff;
    * oracle replay of exactly the acked chunks (old leader's and new
      leader's alike) differs from the final leader state, or from a
      cold :meth:`DurableService.open` of the store;
    * a resurrected writer at the dead epoch is *not* refused, or the
      refusal left any byte behind in the WAL directory.
    """
    import random as _random

    import jax
    import numpy as np

    from repro.api import GraphClient
    from repro.api.ops import encode_updates
    from repro.ckpt import oplog
    from repro.ckpt.durable import DurableService, wal_dir
    from repro.core import graph_state as gs
    from repro.core.replicas import ReplicaSet
    from repro.core.service import SCCService
    from repro.fault import errors as fault_errors
    from repro.ha.lease import FileLease
    from repro.launch.replica import _writer_config
    from repro.launch.stream import typed_op_stream

    cfg = _writer_config(nv, edge_capacity=2048)
    lease = FileLease(directory, owner=f"writer-{os.getpid()}",
                      ttl_s=lease_ttl_s)
    assert lease.try_acquire(), "fresh store: first acquire cannot lose"
    writer = DurableService(
        cfg, directory, state=gs.all_singletons(cfg), buckets=(chunk,),
        proactive_grow=True, sync_every=1, segment_bytes=16 << 10,
        snapshot_every=8, snapshot_keep=4, lease=lease)
    rset = ReplicaSet(directory, replicas, query_buckets=(8,),
                      poll_interval=poll_interval, supervise=True,
                      health_check_s=0.05, promote_on_writer_loss=True,
                      lease_ttl_s=lease_ttl_s,
                      writer_kwargs=dict(sync_every=1,
                                         segment_bytes=16 << 10,
                                         snapshot_every=0))
    client = GraphClient(writer, deadline_s=deadline_s, max_retries=400,
                         backoff_base_s=0.002, backoff_cap_s=0.05,
                         rng=_random.Random(seed),
                         leader_resolver=lambda: rset.leader)

    acked: list = []
    failed: list = []
    violations: list = []

    warm = typed_op_stream(nv, chunk, step=1 << 20, add_frac=0.7,
                           seed=seed)
    client.submit_many(warm)
    acked.append(warm)

    kill_step = max(2, n_chunks // 3)
    old_epoch = writer.epoch
    post_kill_acked = 0
    for step in range(n_chunks):
        if step == kill_step:
            writer.crash()  # kill -9: heartbeat stops, nothing released
        ops = typed_op_stream(nv, chunk, step=step, add_frac=0.7,
                              seed=seed)
        try:
            client.submit_many(ops)
            acked.append(ops)
            if step >= kill_step:
                post_kill_acked += 1
        except fault_errors.FaultError as e:
            failed.append(type(e).__name__)  # typed reject: fine
        except Exception as e:  # contract breach: must be typed
            failed.append(type(e).__name__)
            violations.append(
                f"untyped client failure at step {step}: "
                f"{type(e).__name__}: {e}")

    leader = rset.leader
    if rset.promotions < 1 or leader is None:
        violations.append(
            f"writer loss never promoted a replica (promotions="
            f"{rset.promotions}, last_error={rset.last_promote_error})")
    if post_kill_acked == 0:
        violations.append("no write was acked after the writer kill: "
                          "write availability reached zero")
    if leader is not None and leader.epoch <= old_epoch:
        violations.append(
            f"promoted leader epoch {leader.epoch} does not fence the "
            f"dead writer's epoch {old_epoch}")

    final = leader if leader is not None else writer
    final_gen, final_state = final.gen, final.state
    writer_stats = writer.stats()
    rs_stats = rset.stats()

    # split-brain probe: resurrect the dead writer at its old epoch --
    # the fence must refuse it with nothing written
    wdir = wal_dir(directory)

    def wal_listing():
        return sorted((name, os.path.getsize(os.path.join(wdir, name)))
                      for name in os.listdir(wdir))

    before = wal_listing()
    try:
        zombie = oplog.OpLogWriter(wdir, start_gen=final_gen,
                                   epoch=old_epoch)
        zombie.close()
        violations.append(
            "resurrected old-epoch writer was NOT fenced: split brain")
    except fault_errors.Fenced:
        pass
    if wal_listing() != before:
        violations.append("the fenced resurrect probe left bytes "
                          "behind in the WAL directory")

    # oracle: exactly the acked chunks -- across both leaders -- must
    # reproduce the final leader bit-for-bit (exactly-once handoff)
    oracle = SCCService(cfg, state=gs.all_singletons(cfg),
                        buckets=(chunk,), proactive_grow=True)
    for ops in acked:
        kind, u, v = encode_updates(ops)
        oracle._apply_ops(kind, u, v)
    if oracle.gen != final_gen:
        violations.append(
            f"acked-op oracle at gen {oracle.gen}, leader at "
            f"{final_gen}: an op was lost or double-applied across "
            f"the handoff")
    else:
        for a, b in zip(jax.tree_util.tree_leaves(final_state),
                        jax.tree_util.tree_leaves(oracle.state)):
            if not np.array_equal(np.asarray(a), np.asarray(b)):
                violations.append(
                    "leader state diverged from the acked-op oracle")
                break

    try:
        rset.stop()  # also closes the promoted leader
    except Exception as e:
        violations.append(
            f"replica teardown raised: {type(e).__name__}: {e}")
    writer.close()

    reopened = DurableService.open(directory, snapshot_every=0)
    if reopened.gen != oracle.gen:
        violations.append(
            f"disk recovery at gen {reopened.gen}, oracle at "
            f"{oracle.gen}: durability lost an acked op")
    else:
        for a, b in zip(jax.tree_util.tree_leaves(reopened.state),
                        jax.tree_util.tree_leaves(oracle.state)):
            if not np.array_equal(np.asarray(a), np.asarray(b)):
                violations.append(
                    "disk recovery diverged from the acked-op oracle")
                break
    if reopened.epoch <= old_epoch:
        violations.append(
            f"cold reopen adopted epoch {reopened.epoch}, expected a "
            f"fenced epoch above {old_epoch}")
    reopened.close()

    return {
        "seed": seed, "chunks": n_chunks + 1, "acked": len(acked),
        "failed": failed, "gen": final_gen,
        "old_epoch": old_epoch, "new_epoch": final.epoch,
        "post_kill_acked": post_kill_acked,
        "promotions": rs_stats["promotions"],
        "promote_failures": rs_stats["promote_failures"],
        "notleader_rejects": writer_stats["notleader_rejects"],
        "client_retries": client.retries,
        "client_reroutes": client.stats()["client_reroutes"],
        "violations": violations,
    }


def run_tenant_soak(directory: str, *, seed: int = 0, tenants: int = 3,
                    n_rounds: int = 20, chunk: int = 8, nv: int = 96,
                    deadline_s: float = 8.0) -> dict:
    """Per-tenant WAL fault soak: a seeded ``disk-fault`` plan bites the
    per-tenant WAL dirs (``<dir>/tenants/<tid>/wal``) of a
    :class:`MultiTenantService` while every tenant streams ops.  Holds,
    *per tenant*: a failed lane is a typed retryable reject (never a
    bare exception, never an ack), the surviving lanes of the same wave
    flush normally, and afterwards both the live tenant state and a cold
    per-tenant :meth:`DurableService.open` are bit-identical to an
    oracle replaying exactly that tenant's acked chunks."""
    import jax
    import numpy as np

    from repro.api.ops import encode_updates
    from repro.ckpt.durable import DurableService
    from repro.core.service import SCCService
    from repro.fault import errors as fault_errors
    from repro.fault.inject import FaultPlan, injected
    from repro.launch.replica import _writer_config
    from repro.launch.stream import typed_op_stream
    from repro.tenancy import MultiTenantService

    cfg = _writer_config(nv, edge_capacity=512)
    knobs = dict(buckets=(chunk,), scan_lengths=(1,))
    mts = MultiTenantService(cfg, directory=directory,
                             tenant_batches=(1, 2, max(2, tenants)),
                             coalesce_ops=tenants * chunk,
                             flush_deadline_s=0.0, wal_sync_every=1,
                             **knobs)
    tids = [mts.create_tenant() for _ in range(tenants)]
    clients = {tid: mts.client(tid, deadline_s=deadline_s,
                               max_retries=64, backoff_base_s=0.002,
                               backoff_cap_s=0.05)
               for tid in tids}
    acked = {tid: [] for tid in tids}
    failed: list = []
    violations: list = []

    for i, tid in enumerate(tids):  # warm off the fault clock
        warm = typed_op_stream(nv, chunk, step=1 << 20, add_frac=0.7,
                               seed=seed + i)
        clients[tid].submit_many(warm)
        acked[tid].append(warm)

    plan = FaultPlan.generate(seed, "disk-fault", horizon_gens=n_rounds)
    with injected(plan):
        for rnd in range(n_rounds):
            for i, tid in enumerate(tids):
                ops = typed_op_stream(nv, chunk, step=rnd, add_frac=0.7,
                                      seed=seed + i)
                try:
                    clients[tid].submit_many(ops)
                    acked[tid].append(ops)
                except fault_errors.FaultError as e:
                    failed.append((tid, type(e).__name__))
                except Exception as e:
                    failed.append((tid, type(e).__name__))
                    violations.append(
                        f"untyped tenant failure round {rnd} tenant "
                        f"{tid}: {type(e).__name__}: {e}")
    mts.flush()
    stats = mts.stats()
    wal_faults = sum(t["wal_faults"]
                     for t in stats["per_tenant"].values())
    live = {tid: (mts._tenant_state(tid), mts.tenant_gen(tid))
            for tid in tids}
    mts.close()

    for i, tid in enumerate(tids):
        oracle = SCCService(cfg, **knobs)
        for ops in acked[tid]:
            kind, u, v = encode_updates(ops)
            oracle._apply_ops(kind, u, v)
        state, gen_live = live[tid]
        if oracle.gen != gen_live:
            violations.append(
                f"tenant {tid} live gen {gen_live} != acked-op oracle "
                f"gen {oracle.gen}: an op was lost or double-applied")
        else:
            for a, b in zip(jax.tree_util.tree_leaves(state),
                            jax.tree_util.tree_leaves(oracle.state)):
                if not np.array_equal(np.asarray(a), np.asarray(b)):
                    violations.append(
                        f"tenant {tid} live state diverged from its "
                        f"acked-op oracle")
                    break
        d = DurableService.open(
            os.path.join(directory, "tenants", tid), snapshot_every=0)
        if d.gen != oracle.gen:
            violations.append(
                f"tenant {tid} disk recovery gen {d.gen} != oracle "
                f"gen {oracle.gen}: durability lost an acked op")
        else:
            for a, b in zip(jax.tree_util.tree_leaves(d.state),
                            jax.tree_util.tree_leaves(oracle.state)):
                if not np.array_equal(np.asarray(a), np.asarray(b)):
                    violations.append(
                        f"tenant {tid} disk recovery diverged from "
                        f"its acked-op oracle")
                    break
        d.close()

    return {
        "seed": seed, "tenants": tenants, "rounds": n_rounds,
        "acked": sum(len(v) for v in acked.values()),
        "failed": failed, "wal_faults": wal_faults,
        "fs_faults_planned": len(plan.fs),
        "fs_triggered": len(plan.triggered),
        "violations": violations,
    }


def run_availability(directory: str | None = None, *,
                     replicas: int = 2, nv: int = 256, chunk: int = 32,
                     preload_chunks: int = 8, n_queries: int = 32,
                     window_s: float = 0.8,
                     write_window_s: float | None = None,
                     lease_ttl_s: float = 0.12,
                     poll_interval: float = 0.02,
                     seed: int = 0) -> dict:
    """Windowed availability bench: closed-loop query throughput in a
    steady window vs a window opened by killing a replica (the
    supervisor restarts it mid-window), then closed-loop *write*
    throughput in a steady window vs a window opened by crashing the
    leased writer (the supervisor promotes a replica mid-window and the
    client reroutes on ``NotLeader``).  A closed-loop caller is
    latency-bound, so the read ratio should stay near 1.0; the write
    ratio pays one lease TTL of dead air and should stay well above
    0.5 for windows comfortably longer than the TTL.  ``ci.sh`` gates
    ``ratio >= 0.5`` and ``write_availability >= 0.5``."""
    import random as _random
    import shutil

    import numpy as np

    from repro.api import GraphClient, SameSCC
    from repro.ckpt.durable import DurableService
    from repro.core import graph_state as gs
    from repro.core.replicas import ReplicaSet
    from repro.fault import errors as fault_errors
    from repro.ha.lease import FileLease
    from repro.launch.replica import _writer_config
    from repro.launch.stream import typed_op_stream

    owns_dir = directory is None
    if owns_dir:
        directory = tempfile.mkdtemp(prefix="scc-avail-")
    if write_window_s is None:
        # promotion costs a lease TTL plus the takeover itself
        # (fence + tail drain + service ctor, ~0.3-0.7s): the window
        # must dwarf that dead air for the ratio to measure steady
        # rerouted throughput, not takeover latency
        write_window_s = max(window_s, 2.0)
    cfg = _writer_config(nv, edge_capacity=2048)
    lease = FileLease(directory, owner=f"writer-{os.getpid()}",
                      ttl_s=lease_ttl_s)
    assert lease.try_acquire(), "fresh store: first acquire cannot lose"
    writer = DurableService(
        cfg, directory, state=gs.all_singletons(cfg), buckets=(chunk,),
        proactive_grow=True, sync_every=1, snapshot_every=0,
        lease=lease)
    rset = ReplicaSet(directory, replicas, query_buckets=(n_queries,),
                      poll_interval=poll_interval, supervise=True,
                      health_check_s=0.05, promote_on_writer_loss=True,
                      lease_ttl_s=lease_ttl_s,
                      writer_kwargs=dict(sync_every=1, snapshot_every=0))
    wclient = GraphClient(writer, deadline_s=8.0, max_retries=800,
                          backoff_base_s=0.002, backoff_cap_s=0.05,
                          rng=_random.Random(seed),
                          leader_resolver=lambda: rset.leader)
    for step in range(preload_chunks):
        wclient.submit_many(typed_op_stream(nv, chunk, step=step,
                                            add_frac=0.7, seed=seed))
    rclient = GraphClient(writer, broker=rset, deadline_s=4.0,
                          max_retries=16)
    rng = np.random.default_rng(seed + 11)
    batch = [SameSCC(int(a), int(b))
             for a, b in zip(rng.integers(0, nv, n_queries),
                             rng.integers(0, nv, n_queries))]
    rclient.submit_many(batch)  # compile warmup off the clock

    def window(duration: float):
        served = faults = 0
        t_end = time.perf_counter() + duration
        while time.perf_counter() < t_end:
            try:
                rclient.submit_many(batch)
                served += n_queries
            except fault_errors.FaultError:
                faults += 1
        return served, faults

    wstep = preload_chunks  # distinct op streams past the preload

    def write_window(duration: float):
        nonlocal wstep
        written = faults = 0
        t_end = time.perf_counter() + duration
        while time.perf_counter() < t_end:
            try:
                wclient.submit_many(typed_op_stream(
                    nv, chunk, step=wstep, add_frac=0.7, seed=seed))
                written += chunk
            except fault_errors.FaultError:
                faults += 1
            wstep += 1
        return written, faults

    try:
        steady_q, steady_faults = window(window_s)
        rset.replicas[0].kill()
        faulted_q, faulted_faults = window(window_s)
        steady_w, steady_wfaults = write_window(write_window_s)
        writer.crash()  # kill -9 the leader: promotion happens in-window
        faulted_w, faulted_wfaults = write_window(write_window_s)
        stats = rset.stats()
    finally:
        rset.stop()  # also closes a promoted leader
        writer.close()
        if owns_dir:
            shutil.rmtree(directory, ignore_errors=True)
    steady = steady_q / window_s
    faulted = faulted_q / window_s
    w_steady = steady_w / write_window_s
    w_faulted = faulted_w / write_window_s
    return {
        "replicas": replicas, "window_s": window_s,
        "steady_per_s": int(steady), "faulted_per_s": int(faulted),
        "ratio": round(faulted / max(steady, 1e-9), 4),
        "steady_faults": steady_faults,
        "faulted_faults": faulted_faults,
        "failovers": stats["failovers"], "restarts": stats["restarts"],
        "write_window_s": write_window_s,
        "lease_ttl_s": lease_ttl_s,
        "write_steady_per_s": int(w_steady),
        "write_faulted_per_s": int(w_faulted),
        "write_availability": round(w_faulted / max(w_steady, 1e-9), 4),
        "write_steady_faults": steady_wfaults,
        "write_faulted_faults": faulted_wfaults,
        "promotions": stats["promotions"],
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", default=None,
                    help="keep per-run stores under this root "
                         "(default: throwaway temp dirs)")
    ap.add_argument("--seeds", default="0,1,2")
    ap.add_argument("--profiles", default="mixed",
                    help="comma list of disk-fault|replica-kill|mixed")
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for the CI gate")
    ap.add_argument("--chunks", type=int, default=None)
    ap.add_argument("--nv", type=int, default=None)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--availability", action="store_true",
                    help="run the availability-window bench instead")
    ap.add_argument("--failover", action="store_true",
                    help="writer-loss soak: crash the leased writer "
                         "per seed; require promotion, fencing, "
                         "rerouted writes, zero acked-op loss")
    ap.add_argument("--tenant-soak", action="store_true",
                    help="disk-fault soak over per-tenant WAL dirs of "
                         "the multi-tenant service")
    args = ap.parse_args()
    if args.availability:
        rep = run_availability(replicas=args.replicas)
        print("availability: " + " | ".join(f"{k}={v}"
                                            for k, v in rep.items()))
        if rep["ratio"] < 0.5:
            sys.exit("availability ratio below 0.5")
        if rep["write_availability"] < 0.5:
            sys.exit("write availability below 0.5")
        if rep["promotions"] < 1:
            sys.exit("writer crash never promoted a replica")
        return

    seeds = [int(s) for s in args.seeds.split(",") if s]
    if args.failover:
        bad = 0
        for seed in seeds:
            with tempfile.TemporaryDirectory(
                    prefix=f"scc-failover-s{seed}-") as d:
                rep = run_writer_failover(
                    d, seed=seed,
                    n_chunks=args.chunks or (18 if args.smoke else 36),
                    nv=args.nv or (160 if args.smoke else 384),
                    replicas=args.replicas)
            print(f"seed={seed}: acked={rep['acked']} "
                  f"failed={len(rep['failed'])} gen={rep['gen']} "
                  f"epoch={rep['old_epoch']}->{rep['new_epoch']} "
                  f"post_kill_acked={rep['post_kill_acked']} "
                  f"promotions={rep['promotions']} "
                  f"notleader={rep['notleader_rejects']} "
                  f"reroutes={rep['client_reroutes']} "
                  f"violations={len(rep['violations'])}", flush=True)
            for v in rep["violations"]:
                print(f"  VIOLATION: {v}", flush=True)
            bad += len(rep["violations"])
        print(f"writer failover: {len(seeds)} runs, {bad} violations")
        sys.exit(1 if bad else 0)
    if args.tenant_soak:
        bad = fs_trig = 0
        for seed in seeds:
            with tempfile.TemporaryDirectory(
                    prefix=f"scc-tsoak-s{seed}-") as d:
                rep = run_tenant_soak(
                    d, seed=seed,
                    n_rounds=args.chunks or (14 if args.smoke else 28),
                    nv=args.nv or (96 if args.smoke else 192))
            print(f"seed={seed}: tenants={rep['tenants']} "
                  f"acked={rep['acked']} failed={len(rep['failed'])} "
                  f"wal_faults={rep['wal_faults']} "
                  f"fs_triggered={rep['fs_triggered']} "
                  f"violations={len(rep['violations'])}", flush=True)
            for v in rep["violations"]:
                print(f"  VIOLATION: {v}", flush=True)
            bad += len(rep["violations"])
            fs_trig += rep["fs_triggered"]
        if fs_trig == 0:
            print("VIOLATION: no filesystem fault ever triggered "
                  "(tenant WAL injection is not biting)")
            bad += 1
        print(f"tenant soak: {len(seeds)} runs, {bad} violations")
        sys.exit(1 if bad else 0)
    profiles = [p for p in args.profiles.split(",") if p]
    nv = args.nv or (160 if args.smoke else 384)
    n_chunks = args.chunks or (28 if args.smoke else 64)
    bad = 0
    fs_trig = kills = 0
    for seed, profile in itertools.product(seeds, profiles):
        if args.dir:
            d = os.path.join(args.dir, f"s{seed}-{profile}")
            os.makedirs(d, exist_ok=True)
            rep = run_chaos_soak(d, seed=seed, profile=profile,
                                 n_chunks=n_chunks, nv=nv,
                                 replicas=args.replicas)
        else:
            with tempfile.TemporaryDirectory(
                    prefix=f"scc-chaos-s{seed}-") as d:
                rep = run_chaos_soak(d, seed=seed, profile=profile,
                                     n_chunks=n_chunks, nv=nv,
                                     replicas=args.replicas)
        print(f"seed={seed} profile={profile}: acked={rep['acked']} "
              f"failed={len(rep['failed'])} gen={rep['gen']} "
              f"fs_triggered={rep['fs_triggered']} "
              f"kills={rep['kills_fired']} degraded={rep['degraded']} "
              f"recovered={rep['recovered']} "
              f"retries={rep['client_retries']} "
              f"failovers={rep['failovers']} "
              f"restarts={rep['restarts']} "
              f"violations={len(rep['violations'])}", flush=True)
        for v in rep["violations"]:
            print(f"  VIOLATION: {v}", flush=True)
        bad += len(rep["violations"])
        fs_trig += rep["fs_triggered"]
        kills += rep["kills_fired"]
    if any(p in ("disk-fault", "mixed") for p in profiles) \
            and fs_trig == 0:
        print("VIOLATION: no filesystem fault ever triggered "
              "(injection is not biting)")
        bad += 1
    if any(p in ("replica-kill", "mixed") for p in profiles) \
            and kills == 0:
        print("VIOLATION: no replica kill ever fired")
        bad += 1
    n = len(seeds) * len(profiles)
    print(f"chaos soak: {n} runs, {bad} violations")
    if bad:
        sys.exit(1)


if __name__ == "__main__":
    main()
