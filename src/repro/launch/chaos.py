"""Chaos soak: recorded op stream under a seeded fault plan.

The durability suite proves recovery from a *single* crash point; this
driver holds the serving stack's failure-domain contract under a whole
schedule of faults injected **while serving** (:mod:`repro.fault`):
WAL write/fsync faults (EIO / ENOSPC / torn records) that flip the
writer DEGRADED mid-stream, and replica kills that force query failover
and supervisor restarts.  One soak run asserts, for a seeded
:class:`~repro.fault.inject.FaultPlan`:

* **no acked op is ever lost** -- the writer's final state is
  bit-identical to an in-memory oracle replaying exactly the
  acknowledged chunks, and so is a cold :meth:`DurableService.open` of
  the store afterwards (a chunk the client saw fail was never applied;
  a chunk the client saw ack survives every injected fault);
* **every failure is typed** -- a client only ever observes
  :class:`~repro.fault.errors.FaultError` subclasses (``Unavailable``,
  ``DeadlineExceeded``, ...); any bare exception is a violation;
* **availability never reaches zero while a replica is healthy** -- a
  per-round query probe through the :class:`ReplicaSet` must keep
  answering (transparent failover + supervisor restarts) whenever at
  least one replica is routable;
* **the store heals under fire** -- the plan's fault windows are
  finite, so the writer's rate-limited recovery probes must re-attach
  the WAL and return to HEALTHY *while the plan is still armed*.

Determinism: the fault *schedule* is a pure function of (seed,
profile) and fires on call/generation counters, not wall clock, so a
failing seed reproduces (thread interleavings still vary, but every
assertion above is interleaving-independent).

``--availability`` runs the companion windowed bench
(:func:`run_availability`): closed-loop query throughput in a steady
window vs a window where a replica is killed and supervisor-restarted;
``benchmarks/bench_stream.py`` records the ratio and ``scripts/ci.sh``
gates it.
"""
from __future__ import annotations

import argparse
import itertools
import os
import sys
import tempfile
import time

__all__ = ["run_chaos_soak", "run_availability"]


def run_chaos_soak(directory: str, *, seed: int = 0,
                   profile: str = "mixed", n_chunks: int = 40,
                   chunk: int = 16, nv: int = 192, replicas: int = 2,
                   poll_interval: float = 0.02, n_queries: int = 8,
                   deadline_s: float = 8.0) -> dict:
    """One soak run; returns a report dict whose ``violations`` list is
    empty iff every contract held (the driver never raises for a fault
    outcome -- only for harness bugs)."""
    import jax
    import numpy as np

    from repro.api import GraphClient, SameSCC
    from repro.api.ops import encode_updates
    from repro.ckpt.durable import DurableService, HEALTHY
    from repro.core import graph_state as gs
    from repro.core.replicas import ReplicaSet
    from repro.core.service import SCCService
    from repro.fault import errors as fault_errors
    from repro.fault.inject import FaultPlan, fire_kills, injected
    from repro.launch.replica import _writer_config
    from repro.launch.stream import typed_op_stream

    cfg = _writer_config(nv, edge_capacity=2048)
    writer = DurableService(
        cfg, directory, state=gs.all_singletons(cfg), buckets=(chunk,),
        proactive_grow=True, sync_every=1, segment_bytes=16 << 10,
        snapshot_every=8, snapshot_keep=4, recover_probe_s=0.01)
    rset = ReplicaSet(directory, replicas, query_buckets=(n_queries,),
                      poll_interval=poll_interval, supervise=True,
                      health_check_s=0.05)
    wclient = GraphClient(writer, deadline_s=deadline_s, max_retries=64,
                          backoff_base_s=0.002, backoff_cap_s=0.05)
    rclient = GraphClient(writer, broker=rset, deadline_s=deadline_s,
                          max_retries=16, backoff_base_s=0.002,
                          backoff_cap_s=0.05)
    rng = np.random.default_rng(seed + 101)

    acked: list = []  # the ledger the oracle replays
    failed: list = []
    violations: list = []

    # warm the compiled update/query paths off the fault clock -- the
    # warm chunk is acked, so it joins the ledger like any other
    warm = typed_op_stream(nv, chunk, step=1 << 20, add_frac=0.7,
                           seed=seed)
    wclient.submit_many(warm)
    acked.append(warm)
    rclient.submit_many([SameSCC(0, 1)] * n_queries)

    plan = FaultPlan.generate(seed, profile, replicas=replicas,
                              horizon_gens=n_chunks)
    probe_ok = probe_fail = 0
    with injected(plan):
        for step in range(n_chunks):
            ops = typed_op_stream(nv, chunk, step=step, add_frac=0.7,
                                  seed=seed)
            try:
                wclient.submit_many(ops)
                acked.append(ops)
            except fault_errors.FaultError as e:
                failed.append(type(e).__name__)  # typed reject: fine
            except Exception as e:  # contract breach: must be typed
                failed.append(type(e).__name__)
                violations.append(
                    f"untyped writer failure at step {step}: "
                    f"{type(e).__name__}: {e}")
            fire_kills(plan, rset, writer.gen)
            qu = rng.integers(0, nv, n_queries)
            qv = rng.integers(0, nv, n_queries)
            try:
                rclient.submit_many([SameSCC(int(a), int(b))
                                     for a, b in zip(qu, qv)])
                probe_ok += 1
            except fault_errors.FaultError:
                probe_fail += 1
                if rset.healthy_replicas:
                    violations.append(
                        f"query probe failed at step {step} with "
                        f"{len(rset.healthy_replicas)} healthy replicas")
            except Exception as e:
                probe_fail += 1
                violations.append(
                    f"untyped reader failure at step {step}: "
                    f"{type(e).__name__}: {e}")
        # heal under fire: fault windows are finite counters, so
        # repeated probes must re-attach the WAL with the plan armed
        heal_deadline = time.monotonic() + 10.0
        while writer.health != HEALTHY and \
                time.monotonic() < heal_deadline:
            writer.probe_recovery()
            time.sleep(0.01)
        if writer.health != HEALTHY:
            violations.append(
                "store did not recover after the fault window "
                f"(stuck on: {writer._degraded_error})")

    final_gen = writer.gen
    final_state = writer.state
    writer_stats = writer.stats()
    try:
        rset.wait_all_for_gen(final_gen, timeout=10.0)
        rs_stats = rset.stats()
        rset.stop()
    except Exception as e:
        rs_stats = {"failovers": -1, "restarts": -1}
        violations.append(
            f"replica teardown raised: {type(e).__name__}: {e}")
    writer.close()

    # oracle: replay exactly the acked chunks through a plain in-memory
    # service with the writer's decision knobs -- acked ops and nothing
    # else must reproduce the writer bit-for-bit
    oracle = SCCService(cfg, state=gs.all_singletons(cfg),
                        buckets=(chunk,), proactive_grow=True)
    for ops in acked:
        kind, u, v = encode_updates(ops)
        oracle._apply_ops(kind, u, v)
    if oracle.gen != final_gen:
        violations.append(
            f"acked-op oracle at gen {oracle.gen}, writer at "
            f"{final_gen}: an op was lost or double-applied")
    else:
        for a, b in zip(jax.tree_util.tree_leaves(final_state),
                        jax.tree_util.tree_leaves(oracle.state)):
            if not np.array_equal(np.asarray(a), np.asarray(b)):
                violations.append(
                    "writer state diverged from the acked-op oracle")
                break

    # cold disk recovery must land on the same state (plan disarmed:
    # this checks what the faults left on disk, not new faults)
    reopened = DurableService.open(directory, snapshot_every=0)
    if reopened.gen != oracle.gen:
        violations.append(
            f"disk recovery at gen {reopened.gen}, oracle at "
            f"{oracle.gen}: durability lost an acked op")
    else:
        for a, b in zip(jax.tree_util.tree_leaves(reopened.state),
                        jax.tree_util.tree_leaves(oracle.state)):
            if not np.array_equal(np.asarray(a), np.asarray(b)):
                violations.append(
                    "disk recovery diverged from the acked-op oracle")
                break
    reopened.close()

    return {
        "seed": seed, "profile": profile,
        "chunks": n_chunks + 1, "acked": len(acked),
        "failed": failed, "gen": final_gen,
        "fs_faults_planned": len(plan.fs),
        "fs_triggered": len(plan.triggered),
        "kills_planned": len(plan.kills),
        "kills_fired": len(plan._fired_kills),
        "probe_ok": probe_ok, "probe_fail": probe_fail,
        "degraded": writer_stats["degraded_count"],
        "recovered": writer_stats["recovered_count"],
        "rejects": writer_stats["unavailable_rejects"],
        "client_retries": wclient.retries + rclient.retries,
        "failovers": rs_stats["failovers"],
        "restarts": rs_stats["restarts"],
        "violations": violations,
    }


def run_availability(directory: str | None = None, *,
                     replicas: int = 2, nv: int = 256, chunk: int = 32,
                     preload_chunks: int = 8, n_queries: int = 32,
                     window_s: float = 0.8,
                     poll_interval: float = 0.02,
                     seed: int = 0) -> dict:
    """Windowed availability bench: closed-loop query throughput in a
    steady window vs a window opened by killing a replica (the
    supervisor restarts it mid-window).  A closed-loop caller is
    latency-bound, so the ratio should stay near 1.0 -- failover costs
    one resubmit, not a replica's worth of throughput; ``ci.sh`` gates
    ``ratio >= 0.5``."""
    import shutil

    import numpy as np

    from repro.api import GraphClient, SameSCC
    from repro.ckpt.durable import DurableService
    from repro.core import graph_state as gs
    from repro.core.replicas import ReplicaSet
    from repro.fault import errors as fault_errors
    from repro.launch.replica import _writer_config
    from repro.launch.stream import typed_op_stream

    owns_dir = directory is None
    if owns_dir:
        directory = tempfile.mkdtemp(prefix="scc-avail-")
    cfg = _writer_config(nv, edge_capacity=2048)
    writer = DurableService(
        cfg, directory, state=gs.all_singletons(cfg), buckets=(chunk,),
        proactive_grow=True, sync_every=1, snapshot_every=0)
    wclient = GraphClient(writer)
    for step in range(preload_chunks):
        wclient.submit_many(typed_op_stream(nv, chunk, step=step,
                                            add_frac=0.7, seed=seed))
    rset = ReplicaSet(directory, replicas, query_buckets=(n_queries,),
                      poll_interval=poll_interval, supervise=True,
                      health_check_s=0.05)
    rclient = GraphClient(writer, broker=rset, deadline_s=4.0,
                          max_retries=16)
    rng = np.random.default_rng(seed + 11)
    batch = [SameSCC(int(a), int(b))
             for a, b in zip(rng.integers(0, nv, n_queries),
                             rng.integers(0, nv, n_queries))]
    rclient.submit_many(batch)  # compile warmup off the clock

    def window(duration: float):
        served = faults = 0
        t_end = time.perf_counter() + duration
        while time.perf_counter() < t_end:
            try:
                rclient.submit_many(batch)
                served += n_queries
            except fault_errors.FaultError:
                faults += 1
        return served, faults

    try:
        steady_q, steady_faults = window(window_s)
        rset.replicas[0].kill()
        faulted_q, faulted_faults = window(window_s)
        stats = rset.stats()
    finally:
        rset.stop()
        writer.close()
        if owns_dir:
            shutil.rmtree(directory, ignore_errors=True)
    steady = steady_q / window_s
    faulted = faulted_q / window_s
    return {
        "replicas": replicas, "window_s": window_s,
        "steady_per_s": int(steady), "faulted_per_s": int(faulted),
        "ratio": round(faulted / max(steady, 1e-9), 4),
        "steady_faults": steady_faults,
        "faulted_faults": faulted_faults,
        "failovers": stats["failovers"], "restarts": stats["restarts"],
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", default=None,
                    help="keep per-run stores under this root "
                         "(default: throwaway temp dirs)")
    ap.add_argument("--seeds", default="0,1,2")
    ap.add_argument("--profiles", default="mixed",
                    help="comma list of disk-fault|replica-kill|mixed")
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for the CI gate")
    ap.add_argument("--chunks", type=int, default=None)
    ap.add_argument("--nv", type=int, default=None)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--availability", action="store_true",
                    help="run the availability-window bench instead")
    args = ap.parse_args()
    if args.availability:
        rep = run_availability(replicas=args.replicas)
        print("availability: " + " | ".join(f"{k}={v}"
                                            for k, v in rep.items()))
        if rep["ratio"] < 0.5:
            sys.exit("availability ratio below 0.5")
        return

    seeds = [int(s) for s in args.seeds.split(",") if s]
    profiles = [p for p in args.profiles.split(",") if p]
    nv = args.nv or (160 if args.smoke else 384)
    n_chunks = args.chunks or (28 if args.smoke else 64)
    bad = 0
    fs_trig = kills = 0
    for seed, profile in itertools.product(seeds, profiles):
        if args.dir:
            d = os.path.join(args.dir, f"s{seed}-{profile}")
            os.makedirs(d, exist_ok=True)
            rep = run_chaos_soak(d, seed=seed, profile=profile,
                                 n_chunks=n_chunks, nv=nv,
                                 replicas=args.replicas)
        else:
            with tempfile.TemporaryDirectory(
                    prefix=f"scc-chaos-s{seed}-") as d:
                rep = run_chaos_soak(d, seed=seed, profile=profile,
                                     n_chunks=n_chunks, nv=nv,
                                     replicas=args.replicas)
        print(f"seed={seed} profile={profile}: acked={rep['acked']} "
              f"failed={len(rep['failed'])} gen={rep['gen']} "
              f"fs_triggered={rep['fs_triggered']} "
              f"kills={rep['kills_fired']} degraded={rep['degraded']} "
              f"recovered={rep['recovered']} "
              f"retries={rep['client_retries']} "
              f"failovers={rep['failovers']} "
              f"restarts={rep['restarts']} "
              f"violations={len(rep['violations'])}", flush=True)
        for v in rep["violations"]:
            print(f"  VIOLATION: {v}", flush=True)
        bad += len(rep["violations"])
        fs_trig += rep["fs_triggered"]
        kills += rep["kills_fired"]
    if any(p in ("disk-fault", "mixed") for p in profiles) \
            and fs_trig == 0:
        print("VIOLATION: no filesystem fault ever triggered "
              "(injection is not biting)")
        bad += 1
    if any(p in ("replica-kill", "mixed") for p in profiles) \
            and kills == 0:
        print("VIOLATION: no replica kill ever fired")
        bad += 1
    n = len(seeds) * len(profiles)
    print(f"chaos soak: {n} runs, {bad} violations")
    if bad:
        sys.exit(1)


if __name__ == "__main__":
    main()
