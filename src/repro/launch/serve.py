"""Serving driver: batched LM decode, recsys scoring, or the paper's
streaming SCC service on the host mesh.

    python -m repro.launch.serve --arch gemma3-12b --smoke
    python -m repro.launch.serve --arch mind --smoke
    python -m repro.launch.serve --arch smscc --steps 64
    python -m repro.launch.serve --arch smscc --steps 64 --readers 2
    python -m repro.launch.serve --arch smscc --steps 20 --readers 2 \
        --replicas 2 --dir /tmp/scc-store
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs


def serve_lm(mod, steps: int):
    from repro.models import transformer as tf
    cfg = mod.smoke_config()
    params = tf.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    b, prompt_len, cache_len = 4, 12, 64
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, prompt_len)),
                       jnp.int32)
    cache, logits = tf.prefill(params, toks, cfg, cache_len=cache_len)
    decode = jax.jit(lambda p, c, t: tf.decode_step(p, c, t, cfg))
    out = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    t0 = time.perf_counter()
    for _ in range(steps):
        out.append(tok)
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    print(f"decoded {steps} tokens x batch {b} in {dt:.2f}s "
          f"({steps*b/dt:.0f} tok/s)")
    print("sample:", [int(t[0]) for t in out[:16]])


def serve_mind(mod, steps: int):
    model = mod.MODULE
    cfg = mod.smoke_config()
    params = model.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    b, c = 32, 512
    score = jax.jit(lambda p, batch: model.serve_score(p, batch, cfg))
    t0 = time.perf_counter()
    for step in range(steps):
        batch = {
            "behavior": jnp.asarray(
                rng.integers(-1, cfg.n_items, (b, cfg.seq_len)),
                jnp.int32),
            "profile": jnp.asarray(
                rng.integers(-1, cfg.profile_vocab, (b, cfg.profile_len)),
                jnp.int32),
            "candidates": jnp.asarray(
                rng.integers(0, cfg.n_items, (b, c)), jnp.int32),
        }
        s = score(params, batch)
    jax.block_until_ready(s)
    dt = time.perf_counter() - t0
    print(f"scored {steps} requests x batch {b} x {c} candidates in "
          f"{dt:.2f}s ({steps*b*c/dt:.0f} scores/s)")


def serve_smscc(mod, steps: int, nv: int = 2048, chunk: int = 256,
                readers: int = 0, replicas: int = 0,
                directory: str | None = None):
    """The paper's on-line mode: a typed GraphClient update stream +
    wait-free query batches over the committed snapshot, via the SCC
    service layer.  With ``readers > 0`` the queries move off the update
    thread into per-reader client sessions over one QueryBroker that
    overlaps the update pipeline.  With ``replicas > 0`` the store goes
    durable instead: a WAL-backed writer plus N read replicas tailing
    the log serve the readers' read-your-writes rounds
    (:func:`repro.launch.replica.run_replicated_stream`; requires
    ``directory`` for the durable store)."""
    from repro.core import graph_state as gs
    from repro.core.service import SCCService
    from repro.launch import stream

    if replicas > 0:
        from repro.launch.replica import run_replicated_stream
        if directory is None:
            raise SystemExit("--replicas needs --dir (durable store root)")
        rep = run_replicated_stream(
            directory, replicas=replicas, n_ops=steps * 32,
            readers=max(readers, 1))
        print(rep.pretty())
        return

    cfg = mod.config(n_vertices=nv, edge_capacity=max(1024, nv),
                     max_probes=64, max_outer=64, max_inner=128)
    # boot with every vertex slot live (singleton SCCs) so the update mix
    # lands immediately instead of bouncing off dead endpoints; serving
    # runs the full fused update engine (scan super-chunks + growth
    # rehashes ahead of chunks that cannot fit)
    svc = SCCService(cfg, buckets=(64, chunk),
                     state=gs.all_singletons(cfg),
                     scan_lengths=mod.SCAN_LENGTHS, proactive_grow=True)
    if readers > 0:
        rep = stream.run_concurrent_stream(
            svc, n_ops=steps * chunk, readers=readers, add_frac=0.7,
            chunk=chunk, n_queries=1024)
    else:
        rep = stream.run_stream(svc, n_ops=steps * chunk, add_frac=0.7,
                                query_frac=0.5, chunk=chunk,
                                n_queries=1024)
    print(rep.pretty())
    # the unified GraphClient.stats() telemetry (service + broker merged)
    tele = ("gen", "pipelined_chunks", "fallback_chunks", "compile_count",
            "grows", "compactions", "flushes", "served", "max_coalesced",
            "gen_waits", "coalescing", "client_updates", "client_queries")
    print("[client.stats] " + " | ".join(
        f"{k}={rep[k]}" for k in tele if k in rep))


def serve_tenants(mod, steps: int, tenants: int, nv: int = 256,
                  chunk: int = 64, directory: str | None = None):
    """Multi-tenant serving: N independent session graphs behind ONE
    vmapped engine and one admission queue
    (:class:`repro.tenancy.MultiTenantService`).  Each tenant runs its
    own typed ``GraphClient`` session on its own thread; concurrent
    submits coalesce into tenant-batched vmapped dispatches.  With
    ``directory`` the store is durable per tenant (snapshot + WAL) and
    idle tenants are evicted/rehydrated transparently."""
    import threading

    from repro.api import SameSCC
    from repro.launch import stream
    from repro.tenancy import MultiTenantService

    cfg = mod.config(n_vertices=nv, edge_capacity=max(256, nv),
                     max_probes=64, max_outer=64, max_inner=64)
    mts = MultiTenantService(cfg, buckets=(chunk,),
                             scan_lengths=mod.SCAN_LENGTHS,
                             directory=directory,
                             coalesce_ops=tenants * chunk,
                             flush_deadline_s=0.005)
    tids = [mts.create_tenant() for _ in range(tenants)]
    done = []

    def drive(tid, i):
        client = mts.client(tid)
        rng = np.random.default_rng(100 + i)
        n_ops = 0
        client.submit_many(stream.typed_op_stream(
            nv, chunk, step=0, add_frac=1.0, seed=i,
            include_vertex_ops=True))
        for step in range(steps):
            client.submit_many(stream.typed_op_stream(
                nv, chunk, step=step + 1, add_frac=0.7, seed=i))
            n_ops += chunk
            qs = [SameSCC(int(a), int(b)) for a, b in
                  zip(rng.integers(0, nv, 16), rng.integers(0, nv, 16))]
            client.submit_many(qs)
        client.close()
        done.append(n_ops)

    t0 = time.perf_counter()
    threads = [threading.Thread(target=drive, args=(tid, i))
               for i, tid in enumerate(tids)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    total = sum(done)
    agg = mts.stats()
    print(f"served {tenants} tenants x {steps} chunks "
          f"({total} update ops) in {wall:.2f}s "
          f"({int(total / wall)} ops/s aggregate)")
    q = agg["queue"]
    print(f"[queue] waves={q['waves']} causes={q['flush_causes']} "
          f"depth_max={q['depth_max_ops']} rejects={q['rejects']} "
          f"pool={q['pool']}")
    e = agg["engine"]
    print(f"[engine] compile_count={e['compile_count']} "
          f"(bound {e['compile_bound']}) solo_replays={e['solo_replays']} "
          f"occupancy={e['occupancy']['frac']}")
    for tid in tids[:4]:
        print(f"[tenant {tid}] " + " | ".join(
            f"{k}={v}" for k, v in mts.tenant_stats(tid).items()
            if k in ("gen", "applied_chunks", "fallback_chunks", "grows",
                     "p50_s", "p95_s")))
    mts.close()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-12b")
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--readers", type=int, default=0,
                    help="smscc only: concurrent reader threads (0 = "
                         "serial query interleaving)")
    ap.add_argument("--replicas", type=int, default=0,
                    help="smscc only: serve reads from N WAL-tailing "
                         "replicas over a durable writer (needs --dir)")
    ap.add_argument("--tenants", type=int, default=0,
                    help="smscc only: serve N independent tenant graphs "
                         "behind one vmapped engine + admission queue")
    ap.add_argument("--dir", dest="directory", default=None,
                    help="smscc only: durable store root for --replicas "
                         "/ per-tenant stores for --tenants")
    args = ap.parse_args()
    mod = configs.get(args.arch)
    if mod.FAMILY == "lm":
        serve_lm(mod, args.steps)
    elif mod.FAMILY == "recsys":
        serve_mind(mod, args.steps)
    elif mod.FAMILY == "smscc":
        if args.tenants > 0:
            serve_tenants(mod, args.steps, args.tenants,
                          directory=args.directory)
        else:
            serve_smscc(mod, args.steps, readers=args.readers,
                        replicas=args.replicas, directory=args.directory)
    else:
        raise SystemExit(f"no serve path for family {mod.FAMILY}")


if __name__ == "__main__":
    main()
