"""Replicated serving driver: durable writer + N read replicas.

Three entry points:

* **default** -- a self-contained demo/bench core
  (:func:`run_replicated_stream`): a :class:`repro.ckpt.durable.
  DurableService` writer ingests an *arrival-paced* (open-loop) update
  stream while closed-loop reader sessions run read-your-writes rounds
  against a :class:`repro.core.replicas.ReplicaSet`: each round commits
  one small "touch" update through the writer, then queries at
  ``Consistency.AT_LEAST(max(token, last_gen))`` -- the session's RYW
  token joined with its monotone-reads floor.  The serving regime is
  latency-bound, not compute-bound: the touch write guarantees every
  read round must wait out the replication lag of *some* replica
  (replicas pull the WAL on a staggered fixed cadence), so the set's
  soonest-ticking member hides most of the lag -- expected freshness
  wait drops from ~poll/2 at one replica to ~poll/2N at N -- and
  serving throughput scales with replica count even on a single core.
  ``benchmarks/bench_stream.py`` records this section.

* ``--writer-child`` -- the crash-injection smoke's victim process: an
  ingest-only durable writer that prints its committed generation per
  chunk; the harness (``scripts/ci.sh``, ``tests/test_durability.py``)
  SIGKILLs it at an arbitrary moment.

* ``--verify-recovery`` -- recover the store
  (:meth:`DurableService.open` = latest snapshot + WAL tail) and check
  it bit-for-bit against the independent scratch oracle (generation-0
  boot snapshot + full WAL, :func:`repro.ckpt.durable.scratch_replay`).
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np

__all__ = ["run_replicated_stream", "writer_child", "verify_recovery"]


def _writer_config(nv: int, edge_capacity: int | None = None):
    from repro import configs
    mod = configs.get("smscc")
    return mod.config(n_vertices=nv,
                      edge_capacity=edge_capacity or max(1024, nv),
                      max_probes=64, max_outer=64, max_inner=128)


def run_replicated_stream(directory: str, *, replicas: int = 2,
                          n_ops: int = 640, chunk: int = 32,
                          pace_s: float = 0.080, readers: int = 2,
                          n_queries: int = 96, nv: int = 512,
                          poll_interval: float = 0.150,
                          sync_every: int = 1, seed: int = 0,
                          add_frac: float = 0.7):
    """Paced replicated serving: returns a StreamReport.

    ``pace_s`` is the update arrival period (open-loop ingest: the
    writer never back-pressures the stream) and ``poll_interval`` the
    replicas' WAL pull cadence -- the replication-lag bottleneck the
    replica count hides.  Readers are closed-loop read-your-writes
    sessions: each round commits one touch write through the writer
    (RYW token), then queries the ReplicaSet at
    ``AT_LEAST(max(token, last_gen))``.  The floor is freshly
    committed, so some replica must pull the WAL past it before the
    round can complete: round latency = touch + replication wait +
    query, and the wait is where staggered replicas buy throughput
    (soonest tick ~poll/2N away instead of ~poll/2).  The combined
    floor also keeps per-reader stamps monotone across replicas --
    replicas can run *ahead* of the writer's committed generation (a
    WAL record is durable before the writer's own apply commits), so a
    writer-derived floor alone would not prevent a stamp regression
    when consecutive rounds land on differently-advanced replicas.
    """
    from repro.api import AddEdge, Consistency, GraphClient, RemoveEdge, \
        SameSCC
    from repro.ckpt.durable import DurableService
    from repro.core import graph_state as gs
    from repro.core.replicas import ReplicaSet
    from repro.launch.stream import StreamReport, typed_op_stream

    # provision capacity for the whole run: a growth step mid-run would
    # recompile on the writer AND every replica at once (1-core stall)
    cfg = _writer_config(nv, edge_capacity=2048)
    writer = DurableService(
        cfg, directory, state=gs.all_singletons(cfg), buckets=(8, chunk),
        proactive_grow=True, sync_every=sync_every, snapshot_every=0)
    rset = ReplicaSet(directory, replicas, query_buckets=(n_queries,),
                      poll_interval=poll_interval)
    updater = GraphClient(writer)
    stop = threading.Event()
    q_counts = [0] * readers
    touch_counts = [0] * readers
    errors: list = []

    def reader(i: int):
        rclient = GraphClient(writer, broker=rset)  # reads -> replicas
        wclient = GraphClient(writer)               # session's own writes
        rng = np.random.default_rng(seed + 7919 * (i + 1))
        u0, v0 = 2 * i, 2 * i + 1
        flip = False
        last_gen = 0
        try:
            while not stop.is_set():
                op = RemoveEdge(u0, v0) if flip else AddEdge(u0, v0)
                flip = not flip
                token = wclient.submit_many([op])[0].gen
                touch_counts[i] += 1
                floor = max(token, last_gen)  # RYW + monotone-reads
                qu = rng.integers(0, nv, n_queries)
                qv = rng.integers(0, nv, n_queries)
                res = rclient.submit_many(
                    [SameSCC(int(a), int(b)) for a, b in zip(qu, qv)],
                    consistency=Consistency.AT_LEAST(floor))
                gen = res[0].gen
                if gen < floor:
                    raise AssertionError(
                        f"reader {i}: stamp {gen} below floor {floor}")
                last_gen = gen
                q_counts[i] += n_queries
        except Exception as e:
            errors.append(e)

    # compile warmup off the clock: one stream chunk (bucket `chunk`),
    # one touch write (bucket 8), one replica-served query flush
    updater.submit_many(typed_op_stream(nv, chunk, step=1 << 20,
                                        add_frac=add_frac, seed=seed))
    warm_floor = GraphClient(writer).submit_many([AddEdge(0, 1)])[0].gen
    GraphClient(writer, broker=rset).submit_many(
        [SameSCC(0, 1)], consistency=Consistency.AT_LEAST(warm_floor))

    threads = [threading.Thread(target=reader, args=(i,), daemon=True)
               for i in range(readers)]
    applied = accepted = step = 0
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    try:
        next_due = t0
        while applied < n_ops:
            n = min(chunk, n_ops - applied)
            ops = typed_op_stream(nv, n, step=step, add_frac=add_frac,
                                  seed=seed)
            results = updater.submit_many(ops)
            accepted += sum(r.value for r in results)
            applied += n
            step += 1
            next_due += pace_s
            delay = next_due - time.perf_counter()
            if delay > 0 and applied < n_ops:
                time.sleep(delay)
    finally:
        stop.set()
        for t in threads:
            t.join()
        rs_stats = rset.stats()
        rset.stop()
        writer.close()
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    queries = sum(q_counts)
    touches = sum(touch_counts)
    rep = StreamReport(
        replicas=replicas, readers=readers, ops=applied,
        accepted=accepted, touches=touches, queries=queries,
        wall_s=round(wall, 4),
        pace_ms=round(pace_s * 1e3, 1),
        poll_ms=round(poll_interval * 1e3, 1),
        ops_per_s=int(applied / wall),
        queries_per_s=int(queries / wall),
        combined_per_s=int((applied + touches + queries) / wall),
        routed_fresh=rs_stats["routed_fresh"],
        routed_stale=rs_stats["routed_stale"],
        replica_gen_waits=rs_stats["gen_waits"],
    )
    return rep


def writer_child(directory: str, *, nv: int = 256, steps: int = 10_000,
                 chunk: int = 64, seed: int = 0, pace_s: float = 0.0,
                 snapshot_every: int = 0):
    """Crash-smoke victim: durable ingest loop, one 'gen <g>' line per
    committed chunk on stdout (the harness watches for progress, then
    SIGKILLs this process mid-stream)."""
    from repro.api import GraphClient
    from repro.ckpt.durable import DurableService
    from repro.core import graph_state as gs
    from repro.launch.stream import typed_op_stream

    cfg = _writer_config(nv)
    svc = DurableService(
        cfg, directory, state=gs.all_singletons(cfg), buckets=(chunk,),
        proactive_grow=True, sync_every=1, segment_bytes=16 << 10,
        snapshot_every=snapshot_every, snapshot_keep=1_000_000,
        trim_on_snapshot=False)  # keep the full WAL: the verifier's
    #                              scratch oracle replays from gen 0
    client = GraphClient(svc)
    for step in range(steps):
        ops = typed_op_stream(nv, chunk, step=step, add_frac=0.7,
                              seed=seed)
        client.submit_many(ops)
        print(f"gen {svc.gen}", flush=True)
        if pace_s:
            time.sleep(pace_s)


def verify_recovery(directory: str) -> dict:
    """Recover the (possibly crash-torn) store and prove the two
    independent recovery paths agree bit-for-bit; returns a summary
    dict, raises on any divergence."""
    import jax

    from repro.ckpt.durable import DurableService, scratch_replay

    recovered = DurableService.open(directory, snapshot_every=0)
    oracle = scratch_replay(directory)
    if recovered.gen != oracle.gen:
        raise AssertionError(
            f"recovery diverged: snapshot+tail at gen {recovered.gen}, "
            f"scratch replay at gen {oracle.gen}")
    for a, b in zip(jax.tree_util.tree_leaves(recovered.state),
                    jax.tree_util.tree_leaves(oracle.state)):
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            raise AssertionError("recovery diverged: state leaves differ")
    summary = {"gen": recovered.gen,
               "replayed_records": recovered.replayed_wal_records,
               "live_edges": recovered.stats()["live_edges"]}
    recovered.close()
    return summary


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", required=True, help="durable store root")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--steps", type=int, default=64)
    ap.add_argument("--chunk", type=int, default=64)
    ap.add_argument("--nv", type=int, default=1024)
    ap.add_argument("--readers", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--writer-child", action="store_true",
                    help="run the crash-smoke victim writer")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="writer-child: async snapshot period in gens")
    ap.add_argument("--verify-recovery", action="store_true",
                    help="recover the store and check both recovery "
                         "paths agree bit-for-bit")
    args = ap.parse_args()
    if args.writer_child:
        writer_child(args.dir, nv=args.nv, steps=args.steps,
                     chunk=args.chunk, seed=args.seed,
                     snapshot_every=args.snapshot_every)
        return
    if args.verify_recovery:
        summary = verify_recovery(args.dir)
        print("recovery OK: " + " | ".join(f"{k}={v}"
                                           for k, v in summary.items()))
        return
    rep = run_replicated_stream(args.dir, replicas=args.replicas,
                                n_ops=args.steps * args.chunk,
                                chunk=args.chunk, nv=args.nv,
                                readers=args.readers, seed=args.seed)
    print(rep.pretty())


if __name__ == "__main__":
    main()
