"""Replicated serving driver: durable writer + N read replicas.

Three entry points:

* **default** -- a self-contained demo/bench core
  (:func:`run_replicated_stream`): a :class:`repro.ckpt.durable.
  DurableService` writer ingests an *arrival-paced* (open-loop) update
  stream while closed-loop reader sessions run read-your-writes rounds
  against a :class:`repro.core.replicas.ReplicaSet`: each round commits
  one small "touch" update through the writer, then queries at
  ``Consistency.AT_LEAST(max(token, last_gen))`` -- the session's RYW
  token joined with its monotone-reads floor.  The serving regime is
  latency-bound, not compute-bound: the touch write guarantees every
  read round must wait out the replication lag of *some* replica
  (replicas pull the WAL on a staggered fixed cadence), so the set's
  soonest-ticking member hides most of the lag -- expected freshness
  wait drops from ~poll/2 at one replica to ~poll/2N at N -- and
  serving throughput scales with replica count even on a single core.
  ``benchmarks/bench_stream.py`` records this section.

* ``--writer-child`` -- the crash-injection smoke's victim process: an
  ingest-only durable writer that prints its committed generation per
  chunk; the harness (``scripts/ci.sh``, ``tests/test_durability.py``)
  SIGKILLs it at an arbitrary moment.

* ``--verify-recovery`` -- recover the store
  (:meth:`DurableService.open` = latest snapshot + WAL tail) and check
  it bit-for-bit against the independent scratch oracle (generation-0
  boot snapshot + full WAL, :func:`repro.ckpt.durable.scratch_replay`).

* ``--promote-after-crash`` -- the failover half of the crash smoke:
  after the harness SIGKILLs an ``--ha`` writer child (one that held a
  :class:`~repro.ha.lease.FileLease`), wait out the lease TTL, take it
  over from a fresh :class:`Replica` (epoch bump + WAL fence + tail
  drain), append more chunks as the new epoch's leader, and prove a
  resurrected writer at the dead epoch is refused with nothing
  written.  ``--verify-recovery`` afterwards replays the resulting
  *mixed-epoch* WAL through both recovery paths.

* ``--supervised`` -- multi-process serving (ROADMAP item 4): the
  parent runs the durable writer and spawns ``--replicas`` child
  processes (each a ``--replica-child``: one :class:`Replica` tailing
  the shared store, reporting its generation until it reaches
  ``--until-gen``).  The parent is the process-level supervisor: a
  child that dies (e.g. the ``--kill-child-after`` SIGKILL injection)
  is restarted and fast-forwards from the newest snapshot -- the
  cross-process analogue of ``ReplicaSet(supervise=True)``.  The run
  fails unless every replica slot converges to the writer's final
  generation, restarts included.
"""
from __future__ import annotations

import argparse
import os
import signal
import subprocess
import sys
import threading
import time

import numpy as np

__all__ = ["run_replicated_stream", "writer_child", "verify_recovery",
           "replica_child", "supervised_stream", "promote_after_crash"]


def _writer_config(nv: int, edge_capacity: int | None = None):
    from repro import configs
    mod = configs.get("smscc")
    return mod.config(n_vertices=nv,
                      edge_capacity=edge_capacity or max(1024, nv),
                      max_probes=64, max_outer=64, max_inner=128)


def run_replicated_stream(directory: str, *, replicas: int = 2,
                          n_ops: int = 640, chunk: int = 32,
                          pace_s: float = 0.080, readers: int = 2,
                          n_queries: int = 96, nv: int = 512,
                          poll_interval: float = 0.150,
                          sync_every: int = 1, seed: int = 0,
                          add_frac: float = 0.7):
    """Paced replicated serving: returns a StreamReport.

    ``pace_s`` is the update arrival period (open-loop ingest: the
    writer never back-pressures the stream) and ``poll_interval`` the
    replicas' WAL pull cadence -- the replication-lag bottleneck the
    replica count hides.  Readers are closed-loop read-your-writes
    sessions: each round commits one touch write through the writer
    (RYW token), then queries the ReplicaSet at
    ``AT_LEAST(max(token, last_gen))``.  The floor is freshly
    committed, so some replica must pull the WAL past it before the
    round can complete: round latency = touch + replication wait +
    query, and the wait is where staggered replicas buy throughput
    (soonest tick ~poll/2N away instead of ~poll/2).  The combined
    floor also keeps per-reader stamps monotone across replicas --
    replicas can run *ahead* of the writer's committed generation (a
    WAL record is durable before the writer's own apply commits), so a
    writer-derived floor alone would not prevent a stamp regression
    when consecutive rounds land on differently-advanced replicas.
    """
    from repro.api import AddEdge, Consistency, GraphClient, RemoveEdge, \
        SameSCC
    from repro.ckpt.durable import DurableService
    from repro.core import graph_state as gs
    from repro.core.replicas import ReplicaSet
    from repro.launch.stream import StreamReport, typed_op_stream

    # provision capacity for the whole run: a growth step mid-run would
    # recompile on the writer AND every replica at once (1-core stall)
    cfg = _writer_config(nv, edge_capacity=2048)
    writer = DurableService(
        cfg, directory, state=gs.all_singletons(cfg), buckets=(8, chunk),
        proactive_grow=True, sync_every=sync_every, snapshot_every=0)
    rset = ReplicaSet(directory, replicas, query_buckets=(n_queries,),
                      poll_interval=poll_interval)
    updater = GraphClient(writer)
    stop = threading.Event()
    q_counts = [0] * readers
    touch_counts = [0] * readers
    errors: list = []

    def reader(i: int):
        rclient = GraphClient(writer, broker=rset)  # reads -> replicas
        wclient = GraphClient(writer)               # session's own writes
        rng = np.random.default_rng(seed + 7919 * (i + 1))
        u0, v0 = 2 * i, 2 * i + 1
        flip = False
        last_gen = 0
        try:
            while not stop.is_set():
                op = RemoveEdge(u0, v0) if flip else AddEdge(u0, v0)
                flip = not flip
                token = wclient.submit_many([op])[0].gen
                touch_counts[i] += 1
                floor = max(token, last_gen)  # RYW + monotone-reads
                qu = rng.integers(0, nv, n_queries)
                qv = rng.integers(0, nv, n_queries)
                res = rclient.submit_many(
                    [SameSCC(int(a), int(b)) for a, b in zip(qu, qv)],
                    consistency=Consistency.AT_LEAST(floor))
                gen = res[0].gen
                if gen < floor:
                    raise AssertionError(
                        f"reader {i}: stamp {gen} below floor {floor}")
                last_gen = gen
                q_counts[i] += n_queries
        except Exception as e:
            errors.append(e)

    # compile warmup off the clock: one stream chunk (bucket `chunk`),
    # one touch write (bucket 8), one replica-served query flush
    updater.submit_many(typed_op_stream(nv, chunk, step=1 << 20,
                                        add_frac=add_frac, seed=seed))
    warm_floor = GraphClient(writer).submit_many([AddEdge(0, 1)])[0].gen
    GraphClient(writer, broker=rset).submit_many(
        [SameSCC(0, 1)], consistency=Consistency.AT_LEAST(warm_floor))

    threads = [threading.Thread(target=reader, args=(i,), daemon=True)
               for i in range(readers)]
    applied = accepted = step = 0
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    try:
        next_due = t0
        while applied < n_ops:
            n = min(chunk, n_ops - applied)
            ops = typed_op_stream(nv, n, step=step, add_frac=add_frac,
                                  seed=seed)
            results = updater.submit_many(ops)
            accepted += sum(r.value for r in results)
            applied += n
            step += 1
            next_due += pace_s
            delay = next_due - time.perf_counter()
            if delay > 0 and applied < n_ops:
                time.sleep(delay)
    finally:
        stop.set()
        for t in threads:
            t.join()
        rs_stats = rset.stats()
        rset.stop()
        writer.close()
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    queries = sum(q_counts)
    touches = sum(touch_counts)
    rep = StreamReport(
        replicas=replicas, readers=readers, ops=applied,
        accepted=accepted, touches=touches, queries=queries,
        wall_s=round(wall, 4),
        pace_ms=round(pace_s * 1e3, 1),
        poll_ms=round(poll_interval * 1e3, 1),
        ops_per_s=int(applied / wall),
        queries_per_s=int(queries / wall),
        combined_per_s=int((applied + touches + queries) / wall),
        routed_fresh=rs_stats["routed_fresh"],
        routed_stale=rs_stats["routed_stale"],
        replica_gen_waits=rs_stats["gen_waits"],
    )
    return rep


def writer_child(directory: str, *, nv: int = 256, steps: int = 10_000,
                 chunk: int = 64, seed: int = 0, pace_s: float = 0.0,
                 snapshot_every: int = 0, ha: bool = False,
                 lease_ttl_s: float = 0.5):
    """Crash-smoke victim: durable ingest loop, one 'gen <g>' line per
    committed chunk on stdout (the harness watches for progress, then
    SIGKILLs this process mid-stream).  ``ha=True`` makes it a *leased*
    writer: SIGKILL leaves a stale lease behind for
    :func:`promote_after_crash` to take over."""
    from repro.api import GraphClient
    from repro.ckpt.durable import DurableService
    from repro.core import graph_state as gs
    from repro.launch.stream import typed_op_stream

    lease = None
    if ha:
        from repro.ha.lease import FileLease
        lease = FileLease(directory, owner=f"writer-{os.getpid()}",
                          ttl_s=lease_ttl_s)
        assert lease.try_acquire(), \
            "writer child could not take the lease (store not fresh?)"
    cfg = _writer_config(nv)
    svc = DurableService(
        cfg, directory, state=gs.all_singletons(cfg), buckets=(chunk,),
        proactive_grow=True, sync_every=1, segment_bytes=16 << 10,
        snapshot_every=snapshot_every, snapshot_keep=1_000_000,
        trim_on_snapshot=False, lease=lease)  # keep the full WAL: the
    #                              verifier's scratch oracle replays
    #                              from gen 0
    client = GraphClient(svc)
    for step in range(steps):
        ops = typed_op_stream(nv, chunk, step=step, add_frac=0.7,
                              seed=seed)
        client.submit_many(ops)
        print(f"gen {svc.gen}", flush=True)
        if pace_s:
            time.sleep(pace_s)


def replica_child(directory: str, *, replica_id: int = 0,
                  until_gen: int = 0, duration_s: float = 120.0,
                  poll_interval: float = 0.05) -> int:
    """Out-of-process replica: tail the store at ``directory``, report
    ``replica <id> gen <g>`` lines, exit 0 once ``until_gen`` is
    reached (3 on the ``duration_s`` safety timeout).  The supervised
    parent SIGKILLs / restarts these at will."""
    from repro.core.replicas import Replica

    rep = Replica(directory, replica_id, query_buckets=(8,),
                  poll_interval=poll_interval)
    deadline = time.monotonic() + duration_s
    code = 3
    try:
        while time.monotonic() < deadline:
            print(f"replica {replica_id} gen {rep.gen}", flush=True)
            if rep.gen >= until_gen:
                code = 0
                break
            time.sleep(poll_interval)
    finally:
        rep.stop()
    return code


def supervised_stream(directory: str, *, replicas: int = 2,
                      steps: int = 48, chunk: int = 24, nv: int = 192,
                      pace_s: float = 0.08, seed: int = 0,
                      kill_child_after: float | None = None,
                      child_wait_s: float = 90.0,
                      max_restarts_per_slot: int = 3) -> dict:
    """Supervised multi-process serving: parent writer + N replica
    child processes, restart-on-death; returns a summary dict, raises
    AssertionError when a slot fails to converge (restarts exhausted or
    safety timeout)."""
    from repro.api import GraphClient
    from repro.ckpt.durable import DurableService
    from repro.core import graph_state as gs
    from repro.launch.stream import typed_op_stream

    cfg = _writer_config(nv, edge_capacity=2048)
    writer = DurableService(
        cfg, directory, state=gs.all_singletons(cfg), buckets=(chunk,),
        proactive_grow=True, sync_every=1, segment_bytes=32 << 10,
        snapshot_every=16)
    client = GraphClient(writer)
    final_gen = steps  # one committed generation per chunk

    def spawn(slot: int) -> subprocess.Popen:
        return subprocess.Popen(
            [sys.executable, "-m", "repro.launch.replica",
             "--replica-child", "--id", str(slot), "--dir", directory,
             "--until-gen", str(final_gen),
             "--duration", str(child_wait_s)])

    children = [spawn(i) for i in range(replicas)]
    restarts = [0] * replicas
    kill_at = None if kill_child_after is None \
        else time.monotonic() + kill_child_after
    killed = False

    def reap():
        """Restart any child that died without reaching the target (a
        clean exit 0 means it converged and is done)."""
        for i, p in enumerate(children):
            rc = p.poll()
            if rc is None or rc == 0:
                continue
            if restarts[i] >= max_restarts_per_slot:
                raise AssertionError(
                    f"replica slot {i} died with rc={rc} and is out of "
                    f"restarts")
            restarts[i] += 1
            children[i] = spawn(i)

    try:
        for step in range(steps):
            client.submit_many(typed_op_stream(
                nv, chunk, step=step, add_frac=0.7, seed=seed))
            if kill_at is not None and not killed \
                    and time.monotonic() >= kill_at:
                os.kill(children[0].pid, signal.SIGKILL)
                killed = True
            reap()
            time.sleep(pace_s)
        assert writer.gen == final_gen, (writer.gen, final_gen)
        # children converge on their own once the last record is
        # durable; keep supervising (a late SIGKILL race is restarted)
        deadline = time.monotonic() + child_wait_s
        while time.monotonic() < deadline:
            reap()
            if all(p.poll() == 0 for p in children):
                break
            time.sleep(0.1)
        codes = [p.poll() for p in children]
        if any(c != 0 for c in codes):
            raise AssertionError(
                f"replica children did not converge to gen "
                f"{final_gen}: exit codes {codes}")
    finally:
        for p in children:
            if p.poll() is None:
                p.kill()
                p.wait()
        writer.close()
    if kill_child_after is not None and sum(restarts) == 0:
        raise AssertionError(
            "SIGKILL was injected but no child restart happened")
    return {"replicas": replicas, "gen": final_gen,
            "killed": int(killed), "restarts": sum(restarts)}


def verify_recovery(directory: str) -> dict:
    """Recover the (possibly crash-torn) store and prove the two
    independent recovery paths agree bit-for-bit; returns a summary
    dict, raises on any divergence."""
    import jax

    from repro.ckpt.durable import DurableService, scratch_replay

    recovered = DurableService.open(directory, snapshot_every=0)
    oracle = scratch_replay(directory)
    if recovered.gen != oracle.gen:
        raise AssertionError(
            f"recovery diverged: snapshot+tail at gen {recovered.gen}, "
            f"scratch replay at gen {oracle.gen}")
    for a, b in zip(jax.tree_util.tree_leaves(recovered.state),
                    jax.tree_util.tree_leaves(oracle.state)):
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            raise AssertionError("recovery diverged: state leaves differ")
    summary = {"gen": recovered.gen,
               "replayed_records": recovered.replayed_wal_records,
               "live_edges": recovered.stats()["live_edges"]}
    recovered.close()
    return summary


def promote_after_crash(directory: str, *, owner: str = "promoter",
                        lease_ttl_s: float = 0.5, wait_s: float = 30.0,
                        extra_chunks: int = 4, chunk: int = 64,
                        nv: int = 256, seed: int = 0) -> dict:
    """Process-level failover: take over a SIGKILLed ``--ha`` writer's
    store.  Waits out the dead writer's lease TTL, promotes a fresh
    :class:`Replica` (epoch bump + fence + tail drain), appends
    ``extra_chunks`` more chunks as the epoch-``E+1`` leader, and
    proves a resurrected writer at the dead epoch is refused with
    nothing written.  Raises on timeout or a split-brain breach; the
    store is left with a *mixed-epoch* WAL for ``--verify-recovery``."""
    from repro.api import GraphClient
    from repro.ckpt import oplog
    from repro.ckpt.durable import wal_dir
    from repro.core.replicas import Replica
    from repro.fault import errors as fault_errors
    from repro.ha.lease import FileLease
    from repro.launch.stream import typed_op_stream

    lease = FileLease(directory, owner=owner, ttl_s=lease_ttl_s)
    info = lease.peek()
    old_epoch = info.epoch if info is not None \
        else oplog.newest_epoch(wal_dir(directory))
    rep = Replica(directory, 0, query_buckets=(8,), poll_interval=0.05)
    leader = None
    deadline = time.monotonic() + wait_s
    try:
        while leader is None:
            try:
                # no snapshots: --verify-recovery's scratch oracle
                # replays the full mixed-epoch WAL from gen 0
                leader = rep.promote(lease, sync_every=1,
                                     segment_bytes=16 << 10,
                                     snapshot_every=0)
            except fault_errors.Unavailable:
                if time.monotonic() >= deadline:
                    raise AssertionError(
                        f"dead writer's lease never went stale within "
                        f"{wait_s}s (ttl={lease_ttl_s}s)")
                time.sleep(lease_ttl_s / 4)
        gen_at_takeover = leader.gen
        client = GraphClient(leader)
        for i in range(extra_chunks):
            client.submit_many(typed_op_stream(
                nv, chunk, step=(1 << 19) + i, add_frac=0.7, seed=seed))
        # split-brain probe: the dead writer's epoch must be refused
        # with nothing written
        wdir = wal_dir(directory)
        before = sorted((f, os.path.getsize(os.path.join(wdir, f)))
                        for f in os.listdir(wdir))
        try:
            zombie = oplog.OpLogWriter(wdir, start_gen=leader.gen,
                                       epoch=old_epoch)
            zombie.close()
            raise AssertionError(
                "resurrected old-epoch writer was NOT fenced")
        except fault_errors.Fenced:
            pass
        after = sorted((f, os.path.getsize(os.path.join(wdir, f)))
                       for f in os.listdir(wdir))
        if after != before:
            raise AssertionError(
                "the fenced resurrect probe left bytes in the WAL dir")
        return {"gen_at_takeover": gen_at_takeover, "gen": leader.gen,
                "old_epoch": old_epoch, "new_epoch": leader.epoch,
                "extra_chunks": extra_chunks}
    finally:
        if leader is not None:
            leader.close()
        rep.stop()


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", required=True, help="durable store root")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--steps", type=int, default=64)
    ap.add_argument("--chunk", type=int, default=64)
    ap.add_argument("--nv", type=int, default=1024)
    ap.add_argument("--readers", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--writer-child", action="store_true",
                    help="run the crash-smoke victim writer")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="writer-child: async snapshot period in gens")
    ap.add_argument("--ha", action="store_true",
                    help="writer-child: hold a write lease (SIGKILL "
                         "leaves it stale for --promote-after-crash)")
    ap.add_argument("--lease-ttl", type=float, default=0.5,
                    help="lease TTL in seconds for --ha / promotion")
    ap.add_argument("--promote-after-crash", action="store_true",
                    help="take over a SIGKILLed --ha writer's store: "
                         "promote a replica, append as the new epoch, "
                         "probe the fence")
    ap.add_argument("--verify-recovery", action="store_true",
                    help="recover the store and check both recovery "
                         "paths agree bit-for-bit")
    ap.add_argument("--replica-child", action="store_true",
                    help="run one out-of-process replica (supervised "
                         "mode spawns these)")
    ap.add_argument("--id", type=int, default=0,
                    help="replica-child: replica slot id")
    ap.add_argument("--until-gen", type=int, default=0,
                    help="replica-child: exit 0 once this generation "
                         "is tailed")
    ap.add_argument("--duration", type=float, default=120.0,
                    help="replica-child: safety timeout in seconds")
    ap.add_argument("--supervised", action="store_true",
                    help="multi-process serving: parent writer + "
                         "restart-supervised replica children")
    ap.add_argument("--kill-child-after", type=float, default=None,
                    help="supervised: SIGKILL replica child 0 after "
                         "this many seconds (restart injection)")
    args = ap.parse_args()
    if args.replica_child:
        sys.exit(replica_child(args.dir, replica_id=args.id,
                               until_gen=args.until_gen,
                               duration_s=args.duration))
    if args.supervised:
        rep = supervised_stream(args.dir, replicas=args.replicas,
                                steps=args.steps, chunk=args.chunk,
                                nv=args.nv, seed=args.seed,
                                kill_child_after=args.kill_child_after)
        print("supervised OK: " + " | ".join(f"{k}={v}"
                                             for k, v in rep.items()))
        return
    if args.writer_child:
        writer_child(args.dir, nv=args.nv, steps=args.steps,
                     chunk=args.chunk, seed=args.seed,
                     snapshot_every=args.snapshot_every, ha=args.ha,
                     lease_ttl_s=args.lease_ttl)
        return
    if args.promote_after_crash:
        summary = promote_after_crash(args.dir, chunk=args.chunk,
                                      nv=args.nv, seed=args.seed,
                                      lease_ttl_s=args.lease_ttl)
        print("promote OK: " + " | ".join(f"{k}={v}"
                                          for k, v in summary.items()))
        return
    if args.verify_recovery:
        summary = verify_recovery(args.dir)
        print("recovery OK: " + " | ".join(f"{k}={v}"
                                           for k, v in summary.items()))
        return
    rep = run_replicated_stream(args.dir, replicas=args.replicas,
                                n_ops=args.steps * args.chunk,
                                chunk=args.chunk, nv=args.nv,
                                readers=args.readers, seed=args.seed)
    print(rep.pretty())


if __name__ == "__main__":
    main()
