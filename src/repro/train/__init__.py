from repro.train import trainer  # noqa: F401
