"""Fault-tolerant training loop.

Production behaviors implemented (and unit-tested):

  * **checkpoint/restart**: the full step state -- params, AdamW moments,
    error-feedback residuals, RNG key, data cursor (step) -- is saved
    atomically every ``ckpt_every`` steps; on construction the trainer
    restores the latest intact checkpoint and resumes mid-run.  Because
    the data pipeline is a pure function of the step, a preempted-and-
    resumed run is *bit-identical* to an uninterrupted one
    (tests/test_trainer.py::test_preemption_resume_identical).
  * **straggler surveillance**: per-step wall time vs a rolling median;
    outliers beyond ``straggler_factor``× are counted and logged.  On a
    real fleet this signal feeds the preempt-and-reshard controller; here
    it is the hook + the bookkeeping.
  * **gradient compression**: optional int8 error-feedback path on the
    (pod-axis) gradient reduction (optim/compression.py).
  * **donation**: train_step donates params/opt state buffers, so the
    update is in-place at the XLA level.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint
from repro.optim import compression, optimizer


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 20
    keep_ckpts: int = 3
    log_every: int = 10
    grad_compression: bool = False
    pod_axis: Optional[str] = None  # axis name for the compressed psum
    straggler_factor: float = 3.0


class Trainer:
    def __init__(self, loss_fn: Callable, params, opt_cfg:
                 optimizer.AdamWConfig, cfg: TrainerConfig,
                 data_fn: Callable[[int], dict]):
        """loss_fn(params, batch) -> (loss, metrics); data_fn(step) -> batch."""
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.data_fn = data_fn
        self.loss_fn = loss_fn
        self.state = {
            "params": params,
            "opt": optimizer.init(params),
            "ef": (compression.init(params)
                   if cfg.grad_compression else None),
            "rng": jax.random.PRNGKey(0),
        }
        self.step = 0
        self.metrics_log = []
        self.step_times = []
        self.straggler_events = 0
        self._build()
        self._maybe_restore()

    def _build(self):
        cfg, opt_cfg = self.cfg, self.opt_cfg

        def train_step(state, batch):
            def lf(p):
                return self.loss_fn(p, batch)

            (loss, metrics), grads = jax.value_and_grad(
                lf, has_aux=True)(state["params"])
            ef = state["ef"]
            if ef is not None:
                grads, ef = compression.compressed_psum(
                    grads, ef, cfg.pod_axis)
            params, opt, m2 = optimizer.update(
                grads, state["opt"], state["params"], opt_cfg)
            metrics = dict(metrics, loss=loss, **m2)
            return {"params": params, "opt": opt, "ef": ef,
                    "rng": jax.random.fold_in(state["rng"], 1)}, metrics

        self.train_step = jax.jit(train_step, donate_argnums=(0,))

    def _maybe_restore(self):
        if self.cfg.ckpt_dir is None:
            return
        restored, step = checkpoint.restore(self.cfg.ckpt_dir, self.state)
        if restored is not None:
            self.state = restored
            self.step = int(step)

    def save(self):
        if self.cfg.ckpt_dir is not None:
            checkpoint.save(self.cfg.ckpt_dir, self.step, self.state,
                            keep=self.cfg.keep_ckpts)

    def _watch_straggler(self, dt: float):
        self.step_times.append(dt)
        hist = self.step_times[-50:]
        if len(hist) >= 10:
            med = sorted(hist)[len(hist) // 2]
            if dt > self.cfg.straggler_factor * med:
                self.straggler_events += 1

    def run(self, steps: Optional[int] = None):
        end = self.step + steps if steps is not None else \
            self.cfg.total_steps
        while self.step < end:
            batch = self.data_fn(self.step)
            t0 = time.perf_counter()
            self.state, metrics = self.train_step(self.state, batch)
            jax.block_until_ready(metrics["loss"])
            self._watch_straggler(time.perf_counter() - t0)
            self.step += 1
            if self.step % self.cfg.log_every == 0 or self.step == end:
                self.metrics_log.append(
                    (self.step, {k: float(v) for k, v in metrics.items()}))
            if self.cfg.ckpt_dir is not None and \
                    self.step % self.cfg.ckpt_every == 0:
                self.save()
        return self.metrics_log
