"""Fanout neighbor sampler for minibatch GNN training (minibatch_lg shape).

The large-graph shape (232k nodes / 114M edges, batch_nodes=1024,
fanout 15-10) cannot be trained full-batch; GraphSAGE-style sampled training
needs a *real* neighbor sampler.  This one is jit-able and deterministic:

  * the graph lives in CSR form (``indptr``, ``indices``) built once on host,
  * per minibatch, layer ``l`` samples ``fanout[l]`` neighbors of every
    frontier node with replacement (uniform), in one vectorized gather --
    sampling with replacement keeps every shape static, which is both the
    TPU-friendly and the GraphSAGE-paper-sanctioned choice,
  * isolated nodes self-loop so downstream segment ops stay well-defined.

The output is a padded "block" per layer: (src_idx, dst_idx) pairs local to
the minibatch's node set, exactly what the GNN ``*_step`` functions consume.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class CSRGraph(NamedTuple):
    indptr: jax.Array   # int32[N+1]
    indices: jax.Array  # int32[E]


class SampledBlock(NamedTuple):
    """One message-passing block: edges from sampled srcs into dst frontier."""
    src: jax.Array      # int32[n_dst * fanout]  (global node ids)
    dst_local: jax.Array  # int32[n_dst * fanout] (position in dst frontier)
    n_dst: int


def build_csr(src: np.ndarray, dst: np.ndarray, num_nodes: int) -> CSRGraph:
    """Host-side CSR build (outgoing adjacency of ``dst`` per ``src``).

    Sorted by src; O(E log E) once per graph.
    """
    order = np.argsort(src, kind="stable")
    s, d = np.asarray(src)[order], np.asarray(dst)[order]
    counts = np.bincount(s, minlength=num_nodes)
    indptr = np.zeros(num_nodes + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSRGraph(indptr=jnp.asarray(indptr, jnp.int32),
                    indices=jnp.asarray(d, jnp.int32))


def sample_block(csr: CSRGraph, frontier: jax.Array, fanout: int,
                 key: jax.Array) -> Tuple[SampledBlock, jax.Array]:
    """Sample ``fanout`` neighbors (with replacement) for each frontier node.

    Returns the block plus the next frontier (= sampled srcs, flattened).
    Nodes with zero out-degree sample themselves (self-loop) so shapes and
    aggregations stay total.
    """
    n = frontier.shape[0]
    start = jnp.take(csr.indptr, frontier)
    end = jnp.take(csr.indptr, frontier + 1)
    deg = end - start
    r = jax.random.randint(key, (n, fanout), 0, jnp.iinfo(jnp.int32).max)
    # uniform in [0, deg); deg==0 -> self-loop
    off = jnp.where(deg[:, None] > 0, r % jnp.maximum(deg, 1)[:, None], 0)
    idx = start[:, None] + off
    nbr = jnp.take(csr.indices, idx)  # [n, fanout]
    nbr = jnp.where(deg[:, None] > 0, nbr, frontier[:, None])
    src = nbr.reshape(-1)
    dst_local = jnp.repeat(jnp.arange(n, dtype=jnp.int32), fanout)
    return SampledBlock(src=src, dst_local=dst_local, n_dst=n), src


def sample_blocks(csr: CSRGraph, seeds: jax.Array, fanouts: Sequence[int],
                  key: jax.Array):
    """Multi-layer fanout sampling (innermost layer first, GraphSAGE order).

    Layer l's frontier is the flattened neighbor set of layer l-1 (with
    duplicates -- dedup would break static shapes; aggregation is unaffected
    because messages are averaged per dst).

    Returns (blocks, input_nodes): blocks[0] is applied first (largest
    frontier), input_nodes is the node set whose raw features are gathered.
    """
    blocks = []
    frontier = seeds
    keys = jax.random.split(key, len(fanouts))
    for l, f in enumerate(fanouts):
        blk, frontier = sample_block(csr, frontier, f, keys[l])
        blocks.append(blk)
    blocks.reverse()  # apply from the widest layer inward
    return blocks, frontier


def make_synthetic_csr(num_nodes: int, avg_degree: int, seed: int = 0
                       ) -> CSRGraph:
    """Deterministic synthetic power-law-ish digraph for benchmarks/tests."""
    rng = np.random.default_rng(seed)
    e = num_nodes * avg_degree
    # preferential-attachment flavored: square a uniform to skew hubs
    src = (rng.random(e) ** 2 * num_nodes).astype(np.int64) % num_nodes
    dst = rng.integers(0, num_nodes, e)
    keep = src != dst
    return build_csr(src[keep], dst[keep], num_nodes)
