"""Batched small-graph packing (the ``molecule`` shape: 30 nodes x batch 128).

Many small graphs are packed into one big disjoint graph so a single
segment-op message-passing pass covers the whole batch -- the standard
JAX/jraph-style trick, rebuilt here without the dependency.

Shapes are static: every graph is padded to ``max_nodes`` / ``max_edges``;
masks carry validity.  ``graph_id`` maps nodes to their graph for readout.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class PackedGraphs(NamedTuple):
    src: jax.Array        # int32[B * max_edges]   (global node index)
    dst: jax.Array        # int32[B * max_edges]
    edge_mask: jax.Array  # bool [B * max_edges]
    node_mask: jax.Array  # bool [B * max_nodes]
    graph_id: jax.Array   # int32[B * max_nodes]
    n_graphs: int
    max_nodes: int


def pack(srcs, dsts, n_nodes, max_nodes: int, max_edges: int) -> PackedGraphs:
    """Host-side packer.  ``srcs/dsts``: list of int arrays per graph."""
    b = len(srcs)
    src = np.zeros((b, max_edges), np.int32)
    dst = np.zeros((b, max_edges), np.int32)
    emask = np.zeros((b, max_edges), bool)
    nmask = np.zeros((b, max_nodes), bool)
    for i, (s, d, n) in enumerate(zip(srcs, dsts, n_nodes)):
        e = len(s)
        assert e <= max_edges and n <= max_nodes
        src[i, :e] = s
        dst[i, :e] = d
        emask[i, :e] = True
        nmask[i, :n] = True
    base = (np.arange(b, dtype=np.int32) * max_nodes)[:, None]
    gid = np.repeat(np.arange(b, dtype=np.int32)[:, None], max_nodes, 1)
    return PackedGraphs(
        src=jnp.asarray((src + base).reshape(-1)),
        dst=jnp.asarray((dst + base).reshape(-1)),
        edge_mask=jnp.asarray(emask.reshape(-1)),
        node_mask=jnp.asarray(nmask.reshape(-1)),
        graph_id=jnp.asarray(gid.reshape(-1)),
        n_graphs=b,
        max_nodes=max_nodes,
    )


def pack_dense_batch(batch: int, n_nodes: int, n_edges: int, seed: int = 0
                     ) -> PackedGraphs:
    """Synthetic molecule batch: ``batch`` random connected digraphs."""
    rng = np.random.default_rng(seed)
    srcs, dsts = [], []
    for _ in range(batch):
        # random spanning chain + extra edges => connected-ish molecule
        perm = rng.permutation(n_nodes)
        chain_s, chain_d = perm[:-1], perm[1:]
        extra = n_edges - (n_nodes - 1)
        es = rng.integers(0, n_nodes, extra)
        ed = rng.integers(0, n_nodes, extra)
        srcs.append(np.concatenate([chain_s, es]).astype(np.int32))
        dsts.append(np.concatenate([chain_d, ed]).astype(np.int32))
    return pack(srcs, dsts, [n_nodes] * batch, n_nodes, n_edges)
