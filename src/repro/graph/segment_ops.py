"""Segment/scatter primitives: the message-passing substrate.

JAX has no native EmbeddingBag and only BCOO sparse, so every sparse op the
GNN / recsys / SCC stacks need is built here from ``jnp.take`` +
``jax.ops.segment_*`` (which lower to efficient scatter/gather on TPU).

All functions are shape-polymorphic, jit-able, and differentiable where that
makes sense (segment_softmax, embedding_bag).  ``num_segments`` is always a
*static* int so the results are fixed-shape and pjit-friendly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum(data, segment_ids, num_segments: int):
    return jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)


def segment_max(data, segment_ids, num_segments: int):
    return jax.ops.segment_max(data, segment_ids, num_segments=num_segments)


def segment_min(data, segment_ids, num_segments: int):
    return jax.ops.segment_min(data, segment_ids, num_segments=num_segments)


def segment_mean(data, segment_ids, num_segments: int, eps: float = 1e-9):
    tot = segment_sum(data, segment_ids, num_segments)
    cnt = segment_sum(jnp.ones(data.shape[:1], data.dtype), segment_ids,
                      num_segments)
    cnt = jnp.maximum(cnt, eps)
    return tot / cnt.reshape((num_segments,) + (1,) * (data.ndim - 1))


def segment_std(data, segment_ids, num_segments: int, eps: float = 1e-5):
    """Per-segment standard deviation (PNA-style aggregator)."""
    mean = segment_mean(data, segment_ids, num_segments)
    sq = segment_mean(data * data, segment_ids, num_segments)
    var = jnp.maximum(sq - mean * mean, 0.0)
    return jnp.sqrt(var + eps)


def segment_softmax(logits, segment_ids, num_segments: int):
    """Numerically-stable softmax within each segment (GAT edge softmax)."""
    seg_max = segment_max(logits, segment_ids, num_segments)
    # empty segments produce -inf max; gather is safe, result unused.
    shifted = logits - jnp.take(seg_max, segment_ids, axis=0)
    ex = jnp.exp(shifted)
    denom = segment_sum(ex, segment_ids, num_segments)
    denom = jnp.take(denom, segment_ids, axis=0)
    return ex / jnp.maximum(denom, 1e-30)


def segment_normalize(data, segment_ids, num_segments: int, eps: float = 1e-9):
    """L2-normalize each segment's vector sum (capsule squash helper)."""
    s = segment_sum(data, segment_ids, num_segments)
    n = jnp.linalg.norm(s, axis=-1, keepdims=True)
    return s / jnp.maximum(n, eps)


def embedding_bag(table, ids, offsets=None, *, mode: str = "sum",
                  weights=None):
    """EmbeddingBag: gather rows of ``table`` and reduce per bag.

    JAX has no ``nn.EmbeddingBag``; this is the canonical construction
    (``jnp.take`` + ``segment_sum``) the mandate asks for.

    Args:
      table:   [V, D] embedding matrix.
      ids:     either int[B, L] (fixed-size bags; pad with id<0 to mask) or
               int[N] flat ids used together with ``offsets``.
      offsets: optional int[B] start offsets into flat ``ids`` (torch
               EmbeddingBag semantics).  When given, ``ids`` must be 1-D.
      mode:    'sum' | 'mean' | 'max'.
      weights: optional per-id weights (same shape as ids) for weighted sum.

    Returns [B, D].
    """
    if offsets is not None:
        n = ids.shape[0]
        b = offsets.shape[0]
        # bag id of each flat position: count of offsets <= pos, minus 1
        pos = jnp.arange(n)
        bag = jnp.sum(pos[:, None] >= offsets[None, :], axis=1) - 1
        valid = ids >= 0
        rows = jnp.take(table, jnp.maximum(ids, 0), axis=0)
        if weights is not None:
            rows = rows * weights[:, None]
        rows = jnp.where(valid[:, None], rows, 0.0)
        if mode == "sum":
            return segment_sum(rows, bag, b)
        if mode == "mean":
            cnt = segment_sum(valid.astype(table.dtype), bag, b)
            return segment_sum(rows, bag, b) / jnp.maximum(cnt, 1.0)[:, None]
        if mode == "max":
            rows = jnp.where(valid[:, None], rows, -jnp.inf)
            out = segment_max(rows, bag, b)
            return jnp.where(jnp.isfinite(out), out, 0.0)
        raise ValueError(mode)

    # fixed-shape [B, L] bags
    b, l = ids.shape
    valid = ids >= 0
    rows = jnp.take(table, jnp.maximum(ids, 0), axis=0)  # [B, L, D]
    if weights is not None:
        rows = rows * weights[..., None]
    rows = jnp.where(valid[..., None], rows, 0.0)
    if mode == "sum":
        return jnp.sum(rows, axis=1)
    if mode == "mean":
        cnt = jnp.sum(valid, axis=1, keepdims=True).astype(table.dtype)
        return jnp.sum(rows, axis=1) / jnp.maximum(cnt, 1.0)
    if mode == "max":
        rows = jnp.where(valid[..., None], rows, -jnp.inf)
        out = jnp.max(rows, axis=1)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(mode)


def scatter_or(dst_bool, index, src_bool):
    """dst[index] |= src for boolean arrays (frontier push)."""
    return dst_bool.at[index].max(src_bool)


def coo_spmm(src, dst, edge_val, x, num_nodes: int):
    """y = A @ x with A given as COO (src -> dst messages).

    y[d] = sum over edges e with dst[e]=d of edge_val[e] * x[src[e]].
    ``edge_val`` may be None (unweighted adjacency) or float[E].
    """
    msg = jnp.take(x, src, axis=0)
    if edge_val is not None:
        msg = msg * edge_val.reshape((-1,) + (1,) * (x.ndim - 1))
    return segment_sum(msg, dst, num_nodes)


def degree(dst, num_nodes: int, dtype=jnp.float32):
    return segment_sum(jnp.ones(dst.shape, dtype), dst, num_nodes)
