from repro.graph import segment_ops, sampler, batching  # noqa: F401
