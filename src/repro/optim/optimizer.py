"""AdamW + schedules + global-norm clipping, pytree-native.

ZeRO-1 note: optimizer state tensors inherit the parameter's sharding and
are additionally sharded along the 'data' axis where a parameter is
replicated over it (see launch/partition.zero1_specs) -- the classic
optimizer-state partitioning, expressed purely through PartitionSpecs so
pjit/GSPMD inserts the reduce-scatter/all-gather pair.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # 'cosine' | 'linear' | 'const'
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    m: object
    v: object
    count: jax.Array


def init(params) -> OptState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return OptState(m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params),
                    count=jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * \
            0.5 * (1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_frac) * frac
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def update(grads, state: OptState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = state.count + 1
    lr = schedule(cfg, count)
    b1, b2 = cfg.b1, cfg.b2

    def upd(m, v, g, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        mhat = m / (1 - b1 ** count.astype(jnp.float32))
        vhat = v / (1 - b2 ** count.astype(jnp.float32))
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        new_p = (p.astype(jnp.float32)
                 - lr * (step_ + cfg.weight_decay * p.astype(jnp.float32)))
        return m, v, new_p.astype(p.dtype)

    flat_m, tdef = jax.tree.flatten(state.m)
    flat_v = jax.tree.leaves(state.v)
    flat_g = jax.tree.leaves(grads)
    flat_p = jax.tree.leaves(params)
    new = [upd(m, v, g, p) for m, v, g, p in
           zip(flat_m, flat_v, flat_g, flat_p)]
    new_m = tdef.unflatten([x[0] for x in new])
    new_v = tdef.unflatten([x[1] for x in new])
    new_p = tdef.unflatten([x[2] for x in new])
    return new_p, OptState(new_m, new_v, count), {
        "grad_norm": gnorm, "lr": lr}
