from repro.optim import compression, optimizer  # noqa: F401
