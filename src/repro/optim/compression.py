"""int8 error-feedback gradient compression for the cross-pod all-reduce.

At 512+ chips the pod-to-pod (DCN/ICI inter-pod) links carry one gradient
all-reduce per step; int8 quantization cuts those bytes 4× (bf16) while
error feedback keeps the *accumulated* quantization error bounded, so SGD
convergence is provably unaffected (Seide et al. / Karimireddy et al.,
error-feedback SGD).

Protocol per tensor:  e' = g + err;  q = round(e' / s), s = max|e'| / 127;
transmit (q, s);  err <- e' - q·s.  The reduction runs on the dequantized
values (psum of q·s); only the pod axis uses it -- intra-pod reductions
stay full precision.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    err: object  # pytree matching grads


def init(grads_like) -> EFState:
    return EFState(err=jax.tree.map(
        lambda g: jnp.zeros_like(g, jnp.float32), grads_like))


def compress(g, err):
    e = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(e)) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(e / scale), -127, 127).astype(jnp.int8)
    new_err = e - q.astype(jnp.float32) * scale
    return q, scale, new_err


def decompress(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, ef: EFState, axis_name: str | None):
    """Quantize -> psum -> dequantize with error feedback.

    axis_name=None (single-pod / tests) still quantizes locally so the
    error-feedback dynamics are exercised end-to-end.
    """
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef.err)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = compress(g, e)
        deq = decompress(q, s)
        if axis_name is not None:
            deq = jax.lax.pmean(deq, axis_name)
        out_g.append(deq.astype(g.dtype))
        out_e.append(ne)
    return tdef.unflatten(out_g), EFState(err=tdef.unflatten(out_e))
