"""High-availability primitives for the durable write path.

:mod:`repro.ha.lease` is the leadership protocol: a file-based lease
whose monotonically bumped epoch IS the WAL fencing token
(:mod:`repro.ckpt.oplog`), so write leadership and log authority cannot
diverge.  :class:`repro.ckpt.durable.DurableService` holds the lease;
:meth:`repro.core.replicas.Replica.promote` takes it over.
"""
from repro.ha.lease import FileLease, LeaseInfo

__all__ = ["FileLease", "LeaseInfo"]
