"""File-based write lease: leadership whose fencing token IS the WAL epoch.

The durable writer is the one single point of failure PR 9 left standing:
replicas survive kills, but a dead writer leaves the store read-only
forever.  This module is the coordination half of automatic failover --
a lease file (``<dir>/LEASE``) names the current writer and its
**epoch**, and the epoch doubles as the WAL fencing token
(:mod:`repro.ckpt.oplog`): a promotion bumps the epoch here *first*,
then fences the log at that epoch, so log authority and leadership can
never point at different nodes.

Protocol (single shared filesystem, the paper's shared-memory framing
lifted to processes):

* **fresh acquire** -- publish ``"<epoch> <owner>"`` at epoch 0 via an
  atomic ``O_EXCL``-style link (content is complete before the name
  exists; two racers get exactly one winner);
* **heartbeat renewal** -- the holder re-reads the file (verifying the
  content is still its own) and bumps the mtime; liveness is mtime age
  against ``ttl_s``.  A renewal that finds foreign content raises a
  typed :class:`~repro.fault.errors.LeaseLost`;
* **takeover** -- only once the lease is stale (age > ttl).  The new
  epoch is claimed via an ``O_EXCL`` claim file (unique winner per
  epoch), the observed epoch is re-verified under the claim, and the
  lease is atomically ``os.replace``-d with ``"<epoch+1> <owner>"``.
  Losers see either the claim or the fresh lease and stand down.
  A claim whose owner died mid-takeover goes stale itself (mtime age)
  and is removed by the next claimant;
* **clean release** -- backdates the mtime, so a graceful shutdown hands
  off after one poll instead of a full TTL; :meth:`FileLease.abandon`
  (the crash hook) just stops heartbeating, modelling SIGKILL.

The lease alone is *advisory*: split-brain safety comes from the WAL
fence written at the taken-over epoch -- even a holder that never
notices the takeover has every subsequent append refused with
:class:`~repro.fault.errors.Fenced`, nothing written.
"""
from __future__ import annotations

import os
import threading
import time
from typing import NamedTuple

from repro.fault import errors as fault_errors

__all__ = ["FileLease", "LeaseInfo", "LEASE_NAME"]

LEASE_NAME = "LEASE"


class LeaseInfo(NamedTuple):
    """One observation of the lease file."""
    epoch: int
    owner: str
    age_s: float


class FileLease:
    """One contender's handle on the write lease of a store directory.

    ``try_acquire`` never blocks and never steals a live lease; call it
    again after ``ttl_s`` to attempt a takeover.  A successful acquire
    sets :attr:`epoch` -- pass it to the WAL writer as its fencing
    token.  ``auto-renew`` via :meth:`start_heartbeat`; a failed renewal
    flips :attr:`valid` False and records :attr:`lost_reason`.
    """

    def __init__(self, directory: str, owner: str, *, ttl_s: float = 1.0):
        os.makedirs(directory, exist_ok=True)
        self._dir = directory
        self._path = os.path.join(directory, LEASE_NAME)
        self.owner = str(owner)
        self.ttl_s = float(ttl_s)
        self.epoch = -1            # valid only while held
        self._held = False
        self.lost_reason: BaseException | None = None
        self.takeovers = 0
        self.renewals = 0
        self._hb_stop: threading.Event | None = None
        self._hb_thread: threading.Thread | None = None

    # ------------------------------------------------------------ observe --

    @property
    def path(self) -> str:
        return self._path

    @property
    def held(self) -> bool:
        return self._held

    @property
    def valid(self) -> bool:
        """True while this contender holds the lease and no renewal has
        discovered a takeover."""
        return self._held and self.lost_reason is None

    def peek(self) -> LeaseInfo | None:
        """Read the lease file without touching it (None when absent or
        unreadable)."""
        try:
            with open(self._path) as f:
                txt = f.read()
            mtime = os.path.getmtime(self._path)
        except OSError:
            return None
        parts = txt.split()
        if len(parts) < 2:
            return None
        try:
            epoch = int(parts[0])
        except ValueError:
            return None
        return LeaseInfo(epoch, parts[1], max(0.0, time.time() - mtime))

    # ------------------------------------------------------------ acquire --

    def _publish_fresh(self) -> bool:
        """Atomically create the lease at epoch 0: write the full content
        to a private temp name, then ``os.link`` it into place -- the
        name appears only with complete content, and exactly one of any
        concurrent racers wins the link."""
        tmp = f"{self._path}.tmp_{os.getpid()}_{id(self):x}"
        with open(tmp, "w") as f:
            f.write(f"0 {self.owner}\n")
            f.flush()
            os.fsync(f.fileno())
        try:
            os.link(tmp, self._path)
        except FileExistsError:
            return False
        finally:
            try:
                os.remove(tmp)
            except OSError:
                pass
        self.epoch = 0
        return True

    def _takeover(self, seen: LeaseInfo) -> bool:
        """Bump to ``seen.epoch + 1`` iff the lease still looks exactly
        like ``seen`` (stale, same epoch) while we hold the epoch's
        claim file -- the unique-winner guard."""
        new_epoch = seen.epoch + 1
        claim = f"{self._path}.claim_{new_epoch:08d}"
        try:
            fd = os.open(claim, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
            os.close(fd)
        except FileExistsError:
            # a racing claimant owns this epoch -- unless it died mid-
            # takeover: a claim past its own TTL is abandoned, clear it
            # so the next attempt can proceed
            try:
                if time.time() - os.path.getmtime(claim) > self.ttl_s:
                    os.remove(claim)
            except OSError:
                pass
            return False
        try:
            cur = self.peek()
            if cur is None or cur.epoch != seen.epoch \
                    or cur.age_s < self.ttl_s:
                return False  # the lease moved while we claimed
            tmp = f"{self._path}.tmp_{os.getpid()}_{id(self):x}"
            with open(tmp, "w") as f:
                f.write(f"{new_epoch} {self.owner}\n")
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self._path)
        finally:
            try:
                os.remove(claim)
            except OSError:
                pass
        self.epoch = new_epoch
        self.takeovers += 1
        return True

    def try_acquire(self) -> bool:
        """Acquire the lease if free or stale; never blocks, never steals
        a live lease.  True on success (with :attr:`epoch` set)."""
        if self.valid:
            return True
        info = self.peek()
        if info is None:
            ok = self._publish_fresh()
        elif info.owner == self.owner and info.epoch == self.epoch \
                and self._held:
            ok = True  # still ours (a renewal raced our doubt)
        elif info.age_s < self.ttl_s:
            return False  # holder is alive
        else:
            ok = self._takeover(info)
        if ok:
            self._held = True
            self.lost_reason = None
        return ok

    # -------------------------------------------------------------- renew --

    def renew(self):
        """Heartbeat: verify the lease content is still ours, then bump
        the mtime.  Raises :class:`~repro.fault.errors.LeaseLost` (and
        flips :attr:`valid`) when the lease was taken over."""
        if not self._held:
            raise fault_errors.LeaseLost("lease is not held")
        info = self.peek()
        if info is None or info.epoch != self.epoch \
                or info.owner != self.owner:
            e = fault_errors.LeaseLost(
                f"lease {self._path!r} taken over: now {info}, "
                f"we were epoch {self.epoch} owner {self.owner!r}")
            self.lost_reason = e
            raise e
        os.utime(self._path)
        self.renewals += 1

    def start_heartbeat(self, interval_s: float | None = None):
        """Renew on a background thread every ``interval_s`` (default
        ttl/3).  The thread exits -- flipping :attr:`valid` -- on the
        first failed renewal; the holder checks :attr:`valid` on its
        write path and self-fences."""
        if self._hb_thread is not None and self._hb_thread.is_alive():
            return
        interval = self.ttl_s / 3 if interval_s is None else interval_s
        self._hb_stop = threading.Event()

        def _run(stop=self._hb_stop):
            while not stop.wait(interval):
                try:
                    self.renew()
                except (fault_errors.LeaseLost, OSError) as e:
                    if self.lost_reason is None:
                        self.lost_reason = e
                    return

        self._hb_thread = threading.Thread(
            target=_run, name=f"scc-lease-{self.owner}", daemon=True)
        self._hb_thread.start()

    def _stop_heartbeat(self):
        if self._hb_stop is not None:
            self._hb_stop.set()
        if self._hb_thread is not None:
            self._hb_thread.join()
            self._hb_thread = None

    # ------------------------------------------------------------ handoff --

    def release(self):
        """Graceful handoff: stop heartbeating and backdate the lease's
        mtime so the next contender takes over on its next poll instead
        of waiting out a full TTL.  The epoch stays on disk -- the
        successor still bumps it, keeping the fence monotone."""
        self._stop_heartbeat()
        if self._held and self.lost_reason is None:
            info = self.peek()
            if info is not None and info.epoch == self.epoch \
                    and info.owner == self.owner:
                try:
                    os.utime(self._path, (0, 0))
                except OSError:
                    pass
        self._held = False

    def abandon(self):
        """Crash simulation (chaos): stop heartbeating WITHOUT touching
        the file -- exactly what SIGKILL leaves behind.  Failover then
        costs one full TTL of staleness, the realistic path."""
        self._stop_heartbeat()
        self._held = False

    def stats(self) -> dict:
        return {"lease_epoch": self.epoch, "lease_held": self._held,
                "lease_valid": self.valid, "lease_owner": self.owner,
                "lease_renewals": self.renewals,
                "lease_takeovers": self.takeovers}
