"""Write-ahead typed-op log for the streaming SCC service.

Durability layer of the paper's on-line mode: every update chunk a
:class:`repro.ckpt.durable.DurableService` commits is first appended here
as ONE record -- the chunk is the service's atomicity unit (all-or-nothing
under ``_apply_lock``), so the log's record granularity matches the commit
granularity exactly and replaying a record prefix always lands on a
committed generation boundary.

Log layout (``<dir>/wal_<seq>.seg``, monotonically increasing ``seq``)::

    segment  := header record*
    header   := MAGIC("SCCWAL02") i64(base_gen) i64(epoch)     (v2)
              | MAGIC("SCCWAL01") i64(base_gen)                (v1, read
                                                  back-compat, epoch 0)
    record   := u32(REC_MAGIC) u32(len(payload)) u32(crc32(payload)) payload
    payload  := i64(gen_before) u32(n_ops)
                i32[n_ops](kind) i32[n_ops](u) i32[n_ops](v)

All integers little-endian.  ``gen_before`` is the committed generation
the chunk was applied on top of; successive records carry strictly
increasing ``gen_before`` (every chunk bumps the generation at least
once), which is what lets recovery seek the replay point for any
snapshot generation by a plain scan.

Writer epochs + fencing (the split-brain guard of the HA story,
docs/ARCHITECTURE.md §Failover):

* every v2 segment header carries the **writer epoch** that stamped it;
  epochs are monotone across the segment sequence (v1 segments read as
  epoch 0, so a pre-epoch log upgrades in place);
* a **fence marker** (``fence_<epoch>``, empty file created ``O_EXCL``)
  declares every lower epoch stale.  :func:`write_fence` and every
  :class:`OpLogWriter` mutation serialize on an advisory ``wal.lock``
  flock, and the writer re-checks :func:`newest_epoch` under that lock
  *before* each append/rotation -- so once a promotion has fenced epoch
  ``e``, a resurrected epoch-``<e`` writer's next append raises a typed
  :class:`~repro.fault.errors.Fenced` with **nothing written**, and any
  append that did complete before the fence is durable and visible to
  the promoter's tail drain (exactly-once across failover);
* the promotion order is therefore: take the lease (epoch bump) ->
  ``write_fence`` -> ``repair_tail`` -> drain the tail -> open the new
  epoch's writer segment.

Crash safety:

* a record is torn iff the file ends mid-record or the CRC mismatches;
  readers treat the first invalid record as end-of-segment (the valid
  prefix is kept -- ``read_segment`` reports whether the tail was clean);
* the writer appends with configurable fsync batching (``sync_every``
  records per fsync; 1 = fsync every commit) and can atomically
  ``rollback_last()`` (truncate) when the in-memory apply of the logged
  chunk fails, so failed chunks never survive into recovery;
* segment rotation closes the current file after ``segment_bytes`` and
  opens ``wal_<seq+1>.seg`` whose header carries the current generation,
  so whole segments can be dropped by :func:`trim` once a snapshot
  covers them;
* :class:`LogTailer` is the replica-side incremental reader: it remembers
  its (segment, offset) cursor, re-polls a torn tail (the writer may
  simply not have finished the record yet), and only advances to the
  next segment once one exists -- a torn record followed by a newer
  segment means real corruption and raises.
"""
from __future__ import annotations

import contextlib
import os
import re
import struct
import zlib
from typing import Iterator, List, NamedTuple, Tuple

import numpy as np

try:
    import fcntl
except ImportError:  # non-POSIX: advisory lock degrades to a no-op
    fcntl = None

from repro.fault import errors as fault_errors
from repro.fault.inject import fs_fsync, fs_open

__all__ = ["OpLogWriter", "LogTailer", "OpRecord", "SegmentHeader",
           "read_segment", "read_log", "list_segments", "repair_tail",
           "drop_unapplied_tail", "trim", "segment_header",
           "segment_base_gen", "parse_segment_header", "write_fence",
           "list_fences", "newest_epoch", "SEG_HEADER_BYTES"]

_SEG_MAGIC_V1 = b"SCCWAL01"
_SEG_MAGIC_V2 = b"SCCWAL02"
_REC_MAGIC = 0xA11C0DE5
_REC_HDR = struct.Struct("<III")          # magic, payload len, crc32
_PAYLOAD_HDR = struct.Struct("<qI")       # gen_before, n_ops
_SEG_HDR_V1 = struct.Struct("<8sq")       # magic, base_gen
_SEG_HDR_V2 = struct.Struct("<8sqq")      # magic, base_gen, epoch
SEG_HEADER_BYTES = _SEG_HDR_V2.size       # what the writer emits today

_SEG_RE = re.compile(r"wal_(\d{8})\.seg")
_FENCE_RE = re.compile(r"fence_(\d{8})")
_LOCK_NAME = "wal.lock"


@contextlib.contextmanager
def _wal_lock(directory: str):
    """Advisory per-directory mutex (flock) serializing writer mutations
    against :func:`write_fence`: the fence check and the bytes it guards
    are atomic with respect to a concurrent promotion.  Deliberately NOT
    routed through the fault-injection shims -- the lock is coordination,
    not data, and an injected EIO here would fail appends the durability
    ledger never sees."""
    if fcntl is None:
        yield
        return
    fd = os.open(os.path.join(directory, _LOCK_NAME),
                 os.O_CREAT | os.O_RDWR, 0o644)
    try:
        fcntl.flock(fd, fcntl.LOCK_EX)
        yield
    finally:
        os.close(fd)  # close releases the flock


class OpRecord(NamedTuple):
    """One durably logged update chunk."""
    gen_before: int
    kind: np.ndarray  # int32[n]
    u: np.ndarray     # int32[n]
    v: np.ndarray     # int32[n]


def _seg_path(directory: str, seq: int) -> str:
    return os.path.join(directory, f"wal_{seq:08d}.seg")


def list_segments(directory: str) -> List[Tuple[int, str]]:
    """Sorted [(seq, path)] of the directory's segment files."""
    out = []
    if not os.path.isdir(directory):
        return out
    for name in os.listdir(directory):
        m = _SEG_RE.fullmatch(name)
        if m:
            out.append((int(m.group(1)), os.path.join(directory, name)))
    return sorted(out)


class SegmentHeader(NamedTuple):
    """Parsed segment header: base generation, writer epoch, and the
    header's on-disk size (v1 and v2 differ -- every reader must offset
    records by the *segment's own* header size)."""
    base_gen: int
    epoch: int
    size: int


def parse_segment_header(buf: bytes, path: str = "<buf>") -> SegmentHeader:
    """Decode a segment header (v2, or v1 read as epoch 0); raises a
    typed :class:`~repro.fault.errors.WalCorrupt` on a bad/short magic
    so the replica resync path can dispatch on it."""
    if len(buf) >= _SEG_HDR_V2.size and buf[:8] == _SEG_MAGIC_V2:
        _, base_gen, epoch = _SEG_HDR_V2.unpack_from(buf, 0)
        return SegmentHeader(base_gen, epoch, _SEG_HDR_V2.size)
    if len(buf) >= _SEG_HDR_V1.size and buf[:8] == _SEG_MAGIC_V1:
        _, base_gen = _SEG_HDR_V1.unpack_from(buf, 0)
        return SegmentHeader(base_gen, 0, _SEG_HDR_V1.size)
    raise fault_errors.WalCorrupt(
        f"bad WAL segment header in {path!r}")


def segment_header(path: str) -> SegmentHeader:
    with open(path, "rb") as f:
        buf = f.read(_SEG_HDR_V2.size)
    return parse_segment_header(buf, path)


def segment_base_gen(path: str) -> int:
    return segment_header(path).base_gen


def _fence_path(directory: str, epoch: int) -> str:
    return os.path.join(directory, f"fence_{epoch:08d}")


def list_fences(directory: str) -> List[int]:
    """Sorted epochs with a fence marker in the directory."""
    out = []
    if not os.path.isdir(directory):
        return out
    for name in os.listdir(directory):
        m = _FENCE_RE.fullmatch(name)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def newest_epoch(directory: str) -> int:
    """The directory's current writer epoch: the max over fence markers
    and the newest readable segment header (0 for an empty or pre-epoch
    store).  A writer whose epoch is below this value is stale."""
    top = 0
    fences = list_fences(directory)
    if fences:
        top = fences[-1]
    for _, path in reversed(list_segments(directory)):
        try:
            return max(top, segment_header(path).epoch)
        except (OSError, fault_errors.WalCorrupt):
            continue  # torn header (writer died mid-create): look back
    return top


def write_fence(directory: str, epoch: int) -> str:
    """Durably fence every writer epoch below ``epoch``: create the
    marker ``O_EXCL`` (idempotent if it already exists) under the WAL
    lock, so no stale append can interleave with the fence becoming
    visible -- after this returns, an epoch-``<epoch`` writer's next
    append raises :class:`~repro.fault.errors.Fenced` having written
    nothing, and every append that completed before it is durable on
    disk for the promoter's tail drain."""
    os.makedirs(directory, exist_ok=True)
    path = _fence_path(directory, epoch)
    with _wal_lock(directory):
        try:
            fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            return path
        try:
            os.fsync(fd)
        finally:
            os.close(fd)
        try:  # make the marker's directory entry itself durable
            dfd = os.open(directory, os.O_RDONLY)
            try:
                os.fsync(dfd)
            finally:
                os.close(dfd)
        except OSError:
            pass
    return path


def _encode_record(gen_before: int, kind, u, v) -> bytes:
    kind = np.ascontiguousarray(kind, "<i4")
    u = np.ascontiguousarray(u, "<i4")
    v = np.ascontiguousarray(v, "<i4")
    assert kind.shape == u.shape == v.shape and kind.ndim == 1
    payload = (_PAYLOAD_HDR.pack(int(gen_before), kind.shape[0])
               + kind.tobytes() + u.tobytes() + v.tobytes())
    return _REC_HDR.pack(_REC_MAGIC, len(payload),
                         zlib.crc32(payload)) + payload


def _decode_payload(payload: bytes) -> OpRecord:
    gen_before, n = _PAYLOAD_HDR.unpack_from(payload, 0)
    arrs = np.frombuffer(payload, "<i4", count=3 * n,
                         offset=_PAYLOAD_HDR.size)
    return OpRecord(gen_before, arrs[:n].copy(), arrs[n:2 * n].copy(),
                    arrs[2 * n:].copy())


def _scan_records(buf: bytes, offset: int
                  ) -> Iterator[Tuple[int, OpRecord]]:
    """Yield (end_offset, record) for every complete valid record from
    ``offset``; stops (without raising) at the first torn/invalid one."""
    n = len(buf)
    while offset + _REC_HDR.size <= n:
        magic, plen, crc = _REC_HDR.unpack_from(buf, offset)
        if magic != _REC_MAGIC:
            return
        end = offset + _REC_HDR.size + plen
        if end > n:
            return
        payload = buf[offset + _REC_HDR.size:end]
        if zlib.crc32(payload) != crc or plen < _PAYLOAD_HDR.size:
            return
        yield end, _decode_payload(payload)
        offset = end


def read_segment(path: str) -> Tuple[List[OpRecord], bool, int]:
    """Read one segment; returns ``(records, clean, valid_end)``.

    ``clean`` is False when the file ends in a torn/invalid record;
    ``valid_end`` is the byte offset of the end of the valid prefix
    (what a tail repair would truncate to)."""
    with open(path, "rb") as f:
        buf = f.read()
    try:
        hdr = parse_segment_header(buf, path)
    except fault_errors.WalCorrupt:
        return [], False, 0
    records = []
    end = hdr.size
    for end, rec in _scan_records(buf, hdr.size):
        records.append(rec)
    return records, end == len(buf), end


def read_log(directory: str, from_gen: int = 0) -> List[OpRecord]:
    """All replayable records with ``gen_before >= from_gen``, in order.

    Stops at the first torn record *of the last segment* (normal crash
    tail).  A torn record in a non-final segment means the suffix of the
    log is unreachable; the records after it are dropped (they were
    never acknowledged as a contiguous history) -- recovery converges to
    the longest valid prefix.
    """
    out: List[OpRecord] = []
    for _, path in list_segments(directory):
        records, clean, _ = read_segment(path)
        out.extend(r for r in records if r.gen_before >= from_gen)
        if not clean:
            break
    return out


def repair_tail(directory: str) -> int:
    """Truncate the final segment to its valid record prefix.

    Recovery MUST call this before opening a new writer segment: readers
    treat a torn record as end-of-log only while it is the last thing in
    the log, so leaving torn bytes behind a newer segment would orphan
    every later record.  Returns the number of bytes dropped."""
    dropped = 0
    while True:
        segs = list_segments(directory)
        if not segs:
            return dropped
        _, path = segs[-1]
        _, clean, valid_end = read_segment(path)
        if clean:
            return dropped
        size = os.path.getsize(path)
        if valid_end <= 0:
            # not even a valid header survived: the segment holds no
            # acknowledged data -- a 0-byte stub would still read as
            # torn and orphan any segment a new writer opens after it
            os.remove(path)
            dropped += size
            continue
        with fs_open(path, "r+b") as f:
            f.truncate(valid_end)
            f.flush()
            fs_fsync(f)
        return dropped + (size - valid_end)


def drop_unapplied_tail(directory: str, gen: int) -> int:
    """Truncate trailing records of the final segment whose
    ``gen_before >= gen`` -- valid on disk but never applied by the
    writer (a failed append whose own best-effort rollback could not
    reach the disk).  The writer calls this on (re)attach with its
    committed generation: every chunk it committed advanced the
    generation past its own ``gen_before``, so a record at or past
    ``gen`` was never acknowledged and would shadow the *different*
    chunk the writer logs next at the same generation.  Returns the
    bytes dropped; raises ``OSError`` when the truncate cannot be made
    durable (the caller's recovery probe must then fail)."""
    segs = list_segments(directory)
    if not segs:
        return 0
    _, path = segs[-1]
    with open(path, "rb") as f:
        buf = f.read()
    try:
        hdr = parse_segment_header(buf, path)
    except fault_errors.WalCorrupt:
        return 0
    cut = None
    prev = hdr.size
    for end, rec in _scan_records(buf, hdr.size):
        if cut is None and rec.gen_before >= gen:
            cut = prev  # gen_before is strictly increasing: everything
            #             from here on is unapplied
        prev = end
    if cut is None:
        return 0
    with fs_open(path, "r+b") as f:
        f.truncate(cut)
        f.flush()
        fs_fsync(f)
    return len(buf) - cut


def trim(directory: str, min_gen: int) -> int:
    """Drop whole segments no longer needed to replay from ``min_gen``:
    segment i may go iff segment i+1 exists and starts at or below
    ``min_gen`` (every record with ``gen_before >= min_gen`` then still
    lives in later segments).  Returns the number of files removed."""
    segs = list_segments(directory)
    removed = 0
    for (_, path), (_, nxt) in zip(segs, segs[1:]):
        if segment_base_gen(nxt) <= min_gen:
            os.remove(path)
            removed += 1
        else:
            break
    return removed


class OpLogWriter:
    """Appender with fsync batching, rotation, tail rollback -- and epoch
    fencing: every segment is stamped with this writer's ``epoch``, and
    every append/rotation re-checks (under the WAL lock) that no higher
    epoch has fenced the directory.  ``epoch=None`` adopts the store's
    current epoch (:func:`newest_epoch`) -- the single-writer default;
    an HA writer passes its lease's fencing token explicitly so a
    resurrected stale leader can never adopt its way past a fence."""

    def __init__(self, directory: str, *, segment_bytes: int = 4 << 20,
                 sync_every: int = 1, start_gen: int = 0,
                 epoch: int | None = None):
        os.makedirs(directory, exist_ok=True)
        self._dir = directory
        self._segment_bytes = int(segment_bytes)
        self._sync_every = max(1, int(sync_every))
        self._unsynced = 0
        self._last_span: Tuple[int, int] | None = None  # (start, end)
        top = newest_epoch(directory)
        if epoch is None:
            epoch = top
        elif epoch < top:
            raise fault_errors.Fenced(
                f"writer epoch {epoch} is stale: {directory!r} is fenced "
                f"at epoch {top}; nothing was written")
        self.epoch = int(epoch)
        segs = list_segments(directory)
        self._seq = segs[-1][0] if segs else 0
        self._f = None
        self._open_segment(self._seq + 1, start_gen)
        self.appended = 0
        self.syncs = 0
        self.rotations = 0
        self.rollbacks = 0

    def _assert_unfenced(self, horizon_seq: int):
        """Raise :class:`~repro.fault.errors.Fenced` if a fence marker or
        a foreign segment at/after ``horizon_seq`` carries a higher epoch.
        Caller holds the WAL lock, so the verdict cannot race a
        concurrent :func:`write_fence`."""
        top = -1
        for name in os.listdir(self._dir):
            m = _FENCE_RE.fullmatch(name)
            if m:
                top = max(top, int(m.group(1)))
                continue
            m = _SEG_RE.fullmatch(name)
            if m and int(m.group(1)) >= horizon_seq:
                try:
                    top = max(top, segment_header(
                        os.path.join(self._dir, name)).epoch)
                except (OSError, fault_errors.WalCorrupt):
                    pass
        if top > self.epoch:
            raise fault_errors.Fenced(
                f"writer epoch {self.epoch} fenced by epoch {top} in "
                f"{self._dir!r}; nothing was written")

    def _open_segment(self, seq: int, base_gen: int):
        if self._f is not None:
            self.sync()
            self._f.close()
            self._f = None
        with _wal_lock(self._dir):
            self._assert_unfenced(seq)
            try:
                self._f = fs_open(_seg_path(self._dir, seq), "xb")
            except FileExistsError as e:
                # another writer created it first: by protocol it fenced
                # us before doing so, or it is a misconfigured twin --
                # either way this writer must not touch the log again
                raise fault_errors.Fenced(
                    f"segment {seq} already exists in {self._dir!r}: "
                    f"another writer owns this log") from e
            self._seq = seq
            self._f.write(_SEG_HDR_V2.pack(_SEG_MAGIC_V2, int(base_gen),
                                           self.epoch))
            self._f.flush()
            fs_fsync(self._f)
        self._pos = _SEG_HDR_V2.size
        self._last_span = None

    @property
    def path(self) -> str:
        return _seg_path(self._dir, self._seq)

    def append(self, gen_before: int, kind, u, v) -> None:
        """Durably append one chunk record (write-ahead: call BEFORE
        applying; fsync per ``sync_every`` appends).

        A failed append rolls its own record's bytes back (best-effort)
        before re-raising: the chunk was never acknowledged, so it must
        not survive on disk -- recovery and replica tails would replay
        it ahead of a *different* chunk later logged at the same
        generation, losing the acked one to the ``gen_before < gen``
        skip.  Earlier records of the same fsync batch are preserved
        (they were acknowledged).

        Raises :class:`~repro.fault.errors.Fenced` -- with nothing
        written -- when a higher epoch owns the directory; the check and
        the write are atomic under the WAL lock, so an append can only
        land entirely before a fence (durable, drained by the promoter)
        or fail entirely after it."""
        rec = _encode_record(gen_before, kind, u, v)
        start = self._pos
        with _wal_lock(self._dir):
            self._assert_unfenced(self._seq + 1)
            try:
                self._f.write(rec)
                self._pos += len(rec)
                self._last_span = (start, self._pos)
                self._unsynced += 1
                if self._unsynced >= self._sync_every:
                    self.sync()
            except OSError:
                self._discard_to(start)
                raise
        self.appended += 1

    def rollback_last(self) -> None:
        """Truncate the last appended record (the apply of its chunk
        failed -- a failed chunk must not survive into recovery)."""
        if self._last_span is None:
            raise fault_errors.WalGap(
                "no record to roll back in this segment")
        start, _ = self._last_span
        self._f.flush()
        self._f.truncate(start)
        self._f.seek(start)
        fs_fsync(self._f)
        self._pos = start
        self._last_span = None
        self._unsynced = 0
        self.rollbacks += 1

    def _discard_to(self, pos: int) -> None:
        """Best-effort truncate to ``pos``; errors are swallowed (the
        store is entering its degraded path; ``drop_unapplied_tail`` at
        re-attach covers whatever could not reach the disk)."""
        try:
            self._f.flush()
            self._f.truncate(pos)
            self._f.seek(pos)
            fs_fsync(self._f)
        except OSError:
            pass
        self._pos = pos
        self._last_span = None
        self._unsynced = 0

    def discard_tail(self) -> None:
        """Best-effort truncate to the last known-good byte boundary --
        the ``DurableService.sync()`` failure path, where every record
        up to ``_pos`` was acknowledged (batched appends) and must
        survive; a failed ``append`` rolls back its own record before
        this can run."""
        self._discard_to(self._pos)

    def maybe_rotate(self, gen: int) -> bool:
        """Rotate to a fresh segment (header stamped ``gen``) once the
        current one exceeds ``segment_bytes``; call between chunks."""
        if self._pos < self._segment_bytes:
            return False
        self._open_segment(self._seq + 1, gen)
        self.rotations += 1
        return True

    def sync(self) -> None:
        if self._unsynced == 0:
            return
        self._f.flush()
        fs_fsync(self._f)
        self._unsynced = 0
        self.syncs += 1

    def close(self) -> None:
        if self._f is not None:
            self.sync()
            self._f.close()
            self._f = None

    def stats(self) -> dict:
        return {"wal_appended": self.appended, "wal_syncs": self.syncs,
                "wal_rotations": self.rotations,
                "wal_rollbacks": self.rollbacks,
                "wal_segment": self._seq, "wal_bytes": self._pos,
                "wal_epoch": self.epoch}


class LogTailer:
    """Replica-side incremental reader: poll for newly completed records.

    Keeps a (segment seq, byte offset) cursor.  A torn record at the
    cursor is *pending*, not corrupt -- the writer may still be flushing
    it -- unless a newer segment already exists, which means the writer
    moved on and the bytes will never complete: that raises
    :class:`~repro.fault.errors.WalCorrupt`.  Segments removed underneath
    the cursor (``trim`` racing a slow tailer) raise
    :class:`~repro.fault.errors.WalTrimmed` -- a resync *signal*, not a
    failure: every trimmed record is covered by a newer snapshot (that is
    the trim precondition), so the owner fast-forwards and keeps going.
    The constructor absorbs the same race itself (segment listed, then
    trimmed before its header is read) by re-listing.
    """

    def __init__(self, directory: str, from_gen: int = 0):
        self._dir = directory
        self._from_gen = int(from_gen)
        for _attempt in range(8):
            segs = list_segments(directory)
            if not segs:
                raise FileNotFoundError(
                    f"no WAL segments in {directory!r}")
            # start at the last segment whose base_gen <= from_gen: every
            # record with gen_before >= from_gen lives at or after it
            start = 0
            try:
                for i, (_, path) in enumerate(segs):
                    try:
                        if segment_base_gen(path) <= self._from_gen:
                            start = i
                    except fault_errors.WalCorrupt:
                        break  # header still being written (or torn):
                        # seek no further; poll() adjudicates pending
                        # vs. corrupt once a cursor sits on it
            except FileNotFoundError:
                continue  # trim raced the listing: re-list, never raise
            break
        else:
            raise fault_errors.WalTrimmed(
                f"segments in {directory!r} kept vanishing while "
                f"seeking generation {from_gen}")
        self._seq = segs[start][0]
        self._offset = 0  # 0 = at segment start, header not yet consumed
        self.polled_records = 0

    @property
    def cursor(self) -> Tuple[int, int]:
        return self._seq, self._offset

    def poll(self, max_records: int | None = None) -> List[OpRecord]:
        """Return records completed since the last poll (possibly [])."""
        out: List[OpRecord] = []
        while max_records is None or len(out) < max_records:
            path = _seg_path(self._dir, self._seq)
            try:
                with open(path, "rb") as f:
                    buf = f.read()
            except FileNotFoundError as e:  # trimmed underneath us
                raise fault_errors.WalTrimmed(
                    f"WAL segment {path!r} was trimmed under the tail "
                    f"cursor; resync from the covering snapshot") from e
            if self._offset == 0:
                # first look at this segment: consume its own header (v1
                # and v2 sizes differ).  A short/bad header is *pending*
                # while this is the newest segment (the writer may be
                # mid-create), corrupt once a newer one exists.
                try:
                    self._offset = parse_segment_header(buf, path).size
                except fault_errors.WalCorrupt:
                    if os.path.exists(_seg_path(self._dir, self._seq + 1)):
                        raise fault_errors.WalCorrupt(
                            f"unreadable WAL segment header in {path!r} "
                            f"but a newer segment exists")
                    break
            for end, rec in _scan_records(buf, self._offset):
                self._offset = end
                if rec.gen_before >= self._from_gen:
                    out.append(rec)
                if max_records is not None and len(out) >= max_records:
                    break
            if max_records is not None and len(out) >= max_records:
                break  # stopped early, not torn: keep the cursor here
            nxt = _seg_path(self._dir, self._seq + 1)
            if not os.path.exists(nxt):
                break
            if self._offset < len(buf):
                raise fault_errors.WalCorrupt(
                    f"WAL segment {path!r} has a torn record at offset "
                    f"{self._offset} but a newer segment exists")
            self._seq += 1
            self._offset = 0
        self.polled_records += len(out)
        return out
