"""Step-fenced atomic checkpointing (npz-based; tensorstore-free).

Write protocol (crash-safe at every point):
  1. serialize the full pytree (params, opt state, RNG, data cursor,
     GraphState, error-feedback state, ...) to ``ckpt_<step>.npz.tmp``;
  2. fsync + rename to ``ckpt_<step>.npz``  (atomic on POSIX);
  3. rewrite ``LATEST`` (tiny file: step + payload checksum) via the same
     tmp+rename dance.

A reader never observes a torn checkpoint: either LATEST points to a fully
renamed npz whose checksum matches, or restore falls back to the previous
one.  ``keep`` bounds disk usage.  Pytree structure is restored from the
flattened key paths, so save/restore round-trips arbitrary nested
dict/list/namedtuple states (shapes re-shard automatically under pjit when
the mesh changes -- elasticity).
"""
from __future__ import annotations

import hashlib
import json
import os
import re
from typing import Any

import jax
import numpy as np

_SEP = "|"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return f"d:{p.key}"
    if isinstance(p, jax.tree_util.SequenceKey):
        return f"s:{p.idx}"
    if isinstance(p, jax.tree_util.GetAttrKey):
        return f"a:{p.name}"
    return f"x:{p}"


def save(directory: str, step: int, tree: Any, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(directory, f"ckpt_{step}.npz")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, path)
    digest = _digest(path)
    latest = os.path.join(directory, "LATEST")
    ltmp = latest + ".tmp"
    with open(ltmp, "w") as f:
        json.dump({"step": step, "file": os.path.basename(path),
                   "sha256": digest}, f)
        f.flush()
        os.fsync(f.fileno())
    os.rename(ltmp, latest)
    _gc(directory, keep)
    return path


def _digest(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _gc(directory: str, keep: int):
    files = sorted(
        (f for f in os.listdir(directory)
         if re.fullmatch(r"ckpt_\d+\.npz", f)),
        key=lambda f: int(re.findall(r"\d+", f)[0]))
    for f in files[:-keep]:
        os.remove(os.path.join(directory, f))


def latest_step(directory: str) -> int | None:
    latest = os.path.join(directory, "LATEST")
    if not os.path.exists(latest):
        return None
    with open(latest) as f:
        meta = json.load(f)
    path = os.path.join(directory, meta["file"])
    if not os.path.exists(path) or _digest(path) != meta["sha256"]:
        # torn LATEST (crash between npz rename and LATEST rewrite, or
        # corruption): fall back to newest intact file
        return _fallback_step(directory)
    return meta["step"]


def _fallback_step(directory: str) -> int | None:
    files = sorted(
        (int(re.findall(r"\d+", f)[0]) for f in os.listdir(directory)
         if re.fullmatch(r"ckpt_\d+\.npz", f)), reverse=True)
    return files[0] if files else None


def restore(directory: str, tree_like: Any, step: int | None = None):
    """Restore into the structure of ``tree_like``.  Returns (tree, step)
    or (None, None) when no checkpoint exists."""
    if step is None:
        step = latest_step(directory)
    if step is None:
        return None, None
    path = os.path.join(directory, f"ckpt_{step}.npz")
    data = np.load(path)
    paths, tdef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path_keys, like in paths:
        key = _SEP.join(_path_str(p) for p in path_keys)
        arr = data[key]
        leaves.append(jax.numpy.asarray(arr, dtype=like.dtype)
                      if hasattr(like, "dtype") else arr)
    return tdef.unflatten(leaves), step
