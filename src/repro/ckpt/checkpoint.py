"""Step-fenced atomic checkpointing (npz-based; tensorstore-free).

Write protocol (crash-safe at every point):
  1. serialize the full pytree (params, opt state, RNG, data cursor,
     GraphState, error-feedback state, ...) to ``ckpt_<step>.npz.tmp``;
  2. fsync + rename to ``ckpt_<step>.npz``  (atomic on POSIX);
  3. rewrite ``LATEST`` (tiny file: step + payload checksum) via the same
     tmp+rename dance.

A reader never observes a torn checkpoint: either LATEST points to a fully
renamed npz whose checksum matches, or restore falls back to the previous
one.  ``keep`` bounds disk usage.  Pytree structure is restored from the
flattened key paths, so save/restore round-trips arbitrary nested
dict/list/namedtuple states (shapes re-shard automatically under pjit when
the mesh changes -- elasticity).
"""
from __future__ import annotations

import hashlib
import json
import os
import re
from typing import Any

import jax
import numpy as np

from repro.fault.inject import fs_fsync, fs_open

_SEP = "|"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return f"d:{p.key}"
    if isinstance(p, jax.tree_util.SequenceKey):
        return f"s:{p.idx}"
    if isinstance(p, jax.tree_util.GetAttrKey):
        return f"a:{p.name}"
    return f"x:{p}"


def save(directory: str, step: int, tree: Any, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(directory, f"ckpt_{step}.npz")
    tmp = path + ".tmp"
    with fs_open(tmp, "wb") as f:
        np.savez(f, **flat)
        f.flush()
        fs_fsync(f)
    os.rename(tmp, path)
    digest = _digest(path)
    latest = os.path.join(directory, "LATEST")
    ltmp = latest + ".tmp"
    with fs_open(ltmp, "w") as f:
        json.dump({"step": step, "file": os.path.basename(path),
                   "sha256": digest}, f)
        f.flush()
        fs_fsync(f)
    os.rename(ltmp, latest)
    _gc(directory, keep)
    return path


def _digest(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _gc(directory: str, keep: int):
    files = sorted(
        (f for f in os.listdir(directory)
         if re.fullmatch(r"ckpt_\d+\.npz", f)),
        key=lambda f: int(re.findall(r"\d+", f)[0]))
    for f in files[:-keep]:
        os.remove(os.path.join(directory, f))


def latest_step(directory: str) -> int | None:
    latest = os.path.join(directory, "LATEST")
    if not os.path.exists(latest):
        return None
    with open(latest) as f:
        meta = json.load(f)
    path = os.path.join(directory, meta["file"])
    if not os.path.exists(path) or _digest(path) != meta["sha256"]:
        # torn LATEST (crash between npz rename and LATEST rewrite, or
        # corruption): fall back to newest intact file
        return _fallback_step(directory)
    return meta["step"]


def _fallback_step(directory: str) -> int | None:
    files = sorted(
        (int(re.findall(r"\d+", f)[0]) for f in os.listdir(directory)
         if re.fullmatch(r"ckpt_\d+\.npz", f)), reverse=True)
    return files[0] if files else None


def restore(directory: str, tree_like: Any, step: int | None = None):
    """Restore into the structure of ``tree_like``.  Returns (tree, step)
    or (None, None) when no checkpoint exists."""
    if step is None:
        step = latest_step(directory)
    if step is None:
        return None, None
    path = os.path.join(directory, f"ckpt_{step}.npz")
    data = np.load(path)
    paths, tdef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path_keys, like in paths:
        key = _SEP.join(_path_str(p) for p in path_keys)
        arr = data[key]
        leaves.append(jax.numpy.asarray(arr, dtype=like.dtype)
                      if hasattr(like, "dtype") else arr)
    return tdef.unflatten(leaves), step


# ------------------------------------------------- graph snapshots ------
# Durability-layer extension (see docs/SERVICE_API.md): a graph snapshot
# is an ordinary step-fenced checkpoint whose step IS the committed
# generation, carrying the GraphState pytree plus a JSON meta leaf that
# records everything recovery needs to resume a *bit-identical* run: the
# GraphConfig fields (edge_capacity changes under growth!) and the
# service knobs that steer growth/compaction decisions.  Replaying the
# WAL tail on top of the restored state with the same knobs reproduces
# the exact generation trajectory and table layout of the uninterrupted
# run -- which is what the crash-injection tests assert.


def _graph_template(cfg):
    """A dtype-correct GraphState skeleton for ``restore`` (shapes come
    from the checkpoint file, only dtypes matter here)."""
    from repro.core import edge_table as et
    from repro.core import graph_state as gs
    z32 = np.zeros((), np.int32)
    return gs.GraphState(
        v_alive=np.zeros((), bool), ccid=z32,
        edges=et.EdgeTable(src=z32, dst=z32,
                           state=np.zeros((), np.int8)),
        n_ccs=z32, gen=z32, overflow=z32)


def save_graph_snapshot(directory: str, state, meta: dict,
                        keep: int = 3) -> str:
    """Checkpoint a committed GraphState at generation ``meta['gen']``.

    ``meta`` must carry ``gen``, a ``cfg`` dict of GraphConfig fields,
    and a ``service`` dict of decision-relevant service knobs."""
    assert {"gen", "cfg", "service"} <= meta.keys()
    blob = np.frombuffer(json.dumps(meta).encode(), np.uint8)
    tree = {"graph": state, "meta": blob}
    return save(directory, int(meta["gen"]), tree, keep)


def load_graph_meta(directory: str, step: int | None = None):
    """(meta dict, step) of a graph snapshot, or (None, None)."""
    if step is None:
        step = latest_step(directory)
    if step is None:
        return None, None
    data = np.load(os.path.join(directory, f"ckpt_{step}.npz"))
    key = next(k for k in data.files if k.endswith("meta"))
    return json.loads(bytes(bytearray(data[key]))), step


def _candidate_steps(directory: str) -> list:
    """Snapshot steps to try, newest first: LATEST's pick, then every
    on-disk step in descending order (recovery falls through corrupt or
    unreadable newer snapshots to older intact ones)."""
    if not os.path.isdir(directory):
        return []
    steps = sorted(
        (int(re.findall(r"\d+", f)[0]) for f in os.listdir(directory)
         if re.fullmatch(r"ckpt_\d+\.npz", f)), reverse=True)
    head = latest_step(directory)
    if head is not None and head in steps:
        steps.remove(head)
        steps.insert(0, head)
    return steps


def restore_graph_snapshot(directory: str, step: int | None = None):
    """Restore ``(state, cfg, meta, step)`` from the latest (or given)
    graph snapshot; ``(None, None, None, None)`` when none exists.

    Without an explicit ``step``, an unreadable newest snapshot (torn
    npz payload, dangling LATEST) is skipped in favour of the next
    older one -- the WAL tail replay covers the difference."""
    from repro.core import graph_state as gs
    candidates = [step] if step is not None else \
        _candidate_steps(directory)
    for s in candidates:
        try:
            meta, s = load_graph_meta(directory, s)
            if meta is None:
                continue
            cfg = gs.GraphConfig(
                **{**meta["cfg"], "region_edge_buckets":
                   tuple(meta["cfg"]["region_edge_buckets"])})
            tree, _ = restore(directory,
                              {"graph": _graph_template(cfg),
                               "meta": np.zeros((), np.uint8)}, s)
            return tree["graph"], cfg, meta, s
        except Exception:
            if step is not None:
                raise  # an explicitly requested step must not degrade
            continue
    return None, None, None, None
